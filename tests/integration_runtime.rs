//! Three-layer integration: the AOT-compiled JAX/Pallas artifacts executed
//! through PJRT must agree with the pure-Rust learners, both per-update
//! and end-to-end through the TreeCV engines.
//!
//! These tests require `make artifacts` to have run; they are skipped (with
//! a loud message) when the artifact directory is absent so that plain
//! `cargo test` works on a fresh checkout.

use treecv::cv::folds::Folds;
use treecv::cv::standard::StandardCv;
use treecv::cv::treecv::TreeCv;
use treecv::cv::CvEngine;
use treecv::data::synth::{SyntheticCovertype, SyntheticYearMsd};
use treecv::data::Dataset;
use treecv::learner::lsqsgd::LsqSgd;
use treecv::learner::pegasos::Pegasos;
use treecv::learner::IncrementalLearner;
use treecv::runtime::xla_learner::{XlaLsqSgd, XlaPegasos};
use treecv::runtime::{artifacts_available, Manifest, PjrtRuntime};

macro_rules! require_artifacts {
    () => {
        if !artifacts_available() {
            eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
            return;
        }
    };
}

fn runtime() -> (PjrtRuntime, Manifest) {
    let rt = PjrtRuntime::cpu().expect("PJRT CPU client");
    let manifest = Manifest::load_default().expect("manifest.txt");
    (rt, manifest)
}

#[test]
fn xla_pegasos_update_matches_rust() {
    require_artifacts!();
    let (rt, manifest) = runtime();
    let d = 54;
    let data = SyntheticCovertype::new(700, 21).generate();
    let idx: Vec<u32> = (0..700).collect();
    let lambda = 1e-3;

    let xla = XlaPegasos::from_manifest(&rt, &manifest, d, lambda).unwrap();
    let mut xm = xla.init();
    xla.update(&mut xm, &data, &idx);

    let rust = Pegasos::new(d, lambda);
    let mut rm = rust.init();
    rust.update(&mut rm, &data, &idx);

    assert_eq!(xm.t as u64, rm.t);
    let rw = rm.weights();
    for j in 0..d {
        assert!(
            (xm.w[j] - rw[j]).abs() <= 2e-3 * (1.0 + rw[j].abs()),
            "w[{j}]: xla {} vs rust {}",
            xm.w[j],
            rw[j]
        );
    }
}

#[test]
fn xla_pegasos_eval_matches_rust() {
    require_artifacts!();
    let (rt, manifest) = runtime();
    let d = 54;
    let data = SyntheticCovertype::new(900, 22).generate();
    let train: Vec<u32> = (0..600).collect();
    let test: Vec<u32> = (600..900).collect();

    let xla = XlaPegasos::from_manifest(&rt, &manifest, d, 1e-3).unwrap();
    let mut xm = xla.init();
    xla.update(&mut xm, &data, &train);
    let xla_err = xla.evaluate(&xm, &data, &test);

    // Evaluate the same weights with host-side scoring: identical decision
    // function ⇒ identical error rate.
    let host_err: f64 = test
        .iter()
        .map(|&i| {
            let score: f32 = xm.w.iter().zip(data.row(i)).map(|(a, b)| a * b).sum();
            treecv::loss::misclassification(score, data.label(i))
        })
        .sum::<f64>()
        / test.len() as f64;
    assert!((xla_err - host_err).abs() < 1e-9, "xla {xla_err} vs host {host_err}");
}

#[test]
fn xla_lsqsgd_matches_rust() {
    require_artifacts!();
    let (rt, manifest) = runtime();
    let d = 90;
    let n = 800;
    let data = SyntheticYearMsd::new(n, 23).generate();
    let idx: Vec<u32> = (0..n as u32).collect();
    let alpha = 1.0 / (n as f64).sqrt();

    let xla = XlaLsqSgd::from_manifest(&rt, &manifest, d, alpha).unwrap();
    let mut xm = xla.init();
    xla.update(&mut xm, &data, &idx);

    let rust = LsqSgd::new(d, alpha);
    let mut rm = rust.init();
    rust.update(&mut rm, &data, &idx);

    assert_eq!(xm.t as u64, rm.t);
    for j in 0..d {
        assert!(
            (xm.wavg[j] - rm.wavg[j]).abs() <= 2e-3 * (1.0 + rm.wavg[j].abs()),
            "wavg[{j}]: xla {} vs rust {}",
            xm.wavg[j],
            rm.wavg[j]
        );
    }
}

/// The full composition: TreeCV driving the XLA-backed learner produces a
/// CV estimate close to TreeCV driving the Rust learner (f32 vs
/// scale-trick numerics differ slightly; estimates must agree tightly).
#[test]
fn treecv_over_xla_learner_matches_rust_learner() {
    require_artifacts!();
    let (rt, manifest) = runtime();
    let d = 54;
    let n = 1_024;
    let data = SyntheticCovertype::new(n, 24).generate();
    let folds = Folds::new(n, 8, 25);
    let lambda = 1e-3;

    let xla = XlaPegasos::from_manifest(&rt, &manifest, d, lambda).unwrap();
    let xla_res = TreeCv::default().run(&xla, &data, &folds);

    let rust = Pegasos::new(d, lambda);
    let rust_res = TreeCv::default().run(&rust, &data, &folds);

    assert!(
        (xla_res.estimate - rust_res.estimate).abs() < 0.02,
        "xla {} vs rust {}",
        xla_res.estimate,
        rust_res.estimate
    );
    assert_eq!(xla_res.ops.points_updated, rust_res.ops.points_updated);
}

/// Standard CV over the XLA learner as well — exercises init-from-scratch
/// per fold and block-wise padding with non-multiple-of-block chunks.
#[test]
fn standard_cv_over_xla_learner_runs_with_ragged_chunks() {
    require_artifacts!();
    let (rt, manifest) = runtime();
    let d = 54;
    let n = 777; // deliberately not a multiple of the 256 block
    let data = SyntheticCovertype::new(n, 26).generate();
    let folds = Folds::new(n, 5, 27);
    let xla = XlaPegasos::from_manifest(&rt, &manifest, d, 1e-3).unwrap();
    let res = StandardCv::default().run(&xla, &data, &folds);
    assert!(res.estimate > 0.0 && res.estimate < 1.0);
    assert_eq!(res.ops.evals, 5);
}

/// The tiny (B=8, d=6) variant: block-boundary behavior with chunk sizes
/// below, at, and above the block size.
#[test]
fn tiny_variant_handles_all_chunk_sizes() {
    require_artifacts!();
    let (rt, manifest) = runtime();
    let d = 6;
    let mut x = Vec::new();
    let mut y = Vec::new();
    let mut rng = treecv::rng::Rng::new(99);
    for _ in 0..40 {
        for _ in 0..d {
            x.push(rng.next_gaussian());
        }
        y.push(if rng.next_f64() < 0.5 { 1.0 } else { -1.0 });
    }
    let data = Dataset::new(x, y, d);
    let xla = XlaPegasos::from_manifest(&rt, &manifest, d, 0.1).unwrap();
    assert_eq!(xla.block(), 8);
    let rust = Pegasos::new(d, 0.1);
    for chunk in [3usize, 8, 11, 40] {
        let idx: Vec<u32> = (0..40).collect();
        let mut xm = xla.init();
        let mut rm = rust.init();
        for c in idx.chunks(chunk) {
            xla.update(&mut xm, &data, c);
            rust.update(&mut rm, &data, c);
        }
        let rw = rm.weights();
        for j in 0..d {
            assert!(
                (xm.w[j] - rw[j]).abs() <= 1e-3 * (1.0 + rw[j].abs()),
                "chunk={chunk} w[{j}]"
            );
        }
    }
}
