//! Cross-module integration tests: the CV engines against every learner,
//! the paper's theorems as executable properties, and randomized
//! property-style sweeps (many seeded trials standing in for proptest,
//! which is unavailable offline).

use treecv::cv::exact::ridge_loocv;
use treecv::cv::executor::TreeCvExecutor;
use treecv::cv::folds::{Folds, Ordering};
use treecv::cv::mergecv::MergeCv;
use treecv::cv::parallel::ParallelTreeCv;
use treecv::cv::standard::StandardCv;
use treecv::cv::treecv::TreeCv;
use treecv::cv::{CvEngine, CvResult, Strategy};
use treecv::data::synth::*;
use treecv::data::Dataset;
use treecv::learner::histdensity::HistogramDensity;
use treecv::learner::kmeans::OnlineKMeans;
use treecv::learner::knn::KnnClassifier;
use treecv::learner::lsqsgd::LsqSgd;
use treecv::learner::multiset::MultisetLearner;
use treecv::learner::naive_bayes::GaussianNb;
use treecv::learner::pegasos::Pegasos;
use treecv::learner::perceptron::Perceptron;
use treecv::learner::ridge::OnlineRidge;
use treecv::learner::IncrementalLearner;
use treecv::rng::Rng;

// ---------------------------------------------------------------------------
// Cross-engine oracle matrix: every learner in `learner/`, all three
// engines. PRs 1–2 covered this matrix piecemeal; these four tests close
// it. Equality tier depends on the learner's arithmetic:
//   * exact (bitwise): models that are exactly order/batching-insensitive
//     (multiset, integer-count histogram, k-NN whose model is the set);
//   * sufficient statistics (tight tolerance): order changes only the f64
//     summation order (gaussian NB, online ridge);
//   * order-sensitive (statistical closeness, paper Theorem 1): pegasos,
//     perceptron, lsqsgd, online k-means.
// In EVERY tier the pooled executor must reproduce sequential TreeCv
// bit for bit at worker counts {1, 3, 8} (Copy strategy always; SaveRevert
// too when revert is exact).
// ---------------------------------------------------------------------------

/// Executor ≡ TreeCv, bitwise, per fold, across worker counts.
fn assert_executor_matches_treecv<L>(
    learner: &L,
    data: &Dataset,
    folds: &Folds,
    seq: &CvResult,
    strategy: Strategy,
) where
    L: IncrementalLearner + Sync,
    L::Model: Send,
{
    for threads in [1usize, 3, 8] {
        let exe =
            TreeCvExecutor::new(strategy, Ordering::Fixed, 5, threads).run(learner, data, folds);
        let ctx = format!("{} threads={threads} {strategy:?}", learner.name());
        assert_eq!(seq.per_fold, exe.per_fold, "{ctx}");
        assert_eq!(seq.ops.points_updated, exe.ops.points_updated, "{ctx}");
        assert_eq!(seq.ops.evals, exe.ops.evals, "{ctx}");
    }
}

/// Standard ≡ TreeCv ≡ executor with a per-fold tolerance (None = bitwise).
fn assert_oracle_matrix<L>(learner: &L, data: &Dataset, k: usize, per_fold_tol: Option<f64>)
where
    L: IncrementalLearner + Sync,
    L::Model: Send,
{
    let folds = Folds::new(data.n, k, 0x0AC1E);
    let tree = TreeCv::new(Strategy::Copy, Ordering::Fixed, 5).run(learner, data, &folds);
    let std_res = StandardCv::new(Ordering::Fixed, 5).run(learner, data, &folds);
    match per_fold_tol {
        None => assert_eq!(tree.per_fold, std_res.per_fold, "{} std-vs-tree", learner.name()),
        Some(tol) => {
            for (i, (a, b)) in tree.per_fold.iter().zip(&std_res.per_fold).enumerate() {
                assert!(
                    (a - b).abs() <= tol,
                    "{} fold {i}: tree {a} vs standard {b} (tol {tol})",
                    learner.name()
                );
            }
        }
    }
    assert_executor_matches_treecv(learner, data, &folds, &tree, Strategy::Copy);
}

#[test]
fn oracle_matrix_exact_learners() {
    let n = 240;
    let dummy = Dataset::new(vec![0.0; n], vec![0.0; n], 1);
    assert_oracle_matrix(&MultisetLearner::new(1), &dummy, 9, None);

    let mix = SyntheticMixture1d::new(330, 61).generate();
    assert_oracle_matrix(&HistogramDensity::new(-8.0, 8.0, 32), &mix, 11, None);

    // k-NN really predicts, and its model is exactly the training set
    // (deterministic tie-breaks), so it is the strongest exact oracle.
    let cover = SyntheticCovertype::new(n, 62).generate();
    assert_oracle_matrix(&KnnClassifier::new(54, 3), &cover, 8, None);
}

#[test]
fn oracle_matrix_sufficient_stats_learners() {
    // Feeding order only permutes the f64 accumulation order of the
    // sufficient statistics, so Standard and TreeCv agree to rounding.
    let cover = SyntheticCovertype::new(400, 63).generate();
    assert_oracle_matrix(&GaussianNb::new(54), &cover, 10, Some(1e-9));

    let year = SyntheticYearMsd::new(150, 64).generate();
    assert_oracle_matrix(&OnlineRidge::new(90, 1.0), &year, 10, Some(1e-6));
}

#[test]
fn oracle_matrix_order_sensitive_learners() {
    // Genuinely order-sensitive updates: Standard and TreeCv feed the
    // same multisets in different orders, so only the Theorem-1
    // statistical closeness holds — asserted on the estimate — while the
    // executor still reproduces TreeCv bitwise.
    let n = 1_500;
    let cover = SyntheticCovertype::new(n, 65).generate();
    let year = SyntheticYearMsd::new(n, 66).generate();
    let blobs = SyntheticBlobs::new(800, 8, 5, 67).generate();

    let folds_of = |data: &Dataset, k: usize| Folds::new(data.n, k, 0x0AC1E);

    let pegasos = Pegasos::new(54, 1e-4);
    let perceptron = Perceptron::new(54);
    let lsq = LsqSgd::with_paper_step(90, n);
    let kmeans = OnlineKMeans::new(8, 5);

    // (learner-specific estimate tolerances; loss scales differ.)
    let folds = folds_of(&cover, 8);
    for (tol, tree, std_res) in [
        (
            0.08,
            TreeCv::new(Strategy::Copy, Ordering::Fixed, 5).run(&pegasos, &cover, &folds),
            StandardCv::new(Ordering::Fixed, 5).run(&pegasos, &cover, &folds),
        ),
        (
            0.08,
            TreeCv::new(Strategy::Copy, Ordering::Fixed, 5).run(&perceptron, &cover, &folds),
            StandardCv::new(Ordering::Fixed, 5).run(&perceptron, &cover, &folds),
        ),
    ] {
        assert!(
            (tree.estimate - std_res.estimate).abs() < tol,
            "tree {} vs standard {} (tol {tol})",
            tree.estimate,
            std_res.estimate
        );
    }
    let tree = TreeCv::new(Strategy::Copy, Ordering::Fixed, 5).run(&pegasos, &cover, &folds);
    assert_executor_matches_treecv(&pegasos, &cover, &folds, &tree, Strategy::Copy);
    let tree = TreeCv::new(Strategy::Copy, Ordering::Fixed, 5).run(&perceptron, &cover, &folds);
    assert_executor_matches_treecv(&perceptron, &cover, &folds, &tree, Strategy::Copy);

    let folds = folds_of(&year, 8);
    let tree = TreeCv::new(Strategy::Copy, Ordering::Fixed, 5).run(&lsq, &year, &folds);
    let std_res = StandardCv::new(Ordering::Fixed, 5).run(&lsq, &year, &folds);
    assert!(
        (tree.estimate - std_res.estimate).abs() < 0.05,
        "lsqsgd: tree {} vs standard {}",
        tree.estimate,
        std_res.estimate
    );
    assert_executor_matches_treecv(&lsq, &year, &folds, &tree, Strategy::Copy);

    let folds = folds_of(&blobs, 8);
    let tree = TreeCv::new(Strategy::Copy, Ordering::Fixed, 5).run(&kmeans, &blobs, &folds);
    let std_res = StandardCv::new(Ordering::Fixed, 5).run(&kmeans, &blobs, &folds);
    let scale = tree.estimate.abs().max(std_res.estimate.abs()).max(1e-9);
    assert!(
        (tree.estimate - std_res.estimate).abs() < 0.5 * scale,
        "kmeans: tree {} vs standard {}",
        tree.estimate,
        std_res.estimate
    );
    assert_executor_matches_treecv(&kmeans, &blobs, &folds, &tree, Strategy::Copy);
}

#[test]
fn oracle_matrix_save_revert_exact_revert_learners() {
    // Every learner whose revert is exact (snapshot undo or lossless
    // integer/center restore): executor SaveRevert ≡ sequential SaveRevert
    // bitwise across worker counts. (Perceptron is excluded — f32 ulp
    // revert, covered with tolerance in tests/integration_executor.rs —
    // as are NB/ridge, whose subtract-based reverts are rounding-exact
    // only.)
    let n = 240;
    let dummy = Dataset::new(vec![0.0; n], vec![0.0; n], 1);
    let mix = SyntheticMixture1d::new(330, 71).generate();
    let cover = SyntheticCovertype::new(600, 72).generate();
    let year = SyntheticYearMsd::new(400, 73).generate();
    let blobs = SyntheticBlobs::new(400, 8, 5, 74).generate();

    macro_rules! check {
        ($learner:expr, $data:expr, $k:expr) => {{
            let folds = Folds::new($data.n, $k, 0x5AFE);
            let seq = TreeCv::new(Strategy::SaveRevert, Ordering::Fixed, 5)
                .run(&$learner, &$data, &folds);
            assert_executor_matches_treecv(
                &$learner,
                &$data,
                &folds,
                &seq,
                Strategy::SaveRevert,
            );
        }};
    }
    check!(MultisetLearner::new(1), dummy, 9);
    check!(HistogramDensity::new(-8.0, 8.0, 32), mix, 11);
    check!(KnnClassifier::new(54, 3), cover, 8);
    check!(Pegasos::new(54, 1e-4), cover, 8);
    check!(LsqSgd::with_paper_step(90, 400), year, 8);
    check!(OnlineKMeans::new(8, 5), blobs, 8);
}

/// Property sweep: for random (n, k, seed), TreeCV == Standard CV exactly
/// for the order-insensitive multiset oracle (Theorem 1 with g ≡ 0).
#[test]
fn prop_treecv_equals_standard_for_oracle() {
    let mut rng = Rng::new(0xABCD);
    for trial in 0..60 {
        let n = 2 + (rng.below(200) as usize);
        let k = 2 + (rng.below((n - 1).min(64) as u64) as usize);
        let seed = rng.next_u64();
        let data = Dataset::new(vec![0.0; n], vec![0.0; n], 1);
        let folds = Folds::new(n, k, seed);
        let l = MultisetLearner::new(1);
        let a = TreeCv::default().run(&l, &data, &folds);
        let b = StandardCv::default().run(&l, &data, &folds);
        assert_eq!(a.per_fold, b.per_fold, "trial {trial}: n={n} k={k} seed={seed}");
    }
}

/// Property sweep: Copy and SaveRevert strategies agree for every learner
/// with exact revert, across random shapes.
#[test]
fn prop_strategies_agree_for_exact_revert_learners() {
    let mut rng = Rng::new(0xBEEF);
    for _ in 0..25 {
        let n = 20 + (rng.below(300) as usize);
        let k = 2 + (rng.below(20) as u64 as usize);
        let seed = rng.next_u64();
        let folds = Folds::new(n, k, seed);

        let data = SyntheticMixture1d::new(n, seed).generate();
        let l = HistogramDensity::new(-8.0, 8.0, 32);
        let a = TreeCv::new(Strategy::Copy, Ordering::Fixed, 1).run(&l, &data, &folds);
        let b = TreeCv::new(Strategy::SaveRevert, Ordering::Fixed, 1).run(&l, &data, &folds);
        assert_eq!(a.per_fold, b.per_fold, "hist n={n} k={k}");

        let blobs = SyntheticBlobs::new(n, 4, 3, seed).generate();
        let l = OnlineKMeans::new(4, 3);
        let a = TreeCv::new(Strategy::Copy, Ordering::Fixed, 1).run(&l, &blobs, &folds);
        let b = TreeCv::new(Strategy::SaveRevert, Ordering::Fixed, 1).run(&l, &blobs, &folds);
        assert_eq!(a.per_fold, b.per_fold, "kmeans n={n} k={k}");
    }
}

/// Theorem 3 as a property: TreeCV's update-point count ≤ n·log₂(2k) for
/// random (n, k), across learners (work counting is learner-independent).
#[test]
fn prop_theorem3_work_bound() {
    let mut rng = Rng::new(0xFACE);
    for _ in 0..80 {
        let n = 4 + (rng.below(500) as usize);
        let k = 2 + (rng.below((n - 1).min(128) as u64) as usize);
        let data = Dataset::new(vec![0.0; n], vec![0.0; n], 1);
        let folds = Folds::new(n, k, rng.next_u64());
        let l = MultisetLearner::new(1);
        let res = TreeCv::default().run(&l, &data, &folds);
        let bound = (n as f64) * ((2 * k) as f64).log2();
        assert!(
            res.ops.points_updated as f64 <= bound + 1e-9,
            "n={n} k={k}: {} > {bound}",
            res.ops.points_updated
        );
    }
}

/// PEGASOS: TreeCV estimate is close to the standard estimate (incremental
/// stability, Theorem 1) even though the learner is order-sensitive.
#[test]
fn pegasos_treecv_close_to_standard() {
    let n = 4_000;
    let data = SyntheticCovertype::new(n, 1).generate();
    let l = Pegasos::new(54, 1e-4);
    for k in [5usize, 10, 50] {
        let folds = Folds::new(n, k, 7);
        let tree = TreeCv::default().run(&l, &data, &folds);
        let std_res = StandardCv::default().run(&l, &data, &folds);
        assert!(
            (tree.estimate - std_res.estimate).abs() < 0.05,
            "k={k}: tree {} vs std {}",
            tree.estimate,
            std_res.estimate
        );
    }
}

/// LSQSGD: same closeness property on the regression task.
#[test]
fn lsqsgd_treecv_close_to_standard() {
    let n = 4_000;
    let data = SyntheticYearMsd::new(n, 2).generate();
    let l = LsqSgd::with_paper_step(90, n);
    let folds = Folds::new(n, 10, 8);
    let tree = TreeCv::default().run(&l, &data, &folds);
    let std_res = StandardCv::default().run(&l, &data, &folds);
    assert!(
        (tree.estimate - std_res.estimate).abs() < 0.01,
        "tree {} vs std {}",
        tree.estimate,
        std_res.estimate
    );
}

/// Naive Bayes: TreeCV == Standard == MergeCV to f64 tolerance.
#[test]
fn naive_bayes_three_engines_agree() {
    let n = 1_500;
    let data = SyntheticCovertype::new(n, 3).generate();
    let l = GaussianNb::new(54);
    let folds = Folds::new(n, 12, 9);
    let tree = TreeCv::default().run(&l, &data, &folds);
    let std_res = StandardCv::default().run(&l, &data, &folds);
    let merge = MergeCv.run(&l, &data, &folds);
    for i in 0..12 {
        assert!((tree.per_fold[i] - std_res.per_fold[i]).abs() < 1e-12);
        assert!((merge.per_fold[i] - std_res.per_fold[i]).abs() < 1e-12);
    }
}

/// Perceptron with sparse save/revert undo: revert is only ulp-accurate
/// (f32 re-subtraction), and the mistake-driven update rule is chaotic in
/// those ulps — a flipped decision cascades. The *estimates* must still be
/// statistically indistinguishable.
#[test]
fn perceptron_save_revert_close_to_copy() {
    let n = 2_000;
    let data = SyntheticCovertype::new(n, 4).generate();
    let l = Perceptron::new(54);
    let folds = Folds::new(n, 16, 10);
    let a = TreeCv::new(Strategy::Copy, Ordering::Fixed, 1).run(&l, &data, &folds);
    let b = TreeCv::new(Strategy::SaveRevert, Ordering::Fixed, 1).run(&l, &data, &folds);
    assert!((a.estimate - b.estimate).abs() < 0.02, "{} vs {}", a.estimate, b.estimate);
}

/// End-to-end ridge validation: TreeCV LOOCV == hat-matrix closed form,
/// at a size where brute force would already be unpleasant.
#[test]
fn ridge_loocv_matches_closed_form_end_to_end() {
    let n = 400;
    let d = 12;
    let full = SyntheticYearMsd::new(n, 5).generate();
    let mut x = Vec::with_capacity(n * d);
    for i in 0..n {
        x.extend_from_slice(&full.row(i as u32)[..d]);
    }
    let data = Dataset::new(x, full.y.clone(), d);
    let lambda = 0.3;
    let exact = ridge_loocv(&data, lambda);
    let l = OnlineRidge::new(d, lambda);
    let tree = TreeCv::default().run(&l, &data, &Folds::loocv(n));
    assert!(
        (tree.estimate - exact.estimate).abs() < 1e-6 * (1.0 + exact.estimate),
        "tree {} vs exact {}",
        tree.estimate,
        exact.estimate
    );
}

/// Parallel engine at several fork depths reproduces sequential results
/// and per-fold outputs land in the right slots; the pooled executor does
/// the same at worker counts the fork-depth scheme could never express
/// (non-powers of two).
#[test]
fn parallel_depths_reproduce_sequential() {
    let n = 1_200;
    let data = SyntheticCovertype::new(n, 6).generate();
    let l = Pegasos::new(54, 1e-3);
    let folds = Folds::new(n, 13, 11); // non-power-of-two k
    let seq = TreeCv::new(Strategy::Copy, Ordering::Fixed, 3).run(&l, &data, &folds);
    for depth in [1usize, 2, 4] {
        let par =
            ParallelTreeCv::new(Strategy::Copy, Ordering::Fixed, 3, depth).run(&l, &data, &folds);
        assert_eq!(seq.per_fold, par.per_fold, "depth={depth}");
    }
    for threads in [3usize, 5, 6, 11] {
        let exe = TreeCvExecutor::new(Strategy::Copy, Ordering::Fixed, 3, threads)
            .run(&l, &data, &folds);
        assert_eq!(seq.per_fold, exe.per_fold, "threads={threads}");
    }
}

/// Failure injection: a learner that panics on revert must never be
/// reverted under the Copy strategy (i.e. Copy never calls revert).
#[test]
fn copy_strategy_never_reverts() {
    struct NoRevert;
    impl IncrementalLearner for NoRevert {
        type Model = u64;
        type Undo = ();
        fn name(&self) -> &'static str {
            "no-revert"
        }
        fn dim(&self) -> usize {
            1
        }
        fn init(&self) -> u64 {
            0
        }
        fn update(&self, m: &mut u64, _d: &Dataset, idx: &[u32]) {
            *m += idx.len() as u64;
        }
        fn update_logged(&self, m: &mut u64, d: &Dataset, idx: &[u32]) {
            self.update(m, d, idx);
        }
        fn revert(&self, _m: &mut u64, _d: &Dataset, _u: ()) {
            panic!("revert must not be called under Copy");
        }
        fn loss(&self, m: &u64, _d: &Dataset, _i: u32) -> f64 {
            *m as f64
        }
        fn model_bytes(&self, _m: &u64) -> usize {
            8
        }
    }
    let n = 40;
    let data = Dataset::new(vec![0.0; n], vec![0.0; n], 1);
    let folds = Folds::new(n, 8, 12);
    let res = TreeCv::new(Strategy::Copy, Ordering::Fixed, 0).run(&NoRevert, &data, &folds);
    // Leaf models saw exactly n - b points each.
    for (i, v) in res.per_fold.iter().enumerate() {
        assert_eq!(*v, (n - folds.chunk(i).len()) as f64);
    }
}

/// Degenerate shapes: k = 2 (smallest tree) and k = n (LOOCV) on odd sizes.
#[test]
fn degenerate_fold_counts() {
    for n in [2usize, 3, 5, 17] {
        let data = Dataset::new(vec![0.0; n], vec![0.0; n], 1);
        let l = MultisetLearner::new(1);
        for k in [2usize, n] {
            let folds = Folds::new(n, k, 13);
            let tree = TreeCv::default().run(&l, &data, &folds);
            let std_res = StandardCv::default().run(&l, &data, &folds);
            assert_eq!(tree.per_fold, std_res.per_fold, "n={n} k={k}");
        }
    }
}

/// Randomized ordering: TreeCV estimate is reproducible for a fixed seed
/// and differs across seeds (the permutations actually happen).
#[test]
fn randomized_ordering_seeded_reproducibility() {
    let n = 1_000;
    let data = SyntheticCovertype::new(n, 9).generate();
    let l = Pegasos::new(54, 1e-3);
    let folds = Folds::new(n, 10, 14);
    let a = TreeCv::new(Strategy::Copy, Ordering::Randomized, 42).run(&l, &data, &folds);
    let b = TreeCv::new(Strategy::Copy, Ordering::Randomized, 42).run(&l, &data, &folds);
    let c = TreeCv::new(Strategy::Copy, Ordering::Randomized, 43).run(&l, &data, &folds);
    assert_eq!(a.per_fold, b.per_fold);
    assert_ne!(a.per_fold, c.per_fold);
}
