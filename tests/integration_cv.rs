//! Cross-module integration tests: the CV engines against every learner,
//! the paper's theorems as executable properties, and randomized
//! property-style sweeps (many seeded trials standing in for proptest,
//! which is unavailable offline).

use treecv::cv::exact::ridge_loocv;
use treecv::cv::executor::TreeCvExecutor;
use treecv::cv::folds::{Folds, Ordering};
use treecv::cv::mergecv::MergeCv;
use treecv::cv::parallel::ParallelTreeCv;
use treecv::cv::standard::StandardCv;
use treecv::cv::treecv::TreeCv;
use treecv::cv::{CvEngine, Strategy};
use treecv::data::synth::*;
use treecv::data::Dataset;
use treecv::learner::histdensity::HistogramDensity;
use treecv::learner::kmeans::OnlineKMeans;
use treecv::learner::lsqsgd::LsqSgd;
use treecv::learner::multiset::MultisetLearner;
use treecv::learner::naive_bayes::GaussianNb;
use treecv::learner::pegasos::Pegasos;
use treecv::learner::perceptron::Perceptron;
use treecv::learner::ridge::OnlineRidge;
use treecv::learner::IncrementalLearner;
use treecv::rng::Rng;

/// Property sweep: for random (n, k, seed), TreeCV == Standard CV exactly
/// for the order-insensitive multiset oracle (Theorem 1 with g ≡ 0).
#[test]
fn prop_treecv_equals_standard_for_oracle() {
    let mut rng = Rng::new(0xABCD);
    for trial in 0..60 {
        let n = 2 + (rng.below(200) as usize);
        let k = 2 + (rng.below((n - 1).min(64) as u64) as usize);
        let seed = rng.next_u64();
        let data = Dataset::new(vec![0.0; n], vec![0.0; n], 1);
        let folds = Folds::new(n, k, seed);
        let l = MultisetLearner::new(1);
        let a = TreeCv::default().run(&l, &data, &folds);
        let b = StandardCv::default().run(&l, &data, &folds);
        assert_eq!(a.per_fold, b.per_fold, "trial {trial}: n={n} k={k} seed={seed}");
    }
}

/// Property sweep: Copy and SaveRevert strategies agree for every learner
/// with exact revert, across random shapes.
#[test]
fn prop_strategies_agree_for_exact_revert_learners() {
    let mut rng = Rng::new(0xBEEF);
    for _ in 0..25 {
        let n = 20 + (rng.below(300) as usize);
        let k = 2 + (rng.below(20) as u64 as usize);
        let seed = rng.next_u64();
        let folds = Folds::new(n, k, seed);

        let data = SyntheticMixture1d::new(n, seed).generate();
        let l = HistogramDensity::new(-8.0, 8.0, 32);
        let a = TreeCv::new(Strategy::Copy, Ordering::Fixed, 1).run(&l, &data, &folds);
        let b = TreeCv::new(Strategy::SaveRevert, Ordering::Fixed, 1).run(&l, &data, &folds);
        assert_eq!(a.per_fold, b.per_fold, "hist n={n} k={k}");

        let blobs = SyntheticBlobs::new(n, 4, 3, seed).generate();
        let l = OnlineKMeans::new(4, 3);
        let a = TreeCv::new(Strategy::Copy, Ordering::Fixed, 1).run(&l, &blobs, &folds);
        let b = TreeCv::new(Strategy::SaveRevert, Ordering::Fixed, 1).run(&l, &blobs, &folds);
        assert_eq!(a.per_fold, b.per_fold, "kmeans n={n} k={k}");
    }
}

/// Theorem 3 as a property: TreeCV's update-point count ≤ n·log₂(2k) for
/// random (n, k), across learners (work counting is learner-independent).
#[test]
fn prop_theorem3_work_bound() {
    let mut rng = Rng::new(0xFACE);
    for _ in 0..80 {
        let n = 4 + (rng.below(500) as usize);
        let k = 2 + (rng.below((n - 1).min(128) as u64) as usize);
        let data = Dataset::new(vec![0.0; n], vec![0.0; n], 1);
        let folds = Folds::new(n, k, rng.next_u64());
        let l = MultisetLearner::new(1);
        let res = TreeCv::default().run(&l, &data, &folds);
        let bound = (n as f64) * ((2 * k) as f64).log2();
        assert!(
            res.ops.points_updated as f64 <= bound + 1e-9,
            "n={n} k={k}: {} > {bound}",
            res.ops.points_updated
        );
    }
}

/// PEGASOS: TreeCV estimate is close to the standard estimate (incremental
/// stability, Theorem 1) even though the learner is order-sensitive.
#[test]
fn pegasos_treecv_close_to_standard() {
    let n = 4_000;
    let data = SyntheticCovertype::new(n, 1).generate();
    let l = Pegasos::new(54, 1e-4);
    for k in [5usize, 10, 50] {
        let folds = Folds::new(n, k, 7);
        let tree = TreeCv::default().run(&l, &data, &folds);
        let std_res = StandardCv::default().run(&l, &data, &folds);
        assert!(
            (tree.estimate - std_res.estimate).abs() < 0.05,
            "k={k}: tree {} vs std {}",
            tree.estimate,
            std_res.estimate
        );
    }
}

/// LSQSGD: same closeness property on the regression task.
#[test]
fn lsqsgd_treecv_close_to_standard() {
    let n = 4_000;
    let data = SyntheticYearMsd::new(n, 2).generate();
    let l = LsqSgd::with_paper_step(90, n);
    let folds = Folds::new(n, 10, 8);
    let tree = TreeCv::default().run(&l, &data, &folds);
    let std_res = StandardCv::default().run(&l, &data, &folds);
    assert!(
        (tree.estimate - std_res.estimate).abs() < 0.01,
        "tree {} vs std {}",
        tree.estimate,
        std_res.estimate
    );
}

/// Naive Bayes: TreeCV == Standard == MergeCV to f64 tolerance.
#[test]
fn naive_bayes_three_engines_agree() {
    let n = 1_500;
    let data = SyntheticCovertype::new(n, 3).generate();
    let l = GaussianNb::new(54);
    let folds = Folds::new(n, 12, 9);
    let tree = TreeCv::default().run(&l, &data, &folds);
    let std_res = StandardCv::default().run(&l, &data, &folds);
    let merge = MergeCv.run(&l, &data, &folds);
    for i in 0..12 {
        assert!((tree.per_fold[i] - std_res.per_fold[i]).abs() < 1e-12);
        assert!((merge.per_fold[i] - std_res.per_fold[i]).abs() < 1e-12);
    }
}

/// Perceptron with sparse save/revert undo: revert is only ulp-accurate
/// (f32 re-subtraction), and the mistake-driven update rule is chaotic in
/// those ulps — a flipped decision cascades. The *estimates* must still be
/// statistically indistinguishable.
#[test]
fn perceptron_save_revert_close_to_copy() {
    let n = 2_000;
    let data = SyntheticCovertype::new(n, 4).generate();
    let l = Perceptron::new(54);
    let folds = Folds::new(n, 16, 10);
    let a = TreeCv::new(Strategy::Copy, Ordering::Fixed, 1).run(&l, &data, &folds);
    let b = TreeCv::new(Strategy::SaveRevert, Ordering::Fixed, 1).run(&l, &data, &folds);
    assert!((a.estimate - b.estimate).abs() < 0.02, "{} vs {}", a.estimate, b.estimate);
}

/// End-to-end ridge validation: TreeCV LOOCV == hat-matrix closed form,
/// at a size where brute force would already be unpleasant.
#[test]
fn ridge_loocv_matches_closed_form_end_to_end() {
    let n = 400;
    let d = 12;
    let full = SyntheticYearMsd::new(n, 5).generate();
    let mut x = Vec::with_capacity(n * d);
    for i in 0..n {
        x.extend_from_slice(&full.row(i as u32)[..d]);
    }
    let data = Dataset::new(x, full.y.clone(), d);
    let lambda = 0.3;
    let exact = ridge_loocv(&data, lambda);
    let l = OnlineRidge::new(d, lambda);
    let tree = TreeCv::default().run(&l, &data, &Folds::loocv(n));
    assert!(
        (tree.estimate - exact.estimate).abs() < 1e-6 * (1.0 + exact.estimate),
        "tree {} vs exact {}",
        tree.estimate,
        exact.estimate
    );
}

/// Parallel engine at several fork depths reproduces sequential results
/// and per-fold outputs land in the right slots; the pooled executor does
/// the same at worker counts the fork-depth scheme could never express
/// (non-powers of two).
#[test]
fn parallel_depths_reproduce_sequential() {
    let n = 1_200;
    let data = SyntheticCovertype::new(n, 6).generate();
    let l = Pegasos::new(54, 1e-3);
    let folds = Folds::new(n, 13, 11); // non-power-of-two k
    let seq = TreeCv::new(Strategy::Copy, Ordering::Fixed, 3).run(&l, &data, &folds);
    for depth in [1usize, 2, 4] {
        let par =
            ParallelTreeCv::new(Strategy::Copy, Ordering::Fixed, 3, depth).run(&l, &data, &folds);
        assert_eq!(seq.per_fold, par.per_fold, "depth={depth}");
    }
    for threads in [3usize, 5, 6, 11] {
        let exe = TreeCvExecutor::new(Strategy::Copy, Ordering::Fixed, 3, threads)
            .run(&l, &data, &folds);
        assert_eq!(seq.per_fold, exe.per_fold, "threads={threads}");
    }
}

/// Failure injection: a learner that panics on revert must never be
/// reverted under the Copy strategy (i.e. Copy never calls revert).
#[test]
fn copy_strategy_never_reverts() {
    struct NoRevert;
    impl IncrementalLearner for NoRevert {
        type Model = u64;
        type Undo = ();
        fn name(&self) -> &'static str {
            "no-revert"
        }
        fn dim(&self) -> usize {
            1
        }
        fn init(&self) -> u64 {
            0
        }
        fn update(&self, m: &mut u64, _d: &Dataset, idx: &[u32]) {
            *m += idx.len() as u64;
        }
        fn update_logged(&self, m: &mut u64, d: &Dataset, idx: &[u32]) {
            self.update(m, d, idx);
        }
        fn revert(&self, _m: &mut u64, _d: &Dataset, _u: ()) {
            panic!("revert must not be called under Copy");
        }
        fn loss(&self, m: &u64, _d: &Dataset, _i: u32) -> f64 {
            *m as f64
        }
        fn model_bytes(&self, _m: &u64) -> usize {
            8
        }
    }
    let n = 40;
    let data = Dataset::new(vec![0.0; n], vec![0.0; n], 1);
    let folds = Folds::new(n, 8, 12);
    let res = TreeCv::new(Strategy::Copy, Ordering::Fixed, 0).run(&NoRevert, &data, &folds);
    // Leaf models saw exactly n - b points each.
    for (i, v) in res.per_fold.iter().enumerate() {
        assert_eq!(*v, (n - folds.chunk(i).len()) as f64);
    }
}

/// Degenerate shapes: k = 2 (smallest tree) and k = n (LOOCV) on odd sizes.
#[test]
fn degenerate_fold_counts() {
    for n in [2usize, 3, 5, 17] {
        let data = Dataset::new(vec![0.0; n], vec![0.0; n], 1);
        let l = MultisetLearner::new(1);
        for k in [2usize, n] {
            let folds = Folds::new(n, k, 13);
            let tree = TreeCv::default().run(&l, &data, &folds);
            let std_res = StandardCv::default().run(&l, &data, &folds);
            assert_eq!(tree.per_fold, std_res.per_fold, "n={n} k={k}");
        }
    }
}

/// Randomized ordering: TreeCV estimate is reproducible for a fixed seed
/// and differs across seeds (the permutations actually happen).
#[test]
fn randomized_ordering_seeded_reproducibility() {
    let n = 1_000;
    let data = SyntheticCovertype::new(n, 9).generate();
    let l = Pegasos::new(54, 1e-3);
    let folds = Folds::new(n, 10, 14);
    let a = TreeCv::new(Strategy::Copy, Ordering::Randomized, 42).run(&l, &data, &folds);
    let b = TreeCv::new(Strategy::Copy, Ordering::Randomized, 42).run(&l, &data, &folds);
    let c = TreeCv::new(Strategy::Copy, Ordering::Randomized, 43).run(&l, &data, &folds);
    assert_eq!(a.per_fold, b.per_fold);
    assert_ne!(a.per_fold, c.per_fold);
}
