//! Model-checking battery for the executor's concurrency protocols.
//!
//! Runs the deterministic scheduler (`treecv::analysis::sched`) over the
//! protocol models (`treecv::analysis::protocols`) at two granularities:
//!
//! - **Seeded random exploration** (`Preemption::EveryOp`): every
//!   instrumented primitive operation is a preemption point; the
//!   interleaving is a pure function of the seed. The correct-model
//!   sweeps below explore 10,100 schedules total (see
//!   [`budget::TOTAL_RANDOM_SCHEDULES`]), all of which must satisfy the
//!   protocol invariants.
//! - **Bounded-exhaustive DFS** (`Preemption::ExplicitOnly`): only
//!   explicit `checkpoint()` calls and blocking operations yield, making
//!   the full interleaving space enumerable. The 2-worker park/unpark
//!   handshake space is exhausted outright.
//!
//! Every seeded-bug mutation (10 across the four protocol families) must
//! be *caught* — the checker reports a deadlock or invariant violation
//! within the schedule budget. A checker that cannot re-find a seeded bug
//! has a blind spot, so these tests are as load-bearing as the clean
//! sweeps.
//!
//! Reproducing a failure: every `FailedSchedule` carries its seed (random
//! mode) and its full decision trace; `replay_seed` / `replay` re-run it
//! deterministically. See EXPERIMENTS.md § "Model-checker coverage".

use treecv::analysis::protocols::{
    cancel_tree, handoff, park_chain, priority_dynamic, priority_static, CancelBug, HandoffBug,
    ParkChainBug, PriorityBug,
};
use treecv::analysis::sched::{
    explore_dfs, explore_random, replay, replay_seed, ExplorationReport, ExploreCfg, Outcome,
    Preemption,
};

/// Schedule budgets for the correct-model random sweeps. Kept as named
/// constants so the documented total is auditable in one place.
mod budget {
    /// Seeds per park/unpark handshake configuration (× 4 configs).
    pub const PARK_SEEDS: u64 = 800;
    /// Seeds per external-producer handoff configuration (× 3 configs).
    pub const HANDOFF_SEEDS: u64 = 700;
    /// Seeds per cancellation-tree configuration (× 3 configs).
    pub const CANCEL_SEEDS: u64 = 800;
    /// Seeds per priority-injector variant (× 3 variants).
    pub const PRIORITY_SEEDS: u64 = 800;

    /// Total correct-model random schedules explored by this suite.
    pub const TOTAL_RANDOM_SCHEDULES: u64 =
        PARK_SEEDS * 4 + HANDOFF_SEEDS * 3 + CANCEL_SEEDS * 3 + PRIORITY_SEEDS * 3;
}

fn every_op() -> ExploreCfg {
    ExploreCfg { preemption: Preemption::EveryOp, max_steps: 20_000 }
}

fn explicit_only() -> ExploreCfg {
    ExploreCfg { preemption: Preemption::ExplicitOnly, max_steps: 20_000 }
}

fn assert_clean(report: &ExplorationReport, what: &str) {
    assert!(
        report.all_ok(),
        "{what}: {} of {} schedules failed; first: {:?}",
        report.failures.len(),
        report.schedules,
        report.failures.first()
    );
}

fn assert_caught(report: &ExplorationReport, what: &str) {
    assert!(
        !report.all_ok(),
        "{what}: seeded bug survived all {} schedules — the checker has a blind spot",
        report.schedules
    );
}

#[test]
fn schedule_budget_is_at_least_ten_thousand() {
    // The acceptance bar for this suite: ≥ 10,000 seeded schedules across
    // the protocol sweeps (before counting DFS or mutation hunts).
    assert!(
        budget::TOTAL_RANDOM_SCHEDULES >= 10_000,
        "random-sweep budget shrank to {}",
        budget::TOTAL_RANDOM_SCHEDULES
    );
}

// ---------------------------------------------------------------------------
// Protocol 1: register-before-sweep park/unpark handshake.
// ---------------------------------------------------------------------------

#[test]
fn park_chain_correct_k2_w2() {
    let r = explore_random(|| park_chain(2, 2, ParkChainBug::Correct), 0..budget::PARK_SEEDS,
        &every_op());
    assert_clean(&r, "park_chain k=2 w=2");
    assert_eq!(r.schedules as u64, budget::PARK_SEEDS);
}

#[test]
fn park_chain_correct_k2_w3() {
    let r = explore_random(|| park_chain(2, 3, ParkChainBug::Correct), 0..budget::PARK_SEEDS,
        &every_op());
    assert_clean(&r, "park_chain k=2 w=3");
}

#[test]
fn park_chain_correct_k2_w4() {
    let r = explore_random(|| park_chain(2, 4, ParkChainBug::Correct), 0..budget::PARK_SEEDS,
        &every_op());
    assert_clean(&r, "park_chain k=2 w=4");
}

#[test]
fn park_chain_correct_k3_w2() {
    let r = explore_random(|| park_chain(3, 2, ParkChainBug::Correct), 0..budget::PARK_SEEDS,
        &every_op());
    assert_clean(&r, "park_chain k=3 w=2");
}

#[test]
fn park_chain_dfs_exhausts_two_worker_space() {
    // The tentpole DFS claim: the 2-worker park/unpark handshake state
    // space (1-task chain, explicit preemption points) is explored
    // *exhaustively* — every interleaving of the register → verify →
    // re-check-done → park window against the finishing worker.
    let r = explore_dfs(|| park_chain(1, 2, ParkChainBug::Correct), 300_000, &explicit_only());
    assert!(r.exhausted, "park/unpark DFS space not exhausted in {} schedules", r.schedules);
    assert_clean(&r, "park_chain DFS k=1 w=2");
    // The space is non-trivial: the handshake has real branching.
    assert!(r.schedules > 10, "suspiciously small DFS space: {}", r.schedules);
}

#[test]
fn park_chain_skip_done_recheck_caught_by_random() {
    let r = explore_random(|| park_chain(2, 2, ParkChainBug::SkipDoneRecheck), 0..1500,
        &every_op());
    assert_caught(&r, "SkipDoneRecheck (random)");
}

#[test]
fn park_chain_skip_done_recheck_caught_by_dfs() {
    let r = explore_dfs(|| park_chain(1, 2, ParkChainBug::SkipDoneRecheck), 300_000,
        &explicit_only());
    assert_caught(&r, "SkipDoneRecheck (DFS)");
    // The lost-wakeup manifests as a deadlock: a worker parked forever.
    let deadlocked = r.failures.iter().any(|f| matches!(f.outcome, Outcome::Deadlock { .. }));
    assert!(deadlocked, "expected a deadlock failure, got {:?}", r.failures.first());
}

#[test]
fn park_chain_wake_then_store_caught_by_random() {
    let r = explore_random(|| park_chain(2, 2, ParkChainBug::WakeThenStore), 0..1500,
        &every_op());
    assert_caught(&r, "WakeThenStore (random)");
}

#[test]
fn park_chain_wake_then_store_caught_by_dfs() {
    let r = explore_dfs(|| park_chain(1, 2, ParkChainBug::WakeThenStore), 300_000,
        &explicit_only());
    assert_caught(&r, "WakeThenStore (DFS)");
}

// ---------------------------------------------------------------------------
// Protocol 1b: external-producer handoff (sweep-after-register window).
// ---------------------------------------------------------------------------

#[test]
fn handoff_correct_k1_w1() {
    let r = explore_random(|| handoff(1, 1, HandoffBug::Correct), 0..budget::HANDOFF_SEEDS,
        &every_op());
    assert_clean(&r, "handoff k=1 w=1");
}

#[test]
fn handoff_correct_k2_w2() {
    let r = explore_random(|| handoff(2, 2, HandoffBug::Correct), 0..budget::HANDOFF_SEEDS,
        &every_op());
    assert_clean(&r, "handoff k=2 w=2");
}

#[test]
fn handoff_correct_k3_w2() {
    let r = explore_random(|| handoff(3, 2, HandoffBug::Correct), 0..budget::HANDOFF_SEEDS,
        &every_op());
    assert_clean(&r, "handoff k=3 w=2");
}

#[test]
fn handoff_dfs_exhausts_minimal_space() {
    let r = explore_dfs(|| handoff(1, 2, HandoffBug::Correct), 200_000, &explicit_only());
    assert!(r.exhausted, "handoff DFS space not exhausted in {} schedules", r.schedules);
    assert_clean(&r, "handoff DFS k=1 w=2");
}

#[test]
fn handoff_skip_verify_sweep_caught() {
    // Register-then-verify exists precisely so a push landing between the
    // failed sweep and the park is re-observed; skipping the verify sweep
    // deadlocks when the producer's last push races the consumer's park.
    let dfs = explore_dfs(|| handoff(1, 1, HandoffBug::SkipVerifySweep), 50_000,
        &explicit_only());
    assert_caught(&dfs, "SkipVerifySweep (DFS)");
    let rnd = explore_random(|| handoff(1, 1, HandoffBug::SkipVerifySweep), 0..600,
        &every_op());
    assert_caught(&rnd, "SkipVerifySweep (random)");
}

#[test]
fn handoff_register_after_sweep_caught() {
    // Verifying *before* registering re-opens the same window: the push
    // can land after the verify but before the register, and the wake
    // finds no one registered.
    let dfs = explore_dfs(|| handoff(1, 1, HandoffBug::RegisterAfterSweep), 50_000,
        &explicit_only());
    assert_caught(&dfs, "RegisterAfterSweep (DFS)");
}

#[test]
fn handoff_wake_before_push_caught() {
    // Producer-side ordering bug: waking before the item is visible lets
    // the consumer sweep empty, park, and never be woken again.
    let dfs = explore_dfs(|| handoff(1, 1, HandoffBug::WakeBeforePush), 50_000,
        &explicit_only());
    assert_caught(&dfs, "WakeBeforePush (DFS)");
    let rnd = explore_random(|| handoff(1, 1, HandoffBug::WakeBeforePush), 0..600,
        &every_op());
    assert_caught(&rnd, "WakeBeforePush (random)");
}

// ---------------------------------------------------------------------------
// Protocol 2: cancellation at pop/fork points — drop accounting and
// snapshot-buffer conservation.
// ---------------------------------------------------------------------------

#[test]
fn cancel_correct_k4_w2() {
    let r = explore_random(|| cancel_tree(4, 2, CancelBug::Correct), 0..budget::CANCEL_SEEDS,
        &every_op());
    assert_clean(&r, "cancel k=4 w=2");
}

#[test]
fn cancel_correct_k6_w2() {
    let r = explore_random(|| cancel_tree(6, 2, CancelBug::Correct), 0..budget::CANCEL_SEEDS,
        &every_op());
    assert_clean(&r, "cancel k=6 w=2");
}

#[test]
fn cancel_correct_k6_w3() {
    let r = explore_random(|| cancel_tree(6, 3, CancelBug::Correct), 0..budget::CANCEL_SEEDS,
        &every_op());
    assert_clean(&r, "cancel k=6 w=3");
}

#[test]
fn cancel_leak_snapshot_on_cancel_caught() {
    let r = explore_random(|| cancel_tree(4, 2, CancelBug::LeakSnapshotOnCancel), 0..2000,
        &every_op());
    assert_caught(&r, "LeakSnapshotOnCancel");
}

#[test]
fn cancel_forget_drop_accounting_caught() {
    let r = explore_random(|| cancel_tree(4, 2, CancelBug::ForgetDropAccounting), 0..2000,
        &every_op());
    assert_caught(&r, "ForgetDropAccounting");
}

#[test]
fn cancel_double_account_caught() {
    let r = explore_random(|| cancel_tree(4, 2, CancelBug::DoubleAccount), 0..2000,
        &every_op());
    assert_caught(&r, "DoubleAccount");
}

// ---------------------------------------------------------------------------
// Protocol 3: priority injector — admission order among equal priorities.
// ---------------------------------------------------------------------------

/// Two priority classes with interleaved admission.
const PRIO_MIXED: [(i64, u32); 6] = [(5, 500), (1, 100), (5, 501), (1, 101), (5, 502), (1, 102)];
/// One priority class: pure FIFO-admission invariant.
const PRIO_TIES: [(i64, u32); 4] = [(3, 300), (3, 301), (3, 302), (3, 303)];

#[test]
fn priority_static_mixed_correct() {
    let r = explore_random(|| priority_static(&PRIO_MIXED, 2, PriorityBug::Correct),
        0..budget::PRIORITY_SEEDS, &every_op());
    assert_clean(&r, "priority static mixed");
}

#[test]
fn priority_static_ties_correct() {
    let r = explore_random(|| priority_static(&PRIO_TIES, 2, PriorityBug::Correct),
        0..budget::PRIORITY_SEEDS, &every_op());
    assert_clean(&r, "priority static ties");
}

#[test]
fn priority_dynamic_bump_correct() {
    // A steerer bumps run 1 (the priority-1 run) above run 0 mid-drain;
    // per-run admission order must still be preserved.
    let r = explore_random(|| priority_dynamic(&PRIO_MIXED, 2, PriorityBug::Correct, 9),
        0..budget::PRIORITY_SEEDS, &every_op());
    assert_clean(&r, "priority dynamic bump");
}

#[test]
fn priority_ignore_priority_caught() {
    let r = explore_random(|| priority_static(&PRIO_MIXED, 2, PriorityBug::IgnorePriority),
        0..200, &every_op());
    assert_caught(&r, "IgnorePriority");
}

#[test]
fn priority_lifo_ties_caught() {
    let r = explore_random(|| priority_static(&PRIO_TIES, 2, PriorityBug::LifoTies), 0..200,
        &every_op());
    assert_caught(&r, "LifoTies");
}

// ---------------------------------------------------------------------------
// Reproducibility: a failure replays identically from its trace AND from
// its seed alone.
// ---------------------------------------------------------------------------

#[test]
fn failures_replay_from_trace_and_seed() {
    let r = explore_random(|| park_chain(2, 2, ParkChainBug::SkipDoneRecheck), 0..1500,
        &every_op());
    assert_caught(&r, "SkipDoneRecheck (replay source)");
    let fail = &r.failures[0];

    let by_trace = replay(
        park_chain(2, 2, ParkChainBug::SkipDoneRecheck),
        fail.trace.iter().map(|c| c.idx).collect(),
        &every_op(),
    );
    assert_eq!(by_trace.outcome, fail.outcome, "trace replay diverged");

    // invariant: random-exploration failures always carry their seed.
    let seed = fail.seed.expect("random failure has a seed");
    let by_seed = replay_seed(park_chain(2, 2, ParkChainBug::SkipDoneRecheck), seed,
        &every_op());
    assert_eq!(by_seed.outcome, fail.outcome, "seed replay diverged");
    assert_eq!(by_seed.trace.len(), fail.trace.len(), "seed replay took a different path");
}

#[test]
fn dfs_prefix_replay_reproduces_failure() {
    let r = explore_dfs(|| handoff(1, 1, HandoffBug::SkipVerifySweep), 50_000,
        &explicit_only());
    assert_caught(&r, "SkipVerifySweep (DFS replay source)");
    let fail = &r.failures[0];
    let by_trace = replay(
        handoff(1, 1, HandoffBug::SkipVerifySweep),
        fail.trace.iter().map(|c| c.idx).collect(),
        &explicit_only(),
    );
    assert_eq!(by_trace.outcome, fail.outcome, "DFS trace replay diverged");
}

// ---------------------------------------------------------------------------
// Real executor under the scheduler (requires `--cfg treecv_model_check`,
// which swaps `crate::sync` onto the instrumented shim — the nightly
// model-check CI job builds this way).
// ---------------------------------------------------------------------------

#[cfg(treecv_model_check)]
mod real_executor {
    use super::*;
    use treecv::analysis::sched::{run_schedule, Model, RandomChooser};
    use treecv::cv::executor::TreeCvExecutor;
    use treecv::cv::folds::{Folds, Ordering as CvOrdering};
    use treecv::cv::Strategy;
    use treecv::data::synth::SyntheticMixture1d;
    use treecv::data::Dataset;
    use treecv::learner::histdensity::HistogramDensity;

    /// The executor itself as a model: one declared vthread drives a tiny
    /// 2-worker batch; the shim registers the pool's scoped workers
    /// dynamically. The invariant is the crate's headline property —
    /// the parallel estimate equals the sequential one bit for bit.
    struct ExecutorModel {
        data: Dataset,
        expected: Vec<f64>,
        result: std::sync::Mutex<Option<Vec<f64>>>,
    }

    impl Model for ExecutorModel {
        fn n_threads(&self) -> usize {
            1
        }

        fn thread(&self, _tid: usize) {
            let learner = HistogramDensity::new(-8.0, 8.0, 16);
            let folds = Folds::new(self.data.n, 4, 7);
            let exec = TreeCvExecutor::new(Strategy::Copy, CvOrdering::Fixed, 5, 2);
            let res = exec.run(&learner, &self.data, &folds);
            *self.result.lock().unwrap_or_else(|e| e.into_inner()) = Some(res.per_fold);
        }

        fn check(&self) -> Result<(), String> {
            let got = self.result.lock().unwrap_or_else(|e| e.into_inner());
            match got.as_ref() {
                Some(pf) if *pf == self.expected => Ok(()),
                Some(pf) => Err(format!("per-fold diverged: {pf:?} vs {:?}", self.expected)),
                None => Err("executor never published a result".into()),
            }
        }
    }

    #[test]
    fn executor_is_schedule_independent() {
        use treecv::cv::treecv::TreeCv;
        use treecv::cv::CvEngine;
        let data = SyntheticMixture1d::new(96, 11).generate();
        let learner = HistogramDensity::new(-8.0, 8.0, 16);
        let folds = Folds::new(data.n, 4, 7);
        let expected =
            TreeCv::new(Strategy::Copy, CvOrdering::Fixed, 5).run(&learner, &data, &folds);
        // A handful of seeds: each schedule serializes every shim op, so
        // these are slow-motion runs; the space is sampled, not swept.
        for seed in 0..3u64 {
            let model = std::sync::Arc::new(ExecutorModel {
                data: data.clone(),
                expected: expected.per_fold.clone(),
                result: std::sync::Mutex::new(None),
            });
            let cfg =
                ExploreCfg { preemption: Preemption::EveryOp, max_steps: 2_000_000 };
            let res = run_schedule(model, Box::new(RandomChooser::new(seed)), &cfg);
            assert!(res.outcome.is_ok(), "seed {seed}: {:?}", res.outcome);
        }
    }
}
