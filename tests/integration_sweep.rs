//! Sweep-scheduler equivalence battery: a sweep's `C configs × strategies
//! × r repetitions` runs all execute through ONE pooled executor, and
//! every run must be **bit-identical** to running that configuration
//! alone — same per_fold vector, same estimate, same work counters —
//! across worker counts {1, 3, 8} and both model-preservation
//! strategies, under both feeding orders. Plus: run-twice determinism of
//! the full sweep table, and the pool-spawn accounting (one pool per
//! sweep; one per run for standalone dispatch; zero inline).
//!
//! Spawn accounting reads the executor's *per-pool* counter
//! (`TreeCvExecutor::pool_spawns`, surfaced as `SweepOutcome::
//! pool_spawns`), so these tests run concurrently with any other pool
//! user in the binary — the old process-wide counter and its file-local
//! serialization lock are gone.

use treecv::cv::executor::TreeCvExecutor;
use treecv::cv::folds::{Folds, Ordering};
use treecv::cv::parallel::ParallelTreeCv;
use treecv::cv::stats::{repetition_engine_seed, repetition_fold_seed};
use treecv::cv::sweep::{run_sweep, SweepSpec};
use treecv::cv::Strategy;
use treecv::data::synth::{SyntheticCovertype, SyntheticMixture1d};
use treecv::learner::histdensity::HistogramDensity;
use treecv::learner::pegasos::Pegasos;

fn sweep_spec(strategies: Vec<Strategy>, k: usize, reps: usize, threads: usize) -> SweepSpec {
    SweepSpec { ordering: Ordering::Fixed, strategies, k, repetitions: reps, seed: 42, threads }
}

/// The headline property: each (config, strategy, repetition) run of a
/// sweep is bit-identical to running that config alone through the
/// `ParallelTreeCv` facade (which delegates to the executor) at the same
/// worker count — per-fold scores, estimate, and the §4.1 counters.
/// PEGASOS has snapshot-undo (exact revert), so this holds bitwise even
/// for SaveRevert at any pool size.
#[test]
fn sweep_runs_bit_identical_to_standalone_across_workers_and_strategies() {
    let n = 600;
    let data = SyntheticCovertype::new(n, 51).generate();
    let lambdas = [1e-3, 1e-4, 1e-5];
    let learners: Vec<Pegasos> = lambdas.iter().map(|&l| Pegasos::new(54, l)).collect();
    let (k, reps) = (11usize, 3usize);
    for strategy in [Strategy::Copy, Strategy::SaveRevert] {
        for threads in [1usize, 3, 8] {
            let spec = sweep_spec(vec![strategy], k, reps, threads);
            let out = run_sweep(&learners, &data, &spec).unwrap();
            assert_eq!(out.cells.len(), learners.len());
            for (c, cell) in out.cells.iter().enumerate() {
                assert_eq!(cell.config, c);
                assert_eq!(cell.runs.len(), reps);
                for (r, run) in cell.runs.iter().enumerate() {
                    let folds = Folds::new(n, k, repetition_fold_seed(spec.seed, r));
                    let alone = ParallelTreeCv {
                        strategy,
                        ordering: Ordering::Fixed,
                        seed: repetition_engine_seed(spec.seed, r),
                        fork_depth: 0,
                        threads: Some(threads),
                    }
                    .run(&learners[c], &data, &folds);
                    let ctx =
                        format!("lambda={} rep={r} threads={threads} {strategy:?}", lambdas[c]);
                    assert_eq!(run.per_fold, alone.per_fold, "{ctx}");
                    assert_eq!(run.estimate.to_bits(), alone.estimate.to_bits(), "{ctx}");
                    assert_eq!(run.ops.points_updated, alone.ops.points_updated, "{ctx}");
                    assert_eq!(run.ops.update_calls, alone.ops.update_calls, "{ctx}");
                    assert_eq!(run.ops.model_copies, alone.ops.model_copies, "{ctx}");
                    assert_eq!(run.ops.model_restores, alone.ops.model_restores, "{ctx}");
                    assert_eq!(run.ops.evals, alone.ops.evals, "{ctx}");
                }
            }
        }
    }
}

/// Same property under randomized feeding order: permutation streams are
/// per-(run-seed, node), so pooling runs cannot perturb them.
#[test]
fn sweep_randomized_ordering_bit_identical_to_standalone() {
    let n = 420;
    let data = SyntheticMixture1d::new(n, 57).generate();
    let learners =
        vec![HistogramDensity::new(-8.0, 8.0, 16), HistogramDensity::new(-8.0, 8.0, 48)];
    let mut spec = sweep_spec(vec![Strategy::Copy], 9, 2, 3);
    spec.ordering = Ordering::Randomized;
    let out = run_sweep(&learners, &data, &spec).unwrap();
    for (c, cell) in out.cells.iter().enumerate() {
        for (r, run) in cell.runs.iter().enumerate() {
            let folds = Folds::new(n, 9, repetition_fold_seed(spec.seed, r));
            let alone = TreeCvExecutor::new(
                Strategy::Copy,
                Ordering::Randomized,
                repetition_engine_seed(spec.seed, r),
                3,
            )
            .run(&learners[c], &data, &folds);
            assert_eq!(run.per_fold, alone.per_fold, "config {c} rep {r}");
            assert_eq!(run.ops.points_permuted, alone.ops.points_permuted, "config {c} rep {r}");
        }
    }
}

/// Run-twice determinism: the full sweep table — means, stds, every run's
/// per-fold vector and counters — must be identical across invocations,
/// no matter how the pool schedules or steals.
#[test]
fn sweep_table_is_run_twice_deterministic() {
    let data = SyntheticMixture1d::new(500, 52).generate();
    let learners = vec![
        HistogramDensity::new(-8.0, 8.0, 16),
        HistogramDensity::new(-8.0, 8.0, 32),
        HistogramDensity::new(-8.0, 8.0, 64),
    ];
    let mut spec = sweep_spec(vec![Strategy::Copy, Strategy::SaveRevert], 13, 4, 6);
    spec.ordering = Ordering::Randomized;
    spec.seed = 7;
    let a = run_sweep(&learners, &data, &spec).unwrap();
    let b = run_sweep(&learners, &data, &spec).unwrap();
    assert_eq!(a.cells.len(), 6); // 3 configs × 2 strategies
    for (x, y) in a.cells.iter().zip(&b.cells) {
        assert_eq!(x.config, y.config);
        assert_eq!(x.strategy, y.strategy);
        assert_eq!(x.mean.to_bits(), y.mean.to_bits());
        assert_eq!(x.std.to_bits(), y.std.to_bits());
        for (ra, rb) in x.runs.iter().zip(&y.runs) {
            assert_eq!(ra.per_fold, rb.per_fold);
            assert_eq!(ra.ops.points_updated, rb.ops.points_updated);
            assert_eq!(ra.ops.model_copies, rb.ops.model_copies);
            assert_eq!(ra.ops.model_restores, rb.ops.model_restores);
        }
    }
    // Histogram density reverts exactly, so within a config the Copy and
    // SaveRevert cells must also agree bit for bit.
    for c in 0..3 {
        let (copy, sr) = (&a.cells[2 * c], &a.cells[2 * c + 1]);
        for (x, y) in copy.runs.iter().zip(&sr.runs) {
            assert_eq!(x.per_fold, y.per_fold, "config {c}");
        }
    }
}

/// The acceptance-criterion accounting: a whole sweep of C configs ×
/// strategies × r repetitions spawns EXACTLY one worker pool; the same
/// runs dispatched standalone spawn one pool each; a `threads = 1` sweep
/// runs inline and spawns none.
#[test]
fn whole_sweep_uses_exactly_one_pool() {
    let n = 400;
    let data = SyntheticCovertype::new(n, 53).generate();
    let lambdas = [1e-3, 1e-4, 1e-5, 1e-6];
    let learners: Vec<Pegasos> = lambdas.iter().map(|&l| Pegasos::new(54, l)).collect();
    let (k, reps) = (8usize, 3usize);

    // 4 configs × 2 strategies × 3 reps = 24 runs, one pool. The count
    // comes off the sweep executor's own per-pool counter, so concurrent
    // tests cannot perturb it.
    let spec = sweep_spec(vec![Strategy::Copy, Strategy::SaveRevert], k, reps, 3);
    let out = run_sweep(&learners, &data, &spec).unwrap();
    assert_eq!(out.pool_spawns, 1, "sweep must spawn exactly one pool");
    assert_eq!(out.cells.len(), 8);

    // Standalone dispatch of the same 24 runs pays 24 pool spawns: one
    // per executor batch (each executor's counter reads exactly 1).
    let mut standalone_spawns = 0;
    for learner in &learners {
        for strategy in [Strategy::Copy, Strategy::SaveRevert] {
            for r in 0..reps {
                let folds = Folds::new(n, k, repetition_fold_seed(spec.seed, r));
                let engine = TreeCvExecutor::new(
                    strategy,
                    Ordering::Fixed,
                    repetition_engine_seed(spec.seed, r),
                    3,
                );
                let _ = engine.run(learner, &data, &folds);
                assert_eq!(engine.pool_spawns(), 1);
                standalone_spawns += engine.pool_spawns();
            }
        }
    }
    assert_eq!(standalone_spawns, 24, "standalone dispatch spawns one pool per run");

    // Inline sweeps (threads = 1) never spawn.
    let spec1 = sweep_spec(vec![Strategy::Copy], k, reps, 1);
    let out = run_sweep(&learners, &data, &spec1).unwrap();
    assert_eq!(out.pool_spawns, 0, "threads=1 must run inline");
}

/// Fold assignments are shared across configs: two identical learner
/// configs in one grid must produce bit-identical cells (same folds, same
/// seeds — the hyperparameter really is the only degree of freedom).
#[test]
fn identical_configs_share_partitionings() {
    let data = SyntheticCovertype::new(350, 54).generate();
    let learners = vec![Pegasos::new(54, 1e-4), Pegasos::new(54, 1e-4)];
    let out = run_sweep(&learners, &data, &sweep_spec(vec![Strategy::Copy], 7, 3, 3)).unwrap();
    let (a, b) = (&out.cells[0], &out.cells[1]);
    assert_eq!(a.mean.to_bits(), b.mean.to_bits());
    assert_eq!(a.std.to_bits(), b.std.to_bits());
    for (x, y) in a.runs.iter().zip(&b.runs) {
        assert_eq!(x.per_fold, y.per_fold);
    }
}

/// The coordinator-level sweep (what `repro sweep` drives) reports exact
/// pool accounting and a table ranked by mean loss.
#[test]
fn coordinator_sweep_ranked_and_pooled() {
    use treecv::config::{ExperimentConfig, SweepGrid, Task};
    let cfg = ExperimentConfig {
        task: Task::Pegasos,
        n: 400,
        ks: vec![5],
        repetitions: 2,
        seed: 3,
        threads: 3,
        sweep: Some(SweepGrid::parse("lambda=1e-3,1e-4,1e-5").unwrap()),
        ..ExperimentConfig::default()
    };
    let report = treecv::coordinator::run_sweep(&cfg).unwrap();
    assert_eq!(report.pool_spawns, 1);
    assert_eq!(report.points.len(), 3);
    assert!(report.points.windows(2).all(|w| w[0].mean <= w[1].mean), "ranked by mean");
}
