//! CLI integration: drive the `repro` binary end-to-end (small workloads)
//! and check output shapes. Uses the binary Cargo builds for this package.

use std::process::Command;

fn repro() -> Command {
    Command::new(env!("CARGO_BIN_EXE_repro"))
}

fn run_ok(args: &[&str]) -> String {
    let out = repro().args(args).output().expect("spawn repro");
    assert!(
        out.status.success(),
        "repro {args:?} failed:\nstdout: {}\nstderr: {}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    String::from_utf8(out.stdout).expect("utf8 stdout")
}

#[test]
fn help_lists_commands() {
    let text = run_ok(&["help"]);
    let cmds = [
        "cv", "table2", "figure2", "loocv", "dist", "grid", "sweep", "select", "serve",
        "selfcheck",
    ];
    for cmd in cmds {
        assert!(text.contains(cmd), "missing {cmd}");
    }
}

/// Every runtime-free registry task round-trips through `repro cv`: the
/// name parses, the registry builds the learner, the engine runs, and the
/// table echoes the task name back.
#[test]
fn every_registry_task_roundtrips_through_cv() {
    let tasks = [
        "pegasos",
        "lsqsgd",
        "kmeans",
        "density",
        "naive_bayes",
        "ridge",
        "knn",
        "perceptron",
        "multiset",
    ];
    for task in tasks {
        let text = run_ok(&["cv", "--task", task, "--n", "120", "--ks", "3", "--reps", "1"]);
        assert!(text.contains(task), "{task}:\n{text}");
        assert_eq!(text.lines().count(), 2, "{task}:\n{text}"); // header + one row
    }
}

/// The XLA-backed registry tasks are CLI-reachable too: the name parses
/// and dispatches; without the PJRT runtime + artifacts the run exits
/// nonzero with the clean "built without the `xla` feature" /
/// missing-artifact error, never a parse failure.
#[test]
fn xla_tasks_are_reachable_and_fail_cleanly_without_runtime() {
    for task in ["xla_pegasos", "xla_lsqsgd"] {
        let out = repro()
            .args(["cv", "--task", task, "--n", "100", "--ks", "3", "--reps", "1"])
            .output()
            .unwrap();
        let err = String::from_utf8_lossy(&out.stderr);
        if out.status.success() {
            continue; // artifact-equipped environment: the run worked
        }
        assert!(
            err.contains("xla") || err.contains("artifact") || err.contains("manifest"),
            "{task}: unexpected failure:\n{err}"
        );
        assert!(!err.contains("unknown task"), "{task} must parse:\n{err}");
    }
}

#[test]
fn no_args_prints_usage() {
    let text = run_ok(&[]);
    assert!(text.contains("USAGE"));
}

#[test]
fn unknown_command_exits_nonzero() {
    let out = repro().arg("bogus").output().unwrap();
    assert!(!out.status.success());
}

#[test]
fn cv_text_output() {
    let text = run_ok(&["cv", "--task", "density", "--n", "300", "--ks", "5", "--reps", "2"]);
    assert!(text.contains("density"));
    assert!(text.contains("treecv"));
    assert_eq!(text.lines().count(), 2); // header + one row
}

#[test]
fn cv_json_output_is_valid_shape() {
    let text = run_ok(&[
        "cv", "--task", "density", "--n", "200", "--ks", "4,8", "--reps", "2", "--json",
    ]);
    assert!(text.trim_start().starts_with('['));
    assert!(text.contains("\"engine\": \"treecv\""));
    assert!(text.contains("\"points_updated\""));
    // Two ks → two report objects.
    assert_eq!(text.matches("\"mean\"").count(), 2);
}

#[test]
fn cv_save_revert_honored_on_parallel_engine() {
    // `--engine parallel_treecv --save-revert` must run the requested
    // strategy through the pooled executor (it used to silently run Copy).
    let text = run_ok(&[
        "cv",
        "--task",
        "density",
        "--n",
        "300",
        "--ks",
        "6",
        "--reps",
        "2",
        "--engine",
        "parallel_treecv",
        "--save-revert",
    ]);
    assert!(text.contains("parallel_treecv"));
    assert_eq!(text.lines().count(), 2); // header + one row
}

#[test]
fn cv_save_revert_on_standard_engine_is_an_error() {
    // Engines that cannot honor SaveRevert must hard-error, not downgrade.
    let out = repro()
        .args([
            "cv", "--task", "density", "--n", "200", "--ks", "4", "--engine", "standard",
            "--save-revert",
        ])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("save/revert"), "stderr: {err}");
}

#[test]
fn cv_approx_engine_rejects_non_convex_task() {
    // The approx engine needs a one-step correction (ConvexCorrectable);
    // tasks without one must hard-error, never silently fall back.
    let out = repro()
        .args(["cv", "--task", "knn", "--n", "200", "--ks", "4", "--engine", "approx"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("one-step held-out correction"), "stderr: {err}");
}

#[test]
fn cv_approx_check_reports_gap_in_json() {
    // `--approx-check` runs the exact oracle alongside and surfaces the
    // per-fold sup gap through the ops block of the JSON report.
    let text = run_ok(&[
        "cv", "--task", "ridge", "--n", "200", "--ks", "5", "--reps", "1", "--engine",
        "approx", "--approx-check", "--json",
    ]);
    assert!(text.contains("\"engine\": \"approx\""), "{text}");
    assert!(text.contains("\"corrections\": 5"), "{text}");
    assert!(text.contains("\"exact_gap_max\""), "{text}");
}

#[test]
fn cv_rejects_bad_flags() {
    let out = repro().args(["cv", "--task", "nope"]).output().unwrap();
    assert!(!out.status.success());
    let out = repro().args(["cv", "--ks"]).output().unwrap();
    assert!(!out.status.success());
}

#[test]
fn table2_renders_paper_layout() {
    let text = run_ok(&[
        "table2", "--task", "density", "--n", "150", "--ks", "5,0", "--reps", "2",
    ]);
    assert!(text.contains("Table 2"));
    assert!(text.contains("TreeCV fixed"));
    assert!(text.contains("Standard randomized"));
    assert!(text.contains("N/A")); // standard LOOCV cell
    assert!(text.contains("n=150"));
}

#[test]
fn figure2_emits_csv() {
    let text = run_ok(&[
        "figure2", "--task", "density", "--panel", "loocv", "--ns", "100,150", "--reps", "1",
    ]);
    let mut lines = text.lines();
    assert_eq!(lines.next().unwrap(), "series,n,k,mean_wall_secs,points_updated");
    assert!(text.contains("treecv-loocv-fixed,100,100,"));
}

#[test]
fn dist_reports_comm_columns() {
    let text = run_ok(&["dist", "--n", "400", "--ks", "4,8"]);
    assert!(text.contains("model_msgs"));
    assert!(text.lines().count() >= 4);
}

#[test]
fn grid_reports_best_lambda() {
    let text = run_ok(&["grid", "--n", "400", "--k", "4", "--log-lambdas", "-4,-3"]);
    assert!(text.contains("best:"));
}

#[test]
fn sweep_prints_ranked_table() {
    let text = run_ok(&[
        "sweep", "--task", "pegasos", "--n", "400", "--k", "5", "--reps", "2", "--sweep",
        "lambda=1e-3,1e-4,1e-5", "--threads", "2", "--seed", "9",
    ]);
    assert!(text.contains("pool_spawns=1"), "one pool for the whole sweep:\n{text}");
    assert!(text.contains("rank"), "{text}");
    assert!(text.contains("lambda"), "{text}");
    // Header + column line + one row per grid value.
    assert_eq!(text.lines().count(), 5, "{text}");
    // Rows are ranked by mean loss ascending (mean is the 5th column).
    let means: Vec<f64> = text
        .lines()
        .skip(2)
        .map(|l| l.split_whitespace().nth(4).unwrap().parse().unwrap())
        .collect();
    assert!(means.windows(2).all(|w| w[0] <= w[1]), "not ranked: {means:?}");
}

#[test]
fn sweep_json_output() {
    let text = run_ok(&[
        "sweep", "--task", "ridge", "--n", "200", "--k", "4", "--reps", "2", "--sweep",
        "lambda=0.5,1.0", "--threads", "2", "--json",
    ]);
    assert!(text.trim_start().starts_with('{'), "{text}");
    assert!(text.contains("\"points\""), "{text}");
    assert!(text.contains("\"pool_spawns\": 1"), "{text}");
    assert_eq!(text.matches("\"mean\"").count(), 2);
}

#[test]
fn sweep_malformed_grid_exits_nonzero() {
    let cases: [&[&str]; 5] = [
        // Unparsable value.
        &["sweep", "--task", "pegasos", "--n", "100", "--sweep", "lambda=abc"],
        // No `=` at all.
        &["sweep", "--task", "pegasos", "--n", "100", "--sweep", "lambda"],
        // Task without a sweepable hyperparameter.
        &["sweep", "--task", "density", "--n", "100", "--sweep", "lambda=0.1"],
        // Wrong parameter for the task.
        &["sweep", "--task", "pegasos", "--n", "100", "--sweep", "alpha=0.1"],
        // No grid given.
        &["sweep", "--task", "pegasos", "--n", "100"],
    ];
    for args in cases {
        let out = repro().args(args).output().unwrap();
        assert!(!out.status.success(), "`repro {args:?}` should fail");
    }
}

/// Drop the wall-clock token (the only legitimately nondeterministic
/// field) so two sweep tables can be compared for equality.
fn strip_wall(s: &str) -> String {
    s.lines()
        .map(|l| {
            l.split_whitespace()
                .filter(|t| !t.starts_with("total_wall="))
                .collect::<Vec<_>>()
                .join(" ")
        })
        .collect::<Vec<_>>()
        .join("\n")
}

/// `--no-race` forces the exhaustive sweep: identical output (modulo
/// wall-clock) to the same sweep with no racing flag at all — the
/// escape hatch when a config file sets `race = true`. `--race
/// --no-race` together is an error.
#[test]
fn sweep_no_race_is_the_exhaustive_sweep() {
    let base = [
        "sweep", "--task", "ridge", "--n", "160", "--k", "5", "--reps", "4", "--sweep",
        "lambda=0.1,1.0", "--threads", "1", "--seed", "9",
    ];
    let plain = run_ok(&base);
    assert!(plain.starts_with("sweep task=ridge"), "{plain}");
    let mut no_race = base.to_vec();
    no_race.push("--no-race");
    assert_eq!(strip_wall(&plain), strip_wall(&run_ok(&no_race)));
    let mut both = base.to_vec();
    both.extend(["--race", "--no-race"]);
    let out = repro().args(&both).output().unwrap();
    assert!(!out.status.success(), "--race --no-race must be rejected");
}

/// `sweep --race` end to end on a dominated grid: the race header echoes
/// the knobs, the work-saved line shows the scheduled/completed/cancelled
/// split, survivors are ranked above the eliminated value, and the
/// elimination trace renders with its decision column. The JSON form
/// carries the same counters and trace.
#[test]
fn sweep_race_prints_trace_and_work_saved() {
    let args = [
        "sweep", "--task", "ridge", "--n", "160", "--k", "5", "--reps", "8", "--sweep",
        "lambda=0.1,1000000.0", "--threads", "1", "--seed", "9", "--race", "--rounds", "4",
        "--alpha", "0.5",
    ];
    let text = run_ok(&args);
    assert!(text.starts_with("race task=ridge"), "{text}");
    assert!(text.contains("rounds=4 alpha=0.5"), "{text}");
    assert!(text.contains("work_saved: runs_scheduled=16"), "{text}");
    assert!(text.contains("survived"), "{text}");
    assert!(text.contains("out@r"), "{text}");
    assert!(text.contains("trace:"), "{text}");
    assert!(text.contains("eliminate"), "{text}");

    let mut json_args = args.to_vec();
    json_args.push("--json");
    let json = run_ok(&json_args);
    assert!(json.trim_start().starts_with('{'), "{json}");
    assert!(json.contains("\"runs_cancelled\""), "{json}");
    assert!(json.contains("\"trace\""), "{json}");
    assert!(json.contains("\"eliminated_round\""), "{json}");
}

/// The acceptance criterion end to end: a heterogeneous `repro select`
/// run batches ≥ 3 learner families through exactly ONE pool spawn
/// (per-pool counter, echoed in the table header) and ranks them by mean
/// loss.
#[test]
fn select_ranks_learner_families_through_one_pool() {
    let text = run_ok(&[
        "select",
        "--learners",
        "pegasos:lambda=1e-4,naive_bayes,knn,perceptron",
        "--n",
        "240",
        "--k",
        "4",
        "--reps",
        "2",
        "--threads",
        "3",
        "--seed",
        "9",
    ]);
    assert!(text.contains("pool_spawns=1"), "one pool for the whole selection:\n{text}");
    assert!(text.contains("rank"), "{text}");
    for name in ["pegasos(lambda=1e-4)", "naive_bayes", "knn", "perceptron"] {
        assert!(text.contains(name), "missing {name}:\n{text}");
    }
    // Header + column line + one row per learner.
    assert_eq!(text.lines().count(), 6, "{text}");
    // Rows are ranked by mean loss ascending (mean is the 4th column).
    let means: Vec<f64> = text
        .lines()
        .skip(2)
        .map(|l| l.split_whitespace().nth(3).unwrap().parse().unwrap())
        .collect();
    assert!(means.windows(2).all(|w| w[0] <= w[1]), "not ranked: {means:?}");
}

#[test]
fn select_json_output() {
    let text = run_ok(&[
        "select", "--learners", "pegasos,knn,naive_bayes", "--n", "160", "--k", "4", "--reps",
        "2", "--threads", "2", "--json",
    ]);
    assert!(text.trim_start().starts_with('{'), "{text}");
    assert!(text.contains("\"points\""), "{text}");
    assert!(text.contains("\"pool_spawns\": 1"), "{text}");
    assert!(text.contains("\"learner\""), "{text}");
    assert_eq!(text.matches("\"mean\"").count(), 3);
}

#[test]
fn select_rejects_bad_lists() {
    let cases: [&[&str]; 5] = [
        // No list given.
        &["select", "--n", "100"],
        // Mixed dataset families (classification vs regression).
        &["select", "--learners", "pegasos,ridge", "--n", "100"],
        // Parameter on a task that has none.
        &["select", "--learners", "knn:lambda=0.5,pegasos", "--n", "100"],
        // Unknown task name.
        &["select", "--learners", "pegasos,bogus", "--n", "100"],
        // Non-positive override value (clean error, not a panic).
        &["select", "--learners", "pegasos:lambda=0,knn", "--n", "100"],
    ];
    for args in cases {
        let out = repro().args(args).output().unwrap();
        assert!(!out.status.success(), "`repro {args:?}` should fail");
    }
}

#[test]
fn config_file_roundtrip() {
    let dir = std::env::temp_dir().join("treecv_cli_test");
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = dir.join("exp.toml");
    std::fs::write(&cfg, "task = \"density\"\nn = 120\nks = [3]\nrepetitions = 2\n").unwrap();
    let text = run_ok(&["cv", "--config", cfg.to_str().unwrap()]);
    assert!(text.contains("density"));
    assert!(text.contains("     3 ") || text.contains(" 3 "));
}
