//! Deterministic-race battery (the racing scheduler's acceptance
//! criteria): for a fixed seed the race's [`EliminationTrace`] and final
//! per-cell aggregates must be identical across worker counts {1, 3, 8}
//! and across re-runs — decisions are a pure function of the counted
//! repetition prefix, never of scheduling. With `alpha = 0` the sign
//! test can never reject, so the race must reproduce the exhaustive
//! sweep bit for bit. And on a grid with a clearly dominated value, the
//! coordinator's `RaceReport` must show real work saved: cancelled runs
//! > 0, ranked survivors ahead of the eliminated value, and a trace that
//! records the elimination.

use treecv::config::ExperimentConfig;
use treecv::coordinator::{format_race_table, run_race_sweep};
use treecv::cv::folds::Ordering;
use treecv::cv::race::{run_race, RaceOutcome, RaceSpec};
use treecv::cv::sweep::{run_sweep, SweepSpec};
use treecv::cv::Strategy;
use treecv::data::synth::SyntheticMixture1d;
use treecv::learner::histdensity::HistogramDensity;

const WORKER_COUNTS: [usize; 3] = [1, 3, 8];

fn race_spec(threads: usize, rounds: usize, alpha: f64) -> RaceSpec {
    RaceSpec {
        sweep: SweepSpec {
            ordering: Ordering::Fixed,
            strategies: vec![Strategy::Copy],
            k: 6,
            repetitions: 8,
            seed: 33,
            threads,
        },
        rounds,
        alpha,
    }
}

/// A grid with one clearly dominated configuration: a 2-bin histogram
/// density loses to the 64- and 48-bin models on essentially every
/// partitioning.
fn graded_learners() -> Vec<HistogramDensity> {
    vec![
        HistogramDensity::new(-8.0, 8.0, 64),
        HistogramDensity::new(-8.0, 8.0, 48),
        HistogramDensity::new(-8.0, 8.0, 2),
    ]
}

/// The schedule-independent summary of a race: the full decision trace
/// plus each cell's aggregate, with float fields compared by bits.
fn summary(out: &RaceOutcome) -> Vec<(usize, Option<usize>, usize, u64, u64)> {
    out.cells
        .iter()
        .map(|c| (c.config, c.eliminated_round, c.reps_used, c.mean.to_bits(), c.std.to_bits()))
        .collect()
}

/// Same seed ⇒ identical elimination trace AND final ranking inputs,
/// across worker counts {1, 3, 8} and across two runs at the same count.
/// Only the work-saved counters may differ with scheduling.
#[test]
fn race_trace_and_ranking_deterministic_across_workers_and_reruns() {
    let data = SyntheticMixture1d::new(300, 77).generate();
    let learners = graded_learners();
    let baseline = run_race(&learners, &data, &race_spec(1, 4, 0.3)).unwrap();
    assert!(
        baseline.cells.iter().any(|c| c.eliminated_round.is_some()),
        "the dominated config must actually be eliminated for this test to bite: {:?}",
        baseline.trace.rows
    );
    for threads in WORKER_COUNTS {
        let a = run_race(&learners, &data, &race_spec(threads, 4, 0.3)).unwrap();
        let b = run_race(&learners, &data, &race_spec(threads, 4, 0.3)).unwrap();
        assert_eq!(baseline.trace, a.trace, "threads={threads}");
        assert_eq!(a.trace, b.trace, "threads={threads} (re-run)");
        assert_eq!(summary(&baseline), summary(&a), "threads={threads}");
        assert_eq!(summary(&a), summary(&b), "threads={threads} (re-run)");
        // Per-run results of counted repetitions are bit-identical too.
        for (x, y) in baseline.cells.iter().zip(&a.cells) {
            assert_eq!(x.runs.len(), y.runs.len(), "threads={threads}");
            for (rx, ry) in x.runs.iter().zip(&y.runs) {
                assert_eq!(rx.per_fold, ry.per_fold, "threads={threads}");
            }
        }
    }
}

/// `alpha = 0` never eliminates (the exact binomial upper tail is always
/// strictly positive), so the race degenerates to the exhaustive sweep:
/// same cells, same means and stds to the bit, same per-fold vectors and
/// work counters, zero cancellations.
#[test]
fn alpha_zero_race_is_bitwise_identical_to_exhaustive_sweep() {
    let data = SyntheticMixture1d::new(300, 78).generate();
    let learners = graded_learners();
    let spec = race_spec(3, 4, 0.0);
    let race = run_race(&learners, &data, &spec).unwrap();
    let sweep = run_sweep(&learners, &data, &spec.sweep).unwrap();
    assert_eq!(race.runs_scheduled, 24);
    assert_eq!(race.runs_completed, 24);
    assert_eq!(race.runs_cancelled, 0);
    assert_eq!(race.tasks_cancelled, 0);
    assert_eq!(race.cells.len(), sweep.cells.len());
    for (rc, sc) in race.cells.iter().zip(&sweep.cells) {
        assert_eq!(rc.config, sc.config);
        assert_eq!(rc.eliminated_round, None);
        assert_eq!(rc.reps_used, 8);
        assert_eq!(rc.mean.to_bits(), sc.mean.to_bits());
        assert_eq!(rc.std.to_bits(), sc.std.to_bits());
        assert_eq!(rc.runs.len(), sc.runs.len());
        for (a, b) in rc.runs.iter().zip(&sc.runs) {
            assert_eq!(a.per_fold, b.per_fold);
            assert_eq!(a.estimate.to_bits(), b.estimate.to_bits());
            assert_eq!(a.ops.points_updated, b.ops.points_updated);
            assert_eq!(a.ops.model_copies, b.ops.model_copies);
            assert_eq!(a.ops.evals, b.ops.evals);
        }
    }
    // Every cell gets a decision row at every boundary, none eliminated.
    assert_eq!(race.trace.boundaries, vec![2, 4, 6, 8]);
    assert_eq!(race.trace.rows.len(), 4 * 3);
    assert!(race.trace.rows.iter().all(|r| !r.eliminated));
}

/// The coordinator's racing mode on a dominated hyperparameter grid
/// (`ridge` with a reasonable and an absurd regularizer): the
/// `RaceReport` shows real work saved — cancelled runs > 0 — ranks the
/// survivor ahead of the eliminated value, and the rendered table carries
/// the work-saved and trace sections.
#[test]
fn dominated_grid_race_report_saves_work() {
    let cfg = ExperimentConfig::parse(
        "task = \"ridge\"\n\
         n = 160\n\
         ks = [5]\n\
         repetitions = 8\n\
         seed = 9\n\
         threads = 1\n\
         sweep = \"lambda=0.1,1000000.0\"\n\
         race = true\n\
         race_rounds = 4\n\
         race_alpha = 0.5\n",
    )
    .unwrap();
    assert!(cfg.race);
    let report = run_race_sweep(&cfg).unwrap();
    assert_eq!(report.rounds, 4);
    assert_eq!(report.alpha, 0.5);
    assert_eq!(report.runs_scheduled, 16);
    assert_eq!(report.runs_completed + report.runs_cancelled, 16, "no run may fail");
    assert!(report.runs_cancelled > 0, "the dominated value must have runs cancelled");
    assert!(report.tree_tasks_cancelled > 0);
    // Exactly one value is eliminated, and it is ranked after the
    // survivor with a short repetition prefix.
    assert_eq!(report.points.len(), 2);
    assert_eq!(report.points[0].eliminated_round, None);
    assert_eq!(report.points[0].reps_used, 8);
    let loser = &report.points[1];
    assert!(loser.eliminated_round.is_some());
    assert!(loser.reps_used < 8, "a loser aggregates only its counted prefix");
    // The trace records the elimination with a significant p-value.
    let elim: Vec<_> = report.trace.iter().filter(|t| t.eliminated).collect();
    assert_eq!(elim.len(), 1);
    assert!(elim[0].p_value <= 0.5);
    assert_eq!(elim[0].value, loser.value);
    let table = format_race_table(&report);
    assert!(table.contains("work_saved:"), "{table}");
    assert!(table.contains("survived"), "{table}");
    assert!(table.contains("out@r"), "{table}");
    assert!(table.contains("trace:"), "{table}");
}

/// Raced and exhaustive coordinator paths agree at `alpha = 0`: same
/// ranked values in the same order, means and stds equal to the bit —
/// the `--no-race` escape hatch and the degenerate race are the same
/// table.
#[test]
fn coordinator_alpha_zero_race_matches_exhaustive_report() {
    let base = "task = \"ridge\"\n\
                n = 140\n\
                ks = [5]\n\
                repetitions = 4\n\
                seed = 11\n\
                threads = 2\n\
                sweep = \"lambda=0.01,0.1,1.0\"\n";
    let exhaustive =
        treecv::coordinator::run_sweep(&ExperimentConfig::parse(base).unwrap()).unwrap();
    let raced = run_race_sweep(
        &ExperimentConfig::parse(&format!(
            "{base}race = true\nrace_rounds = 2\nrace_alpha = 0.0\n"
        ))
        .unwrap(),
    )
    .unwrap();
    assert_eq!(raced.runs_cancelled, 0);
    assert_eq!(raced.points.len(), exhaustive.points.len());
    for (r, s) in raced.points.iter().zip(&exhaustive.points) {
        assert_eq!(r.value, s.value, "ranking order must match the exhaustive table");
        assert_eq!(r.mean.to_bits(), s.mean.to_bits());
        assert_eq!(r.std.to_bits(), s.std.to_bits());
        assert_eq!(r.eliminated_round, None);
    }
}
