//! Fold-contiguous layout equivalence battery (the acceptance criterion
//! of the physical-layout optimization): for EVERY pure-Rust learner in
//! the crate, running on the [`FoldedDataset`] layout must reproduce the
//! classic indexed path **bit-identically** — same estimate, same
//! per-fold scores in *original* fold numbering, same semantic work
//! counters — across engines {StandardCv, TreeCv, TreeCvExecutor},
//! strategies {Copy, SaveRevert}, orderings {Fixed, Randomized} and
//! worker counts {1, 3, 8}, including remainder-fold (`n % k ≠ 0`) and
//! LOOCV shapes.
//!
//! `stream_allocs` is the one layout-dependent counter (that is its
//! point): fixed-order folded runs must report **zero** node-stream
//! allocations, which is the "no index vector at all" claim made
//! observable.

use treecv::cv::executor::TreeCvExecutor;
use treecv::cv::folds::{Folds, Ordering};
use treecv::cv::standard::StandardCv;
use treecv::cv::treecv::TreeCv;
use treecv::cv::{CvEngine, CvResult, Strategy};
use treecv::data::folded::FoldedDataset;
use treecv::data::synth::{
    SyntheticBlobs, SyntheticCovertype, SyntheticMixture1d, SyntheticYearMsd,
};
use treecv::data::Dataset;
use treecv::learner::erased::{Erased, ErasedLearner};
use treecv::learner::histdensity::HistogramDensity;
use treecv::learner::kmeans::OnlineKMeans;
use treecv::learner::knn::KnnClassifier;
use treecv::learner::lsqsgd::LsqSgd;
use treecv::learner::multiset::MultisetLearner;
use treecv::learner::naive_bayes::GaussianNb;
use treecv::learner::pegasos::Pegasos;
use treecv::learner::perceptron::Perceptron;
use treecv::learner::ridge::OnlineRidge;
use treecv::learner::IncrementalLearner;

const WORKER_COUNTS: [usize; 3] = [1, 3, 8];

/// Bitwise equality of results and of every *semantic* counter.
/// `stream_allocs` is deliberately excluded — it is the layout-dependent
/// metric the optimization exists to change.
fn assert_bit_identical(indexed: &CvResult, folded: &CvResult, ctx: &str) {
    assert_eq!(indexed.per_fold.len(), folded.per_fold.len(), "{ctx}: fold count");
    for (i, (a, b)) in indexed.per_fold.iter().zip(&folded.per_fold).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "{ctx}: per_fold[{i}] {a} vs {b}");
    }
    assert_eq!(indexed.estimate.to_bits(), folded.estimate.to_bits(), "{ctx}: estimate");
    let (a, b) = (&indexed.ops, &folded.ops);
    assert_eq!(a.update_calls, b.update_calls, "{ctx}: update_calls");
    assert_eq!(a.points_updated, b.points_updated, "{ctx}: points_updated");
    assert_eq!(a.model_copies, b.model_copies, "{ctx}: model_copies");
    assert_eq!(a.bytes_copied, b.bytes_copied, "{ctx}: bytes_copied");
    assert_eq!(a.model_restores, b.model_restores, "{ctx}: model_restores");
    assert_eq!(a.evals, b.evals, "{ctx}: evals");
    assert_eq!(a.points_evaluated, b.points_evaluated, "{ctx}: points_evaluated");
    assert_eq!(a.points_permuted, b.points_permuted, "{ctx}: points_permuted");
}

/// The battery core: every engine × strategy × ordering × worker count,
/// indexed vs folded, on one `(learner, data, k)` cell.
fn check_learner<L>(name: &str, learner: &L, data: &Dataset, k: usize)
where
    L: IncrementalLearner + Sync,
    L::Model: Send,
{
    let folds = Folds::new(data.n, k, 0xF01D + k as u64);
    let folded = FoldedDataset::build(data, &folds);
    for ordering in [Ordering::Fixed, Ordering::Randomized] {
        // Standard CV (no strategy axis: it never rewinds a model).
        let engine = StandardCv::new(ordering, 7);
        let a = engine.run(learner, data, &folds);
        let b = engine.run_folded(learner, data, &folded);
        assert_bit_identical(&a, &b, &format!("{name} standard {ordering:?}"));
        if ordering == Ordering::Fixed {
            assert_eq!(b.ops.stream_allocs, 0, "{name} standard: folded fixed allocated");
        }
        for strategy in [Strategy::Copy, Strategy::SaveRevert] {
            let engine = TreeCv::new(strategy, ordering, 5);
            let a = engine.run(learner, data, &folds);
            let b = engine.run_folded(learner, data, &folded);
            let ctx = format!("{name} treecv {strategy:?} {ordering:?}");
            assert_bit_identical(&a, &b, &ctx);
            if ordering == Ordering::Fixed {
                assert_eq!(b.ops.stream_allocs, 0, "{ctx}: folded fixed allocated");
            }
            for threads in WORKER_COUNTS {
                let exe = TreeCvExecutor::new(strategy, ordering, 5, threads);
                let ai = exe.run(learner, data, &folds);
                let bi = exe.run_folded(learner, data, &folded);
                let ctx = format!("{name} executor {strategy:?} {ordering:?} t={threads}");
                assert_bit_identical(&ai, &bi, &ctx);
                if ordering == Ordering::Fixed {
                    assert_eq!(bi.ops.stream_allocs, 0, "{ctx}: folded fixed allocated");
                }
            }
        }
    }
}

fn covertype(n: usize) -> Dataset {
    SyntheticCovertype::new(n, 601).generate()
}

#[test]
fn pegasos_folded_is_bit_identical() {
    check_learner("pegasos", &Pegasos::new(54, 1e-3), &covertype(180), 7);
}

#[test]
fn perceptron_folded_is_bit_identical() {
    check_learner("perceptron", &Perceptron::new(54), &covertype(180), 7);
}

#[test]
fn knn_folded_is_bit_identical() {
    // Index-dependent model (the training set IS indices): exercises the
    // original-ids fallback path.
    check_learner("knn", &KnnClassifier::new(54, 3), &covertype(150), 6);
}

#[test]
fn naive_bayes_folded_is_bit_identical() {
    check_learner("gaussian-nb", &GaussianNb::new(54), &covertype(180), 7);
}

#[test]
fn multiset_folded_is_bit_identical() {
    // The structural oracle: its loss hashes the training *indices*, so
    // any engine that leaked folded positions into a learner would fail
    // loudly here.
    let data = SyntheticMixture1d::new(160, 602).generate();
    check_learner("multiset", &MultisetLearner::new(1), &data, 7);
}

#[test]
fn histdensity_folded_is_bit_identical() {
    let data = SyntheticMixture1d::new(200, 603).generate();
    check_learner("hist-density", &HistogramDensity::new(-8.0, 8.0, 32), &data, 9);
}

#[test]
fn kmeans_folded_is_bit_identical() {
    let data = SyntheticBlobs::new(180, 8, 5, 604).generate();
    check_learner("online-kmeans", &OnlineKMeans::new(8, 5), &data, 7);
}

#[test]
fn lsqsgd_folded_is_bit_identical() {
    let data = SyntheticYearMsd::new(180, 605).generate();
    check_learner("lsqsgd", &LsqSgd::new(90, 0.05), &data, 7);
}

#[test]
fn ridge_folded_is_bit_identical() {
    // Ridge overrides both `evaluate` (lazy solve) and the contiguous
    // fast paths; all four variants must agree bitwise.
    let data = SyntheticYearMsd::new(150, 606).generate();
    check_learner("online-ridge", &OnlineRidge::new(90, 0.7), &data, 6);
}

#[test]
fn remainder_folds_are_bit_identical() {
    // n % k != 0 puts the +1-sized chunks first; boundary arithmetic in
    // the contiguous ranges must match the logical chunks exactly.
    let data = SyntheticMixture1d::new(103, 607).generate();
    check_learner("hist-density", &HistogramDensity::new(-8.0, 8.0, 16), &data, 10);
    let data = covertype(94);
    check_learner("pegasos", &Pegasos::new(54, 1e-3), &data, 9);
}

#[test]
fn loocv_is_bit_identical() {
    // k = n: every chunk is a single contiguous row; the tree is as deep
    // as it gets and the leaf-evaluation fast path fires n times.
    let data = SyntheticMixture1d::new(48, 608).generate();
    check_learner("hist-density", &HistogramDensity::new(-8.0, 8.0, 16), &data, 48);
    let data = SyntheticMixture1d::new(40, 609).generate();
    check_learner("multiset", &MultisetLearner::new(1), &data, 40);
}

#[test]
fn erased_folded_matches_generic_folded() {
    // The layout must survive type erasure: run_erased_folded ==
    // run_folded bit for bit (ridge included, for its evaluate override).
    let data = SyntheticYearMsd::new(150, 610).generate();
    let ridge = OnlineRidge::new(90, 0.5);
    let folds = Folds::new(150, 8, 611);
    let folded = FoldedDataset::build(&data, &folds);
    let erased: Box<dyn ErasedLearner> = Erased::boxed(ridge.clone());
    for threads in WORKER_COUNTS {
        for strategy in [Strategy::Copy, Strategy::SaveRevert] {
            for ordering in [Ordering::Fixed, Ordering::Randomized] {
                let exe = TreeCvExecutor::new(strategy, ordering, 13, threads);
                let want = exe.run_folded(&ridge, &data, &folded);
                let got = exe.run_erased_folded(&*erased, &data, &folded);
                let ctx = format!("ridge erased {strategy:?} {ordering:?} t={threads}");
                assert_bit_identical(&want, &got, &ctx);
                // stream_allocs is schedule-dependent for multi-worker
                // randomized runs (one buffer per worker that touches an
                // update phase), so only the Fixed case has a pinnable
                // value — zero.
                if ordering == Ordering::Fixed {
                    assert_eq!(want.ops.stream_allocs, 0, "{ctx}");
                    assert_eq!(got.ops.stream_allocs, 0, "{ctx}");
                }
            }
        }
    }
}

#[test]
fn permutation_round_trip_property() {
    // Forward/inverse permutation bijection + content preservation, over
    // random shapes including k = 1, k = n and remainder folds.
    let mut seed = 0x5EEDu64;
    for _ in 0..25 {
        seed = seed.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        let n = 2 + (seed >> 33) as usize % 240;
        let k = 1 + (seed >> 17) as usize % n;
        let data = SyntheticMixture1d::new(n, seed).generate();
        let folds = Folds::new(n, k, seed ^ 0xF01D5);
        let f = FoldedDataset::build(&data, &folds);
        for p in 0..n as u32 {
            assert_eq!(f.position_of(f.original_of(p)), p, "n={n} k={k}");
            let i = f.original_of(p);
            assert_eq!(f.folded_data().row(p), data.row(i), "n={n} k={k}");
            assert_eq!(f.folded_data().label(p), data.label(i), "n={n} k={k}");
        }
        assert_eq!(f.ids(0, k - 1), folds.gather_range(0, k - 1).as_slice(), "n={n} k={k}");
        for c in 0..k {
            assert_eq!(f.ids(c, c), folds.chunk(c), "n={n} k={k} chunk {c}");
        }
    }
}

#[test]
fn indexed_paths_report_their_allocations() {
    // The other side of the zero-alloc claim: the indexed engines now
    // *count* their node-stream materializations — 2 per interior node
    // for the tree engines, one reused buffer for standard CV.
    let data = SyntheticMixture1d::new(128, 612).generate();
    let l = HistogramDensity::new(-8.0, 8.0, 16);
    let k = 16;
    let folds = Folds::new(128, k, 613);
    let tree = TreeCv::default().run(&l, &data, &folds);
    assert_eq!(tree.ops.stream_allocs, 2 * (k as u64 - 1));
    let std_res = StandardCv::default().run(&l, &data, &folds);
    assert_eq!(std_res.ops.stream_allocs, 1);
    let exe = TreeCvExecutor::new(Strategy::Copy, Ordering::Fixed, 0, 4).run(&l, &data, &folds);
    assert_eq!(exe.ops.stream_allocs, 2 * (k as u64 - 1));
    // Folded + randomized: streams come from recycled buffers — at most
    // one fresh allocation per worker, instead of 2(k−1) per run.
    let folded = FoldedDataset::build(&data, &folds);
    let exe = TreeCvExecutor::new(Strategy::Copy, Ordering::Randomized, 0, 4)
        .run_folded(&l, &data, &folded);
    assert!(
        exe.ops.stream_allocs <= 4,
        "folded randomized allocated {} buffers (> workers)",
        exe.ops.stream_allocs
    );
}

#[test]
fn folded_runs_are_run_twice_deterministic() {
    let data = covertype(160);
    let l = Pegasos::new(54, 1e-3);
    let folds = Folds::new(160, 9, 614);
    let folded = FoldedDataset::build(&data, &folds);
    let exe = TreeCvExecutor::new(Strategy::Copy, Ordering::Randomized, 2, 6);
    let a = exe.run_folded(&l, &data, &folded);
    let b = exe.run_folded(&l, &data, &folded);
    assert_bit_identical(&a, &b, "run-twice");
}

#[test]
fn kernel_dispatch_is_invisible() {
    // The kernel layer's equivalence contract at full system scale: pin
    // the backend to scalar, run the engine batteries, then rerun on the
    // machine-detected backend — every engine × strategy × ordering ×
    // worker-count result must be bit-identical. This is the regression
    // gate that lets `linalg` grow new SIMD paths without ever moving a
    // published number.
    use treecv::learner::linalg;

    let initial = linalg::kernel_backend();
    let detected = linalg::backend_from_override(None, linalg::avx2_available());
    let cells: [(&str, Dataset, usize); 3] = [
        ("pegasos", covertype(150), 6),
        ("online-ridge", SyntheticYearMsd::new(120, 615).generate(), 5),
        ("online-kmeans", SyntheticBlobs::new(150, 8, 5, 616).generate(), 6),
    ];

    linalg::force_backend(linalg::KernelBackend::Scalar);
    let scalar: Vec<CvResult> = cells
        .iter()
        .map(|(name, data, k)| run_cell(name, data, *k))
        .collect();
    linalg::force_backend(detected);
    let auto: Vec<CvResult> = cells
        .iter()
        .map(|(name, data, k)| run_cell(name, data, *k))
        .collect();
    linalg::force_backend(initial);

    for (i, (a, b)) in scalar.iter().zip(&auto).enumerate() {
        let ctx = format!("kernel-dispatch {} ({})", cells[i].0, detected.name());
        assert_bit_identical(a, b, &ctx);
    }
}

/// One representative engine run per learner family for the dispatch
/// battery (the exhaustive grid is `check_learner`'s job — and that whole
/// battery itself runs under whichever backend the machine detects).
fn run_cell(name: &str, data: &Dataset, k: usize) -> CvResult {
    let folds = Folds::new(data.n, k, 0xD15B + k as u64);
    let folded = FoldedDataset::build(data, &folds);
    let exe = TreeCvExecutor::new(Strategy::Copy, Ordering::Randomized, 3, 4);
    match name {
        "pegasos" => exe.run_folded(&Pegasos::new(data.d, 1e-3), data, &folded),
        "online-ridge" => exe.run_folded(&OnlineRidge::new(data.d, 0.7), data, &folded),
        "online-kmeans" => exe.run_folded(&OnlineKMeans::new(data.d, 5), data, &folded),
        _ => unreachable!("unknown cell {name}"),
    }
}
