//! Erased-vs-generic equivalence battery (the acceptance criterion of the
//! object-safe learner layer): for EVERY learner in the crate, the
//! type-erased path — `Erased(learner)` driven through
//! `TreeCvExecutor::run_erased` / `run_many_erased` — must reproduce the
//! generic `TreeCvExecutor` path **bit-identically**: same estimate, same
//! per-fold scores, same work counters, across both model-preservation
//! strategies and worker counts {1, 3, 8}, under both feeding orders.
//!
//! The XLA-backed learners run the same check when the PJRT runtime and
//! AOT artifacts are present, and skip cleanly otherwise (stub builds).

use treecv::cv::executor::{ErasedRunSpec, RunCtrl, TreeCvExecutor};
use treecv::cv::folds::{Folds, Ordering};
use treecv::cv::{CvResult, Strategy};
use treecv::data::synth::{
    SyntheticBlobs, SyntheticCovertype, SyntheticMixture1d, SyntheticYearMsd,
};
use treecv::data::Dataset;
use treecv::learner::erased::{Erased, ErasedLearner};
use treecv::learner::histdensity::HistogramDensity;
use treecv::learner::kmeans::OnlineKMeans;
use treecv::learner::knn::KnnClassifier;
use treecv::learner::lsqsgd::LsqSgd;
use treecv::learner::multiset::MultisetLearner;
use treecv::learner::naive_bayes::GaussianNb;
use treecv::learner::pegasos::Pegasos;
use treecv::learner::perceptron::Perceptron;
use treecv::learner::ridge::OnlineRidge;
use treecv::learner::IncrementalLearner;

const WORKER_COUNTS: [usize; 3] = [1, 3, 8];

fn assert_bit_identical(generic: &CvResult, erased: &CvResult, ctx: &str) {
    assert_eq!(generic.per_fold, erased.per_fold, "{ctx}: per_fold");
    assert_eq!(generic.estimate.to_bits(), erased.estimate.to_bits(), "{ctx}: estimate");
    let (g, e) = (&generic.ops, &erased.ops);
    assert_eq!(g.update_calls, e.update_calls, "{ctx}: update_calls");
    assert_eq!(g.points_updated, e.points_updated, "{ctx}: points_updated");
    assert_eq!(g.model_copies, e.model_copies, "{ctx}: model_copies");
    assert_eq!(g.bytes_copied, e.bytes_copied, "{ctx}: bytes_copied");
    assert_eq!(g.model_restores, e.model_restores, "{ctx}: model_restores");
    assert_eq!(g.evals, e.evals, "{ctx}: evals");
    assert_eq!(g.points_evaluated, e.points_evaluated, "{ctx}: points_evaluated");
    assert_eq!(g.points_permuted, e.points_permuted, "{ctx}: points_permuted");
}

/// The battery core: run `learner` generically and erased through the
/// executor at every (strategy × workers × ordering) combination and
/// demand bit-identical results. Takes the learner by value: the generic
/// runs borrow it, then the SAME instance is erased, so both paths use
/// identical hyperparameters.
fn check_learner<L>(name: &str, learner: L, data: &Dataset, k: usize)
where
    L: IncrementalLearner + Send + Sync + 'static,
    L::Model: Send + 'static,
    L::Undo: 'static,
{
    let folds = Folds::new(data.n, k, 901);
    let mut generic: Vec<(String, CvResult)> = Vec::new();
    for strategy in [Strategy::Copy, Strategy::SaveRevert] {
        for ordering in [Ordering::Fixed, Ordering::Randomized] {
            for threads in WORKER_COUNTS {
                let res = TreeCvExecutor::new(strategy, ordering, 17, threads)
                    .run(&learner, data, &folds);
                let ctx = format!("{name} {strategy:?} {ordering:?} threads={threads}");
                generic.push((ctx, res));
            }
        }
    }
    let erased: Box<dyn ErasedLearner> = Erased::boxed(learner);
    let mut it = generic.into_iter();
    for strategy in [Strategy::Copy, Strategy::SaveRevert] {
        for ordering in [Ordering::Fixed, Ordering::Randomized] {
            for threads in WORKER_COUNTS {
                let res = TreeCvExecutor::new(strategy, ordering, 17, threads)
                    .run_erased(&*erased, data, &folds);
                let (ctx, want) = it.next().expect("same combination count");
                assert_bit_identical(&want, &res, &ctx);
            }
        }
    }
}

fn covertype(n: usize) -> Dataset {
    SyntheticCovertype::new(n, 501).generate()
}

#[test]
fn pegasos_erased_is_bit_identical() {
    check_learner("pegasos", Pegasos::new(54, 1e-3), &covertype(180), 7);
}

#[test]
fn perceptron_erased_is_bit_identical() {
    check_learner("perceptron", Perceptron::new(54), &covertype(180), 7);
}

#[test]
fn knn_erased_is_bit_identical() {
    check_learner("knn", KnnClassifier::new(54, 3), &covertype(150), 6);
}

#[test]
fn naive_bayes_erased_is_bit_identical() {
    check_learner("gaussian-nb", GaussianNb::new(54), &covertype(180), 7);
}

#[test]
fn multiset_erased_is_bit_identical() {
    let data = SyntheticMixture1d::new(160, 502).generate();
    check_learner("multiset", MultisetLearner::new(1), &data, 7);
}

#[test]
fn histdensity_erased_is_bit_identical() {
    let data = SyntheticMixture1d::new(200, 503).generate();
    check_learner("hist-density", HistogramDensity::new(-8.0, 8.0, 32), &data, 9);
}

#[test]
fn kmeans_erased_is_bit_identical() {
    let data = SyntheticBlobs::new(180, 8, 5, 504).generate();
    check_learner("online-kmeans", OnlineKMeans::new(8, 5), &data, 7);
}

#[test]
fn lsqsgd_erased_is_bit_identical() {
    let data = SyntheticYearMsd::new(180, 505).generate();
    check_learner("lsqsgd", LsqSgd::new(90, 0.05), &data, 7);
}

#[test]
fn ridge_erased_is_bit_identical() {
    // Ridge overrides `evaluate` (lazy closed-form solve per chunk); the
    // erased layer must forward that override, not rebuild from `loss`.
    let data = SyntheticYearMsd::new(150, 506).generate();
    check_learner("online-ridge", OnlineRidge::new(90, 0.7), &data, 6);
}

/// XLA learners: same battery, gated on the PJRT runtime + artifacts
/// actually being present (clean skip in stub builds — constructors
/// error, never panic).
#[test]
fn xla_learners_erased_bit_identical_when_runtime_available() {
    use treecv::runtime::{xla_learner, Manifest, PjrtRuntime};
    let rt = match PjrtRuntime::cpu() {
        Ok(rt) => rt,
        Err(err) => {
            eprintln!("skipping XLA erased battery: {err}");
            return;
        }
    };
    let manifest = match Manifest::load_default() {
        Ok(m) => m,
        Err(err) => {
            eprintln!("skipping XLA erased battery: {err}");
            return;
        }
    };
    let data = covertype(128);
    match xla_learner::XlaPegasos::from_manifest(&rt, &manifest, data.d, 1e-3) {
        Ok(l) => check_learner("xla-pegasos", l, &data, 5),
        Err(err) => eprintln!("skipping xla-pegasos: {err}"),
    }
    let data = SyntheticYearMsd::new(128, 507).generate();
    match xla_learner::XlaLsqSgd::from_manifest(&rt, &manifest, data.d, 0.05) {
        Ok(l) => check_learner("xla-lsqsgd", l, &data, 5),
        Err(err) => eprintln!("skipping xla-lsqsgd: {err}"),
    }
}

/// Heterogeneous `run_many_erased` batches: runs of four different
/// learner families (mixed strategies and seeds) through ONE pool must
/// each be bit-identical to their standalone generic executor run at the
/// same worker count — and cost exactly one pool spawn per multi-worker
/// batch on the executor's per-pool counter.
#[test]
fn heterogeneous_batch_bit_identical_to_generic_standalone() {
    let data = covertype(160);
    let folds_a = Folds::new(160, 7, 902);
    let folds_b = Folds::new(160, 12, 903);
    let pegasos = Pegasos::new(54, 1e-4);
    let nb = GaussianNb::new(54);
    let knn = KnnClassifier::new(54, 3);
    let perceptron = Perceptron::new(54);
    let erased: [Box<dyn ErasedLearner>; 4] = [
        Erased::boxed(pegasos.clone()),
        Erased::boxed(nb.clone()),
        Erased::boxed(knn.clone()),
        Erased::boxed(perceptron.clone()),
    ];
    let strategies =
        [Strategy::Copy, Strategy::SaveRevert, Strategy::Copy, Strategy::SaveRevert];

    for threads in WORKER_COUNTS {
        let specs: Vec<ErasedRunSpec<'_>> = erased
            .iter()
            .zip(strategies)
            .enumerate()
            .map(|(i, (l, strategy))| ErasedRunSpec {
                learner: &**l,
                folds: if i % 2 == 0 { &folds_a } else { &folds_b },
                seed: 40 + i as u64,
                strategy,
                folded: None,
                ctrl: RunCtrl::default(),
            })
            .collect();
        let exe = TreeCvExecutor::new(Strategy::Copy, Ordering::Fixed, 0, threads);
        let batch = exe.run_many_erased(&data, &specs);
        assert_eq!(exe.pool_spawns(), u64::from(threads > 1), "threads={threads}");
        assert_eq!(batch.len(), 4);

        let standalone = |spec_idx: usize| -> CvResult {
            let spec = &specs[spec_idx];
            let engine = TreeCvExecutor::new(spec.strategy, Ordering::Fixed, spec.seed, threads);
            match spec_idx {
                0 => engine.run(&pegasos, &data, spec.folds),
                1 => engine.run(&nb, &data, spec.folds),
                2 => engine.run(&knn, &data, spec.folds),
                _ => engine.run(&perceptron, &data, spec.folds),
            }
        };
        for (i, got) in batch.iter().enumerate() {
            let want = standalone(i);
            assert_bit_identical(&want, got, &format!("run {i} threads={threads}"));
        }
    }
}

/// Run-twice determinism of a heterogeneous batch: scheduling and
/// stealing may differ between invocations, results may not.
#[test]
fn heterogeneous_batch_is_run_twice_deterministic() {
    let data = covertype(140);
    let folds = Folds::new(140, 9, 904);
    let erased: [Box<dyn ErasedLearner>; 3] = [
        Erased::boxed(Pegasos::new(54, 1e-3)),
        Erased::boxed(KnnClassifier::new(54, 3)),
        Erased::boxed(GaussianNb::new(54)),
    ];
    let specs: Vec<ErasedRunSpec<'_>> = erased
        .iter()
        .enumerate()
        .map(|(i, l)| ErasedRunSpec {
            learner: &**l,
            folds: &folds,
            seed: i as u64,
            strategy: Strategy::Copy,
            folded: None,
            ctrl: RunCtrl::default(),
        })
        .collect();
    let exe = TreeCvExecutor::new(Strategy::Copy, Ordering::Randomized, 0, 6);
    let a = exe.run_many_erased(&data, &specs);
    let b = exe.run_many_erased(&data, &specs);
    for (i, (x, y)) in a.iter().zip(&b).enumerate() {
        assert_bit_identical(x, y, &format!("run {i}"));
    }
    assert_eq!(exe.pool_spawns(), 2);
}
