//! Streaming-refresh integration battery: the incremental re-estimation
//! engine (`cv::refresh`) against every pure-Rust learner, both
//! strategies, several worker counts and fold shapes — the tentpole claim
//! is that an appended-batch refresh reproduces a from-scratch folded run
//! on the extended dataset while recomputing only O(log k) subtrees per
//! touched fold (pinned via `OpCounts::subtrees_recomputed`). Plus the
//! retire-then-append round trip, run-twice determinism, and a `repro
//! serve` CLI smoke test over the line protocol.
//!
//! Equality tiers mirror `tests/integration_cv.rs`: under Copy every
//! learner is bitwise (refresh replays the exact per-node update streams
//! a scratch run feeds, reaching interior models through exact clones);
//! under SaveRevert bitwise holds for exact-revert learners, while the
//! f32/f64 inexact-revert learners (perceptron, gaussian NB, online
//! ridge) agree to the usual revert-cascade tolerances — their scratch
//! runs reach interior models through lossy reverts, the refresh through
//! clones.

use treecv::cv::executor::TreeCvExecutor;
use treecv::cv::folds::{Folds, Ordering};
use treecv::cv::Strategy;
use treecv::data::folded::FoldedDataset;
use treecv::data::synth::*;
use treecv::data::Dataset;
use treecv::learner::histdensity::HistogramDensity;
use treecv::learner::kmeans::OnlineKMeans;
use treecv::learner::knn::KnnClassifier;
use treecv::learner::lsqsgd::LsqSgd;
use treecv::learner::multiset::MultisetLearner;
use treecv::learner::naive_bayes::GaussianNb;
use treecv::learner::pegasos::Pegasos;
use treecv::learner::perceptron::Perceptron;
use treecv::learner::ridge::OnlineRidge;
use treecv::learner::IncrementalLearner;

fn ceil_log2(k: usize) -> u64 {
    (usize::BITS - (k - 1).leading_zeros()) as u64
}

fn dummy(n: usize) -> Dataset {
    Dataset::new(vec![0.0; n], vec![0.0; n], 1)
}

fn assert_close(a: &[f64], b: &[f64], tol: Option<f64>, ctx: &str) {
    match tol {
        None => assert_eq!(a, b, "{ctx}"),
        Some(t) => {
            assert_eq!(a.len(), b.len(), "{ctx}");
            for (i, (x, y)) in a.iter().zip(b).enumerate() {
                assert!((x - y).abs() <= t, "{ctx} fold {i}: {x} vs {y} (tol {t})");
            }
        }
    }
}

/// The battery core: prime on the first `n` rows of `full`, stream the
/// rest in two appended batches through `refresh`, and compare the final
/// estimate per fold against a from-scratch pooled run on the extended
/// layout — at worker counts {1, 3, 8} — while pinning the
/// `subtrees_recomputed ≤ touched · ⌈log₂(2k)⌉` budget on every refresh.
fn assert_streamed_matches_scratch<L>(
    learner: &L,
    full: &Dataset,
    n: usize,
    k: usize,
    strategy: Strategy,
    ordering: Ordering,
    tol: Option<f64>,
) where
    L: IncrementalLearner + Sync,
    L::Model: Send,
{
    let d = full.d;
    let extra = full.n - n;
    assert!(extra >= 2, "need at least two append batches");
    let cut = n + extra / 2;
    let batches = [(n, cut), (cut, full.n)];
    for threads in [1usize, 3, 8] {
        let exe = TreeCvExecutor::new(strategy, ordering, 5, threads);
        let mut data = full.take(n);
        let folds = Folds::new(n, k, 0x5EED);
        let mut folded = FoldedDataset::build(&data, &folds);
        let (mut session, baseline) = exe.prime(learner, &data, &folded);
        assert_eq!(baseline.per_fold.len(), k);
        let ctx = format!(
            "{} n={n} k={k} threads={threads} {strategy:?} {ordering:?}",
            learner.name()
        );
        let mut last = baseline;
        for &(lo, hi) in &batches {
            let xs = &full.x[lo * d..hi * d];
            let ys = &full.y[lo..hi];
            data.push_rows(xs, ys);
            let delta = folded.append_rows(xs, ys);
            last = exe.refresh(&mut session, learner, &data, &folded, &delta);
            let bound = delta.touched.len() as u64 * (ceil_log2(k) + 1);
            assert!(
                last.ops.subtrees_recomputed <= bound,
                "{ctx}: subtrees_recomputed {} > bound {bound}",
                last.ops.subtrees_recomputed
            );
            assert!(last.ops.subtrees_recomputed > 0, "{ctx}: refresh did no work");
        }
        let scratch = exe.run_folded(learner, &data, &folded);
        assert_eq!(scratch.ops.subtrees_recomputed, 0, "{ctx}: scratch runs never refresh");
        assert_close(&last.per_fold, &scratch.per_fold, tol, &ctx);
        assert_eq!(data.n, full.n, "{ctx}");
    }
}

/// Exact-arithmetic learners: bitwise under BOTH strategies (their revert
/// is exact, so scratch SaveRevert runs reach the same interior states
/// the refresh clones).
#[test]
fn streamed_refresh_exact_learners_bitwise() {
    let (n, b) = (120usize, 10usize);
    let flat = dummy(n + b);
    let mix = SyntheticMixture1d::new(n + b, 61).generate();
    let blobs = SyntheticBlobs::new(n + b, 8, 5, 67).generate();
    for strategy in [Strategy::Copy, Strategy::SaveRevert] {
        let o = Ordering::Fixed;
        assert_streamed_matches_scratch(&MultisetLearner::new(1), &flat, n, 8, strategy, o, None);
        let hist = HistogramDensity::new(-8.0, 8.0, 32);
        assert_streamed_matches_scratch(&hist, &mix, n, 5, strategy, o, None);
        let km = OnlineKMeans::new(8, 5);
        assert_streamed_matches_scratch(&km, &blobs, n, 6, strategy, o, None);
    }
}

/// Covertype classifiers. k-NN and Pegasos revert exactly (model = the
/// training set / exact logged weights) → bitwise both strategies. The
/// f32 perceptron's revert is ulp-inexact and its per-fold loss is a 0/1
/// error rate, so SaveRevert agreement is up to a few flipped
/// predictions per fold; gaussian NB's f64 sufficient statistics agree
/// to rounding.
#[test]
fn streamed_refresh_covertype_learners() {
    let (n, b) = (160usize, 12usize);
    let cover = SyntheticCovertype::new(n + b, 62).generate();
    for strategy in [Strategy::Copy, Strategy::SaveRevert] {
        let o = Ordering::Fixed;
        let knn = KnnClassifier::new(54, 3);
        assert_streamed_matches_scratch(&knn, &cover, n, 8, strategy, o, None);
        let pegasos = Pegasos::new(54, 1e-4);
        assert_streamed_matches_scratch(&pegasos, &cover, n, 8, strategy, o, None);
        let nb_tol = match strategy {
            Strategy::Copy => None,
            Strategy::SaveRevert => Some(1e-9),
        };
        assert_streamed_matches_scratch(&GaussianNb::new(54), &cover, n, 8, strategy, o, nb_tol);
        let p_tol = match strategy {
            Strategy::Copy => None,
            Strategy::SaveRevert => Some(0.15),
        };
        assert_streamed_matches_scratch(&Perceptron::new(54), &cover, n, 8, strategy, o, p_tol);
    }
}

/// Regression learners on the YearMSD family: LsqSgd's logged revert is
/// exact → bitwise; online ridge's d² sufficient statistics agree to the
/// usual 1e-6 under SaveRevert.
#[test]
fn streamed_refresh_regression_learners() {
    let (n, b) = (140usize, 10usize);
    let year = SyntheticYearMsd::new(n + b, 64).generate();
    for strategy in [Strategy::Copy, Strategy::SaveRevert] {
        let o = Ordering::Fixed;
        let lsq = LsqSgd::with_paper_step(90, n);
        assert_streamed_matches_scratch(&lsq, &year, n, 7, strategy, o, None);
        let ridge_tol = match strategy {
            Strategy::Copy => None,
            Strategy::SaveRevert => Some(1e-6),
        };
        let ridge = OnlineRidge::new(90, 1.0);
        assert_streamed_matches_scratch(&ridge, &year, n, 7, strategy, o, ridge_tol);
    }
}

/// Remainder folds (k ∤ n) and a LOOCV-shaped session (k = initial n;
/// appended rows grow the leaf chunks past size 1, which stays a valid
/// k-fold layout).
#[test]
fn streamed_refresh_remainder_and_loocv_shapes() {
    let b = 6;
    let odd = dummy(43 + b);
    for strategy in [Strategy::Copy, Strategy::SaveRevert] {
        let l = MultisetLearner::new(1);
        assert_streamed_matches_scratch(&l, &odd, 43, 8, strategy, Ordering::Fixed, None);
    }
    let tiny = dummy(24 + b);
    let l = MultisetLearner::new(1);
    assert_streamed_matches_scratch(&l, &tiny, 24, 24, Strategy::Copy, Ordering::Fixed, None);
}

/// Randomized feeding order: refresh derives the identical per-node
/// `(seed, tag)` permutation streams a scratch run derives, so it stays
/// bitwise — the strongest scheduling-equivalence check.
#[test]
fn streamed_refresh_randomized_ordering_bitwise() {
    let (n, b) = (110usize, 8usize);
    let flat = dummy(n + b);
    let mix = SyntheticMixture1d::new(n + b, 44).generate();
    for strategy in [Strategy::Copy, Strategy::SaveRevert] {
        let o = Ordering::Randomized;
        assert_streamed_matches_scratch(&MultisetLearner::new(1), &flat, n, 8, strategy, o, None);
        let hist = HistogramDensity::new(-8.0, 8.0, 32);
        assert_streamed_matches_scratch(&hist, &mix, n, 5, strategy, o, None);
    }
}

/// Sliding window: retire the oldest rows (invalidate + re-prime, as the
/// serve loop does), then append and refresh — the result must match a
/// from-scratch run on the slid-and-extended window.
#[test]
fn retire_then_append_round_trip_matches_scratch() {
    let (n, b, retired) = (60usize, 8usize, 10usize);
    let full = SyntheticMixture1d::new(n + b, 3).generate();
    let d = full.d;
    let l = HistogramDensity::new(-8.0, 8.0, 32);
    let exe = TreeCvExecutor::new(Strategy::Copy, Ordering::Fixed, 5, 3);
    let mut data = full.take(n);
    let folds = Folds::new(n, 5, 7);
    let mut folded = FoldedDataset::build(&data, &folds);
    let (mut session, _) = exe.prime(&l, &data, &folded);

    assert!(folded.folds().can_retire_below(retired as u32));
    data.retire_front(retired);
    folded.retire_oldest(retired);
    session.invalidate();
    let (fresh_session, _) = exe.prime(&l, &data, &folded);
    session = fresh_session;

    let xs = &full.x[n * d..];
    let ys = &full.y[n..];
    data.push_rows(xs, ys);
    let delta = folded.append_rows(xs, ys);
    let got = exe.refresh(&mut session, &l, &data, &folded, &delta);
    let scratch = exe.run_folded(&l, &data, &folded);
    assert_eq!(got.per_fold, scratch.per_fold);
    assert_eq!(got.estimate, scratch.estimate);
    assert_eq!(data.n, n - retired + b);
}

/// The whole streaming session — prime, three appended batches, their
/// refreshed estimates — is a pure function of (data, seeds): running it
/// twice reproduces every intermediate estimate bitwise, even under
/// randomized ordering on a pooled executor.
#[test]
fn streaming_run_twice_is_deterministic() {
    let (n, b) = (90usize, 9usize);
    let full = SyntheticCovertype::new(n + b, 8).generate();
    let l = Pegasos::new(54, 1e-4);
    let run_once = || {
        let exe = TreeCvExecutor::new(Strategy::Copy, Ordering::Randomized, 13, 3);
        let mut data = full.take(n);
        let folds = Folds::new(n, 6, 17);
        let mut folded = FoldedDataset::build(&data, &folds);
        let (mut session, baseline) = exe.prime(&l, &data, &folded);
        let mut estimates = vec![baseline.estimate];
        let mut lo = n;
        while lo < n + b {
            let hi = (lo + 3).min(n + b);
            let xs = &full.x[lo * 54..hi * 54];
            let ys = &full.y[lo..hi];
            data.push_rows(xs, ys);
            let delta = folded.append_rows(xs, ys);
            estimates.push(exe.refresh(&mut session, &l, &data, &folded, &delta).estimate);
            lo = hi;
        }
        estimates
    };
    let first = run_once();
    let second = run_once();
    assert_eq!(first, second);
    assert_eq!(first.len(), 4, "baseline + three refreshed estimates");
}

/// `repro serve` end to end over the line protocol: rows auto-apply at
/// the batch size, queries report staleness, and the final report renders
/// the throughput/staleness schema.
#[test]
fn serve_cli_smoke() {
    use std::io::Write as _;
    use std::process::{Command, Stdio};
    let mut child = Command::new(env!("CARGO_BIN_EXE_repro"))
        .args([
            "serve", "--task", "multiset", "--n", "60", "--k", "4", "--batch", "2", "--seed",
            "3", "--threads", "1",
        ])
        .stdin(Stdio::piped())
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .spawn()
        .expect("spawn repro serve");
    {
        // invariant: stdin was piped three lines above, so it is present.
        let stdin = child.stdin.as_mut().expect("stdin piped");
        stdin
            .write_all(b"row 0.5 1.0\nquery\nrow -0.5 2.0\nquery\nstats\nquit\n")
            .expect("write protocol");
    }
    let out = child.wait_with_output().expect("serve run");
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8(out.stdout).expect("utf8 stdout");
    assert!(text.contains("applied rows=2"), "{text}");
    assert!(text.contains("pending 1"), "{text}");
    assert!(text.contains("pending 0"), "{text}");
    assert!(text.contains("stats n=62"), "{text}");
    assert!(text.contains("serve task=multiset"), "{text}");
    assert!(text.contains("rows_per_sec"), "{text}");
    assert!(text.contains("subtrees_recomputed"), "{text}");
}
