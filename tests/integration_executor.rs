//! Executor equivalence properties: the pooled work-stealing executor
//! (`cv::executor::TreeCvExecutor`), the §4.1 parallel facade
//! (`cv::parallel::ParallelTreeCv`), the scoped-fork baseline, and the
//! sequential engine must all compute the *same function* — identical
//! `per_fold` vectors and identical work counters — across random shapes,
//! both orderings, and both model-preservation strategies. Seeded trials
//! stand in for proptest (unavailable offline), mirroring
//! `tests/integration_cv.rs`.

use treecv::cv::executor::TreeCvExecutor;
use treecv::cv::folds::{Folds, Ordering};
use treecv::cv::parallel::{ParallelTreeCv, ScopedForkTreeCv};
use treecv::cv::treecv::TreeCv;
use treecv::cv::{CvEngine, Strategy};
use treecv::data::synth::{SyntheticCovertype, SyntheticMixture1d};
use treecv::learner::histdensity::HistogramDensity;
use treecv::learner::pegasos::Pegasos;

/// Draw a random CV shape: k ∈ [2, 64], n ∈ [k, 400].
fn random_shape(rng: &mut treecv::rng::Rng) -> (usize, usize) {
    let k = 2 + rng.below(63) as usize;
    let n = k + rng.below((400 - k) as u64 + 1) as usize;
    (n, k)
}

/// Property: for an order-*sensitive* learner (PEGASOS) under the Copy
/// strategy, executor == parallel facade == scoped baseline == sequential,
/// bit for bit, under both orderings — including the counters the
/// Theorem-3 bound is asserted against.
#[test]
fn prop_executor_matches_sequential_and_parallel() {
    let mut rng = treecv::rng::Rng::new(0xEC5);
    for trial in 0..12 {
        let (n, k) = random_shape(&mut rng);
        let seed = rng.next_u64();
        let threads = 1 + rng.below(8) as usize;
        let data = SyntheticCovertype::new(n, seed).generate();
        let folds = Folds::new(n, k, seed ^ 0x0F);
        let l = Pegasos::new(54, 1e-3);
        for ordering in [Ordering::Fixed, Ordering::Randomized] {
            let ctx = format!("trial {trial}: n={n} k={k} threads={threads} {ordering:?}");
            let seq = TreeCv::new(Strategy::Copy, ordering, seed).run(&l, &data, &folds);
            let par = ParallelTreeCv::new(ordering, seed, 3).run(&l, &data, &folds);
            let sco = ScopedForkTreeCv::new(ordering, seed, 2).run(&l, &data, &folds);
            let exe = TreeCvExecutor::new(ordering, seed, threads).run(&l, &data, &folds);
            assert_eq!(seq.per_fold, par.per_fold, "{ctx} (parallel facade)");
            assert_eq!(seq.per_fold, sco.per_fold, "{ctx} (scoped baseline)");
            assert_eq!(seq.per_fold, exe.per_fold, "{ctx} (executor)");
            assert_eq!(seq.ops.points_updated, exe.ops.points_updated, "{ctx}");
            assert_eq!(seq.ops.evals, exe.ops.evals, "{ctx}");
            assert_eq!(seq.ops.update_calls, exe.ops.update_calls, "{ctx}");
            assert_eq!(seq.ops.points_evaluated, exe.ops.points_evaluated, "{ctx}");
            assert_eq!(seq.ops.points_permuted, exe.ops.points_permuted, "{ctx}");
            // Theorem 3 still holds for the executor's counters.
            let bound = (n as f64) * ((2 * k) as f64).log2();
            assert!(
                exe.ops.points_updated as f64 <= bound + 1e-9,
                "{ctx}: {} > {bound}",
                exe.ops.points_updated
            );
        }
    }
}

/// Property: for a learner with exact revert (histogram density), the
/// executor (which always copies at forks) agrees with sequential TreeCV
/// under *both* strategies — Copy and SaveRevert compute the same leaves.
#[test]
fn prop_executor_matches_both_strategies() {
    let mut rng = treecv::rng::Rng::new(0xEC6);
    for trial in 0..12 {
        let (n, k) = random_shape(&mut rng);
        let seed = rng.next_u64();
        let data = SyntheticMixture1d::new(n, seed).generate();
        let folds = Folds::new(n, k, seed ^ 0xF0);
        let l = HistogramDensity::new(-8.0, 8.0, 32);
        for ordering in [Ordering::Fixed, Ordering::Randomized] {
            let exe = TreeCvExecutor::new(ordering, seed, 4).run(&l, &data, &folds);
            for strategy in [Strategy::Copy, Strategy::SaveRevert] {
                let seq = TreeCv::new(strategy, ordering, seed).run(&l, &data, &folds);
                assert_eq!(
                    seq.per_fold, exe.per_fold,
                    "trial {trial}: n={n} k={k} {ordering:?} {strategy:?}"
                );
                assert_eq!(seq.ops.points_updated, exe.ops.points_updated);
                assert_eq!(seq.ops.evals, exe.ops.evals);
            }
        }
    }
}

/// The executor's copy count is exactly one snapshot per interior node
/// (k − 1), independent of the worker count — the buffer pool recycles
/// storage without changing the §4.1 accounting.
#[test]
fn executor_copy_accounting_is_pool_size_independent() {
    let n = 450;
    let k = 30;
    let data = SyntheticMixture1d::new(n, 7).generate();
    let l = HistogramDensity::new(-8.0, 8.0, 32);
    let folds = Folds::new(n, k, 8);
    for threads in [1usize, 2, 5, 8] {
        let exe = TreeCvExecutor::new(Ordering::Fixed, 0, threads).run(&l, &data, &folds);
        assert_eq!(exe.ops.model_copies, (k - 1) as u64, "threads={threads}");
        assert_eq!(exe.ops.model_restores, 0, "threads={threads}");
        assert_eq!(exe.ops.evals, k as u64, "threads={threads}");
    }
}
