//! Executor equivalence properties: the pooled work-stealing executor
//! (`cv::executor::TreeCvExecutor`), the §4.1 parallel facade
//! (`cv::parallel::ParallelTreeCv`), the scoped-fork baseline, and the
//! sequential engine must all compute the *same function* — identical
//! `per_fold` vectors and identical work counters — across random shapes,
//! both orderings, and both model-preservation strategies. For SaveRevert
//! the executor additionally must keep its model-copy count at the fork
//! frontier (O(workers), strictly below the k − 1 a Copy run pays), with
//! `model_restores` carrying the rest. Seeded trials stand in for proptest
//! (unavailable offline), mirroring `tests/integration_cv.rs`.

use treecv::cv::executor::{snapshot_cutoff, RunCtrl, RunOutcome, RunSpec, TreeCvExecutor};
use treecv::cv::folds::{Folds, Ordering};
use treecv::cv::parallel::{ParallelTreeCv, ScopedForkTreeCv};
use treecv::cv::treecv::TreeCv;
use treecv::cv::{CvEngine, Strategy};
use treecv::data::synth::{SyntheticCovertype, SyntheticMixture1d};
use treecv::data::Dataset;
use treecv::learner::histdensity::HistogramDensity;
use treecv::learner::multiset::MultisetLearner;
use treecv::learner::pegasos::Pegasos;
use treecv::learner::perceptron::Perceptron;

/// The worker counts the SaveRevert properties sweep: inline (1), odd (3),
/// a typical machine (6), and more workers than some trees have depth (16).
const WORKER_COUNTS: [usize; 4] = [1, 3, 6, 16];

/// Draw a random CV shape: k ∈ [2, 64], n ∈ [k, 400].
fn random_shape(rng: &mut treecv::rng::Rng) -> (usize, usize) {
    let k = 2 + rng.below(63) as usize;
    let n = k + rng.below((400 - k) as u64 + 1) as usize;
    (n, k)
}

/// Property: for an order-*sensitive* learner (PEGASOS) under the Copy
/// strategy, executor == parallel facade == scoped baseline == sequential,
/// bit for bit, under both orderings — including the counters the
/// Theorem-3 bound is asserted against.
#[test]
fn prop_executor_matches_sequential_and_parallel() {
    let mut rng = treecv::rng::Rng::new(0xEC5);
    for trial in 0..12 {
        let (n, k) = random_shape(&mut rng);
        let seed = rng.next_u64();
        let threads = 1 + rng.below(8) as usize;
        let data = SyntheticCovertype::new(n, seed).generate();
        let folds = Folds::new(n, k, seed ^ 0x0F);
        let l = Pegasos::new(54, 1e-3);
        for ordering in [Ordering::Fixed, Ordering::Randomized] {
            let ctx = format!("trial {trial}: n={n} k={k} threads={threads} {ordering:?}");
            let seq = TreeCv::new(Strategy::Copy, ordering, seed).run(&l, &data, &folds);
            let par = ParallelTreeCv::new(Strategy::Copy, ordering, seed, 3).run(&l, &data, &folds);
            let sco =
                ScopedForkTreeCv::new(Strategy::Copy, ordering, seed, 2).run(&l, &data, &folds);
            let exe =
                TreeCvExecutor::new(Strategy::Copy, ordering, seed, threads).run(&l, &data, &folds);
            assert_eq!(seq.per_fold, par.per_fold, "{ctx} (parallel facade)");
            assert_eq!(seq.per_fold, sco.per_fold, "{ctx} (scoped baseline)");
            assert_eq!(seq.per_fold, exe.per_fold, "{ctx} (executor)");
            assert_eq!(seq.ops.points_updated, exe.ops.points_updated, "{ctx}");
            assert_eq!(seq.ops.evals, exe.ops.evals, "{ctx}");
            assert_eq!(seq.ops.update_calls, exe.ops.update_calls, "{ctx}");
            assert_eq!(seq.ops.points_evaluated, exe.ops.points_evaluated, "{ctx}");
            assert_eq!(seq.ops.points_permuted, exe.ops.points_permuted, "{ctx}");
            // Theorem 3 still holds for the executor's counters.
            let bound = (n as f64) * ((2 * k) as f64).log2();
            assert!(
                exe.ops.points_updated as f64 <= bound + 1e-9,
                "{ctx}: {} > {bound}",
                exe.ops.points_updated
            );
        }
    }
}

/// Property: for a learner with exact revert (histogram density), the
/// strategy-aware executor run under each strategy agrees bit-for-bit with
/// sequential TreeCV under that same strategy, both orderings, random
/// shapes and pool sizes.
#[test]
fn prop_executor_matches_both_strategies() {
    let mut rng = treecv::rng::Rng::new(0xEC6);
    for trial in 0..12 {
        let (n, k) = random_shape(&mut rng);
        let seed = rng.next_u64();
        let threads = 1 + rng.below(8) as usize;
        let data = SyntheticMixture1d::new(n, seed).generate();
        let folds = Folds::new(n, k, seed ^ 0xF0);
        let l = HistogramDensity::new(-8.0, 8.0, 32);
        for ordering in [Ordering::Fixed, Ordering::Randomized] {
            for strategy in [Strategy::Copy, Strategy::SaveRevert] {
                let seq = TreeCv::new(strategy, ordering, seed).run(&l, &data, &folds);
                let exe = TreeCvExecutor::new(strategy, ordering, seed, threads)
                    .run(&l, &data, &folds);
                assert_eq!(
                    seq.per_fold, exe.per_fold,
                    "trial {trial}: n={n} k={k} threads={threads} {ordering:?} {strategy:?}"
                );
                assert_eq!(seq.ops.points_updated, exe.ops.points_updated);
                assert_eq!(seq.ops.evals, exe.ops.evals);
                assert_eq!(seq.ops.points_permuted, exe.ops.points_permuted);
            }
        }
    }
}

/// SaveRevert equivalence on the *exactly reverting* structural oracle:
/// executor ≡ sequential TreeCv per fold, bit for bit, across worker
/// counts, remainder folds (k ∤ n), and LOOCV.
#[test]
fn save_revert_multiset_oracle_bit_identical() {
    for (n, k) in [(96usize, 8usize), (103, 13), (47, 47), (200, 200)] {
        let data = Dataset::new(vec![0.0; n], vec![0.0; n], 1);
        let l = MultisetLearner::new(1);
        let folds = if k == n { Folds::loocv(n) } else { Folds::new(n, k, 5) };
        let seq = TreeCv::new(Strategy::SaveRevert, Ordering::Fixed, 2).run(&l, &data, &folds);
        for threads in WORKER_COUNTS {
            let exe = TreeCvExecutor::new(Strategy::SaveRevert, Ordering::Fixed, 2, threads)
                .run(&l, &data, &folds);
            assert_eq!(seq.per_fold, exe.per_fold, "n={n} k={k} threads={threads}");
            assert_eq!(seq.ops.points_updated, exe.ops.points_updated, "n={n} k={k}");
            assert_eq!(seq.ops.evals, exe.ops.evals, "n={n} k={k}");
        }
    }
}

/// SaveRevert equivalence for the perceptron, whose revert is only
/// ulp-accurate (f32 re-subtraction): `threads = 1` runs the whole tree
/// inline and must be bit-identical, ulp noise and all; larger pools
/// snapshot at the fork frontier where the sequential engine reverts, so
/// per-fold scores agree to the ulp-cascade tolerance the sequential
/// Copy-vs-SaveRevert comparison already exhibits
/// (`integration_cv::perceptron_save_revert_close_to_copy`).
#[test]
fn save_revert_perceptron_matches_sequential_ulp_tolerant() {
    let n = 2_000;
    let data = SyntheticCovertype::new(n, 21).generate();
    let l = Perceptron::new(54);
    let folds = Folds::new(n, 16, 22);
    let seq = TreeCv::new(Strategy::SaveRevert, Ordering::Fixed, 2).run(&l, &data, &folds);
    let inline =
        TreeCvExecutor::new(Strategy::SaveRevert, Ordering::Fixed, 2, 1).run(&l, &data, &folds);
    assert_eq!(seq.per_fold, inline.per_fold, "threads=1 must be bit-identical");
    for threads in [3usize, 6, 16] {
        let exe = TreeCvExecutor::new(Strategy::SaveRevert, Ordering::Fixed, 2, threads)
            .run(&l, &data, &folds);
        for (i, (a, b)) in seq.per_fold.iter().zip(&exe.per_fold).enumerate() {
            assert!((a - b).abs() < 0.25, "fold {i} threads={threads}: {a} vs {b}");
        }
        assert!(
            (seq.estimate - exe.estimate).abs() < 0.05,
            "threads={threads}: {} vs {}",
            seq.estimate,
            exe.estimate
        );
    }
}

/// The *exact* multi-worker SaveRevert oracle for inexact-revert learners:
/// an executor with cutoff `c` has the identical model flow to the scoped
/// baseline with `fork_depth = c` — snapshot at every forked node,
/// save/revert below, same tags, same update order — so the two must agree
/// bit for bit even for the perceptron, whose ulp cascade defeats
/// tolerance-based comparison against the purely sequential engine. Also
/// pins scheduling determinism: two runs at the same pool size must be
/// bit-identical.
#[test]
fn save_revert_perceptron_executor_equals_scoped_with_cutoff_depth() {
    let n = 2_000;
    let data = SyntheticCovertype::new(n, 23).generate();
    let l = Perceptron::new(54);
    // k = 64 (tree depth 6) so threads ∈ {2, 3, 6} leave real SaveRevert
    // subtrees below the fork frontier; threads = 16 is the all-fork edge.
    let folds = Folds::new(n, 64, 24);
    for threads in [2usize, 3, 6, 16] {
        let cutoff = snapshot_cutoff(threads);
        let exe = TreeCvExecutor::new(Strategy::SaveRevert, Ordering::Fixed, 9, threads);
        let sco = ScopedForkTreeCv::new(Strategy::SaveRevert, Ordering::Fixed, 9, cutoff);
        let a = exe.run(&l, &data, &folds);
        let b = sco.run(&l, &data, &folds);
        assert_eq!(a.per_fold, b.per_fold, "threads={threads} cutoff={cutoff}");
        assert_eq!(a.ops.points_updated, b.ops.points_updated, "threads={threads}");
        assert_eq!(a.ops.model_copies, b.ops.model_copies, "threads={threads}");
        assert_eq!(a.ops.model_restores, b.ops.model_restores, "threads={threads}");
        // Determinism: work stealing must never change the computed values.
        let again = exe.run(&l, &data, &folds);
        assert_eq!(a.per_fold, again.per_fold, "threads={threads} (rerun)");
    }
}

/// The SaveRevert copy bill: `model_copies` stays at the fork frontier —
/// at most `2^cutoff − 1 = O(workers)` per run and strictly below the
/// `k − 1` of a Copy run — while `model_restores` carries every remaining
/// interior node (two per node). LOOCV at n = 200 makes the gap stark:
/// Copy pays 199 snapshots, SaveRevert at most 63 even at 16 workers.
#[test]
fn save_revert_copies_stay_o_workers() {
    let n = 200;
    let k = n as u64;
    let data = Dataset::new(vec![0.0; n], vec![0.0; n], 1);
    let l = MultisetLearner::new(1);
    let folds = Folds::loocv(n);
    for threads in WORKER_COUNTS {
        let exe = TreeCvExecutor::new(Strategy::SaveRevert, Ordering::Fixed, 0, threads)
            .run(&l, &data, &folds);
        let max_forks = (1u64 << snapshot_cutoff(threads)) - 1;
        assert!(
            exe.ops.model_copies <= max_forks,
            "threads={threads}: {} copies exceed the {max_forks} fork nodes",
            exe.ops.model_copies
        );
        assert!(
            exe.ops.model_copies < k - 1,
            "threads={threads}: {} copies is not below Copy's k-1 = {}",
            exe.ops.model_copies,
            k - 1
        );
        assert_eq!(
            exe.ops.model_restores,
            2 * (k - 1 - exe.ops.model_copies),
            "threads={threads}: restores must cover every non-forked interior node"
        );
        assert_eq!(exe.ops.evals, k, "threads={threads}");

        // And Copy at the same pool size still pays one snapshot per
        // interior node — no strategy leaks into the other.
        let copy = TreeCvExecutor::new(Strategy::Copy, Ordering::Fixed, 0, threads)
            .run(&l, &data, &folds);
        assert_eq!(copy.ops.model_copies, k - 1, "threads={threads}");
        assert_eq!(copy.ops.model_restores, 0, "threads={threads}");
    }
}

/// The executor's Copy-strategy copy count is exactly one snapshot per
/// interior node (k − 1), independent of the worker count — the
/// fork/inline split and buffer pool recycle storage without changing the
/// §4.1 accounting.
#[test]
fn executor_copy_accounting_is_pool_size_independent() {
    let n = 450;
    let k = 30;
    let data = SyntheticMixture1d::new(n, 7).generate();
    let l = HistogramDensity::new(-8.0, 8.0, 32);
    let folds = Folds::new(n, k, 8);
    for threads in [1usize, 2, 5, 8] {
        let exe = TreeCvExecutor::new(Strategy::Copy, Ordering::Fixed, 0, threads)
            .run(&l, &data, &folds);
        assert_eq!(exe.ops.model_copies, (k - 1) as u64, "threads={threads}");
        assert_eq!(exe.ops.model_restores, 0, "threads={threads}");
        assert_eq!(exe.ops.evals, k as u64, "threads={threads}");
    }
}

// ---------------------------------------------------------------------------
// Cancellation-path hardening: the executor's cancellation contract.
// ---------------------------------------------------------------------------

/// The batch a hardening test dispatches: four histogram-density runs over
/// the same folds with distinct per-run seeds, each holding a clone of the
/// caller's control block.
fn batch_specs<'a>(
    l: &'a HistogramDensity,
    folds: &'a Folds,
    ctrls: &'a [RunCtrl],
) -> Vec<RunSpec<'a, HistogramDensity>> {
    ctrls
        .iter()
        .enumerate()
        .map(|(i, ctrl)| RunSpec {
            learner: l,
            folds,
            seed: 70 + i as u64,
            strategy: Strategy::Copy,
            folded: None,
            ctrl: ctrl.clone(),
        })
        .collect()
}

fn assert_same_result(want: &treecv::cv::CvResult, got: &treecv::cv::CvResult, ctx: &str) {
    assert_eq!(want.per_fold, got.per_fold, "{ctx}: per_fold");
    assert_eq!(want.estimate.to_bits(), got.estimate.to_bits(), "{ctx}: estimate");
    assert_eq!(want.ops.points_updated, got.ops.points_updated, "{ctx}: points_updated");
    assert_eq!(want.ops.model_copies, got.ops.model_copies, "{ctx}: model_copies");
    assert_eq!(want.ops.evals, got.ops.evals, "{ctx}: evals");
}

/// A run whose token is cancelled before dispatch is dropped whole at the
/// injector pop — zero leaves evaluated, every leaf reported dropped,
/// exactly its root task counted — at EVERY worker count, while sibling
/// runs complete bit-identically to the same specs in a cancellation-free
/// batch. Cancelled runs report a distinct status, never a fabricated
/// `CvResult` over a partial per-fold buffer.
#[test]
fn pre_cancelled_runs_drop_whole_and_siblings_are_unaffected() {
    let n = 240;
    let k = 8;
    let data = SyntheticMixture1d::new(n, 601).generate();
    let l = HistogramDensity::new(-8.0, 8.0, 32);
    let folds = Folds::new(n, k, 602);
    let standalone: Vec<_> = (0..4u64)
        .map(|i| {
            TreeCvExecutor::new(Strategy::Copy, Ordering::Fixed, 70 + i, 1).run(&l, &data, &folds)
        })
        .collect();
    for threads in [1usize, 3, 8] {
        let ctrls: Vec<RunCtrl> = (0..4).map(|_| RunCtrl::new()).collect();
        ctrls[1].cancel();
        ctrls[3].cancel();
        let specs = batch_specs(&l, &folds, &ctrls);
        let exe = TreeCvExecutor::new(Strategy::Copy, Ordering::Fixed, 0, threads);
        let outs = exe.run_many_outcomes(&data, &specs, None);
        assert_eq!(outs.len(), 4, "threads={threads}");
        for survivor in [0usize, 2] {
            let res = outs[survivor]
                .completed()
                .unwrap_or_else(|| panic!("threads={threads}: run {survivor} must complete"));
            assert_same_result(&standalone[survivor], res, &format!("threads={threads}"));
        }
        for loser in [1usize, 3] {
            match &outs[loser] {
                RunOutcome::Cancelled { leaves_done, leaves_dropped, tasks_dropped } => {
                    assert_eq!(*leaves_done, 0, "threads={threads} run {loser}");
                    assert_eq!(*leaves_dropped, k, "threads={threads} run {loser}");
                    assert_eq!(*tasks_dropped, 1, "threads={threads} run {loser}");
                }
                other => panic!("threads={threads} run {loser}: expected Cancelled, got {other:?}"),
            }
            assert!(outs[loser].completed().is_none(), "no CvResult for a cancelled run");
            assert!(outs[loser].is_cancelled(), "threads={threads} run {loser}");
        }
    }
}

/// Mid-flight cancellation from the incremental-delivery callback: the
/// moment run 0's outcome lands, every sibling is cancelled. Scheduling
/// decides how far the siblings got, so the invariants are the
/// schedule-independent ones — run 0 completes bit-identically, and each
/// sibling either completed (bit-identical) or was cancelled with its
/// leaf ledger balancing exactly (`leaves_done + leaves_dropped == k`).
/// With one worker the injector admits runs in order, so all three
/// siblings must report Cancelled there.
#[test]
fn callback_cancellation_mid_flight_keeps_invariants() {
    let n = 240;
    let k = 8;
    let data = SyntheticMixture1d::new(n, 603).generate();
    let l = HistogramDensity::new(-8.0, 8.0, 32);
    let folds = Folds::new(n, k, 604);
    let standalone: Vec<_> = (0..4u64)
        .map(|i| {
            TreeCvExecutor::new(Strategy::Copy, Ordering::Fixed, 70 + i, 1).run(&l, &data, &folds)
        })
        .collect();
    for threads in [1usize, 3, 8] {
        let ctrls: Vec<RunCtrl> = (0..4).map(|_| RunCtrl::new()).collect();
        let specs = batch_specs(&l, &folds, &ctrls);
        let on_result = |idx: usize, _out: &RunOutcome| {
            if idx == 0 {
                for c in &ctrls[1..] {
                    c.cancel();
                }
            }
        };
        let exe = TreeCvExecutor::new(Strategy::Copy, Ordering::Fixed, 0, threads);
        let outs = exe.run_many_outcomes(&data, &specs, Some(&on_result));
        let res = outs[0].completed().expect("run 0 is never cancelled");
        assert_same_result(&standalone[0], res, &format!("threads={threads} run 0"));
        let mut cancelled = 0usize;
        for (i, out) in outs.iter().enumerate().skip(1) {
            match out {
                RunOutcome::Completed(res) => {
                    assert_same_result(&standalone[i], res, &format!("threads={threads} run {i}"));
                }
                RunOutcome::Cancelled { leaves_done, leaves_dropped, .. } => {
                    cancelled += 1;
                    assert_eq!(
                        leaves_done + leaves_dropped,
                        k,
                        "threads={threads} run {i}: leaf ledger must balance"
                    );
                }
                RunOutcome::Failed { error } => {
                    panic!("threads={threads} run {i} failed: {error}")
                }
            }
        }
        if threads == 1 {
            assert_eq!(cancelled, 3, "inline worker admits runs in order");
        }
    }
}

/// A batch with cancellations leaves the executor handle fully reusable:
/// a subsequent cancellation-free `run_many` on the SAME handle is
/// bit-identical to the same batch on a fresh handle (the per-batch
/// buffer pool is torn down with the batch, and cancelled subtrees
/// recycle their buffers through the same capped pool, so nothing leaks
/// across batches), and the per-pool spawn counter keeps counting.
#[test]
fn pool_is_reusable_after_a_cancelled_batch() {
    let n = 240;
    let k = 8;
    let data = SyntheticMixture1d::new(n, 605).generate();
    let l = HistogramDensity::new(-8.0, 8.0, 32);
    let folds = Folds::new(n, k, 606);
    for threads in [1usize, 3, 8] {
        let exe = TreeCvExecutor::new(Strategy::Copy, Ordering::Fixed, 0, threads);
        // Batch 1: half the runs cancelled up front.
        let ctrls: Vec<RunCtrl> = (0..4).map(|_| RunCtrl::new()).collect();
        ctrls[0].cancel();
        ctrls[2].cancel();
        let specs = batch_specs(&l, &folds, &ctrls);
        let outs = exe.run_many_outcomes(&data, &specs, None);
        assert_eq!(outs.iter().filter(|o| o.is_cancelled()).count(), 2, "threads={threads}");
        // Batch 2 on the same handle, nothing cancelled: must equal the
        // identical batch on a fresh executor, bit for bit.
        let clean: Vec<RunCtrl> = (0..4).map(|_| RunCtrl::new()).collect();
        let again = exe.run_many(&data, &batch_specs(&l, &folds, &clean));
        let fresh_ctrls: Vec<RunCtrl> = (0..4).map(|_| RunCtrl::new()).collect();
        let fresh = TreeCvExecutor::new(Strategy::Copy, Ordering::Fixed, 0, threads)
            .run_many(&data, &batch_specs(&l, &folds, &fresh_ctrls));
        for (i, (a, b)) in fresh.iter().zip(&again).enumerate() {
            assert_same_result(a, b, &format!("threads={threads} run {i} (reused pool)"));
        }
        // Two multi-worker batches → two pool spawns on the shared handle
        // (inline single-worker batches spawn nothing).
        assert_eq!(exe.pool_spawns(), 2 * u64::from(threads > 1), "threads={threads}");
    }
}
