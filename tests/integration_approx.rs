//! Bounded-error battery for the approximate-CV engine: one full-data
//! training pass plus a one-step held-out correction per fold must track
//! the exact engines (sequential TreeCV, standard k-fold retraining, and
//! — for ridge — the closed-form hat-matrix LOOCV) within the documented
//! error contract:
//!
//! * ridge: the Sherman–Morrison block downdate is algebraically exact,
//!   so only f64 rounding separates approx from exact — pinned at 1e-8
//!   relative (λ = 1);
//! * pegasos / lsqsgd: the correction is first-order, so the contract is
//!   a loose bound (0.5 relative on the estimate), not bit-tracking;
//! * per-fold results are bitwise independent of the worker count, and a
//!   rerun is bitwise identical (work stealing never changes values);
//! * the erased registry path computes the generic path's exact bits.
//!
//! Seeded fixed shapes stand in for proptest (unavailable offline),
//! mirroring `tests/integration_executor.rs`.

use treecv::cv::approx::{max_fold_gap, ApproxCv};
use treecv::cv::exact::ridge_loocv;
use treecv::cv::executor::{ErasedRunSpec, RunCtrl, TreeCvExecutor};
use treecv::cv::folds::{Folds, Ordering};
use treecv::cv::standard::StandardCv;
use treecv::cv::treecv::TreeCv;
use treecv::cv::{CvEngine, CvResult, Strategy};
use treecv::data::synth::{SyntheticCovertype, SyntheticYearMsd};
use treecv::data::Dataset;
use treecv::learner::erased::Erased;
use treecv::learner::lsqsgd::LsqSgd;
use treecv::learner::pegasos::Pegasos;
use treecv::learner::ridge::OnlineRidge;
use treecv::learner::IncrementalLearner;

/// Worker counts the battery sweeps: inline, odd, and oversubscribed.
const WORKER_COUNTS: [usize; 3] = [1, 3, 8];

/// Small-d regression data (the `cv::exact` pattern): slice the YearMSD
/// generator's rows to d = 8 so closed-form oracles stay cheap.
fn small_data(n: usize, seed: u64) -> Dataset {
    let full = SyntheticYearMsd::new(n, seed).generate();
    let d = 8;
    let mut x = Vec::with_capacity(n * d);
    for i in 0..n {
        x.extend_from_slice(&full.row(i as u32)[..d]);
    }
    Dataset::new(x, full.y.clone(), d)
}

fn approx_run<L>(l: &L, data: &Dataset, folds: &Folds, threads: usize) -> CvResult
where
    L: IncrementalLearner + Sync,
    L::Model: Send,
{
    TreeCvExecutor::new(Strategy::Copy, Ordering::Fixed, 11, threads).run_approx(l, data, folds)
}

/// The shared battery: counter shape, bounded error against both exact
/// engines, and bitwise worker-count independence, across k ∈ {5, 32, n}.
/// `est_tol` is the relative estimate bound; `fold_tol` (where given) the
/// relative bound on the per-fold sup-norm gap vs exact TreeCV.
fn battery<L>(l: &L, data: &Dataset, est_tol: f64, fold_tol: Option<f64>, name: &str)
where
    L: IncrementalLearner + Sync,
    L::Model: Send,
{
    let n = data.n;
    for k in [5usize, 32, n] {
        let folds = if k == n { Folds::loocv(n) } else { Folds::new(n, k, 3) };
        let exact = TreeCv::new(Strategy::Copy, Ordering::Fixed, 11).run(l, data, &folds);
        let std_res = StandardCv::new(Ordering::Fixed, 11).run(l, data, &folds);
        let base = approx_run(l, data, &folds, 1);

        // The engine's cost shape: one training pass over n rows, one
        // correction and one evaluation per fold — never a retrain.
        assert_eq!(base.ops.update_calls, 1, "{name} k={k}");
        assert_eq!(base.ops.points_updated, n as u64, "{name} k={k}");
        assert_eq!(base.ops.corrections, k as u64, "{name} k={k}");
        assert_eq!(base.ops.evals, k as u64, "{name} k={k}");

        // Bounded error against both exact oracles.
        for (oracle, res) in [("treecv", &exact), ("standard", &std_res)] {
            let gap = (base.estimate - res.estimate).abs();
            assert!(
                gap <= est_tol * (1.0 + res.estimate.abs()),
                "{name} k={k} vs {oracle}: |{} - {}| = {gap:e}",
                base.estimate,
                res.estimate
            );
        }
        if let Some(tol) = fold_tol {
            let g = max_fold_gap(&base, &exact);
            assert!(
                g <= tol * (1.0 + exact.estimate.abs()),
                "{name} k={k}: per-fold sup gap {g:e}"
            );
        }

        // Per-fold results must not depend on the pool size, bit for bit.
        for threads in WORKER_COUNTS {
            let r = approx_run(l, data, &folds, threads);
            assert_eq!(base.per_fold, r.per_fold, "{name} k={k} threads={threads}");
            assert_eq!(
                base.estimate.to_bits(),
                r.estimate.to_bits(),
                "{name} k={k} threads={threads}"
            );
            assert_eq!(base.ops.corrections, r.ops.corrections, "{name} k={k}");
            assert_eq!(base.ops.points_updated, r.ops.points_updated, "{name} k={k}");
        }
    }
}

/// Ridge: the downdate is exact modulo rounding — 1e-8 relative at λ = 1,
/// on the estimate AND the per-fold sup-norm.
#[test]
fn ridge_tracks_exact_engines_to_rounding() {
    let data = small_data(160, 41);
    battery(&OnlineRidge::new(8, 1.0), &data, 1e-8, Some(1e-8), "ridge");
}

/// PEGASOS: first-order correction, loose contract on the estimate.
#[test]
fn pegasos_bounded_error_vs_exact() {
    let data = SyntheticCovertype::new(200, 42).generate();
    battery(&Pegasos::new(54, 1e-3), &data, 0.5, None, "pegasos");
}

/// Least-squares SGD: first-order correction on the averaged iterate,
/// loose contract on the estimate.
#[test]
fn lsqsgd_bounded_error_vs_exact() {
    let data = small_data(160, 43);
    battery(&LsqSgd::new(8, 1e-3), &data, 0.5, None, "lsqsgd");
}

/// The headline k = n validation: approx LOOCV for ridge agrees with the
/// closed-form hat-matrix oracle (independent mathematics, no incremental
/// code path shared) to the same tolerance the exact engine does, while
/// paying a fraction of its row updates.
#[test]
fn ridge_loocv_matches_closed_form_oracle() {
    let data = small_data(200, 44);
    let lambda = 1.0;
    let l = OnlineRidge::new(8, lambda);
    let folds = Folds::loocv(data.n);
    let closed = ridge_loocv(&data, lambda);
    let approx = ApproxCv::new(Ordering::Fixed, 11).run(&l, &data, &folds);
    assert!(
        (approx.estimate - closed.estimate).abs() < 1e-7 * (1.0 + closed.estimate),
        "approx {} vs closed form {}",
        approx.estimate,
        closed.estimate
    );
    // And the op-count advantage the engine exists for: exact TreeCV pays
    // Θ(n log₂(2n)) row updates at LOOCV, approx exactly n.
    let exact = TreeCv::new(Strategy::Copy, Ordering::Fixed, 11).run(&l, &data, &folds);
    assert_eq!(approx.ops.points_updated, data.n as u64);
    assert!(
        exact.ops.points_updated > 4 * approx.ops.points_updated,
        "exact {} vs approx {} row updates",
        exact.ops.points_updated,
        approx.ops.points_updated
    );
}

/// Rerunning the same engine is bitwise identical (estimates, per-fold
/// values, and counters), and the type-erased registry path computes the
/// generic path's exact bits.
#[test]
fn rerun_and_erased_path_are_bitwise_identical() {
    let data = small_data(120, 45);
    let l = OnlineRidge::new(8, 1.0);
    let folds = Folds::new(data.n, 8, 6);
    let exe = TreeCvExecutor::new(Strategy::Copy, Ordering::Fixed, 11, 3);
    let a = exe.run_approx(&l, &data, &folds);
    let b = exe.run_approx(&l, &data, &folds);
    assert_eq!(a.per_fold, b.per_fold);
    assert_eq!(a.estimate.to_bits(), b.estimate.to_bits());
    assert_eq!(a.ops.points_updated, b.ops.points_updated);
    assert_eq!(a.ops.corrections, b.ops.corrections);
    assert_eq!(a.ops.model_copies, b.ops.model_copies);

    let boxed = Erased::boxed(OnlineRidge::new(8, 1.0));
    let specs = [ErasedRunSpec {
        learner: &*boxed,
        folds: &folds,
        seed: 11,
        strategy: Strategy::Copy,
        folded: None,
        ctrl: RunCtrl::default(),
    }];
    let erased = exe.run_many_approx_erased(&data, &specs);
    assert_eq!(erased.len(), 1);
    assert_eq!(a.per_fold, erased[0].per_fold, "erased path must match generic bits");
    assert_eq!(a.ops.corrections, erased[0].ops.corrections);
}
