//! Ablations over the design choices DESIGN.md calls out:
//!
//! 1. **Copy vs SaveRevert** (paper §4.1's trade-off) on three learners
//!    with different undo cost profiles: PEGASOS (dense model → snapshot),
//!    perceptron (sparse mistake log), online k-means (per-point O(d) log
//!    vs O(K·d) copy).
//! 2. **Parallel TreeCV fork depth** — speedup vs the sequential engine.
//! 3. **Randomized vs fixed feeding order** — the constant-factor overhead
//!    the paper quotes (≈2× for TreeCV, ≈1.5× for standard).
//!
//! Run: `cargo bench --bench ablations` (env `ABL_N` to resize).

use treecv::benchkit::Bench;
use treecv::cv::executor::TreeCvExecutor;
use treecv::cv::folds::{Folds, Ordering};
use treecv::cv::parallel::ParallelTreeCv;
use treecv::cv::standard::StandardCv;
use treecv::cv::treecv::TreeCv;
use treecv::cv::{CvEngine, Strategy};
use treecv::data::synth::{SyntheticBlobs, SyntheticCovertype};
use treecv::learner::kmeans::OnlineKMeans;
use treecv::learner::pegasos::Pegasos;
use treecv::learner::perceptron::Perceptron;

fn main() {
    let n: usize = std::env::var("ABL_N").ok().and_then(|v| v.parse().ok()).unwrap_or(65_536);
    let k = 64;
    let mut bench = Bench::default();

    // --- 1. Copy vs SaveRevert ------------------------------------------
    println!("== strategy ablation (k = {k}, n = {n}) ==");
    let cover = SyntheticCovertype::new(n, 42).generate();
    let folds = Folds::new(n, k, 7);

    let pegasos = Pegasos::new(cover.d, 1e-5);
    for (name, strat) in [("copy", Strategy::Copy), ("save_revert", Strategy::SaveRevert)] {
        bench.run(&format!("pegasos/{name}"), || {
            std::hint::black_box(
                TreeCv::new(strat, Ordering::Fixed, 1).run(&pegasos, &cover, &folds),
            );
        });
    }
    let perceptron = Perceptron::new(cover.d);
    for (name, strat) in [("copy", Strategy::Copy), ("save_revert", Strategy::SaveRevert)] {
        bench.run(&format!("perceptron/{name}"), || {
            std::hint::black_box(
                TreeCv::new(strat, Ordering::Fixed, 1).run(&perceptron, &cover, &folds),
            );
        });
    }
    let blobs = SyntheticBlobs::new(n, 16, 8, 42).generate();
    let kmeans = OnlineKMeans::new(16, 8);
    for (name, strat) in [("copy", Strategy::Copy), ("save_revert", Strategy::SaveRevert)] {
        bench.run(&format!("kmeans/{name}"), || {
            std::hint::black_box(
                TreeCv::new(strat, Ordering::Fixed, 1).run(&kmeans, &blobs, &folds),
            );
        });
    }

    // Copy-cost accounting (bytes snapshotted vs restores).
    let copy_res = TreeCv::new(Strategy::Copy, Ordering::Fixed, 1).run(&kmeans, &blobs, &folds);
    let sr_res =
        TreeCv::new(Strategy::SaveRevert, Ordering::Fixed, 1).run(&kmeans, &blobs, &folds);
    println!(
        "kmeans copy: {} copies / {:.1} KB snapshotted; save_revert: {} restores / 0 snap bytes",
        copy_res.ops.model_copies,
        copy_res.ops.bytes_copied as f64 / 1e3,
        sr_res.ops.model_restores
    );

    // --- 1b. Copy vs SaveRevert on the pooled executor --------------------
    // The EXPERIMENTS.md ablation row: the strategy-aware executor keeps
    // SaveRevert's snapshots at its fork frontier (O(workers)), so
    // bytes_copied collapses versus Copy's k − 1 snapshots while wall time
    // must not regress.
    let threads = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    println!("\n== executor strategy ablation (perceptron, k = {k}, {threads} workers) ==");
    for (name, strat) in [("copy", Strategy::Copy), ("save_revert", Strategy::SaveRevert)] {
        let exe = TreeCvExecutor::with_available_parallelism(strat, Ordering::Fixed, 1);
        let res = exe.run(&perceptron, &cover, &folds);
        let s = bench.run(&format!("executor-perceptron/{name}"), || {
            std::hint::black_box(exe.run(&perceptron, &cover, &folds));
        });
        println!(
            "  {name:>11}: {:>4} copies / {:>8.1} KB copied / {:>4} restores, median {:.4}s",
            res.ops.model_copies,
            res.ops.bytes_copied as f64 / 1e3,
            res.ops.model_restores,
            s.median()
        );
    }

    // --- 2. Parallel fork depth ------------------------------------------
    println!("\n== parallel fork-depth ablation (pegasos, k = {k}) ==");
    let seq = bench.run("parallel/depth0(seq)", || {
        std::hint::black_box(
            TreeCv::new(Strategy::Copy, Ordering::Fixed, 1).run(&pegasos, &cover, &folds),
        );
    });
    let t_seq = seq.median();
    for depth in [1usize, 2, 3, 4] {
        let s = bench.run(&format!("parallel/depth{depth}"), || {
            std::hint::black_box(
                ParallelTreeCv::new(Strategy::Copy, Ordering::Fixed, 1, depth)
                    .run(&pegasos, &cover, &folds),
            );
        });
        println!("  depth {depth}: speedup {:.2}x", t_seq / s.median());
    }

    // --- 3. Randomized-order overhead ------------------------------------
    println!("\n== ordering ablation (pegasos, k = {k}) ==");
    let t_fixed = bench
        .run("ordering/treecv-fixed", || {
            std::hint::black_box(
                TreeCv::new(Strategy::Copy, Ordering::Fixed, 1).run(&pegasos, &cover, &folds),
            );
        })
        .median();
    let t_rand = bench
        .run("ordering/treecv-randomized", || {
            std::hint::black_box(
                TreeCv::new(Strategy::Copy, Ordering::Randomized, 1).run(&pegasos, &cover, &folds),
            );
        })
        .median();
    let s_fixed = bench
        .run("ordering/standard-fixed", || {
            std::hint::black_box(
                StandardCv::new(Ordering::Fixed, 1).run(&pegasos, &cover, &folds),
            );
        })
        .median();
    let s_rand = bench
        .run("ordering/standard-randomized", || {
            std::hint::black_box(
                StandardCv::new(Ordering::Randomized, 1).run(&pegasos, &cover, &folds),
            );
        })
        .median();
    println!(
        "randomized overhead: treecv {:.2}x (paper ~2x), standard {:.2}x (paper ~1.5x)",
        t_rand / t_fixed,
        s_rand / s_fixed
    );

    println!("\nCSV summary:\n{}", bench.csv());
}
