//! Bench: Theorem 3 / Corollary 4 — TreeCV's total work and wall time
//! scale as O(log k) times a single training, while the standard method
//! scales linearly in k. Sweeps k at fixed n and reports measured
//! update-points against the (1+c)·n·log₂(2k) bound, plus wall-time
//! ratios to a single training run.
//!
//! Run: `cargo bench --bench scaling_k` (env `SCALING_N` to resize).

use treecv::benchkit::Bench;
use treecv::cv::executor::TreeCvExecutor;
use treecv::cv::folds::{Folds, Ordering};
use treecv::cv::parallel::ScopedForkTreeCv;
use treecv::cv::standard::StandardCv;
use treecv::cv::treecv::TreeCv;
use treecv::cv::{CvEngine, Strategy};
use treecv::data::synth::SyntheticCovertype;
use treecv::learner::pegasos::Pegasos;
use treecv::learner::IncrementalLearner;

fn main() {
    let n: usize =
        std::env::var("SCALING_N").ok().and_then(|v| v.parse().ok()).unwrap_or(131_072);
    let data = SyntheticCovertype::new(n, 42).generate();
    let learner = Pegasos::new(data.d, 1e-5);
    let mut bench = Bench::default();

    // Single-training baseline T_L.
    let idx: Vec<u32> = (0..n as u32).collect();
    let single = bench.run("single-training", || {
        let mut m = learner.init();
        learner.update(&mut m, &data, &idx);
        std::hint::black_box(&m);
    });
    let t_single = single.median();

    println!();
    println!(
        "{:>6} | {:>13} | {:>13} | {:>9} | {:>11} | {:>11} | {:>9}",
        "k", "tree pts", "n*log2(2k)", "tree T/TL", "log2(2k)", "std T/TL", "std/tree"
    );
    for k in [2usize, 4, 8, 16, 32, 64, 128, 256, 1024] {
        let folds = Folds::new(n, k, 7);
        let tree = TreeCv::default().run(&learner, &data, &folds);
        let tree_t = {
            let s = bench.run(&format!("treecv-k{k}"), || {
                std::hint::black_box(TreeCv::default().run(&learner, &data, &folds));
            });
            s.median()
        };
        // Standard gets expensive fast; skip wall-time above k=64.
        let std_t = if k <= 64 {
            let s = bench.run(&format!("standard-k{k}"), || {
                std::hint::black_box(StandardCv::default().run(&learner, &data, &folds));
            });
            Some(s.median())
        } else {
            None
        };
        let bound = n as f64 * ((2 * k) as f64).log2();
        assert!(tree.ops.points_updated as f64 <= bound + 1.0, "Thm 3 violated at k={k}");
        println!(
            "{:>6} | {:>13} | {:>13.0} | {:>9.2} | {:>11.2} | {:>11} | {:>9}",
            k,
            tree.ops.points_updated,
            bound,
            tree_t / t_single,
            ((2 * k) as f64).log2(),
            std_t.map(|t| format!("{:.2}", t / t_single)).unwrap_or_else(|| "-".into()),
            std_t.map(|t| format!("{:.2}x", t / tree_t)).unwrap_or_else(|| "-".into()),
        );
    }
    // Executor vs scoped-thread forking: the pooled work-stealing executor
    // must be no slower than the per-node thread-spawning baseline at any
    // k, and both must agree with the sequential engine bit-for-bit.
    let threads = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    println!();
    println!("== pooled executor vs scoped-thread forking ({threads} hw threads) ==");
    println!(
        "{:>6} | {:>12} | {:>12} | {:>14}",
        "k", "executor(s)", "scoped(s)", "scoped/executor"
    );
    for k in [16usize, 64, 256] {
        let folds = Folds::new(n, k, 7);
        let pooled =
            TreeCvExecutor::with_available_parallelism(Strategy::Copy, Ordering::Fixed, 7);
        let scoped =
            ScopedForkTreeCv::with_available_parallelism(Strategy::Copy, Ordering::Fixed, 7);
        // A baseline-vs-executor wall-time ratio is only meaningful if both
        // engines preserve models the same way — never compare a Copy run
        // against a SaveRevert run.
        assert_eq!(
            pooled.strategy, scoped.strategy,
            "baseline and executor must be benchmarked under the same strategy"
        );
        let seq_res = TreeCv::default().run(&learner, &data, &folds);
        let pooled_res = pooled.run(&learner, &data, &folds);
        let scoped_res = scoped.run(&learner, &data, &folds);
        assert_eq!(seq_res.per_fold, pooled_res.per_fold, "executor diverged at k={k}");
        assert_eq!(seq_res.per_fold, scoped_res.per_fold, "scoped baseline diverged at k={k}");
        let e_t = bench
            .run(&format!("executor-k{k}"), || {
                std::hint::black_box(pooled.run(&learner, &data, &folds));
            })
            .median();
        let s_t = bench
            .run(&format!("scoped-k{k}"), || {
                std::hint::black_box(scoped.run(&learner, &data, &folds));
            })
            .median();
        println!("{:>6} | {:>12.4} | {:>12.4} | {:>13.2}x", k, e_t, s_t, s_t / e_t);
    }

    println!();
    println!("CSV summary:\n{}", bench.csv());
}
