//! Kernel-layer micro-benchmarks: the folded hot loop's building blocks
//! (blocked row sweeps, rank-B sufficient-stats accumulation, center
//! assignment) measured in points/s and GB/s, with the dispatched SIMD
//! backend recorded per scenario.
//!
//! Every auto-vs-scalar pair asserts **bit-identity** in-bench before any
//! number is reported — the kernel layer's equivalence contract
//! (`rust/src/learner/linalg.rs`) made load-bearing. Forced-scalar
//! scenarios use `force_backend` and restore the detected backend after;
//! this is safe mid-process precisely because the backends agree bitwise.
//!
//! Run: `cargo bench --bench kernels` (env `KERNELS_D`, `KERNELS_ROWS`,
//! `KERNELS_K` for sizes, `KERNELS_JSON` for the output path;
//! `BENCH_SAMPLES` / `BENCH_WARMUP` as usual). Committed output
//! (`BENCH_kernels.json`) is the perf baseline later PRs diff against.

use treecv::benchkit::{Bench, JsonReport};
use treecv::learner::linalg;
use treecv::rng::Rng;

fn gen_rows(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.next_gaussian()).collect()
}

/// points/s and GB/s metric pair for a sweep touching `rows` rows of
/// `bytes_per_row` bytes per call.
fn throughput(median_s: f64, rows: usize, bytes_per_row: usize) -> [(&'static str, f64); 2] {
    let t = median_s.max(1e-12);
    [
        ("points_per_s", rows as f64 / t),
        ("gb_per_s", (rows * bytes_per_row) as f64 / t / 1e9),
    ]
}

fn main() {
    let d: usize = std::env::var("KERNELS_D").ok().and_then(|v| v.parse().ok()).unwrap_or(90);
    let rows: usize =
        std::env::var("KERNELS_ROWS").ok().and_then(|v| v.parse().ok()).unwrap_or(65_536);
    let kc: usize = std::env::var("KERNELS_K").ok().and_then(|v| v.parse().ok()).unwrap_or(32);
    let json_path =
        std::env::var("KERNELS_JSON").unwrap_or_else(|_| "BENCH_kernels.json".to_string());

    let detected = linalg::kernel_backend();
    println!(
        "== kernel layer (d = {d}, rows = {rows}, k = {kc}, backend = {}) ==",
        detected.name()
    );

    let mut rng = Rng::new(0x6b65726e);
    let xs = gen_rows(&mut rng, rows * d);
    let w: Vec<f32> = gen_rows(&mut rng, d);
    let w64: Vec<f64> = w.iter().map(|&v| v as f64).collect();
    let centers = gen_rows(&mut rng, kc * d);
    let x0 = &xs[..d];

    // In-bench equivalence checks: scalar vs dispatched, blocked vs
    // row-wise. A mismatch aborts before any number is written.
    let mut out_auto = vec![0f32; rows];
    let mut out_scalar = vec![0f32; rows];
    linalg::dot_block(&w, &xs, d, &mut out_auto);
    linalg::force_backend(linalg::KernelBackend::Scalar);
    linalg::dot_block(&w, &xs, d, &mut out_scalar);
    linalg::force_backend(detected);
    for (a, b) in out_auto.iter().zip(&out_scalar) {
        assert_eq!(a.to_bits(), b.to_bits(), "dot_block: scalar vs dispatched diverged");
    }
    let rowwise: Vec<f32> = xs.chunks_exact(d).map(|r| linalg::dot(&w, r)).collect();
    for (a, b) in out_auto.iter().zip(&rowwise) {
        assert_eq!(a.to_bits(), b.to_bits(), "dot_block: blocked vs row-wise diverged");
    }
    let syrk_rows = rows.min(4096);
    let mut a_blocked = vec![0f64; d * d];
    let mut a_rowwise = vec![0f64; d * d];
    linalg::syrk_accumulate(&mut a_blocked, d, &xs[..syrk_rows * d]);
    linalg::syrk_accumulate_blocked(&mut a_rowwise, d, &xs[..syrk_rows * d], 1);
    for (a, b) in a_blocked.iter().zip(&a_rowwise) {
        assert_eq!(a.to_bits(), b.to_bits(), "syrk: blocked vs rank-one diverged");
    }

    let mut bench = Bench::default();
    let mut report = JsonReport::new("kernels");
    report.env("d", d as f64);
    report.env("rows", rows as f64);
    report.env("k", kc as f64);
    report.env("syrk_rows", syrk_rows as f64);
    report.env("syrk_block_rows", linalg::SYRK_BLOCK_ROWS as f64);
    report.env("eval_block_rows", linalg::EVAL_BLOCK_ROWS as f64);
    report.env("assign_block_centers", linalg::ASSIGN_BLOCK_CENTERS as f64);
    report.env_str("detected_backend", detected.name());

    let row_bytes = d * std::mem::size_of::<f32>();

    // Blocked row sweep (the evaluate_rows shape), dispatched vs forced
    // scalar.
    let s = bench.run("kernels/dot_block/auto", || {
        linalg::dot_block(&w, &xs, d, &mut out_auto);
        std::hint::black_box(&out_auto);
    });
    let s = s.clone();
    report.push_samples(&s, &throughput(s.median(), rows, row_bytes));
    let auto_median = s.median();

    linalg::force_backend(linalg::KernelBackend::Scalar);
    let s = bench.run("kernels/dot_block/scalar", || {
        linalg::dot_block(&w, &xs, d, &mut out_scalar);
        std::hint::black_box(&out_scalar);
    });
    let s = s.clone();
    linalg::force_backend(detected);
    let mut m = throughput(s.median(), rows, row_bytes).to_vec();
    m.push(("speedup_auto_vs_scalar", s.median() / auto_median.max(1e-12)));
    report.push_samples_tagged(&s, &m, &[("kernel_backend", "scalar")]);

    // Row-at-a-time dots: what evaluate_rows did before blocking.
    let mut acc = 0f32;
    let s = bench.run("kernels/dot_rowwise/auto", || {
        for r in xs.chunks_exact(d) {
            acc += linalg::dot(&w, r);
        }
        std::hint::black_box(acc);
    });
    let s = s.clone();
    report.push_samples(&s, &throughput(s.median(), rows, row_bytes));

    // Ridge's f64-accumulator sweep.
    let mut out64 = vec![0f64; rows];
    let s = bench.run("kernels/dot_block_f64f32/auto", || {
        linalg::dot_block_f64f32(&w64, &xs, d, &mut out64);
        std::hint::black_box(&out64);
    });
    let s = s.clone();
    report.push_samples(&s, &throughput(s.median(), rows, row_bytes));

    // Rank-B sufficient statistics (ridge A += XᵀX): cache-blocked vs
    // the rank-one sequence it replaced. Each point touches d rows of A.
    let syrk_bytes = row_bytes + d * std::mem::size_of::<f64>();
    let s = bench.run("kernels/syrk_blocked/auto", || {
        a_blocked.fill(0.0);
        linalg::syrk_accumulate(&mut a_blocked, d, &xs[..syrk_rows * d]);
        std::hint::black_box(&a_blocked);
    });
    let s = s.clone();
    report.push_samples(&s, &throughput(s.median(), syrk_rows, syrk_bytes));
    let blocked_median = s.median();

    let s = bench.run("kernels/syrk_rowwise/auto", || {
        a_rowwise.fill(0.0);
        linalg::syrk_accumulate_blocked(&mut a_rowwise, d, &xs[..syrk_rows * d], 1);
        std::hint::black_box(&a_rowwise);
    });
    let s = s.clone();
    let mut m = throughput(s.median(), syrk_rows, syrk_bytes).to_vec();
    m.push(("speedup_blocked_vs_rowwise", s.median() / blocked_median.max(1e-12)));
    report.push_samples(&s, &m);

    // K-means assignment: one query against all centers, blocked.
    let mut dists = vec![0f64; kc];
    let s = bench.run("kernels/sq_dist_block/auto", || {
        for _ in 0..rows / kc {
            linalg::sq_dist_block(x0, &centers, d, &mut dists);
        }
        std::hint::black_box(&dists);
    });
    let s = s.clone();
    report.push_samples(&s, &throughput(s.median(), rows, row_bytes));

    linalg::force_backend(linalg::KernelBackend::Scalar);
    let s = bench.run("kernels/sq_dist_block/scalar", || {
        for _ in 0..rows / kc {
            linalg::sq_dist_block(x0, &centers, d, &mut dists);
        }
        std::hint::black_box(&dists);
    });
    let s = s.clone();
    linalg::force_backend(detected);
    report.push_samples_tagged(
        &s,
        &throughput(s.median(), rows, row_bytes),
        &[("kernel_backend", "scalar")],
    );

    // Elementwise axpy (the SGD update shape — bitwise backend-independent).
    let mut y = vec![0f32; d];
    let s = bench.run("kernels/axpy/auto", || {
        for r in xs.chunks_exact(d) {
            linalg::axpy(1e-7, r, &mut y);
        }
        std::hint::black_box(&y);
    });
    let s = s.clone();
    report.push_samples(&s, &throughput(s.median(), rows, 2 * row_bytes));

    println!("\nCSV summary:\n{}", bench.csv());
    match report.write(&json_path) {
        Ok(()) => println!("wrote {json_path}"),
        Err(e) => eprintln!("could not write {json_path}: {e}"),
    }
}
