//! Sweep-vs-sequential-runs: the multi-run scheduling claim. C
//! hyperparameter configs × r repetitions execute either as ONE pooled
//! batch (`cv::sweep::run_sweep` → `TreeCvExecutor::run_many`) or as r·C
//! standalone executor invocations (one pool spawn, one model-pool cold
//! start, and one join barrier each — exactly what `run_repetitions` used
//! to do). Results are asserted bit-identical, so any wall-time gap is
//! pure scheduling overhead.
//!
//! Run: `cargo bench --bench sweep` (env `SWEEP_N`, `SWEEP_REPS`).

use treecv::benchkit::Bench;
use treecv::cv::executor::TreeCvExecutor;
use treecv::cv::folds::{Folds, Ordering};
use treecv::cv::stats::{repetition_engine_seed, repetition_fold_seed};
use treecv::cv::sweep::{run_sweep, SweepSpec};
use treecv::cv::Strategy;
use treecv::data::synth::SyntheticCovertype;
use treecv::learner::pegasos::Pegasos;

fn main() {
    let n: usize = std::env::var("SWEEP_N").ok().and_then(|v| v.parse().ok()).unwrap_or(32_768);
    let reps: usize = std::env::var("SWEEP_REPS").ok().and_then(|v| v.parse().ok()).unwrap_or(3);
    let k = 16;
    let seed = 9u64;
    let lambdas = [1e-3, 1e-4, 1e-5, 1e-6];
    let threads = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);

    let data = SyntheticCovertype::new(n, 42).generate();
    let learners: Vec<Pegasos> = lambdas.iter().map(|&l| Pegasos::new(data.d, l)).collect();
    let spec = SweepSpec {
        ordering: Ordering::Fixed,
        strategies: vec![Strategy::Copy],
        k,
        repetitions: reps,
        seed,
        threads,
    };

    println!(
        "== sweep vs sequential runs (pegasos, {} configs x {reps} reps, k = {k}, n = {n}, \
         {threads} workers) ==",
        lambdas.len()
    );
    let mut bench = Bench::default();
    let seq = bench.run("sweep/sequential-runs", || {
        for learner in &learners {
            for r in 0..reps {
                let folds = Folds::new(n, k, repetition_fold_seed(seed, r));
                let engine = TreeCvExecutor::new(
                    Strategy::Copy,
                    Ordering::Fixed,
                    repetition_engine_seed(seed, r),
                    threads,
                );
                std::hint::black_box(engine.run(learner, &data, &folds));
            }
        }
    });
    let t_seq = seq.median();
    let pooled = bench.run("sweep/one-pool", || {
        std::hint::black_box(run_sweep(&learners, &data, &spec).unwrap());
    });
    println!("  one-pool speedup over sequential dispatch: {:.2}x", t_seq / pooled.median());

    // The correctness half of the claim: bit-identical results, one pool
    // (read off the sweep executor's per-pool counter).
    let out = run_sweep(&learners, &data, &spec).unwrap();
    let sweep_spawns = out.pool_spawns;
    for (c, cell) in out.cells.iter().enumerate() {
        for (r, run) in cell.runs.iter().enumerate() {
            let folds = Folds::new(n, k, repetition_fold_seed(seed, r));
            let alone = TreeCvExecutor::new(
                Strategy::Copy,
                Ordering::Fixed,
                repetition_engine_seed(seed, r),
                threads,
            )
            .run(&learners[c], &data, &folds);
            assert_eq!(
                run.per_fold, alone.per_fold,
                "sweep must be bit-identical to standalone (config {c}, rep {r})"
            );
        }
    }
    println!(
        "  pool spawns: sweep {} vs sequential {}",
        sweep_spawns,
        lambdas.len() * reps
    );

    println!("\nCSV summary:\n{}", bench.csv());
}
