//! Bench: the PJRT runtime path — per-block execution cost of the AOT
//! JAX/Pallas artifacts vs the pure-Rust learners, and the end-to-end
//! TreeCV crossover. Quantifies the FFI + interpret-mode-kernel overhead
//! so DESIGN.md §Perf can state when each path wins.
//!
//! Requires `make artifacts`; exits cleanly when missing.

use treecv::benchkit::Bench;
use treecv::cv::folds::Folds;
use treecv::cv::treecv::TreeCv;
use treecv::cv::CvEngine;
use treecv::data::synth::SyntheticCovertype;
use treecv::learner::pegasos::Pegasos;
use treecv::learner::IncrementalLearner;
use treecv::runtime::xla_learner::XlaPegasos;
use treecv::runtime::{artifacts_available, Manifest, PjrtRuntime};

fn main() {
    if !artifacts_available() {
        eprintln!("SKIP runtime_xla bench: artifacts/ missing — run `make artifacts`");
        return;
    }
    let rt = PjrtRuntime::cpu().expect("PJRT CPU client");
    let manifest = Manifest::load_default().expect("manifest");
    let mut bench = Bench::default();

    let n = 8_192;
    let data = SyntheticCovertype::new(n, 42).generate();
    let idx: Vec<u32> = (0..n as u32).collect();
    let lambda = 1e-4;

    let xla = XlaPegasos::from_manifest(&rt, &manifest, data.d, lambda).unwrap();
    let rust = Pegasos::new(data.d, lambda);

    // Per-pass update throughput.
    let x_upd = bench
        .run("update-pass/xla(b256)", || {
            let mut m = xla.init();
            xla.update(&mut m, &data, &idx);
            std::hint::black_box(&m);
        })
        .median();
    let r_upd = bench
        .run("update-pass/rust", || {
            let mut m = rust.init();
            rust.update(&mut m, &data, &idx);
            std::hint::black_box(&m);
        })
        .median();
    println!(
        "update: xla {:.1} kpts/s vs rust {:.1} kpts/s \
         ({:.1}x overhead — interpret-mode pallas + per-block FFI)",
        n as f64 / x_upd / 1e3,
        n as f64 / r_upd / 1e3,
        x_upd / r_upd
    );

    // Evaluation throughput (the mat-vec kernel).
    let mut xm = xla.init();
    xla.update(&mut xm, &data, &idx);
    let x_eval = bench
        .run("eval-pass/xla(b256)", || {
            std::hint::black_box(xla.evaluate(&xm, &data, &idx));
        })
        .median();
    let mut rm = rust.init();
    rust.update(&mut rm, &data, &idx);
    let r_eval = bench
        .run("eval-pass/rust", || {
            std::hint::black_box(rust.evaluate(&rm, &data, &idx));
        })
        .median();
    println!(
        "eval:   xla {:.1} kpts/s vs rust {:.1} kpts/s",
        n as f64 / x_eval / 1e3,
        n as f64 / r_eval / 1e3
    );

    // End-to-end TreeCV over each learner.
    let folds = Folds::new(n, 16, 7);
    bench.run("treecv-k16/xla", || {
        std::hint::black_box(TreeCv::default().run(&xla, &data, &folds));
    });
    bench.run("treecv-k16/rust", || {
        std::hint::black_box(TreeCv::default().run(&rust, &data, &folds));
    });

    println!("\nCSV summary:\n{}", bench.csv());
}
