//! Approximate-CV macro-benchmarks: the k = n regime the engine exists
//! for. Per (n, k) cell the one-step-correction engine
//! (`treecv::cv::approx`) runs LOOCV-style ridge CV and is compared
//! against exact sequential TreeCV — on measured counters AND estimates
//! where exact is affordable, on the Theorem-3 analytic update floor
//! where it is not.
//!
//! In-bench assertions (a failure aborts before any number is written):
//! * approx row-update work is exactly n and corrections exactly k;
//! * at k = n the exact engine's row-update work is ≥ 10× approx's
//!   (measured when exact ran, else the `n·(log₂(2k) − 1)` floor);
//! * wherever exact ran, the largest per-fold |approx − exact| is within
//!   `1e-6·(1 + |exact|)` — ridge's downdate is exact up to rounding.
//!
//! Run: `cargo bench --bench approx` (env `APPROX_NS` for the n sweep,
//! `APPROX_EXACT_MAX` for the largest n the exact oracle runs at every k,
//! `APPROX_JSON` for the output path; `BENCH_SAMPLES` / `BENCH_WARMUP`
//! as usual). d is fixed at 8 so the per-fold ridge re-solve stays O(1)
//! against the row sweep. Committed output (`BENCH_approx.json`) is the
//! perf baseline later PRs diff against.

use treecv::benchkit::{Bench, JsonReport};
use treecv::cv::approx::{max_fold_gap, ApproxCv};
use treecv::cv::folds::{Folds, Ordering};
use treecv::cv::treecv::TreeCv;
use treecv::cv::{CvEngine, Strategy};
use treecv::data::Dataset;
use treecv::learner::ridge::OnlineRidge;
use treecv::rng::Rng;

const D: usize = 8;
const LAMBDA: f64 = 1.0;
const SEED: u64 = 0xA11A;

/// Well-conditioned d = 8 regression data (Gaussian features, linear
/// teacher + noise) — the `cv::exact` small-d pattern, generated directly
/// so the n = 10⁶ cells never materialize a d = 90 intermediate.
fn gen_data(n: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let teacher: Vec<f32> = (0..D).map(|_| rng.next_gaussian()).collect();
    let mut x = Vec::with_capacity(n * D);
    let mut y = Vec::with_capacity(n);
    for _ in 0..n {
        let mut dot = 0f32;
        for t in teacher.iter().take(D) {
            let v = rng.next_gaussian();
            x.push(v);
            dot += t * v;
        }
        y.push(dot + 0.1 * rng.next_gaussian());
    }
    Dataset::new(x, y, D)
}

fn main() {
    let ns: Vec<usize> = std::env::var("APPROX_NS")
        .ok()
        .map(|v| v.split(',').map(|p| p.trim().parse().expect("APPROX_NS entry")).collect())
        .unwrap_or_else(|| vec![10_000, 100_000, 1_000_000]);
    let exact_max: usize = std::env::var("APPROX_EXACT_MAX")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(100_000);
    let json_path =
        std::env::var("APPROX_JSON").unwrap_or_else(|_| "BENCH_approx.json".to_string());

    println!("== approximate CV (ridge, d = {D}, λ = {LAMBDA}, exact_max = {exact_max}) ==");

    let mut bench = Bench::default();
    let mut report = JsonReport::new("approx");
    report.env("d", D as f64);
    report.env("lambda", LAMBDA);
    report.env("exact_max", exact_max as f64);

    for &n in &ns {
        let data = gen_data(n, SEED ^ n as u64);
        let learner = OnlineRidge::new(D, LAMBDA);
        let sqrt_k = (n as f64).sqrt().round() as usize;
        for (label, k) in [("k10", 10usize), ("ksqrt", sqrt_k), ("kn", n)] {
            let folds = if k == n { Folds::loocv(n) } else { Folds::new(n, k, 7) };
            let engine = ApproxCv::new(Ordering::Fixed, 11);
            let approx = engine.run(&learner, &data, &folds);
            assert_eq!(approx.ops.points_updated, n as u64, "approx trains each row once");
            assert_eq!(approx.ops.corrections, k as u64, "one correction per fold");

            // Exact oracle where affordable; elsewhere the Theorem-3
            // analytic floor on TreeCV's row-update work stands in (the
            // real count is Θ(n log₂(2k)); subtracting 1 keeps the
            // stand-in a conservative lower bound).
            let run_exact = n <= exact_max || k <= 32;
            let (exact_updates, gap) = if run_exact {
                let exact =
                    TreeCv::new(Strategy::Copy, Ordering::Fixed, 11).run(&learner, &data, &folds);
                let g = max_fold_gap(&approx, &exact);
                assert!(
                    g <= 1e-6 * (1.0 + exact.estimate.abs()),
                    "n={n} k={k}: approx drifted from exact by {g:e}"
                );
                (exact.ops.points_updated as f64, Some(g))
            } else {
                ((n as f64) * (((2 * k) as f64).log2() - 1.0), None)
            };
            let ratio = exact_updates / approx.ops.points_updated.max(1) as f64;
            if k == n {
                assert!(
                    ratio >= 10.0,
                    "n={n} LOOCV: exact/approx update ratio {ratio:.1} below the 10x floor"
                );
            }
            println!(
                "n={n} {label}: estimate {:.6}, update ratio {ratio:.1}{}",
                approx.estimate,
                match gap {
                    Some(g) => format!(", gap vs exact {g:.2e}"),
                    None => String::from(", exact skipped (analytic floor)"),
                }
            );

            let name = format!("approx/ridge/n{n}/{label}");
            let s = bench.run(&name, || {
                let r = engine.run(&learner, &data, &folds);
                std::hint::black_box(r.estimate);
            });
            let s = s.clone();
            let mut m = vec![
                ("points_updated", approx.ops.points_updated as f64),
                ("corrections", approx.ops.corrections as f64),
                ("update_ratio_vs_exact", ratio),
                ("rows_per_s", n as f64 / s.median().max(1e-12)),
            ];
            if let Some(g) = gap {
                m.push(("gap_vs_exact", g));
            }
            report.push_samples(&s, &m);
        }
    }

    println!("\nCSV summary:\n{}", bench.csv());
    match report.write(&json_path) {
        Ok(()) => println!("wrote {json_path}"),
        Err(e) => eprintln!("could not write {json_path}: {e}"),
    }
}
