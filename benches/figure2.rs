//! Bench: regenerate the paper's Figure 2 — running time of TreeCV and
//! standard k-CV as a function of n, for PEGASOS (top row) and LSQSGD
//! (bottom row), in all three columns:
//!   left   — k ∈ {5,10,100}, fixed order
//!   middle — k ∈ {5,10,100}, randomized order
//!   right  — LOOCV (log-scale runtime; standard only up to n = 10,000)
//!
//! Emits one CSV block per (task, panel). Env overrides: `FIG2_MAX_N`,
//! `FIG2_REPS`.

use treecv::config::Task;
use treecv::coordinator::paper::{self, Panel};

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let max_n = env_usize("FIG2_MAX_N", 100_000);
    let reps = env_usize("FIG2_REPS", 3);
    let ns = paper::default_ns(max_n);
    // LOOCV panel: k = n makes the standard method Θ(n²) — cap its sweep
    // like the paper did, but let TreeCV go to max_n.
    for task in [Task::Pegasos, Task::Lsqsgd] {
        for panel in [Panel::Fixed, Panel::Randomized, Panel::Loocv] {
            println!("# figure2 task={} panel={:?} reps={reps}", task.name(), panel);
            let out = paper::figure2(task, panel, &ns, reps, 42).expect("figure2");
            print!("{}", out.render_csv());
            // Shape report for the k-sweep panels: at the largest n, the
            // standard/treecv time ratio should grow with k.
            if !matches!(panel, Panel::Loocv) {
                let n = *ns.last().unwrap();
                for k in [5usize, 10, 100] {
                    let get = |series: &str| {
                        out.rows
                            .iter()
                            .find(|r| r.n == n && r.k == k && r.series.starts_with(series))
                            .map(|r| r.mean_wall_secs)
                    };
                    if let (Some(t), Some(s)) = (get("treecv"), get("standard")) {
                        println!(
                            "# shape n={n} k={k}: standard/treecv = {:.2}x (theory ~ {:.2}x)",
                            s / t.max(1e-12),
                            k as f64 / ((2 * k) as f64).log2()
                        );
                    }
                }
            }
            println!();
        }
    }
}
