//! Bench: regenerate the paper's Table 2 — k-CV estimates (mean ± std over
//! repetitions) for PEGASOS (top) and LSQSGD (bottom), TreeCV vs Standard,
//! fixed vs randomized feeding order, k ∈ {5, 10, 100, n}.
//!
//! Run: `cargo bench --bench table2` — env `TABLE2_N` / `TABLE2_REPS`
//! override the workload (paper: n = 581,012 / 463,715 with 100 reps; the
//! default here is scaled for minutes-not-hours wall time).

use treecv::config::Engine::*;
use treecv::config::{OrderingCfg, Task};
use treecv::coordinator::paper;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let n = env_usize("TABLE2_N", 20_000);
    let reps = env_usize("TABLE2_REPS", 20);
    let ks = [5usize, 10, 100, 0];

    for task in [Task::Pegasos, Task::Lsqsgd] {
        let out = paper::table2(task, n, &ks, reps, 42).expect("table2");
        println!("{}", out.render());
        // Paper-shape report: TreeCV's std shrinks with k (Table 2's
        // observation); Standard-fixed's shrinks much less for PEGASOS.
        let std_of = |k: usize, engine: treecv::config::Engine, ordering: OrderingCfg| {
            out.cells
                .iter()
                .find(|c| {
                    (c.k == k || (k == 0 && c.is_loocv))
                        && c.engine == engine
                        && c.ordering == ordering
                })
                .map(|c| c.std)
        };
        if let (Some(t5), Some(tn)) =
            (std_of(5, Treecv, OrderingCfg::Fixed), std_of(0, Treecv, OrderingCfg::Fixed))
        {
            println!(
                "shape [{:}]: std(TreeCV fixed) k=5 {:.5} -> k=n {:.5}  (decays: {})",
                task.name(),
                t5,
                tn,
                tn < t5
            );
        }
        if let (Some(s5), Some(s100)) =
            (std_of(5, Standard, OrderingCfg::Fixed), std_of(100, Standard, OrderingCfg::Fixed))
        {
            println!(
                "shape [{:}]: std(Standard fixed) k=5 {:.5} -> k=100 {:.5}",
                task.name(),
                s5,
                s100
            );
        }
        println!();
    }
}
