//! Fold-contiguous layout vs classic indexed node streams: the hot-loop
//! memory-layout experiment, and the seed of the repo's machine-readable
//! perf trajectory.
//!
//! Every scenario runs the SAME computation twice — indexed (`run`) and
//! folded (`run_folded` over a prebuilt [`FoldedDataset`]) — asserts the
//! results are **bit-identical** in-bench (per-fold scores, estimate,
//! semantic counters) before any number is reported, then records both
//! timings plus the layout-sensitive metrics (`stream_allocs`,
//! `points_updated`) into `BENCH_layout.json` via `benchkit::JsonReport`.
//!
//! Run: `cargo bench --bench layout` (env `LAYOUT_N`, `LAYOUT_K`,
//! `LAYOUT_THREADS`, `LAYOUT_JSON` for the output path; `BENCH_SAMPLES`
//! / `BENCH_WARMUP` as usual). Committed output is the perf baseline
//! subsequent PRs diff against — regenerate it on a quiet machine.

use treecv::benchkit::{Bench, JsonReport};
use treecv::cv::executor::TreeCvExecutor;
use treecv::cv::folds::{Folds, Ordering};
use treecv::cv::standard::StandardCv;
use treecv::cv::treecv::TreeCv;
use treecv::cv::{CvEngine, CvResult, Strategy};
use treecv::data::folded::FoldedDataset;
use treecv::data::synth::{SyntheticCovertype, SyntheticYearMsd};
use treecv::data::Dataset;
use treecv::learner::lsqsgd::LsqSgd;
use treecv::learner::pegasos::Pegasos;

fn assert_bit_identical(indexed: &CvResult, folded: &CvResult, ctx: &str) {
    assert_eq!(indexed.per_fold, folded.per_fold, "{ctx}: per_fold diverged");
    assert_eq!(indexed.estimate.to_bits(), folded.estimate.to_bits(), "{ctx}: estimate");
    assert_eq!(indexed.ops.points_updated, folded.ops.points_updated, "{ctx}: points_updated");
    assert_eq!(indexed.ops.update_calls, folded.ops.update_calls, "{ctx}: update_calls");
    assert_eq!(indexed.ops.model_copies, folded.ops.model_copies, "{ctx}: model_copies");
    assert_eq!(indexed.ops.points_permuted, folded.ops.points_permuted, "{ctx}: points_permuted");
    assert_eq!(indexed.ops.evals, folded.ops.evals, "{ctx}: evals");
}

/// Bench one indexed-vs-folded pair; returns (indexed median, folded
/// median) and pushes both scenarios (with counters + speedup) into the
/// JSON report.
///
/// `stable_allocs`: whether the folded run's `stream_allocs` is a pure
/// function of the configuration. It is for everything except
/// multi-worker randomized runs (there it is 1..=workers, depending on
/// which workers touch an update phase) — those pass `false` so the
/// committed baseline never records a schedule-dependent number.
fn pair<FI, FF>(
    bench: &mut Bench,
    report: &mut JsonReport,
    name: &str,
    data: &Dataset,
    stable_allocs: bool,
    mut indexed: FI,
    mut folded: FF,
) -> (f64, f64)
where
    FI: FnMut(&Dataset) -> CvResult,
    FF: FnMut(&Dataset) -> CvResult,
{
    let want = indexed(data);
    let got = folded(data);
    assert_bit_identical(&want, &got, name);

    let si = bench.run(&format!("{name}/indexed"), || {
        std::hint::black_box(indexed(data));
    });
    let (ti, si) = (si.median(), si.clone());
    let sf = bench.run(&format!("{name}/folded"), || {
        std::hint::black_box(folded(data));
    });
    let (tf, sf) = (sf.median(), sf.clone());
    println!("  folded speedup: {:.3}x", ti / tf.max(1e-12));

    report.push_samples(
        &si,
        &[
            ("stream_allocs", want.ops.stream_allocs as f64),
            ("points_updated", want.ops.points_updated as f64),
        ],
    );
    let mut folded_metrics = vec![
        ("points_updated", got.ops.points_updated as f64),
        ("speedup_vs_indexed", ti / tf.max(1e-12)),
    ];
    if stable_allocs {
        folded_metrics.push(("stream_allocs", got.ops.stream_allocs as f64));
    }
    report.push_samples(&sf, &folded_metrics);
    (ti, tf)
}

fn main() {
    let n: usize = std::env::var("LAYOUT_N").ok().and_then(|v| v.parse().ok()).unwrap_or(16_384);
    let k: usize = std::env::var("LAYOUT_K").ok().and_then(|v| v.parse().ok()).unwrap_or(32);
    let threads: usize = std::env::var("LAYOUT_THREADS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or_else(|| std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1));
    let json_path =
        std::env::var("LAYOUT_JSON").unwrap_or_else(|_| "BENCH_layout.json".to_string());

    println!("== folded vs indexed node streams (n = {n}, k = {k}, {threads} workers) ==");
    let mut bench = Bench::default();
    let mut report = JsonReport::new("layout");
    report.env("n", n as f64);
    report.env("k", k as f64);
    report.env("threads", threads as f64);

    // PEGASOS on Covertype-like data: the crate's cheapest per-point
    // update, so stream overhead is maximally visible.
    {
        let data = SyntheticCovertype::new(n, 31).generate();
        let learner = Pegasos::new(data.d, 1e-4);
        let folds = Folds::new(n, k, 32);
        let folded = FoldedDataset::build(&data, &folds);

        let build = bench.run("layout/build", || {
            std::hint::black_box(FoldedDataset::build(&data, &folds));
        });
        let build = build.clone();
        report.push_samples(&build, &[("rows_copied", n as f64)]);

        let seq = TreeCv::new(Strategy::Copy, Ordering::Fixed, 5);
        pair(
            &mut bench,
            &mut report,
            "layout/pegasos/treecv/fixed",
            &data,
            true,
            |d| seq.run(&learner, d, &folds),
            |d| seq.run_folded(&learner, d, &folded),
        );

        let exe = TreeCvExecutor::new(Strategy::Copy, Ordering::Fixed, 5, threads);
        pair(
            &mut bench,
            &mut report,
            "layout/pegasos/executor/fixed",
            &data,
            true,
            |d| exe.run(&learner, d, &folds),
            |d| exe.run_folded(&learner, d, &folded),
        );

        let std_engine = StandardCv::new(Ordering::Fixed, 5);
        pair(
            &mut bench,
            &mut report,
            "layout/pegasos/standard/fixed",
            &data,
            true,
            |d| std_engine.run(&learner, d, &folds),
            |d| std_engine.run_folded(&learner, d, &folded),
        );

        // Randomized ordering: the folded win here is allocation removal
        // (recycled scratch), not sequential access — keep it honest.
        let exe_r = TreeCvExecutor::new(Strategy::Copy, Ordering::Randomized, 5, threads);
        pair(
            &mut bench,
            &mut report,
            "layout/pegasos/executor/randomized",
            &data,
            false,
            |d| exe_r.run(&learner, d, &folds),
            |d| exe_r.run_folded(&learner, d, &folded),
        );
    }

    // LSQSGD on YearMSD-like data: denser rows (d = 90), every point
    // touches the full row — the bandwidth-bound regime.
    {
        let data = SyntheticYearMsd::new(n / 2, 33).generate();
        let learner = LsqSgd::with_paper_step(data.d, n / 2);
        let folds = Folds::new(n / 2, k, 34);
        let folded = FoldedDataset::build(&data, &folds);
        let seq = TreeCv::new(Strategy::Copy, Ordering::Fixed, 6);
        pair(
            &mut bench,
            &mut report,
            "layout/lsqsgd/treecv/fixed",
            &data,
            true,
            |d| seq.run(&learner, d, &folds),
            |d| seq.run_folded(&learner, d, &folded),
        );
    }

    println!("\nCSV summary:\n{}", bench.csv());
    match report.write(&json_path) {
        Ok(()) => println!("wrote {json_path}"),
        Err(e) => eprintln!("could not write {json_path}: {e}"),
    }
}
