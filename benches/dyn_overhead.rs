//! Erased-vs-generic dispatch overhead: what does the object-safe learner
//! layer (`learner::erased`) cost on top of the monomorphized path?
//!
//! Two learners bracket the range: PEGASOS (tiny per-point work — every
//! vtable call is maximally visible) and HistogramDensity (integer bin
//! bumps — similar, with exact-revert SaveRevert exercised too). Each
//! measurement runs the SAME computation through `TreeCvExecutor::run`
//! (generic) and `TreeCvExecutor::run_erased` (erased) and asserts the
//! results are **bit-identical** in-bench — per-fold scores, estimate,
//! and work counters — so a regression in the equivalence contract fails
//! the bench before any number is reported.
//!
//! Run: `cargo bench --bench dyn_overhead` (env `DYN_N`, `DYN_K`,
//! `DYN_THREADS`).

use treecv::benchkit::Bench;
use treecv::cv::executor::TreeCvExecutor;
use treecv::cv::folds::{Folds, Ordering};
use treecv::cv::{CvResult, Strategy};
use treecv::data::synth::{SyntheticCovertype, SyntheticMixture1d};
use treecv::learner::erased::{Erased, ErasedLearner};
use treecv::learner::histdensity::HistogramDensity;
use treecv::learner::pegasos::Pegasos;

fn assert_bit_identical(generic: &CvResult, erased: &CvResult, ctx: &str) {
    assert_eq!(generic.per_fold, erased.per_fold, "{ctx}: per_fold diverged");
    assert_eq!(generic.estimate.to_bits(), erased.estimate.to_bits(), "{ctx}: estimate");
    assert_eq!(generic.ops.points_updated, erased.ops.points_updated, "{ctx}: points_updated");
    assert_eq!(generic.ops.model_copies, erased.ops.model_copies, "{ctx}: model_copies");
    assert_eq!(generic.ops.model_restores, erased.ops.model_restores, "{ctx}: model_restores");
    assert_eq!(generic.ops.evals, erased.ops.evals, "{ctx}: evals");
}

fn main() {
    let n: usize = std::env::var("DYN_N").ok().and_then(|v| v.parse().ok()).unwrap_or(16_384);
    let k: usize = std::env::var("DYN_K").ok().and_then(|v| v.parse().ok()).unwrap_or(32);
    let threads: usize = std::env::var("DYN_THREADS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or_else(|| std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1));

    println!("== erased vs generic dispatch (n = {n}, k = {k}, {threads} workers) ==");
    let mut bench = Bench::default();

    // PEGASOS, Copy strategy: cheapest per-point update in the crate.
    {
        let data = SyntheticCovertype::new(n, 21).generate();
        let folds = Folds::new(n, k, 22);
        let learner = Pegasos::new(data.d, 1e-4);
        let erased: Box<dyn ErasedLearner> = Erased::boxed(learner.clone());
        let engine = TreeCvExecutor::new(Strategy::Copy, Ordering::Fixed, 5, threads);
        let g = bench.run("dyn/pegasos/generic", || {
            std::hint::black_box(engine.run(&learner, &data, &folds));
        });
        let t_generic = g.median();
        let e = bench.run("dyn/pegasos/erased", || {
            std::hint::black_box(engine.run_erased(&*erased, &data, &folds));
        });
        println!("  erased/generic ratio: {:.3}x", e.median() / t_generic.max(1e-12));

        let want = engine.run(&learner, &data, &folds);
        let got = engine.run_erased(&*erased, &data, &folds);
        assert_bit_identical(&want, &got, "pegasos/copy");
    }

    // HistogramDensity, both strategies (exact revert).
    {
        let data = SyntheticMixture1d::new(n, 23).generate();
        let folds = Folds::new(n, k, 24);
        let learner = HistogramDensity::new(-8.0, 8.0, 64);
        let erased: Box<dyn ErasedLearner> = Erased::boxed(learner.clone());
        for strategy in [Strategy::Copy, Strategy::SaveRevert] {
            let tag = match strategy {
                Strategy::Copy => "copy",
                Strategy::SaveRevert => "save_revert",
            };
            let engine = TreeCvExecutor::new(strategy, Ordering::Fixed, 5, threads);
            let g = bench.run(&format!("dyn/histdensity/{tag}/generic"), || {
                std::hint::black_box(engine.run(&learner, &data, &folds));
            });
            let t_generic = g.median();
            let e = bench.run(&format!("dyn/histdensity/{tag}/erased"), || {
                std::hint::black_box(engine.run_erased(&*erased, &data, &folds));
            });
            println!("  erased/generic ratio: {:.3}x", e.median() / t_generic.max(1e-12));

            let want = engine.run(&learner, &data, &folds);
            let got = engine.run_erased(&*erased, &data, &folds);
            assert_bit_identical(&want, &got, &format!("histdensity/{tag}"));
        }
    }

    println!("\nCSV summary:\n{}", bench.csv());
}
