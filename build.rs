//! Build probe for the PJRT runtime gate.
//!
//! The real PJRT client (`runtime::PjrtRuntime`) needs the external `xla`
//! crate, which offline builds don't have — so enabling the `xla` cargo
//! feature alone must still compile (CI's feature-matrix job builds
//! `--features xla` as a stub). The real implementation is therefore
//! gated on `cfg(treecv_pjrt)`, emitted here only when BOTH the feature
//! is on AND `TREECV_XLA_RUNTIME=1` is set — the same environment that
//! adds the `xla = "..."` dependency to Cargo.toml.

fn main() {
    // Declare the custom cfg so check-cfg-aware toolchains don't warn;
    // older cargos ignore unknown instructions.
    println!("cargo:rustc-check-cfg=cfg(treecv_pjrt)");
    // Model-check builds pass `--cfg treecv_model_check` via RUSTFLAGS to
    // swap crate::sync onto the instrumented scheduler shim; declare the
    // cfg so check-cfg toolchains accept it everywhere else.
    println!("cargo:rustc-check-cfg=cfg(treecv_model_check)");
    println!("cargo:rerun-if-env-changed=TREECV_XLA_RUNTIME");
    let feature_on = std::env::var_os("CARGO_FEATURE_XLA").is_some();
    // Compare the value, not mere presence: TREECV_XLA_RUNTIME=0 must
    // keep the stub (the documented opt-in is exactly `=1`).
    let runtime_present = std::env::var("TREECV_XLA_RUNTIME").is_ok_and(|v| v == "1");
    if feature_on && runtime_present {
        println!("cargo:rustc-cfg=treecv_pjrt");
    }
}
