"""Layer-1 Pallas kernels for PEGASOS: masked sequential chunk update and
masked chunk evaluation.

Hardware adaptation (DESIGN.md §3): the paper's experiments are CPU, so
there is no GPU code to port; the kernels are still *structured* for TPU.
The whole working set of one call -- a (B, d) tile of rows, the (d,)
weight vector, labels and mask -- is a single VMEM-resident block
(BlockSpec with no grid): for the shipped shapes (B=256, d<=90 f32) that
is ~96 KiB, far under the ~16 MiB VMEM budget, so the HBM<->VMEM schedule
is one load + one store per call. The update kernel is a sequential scan
(SGD's loop-carried dependence; its roofline is latency-, not
throughput-bound), with each step doing one fused dot product + axpy on
the VMEM-held weights. The evaluation kernel has no loop-carried state:
it is a (B, d) x (d,) mat-vec -- the MXU-shaped part -- plus a masked
reduction.

interpret=True everywhere: real TPU lowering emits a Mosaic custom-call
the CPU PJRT plugin cannot execute (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pegasos_update_kernel(w_ref, t_ref, lam_ref, x_ref, y_ref, mask_ref, wo_ref, to_ref):
    """Sequential masked PEGASOS scan over the B rows of the block.

    State lives in the *output* refs (wo, to), which double as the scan
    carry: they are initialized from the inputs and updated in place per
    row. Masked rows are no-ops (t does not advance).
    """
    wo_ref[...] = w_ref[...]
    to_ref[...] = t_ref[...]
    lam = lam_ref[0]
    b = x_ref.shape[0]

    def body(i, _):
        m = mask_ref[i]
        w = wo_ref[...]
        t = to_ref[0] + m  # advances only on real rows
        x = x_ref[i, :]
        yv = y_ref[i]
        margin = yv * jnp.dot(w, x)
        shrink = 1.0 - 1.0 / t
        eta = 1.0 / (lam * t)
        coeff = jnp.where(margin < 1.0, eta * yv, 0.0)
        new_w = shrink * w + coeff * x
        keep = m > 0.0
        wo_ref[...] = jnp.where(keep, new_w, w)
        to_ref[0] = jnp.where(keep, t, to_ref[0])
        return 0

    jax.lax.fori_loop(0, b, body, 0)


def _pegasos_eval_kernel(w_ref, x_ref, y_ref, mask_ref, out_ref):
    """Masked misclassification count: one mat-vec + reduction."""
    scores = x_ref[...] @ w_ref[...]
    pred = jnp.where(scores >= 0.0, 1.0, -1.0)
    wrong = jnp.where(pred != y_ref[...], 1.0, 0.0)
    out_ref[0] = jnp.sum(wrong * mask_ref[...])


@functools.partial(jax.jit, static_argnames=("block", "dim"))
def pegasos_update(w, t, lam, x, y, mask, *, block, dim):
    """L2 entry point: masked PEGASOS chunk update via the Pallas kernel.

    Scalars arrive rank-0 (that is what the Rust runtime feeds) and are
    lifted to (1,) for the kernel.
    """
    t1 = jnp.reshape(t, (1,)).astype(jnp.float32)
    lam1 = jnp.reshape(lam, (1,)).astype(jnp.float32)
    w_out, t_out = pl.pallas_call(
        _pegasos_update_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((dim,), jnp.float32),
            jax.ShapeDtypeStruct((1,), jnp.float32),
        ),
        interpret=True,
    )(w, t1, lam1, x, y, mask)
    return w_out, t_out[0]


@functools.partial(jax.jit, static_argnames=("block", "dim"))
def pegasos_eval(w, x, y, mask, *, block, dim):
    """L2 entry point: masked misclassification count via the Pallas kernel."""
    errs = pl.pallas_call(
        _pegasos_eval_kernel,
        out_shape=jax.ShapeDtypeStruct((1,), jnp.float32),
        interpret=True,
    )(w, x, y, mask)
    return errs[0]
