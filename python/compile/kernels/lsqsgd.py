"""Layer-1 Pallas kernels for LSQSGD (robust-SA least squares): masked
sequential chunk update (with unit-ball projection and running average)
and masked squared-error evaluation.

Same VMEM/MXU structure as `pegasos.py`: one (B, d) block per call, the
update a latency-bound sequential scan over rows with the (w, wavg, t)
carry held in the output refs, the evaluation a single mat-vec + masked
reduction. interpret=True for CPU-PJRT execution.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _lsqsgd_update_kernel(
    w_ref, wavg_ref, t_ref, alpha_ref, x_ref, y_ref, mask_ref, wo_ref, wao_ref, to_ref
):
    """Sequential masked LSQSGD scan; carry = (wo, wao, to) refs."""
    wo_ref[...] = w_ref[...]
    wao_ref[...] = wavg_ref[...]
    to_ref[...] = t_ref[...]
    alpha = alpha_ref[0]
    b = x_ref.shape[0]

    def body(i, _):
        m = mask_ref[i]
        w = wo_ref[...]
        wavg = wao_ref[...]
        t = to_ref[0] + m
        x = x_ref[i, :]
        resid = jnp.dot(w, x) - y_ref[i]
        stepped = w - alpha * 2.0 * resid * x
        # Project onto the unit l2 ball.
        nrm2 = jnp.dot(stepped, stepped)
        scale = jnp.where(nrm2 > 1.0, jax.lax.rsqrt(nrm2), 1.0)
        projected = stepped * scale
        new_avg = wavg + (projected - wavg) / t
        keep = m > 0.0
        wo_ref[...] = jnp.where(keep, projected, w)
        wao_ref[...] = jnp.where(keep, new_avg, wavg)
        to_ref[0] = jnp.where(keep, t, to_ref[0])
        return 0

    jax.lax.fori_loop(0, b, body, 0)


def _lsqsgd_eval_kernel(wavg_ref, x_ref, y_ref, mask_ref, out_ref):
    """Masked sum of squared errors: one mat-vec + reduction."""
    pred = x_ref[...] @ wavg_ref[...]
    err = pred - y_ref[...]
    out_ref[0] = jnp.sum(err * err * mask_ref[...])


@functools.partial(jax.jit, static_argnames=("block", "dim"))
def lsqsgd_update(w, wavg, t, alpha, x, y, mask, *, block, dim):
    """L2 entry point: masked LSQSGD chunk update via the Pallas kernel."""
    t1 = jnp.reshape(t, (1,)).astype(jnp.float32)
    a1 = jnp.reshape(alpha, (1,)).astype(jnp.float32)
    w_out, wavg_out, t_out = pl.pallas_call(
        _lsqsgd_update_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((dim,), jnp.float32),
            jax.ShapeDtypeStruct((dim,), jnp.float32),
            jax.ShapeDtypeStruct((1,), jnp.float32),
        ),
        interpret=True,
    )(w, wavg, t1, a1, x, y, mask)
    return w_out, wavg_out, t_out[0]


@functools.partial(jax.jit, static_argnames=("block", "dim"))
def lsqsgd_eval(wavg, x, y, mask, *, block, dim):
    """L2 entry point: masked SSE via the Pallas kernel."""
    sse = pl.pallas_call(
        _lsqsgd_eval_kernel,
        out_shape=jax.ShapeDtypeStruct((1,), jnp.float32),
        interpret=True,
    )(wavg, x, y, mask)
    return sse[0]
