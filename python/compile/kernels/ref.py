"""Pure-NumPy oracles for the Pallas kernels (Layer 1 correctness ground
truth).

These mirror, step for step, the math the kernels implement:

* PEGASOS chunk update (Shalev-Shwartz et al. 2011, "last hypothesis"):
  per point t += 1; margin = y.<w,x>; w <- (1 - 1/t) w; on margin < 1
  additionally w += (1/(lambda t)) y x. Masked (padding) rows are skipped
  entirely -- they advance neither t nor w.
* PEGASOS chunk evaluation: masked misclassification count of sign(<w,x>)
  (ties predict +1, matching the Rust learner).
* LSQSGD chunk update (Nemirovski et al. 2009 robust SA): per point
  w <- Pi_{||.||<=1}(w - alpha * 2(<w,x> - y) x); running average
  wavg += (w - wavg)/t.
* LSQSGD chunk evaluation: masked sum of squared errors of <wavg, x>.

Everything is float32 to match both the artifacts and the Rust learners.
"""

from __future__ import annotations

import numpy as np

F32 = np.float32


def pegasos_update_ref(w, t, lam, x, y, mask):
    """Reference PEGASOS chunk update.

    Args:
      w: (d,) float32 weights.
      t: scalar float32 step counter (points consumed so far).
      lam: scalar float32 regularizer.
      x: (B, d) float32 rows.
      y: (B,) float32 labels in {+1, -1} (arbitrary on masked rows).
      mask: (B,) float32 validity (1 = real row, 0 = padding).

    Returns:
      (w', t') after consuming the masked chunk in row order.
    """
    w = np.array(w, dtype=F32).copy()
    t = F32(t)
    lam = F32(lam)
    for i in range(x.shape[0]):
        if mask[i] == 0:
            continue
        t = F32(t + F32(1.0))
        xi = x[i].astype(F32)
        margin = F32(y[i]) * F32(np.dot(w, xi))
        shrink = F32(1.0) - F32(1.0) / t
        eta = F32(1.0) / (lam * t)
        w = (shrink * w).astype(F32)
        if margin < F32(1.0):
            w = (w + eta * F32(y[i]) * xi).astype(F32)
    return w, t


def pegasos_eval_ref(w, x, y, mask):
    """Masked misclassification count (not rate) for sign(<w,x>)."""
    scores = x.astype(F32) @ np.asarray(w, dtype=F32)
    pred = np.where(scores >= 0, F32(1.0), F32(-1.0))
    wrong = (pred != y.astype(F32)).astype(F32)
    return F32(np.sum(wrong * mask.astype(F32)))


def lsqsgd_update_ref(w, wavg, t, alpha, x, y, mask):
    """Reference LSQSGD chunk update; returns (w', wavg', t')."""
    w = np.array(w, dtype=F32).copy()
    wavg = np.array(wavg, dtype=F32).copy()
    t = F32(t)
    alpha = F32(alpha)
    for i in range(x.shape[0]):
        if mask[i] == 0:
            continue
        t = F32(t + F32(1.0))
        xi = x[i].astype(F32)
        resid = F32(np.dot(w, xi)) - F32(y[i])
        w = (w - alpha * F32(2.0) * resid * xi).astype(F32)
        nrm2 = float(np.dot(w.astype(np.float64), w.astype(np.float64)))
        if nrm2 > 1.0:
            w = (w / F32(np.sqrt(nrm2))).astype(F32)
        wavg = (wavg + (w - wavg) / t).astype(F32)
    return w, wavg, t


def lsqsgd_eval_ref(wavg, x, y, mask):
    """Masked sum of squared errors (not mean) of <wavg, x>."""
    pred = x.astype(F32) @ np.asarray(wavg, dtype=F32)
    err = (pred - y.astype(F32)).astype(F32)
    return F32(np.sum(err * err * mask.astype(F32)))
