"""Layer 2: the jitted JAX programs the Rust runtime executes, built on the
Layer-1 Pallas kernels.

Each program family is a function of fixed (block, dim) shape:

* ``pegasos_update(w, t, lam, x, y, mask) -> (w', t')``
* ``pegasos_eval(w, x, y, mask) -> err_count``
* ``lsqsgd_update(w, wavg, t, alpha, x, y, mask) -> (w', wavg', t')``
* ``lsqsgd_eval(wavg, x, y, mask) -> sse``

``aot.py`` lowers these once per shape variant to HLO text under
``artifacts/``; they are never imported at runtime. The functions return
tuples so the lowered programs have a uniform tuple ABI on the Rust side
(``Literal::to_tuple``).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels import lsqsgd as lsqsgd_k
from compile.kernels import pegasos as pegasos_k

F32 = jnp.float32


def make_specs(block: int, dim: int):
    """ShapeDtypeStructs for one (block, dim) variant, keyed by input name."""
    return {
        "w": jax.ShapeDtypeStruct((dim,), F32),
        "wavg": jax.ShapeDtypeStruct((dim,), F32),
        "t": jax.ShapeDtypeStruct((), F32),
        "lam": jax.ShapeDtypeStruct((), F32),
        "alpha": jax.ShapeDtypeStruct((), F32),
        "x": jax.ShapeDtypeStruct((block, dim), F32),
        "y": jax.ShapeDtypeStruct((block,), F32),
        "mask": jax.ShapeDtypeStruct((block,), F32),
    }


def pegasos_update_fn(block: int, dim: int):
    """(w, t, lam, x, y, mask) -> (w', t')."""

    def fn(w, t, lam, x, y, mask):
        w2, t2 = pegasos_k.pegasos_update(w, t, lam, x, y, mask, block=block, dim=dim)
        return (w2, t2)

    return fn


def pegasos_eval_fn(block: int, dim: int):
    """(w, x, y, mask) -> (masked error count,)."""

    def fn(w, x, y, mask):
        return (pegasos_k.pegasos_eval(w, x, y, mask, block=block, dim=dim),)

    return fn


def lsqsgd_update_fn(block: int, dim: int):
    """(w, wavg, t, alpha, x, y, mask) -> (w', wavg', t')."""

    def fn(w, wavg, t, alpha, x, y, mask):
        w2, wavg2, t2 = lsqsgd_k.lsqsgd_update(
            w, wavg, t, alpha, x, y, mask, block=block, dim=dim
        )
        return (w2, wavg2, t2)

    return fn


def lsqsgd_eval_fn(block: int, dim: int):
    """(wavg, x, y, mask) -> (masked SSE,)."""

    def fn(wavg, x, y, mask):
        return (lsqsgd_k.lsqsgd_eval(wavg, x, y, mask, block=block, dim=dim),)

    return fn


def program_table(block: int, dim: int):
    """All programs for one (block, dim): name -> (fn, arg spec names)."""
    return {
        f"pegasos_update_b{block}_d{dim}": (
            pegasos_update_fn(block, dim),
            ["w", "t", "lam", "x", "y", "mask"],
        ),
        f"pegasos_eval_b{block}_d{dim}": (
            pegasos_eval_fn(block, dim),
            ["w", "x", "y", "mask"],
        ),
        f"lsqsgd_update_b{block}_d{dim}": (
            lsqsgd_update_fn(block, dim),
            ["w", "wavg", "t", "alpha", "x", "y", "mask"],
        ),
        f"lsqsgd_eval_b{block}_d{dim}": (
            lsqsgd_eval_fn(block, dim),
            ["wavg", "x", "y", "mask"],
        ),
    }
