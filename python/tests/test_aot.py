"""AOT pipeline tests: every Layer-2 program lowers to valid HLO text, the
manifest is consistent, and the tuple ABI the Rust runtime expects holds.
"""

from __future__ import annotations

import os

import numpy as np
import pytest

from compile import aot, model


class TestLowering:
    @pytest.mark.parametrize("block,dim", [(8, 6)])
    def test_programs_lower_to_hlo_text(self, block, dim):
        specs = model.make_specs(block, dim)
        for name, (fn, arg_names) in model.program_table(block, dim).items():
            lowered = aot.lower_program(fn, specs, arg_names)
            text = aot.to_hlo_text(lowered)
            assert text.startswith("HloModule"), name
            assert "ROOT" in text, name
            # The tuple ABI: the root is a tuple (return_tuple=True).
            assert "tuple(" in text or "(f32[" in text, name

    def test_update_program_shapes(self):
        block, dim = 8, 6
        specs = model.make_specs(block, dim)
        fn, arg_names = model.program_table(block, dim)[f"pegasos_update_b{block}_d{dim}"]
        lowered = aot.lower_program(fn, specs, arg_names)
        text = aot.to_hlo_text(lowered)
        assert f"f32[{block},{dim}]" in text  # the X input survives lowering


class TestBuild:
    def test_build_writes_artifacts_and_manifest(self, tmp_path):
        rows = aot.build(str(tmp_path), variants=[(4, 3)])
        assert len(rows) == 4
        names = {r[0] for r in rows}
        assert f"pegasos_update_b4_d3" in names
        for name, _, _ in rows:
            p = tmp_path / f"{name}.hlo.txt"
            assert p.exists() and p.stat().st_size > 100, name
        manifest = (tmp_path / "manifest.txt").read_text()
        assert "jax " in manifest
        for name, block, dim in rows:
            assert f"program {name} {block} {dim}" in manifest

    def test_shipped_variants_cover_paper_dims(self):
        dims = {d for _, d in aot.VARIANTS}
        assert 54 in dims, "covertype dimension missing"
        assert 90 in dims, "yearmsd dimension missing"


class TestNumericsThroughLowering:
    """Executing the jitted L2 programs (the exact computations that get
    lowered) must agree with the NumPy oracles — this is the L2-level
    correctness gate; the Rust integration test then checks the same
    numbers come out of the compiled artifacts via PJRT."""

    def test_pegasos_roundtrip(self):
        from compile.kernels import ref

        block, dim = 8, 6
        rng = np.random.default_rng(0)
        w = np.zeros(dim, dtype=np.float32)
        x = rng.normal(size=(block, dim)).astype(np.float32)
        y = rng.choice([-1.0, 1.0], size=block).astype(np.float32)
        mask = np.ones(block, dtype=np.float32)
        fn, _ = model.program_table(block, dim)[f"pegasos_update_b{block}_d{dim}"]
        got_w, got_t = fn(w, np.float32(0.0), np.float32(0.1), x, y, mask)
        want_w, want_t = ref.pegasos_update_ref(w, 0.0, 0.1, x, y, mask)
        np.testing.assert_allclose(np.asarray(got_w), want_w, rtol=2e-4, atol=1e-5)
        assert float(got_t) == float(want_t)

    def test_lsqsgd_roundtrip(self):
        from compile.kernels import ref

        block, dim = 8, 6
        rng = np.random.default_rng(1)
        w = np.zeros(dim, dtype=np.float32)
        wavg = np.zeros(dim, dtype=np.float32)
        x = rng.normal(size=(block, dim)).astype(np.float32)
        y = rng.random(block).astype(np.float32)
        mask = np.ones(block, dtype=np.float32)
        fn, _ = model.program_table(block, dim)[f"lsqsgd_update_b{block}_d{dim}"]
        got = fn(w, wavg, np.float32(0.0), np.float32(0.1), x, y, mask)
        want = ref.lsqsgd_update_ref(w, wavg, 0.0, 0.1, x, y, mask)
        np.testing.assert_allclose(np.asarray(got[0]), want[0], rtol=2e-4, atol=1e-5)
        np.testing.assert_allclose(np.asarray(got[1]), want[1], rtol=2e-4, atol=1e-5)
