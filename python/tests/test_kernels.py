"""Layer-1 correctness: Pallas kernels (interpret mode) vs the pure-NumPy
oracles in ``compile.kernels.ref`` — the core correctness signal for the
compute hot path, swept over shapes/masks/values with hypothesis.
"""

from __future__ import annotations

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import lsqsgd as lsqsgd_k
from compile.kernels import pegasos as pegasos_k
from compile.kernels import ref

RTOL = 2e-4  # f32 sequential scans; tolerances include reassociation slack
ATOL = 1e-5


def make_case(rng, block, dim, mask_kind="mixed"):
    x = rng.normal(size=(block, dim)).astype(np.float32)
    y = rng.choice([-1.0, 1.0], size=block).astype(np.float32)
    if mask_kind == "full":
        mask = np.ones(block, dtype=np.float32)
    elif mask_kind == "empty":
        mask = np.zeros(block, dtype=np.float32)
    else:
        mask = (rng.random(block) < 0.7).astype(np.float32)
    w = (0.1 * rng.normal(size=dim)).astype(np.float32)
    return w, x, y, mask


class TestPegasosUpdate:
    @pytest.mark.parametrize("block,dim", [(4, 3), (8, 6), (16, 54), (5, 7)])
    @pytest.mark.parametrize("mask_kind", ["full", "mixed", "empty"])
    def test_matches_ref(self, block, dim, mask_kind):
        rng = np.random.default_rng(block * 1000 + dim)
        w, x, y, mask = make_case(rng, block, dim, mask_kind)
        t0, lam = np.float32(17.0), np.float32(1e-3)
        got_w, got_t = pegasos_k.pegasos_update(w, t0, lam, x, y, mask, block=block, dim=dim)
        want_w, want_t = ref.pegasos_update_ref(w, t0, lam, x, y, mask)
        np.testing.assert_allclose(np.asarray(got_w), want_w, rtol=RTOL, atol=ATOL)
        assert float(got_t) == float(want_t)

    def test_fresh_model_first_step(self):
        # Fresh model (w=0, t=0): margin 0 < 1, shrink factor (1-1/1) = 0,
        # so after the first real row w = (1/λ)·y·x exactly.
        block, dim = 4, 3
        x = np.eye(block, dim, dtype=np.float32)
        y = np.ones(block, dtype=np.float32)
        mask = np.array([1, 0, 0, 0], dtype=np.float32)
        w0 = np.zeros(dim, dtype=np.float32)
        lam = np.float32(0.5)
        got_w, got_t = pegasos_k.pegasos_update(
            w0, np.float32(0.0), lam, x, y, mask, block=block, dim=dim
        )
        assert float(got_t) == 1.0
        np.testing.assert_allclose(np.asarray(got_w), [2.0, 0.0, 0.0], rtol=1e-6)

    def test_masked_rows_do_not_advance_t(self):
        rng = np.random.default_rng(5)
        w, x, y, mask = make_case(rng, 8, 4, "mixed")
        t0 = np.float32(3.0)
        _, got_t = pegasos_k.pegasos_update(w, t0, np.float32(0.1), x, y, mask, block=8, dim=4)
        assert float(got_t) == float(t0) + float(mask.sum())

    def test_incremental_composition(self):
        # Two half-block updates == one concatenated update (same mask).
        rng = np.random.default_rng(6)
        dim = 5
        w, x, y, mask = make_case(rng, 8, dim, "full")
        lam = np.float32(0.05)
        w_full, t_full = pegasos_k.pegasos_update(
            w, np.float32(0.0), lam, x, y, mask, block=8, dim=dim
        )
        w_a, t_a = pegasos_k.pegasos_update(
            w, np.float32(0.0), lam, x[:4], y[:4], mask[:4], block=4, dim=dim
        )
        w_b, t_b = pegasos_k.pegasos_update(
            np.asarray(w_a), t_a, lam, x[4:], y[4:], mask[4:], block=4, dim=dim
        )
        assert float(t_b) == float(t_full)
        np.testing.assert_allclose(np.asarray(w_b), np.asarray(w_full), rtol=RTOL, atol=ATOL)

    @settings(max_examples=25, deadline=None)
    @given(
        block=st.integers(2, 12),
        dim=st.integers(1, 16),
        seed=st.integers(0, 2**32 - 1),
        lam=st.floats(1e-4, 1.0),
        t0=st.floats(0.0, 1e4),
    )
    def test_hypothesis_sweep(self, block, dim, seed, lam, t0):
        rng = np.random.default_rng(seed)
        w, x, y, mask = make_case(rng, block, dim)
        got_w, got_t = pegasos_k.pegasos_update(
            w, np.float32(t0), np.float32(lam), x, y, mask, block=block, dim=dim
        )
        want_w, want_t = ref.pegasos_update_ref(w, np.float32(t0), np.float32(lam), x, y, mask)
        np.testing.assert_allclose(np.asarray(got_w), want_w, rtol=1e-3, atol=1e-4)
        assert float(got_t) == float(want_t)


class TestPegasosEval:
    @pytest.mark.parametrize("block,dim", [(4, 3), (16, 54), (7, 9)])
    @pytest.mark.parametrize("mask_kind", ["full", "mixed", "empty"])
    def test_matches_ref(self, block, dim, mask_kind):
        rng = np.random.default_rng(block + dim)
        w, x, y, mask = make_case(rng, block, dim, mask_kind)
        got = pegasos_k.pegasos_eval(w, x, y, mask, block=block, dim=dim)
        want = ref.pegasos_eval_ref(w, x, y, mask)
        assert float(got) == pytest.approx(float(want), abs=1e-6)

    def test_tie_predicts_positive(self):
        # score exactly 0 → predict +1 (matches the Rust learner).
        w = np.zeros(3, dtype=np.float32)
        x = np.ones((2, 3), dtype=np.float32)
        y = np.array([1.0, -1.0], dtype=np.float32)
        mask = np.ones(2, dtype=np.float32)
        got = pegasos_k.pegasos_eval(w, x, y, mask, block=2, dim=3)
        assert float(got) == 1.0  # only the −1 row is wrong


class TestLsqsgdUpdate:
    @pytest.mark.parametrize("block,dim", [(4, 3), (8, 6), (16, 90)])
    @pytest.mark.parametrize("mask_kind", ["full", "mixed", "empty"])
    def test_matches_ref(self, block, dim, mask_kind):
        rng = np.random.default_rng(block * 7 + dim)
        w, x, y, mask = make_case(rng, block, dim, mask_kind)
        y = rng.random(block).astype(np.float32)  # regression targets in [0,1]
        wavg = (0.05 * rng.normal(size=dim)).astype(np.float32)
        t0, alpha = np.float32(9.0), np.float32(0.05)
        got = lsqsgd_k.lsqsgd_update(w, wavg, t0, alpha, x, y, mask, block=block, dim=dim)
        want = ref.lsqsgd_update_ref(w, wavg, t0, alpha, x, y, mask)
        np.testing.assert_allclose(np.asarray(got[0]), want[0], rtol=RTOL, atol=ATOL)
        np.testing.assert_allclose(np.asarray(got[1]), want[1], rtol=RTOL, atol=ATOL)
        assert float(got[2]) == float(want[2])

    def test_projection_keeps_unit_ball(self):
        rng = np.random.default_rng(11)
        block, dim = 16, 6
        w, x, y, mask = make_case(rng, block, dim, "full")
        y = (10.0 * rng.random(block)).astype(np.float32)  # big targets force steps
        wavg = np.zeros(dim, dtype=np.float32)
        got_w, _, _ = lsqsgd_k.lsqsgd_update(
            w, wavg, np.float32(0.0), np.float32(0.9), x, y, mask, block=block, dim=dim
        )
        assert float(np.linalg.norm(np.asarray(got_w))) <= 1.0 + 1e-5

    @settings(max_examples=25, deadline=None)
    @given(
        block=st.integers(2, 10),
        dim=st.integers(1, 12),
        seed=st.integers(0, 2**32 - 1),
        alpha=st.floats(1e-3, 0.5),
    )
    def test_hypothesis_sweep(self, block, dim, seed, alpha):
        rng = np.random.default_rng(seed)
        w, x, y, mask = make_case(rng, block, dim)
        y = rng.random(block).astype(np.float32)
        wavg = (0.05 * rng.normal(size=dim)).astype(np.float32)
        got = lsqsgd_k.lsqsgd_update(
            w, wavg, np.float32(2.0), np.float32(alpha), x, y, mask, block=block, dim=dim
        )
        want = ref.lsqsgd_update_ref(w, wavg, np.float32(2.0), np.float32(alpha), x, y, mask)
        np.testing.assert_allclose(np.asarray(got[0]), want[0], rtol=1e-3, atol=1e-4)
        np.testing.assert_allclose(np.asarray(got[1]), want[1], rtol=1e-3, atol=1e-4)


class TestLsqsgdEval:
    @pytest.mark.parametrize("block,dim", [(4, 3), (16, 90), (9, 5)])
    def test_matches_ref(self, block, dim):
        rng = np.random.default_rng(block * 13 + dim)
        w, x, _, mask = make_case(rng, block, dim)
        y = rng.random(block).astype(np.float32)
        got = lsqsgd_k.lsqsgd_eval(w, x, y, mask, block=block, dim=dim)
        want = ref.lsqsgd_eval_ref(w, x, y, mask)
        assert float(got) == pytest.approx(float(want), rel=1e-5, abs=1e-6)

    def test_empty_mask_is_zero(self):
        dim, block = 4, 6
        w = np.ones(dim, dtype=np.float32)
        x = np.ones((block, dim), dtype=np.float32)
        y = np.zeros(block, dtype=np.float32)
        mask = np.zeros(block, dtype=np.float32)
        got = lsqsgd_k.lsqsgd_eval(w, x, y, mask, block=block, dim=dim)
        assert float(got) == 0.0
