//! Performance measures (the paper's `ℓ(p, x, y)`).
//!
//! The paper's setting scores a prediction `p` for a pair `(x, y)` with an
//! arbitrary loss `ℓ : P × X × Y → R` (its Table 1). These are the concrete
//! instantiations used by the learners and the CV engines. They are free
//! functions (not a trait) because each learner's `loss` method picks the
//! measure the paper pairs with it — PEGASOS reports misclassification,
//! LSQSGD squared error, K-means quantization error, density estimation
//! negative log-likelihood.

/// 0/1 misclassification: `I{sign(score) != y}` with ties predicted as +1.
#[inline(always)]
pub fn misclassification(score: f32, y: f32) -> f64 {
    let pred = if score >= 0.0 { 1.0 } else { -1.0 };
    if pred == y {
        0.0
    } else {
        1.0
    }
}

/// Hinge loss `max(0, 1 - y·score)` (PEGASOS's surrogate objective; the
/// stability guarantee of the paper's Thm 2 is w.r.t. this loss).
#[inline(always)]
pub fn hinge(score: f32, y: f32) -> f64 {
    (1.0 - (y * score) as f64).max(0.0)
}

/// Regularized hinge: `max(0, 1 - y·score) + (λ/2)·||w||²`.
#[inline(always)]
pub fn regularized_hinge(score: f32, y: f32, lambda: f64, w_norm_sq: f64) -> f64 {
    hinge(score, y) + 0.5 * lambda * w_norm_sq
}

/// Squared error `(pred - y)²`.
#[inline(always)]
pub fn squared_error(pred: f32, y: f32) -> f64 {
    let e = (pred - y) as f64;
    e * e
}

/// K-means quantization error `||x - c||²` for the assigned center `c`.
#[inline(always)]
pub fn quantization_error(x: &[f32], c: &[f32]) -> f64 {
    debug_assert_eq!(x.len(), c.len());
    let mut s = 0f64;
    for (a, b) in x.iter().zip(c) {
        let dv = (a - b) as f64;
        s += dv * dv;
    }
    s
}

/// Negative log-likelihood `-log f(x)` for density estimation, clamped to
/// avoid `inf` when the model assigns (numerically) zero mass.
#[inline(always)]
pub fn negative_log_likelihood(density: f64) -> f64 {
    -(density.max(1e-300)).ln()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn misclassification_basic() {
        assert_eq!(misclassification(0.7, 1.0), 0.0);
        assert_eq!(misclassification(-0.7, 1.0), 1.0);
        assert_eq!(misclassification(-0.2, -1.0), 0.0);
        // Ties predict +1.
        assert_eq!(misclassification(0.0, 1.0), 0.0);
        assert_eq!(misclassification(0.0, -1.0), 1.0);
    }

    #[test]
    fn hinge_basic() {
        assert_eq!(hinge(1.0, 1.0), 0.0);
        assert_eq!(hinge(2.0, 1.0), 0.0);
        assert!((hinge(0.5, 1.0) - 0.5).abs() < 1e-12);
        assert!((hinge(-1.0, 1.0) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn regularized_hinge_adds_penalty() {
        let base = hinge(0.5, 1.0);
        let reg = regularized_hinge(0.5, 1.0, 0.1, 4.0);
        assert!((reg - (base + 0.2)).abs() < 1e-12);
    }

    #[test]
    fn squared_error_basic() {
        assert_eq!(squared_error(3.0, 1.0), 4.0);
        assert_eq!(squared_error(1.0, 1.0), 0.0);
    }

    #[test]
    fn quantization_error_basic() {
        assert!((quantization_error(&[1.0, 2.0], &[0.0, 0.0]) - 5.0).abs() < 1e-12);
        assert_eq!(quantization_error(&[1.0], &[1.0]), 0.0);
    }

    #[test]
    fn nll_clamps_zero_density() {
        assert!(negative_log_likelihood(0.0).is_finite());
        assert!((negative_log_likelihood(1.0)).abs() < 1e-12);
        assert!(negative_log_likelihood(0.1) > 0.0);
    }
}
