//! Minimal JSON emission for reports.
//!
//! The offline build environment vendors no serde facade, so the library
//! carries its own small JSON value model + writer. Reports (cells, bench
//! rows, distributed stats) convert to [`Json`] and render; there is no
//! parser because nothing in the system consumes JSON (the artifact
//! manifest uses a line format precisely to keep it that way).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value (sufficient subset; maps are ordered for stable output).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num<T: Into<f64>>(v: T) -> Json {
        Json::Num(v.into())
    }

    pub fn str<S: Into<String>>(s: S) -> Json {
        Json::Str(s.into())
    }

    /// Render compactly.
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Render with 2-space indentation.
    pub fn render_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(v) => {
                if v.is_finite() {
                    if *v == v.trunc() && v.abs() < 9.0e15 {
                        let _ = write!(out, "{}", *v as i64);
                    } else {
                        let _ = write!(out, "{v}");
                    }
                } else {
                    out.push_str("null"); // JSON has no inf/nan
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Self::newline(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    Self::newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Self::newline(out, indent, depth + 1);
                    Json::Str(k.clone()).write(out, None, 0);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    Self::newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }

    fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
        if let Some(w) = indent {
            out.push('\n');
            for _ in 0..w * depth {
                out.push(' ');
            }
        }
    }
}

/// Types that can report themselves as JSON.
pub trait ToJson {
    fn to_json(&self) -> Json;
}

impl ToJson for crate::metrics::OpCounts {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("update_calls", Json::num(self.update_calls as f64)),
            ("points_updated", Json::num(self.points_updated as f64)),
            ("model_copies", Json::num(self.model_copies as f64)),
            ("bytes_copied", Json::num(self.bytes_copied as f64)),
            ("model_restores", Json::num(self.model_restores as f64)),
            ("evals", Json::num(self.evals as f64)),
            ("points_evaluated", Json::num(self.points_evaluated as f64)),
            ("points_permuted", Json::num(self.points_permuted as f64)),
            ("stream_allocs", Json::num(self.stream_allocs as f64)),
            ("subtrees_recomputed", Json::num(self.subtrees_recomputed as f64)),
            ("corrections", Json::num(self.corrections as f64)),
            ("exact_gap_max", Json::Num(self.exact_gap_max)),
            ("kernel_backend", Json::str(self.kernel_backend)),
        ])
    }
}

impl ToJson for crate::coordinator::SweepReport {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("task", Json::str(self.task.name())),
            ("n", Json::num(self.n as f64)),
            ("k", Json::num(self.k as f64)),
            ("repetitions", Json::num(self.repetitions as f64)),
            ("threads", Json::num(self.threads as f64)),
            ("pool_spawns", Json::num(self.pool_spawns as f64)),
            ("total_wall_secs", Json::Num(self.total_wall_secs)),
            (
                "points",
                Json::Arr(
                    self.points
                        .iter()
                        .map(|p| {
                            Json::obj(vec![
                                ("param", Json::str(p.param.clone())),
                                ("value", Json::Num(p.value)),
                                ("strategy", Json::str(p.strategy.name())),
                                ("mean", Json::Num(p.mean)),
                                ("std", Json::Num(p.std)),
                                ("ops", p.ops.to_json()),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

impl ToJson for crate::coordinator::RaceReport {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("task", Json::str(self.task.name())),
            ("n", Json::num(self.n as f64)),
            ("k", Json::num(self.k as f64)),
            ("repetitions", Json::num(self.repetitions as f64)),
            ("rounds", Json::num(self.rounds as f64)),
            ("alpha", Json::Num(self.alpha)),
            ("threads", Json::num(self.threads as f64)),
            ("pool_spawns", Json::num(self.pool_spawns as f64)),
            ("total_wall_secs", Json::Num(self.total_wall_secs)),
            ("runs_scheduled", Json::num(self.runs_scheduled as f64)),
            ("runs_completed", Json::num(self.runs_completed as f64)),
            ("runs_cancelled", Json::num(self.runs_cancelled as f64)),
            ("tree_tasks_cancelled", Json::num(self.tree_tasks_cancelled as f64)),
            (
                "points",
                Json::Arr(
                    self.points
                        .iter()
                        .map(|p| {
                            Json::obj(vec![
                                ("param", Json::str(p.param.clone())),
                                ("value", Json::Num(p.value)),
                                ("strategy", Json::str(p.strategy.name())),
                                ("mean", Json::Num(p.mean)),
                                ("std", Json::Num(p.std)),
                                ("reps_used", Json::num(p.reps_used as f64)),
                                (
                                    "eliminated_round",
                                    match p.eliminated_round {
                                        Some(r) => Json::num(r as f64),
                                        None => Json::Null,
                                    },
                                ),
                                ("ops", p.ops.to_json()),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "trace",
                Json::Arr(
                    self.trace
                        .iter()
                        .map(|t| {
                            Json::obj(vec![
                                ("round", Json::num(t.round as f64)),
                                ("reps_used", Json::num(t.reps_used as f64)),
                                ("param", Json::str(t.param.clone())),
                                ("value", Json::Num(t.value)),
                                ("strategy", Json::str(t.strategy.name())),
                                ("mean", Json::Num(t.mean)),
                                ("wins", Json::num(t.wins as f64)),
                                ("n_eff", Json::num(t.n_eff as f64)),
                                ("p_value", Json::Num(t.p_value)),
                                ("eliminated", Json::Bool(t.eliminated)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

impl ToJson for crate::coordinator::SelectReport {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("n", Json::num(self.n as f64)),
            ("k", Json::num(self.k as f64)),
            ("repetitions", Json::num(self.repetitions as f64)),
            ("threads", Json::num(self.threads as f64)),
            ("pool_spawns", Json::num(self.pool_spawns as f64)),
            ("total_wall_secs", Json::Num(self.total_wall_secs)),
            (
                "points",
                Json::Arr(
                    self.points
                        .iter()
                        .map(|p| {
                            Json::obj(vec![
                                ("learner", Json::str(p.learner.clone())),
                                ("task", Json::str(p.task.name())),
                                ("strategy", Json::str(p.strategy.name())),
                                ("mean", Json::Num(p.mean)),
                                ("std", Json::Num(p.std)),
                                ("ops", p.ops.to_json()),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

impl ToJson for crate::coordinator::ServeReport {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("task", Json::str(self.task.name())),
            ("k", Json::num(self.k as f64)),
            ("n_final", Json::num(self.n_final as f64)),
            ("threads", Json::num(self.threads as f64)),
            ("rows_ingested", Json::num(self.rows_ingested as f64)),
            ("rows_retired", Json::num(self.rows_retired as f64)),
            ("batches_applied", Json::num(self.batches_applied as f64)),
            ("refreshes", Json::num(self.refreshes as f64)),
            ("primes", Json::num(self.primes as f64)),
            ("queries", Json::num(self.queries as f64)),
            ("stale_queries", Json::num(self.stale_queries as f64)),
            ("mean_pending_at_query", Json::Num(self.mean_pending_at_query)),
            ("max_pending_at_query", Json::num(self.max_pending_at_query as f64)),
            ("subtrees_recomputed", Json::num(self.subtrees_recomputed as f64)),
            ("refresh_wall_secs", Json::Num(self.refresh_wall_secs)),
            ("prime_wall_secs", Json::Num(self.prime_wall_secs)),
            ("total_wall_secs", Json::Num(self.total_wall_secs)),
            ("rows_per_sec", Json::Num(self.rows_per_sec)),
            ("estimate", Json::Num(self.estimate)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_scalars() {
        assert_eq!(Json::Null.render(), "null");
        assert_eq!(Json::Bool(true).render(), "true");
        assert_eq!(Json::num(3.0).render(), "3");
        assert_eq!(Json::num(3.5).render(), "3.5");
        assert_eq!(Json::Num(f64::NAN).render(), "null");
    }

    #[test]
    fn escapes_strings() {
        assert_eq!(Json::str("a\"b\\c\nd").render(), r#""a\"b\\c\nd""#);
        assert_eq!(Json::str("\u{1}").render(), "\"\\u0001\"");
    }

    #[test]
    fn renders_nested() {
        let j = Json::obj(vec![
            ("k", Json::num(5.0)),
            ("name", Json::str("treecv")),
            ("folds", Json::Arr(vec![Json::num(1.0), Json::num(2.0)])),
        ]);
        assert_eq!(j.render(), r#"{"folds":[1,2],"k":5,"name":"treecv"}"#);
    }

    #[test]
    fn pretty_has_newlines() {
        let j = Json::obj(vec![("a", Json::num(1.0))]);
        let p = j.render_pretty();
        assert!(p.contains('\n'));
        assert!(p.contains("\"a\": 1"));
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::Arr(vec![]).render(), "[]");
        assert_eq!(Json::Obj(Default::default()).render(), "{}");
    }
}
