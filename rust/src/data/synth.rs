//! Synthetic stand-ins for the paper's UCI workloads.
//!
//! The paper evaluates on UCI Covertype (581,012 × 54, class "1" vs rest,
//! features scaled to unit variance) and UCI YearPredictionMSD
//! (463,715 × 90, targets scaled to [0,1]). Those files are not available
//! in this environment, so per the substitution policy (DESIGN.md §4) we
//! generate synthetic datasets that match the quantities the experiments
//! actually depend on: `n`, `d`, feature scaling, label balance, and the
//! achievable loss level of the linear models under test. If the real
//! files are present, [`super::libsvm`] loads them instead.

use super::Dataset;
use crate::rng::Rng;

/// Covertype-like binary classification: 54 unit-variance features, labels
/// from a noisy linear teacher tuned so linear-SVM misclassification lands
/// near the paper's ≈30.6% (Table 2 top).
#[derive(Debug, Clone)]
pub struct SyntheticCovertype {
    pub n: usize,
    pub seed: u64,
    /// Label-flip probability. Together with the positive-side flips below
    /// this is tuned so single-pass PEGASOS at the paper's λ = 10⁻⁶ lands
    /// near the paper's ≈30.6% (Table 2 top) at paper-scale n — measured
    /// ≈33.5% at n = 100k on this generator.
    pub noise: f64,
}

impl SyntheticCovertype {
    pub const D: usize = 54;

    pub fn new(n: usize, seed: u64) -> Self {
        Self { n, seed, noise: 0.15 }
    }

    /// Generate the dataset. Deterministic in `(n, seed, noise)`; a longer
    /// generation is a strict prefix-extension of a shorter one only in
    /// distribution, so `n`-sweeps should generate once at max `n` and
    /// [`Dataset::take`] prefixes.
    pub fn generate(&self) -> Dataset {
        let d = Self::D;
        let mut rng = Rng::derive(self.seed, 0xC0FE);
        // Fixed random teacher hyperplane.
        let mut teacher = Rng::derive(self.seed, 0x7EAC);
        let w: Vec<f32> = (0..d).map(|_| teacher.next_gaussian()).collect();
        let wn = (w.iter().map(|v| (v * v) as f64).sum::<f64>()).sqrt() as f32;

        let mut x = Vec::with_capacity(self.n * d);
        let mut y = Vec::with_capacity(self.n);
        for _ in 0..self.n {
            let start = x.len();
            let mut dot = 0f32;
            for j in 0..d {
                let v = rng.next_gaussian();
                x.push(v);
                dot += v * w[j];
            }
            let _ = start;
            let mut label = if dot / wn >= 0.0 { 1.0 } else { -1.0 };
            if rng.next_f64() < self.noise {
                label = -label;
            }
            // Covertype class "1" vs rest is imbalanced (≈36.5% positive);
            // bias the kept labels toward that ratio by flipping a slice of
            // positives (keeps the linear structure).
            if label > 0.0 && rng.next_f64() < 0.08 {
                label = -1.0;
            }
            y.push(label);
        }
        Dataset::new(x, y, d)
    }
}

/// YearPredictionMSD-like regression: 90 unit-variance features, targets in
/// [0, 1] from a bounded linear teacher plus noise. With unit-ball
/// constrained LSQSGD this yields a squared-error plateau in the same
/// regime as the paper's ≈0.253 (Table 2 bottom is ×100).
#[derive(Debug, Clone)]
pub struct SyntheticYearMsd {
    pub n: usize,
    pub seed: u64,
    /// Additive target noise std (pre-clipping).
    pub noise_std: f64,
}

impl SyntheticYearMsd {
    pub const D: usize = 90;

    pub fn new(n: usize, seed: u64) -> Self {
        Self { n, seed, noise_std: 0.40 }
    }

    pub fn generate(&self) -> Dataset {
        let d = Self::D;
        let mut rng = Rng::derive(self.seed, 0x5EED);
        let mut teacher = Rng::derive(self.seed, 0x7EAC2);
        // Teacher inside the unit ball so the constrained learner can
        // express it; signal-to-noise tuned so the squared-error plateau
        // lands in the paper's regime while remaining clearly learnable.
        let mut w: Vec<f32> = (0..d).map(|_| teacher.next_gaussian()).collect();
        let wn = (w.iter().map(|v| (v * v) as f64).sum::<f64>()).sqrt() as f32;
        for v in w.iter_mut() {
            *v *= 0.30 / wn;
        }

        let mut x = Vec::with_capacity(self.n * d);
        let mut y = Vec::with_capacity(self.n);
        for _ in 0..self.n {
            let mut dot = 0f32;
            for wj in w.iter().take(d) {
                let v = rng.next_gaussian();
                x.push(v);
                dot += v * wj;
            }
            let t = 0.5 + dot as f64 + self.noise_std * rng.next_gaussian() as f64;
            y.push(t.clamp(0.0, 1.0) as f32);
        }
        Dataset::new(x, y, d)
    }
}

/// Isotropic Gaussian blobs for the K-means instantiation of the paper's
/// Table 1 (unsupervised; `y` is all zeros = NoLabel).
#[derive(Debug, Clone)]
pub struct SyntheticBlobs {
    pub n: usize,
    pub d: usize,
    pub centers: usize,
    pub spread: f32,
    pub seed: u64,
}

impl SyntheticBlobs {
    pub fn new(n: usize, d: usize, centers: usize, seed: u64) -> Self {
        Self { n, d, centers, spread: 0.3, seed }
    }

    pub fn generate(&self) -> Dataset {
        let mut rng = Rng::derive(self.seed, 0xB10B);
        let mut cgen = Rng::derive(self.seed, 0xCE27);
        let centers: Vec<Vec<f32>> = (0..self.centers)
            .map(|_| (0..self.d).map(|_| 2.0 * cgen.next_gaussian()).collect())
            .collect();
        let mut x = Vec::with_capacity(self.n * self.d);
        let y = vec![0f32; self.n];
        for _ in 0..self.n {
            let c = &centers[rng.below(self.centers as u64) as usize];
            for &cj in c.iter() {
                x.push(cj + self.spread * rng.next_gaussian());
            }
        }
        Dataset::new(x, y, self.d)
    }
}

/// 1-D Gaussian-mixture samples for the density-estimation instantiation of
/// Table 1 (loss = negative log-likelihood).
#[derive(Debug, Clone)]
pub struct SyntheticMixture1d {
    pub n: usize,
    pub seed: u64,
}

impl SyntheticMixture1d {
    pub fn new(n: usize, seed: u64) -> Self {
        Self { n, seed }
    }

    pub fn generate(&self) -> Dataset {
        let mut rng = Rng::derive(self.seed, 0xD157);
        let mut x = Vec::with_capacity(self.n);
        for _ in 0..self.n {
            let v = if rng.next_f64() < 0.5 {
                -2.0 + 0.7 * rng.next_gaussian()
            } else {
                1.5 + 1.1 * rng.next_gaussian()
            };
            x.push(v);
        }
        Dataset::new(x, vec![0f32; self.n], 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn covertype_shape_and_determinism() {
        let a = SyntheticCovertype::new(500, 1).generate();
        let b = SyntheticCovertype::new(500, 1).generate();
        assert_eq!(a.n, 500);
        assert_eq!(a.d, 54);
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
    }

    #[test]
    fn covertype_seed_changes_data() {
        let a = SyntheticCovertype::new(100, 1).generate();
        let b = SyntheticCovertype::new(100, 2).generate();
        assert_ne!(a.x, b.x);
    }

    #[test]
    fn covertype_labels_are_binary_and_imbalanced() {
        let d = SyntheticCovertype::new(20_000, 3).generate();
        let pos = d.y.iter().filter(|&&v| v == 1.0).count() as f64 / d.n as f64;
        assert!(d.y.iter().all(|&v| v == 1.0 || v == -1.0));
        assert!(pos > 0.25 && pos < 0.5, "positive ratio {pos}");
    }

    #[test]
    fn covertype_features_near_unit_variance() {
        let d = SyntheticCovertype::new(20_000, 4).generate();
        let mut var = 0f64;
        for i in 0..d.n {
            var += (d.x[i * d.d] as f64).powi(2);
        }
        var /= d.n as f64;
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn yearmsd_targets_in_unit_interval() {
        let d = SyntheticYearMsd::new(5_000, 5).generate();
        assert_eq!(d.d, 90);
        assert!(d.y.iter().all(|&v| (0.0..=1.0).contains(&v)));
        let mean = d.y.iter().map(|&v| v as f64).sum::<f64>() / d.n as f64;
        assert!((mean - 0.5).abs() < 0.05, "target mean {mean}");
    }

    #[test]
    fn blobs_cluster_structure() {
        let g = SyntheticBlobs::new(2_000, 4, 3, 6);
        let d = g.generate();
        assert_eq!(d.n, 2_000);
        // Spread within a blob (0.3) is much smaller than between centers
        // (~2σ per coord); overall variance must exceed within-blob variance.
        let mut var = 0f64;
        let mut mean = 0f64;
        for i in 0..d.n {
            mean += d.x[i * d.d] as f64;
        }
        mean /= d.n as f64;
        for i in 0..d.n {
            var += (d.x[i * d.d] as f64 - mean).powi(2);
        }
        var /= d.n as f64;
        assert!(var > 0.5, "var {var}");
    }

    #[test]
    fn mixture_is_bimodalish() {
        let d = SyntheticMixture1d::new(10_000, 7).generate();
        let lo = d.x.iter().filter(|&&v| v < -0.5).count();
        let hi = d.x.iter().filter(|&&v| v > 0.5).count();
        assert!(lo > 2_000 && hi > 2_000);
    }
}
