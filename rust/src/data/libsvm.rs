//! LIBSVM / SVMlight text-format parser.
//!
//! The paper's datasets (Covertype, YearPredictionMSD) are distributed in
//! this format on the LIBSVM site. When a real file is available on disk,
//! experiments load it here instead of using the synthetic stand-ins; the
//! parser handles the 1-based sparse `idx:val` encoding and densifies.

use super::Dataset;
use crate::Result;
use anyhow::{anyhow, bail, Context};
use std::io::{BufRead, BufReader, Read};
use std::path::Path;

/// Parse LIBSVM-format text from any reader into a dense [`Dataset`].
///
/// * `d` — feature dimension; pass `None` to infer from the max index seen
///   (requires buffering all rows, which we do anyway).
/// * `binarize_label` — if `Some(c)`, labels equal to `c` map to `+1` and
///   everything else to `-1` (the paper's "class 1 against the rest").
pub fn parse<R: Read>(reader: R, d: Option<usize>, binarize_label: Option<f32>) -> Result<Dataset> {
    let reader = BufReader::new(reader);
    let mut rows: Vec<(f32, Vec<(usize, f32)>)> = Vec::new();
    let mut max_idx = 0usize;

    for (lineno, line) in reader.lines().enumerate() {
        let line = line.context("I/O error reading libsvm data")?;
        let line = line.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let mut parts = line.split_ascii_whitespace();
        let label: f32 = parts
            .next()
            .ok_or_else(|| anyhow!("line {}: empty", lineno + 1))?
            .parse()
            .map_err(|e| anyhow!("line {}: bad label: {e}", lineno + 1))?;
        let mut feats = Vec::new();
        for tok in parts {
            let (idx, val) = tok
                .split_once(':')
                .ok_or_else(|| anyhow!("line {}: token `{tok}` missing `:`", lineno + 1))?;
            let idx: usize =
                idx.parse().map_err(|e| anyhow!("line {}: bad index `{idx}`: {e}", lineno + 1))?;
            if idx == 0 {
                bail!("line {}: libsvm indices are 1-based, got 0", lineno + 1);
            }
            let val: f32 =
                val.parse().map_err(|e| anyhow!("line {}: bad value `{val}`: {e}", lineno + 1))?;
            max_idx = max_idx.max(idx);
            feats.push((idx - 1, val));
        }
        rows.push((label, feats));
    }

    let d = match d {
        Some(d) => {
            if max_idx > d {
                bail!("feature index {max_idx} exceeds declared dimension {d}");
            }
            d
        }
        None => max_idx.max(1),
    };

    let n = rows.len();
    let mut x = vec![0f32; n * d];
    let mut y = Vec::with_capacity(n);
    for (i, (label, feats)) in rows.into_iter().enumerate() {
        y.push(match binarize_label {
            Some(c) => {
                if label == c {
                    1.0
                } else {
                    -1.0
                }
            }
            None => label,
        });
        for (j, v) in feats {
            x[i * d + j] = v;
        }
    }
    Ok(Dataset::new(x, y, d))
}

/// Load a LIBSVM file from disk. See [`parse`].
pub fn load(path: &Path, d: Option<usize>, binarize_label: Option<f32>) -> Result<Dataset> {
    let f = std::fs::File::open(path).with_context(|| format!("opening {}", path.display()))?;
    parse(f, d, binarize_label)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_basic_file() {
        let text = "1 1:0.5 3:1.5\n-1 2:2.0\n";
        let d = parse(text.as_bytes(), None, None).unwrap();
        assert_eq!(d.n, 2);
        assert_eq!(d.d, 3);
        assert_eq!(d.row(0), &[0.5, 0.0, 1.5]);
        assert_eq!(d.row(1), &[0.0, 2.0, 0.0]);
        assert_eq!(d.y, vec![1.0, -1.0]);
    }

    #[test]
    fn binarizes_multiclass() {
        let text = "1 1:1\n2 1:2\n7 1:3\n1 1:4\n";
        let d = parse(text.as_bytes(), None, Some(1.0)).unwrap();
        assert_eq!(d.y, vec![1.0, -1.0, -1.0, 1.0]);
    }

    #[test]
    fn declared_dimension_pads() {
        let text = "0.5 1:1\n";
        let d = parse(text.as_bytes(), Some(5), None).unwrap();
        assert_eq!(d.d, 5);
        assert_eq!(d.row(0), &[1.0, 0.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn skips_comments_and_blank_lines() {
        let text = "# header\n\n1 1:1 # trailing\n";
        let d = parse(text.as_bytes(), None, None).unwrap();
        assert_eq!(d.n, 1);
    }

    #[test]
    fn rejects_zero_index() {
        let text = "1 0:1\n";
        assert!(parse(text.as_bytes(), None, None).is_err());
    }

    #[test]
    fn rejects_index_beyond_declared_d() {
        let text = "1 9:1\n";
        assert!(parse(text.as_bytes(), Some(3), None).is_err());
    }

    #[test]
    fn rejects_malformed_token() {
        assert!(parse("1 abc\n".as_bytes(), None, None).is_err());
        assert!(parse("x 1:1\n".as_bytes(), None, None).is_err());
    }

    #[test]
    fn indices_are_one_based_and_may_be_out_of_order() {
        // LIBSVM indices are 1-based: index 1 lands in column 0. Sparse
        // rows need not list indices in ascending order — real dumps
        // occasionally don't — and densification must not care.
        let text = "1 3:3.0 1:1.0 2:2.0\n-1 2:5.0\n";
        let d = parse(text.as_bytes(), None, None).unwrap();
        assert_eq!(d.d, 3);
        assert_eq!(d.row(0), &[1.0, 2.0, 3.0]);
        assert_eq!(d.row(1), &[0.0, 5.0, 0.0]);
    }

    #[test]
    fn duplicate_index_last_wins() {
        // Not legal LIBSVM strictly speaking, but the parser's write-into-
        // dense semantics make the behavior well-defined: pin it.
        let d = parse("1 1:1.0 1:9.0\n".as_bytes(), None, None).unwrap();
        assert_eq!(d.row(0), &[9.0]);
    }

    #[test]
    fn tolerates_trailing_and_mixed_whitespace() {
        // Trailing spaces/tabs, CRLF line endings, and runs of interior
        // whitespace between tokens must all parse.
        let text = "1 1:0.5 2:1.5   \n-1\t1:2.0\t \r\n  1 \t 2:3.0  \n";
        let d = parse(text.as_bytes(), None, None).unwrap();
        assert_eq!(d.n, 3);
        assert_eq!(d.d, 2);
        assert_eq!(d.row(0), &[0.5, 1.5]);
        assert_eq!(d.row(1), &[2.0, 0.0]);
        assert_eq!(d.row(2), &[0.0, 3.0]);
        assert_eq!(d.y, vec![1.0, -1.0, 1.0]);
    }

    #[test]
    fn label_only_rows_are_valid_and_all_zero() {
        // A row may hold no features at all (all-zero sparse row).
        let d = parse("1\n-1 1:1\n".as_bytes(), None, None).unwrap();
        assert_eq!(d.n, 2);
        assert_eq!(d.row(0), &[0.0]);
    }

    #[test]
    fn malformed_lines_error_with_line_numbers() {
        // Each malformed shape reports the 1-based line it came from.
        for (text, needle) in [
            ("1 1:1\n1 :2\n", "line 2"),          // empty index
            ("1 1:1\n\n1 2:\n", "line 3"),        // empty value (blank line skipped)
            ("1 1:1\n1 x:1\n", "bad index"),      // non-numeric index
            ("1 1:1\n1 2:y\n", "bad value"),      // non-numeric value
            ("1 0:1\n", "1-based"),               // zero index
        ] {
            let err = parse(text.as_bytes(), None, None).unwrap_err();
            assert!(format!("{err}").contains(needle), "`{text}` -> {err}");
        }
    }
}
