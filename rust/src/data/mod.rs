//! Dataset substrate: dense in-memory datasets, the fold-contiguous
//! physical layout the CV engines' hot loops stream over, synthetic
//! generators that stand in for the paper's UCI workloads, and a
//! LIBSVM-format parser so the real files drop in when available.

pub mod dataset;
pub mod folded;
pub mod libsvm;
pub mod synth;

pub use dataset::Dataset;
pub use folded::FoldedDataset;
