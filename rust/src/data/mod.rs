//! Dataset substrate: dense in-memory datasets, synthetic generators that
//! stand in for the paper's UCI workloads, and a LIBSVM-format parser so
//! the real files drop in when available.

pub mod dataset;
pub mod libsvm;
pub mod synth;

pub use dataset::Dataset;
