//! Dense, row-major in-memory dataset.
//!
//! The paper's general setting (its Table 1) is points `z_i = (x_i, y_i)`
//! with `x ∈ R^d` and an outcome `y` that is a class label, a regression
//! target, or `NoLabel` for unsupervised tasks. We store `x` densely
//! (`n × d`, row-major `f32`) and `y` as `f32` (±1 for binary labels,
//! real-valued targets, or ignored by unsupervised learners).

use crate::rng::Rng;

/// A dense supervised/unsupervised dataset.
#[derive(Debug, Clone)]
pub struct Dataset {
    /// Row-major features, length `n * d`.
    pub x: Vec<f32>,
    /// Outcomes, length `n`. For unsupervised tasks this is all zeros.
    pub y: Vec<f32>,
    /// Number of points.
    pub n: usize,
    /// Feature dimension.
    pub d: usize,
}

impl Dataset {
    /// Build from parts, checking shape consistency.
    pub fn new(x: Vec<f32>, y: Vec<f32>, d: usize) -> Self {
        assert!(d > 0, "feature dimension must be positive");
        assert_eq!(x.len() % d, 0, "x length {} not a multiple of d {}", x.len(), d);
        let n = x.len() / d;
        assert_eq!(y.len(), n, "y length {} != n {}", y.len(), n);
        Self { x, y, n, d }
    }

    /// Feature row `i`.
    #[inline(always)]
    pub fn row(&self, i: u32) -> &[f32] {
        let i = i as usize;
        &self.x[i * self.d..(i + 1) * self.d]
    }

    /// Outcome of point `i`.
    #[inline(always)]
    pub fn label(&self, i: u32) -> f32 {
        self.y[i as usize]
    }

    /// A subset of the dataset (copies rows; used by tests and the
    /// distributed simulation where chunks live on different nodes).
    pub fn subset(&self, idx: &[u32]) -> Dataset {
        let mut x = Vec::with_capacity(idx.len() * self.d);
        let mut y = Vec::with_capacity(idx.len());
        for &i in idx {
            x.extend_from_slice(self.row(i));
            y.push(self.label(i));
        }
        Dataset::new(x, y, self.d)
    }

    /// Truncate to the first `n` points (used by the Figure-2 `n`-sweeps so
    /// all sweep points share one generated dataset, as in the paper).
    pub fn take(&self, n: usize) -> Dataset {
        assert!(n <= self.n);
        Dataset::new(self.x[..n * self.d].to_vec(), self.y[..n].to_vec(), self.d)
    }

    /// Append rows in place (`x` row-major `b × d`, `y` length `b`) —
    /// streaming arrivals get ids `n..n+b`. The streaming service keeps
    /// this original dataset in lock-step with its
    /// [`crate::data::folded::FoldedDataset::append_rows`] window.
    pub fn push_rows(&mut self, x: &[f32], y: &[f32]) {
        assert_eq!(x.len() % self.d, 0, "x length {} not a multiple of d {}", x.len(), self.d);
        assert_eq!(y.len(), x.len() / self.d, "y length {} != row count", y.len());
        self.x.extend_from_slice(x);
        self.y.extend_from_slice(y);
        self.n += y.len();
    }

    /// Drop the first `count` rows in place (sliding-window retirement);
    /// surviving rows shift down by `count`, mirroring
    /// [`crate::data::folded::FoldedDataset::retire_oldest`].
    pub fn retire_front(&mut self, count: usize) {
        assert!(count <= self.n, "retire_front({count}) exceeds n = {}", self.n);
        self.x.drain(..count * self.d);
        self.y.drain(..count);
        self.n -= count;
    }

    /// Scale every feature column to unit variance (the paper does this for
    /// Covertype). Returns the per-column scale factors applied.
    pub fn scale_to_unit_variance(&mut self) -> Vec<f32> {
        let (n, d) = (self.n, self.d);
        let mut mean = vec![0f64; d];
        let mut m2 = vec![0f64; d];
        for i in 0..n {
            for j in 0..d {
                mean[j] += self.x[i * d + j] as f64;
            }
        }
        for m in mean.iter_mut() {
            *m /= n as f64;
        }
        for i in 0..n {
            for j in 0..d {
                let dv = self.x[i * d + j] as f64 - mean[j];
                m2[j] += dv * dv;
            }
        }
        let mut scales = vec![1f32; d];
        for j in 0..d {
            let var = m2[j] / n as f64;
            if var > 1e-12 {
                scales[j] = (1.0 / var.sqrt()) as f32;
            }
        }
        for i in 0..n {
            for j in 0..d {
                self.x[i * d + j] *= scales[j];
            }
        }
        scales
    }

    /// Min-max scale the targets to [0, 1] (the paper does this for
    /// YearPredictionMSD).
    pub fn scale_targets_to_unit_interval(&mut self) {
        let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
        for &v in &self.y {
            lo = lo.min(v);
            hi = hi.max(v);
        }
        let span = (hi - lo).max(1e-12);
        for v in self.y.iter_mut() {
            *v = (*v - lo) / span;
        }
    }

    /// Shuffle the dataset rows in place (paper: datasets are shuffled once
    /// before fold assignment).
    pub fn shuffle(&mut self, rng: &mut Rng) {
        let perm = rng.permutation(self.n);
        let mut x = Vec::with_capacity(self.x.len());
        let mut y = Vec::with_capacity(self.n);
        for &i in &perm {
            x.extend_from_slice(self.row(i));
            y.push(self.label(i));
        }
        self.x = x;
        self.y = y;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Dataset {
        Dataset::new(vec![1., 2., 3., 4., 5., 6.], vec![1., -1., 1.], 2)
    }

    #[test]
    fn shape_accessors() {
        let d = toy();
        assert_eq!(d.n, 3);
        assert_eq!(d.d, 2);
        assert_eq!(d.row(1), &[3., 4.]);
        assert_eq!(d.label(2), 1.0);
    }

    #[test]
    #[should_panic]
    fn bad_shape_panics() {
        Dataset::new(vec![1., 2., 3.], vec![1.], 2);
    }

    #[test]
    fn subset_copies_rows() {
        let d = toy();
        let s = d.subset(&[2, 0]);
        assert_eq!(s.n, 2);
        assert_eq!(s.row(0), &[5., 6.]);
        assert_eq!(s.row(1), &[1., 2.]);
        assert_eq!(s.y, vec![1., 1.]);
    }

    #[test]
    fn take_prefix() {
        let d = toy();
        let t = d.take(2);
        assert_eq!(t.n, 2);
        assert_eq!(t.x, vec![1., 2., 3., 4.]);
    }

    #[test]
    fn push_rows_appends_and_retire_front_shifts() {
        let mut d = toy();
        d.push_rows(&[7., 8., 9., 10.], &[-1., 1.]);
        assert_eq!(d.n, 5);
        assert_eq!(d.row(3), &[7., 8.]);
        assert_eq!(d.label(4), 1.0);
        d.retire_front(2);
        assert_eq!(d.n, 3);
        assert_eq!(d.row(0), &[5., 6.]);
        assert_eq!(d.y, vec![1., -1., 1.]);
    }

    #[test]
    #[should_panic(expected = "not a multiple")]
    fn push_rows_rejects_ragged_x() {
        let mut d = toy();
        d.push_rows(&[7., 8., 9.], &[1.]);
    }

    #[test]
    #[should_panic(expected = "exceeds n")]
    fn retire_front_rejects_overdrain() {
        let mut d = toy();
        d.retire_front(4);
    }

    #[test]
    fn unit_variance_scaling() {
        let mut d = Dataset::new(
            vec![0., 10., 1., 20., 2., 30., 3., 40.],
            vec![0.; 4],
            2,
        );
        d.scale_to_unit_variance();
        for j in 0..2 {
            let mean: f64 = (0..4).map(|i| d.x[i * 2 + j] as f64).sum::<f64>() / 4.0;
            let var: f64 =
                (0..4).map(|i| (d.x[i * 2 + j] as f64 - mean).powi(2)).sum::<f64>() / 4.0;
            assert!((var - 1.0).abs() < 1e-5, "col {j} var {var}");
        }
    }

    #[test]
    fn target_scaling() {
        let mut d = Dataset::new(vec![0.; 8], vec![-5., 0., 5., 15.], 2);
        d.scale_targets_to_unit_interval();
        assert_eq!(d.y[0], 0.0);
        assert_eq!(d.y[3], 1.0);
        assert!((d.y[1] - 0.25).abs() < 1e-6);
    }

    #[test]
    fn shuffle_preserves_rows() {
        let mut d = toy();
        let mut rng = Rng::new(1);
        let before: Vec<(Vec<f32>, f32)> =
            (0..3).map(|i| (d.row(i).to_vec(), d.label(i))).collect();
        d.shuffle(&mut rng);
        let mut after: Vec<(Vec<f32>, f32)> =
            (0..3).map(|i| (d.row(i).to_vec(), d.label(i))).collect();
        for b in &before {
            let pos = after.iter().position(|a| a == b).expect("row lost");
            after.remove(pos);
        }
        assert!(after.is_empty());
    }
}
