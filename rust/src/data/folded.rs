//! Fold-contiguous physical data layout.
//!
//! The CV engines stream *chunk groups* into incremental learners: every
//! TreeCV node feeds the concatenation of chunks `Z_lo..Z_hi`, and every
//! standard-CV repetition feeds "all chunks but one". With the logical
//! [`Folds`] partition alone, each of those streams is a fresh `Vec<u32>`
//! of row indices scattered across the whole `n × d` matrix — Θ(k log k)
//! transient allocations per TreeCV run and a random-access pattern the
//! hardware prefetcher cannot help with.
//!
//! [`FoldedDataset`] fixes the *physical* side once per run: it permutes
//! the dataset's rows so that each fold chunk occupies one contiguous row
//! range (chunks in fold order, rows in chunk order). Every hierarchical
//! chunk group `lo..=hi` — and standard CV's "all but fold i", which
//! becomes exactly two such groups — is then a contiguous slice of the
//! permuted `x`/`y` storage, which the engines feed straight into the
//! learners' contiguous fast paths
//! ([`crate::learner::IncrementalLearner::update_rows`] /
//! [`crate::learner::IncrementalLearner::evaluate_rows`]) with **no
//! index vector at all**.
//!
//! The layout changes *where rows live*, never *which points are fed in
//! which order*:
//!
//! * The permutation concatenates `folds.chunk(0..k)` in order, so the
//!   contiguous block of chunks `lo..=hi` lists the same points in the
//!   same order as [`Folds::gather_range`].
//! * The forward map [`FoldedDataset::ids`] exposes the **original**
//!   dataset indices of any block as a contiguous `&[u32]` slice, so
//!   index-dependent learners (k-NN's training-set model, the multiset
//!   oracle) and save/revert undo logs keep speaking in original indices
//!   against the original dataset — bit-identical to the unfolded path.
//! * Randomized-ordering streams shuffle a copy of that id slice with the
//!   same per-node derived RNG stream the unfolded path uses, so the
//!   shuffled sequence is identical too (the engines recycle the copy
//!   buffer through a free list instead of allocating per node).
//!
//! Per-fold results therefore stay in the *original* fold numbering, and
//! `tests/integration_layout.rs` pins bit-identity of the folded path
//! against the unfolded one across every engine × strategy × ordering ×
//! worker-count combination.

use super::Dataset;
use crate::cv::folds::Folds;

/// A dataset physically re-ordered so each fold chunk is one contiguous
/// row range. Built once per run with [`FoldedDataset::build`]; carries
/// the forward (`position → original id`) and inverse (`original id →
/// position`) permutations plus the owning [`Folds`] partition.
#[derive(Debug, Clone)]
pub struct FoldedDataset {
    /// The permuted copy: row `p` holds original row `orig[p]`.
    data: Dataset,
    /// The logical partition this layout realizes (original indices).
    folds: Folds,
    /// Forward permutation: `orig[p]` = original index of folded row `p`.
    orig: Vec<u32>,
    /// Inverse permutation: `pos[i]` = folded position of original row `i`.
    pos: Vec<u32>,
    /// Chunk boundaries: chunk `c` occupies rows `starts[c]..starts[c+1]`.
    starts: Vec<usize>,
}

impl FoldedDataset {
    /// Build the fold-contiguous layout of `data` under `folds`. Copies
    /// the dataset once (`O(n·d)`); every per-node stream afterwards is a
    /// slice borrow.
    pub fn build(data: &Dataset, folds: &Folds) -> Self {
        assert_eq!(
            folds.n(),
            data.n,
            "fold partition covers {} points but the dataset has {}",
            folds.n(),
            data.n
        );
        let k = folds.k();
        let orig = folds.gather_range(0, k - 1);
        let mut starts = Vec::with_capacity(k + 1);
        let mut off = 0usize;
        starts.push(0);
        for c in 0..k {
            off += folds.chunk(c).len();
            starts.push(off);
        }
        debug_assert_eq!(off, data.n);
        let mut pos = vec![0u32; data.n];
        for (p, &i) in orig.iter().enumerate() {
            pos[i as usize] = p as u32;
        }
        Self { data: data.subset(&orig), folds: folds.clone(), orig, pos, starts }
    }

    /// The logical fold partition (original indices, original numbering).
    pub fn folds(&self) -> &Folds {
        &self.folds
    }

    /// The permuted physical copy (row `p` = original row
    /// [`Self::original_of`]`(p)`). Exposed for benches and tests; the
    /// engines only hand out its slices.
    pub fn folded_data(&self) -> &Dataset {
        &self.data
    }

    pub fn n(&self) -> usize {
        self.data.n
    }

    pub fn d(&self) -> usize {
        self.data.d
    }

    pub fn k(&self) -> usize {
        self.folds.k()
    }

    /// Original dataset index of folded row `p`.
    pub fn original_of(&self, p: u32) -> u32 {
        self.orig[p as usize]
    }

    /// Folded row position of original dataset index `i`.
    pub fn position_of(&self, i: u32) -> u32 {
        self.pos[i as usize]
    }

    /// Original ids of the contiguous block of chunks `lo..=hi` — the
    /// same sequence [`Folds::gather_range`]`(lo, hi)` returns, borrowed
    /// instead of allocated.
    pub fn ids(&self, lo: usize, hi: usize) -> &[u32] {
        &self.orig[self.starts[lo]..self.starts[hi + 1]]
    }

    /// Contiguous row block of chunks `lo..=hi` as
    /// `(features, outcomes, original ids)` — the learner fast-path
    /// triple: `features` is row-major `ids.len() × d`, and element `j`
    /// of each slice describes the same point.
    pub fn rows(&self, lo: usize, hi: usize) -> (&[f32], &[f32], &[u32]) {
        let (a, b) = (self.starts[lo], self.starts[hi + 1]);
        (&self.data.x[a * self.data.d..b * self.data.d], &self.data.y[a..b], &self.orig[a..b])
    }

    /// Row block of every chunk *before* `i` (empty for `i = 0`).
    /// Together with [`Self::rows_after`] this is standard CV's training
    /// set "all chunks but `i`", in exactly
    /// [`Folds::gather_except`]'s order.
    pub fn rows_before(&self, i: usize) -> (&[f32], &[f32], &[u32]) {
        let b = self.starts[i];
        (&self.data.x[..b * self.data.d], &self.data.y[..b], &self.orig[..b])
    }

    /// Row block of every chunk *after* `i` (empty for `i = k − 1`).
    pub fn rows_after(&self, i: usize) -> (&[f32], &[f32], &[u32]) {
        let a = self.starts[i + 1];
        (&self.data.x[a * self.data.d..], &self.data.y[a..], &self.orig[a..])
    }

    /// Original ids of every chunk before `i`.
    pub fn ids_before(&self, i: usize) -> &[u32] {
        &self.orig[..self.starts[i]]
    }

    /// Original ids of every chunk after `i`.
    pub fn ids_after(&self, i: usize) -> &[u32] {
        &self.orig[self.starts[i + 1]..]
    }

    /// Whether this layout realizes exactly the partition `folds` (same
    /// chunks, same within-chunk order). The engines assert this when a
    /// caller pairs a layout with separately-supplied folds.
    pub fn matches_folds(&self, folds: &Folds) -> bool {
        if std::ptr::eq(folds, &self.folds) {
            return true;
        }
        self.folds.k() == folds.k()
            && self.folds.n() == folds.n()
            && (0..folds.k()).all(|c| self.folds.chunk(c) == folds.chunk(c))
    }

    /// Append a batch of rows (row-major `b × d` features plus `b`
    /// outcomes) to the window. Each row is assigned original id
    /// `old_n + j` and lands at the *tail* of the currently smallest fold
    /// chunk ([`Folds::smallest_chunk`]) — fold sizes stay within 1 of
    /// each other and every pre-existing point keeps its id, its fold and
    /// its within-chunk position. The permuted storage, forward/inverse
    /// permutations and chunk boundaries are rebuilt in one `O(n·d)` pass,
    /// bit-identical to [`FoldedDataset::build`] on the extended dataset
    /// under the mutated folds (the streaming tests pin this).
    ///
    /// Returns the [`AppendDelta`] the incremental refresh engine
    /// ([`crate::cv::refresh`]) consumes.
    pub fn append_rows(&mut self, x: &[f32], y: &[f32]) -> AppendDelta {
        let d = self.data.d;
        assert!(!y.is_empty(), "append_rows needs at least one row");
        assert_eq!(x.len() % d, 0, "x length {} not a multiple of d {d}", x.len());
        assert_eq!(y.len(), x.len() / d, "y length {} != row count {}", y.len(), x.len() / d);
        let b = y.len();
        let old_n = self.data.n;
        let mut appended = Vec::with_capacity(b);
        let mut fold_of = Vec::with_capacity(b);
        for j in 0..b {
            let id = (old_n + j) as u32;
            let c = self.folds.smallest_chunk();
            self.folds.append_to_chunk(c, id);
            appended.push(id);
            fold_of.push(c);
        }
        let mut touched = fold_of.clone();
        touched.sort_unstable();
        touched.dedup();
        self.rebuild(x, y, old_n, 0);
        AppendDelta { appended, fold_of, touched }
    }

    /// Sliding-window retirement: drop the `count` oldest rows (original
    /// ids `0..count`) and renumber the survivors down by `count`, in both
    /// the fold partition ([`Folds::retire_below`]) and the permuted
    /// storage. Panics if any fold chunk would end up empty — long-running
    /// callers check [`Folds::can_retire_below`] first.
    ///
    /// Retirement changes every fold's *contents*, so it invalidates any
    /// [`crate::cv::refresh::RefreshSession`] built on this layout; the
    /// caller re-primes.
    pub fn retire_oldest(&mut self, count: usize) {
        if count == 0 {
            return;
        }
        assert!(
            u32::try_from(count).is_ok(),
            "retire_oldest({count}) exceeds the u32 id space"
        );
        self.folds.retire_below(count as u32);
        // No fresh rows: every surviving id sources from the old permuted
        // copy, shifted down by `count`.
        self.rebuild(&[], &[], self.folds.n(), count);
    }

    /// Rebuild the permuted storage, forward/inverse permutations and
    /// chunk boundaries after a fold mutation, in one `O(n·d)` pass.
    /// Surviving id `i < fresh_base` sources from the *old* permuted copy
    /// at the old position of id `i + shift`; id `i >= fresh_base` is a
    /// fresh row, read from `x`/`y` at `i - fresh_base`.
    fn rebuild(&mut self, x: &[f32], y: &[f32], fresh_base: usize, shift: usize) {
        let d = self.data.d;
        let k = self.folds.k();
        let orig = self.folds.gather_range(0, k - 1);
        let n = orig.len();
        let mut starts = Vec::with_capacity(k + 1);
        starts.push(0usize);
        let mut off = 0usize;
        for c in 0..k {
            off += self.folds.chunk(c).len();
            starts.push(off);
        }
        debug_assert_eq!(off, n);
        let mut pos = vec![0u32; n];
        let mut nx = Vec::with_capacity(n * d);
        let mut ny = Vec::with_capacity(n);
        for (p, &id) in orig.iter().enumerate() {
            pos[id as usize] = p as u32;
            if (id as usize) < fresh_base {
                let q = self.pos[id as usize + shift] as usize;
                nx.extend_from_slice(&self.data.x[q * d..(q + 1) * d]);
                ny.push(self.data.y[q]);
            } else {
                let j = id as usize - fresh_base;
                nx.extend_from_slice(&x[j * d..(j + 1) * d]);
                ny.push(y[j]);
            }
        }
        self.data = Dataset::new(nx, ny, d);
        self.orig = orig;
        self.pos = pos;
        self.starts = starts;
    }
}

/// What one [`FoldedDataset::append_rows`] call changed — the incremental
/// refresh engine's work order ([`crate::cv::refresh`]).
#[derive(Debug, Clone)]
pub struct AppendDelta {
    /// Original ids assigned to the appended rows (dense `old_n..new_n`,
    /// in arrival order).
    pub appended: Vec<u32>,
    /// Fold chunk each appended row landed in (`fold_of[j]` holds
    /// `appended[j]`).
    pub fold_of: Vec<usize>,
    /// Folds that received at least one appended row — sorted ascending,
    /// deduped. The refresh engine recomputes exactly the O(log k)
    /// subtrees along these folds' root-to-leaf paths.
    pub touched: Vec<usize>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn arange_data(n: usize, d: usize) -> Dataset {
        let x: Vec<f32> = (0..n * d).map(|v| v as f32).collect();
        let y: Vec<f32> = (0..n).map(|v| -(v as f32)).collect();
        Dataset::new(x, y, d)
    }

    #[test]
    fn permutation_round_trip() {
        let mut rng = Rng::new(0xF01DED);
        for _ in 0..30 {
            let n = 2 + rng.below(200) as usize;
            let k = 1 + rng.below(n as u64) as usize;
            let data = arange_data(n, 3);
            let folds = Folds::new(n, k, (n * 31 + k) as u64);
            let f = FoldedDataset::build(&data, &folds);
            assert_eq!(f.n(), n);
            assert_eq!(f.d(), 3);
            assert_eq!(f.k(), k);
            for p in 0..n as u32 {
                assert_eq!(f.position_of(f.original_of(p)), p, "n={n} k={k} p={p}");
            }
            for i in 0..n as u32 {
                assert_eq!(f.original_of(f.position_of(i)), i, "n={n} k={k} i={i}");
            }
            // Folded row p holds the original row orig[p].
            for p in 0..n as u32 {
                let i = f.original_of(p);
                assert_eq!(f.folded_data().row(p), data.row(i));
                assert_eq!(f.folded_data().label(p), data.label(i));
            }
        }
    }

    #[test]
    fn ids_match_gather_range_and_except() {
        let n = 103; // remainder folds
        let k = 10;
        let data = arange_data(n, 2);
        let folds = Folds::new(n, k, 7);
        let f = FoldedDataset::build(&data, &folds);
        for lo in 0..k {
            for hi in lo..k {
                assert_eq!(f.ids(lo, hi), folds.gather_range(lo, hi), "({lo},{hi})");
            }
        }
        for i in 0..k {
            let mut joined = f.ids_before(i).to_vec();
            joined.extend_from_slice(f.ids_after(i));
            assert_eq!(joined, folds.gather_except(i), "fold {i}");
        }
    }

    #[test]
    fn row_blocks_are_materialized_gathers() {
        let n = 37;
        let k = 5;
        let data = arange_data(n, 4);
        let folds = Folds::new(n, k, 9);
        let f = FoldedDataset::build(&data, &folds);
        let (x, y, ids) = f.rows(1, 3);
        assert_eq!(ids, folds.gather_range(1, 3));
        assert_eq!(x.len(), ids.len() * 4);
        assert_eq!(y.len(), ids.len());
        for (j, &i) in ids.iter().enumerate() {
            assert_eq!(&x[j * 4..(j + 1) * 4], data.row(i), "j={j}");
            assert_eq!(y[j], data.label(i), "j={j}");
        }
        // Boundary blocks are empty, not out of range.
        assert!(f.rows_before(0).2.is_empty());
        assert!(f.rows_after(k - 1).2.is_empty());
    }

    #[test]
    fn matches_folds_detects_mismatch() {
        let data = arange_data(40, 1);
        let folds = Folds::new(40, 5, 11);
        let f = FoldedDataset::build(&data, &folds);
        assert!(f.matches_folds(&folds));
        assert!(f.matches_folds(&folds.clone()));
        let other = Folds::new(40, 5, 12);
        assert!(!f.matches_folds(&other));
        let other_k = Folds::new(40, 8, 11);
        assert!(!f.matches_folds(&other_k));
    }

    #[test]
    #[should_panic(expected = "fold partition covers")]
    fn wrong_dataset_size_panics() {
        let data = arange_data(10, 1);
        let folds = Folds::new(9, 3, 1);
        let _ = FoldedDataset::build(&data, &folds);
    }

    #[test]
    fn loocv_layout() {
        let data = arange_data(7, 2);
        let folds = Folds::loocv(7);
        let f = FoldedDataset::build(&data, &folds);
        assert_eq!(f.k(), 7);
        for i in 0..7 {
            assert_eq!(f.ids(i, i), folds.chunk(i));
        }
    }

    /// The incremental rebuild after `append_rows` must be bit-identical
    /// to a from-scratch `build` of the extended dataset under the
    /// mutated folds — same permuted rows, same permutations, same chunk
    /// boundaries.
    #[test]
    fn append_rebuild_matches_fresh_build() {
        let mut rng = Rng::new(0xAB5EED);
        for _ in 0..20 {
            let n = 6 + rng.below(80) as usize;
            let k = 1 + rng.below(n as u64 / 2 + 1) as usize;
            let b = 1 + rng.below(9) as usize;
            let d = 3;
            let all = arange_data(n + b, d);
            let window = all.take(n);
            let folds = Folds::new(n, k, (n * 7 + k) as u64);
            let mut f = FoldedDataset::build(&window, &folds);

            let (nx, ny) = (&all.x[n * d..], &all.y[n..]);
            let delta = f.append_rows(nx, ny);
            assert_eq!(delta.appended, (n as u32..(n + b) as u32).collect::<Vec<_>>());
            assert_eq!(delta.fold_of.len(), b);
            assert!(delta.touched.windows(2).all(|w| w[0] < w[1]));

            let fresh = FoldedDataset::build(&all, f.folds());
            assert_eq!(f.folded_data().x, fresh.folded_data().x, "n={n} k={k} b={b}");
            assert_eq!(f.folded_data().y, fresh.folded_data().y);
            for p in 0..(n + b) as u32 {
                assert_eq!(f.original_of(p), fresh.original_of(p));
                assert_eq!(f.position_of(p), fresh.position_of(p));
            }
            for c in 0..k {
                assert_eq!(f.ids(c, c), fresh.ids(c, c), "chunk {c}");
            }
        }
    }

    /// Appended rows land at chunk tails: pre-existing ids keep their
    /// folds and within-chunk positions.
    #[test]
    fn append_preserves_existing_assignment() {
        let data = arange_data(20, 2);
        let folds = Folds::new(20, 4, 5);
        let before: Vec<Vec<u32>> = (0..4).map(|c| folds.chunk(c).to_vec()).collect();
        let mut f = FoldedDataset::build(&data, &folds);
        let extra = arange_data(26, 2);
        f.append_rows(&extra.x[40..], &extra.y[20..]);
        for (c, old) in before.iter().enumerate() {
            assert_eq!(&f.folds().chunk(c)[..old.len()], &old[..], "chunk {c} prefix");
        }
    }

    /// retire_oldest(c) must equal a fresh build over the shifted window:
    /// surviving original row `i + c` becomes row `i`.
    #[test]
    fn retire_matches_fresh_build_on_shifted_window() {
        let n = 40;
        let d = 2;
        let all = arange_data(n, d);
        let folds = Folds::new(n, 5, 9);
        let mut f = FoldedDataset::build(&all, &folds);
        let c = 6;
        assert!(f.folds().can_retire_below(c as u32));
        f.retire_oldest(c);
        assert_eq!(f.n(), n - c);

        let shifted = Dataset::new(all.x[c * d..].to_vec(), all.y[c..].to_vec(), d);
        let fresh = FoldedDataset::build(&shifted, f.folds());
        assert_eq!(f.folded_data().x, fresh.folded_data().x);
        assert_eq!(f.folded_data().y, fresh.folded_data().y);
        for p in 0..(n - c) as u32 {
            assert_eq!(f.original_of(p), fresh.original_of(p));
            assert_eq!(f.position_of(p), fresh.position_of(p));
        }
    }

    /// Retire-then-append round trip: the window slides and the layout
    /// still matches a from-scratch build at every step.
    #[test]
    fn retire_then_append_round_trip() {
        let n = 30;
        let d = 3;
        let all = arange_data(n + 10, d);
        let window = all.take(n);
        let folds = Folds::new(n, 6, 17);
        let mut f = FoldedDataset::build(&window, &folds);
        f.retire_oldest(4);
        let delta = f.append_rows(&all.x[n * d..], &all.y[n..]);
        assert_eq!(f.n(), n - 4 + 10);
        assert!(!delta.touched.is_empty());

        // Reference: rows 4..n+10 of the stream, ids shifted down by 4.
        let shifted = Dataset::new(all.x[4 * d..].to_vec(), all.y[4..].to_vec(), d);
        let fresh = FoldedDataset::build(&shifted, f.folds());
        assert_eq!(f.folded_data().x, fresh.folded_data().x);
        assert_eq!(f.folded_data().y, fresh.folded_data().y);
    }
}
