//! `repro` — the TreeCV experiment launcher.
//!
//! Subcommands map one-to-one onto the paper's evaluation artifacts:
//! * `cv`      — run any (task, engine, k, ordering, strategy) combination.
//! * `table2`  — reproduce Table 2 (estimate mean ± std over repetitions).
//! * `figure2` — reproduce Figure 2 (runtime vs n for several k; all panels).
//! * `loocv`   — the headline: LOOCV at large n with TreeCV vs standard.
//! * `dist`    — the §4.1 distributed simulation (communication accounting).
//! * `grid`    — the intro's motivation: hyper-parameter grid search driven
//!               by fast CV.
//! * `sweep`   — a hyperparameter grid through ONE pooled executor.
//! * `select`  — model selection across learner families (registry-built,
//!               heterogeneous batch through ONE pooled executor).
//! * `serve`   — streaming CV service: append row batches over stdin and
//!               keep the estimate warm via O(log k) incremental refresh.
//! * `selfcheck` — verify the PJRT runtime and AOT artifacts end-to-end.
//!
//! Argument parsing is in-tree (`--flag value` / `--flag` booleans); run
//! `repro help` for usage.

use treecv::config::{
    Engine, ExperimentConfig, OrderingCfg, SelectList, StrategyCfg, SweepGrid, Task,
};
use treecv::coordinator::{self, paper};
use treecv::report::{Json, ToJson};
use treecv::Result;

const USAGE: &str = "\
repro — TreeCV (IJCAI 2015) reproduction driver

USAGE: repro <command> [--flag value ...]

COMMANDS
  cv         Run a CV experiment. Every learner in the registry is
             reachable; xla_* tasks need the PJRT runtime + artifacts.
             --task pegasos|lsqsgd|kmeans|density|naive_bayes|ridge|
                    knn|perceptron|multiset|xla_pegasos|xla_lsqsgd
             --engine treecv|standard|parallel_treecv|merge|approx
                                  (parallel_treecv — alias: executor — runs
                                   on the pooled work-stealing executor;
                                   approx trains ONCE and derives each
                                   fold by a one-step correction — convex
                                   tasks only: pegasos, lsqsgd, ridge)
             --ks 5,10,100        fold counts (0 = LOOCV)
             --n 20000  --reps 20  --seed 42
             --randomized          randomized feeding order
             --save-revert         save/revert strategy (default: copy);
                                   honored by treecv and parallel_treecv
                                   (the executor snapshots only at its
                                   fork frontier); a hard error on
                                   standard/merge, never silently copy
             --threads 0           worker-pool size for parallel_treecv
                                   and approx (0 = all cores)
             --approx-check        (approx only) also run exact TreeCV per
                                   repetition and report the largest
                                   per-fold deviation as exact_gap_max
             --lambda L            regularizer (default: pegasos 1e-6,
                                   ridge 1.0)
             --alpha 0  --data FILE.libsvm
             --config FILE         load a config file (flags override)
             --json                emit JSON
  table2     Reproduce Table 2.   --task --n --ks --reps --seed --json
  figure2    Reproduce Figure 2.  --task --panel fixed|randomized|loocv
             --ns 1000,2000,...   --reps --seed   (CSV to stdout)
  loocv      LOOCV headline.      --task --n --standard-max-n --seed
  dist       Distributed sim.     --n --ks --seed
  grid       λ grid search.       --n --k --log-lambdas -7,-6,-5 --seed
  sweep      Hyperparameter sweep: every (value × repetition) TreeCV run
             through ONE pooled work-stealing executor; prints a table
             ranked by mean loss (best first).
             --task pegasos|ridge|lsqsgd
             --sweep lambda=1e-3,1e-4,1e-5   (lsqsgd: alpha=...)
             --k 10  --n 20000  --reps 20  --seed 42
             --threads 0          pool size (0 = all cores)
             --race               race the grid: a sequential sign test
                                  eliminates losing values at round
                                  boundaries and cancels their remaining
                                  runs; prints ranked survivors, the
                                  elimination trace and work-saved
                                  counters. Deterministic per seed;
                                  --alpha 0 reproduces the exhaustive
                                  table bit for bit.
             --rounds 4           decision rounds of the race
             --alpha 0.05         sign-test significance level
             --no-race            force the exhaustive sweep (overrides a
                                  config file's `race = true`)
             --randomized --save-revert --json --config FILE
  select     Model selection across learner FAMILIES: every (learner x
             repetition) TreeCV run batches through ONE pooled executor;
             prints a table ranked by mean loss. All learners must share
             one dataset family (e.g. the covertype classifiers).
             --learners pegasos:lambda=1e-4,naive_bayes,knn,perceptron
             --k 10  --n 20000  --reps 20  --seed 42
             --threads 0          pool size (0 = all cores)
             --randomized --save-revert --json --config FILE
  serve      Streaming CV service: prime a baseline estimate, then read a
             line protocol on stdin — `row <y> <x1>..<xd>` appends rows
             (auto-applied every --batch rows through the O(log k)
             incremental refresh engine), `query` answers
             `estimate <v> pending <p>`, `flush` applies buffered rows,
             `retire <count>` slides the window (drops the oldest rows
             and re-primes), `stats` snapshots counters, `quit`/EOF ends
             the session and prints throughput + staleness metrics.
             With --engine approx (convex tasks only), `query` folds the
             pending buffer into a one-step-corrected estimate instead of
             answering from the last refresh alone.
             --task multiset|density|pegasos|...   (any registry task)
             --batch 32           rows buffered per refresh
             --k 10  --n 20000  --seed 42
             --threads 0          pool size for prime runs (0 = all
                                  cores; refreshes run sequentially)
             --randomized --save-revert --json --config FILE
  selfcheck  Verify PJRT runtime + artifacts.
  help       Show this message.
";

/// Tiny flag parser: `--key value` pairs plus boolean `--key` switches.
struct Args {
    flags: Vec<(String, Option<String>)>,
}

impl Args {
    fn parse(argv: &[String], boolean_flags: &[&str]) -> Result<Args> {
        let mut flags = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            let Some(key) = a.strip_prefix("--") else {
                anyhow::bail!("unexpected argument `{a}` (flags start with --)");
            };
            if boolean_flags.contains(&key) {
                flags.push((key.to_string(), None));
                i += 1;
            } else {
                let Some(val) = argv.get(i + 1) else {
                    anyhow::bail!("flag --{key} needs a value");
                };
                flags.push((key.to_string(), Some(val.clone())));
                i += 2;
            }
        }
        Ok(Args { flags })
    }

    fn has(&self, key: &str) -> bool {
        self.flags.iter().any(|(k, _)| k == key)
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.iter().find(|(k, _)| k == key).and_then(|(_, v)| v.as_deref())
    }

    fn get_parse<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow::anyhow!("--{key} {v}: {e}")),
        }
    }

    fn get_list(&self, key: &str, default: Vec<usize>) -> Result<Vec<usize>> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .split(',')
                .map(|p| p.trim().parse().map_err(|e| anyhow::anyhow!("--{key} `{p}`: {e}")))
                .collect(),
        }
    }

    fn get_f64_list(&self, key: &str, default: Vec<f64>) -> Result<Vec<f64>> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .split(',')
                .map(|p| p.trim().parse().map_err(|e| anyhow::anyhow!("--{key} `{p}`: {e}")))
                .collect(),
        }
    }
}

/// Shared flag plumbing of the pooled-batch subcommands (`sweep`,
/// `select`): config-file load, the common numeric overrides, single-k
/// resolution, ordering/strategy switches, and `--data` — one
/// implementation so the two subcommands cannot drift.
fn batch_cfg(args: &Args) -> Result<ExperimentConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => ExperimentConfig::load(std::path::Path::new(path))?,
        None => ExperimentConfig::default(),
    };
    cfg.n = args.get_parse("n", cfg.n)?;
    cfg.seed = args.get_parse("seed", cfg.seed)?;
    cfg.repetitions = args.get_parse("reps", cfg.repetitions)?;
    cfg.threads = args.get_parse("threads", cfg.threads)?;
    // Batch runs use a single fold count: keep a single configured k,
    // else fall back to 10; `--k` overrides either.
    let default_k = if cfg.ks.len() == 1 { cfg.ks[0] } else { 10 };
    cfg.ks = vec![args.get_parse("k", default_k)?];
    if args.has("randomized") {
        cfg.ordering = OrderingCfg::Randomized;
    }
    if args.has("save-revert") {
        cfg.strategy = StrategyCfg::SaveRevert;
    }
    if let Some(d) = args.get("data") {
        cfg.data_path = Some(d.to_string());
    }
    Ok(cfg)
}

fn cell_reports_json(reports: &[coordinator::CellReport]) -> Json {
    Json::Arr(
        reports
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("task", Json::str(r.task.name())),
                    ("engine", Json::str(r.engine.name())),
                    ("k", Json::num(r.k as f64)),
                    ("n", Json::num(r.n as f64)),
                    ("repetitions", Json::num(r.repetitions as f64)),
                    ("mean", Json::Num(r.mean)),
                    ("std", Json::Num(r.std)),
                    ("mean_wall_secs", Json::Num(r.mean_wall_secs)),
                    ("ops", r.ops.to_json()),
                ])
            })
            .collect(),
    )
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first().map(|s| s.as_str()) else {
        print!("{USAGE}");
        return Ok(());
    };
    let rest = &argv[1..];
    match cmd {
        "cv" => {
            let args =
                Args::parse(rest, &["randomized", "save-revert", "json", "approx-check"])?;
            let mut cfg = match args.get("config") {
                Some(path) => ExperimentConfig::load(std::path::Path::new(path))?,
                None => ExperimentConfig::default(),
            };
            if let Some(t) = args.get("task") {
                cfg.task = Task::parse(t)?;
            }
            if let Some(e) = args.get("engine") {
                cfg.engine = Engine::parse(e)?;
            }
            cfg.ks = args.get_list("ks", cfg.ks.clone())?;
            cfg.n = args.get_parse("n", cfg.n)?;
            cfg.seed = args.get_parse("seed", cfg.seed)?;
            cfg.repetitions = args.get_parse("reps", cfg.repetitions)?;
            cfg.threads = args.get_parse("threads", cfg.threads)?;
            if args.has("randomized") {
                cfg.ordering = OrderingCfg::Randomized;
            }
            if args.has("save-revert") {
                cfg.strategy = StrategyCfg::SaveRevert;
            }
            if args.has("approx-check") {
                cfg.approx_check = true;
            }
            if let Some(v) = args.get("lambda") {
                cfg.lambda =
                    Some(v.parse().map_err(|e| anyhow::anyhow!("--lambda {v}: {e}"))?);
            }
            cfg.alpha = args.get_parse("alpha", cfg.alpha)?;
            if let Some(d) = args.get("data") {
                cfg.data_path = Some(d.to_string());
            }
            let reports = coordinator::run_experiment(&cfg)?;
            if args.has("json") {
                println!("{}", cell_reports_json(&reports).render_pretty());
            } else {
                print!("{}", coordinator::format_table(&reports));
            }
        }
        "table2" => {
            let args = Args::parse(rest, &["json"])?;
            let task = Task::parse(args.get("task").unwrap_or("pegasos"))?;
            let n = args.get_parse("n", 20_000usize)?;
            let ks = args.get_list("ks", vec![5, 10, 100, 0])?;
            let reps = args.get_parse("reps", 20usize)?;
            let seed = args.get_parse("seed", 42u64)?;
            let out = paper::table2(task, n, &ks, reps, seed)?;
            if args.has("json") {
                println!("{}", out.to_json().render_pretty());
            } else {
                print!("{}", out.render());
            }
        }
        "figure2" => {
            let args = Args::parse(rest, &[])?;
            let task = Task::parse(args.get("task").unwrap_or("pegasos"))?;
            let panel = paper::Panel::parse(args.get("panel").unwrap_or("fixed"))?;
            let n = args.get_parse("n", 100_000usize)?;
            let ns = args.get_list("ns", paper::default_ns(n))?;
            let reps = args.get_parse("reps", 5usize)?;
            let seed = args.get_parse("seed", 42u64)?;
            let out = paper::figure2(task, panel, &ns, reps, seed)?;
            print!("{}", out.render_csv());
        }
        "loocv" => {
            let args = Args::parse(rest, &[])?;
            let task = Task::parse(args.get("task").unwrap_or("pegasos"))?;
            let n = args.get_parse("n", 581_012usize)?;
            let max_std = args.get_parse("standard-max-n", 10_000usize)?;
            let seed = args.get_parse("seed", 42u64)?;
            print!("{}", paper::loocv_headline(task, n, max_std, seed)?);
        }
        "dist" => {
            let args = Args::parse(rest, &[])?;
            let n = args.get_parse("n", 20_000usize)?;
            let ks = args.get_list("ks", vec![4, 8, 16, 32, 64])?;
            let seed = args.get_parse("seed", 42u64)?;
            print!("{}", paper::distributed_report(n, &ks, seed)?);
        }
        "grid" => {
            let args = Args::parse(rest, &[])?;
            let n = args.get_parse("n", 20_000usize)?;
            let k = args.get_parse("k", 10usize)?;
            let lls = args.get_f64_list("log-lambdas", vec![-7.0, -6.0, -5.0, -4.0, -3.0])?;
            let seed = args.get_parse("seed", 42u64)?;
            print!("{}", paper::grid_search(n, k, &lls, seed)?);
        }
        "sweep" => {
            let args =
                Args::parse(rest, &["randomized", "save-revert", "json", "race", "no-race"])?;
            let mut cfg = batch_cfg(&args)?;
            if let Some(t) = args.get("task") {
                cfg.task = Task::parse(t)?;
            }
            if let Some(g) = args.get("sweep") {
                cfg.sweep = Some(SweepGrid::parse(g)?);
            }
            if args.has("race") && args.has("no-race") {
                anyhow::bail!("--race and --no-race are mutually exclusive");
            }
            if args.has("race") {
                cfg.race = true;
            }
            if args.has("no-race") {
                cfg.race = false;
            }
            cfg.race_rounds = args.get_parse("rounds", cfg.race_rounds)?;
            cfg.race_alpha = args.get_parse("alpha", cfg.race_alpha)?;
            if cfg.race {
                let report = coordinator::run_race_sweep(&cfg)?;
                if args.has("json") {
                    println!("{}", report.to_json().render_pretty());
                } else {
                    print!("{}", coordinator::format_race_table(&report));
                }
            } else {
                let report = coordinator::run_sweep(&cfg)?;
                if args.has("json") {
                    println!("{}", report.to_json().render_pretty());
                } else {
                    print!("{}", coordinator::format_sweep_table(&report));
                }
            }
        }
        "select" => {
            let args = Args::parse(rest, &["randomized", "save-revert", "json"])?;
            let mut cfg = batch_cfg(&args)?;
            if let Some(l) = args.get("learners") {
                cfg.learners = Some(SelectList::parse(l)?);
            }
            let report = coordinator::run_select(&cfg)?;
            if args.has("json") {
                println!("{}", report.to_json().render_pretty());
            } else {
                print!("{}", coordinator::format_select_table(&report));
            }
        }
        "serve" => {
            let args = Args::parse(rest, &["randomized", "save-revert", "json"])?;
            let mut cfg = batch_cfg(&args)?;
            if let Some(t) = args.get("task") {
                cfg.task = Task::parse(t)?;
            }
            let batch = args.get_parse("batch", 32usize)?;
            let stdin = std::io::stdin();
            let mut stdout = std::io::stdout();
            let report = coordinator::run_serve(&cfg, batch, stdin.lock(), &mut stdout)?;
            if args.has("json") {
                println!("{}", report.to_json().render_pretty());
            } else {
                print!("{}", coordinator::format_serve_table(&report));
            }
        }
        "selfcheck" => paper::selfcheck()?,
        "help" | "--help" | "-h" => print!("{USAGE}"),
        other => {
            eprint!("unknown command `{other}`\n\n{USAGE}");
            std::process::exit(2);
        }
    }
    Ok(())
}
