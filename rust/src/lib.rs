//! # TreeCV — Fast Cross-Validation for Incremental Learning
//!
//! A production reproduction of Joulani, György & Szepesvári,
//! *"Fast Cross-Validation for Incremental Learning"*, IJCAI 2015.
//!
//! TreeCV computes the k-fold cross-validation estimate for incremental
//! learning algorithms in `O(log k)`-times single-training time, instead of
//! the `k`-times cost of the standard method, by organizing the fold
//! computation in a binary recursion tree (paper Algorithm 1).
//!
//! ## Architecture (three layers)
//!
//! * **Layer 3 (this crate)** — the coordination contribution: the TreeCV
//!   scheduler ([`cv::treecv`]), the pooled work-stealing parallel
//!   executor ([`cv::executor`]), the standard baseline
//!   ([`cv::standard`]), fold management, save/restore strategies, the
//!   repetition/variance harness, and a simulated distributed runtime
//!   ([`distributed`]).
//! * **Layer 2 (python/compile/model.py)** — the incremental learners'
//!   chunk-update / chunk-evaluate steps as JAX functions, AOT-lowered to
//!   HLO text under `artifacts/`.
//! * **Layer 1 (python/compile/kernels/)** — Pallas kernels for the compute
//!   hot-spots, validated against pure-jnp oracles.
//!
//! The [`runtime`] module loads the AOT artifacts through PJRT (the `xla`
//! crate) so that Python is never on the measurement path.
//!
//! ## Quick start
//!
//! ```no_run
//! use treecv::data::synth::SyntheticCovertype;
//! use treecv::learner::pegasos::Pegasos;
//! use treecv::cv::{folds::Folds, treecv::TreeCv, CvEngine};
//!
//! let data = SyntheticCovertype::new(10_000, 42).generate();
//! let learner = Pegasos::new(54, 1e-6);
//! let folds = Folds::new(data.n, 10, 7);
//! let res = TreeCv::default().run(&learner, &data, &folds);
//! println!("10-CV misclassification = {:.4}", res.estimate);
//! ```

pub mod analysis;
pub mod benchkit;
pub mod config;
pub mod coordinator;
pub mod cv;
pub mod data;
pub mod distributed;
pub mod learner;
pub mod loss;
pub mod metrics;
pub mod report;
pub mod rng;
pub mod runtime;
pub mod sync;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
