//! Incremental learners (the paper's `L : (M ∪ {∅}) × Z* → M`).
//!
//! The paper's only requirement on the base algorithm is that it is
//! *incremental*: given a model trained on previous data and a new batch of
//! points, it updates the model at a fraction of the cost of retraining
//! from scratch. [`IncrementalLearner`] captures exactly that interface,
//! plus the two mechanisms TreeCV needs at interior tree nodes (paper §4.1):
//! copying a model, or reverting the in-place changes an update made
//! (`update_logged` / `revert`). The contiguous fast paths
//! (`update_rows` / `evaluate_rows`) let the engines stream the
//! fold-contiguous layout ([`crate::data::folded::FoldedDataset`])
//! without per-node index vectors; the dense learners override them,
//! everything else inherits the (bit-identical) indexed defaults. All
//! dense per-point math routes through the [`linalg`] kernel layer
//! (runtime-dispatched SIMD with a bit-identical scalar fallback —
//! enforced by `xtask lint`'s `kernel-layer` rule).
//!
//! Implementations:
//! * [`pegasos::Pegasos`] — linear PEGASOS SVM (paper §5, Table 2 top).
//! * [`lsqsgd::LsqSgd`] — robust-SA least-squares SGD with averaging
//!   (paper §5, Table 2 bottom).
//! * [`perceptron::Perceptron`] — classic online perceptron; its sparse
//!   mistake-driven updates make the save/revert strategy genuinely
//!   cheaper than copying.
//! * [`kmeans::OnlineKMeans`] — online K-means (paper Table 1, row 3).
//! * [`histdensity::HistogramDensity`] — integer-count histogram density
//!   estimator (Table 1, row 4); exactly order-insensitive, so TreeCV
//!   equals standard CV bit-for-bit — a key correctness oracle.
//! * [`naive_bayes::GaussianNb`] — Gaussian naive Bayes over sufficient
//!   statistics; *mergeable*, so it also drives the Izbicki-style
//!   fold-merging baseline ([`crate::cv::mergecv`]).
//! * [`ridge::OnlineRidge`] — ridge regression over running sufficient
//!   statistics; order-insensitive and the subject of the exact
//!   closed-form LOOCV comparator ([`crate::cv::exact`]).
//! * [`knn::KnnClassifier`] — k-nearest-neighbour classification (related
//!   work: Mullin & Sukthankar 2000); the model is the training set, so it
//!   is an exactness oracle that makes real predictions.
//! * [`multiset::MultisetLearner`] — a structural test oracle whose model
//!   is the exact multiset of training indices.
//!
//! The XLA-backed learners (running the AOT Pallas/JAX artifacts through
//! PJRT) live in [`crate::runtime`] and implement the same trait.
//!
//! ## Generic vs erased
//!
//! [`IncrementalLearner`] is the *generic* interface: associated
//! `Model`/`Undo` types, zero-cost static dispatch, one monomorphized
//! engine per learner. [`erased`] adds the *object-safe* view on top —
//! [`erased::ErasedLearner`] / [`erased::DynModel`] with storage-reusing
//! `clone_from_dyn` — so heterogeneous learner collections (the
//! coordinator's registry, `repro select`) can schedule runs of different
//! families through one executor pool. The erased path delegates to the
//! same engine code via [`erased::DynLearner`], so its results are
//! bit-identical to the generic path, learner by learner.

pub mod erased;
pub mod histdensity;
pub mod kmeans;
pub mod knn;
pub mod linalg;
pub mod lsqsgd;
pub mod multiset;
pub mod naive_bayes;
pub mod pegasos;
pub mod perceptron;
pub mod ridge;

use crate::data::Dataset;

/// An incremental learning algorithm, in the paper's sense.
///
/// `update` must treat the index slice as an *ordered* sequence: online
/// learners visit points in exactly the given order (the CV engines control
/// ordering to reproduce the paper's fixed vs randomized variants).
pub trait IncrementalLearner {
    /// Trained model state (the paper allows "padding" models with internal
    /// state such as step counters; that lives here too).
    type Model: Clone + Send;
    /// Token holding enough information to revert one `update_logged` call.
    type Undo: Send;

    /// Short human-readable name for reports.
    fn name(&self) -> &'static str;

    /// Expected feature dimension.
    fn dim(&self) -> usize;

    /// The empty model `∅` — what `L(∅, Z)` starts from.
    fn init(&self) -> Self::Model;

    /// Incremental update: feed the points `data[idx]`, in order, into the
    /// model.
    ///
    /// Contract: updates must be *call-splittable* —
    /// `update(m, A); update(m, B)` must equal `update(m, A ++ B)` — the
    /// defining property of an incremental learner (the paper's
    /// `L(L(m, A), B) = L(m, A ++ B)`), which every engine relies on and
    /// the fold-contiguous standard-CV path exploits by feeding "all but
    /// fold i" as two contiguous blocks. Learners with per-call batch
    /// structure (e.g. device-padded block execution) must make the
    /// split invisible in their results.
    fn update(&self, model: &mut Self::Model, data: &Dataset, idx: &[u32]);

    /// Like [`update`](Self::update), but records an undo token so the
    /// caller can restore the pre-update model (the paper's save/revert
    /// strategy, §4.1). Default implementations in concrete learners either
    /// snapshot the model (compact models) or log the touched state
    /// (sparse-update models).
    fn update_logged(&self, model: &mut Self::Model, data: &Dataset, idx: &[u32]) -> Self::Undo;

    /// Restore the model to its state before the matching
    /// [`update_logged`](Self::update_logged) call. `data` is the same
    /// dataset the update saw — sparse undo logs (e.g. the perceptron's
    /// mistake list) re-fetch rows from it instead of storing them.
    fn revert(&self, model: &mut Self::Model, data: &Dataset, undo: Self::Undo);

    /// The paper's `ℓ(f(x_i), x_i, y_i)` for a single held-out point.
    fn loss(&self, model: &Self::Model, data: &Dataset, i: u32) -> f64;

    /// Mean loss over a held-out chunk (`R_i` in the paper). Learners with
    /// amortizable per-chunk work (e.g. lazily solved ridge, batched XLA
    /// execution) override this.
    fn evaluate(&self, model: &Self::Model, data: &Dataset, idx: &[u32]) -> f64 {
        if idx.is_empty() {
            return 0.0;
        }
        let mut s = 0f64;
        for &i in idx {
            s += self.loss(model, data, i);
        }
        s / idx.len() as f64
    }

    /// Contiguous fast path for [`update`](Self::update): feed the
    /// `ids.len()` points whose features are the row-major block `x`
    /// (`ids.len() × dim`) and whose outcomes are `y`, in slice order.
    ///
    /// Contract (upheld by [`crate::data::folded::FoldedDataset`], the
    /// only producer): the slices are a materialized copy of rows `ids`
    /// of `data` — `x[j·d..(j+1)·d] == data.row(ids[j])` and
    /// `y[j] == data.label(ids[j])` for every `j`. Implementations MUST
    /// compute exactly what the indexed [`update`](Self::update) would
    /// compute for `ids`; the engines' cross-layout bit-identity
    /// guarantees depend on it. The default forwards to the indexed path
    /// (correct for every learner, including index-dependent models like
    /// k-NN's training-index set); the dense learners override it so
    /// their inner loops sweep `x` linearly at memory bandwidth.
    fn update_rows(
        &self,
        model: &mut Self::Model,
        x: &[f32],
        y: &[f32],
        data: &Dataset,
        ids: &[u32],
    ) {
        let _ = (x, y);
        self.update(model, data, ids);
    }

    /// Contiguous fast path for [`evaluate`](Self::evaluate), under the
    /// same slice contract as [`update_rows`](Self::update_rows). The
    /// default forwards to `evaluate` — not a per-point loop — so
    /// per-chunk overrides (ridge's one-shot solve, XLA batching)
    /// survive on the folded layout too.
    fn evaluate_rows(
        &self,
        model: &Self::Model,
        x: &[f32],
        y: &[f32],
        data: &Dataset,
        ids: &[u32],
    ) -> f64 {
        let _ = (x, y);
        self.evaluate(model, data, ids)
    }

    /// Approximate model size in bytes (drives the copy-cost metrics and
    /// the distributed simulation's communication accounting).
    fn model_bytes(&self, model: &Self::Model) -> usize;

    /// Whether this learner supports the approximate-CV one-step
    /// correction ([`ConvexCorrectable`]). The default is `false`; convex
    /// learners that implement [`ConvexCorrectable`] override this to
    /// `true` so engines (and the erased layer) can probe the capability
    /// without specialization.
    fn correctable(&self) -> bool {
        false
    }

    /// Probe-and-apply form of [`ConvexCorrectable::correct_heldout`]:
    /// returns `false` (leaving `model` untouched) when the learner has no
    /// correction, `true` after applying it. Convex learners override both
    /// this and [`correctable`](Self::correctable); the pair must agree.
    fn try_correct_heldout(&self, model: &mut Self::Model, data: &Dataset, idx: &[u32]) -> bool {
        let _ = (model, data, idx);
        false
    }
}

/// Convex learners whose full-data model can be *corrected* into an
/// approximation of the model trained without a held-out block — the
/// one-step Newton/gradient correction of iterative approximate CV
/// (Luo, Ren & Barber; PAPERS.md).
///
/// Contract: `correct_heldout(m, data, idx)` mutates `m`, which was
/// trained on **all** rows of `data`, into an approximation of the model
/// trained on all rows *except* `idx`. Each implementation documents its
/// correction formula and error bound in EXPERIMENTS.md ("Approximate
/// CV"); exact learners over sufficient statistics (ridge) have an
/// *exact* downdate, SGD learners (pegasos, lsqsgd) a first-order one.
/// Implementors must also override the two probe methods on
/// [`IncrementalLearner`] (`correctable` → `true`, `try_correct_heldout`
/// → delegate here) so generic engine code and the erased layer reach
/// the capability without specialization.
pub trait ConvexCorrectable: IncrementalLearner {
    /// Turn the full-data `model` into an approximation of the model
    /// trained without the rows `idx`.
    fn correct_heldout(&self, model: &mut Self::Model, data: &Dataset, idx: &[u32]);
}

/// Learners whose models can be *merged*: `merge(f(A), f(B)) == f(A ∪ B)`.
///
/// This is exactly the (restrictive) assumption of Izbicki [2013], which the
/// paper contrasts against; [`crate::cv::mergecv`] implements that O(n + k)
/// baseline for learners that satisfy it.
pub trait MergeableLearner: IncrementalLearner {
    /// Combine two models trained on disjoint data into one trained on the
    /// union.
    fn merge(&self, a: &Self::Model, b: &Self::Model) -> Self::Model;
}
