//! Gaussian naive Bayes over per-class running sufficient statistics
//! (count, Σx, Σx² per feature). Two roles here:
//!
//! 1. It is *mergeable* — models trained on disjoint data combine by adding
//!    statistics — which is exactly the restrictive assumption of
//!    Izbicki [2013] that the paper's related-work section contrasts
//!    against ("applies only to simple methods, such as Bayesian
//!    classification"). [`crate::cv::mergecv`] uses it to implement that
//!    O(n + k) baseline.
//! 2. Its sufficient statistics are order-insensitive up to f64 rounding,
//!    so TreeCV and standard CV agree to ~1e-12 — a strong near-exactness
//!    check on the tree recursion with a "real" learner.

use super::{linalg, IncrementalLearner, MergeableLearner};
use crate::data::Dataset;
use crate::loss;

/// Gaussian NB trainer for binary labels in {+1, −1}.
#[derive(Debug, Clone)]
pub struct GaussianNb {
    d: usize,
    /// Variance floor to keep log-densities finite.
    pub var_floor: f64,
}

/// Per-class sufficient statistics.
#[derive(Debug, PartialEq)]
pub struct NbClassStats {
    pub count: u64,
    pub sum: Vec<f64>,
    pub sumsq: Vec<f64>,
}

// Hand-written so `clone_from` reuses the target's heap storage (the
// derive's fallback reallocates; the CV engines recycle snapshot buffers).
impl Clone for NbClassStats {
    fn clone(&self) -> Self {
        Self { count: self.count, sum: self.sum.clone(), sumsq: self.sumsq.clone() }
    }

    fn clone_from(&mut self, src: &Self) {
        self.count = src.count;
        self.sum.clone_from(&src.sum);
        self.sumsq.clone_from(&src.sumsq);
    }
}

impl NbClassStats {
    fn new(d: usize) -> Self {
        Self { count: 0, sum: vec![0.0; d], sumsq: vec![0.0; d] }
    }

    // Add/subtract share one signed kernel: `±1·v` is exact and
    // `a − b ≡ a + (−b)` bitwise, so both directions route through
    // `linalg::accumulate_stats` bitwise-unchanged.
    fn add_point(&mut self, x: &[f32]) {
        self.count += 1;
        linalg::accumulate_stats(1.0, x, &mut self.sum, &mut self.sumsq);
    }

    fn sub_point(&mut self, x: &[f32]) {
        self.count -= 1;
        linalg::accumulate_stats(-1.0, x, &mut self.sum, &mut self.sumsq);
    }

    fn add(&mut self, other: &Self) {
        self.count += other.count;
        for j in 0..self.sum.len() {
            self.sum[j] += other.sum[j];
            self.sumsq[j] += other.sumsq[j];
        }
    }
}

/// NB model: statistics for the positive and negative class.
#[derive(Debug, PartialEq)]
pub struct NbModel {
    pub pos: NbClassStats,
    pub neg: NbClassStats,
}

// Delegates to [`NbClassStats`]' storage-reusing `clone_from`.
impl Clone for NbModel {
    fn clone(&self) -> Self {
        Self { pos: self.pos.clone(), neg: self.neg.clone() }
    }

    fn clone_from(&mut self, src: &Self) {
        self.pos.clone_from(&src.pos);
        self.neg.clone_from(&src.neg);
    }
}

impl GaussianNb {
    pub fn new(d: usize) -> Self {
        Self { d, var_floor: 1e-6 }
    }

    /// Class log-posterior difference `log P(+|x) − log P(−|x)` (up to the
    /// shared evidence term).
    pub fn score(&self, m: &NbModel, x: &[f32]) -> f64 {
        let total = (m.pos.count + m.neg.count).max(1) as f64;
        // Laplace-smoothed priors.
        let lp_pos = ((m.pos.count as f64 + 1.0) / (total + 2.0)).ln();
        let lp_neg = ((m.neg.count as f64 + 1.0) / (total + 2.0)).ln();
        let ll = |s: &NbClassStats| -> f64 {
            if s.count == 0 {
                return 0.0; // uninformative class-conditional
            }
            let n = s.count as f64;
            let mut acc = 0.0;
            for j in 0..self.d {
                let mean = s.sum[j] / n;
                let var = (s.sumsq[j] / n - mean * mean).max(self.var_floor);
                let dv = x[j] as f64 - mean;
                acc += -0.5 * (var.ln() + dv * dv / var);
            }
            acc
        };
        (lp_pos + ll(&m.pos)) - (lp_neg + ll(&m.neg))
    }
}

impl IncrementalLearner for GaussianNb {
    type Model = NbModel;
    /// Undo by subtracting the points back out (exact for the counts,
    /// f64-rounding-exact for the sums; the reverse-order replay makes it
    /// bit-exact because fl(fl(a+b)−b) replays the inverse op sequence —
    /// still not guaranteed identical, so exactness tests use tolerance).
    type Undo = Vec<u32>;

    fn name(&self) -> &'static str {
        "gaussian-nb"
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn init(&self) -> NbModel {
        NbModel { pos: NbClassStats::new(self.d), neg: NbClassStats::new(self.d) }
    }

    fn update(&self, m: &mut NbModel, data: &Dataset, idx: &[u32]) {
        for &i in idx {
            if data.label(i) > 0.0 {
                m.pos.add_point(data.row(i));
            } else {
                m.neg.add_point(data.row(i));
            }
        }
    }

    /// Contiguous fast path: identical per-point accumulation over a
    /// row-major slice (folded-layout contract, bit-identical).
    fn update_rows(&self, m: &mut NbModel, x: &[f32], y: &[f32], _data: &Dataset, _ids: &[u32]) {
        debug_assert_eq!(x.len(), y.len() * self.d);
        for (row, &yi) in x.chunks_exact(self.d).zip(y) {
            if yi > 0.0 {
                m.pos.add_point(row);
            } else {
                m.neg.add_point(row);
            }
        }
    }

    fn update_logged(&self, m: &mut NbModel, data: &Dataset, idx: &[u32]) -> Vec<u32> {
        self.update(m, data, idx);
        idx.to_vec()
    }

    fn revert(&self, m: &mut NbModel, data: &Dataset, undo: Vec<u32>) {
        for &i in undo.iter().rev() {
            if data.label(i) > 0.0 {
                m.pos.sub_point(data.row(i));
            } else {
                m.neg.sub_point(data.row(i));
            }
        }
    }

    fn loss(&self, m: &NbModel, data: &Dataset, i: u32) -> f64 {
        let s = self.score(m, data.row(i)) as f32;
        loss::misclassification(s, data.label(i))
    }

    fn evaluate_rows(
        &self,
        m: &NbModel,
        x: &[f32],
        y: &[f32],
        _data: &Dataset,
        _ids: &[u32],
    ) -> f64 {
        if y.is_empty() {
            return 0.0;
        }
        let mut s = 0f64;
        for (row, &yi) in x.chunks_exact(self.d).zip(y) {
            s += loss::misclassification(self.score(m, row) as f32, yi);
        }
        s / y.len() as f64
    }

    fn model_bytes(&self, _m: &NbModel) -> usize {
        2 * (self.d * 16 + 8)
    }
}

impl MergeableLearner for GaussianNb {
    fn merge(&self, a: &NbModel, b: &NbModel) -> NbModel {
        let mut out = a.clone();
        out.pos.add(&b.pos);
        out.neg.add(&b.neg);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SyntheticCovertype;

    #[test]
    fn classifies_shifted_gaussians() {
        // Two well-separated classes.
        let n = 1_000;
        let mut x = Vec::new();
        let mut y = Vec::new();
        let mut rng = crate::rng::Rng::new(61);
        for i in 0..n {
            let s = if i % 2 == 0 { 1.0f32 } else { -1.0 };
            x.push(3.0 * s + rng.next_gaussian());
            x.push(-2.0 * s + rng.next_gaussian());
            y.push(s);
        }
        let data = Dataset::new(x, y, 2);
        let l = GaussianNb::new(2);
        let mut m = l.init();
        let idx: Vec<u32> = (0..n as u32).collect();
        l.update(&mut m, &data, &idx);
        let err = l.evaluate(&m, &data, &idx);
        assert!(err < 0.02, "error {err}");
    }

    #[test]
    fn order_insensitive_to_tolerance() {
        let data = SyntheticCovertype::new(500, 62).generate();
        let l = GaussianNb::new(54);
        let fwd: Vec<u32> = (0..500).collect();
        let mut rev = fwd.clone();
        rev.reverse();
        let mut a = l.init();
        let mut b = l.init();
        l.update(&mut a, &data, &fwd);
        l.update(&mut b, &data, &rev);
        assert_eq!(a.pos.count, b.pos.count);
        for j in 0..54 {
            assert!((a.pos.sum[j] - b.pos.sum[j]).abs() < 1e-9);
            assert!((a.neg.sumsq[j] - b.neg.sumsq[j]).abs() < 1e-7);
        }
    }

    #[test]
    fn merge_equals_joint_training() {
        let data = SyntheticCovertype::new(600, 63).generate();
        let l = GaussianNb::new(54);
        let mut a = l.init();
        let mut b = l.init();
        let mut joint = l.init();
        l.update(&mut a, &data, &(0..300).collect::<Vec<_>>());
        l.update(&mut b, &data, &(300..600).collect::<Vec<_>>());
        l.update(&mut joint, &data, &(0..600).collect::<Vec<_>>());
        let merged = l.merge(&a, &b);
        assert_eq!(merged.pos.count, joint.pos.count);
        for j in 0..54 {
            assert!((merged.pos.sum[j] - joint.pos.sum[j]).abs() < 1e-9);
        }
    }

    #[test]
    fn revert_restores_counts_and_sums() {
        let data = SyntheticCovertype::new(300, 64).generate();
        let l = GaussianNb::new(54);
        let mut m = l.init();
        l.update(&mut m, &data, &(0..150).collect::<Vec<_>>());
        let before = m.clone();
        let undo = l.update_logged(&mut m, &data, &(150..300).collect::<Vec<_>>());
        l.revert(&mut m, &data, undo);
        assert_eq!(m.pos.count, before.pos.count);
        assert_eq!(m.neg.count, before.neg.count);
        for j in 0..54 {
            assert!((m.pos.sum[j] - before.pos.sum[j]).abs() < 1e-9);
            assert!((m.neg.sumsq[j] - before.neg.sumsq[j]).abs() < 1e-7);
        }
    }

    #[test]
    fn contiguous_fast_path_is_bit_identical() {
        let data = SyntheticCovertype::new(240, 65).generate();
        let idx: Vec<u32> = (0..200).collect();
        let block = data.subset(&idx);
        let l = GaussianNb::new(54);
        let mut a = l.init();
        l.update(&mut a, &data, &idx);
        let mut b = l.init();
        l.update_rows(&mut b, &block.x, &block.y, &data, &idx);
        assert_eq!(a, b);
        let held: Vec<u32> = (200..240).collect();
        let hb = data.subset(&held);
        let fast = l.evaluate_rows(&a, &hb.x, &hb.y, &data, &held);
        assert_eq!(l.evaluate(&a, &data, &held).to_bits(), fast.to_bits());
    }

    #[test]
    fn empty_class_does_not_nan() {
        let data = Dataset::new(vec![1.0, 2.0], vec![1.0, 1.0], 1);
        let l = GaussianNb::new(1);
        let mut m = l.init();
        l.update(&mut m, &data, &[0, 1]);
        assert!(l.score(&m, &[1.5]).is_finite());
    }
}
