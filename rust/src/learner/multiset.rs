//! A structural test oracle: the "model" is the exact multiset of dataset
//! indices it was trained on, and the loss of a held-out point is a
//! deterministic hash of (training multiset, point index).
//!
//! Because the model depends only on the *set* of points (not their order
//! or batching), this learner is exactly incrementally stable (g ≡ 0), and
//! by the paper's Theorem 1 TreeCV must produce bit-for-bit the standard
//! k-CV estimate. More importantly, it lets the test suite assert the
//! *defining invariant* of Algorithm 1: the model evaluated at leaf `i`
//! was trained on exactly `Z \ Z_i` — every chunk except the held-out one,
//! each point exactly once. Any scheduling bug in the tree recursion
//! (wrong half updated, missed restore, double update) breaks this
//! immediately and observably.

use super::IncrementalLearner;
use crate::data::Dataset;

/// The oracle learner. `dim` is free; it never reads features.
#[derive(Debug, Clone)]
pub struct MultisetLearner {
    d: usize,
}

/// Model: indices seen, in arrival order (so order effects are detectable
/// by tests that want them), plus a running count.
#[derive(Debug, Default, PartialEq, Eq)]
pub struct MultisetModel {
    pub seen: Vec<u32>,
}

// Hand-written so `clone_from` reuses the target's heap storage (the
// derive's fallback reallocates; the CV engines recycle snapshot buffers).
impl Clone for MultisetModel {
    fn clone(&self) -> Self {
        Self { seen: self.seen.clone() }
    }

    fn clone_from(&mut self, src: &Self) {
        self.seen.clone_from(&src.seen);
    }
}

impl MultisetModel {
    /// The canonical (sorted) multiset of trained indices.
    pub fn sorted(&self) -> Vec<u32> {
        let mut s = self.seen.clone();
        s.sort_unstable();
        s
    }
}

impl MultisetLearner {
    pub fn new(d: usize) -> Self {
        Self { d }
    }

    /// Order-insensitive 64-bit fingerprint of the training multiset.
    pub fn fingerprint(model: &MultisetModel) -> u64 {
        // Sum of per-element hashes: commutative ⇒ order-insensitive.
        model.seen.iter().fold(0u64, |acc, &i| {
            let mut h = i as u64 + 0x9E3779B97F4A7C15;
            h = (h ^ (h >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            h = (h ^ (h >> 27)).wrapping_mul(0x94D049BB133111EB);
            acc.wrapping_add(h ^ (h >> 31))
        })
    }
}

impl IncrementalLearner for MultisetLearner {
    type Model = MultisetModel;
    type Undo = usize; // number of points appended

    fn name(&self) -> &'static str {
        "multiset-oracle"
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn init(&self) -> MultisetModel {
        MultisetModel::default()
    }

    fn update(&self, m: &mut MultisetModel, _data: &Dataset, idx: &[u32]) {
        m.seen.extend_from_slice(idx);
    }

    fn update_logged(&self, m: &mut MultisetModel, _data: &Dataset, idx: &[u32]) -> usize {
        m.seen.extend_from_slice(idx);
        idx.len()
    }

    fn revert(&self, m: &mut MultisetModel, _data: &Dataset, undo: usize) {
        m.seen.truncate(m.seen.len() - undo);
    }

    fn loss(&self, m: &MultisetModel, _data: &Dataset, i: u32) -> f64 {
        // Deterministic in (training multiset, i); maps to [0, 1).
        let h = Self::fingerprint(m) ^ (i as u64).wrapping_mul(0xD1B54A32D192ED03);
        (h >> 11) as f64 / (1u64 << 53) as f64
    }

    fn model_bytes(&self, m: &MultisetModel) -> usize {
        m.seen.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dummy(n: usize) -> Dataset {
        Dataset::new(vec![0.0; n], vec![0.0; n], 1)
    }

    #[test]
    fn update_appends() {
        let l = MultisetLearner::new(1);
        let d = dummy(10);
        let mut m = l.init();
        l.update(&mut m, &d, &[3, 1]);
        l.update(&mut m, &d, &[2]);
        assert_eq!(m.seen, vec![3, 1, 2]);
        assert_eq!(m.sorted(), vec![1, 2, 3]);
    }

    #[test]
    fn fingerprint_order_insensitive() {
        let a = MultisetModel { seen: vec![1, 2, 3] };
        let b = MultisetModel { seen: vec![3, 1, 2] };
        let c = MultisetModel { seen: vec![1, 2, 4] };
        assert_eq!(MultisetLearner::fingerprint(&a), MultisetLearner::fingerprint(&b));
        assert_ne!(MultisetLearner::fingerprint(&a), MultisetLearner::fingerprint(&c));
    }

    #[test]
    fn fingerprint_sees_multiplicity() {
        let a = MultisetModel { seen: vec![1, 1, 2] };
        let b = MultisetModel { seen: vec![1, 2] };
        assert_ne!(MultisetLearner::fingerprint(&a), MultisetLearner::fingerprint(&b));
    }

    #[test]
    fn revert_truncates() {
        let l = MultisetLearner::new(1);
        let d = dummy(10);
        let mut m = l.init();
        l.update(&mut m, &d, &[5, 6]);
        let undo = l.update_logged(&mut m, &d, &[7, 8, 9]);
        l.revert(&mut m, &d, undo);
        assert_eq!(m.seen, vec![5, 6]);
    }

    #[test]
    fn loss_depends_on_set_and_point() {
        let l = MultisetLearner::new(1);
        let d = dummy(10);
        let m1 = MultisetModel { seen: vec![1, 2] };
        let m2 = MultisetModel { seen: vec![1, 3] };
        assert_ne!(l.loss(&m1, &d, 0), l.loss(&m2, &d, 0));
        assert_ne!(l.loss(&m1, &d, 0), l.loss(&m1, &d, 1));
        // And is in [0,1).
        assert!((0.0..1.0).contains(&l.loss(&m1, &d, 0)));
    }
}
