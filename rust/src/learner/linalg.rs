//! The kernel layer: small dense linear-algebra kernels shared by the
//! pure-Rust learners and the exact-LOOCV comparator, with runtime backend
//! dispatch. These are the L3 hot path for the large-`n` experiments (the
//! XLA artifacts cover the L1/L2 path), so they are allocation-free and run
//! either as explicit AVX2 SIMD or as lane-structured scalar code.
//!
//! # Dispatch
//!
//! Every public kernel is a thin wrapper that consults a process-wide
//! backend cache ([`kernel_backend`], a one-time `is_x86_feature_detected!`
//! probe stored in an atomic) and forwards to one of two implementations:
//!
//! | backend  | module     | where                                        |
//! |----------|------------|----------------------------------------------|
//! | `avx2`   | [`avx2`]   | x86-64 with AVX2+FMA, detected at runtime    |
//! | `scalar` | [`scalar`] | everywhere else (and `TREECV_KERNEL_BACKEND=scalar`) |
//!
//! The `TREECV_KERNEL_BACKEND=scalar` environment variable (read once, at
//! first dispatch) forces the scalar backend; [`force_backend`] does the
//! same programmatically for tests and benches. The selected backend is
//! surfaced in every report via `OpCounts::kernel_backend`.
//!
//! # Equivalence contract
//!
//! The two backends are **bit-identical**: for every kernel, the AVX2 path
//! keeps its per-lane accumulators in the same lane structure as the scalar
//! path (eight f32 lanes for [`dot`], four f64 lanes for the widening
//! kernels), spills them to an array, and applies the exact same scalar
//! reduction tree and sequential remainder loop. Multiplies and adds stay
//! separate instructions (never FMA-contracted) because the scalar path
//! cannot contract. The block kernels ([`dot_block`], [`sq_dist_block`],
//! [`syrk_accumulate`]) are bitwise equal to their row-at-a-time
//! counterparts for every block size: blocking only reorders *independent*
//! rows/centers, never the additions inside one accumulator. The unit
//! battery below pins all of this across remainder-lane dimensions, and
//! `tests/integration_layout.rs` pins that dispatch is invisible to every
//! engine × strategy × ordering result.
//!
//! # Blocking parameters
//!
//! [`SYRK_BLOCK_ROWS`], [`EVAL_BLOCK_ROWS`] and [`ASSIGN_BLOCK_CENTERS`]
//! are the cache-blocking sizes the learners use; `benches/kernels.rs`
//! records them (plus the active backend) in `BENCH_kernels.json`.

use crate::sync::{AtomicU64, Ordering};

/// Row-block size for [`syrk_accumulate`]: ridge's `A += XᵀX` sweeps each
/// row of `A` once per block of this many points instead of once per point.
pub const SYRK_BLOCK_ROWS: usize = 16;

/// Row-block size the dense learners use when staging `evaluate_rows`
/// through [`dot_block`] (scores buffer lives on the stack).
pub const EVAL_BLOCK_ROWS: usize = 64;

/// Center-block size for kmeans assignment via [`sq_dist_block`] (distance
/// buffer lives on the stack; the query point stays register/L1-resident).
pub const ASSIGN_BLOCK_CENTERS: usize = 32;

/// The kernel backend in effect (process-wide, resolved once).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelBackend {
    /// Lane-structured portable kernels — the bit-exactness specification.
    Scalar,
    /// Explicit AVX2 kernels (x86-64 only, runtime-detected), bit-identical
    /// to [`KernelBackend::Scalar`] by the equivalence contract above.
    Avx2,
}

impl KernelBackend {
    /// Stable lowercase name used in reports and bench JSON.
    pub fn name(self) -> &'static str {
        match self {
            KernelBackend::Scalar => "scalar",
            KernelBackend::Avx2 => "avx2",
        }
    }
}

const BACKEND_UNRESOLVED: u64 = 0;
const BACKEND_SCALAR: u64 = 1;
const BACKEND_AVX2: u64 = 2;

/// One-time backend cache. 0 = unresolved; the first [`kernel_backend`]
/// call runs feature detection (+ env override) and stores the result.
static BACKEND: AtomicU64 = AtomicU64::new(BACKEND_UNRESOLVED);

/// The backend every kernel wrapper dispatches on. Resolves (feature probe
/// + `TREECV_KERNEL_BACKEND` override) on first call, then a relaxed load.
#[inline]
pub fn kernel_backend() -> KernelBackend {
    match BACKEND.load(Ordering::Relaxed) {
        BACKEND_SCALAR => KernelBackend::Scalar,
        BACKEND_AVX2 => KernelBackend::Avx2,
        _ => resolve_backend(),
    }
}

/// Name of the backend in effect (resolving it on first call).
pub fn backend_name() -> &'static str {
    kernel_backend().name()
}

#[cold]
fn resolve_backend() -> KernelBackend {
    let over = std::env::var("TREECV_KERNEL_BACKEND").ok();
    let b = backend_from_override(over.as_deref(), avx2_available());
    force_backend(b);
    b
}

/// Pure override-resolution rule (unit-tested): `Some("scalar")` forces the
/// scalar backend; any other value (or none) selects AVX2 iff the CPU
/// supports it.
pub fn backend_from_override(over: Option<&str>, avx2: bool) -> KernelBackend {
    if over == Some("scalar") || !avx2 {
        KernelBackend::Scalar
    } else {
        KernelBackend::Avx2
    }
}

/// Whether the AVX2 kernels can run on this CPU (AVX2 + FMA probe; FMA is
/// required by the dispatch contract even though the kernels never contract,
/// so a future fused variant cannot silently change the detection story).
pub fn avx2_available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
            return true;
        }
    }
    false
}

/// Force the kernel backend (tests / benches / CI legs). Safe to call at
/// any point mid-run because the backends are bit-identical — flipping the
/// backend can never change a result. Callers selecting
/// [`KernelBackend::Avx2`] must have checked [`avx2_available`] first.
pub fn force_backend(b: KernelBackend) {
    let code = match b {
        KernelBackend::Scalar => BACKEND_SCALAR,
        KernelBackend::Avx2 => BACKEND_AVX2,
    };
    BACKEND.store(code, Ordering::Relaxed);
}

/// Dot product `⟨a, b⟩` in f32 — the single hottest operation in the whole
/// system (PEGASOS margin checks + all evaluations); see EXPERIMENTS.md
/// §Kernel layer.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    #[cfg(target_arch = "x86_64")]
    {
        if kernel_backend() == KernelBackend::Avx2 {
            // SAFETY: Avx2 is only selected after runtime feature detection.
            return unsafe { avx2::dot(a, b) };
        }
    }
    scalar::dot(a, b)
}

/// `y += alpha * x`.
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    #[cfg(target_arch = "x86_64")]
    {
        if kernel_backend() == KernelBackend::Avx2 {
            // SAFETY: Avx2 is only selected after runtime feature detection.
            return unsafe { avx2::axpy(alpha, x, y) };
        }
    }
    scalar::axpy(alpha, x, y)
}

/// `y *= alpha`.
#[inline]
pub fn scale(alpha: f32, y: &mut [f32]) {
    #[cfg(target_arch = "x86_64")]
    {
        if kernel_backend() == KernelBackend::Avx2 {
            // SAFETY: Avx2 is only selected after runtime feature detection.
            return unsafe { avx2::scale(alpha, y) };
        }
    }
    scalar::scale(alpha, y)
}

/// Squared l2 norm, f64 accumulator (used for projections and regularizers
/// where drift matters).
#[inline]
pub fn norm_sq(a: &[f32]) -> f64 {
    #[cfg(target_arch = "x86_64")]
    {
        if kernel_backend() == KernelBackend::Avx2 {
            // SAFETY: Avx2 is only selected after runtime feature detection.
            return unsafe { avx2::norm_sq(a) };
        }
    }
    scalar::norm_sq(a)
}

/// Squared euclidean distance `||a - b||²`, f64 accumulator (subtraction in
/// f32, then widened — see the scalar kernel for the exact structure).
#[inline]
pub fn dist_sq(a: &[f32], b: &[f32]) -> f64 {
    #[cfg(target_arch = "x86_64")]
    {
        if kernel_backend() == KernelBackend::Avx2 {
            // SAFETY: Avx2 is only selected after runtime feature detection.
            return unsafe { avx2::dist_sq(a, b) };
        }
    }
    scalar::dist_sq(a, b)
}

/// Mixed-precision dot `Σ w[j] · (x[j] as f64)` — ridge predictions (f64
/// weights against f32 rows).
#[inline]
pub fn dot_f64f32(w: &[f64], x: &[f32]) -> f64 {
    #[cfg(target_arch = "x86_64")]
    {
        if kernel_backend() == KernelBackend::Avx2 {
            // SAFETY: Avx2 is only selected after runtime feature detection.
            return unsafe { avx2::dot_f64f32(w, x) };
        }
    }
    scalar::dot_f64f32(w, x)
}

/// Mixed-precision axpy `y[j] += alpha * (x[j] as f64)` — ridge
/// sufficient-stats rows (f64 accumulators fed by f32 points).
#[inline]
pub fn axpy_f64f32(alpha: f64, x: &[f32], y: &mut [f64]) {
    #[cfg(target_arch = "x86_64")]
    {
        if kernel_backend() == KernelBackend::Avx2 {
            // SAFETY: Avx2 is only selected after runtime feature detection.
            return unsafe { avx2::axpy_f64f32(alpha, x, y) };
        }
    }
    scalar::axpy_f64f32(alpha, x, y)
}

/// Running-average relaxation `y[j] += alpha * (x[j] - y[j])` — lsqsgd's
/// iterate averaging and kmeans' center update share this form.
#[inline]
pub fn avg_update(alpha: f32, x: &[f32], y: &mut [f32]) {
    #[cfg(target_arch = "x86_64")]
    {
        if kernel_backend() == KernelBackend::Avx2 {
            // SAFETY: Avx2 is only selected after runtime feature detection.
            return unsafe { avx2::avg_update(alpha, x, y) };
        }
    }
    scalar::avg_update(alpha, x, y)
}

/// Signed per-feature moment accumulation for naive Bayes:
/// `sum[j] += sign·v` and `sumsq[j] += sign·(v·v)` with `v = x[j] as f64`.
/// `sign` is ±1.0, so add and subtract (`a − b ≡ a + (−b)` exactly) share
/// one kernel.
#[inline]
pub fn accumulate_stats(sign: f64, x: &[f32], sum: &mut [f64], sumsq: &mut [f64]) {
    #[cfg(target_arch = "x86_64")]
    {
        if kernel_backend() == KernelBackend::Avx2 {
            // SAFETY: Avx2 is only selected after runtime feature detection.
            return unsafe { avx2::accumulate_stats(sign, x, sum, sumsq) };
        }
    }
    scalar::accumulate_stats(sign, x, sum, sumsq)
}

/// Fused block dot: `out[r] = ⟨w, xs[r·d .. (r+1)·d]⟩` for each row of a
/// contiguous row-major block — the weight vector is loaded once per block
/// of rows instead of once per row. Bitwise equal to calling [`dot`] per
/// row.
#[inline]
pub fn dot_block(w: &[f32], xs: &[f32], d: usize, out: &mut [f32]) {
    debug_assert_eq!(w.len(), d);
    debug_assert_eq!(xs.len(), d * out.len());
    if d == 0 {
        out.fill(0.0);
        return;
    }
    #[cfg(target_arch = "x86_64")]
    {
        if kernel_backend() == KernelBackend::Avx2 {
            // SAFETY: Avx2 is only selected after runtime feature detection.
            return unsafe { avx2::dot_block(w, xs, d, out) };
        }
    }
    scalar::dot_block(w, xs, d, out)
}

/// Mixed-precision block dot (`out[r] = Σ_j w[j]·(xs[r·d+j] as f64)`) for
/// ridge's `evaluate_rows`. Bitwise equal to [`dot_f64f32`] per row.
#[inline]
pub fn dot_block_f64f32(w: &[f64], xs: &[f32], d: usize, out: &mut [f64]) {
    debug_assert_eq!(w.len(), d);
    debug_assert_eq!(xs.len(), d * out.len());
    if d == 0 {
        out.fill(0.0);
        return;
    }
    #[cfg(target_arch = "x86_64")]
    {
        if kernel_backend() == KernelBackend::Avx2 {
            // SAFETY: Avx2 is only selected after runtime feature detection.
            return unsafe { avx2::dot_block_f64f32(w, xs, d, out) };
        }
    }
    scalar::dot_block_f64f32(w, xs, d, out)
}

/// Fused assignment distances: `out[c] = ||x − centers[c·d..(c+1)·d]||²`
/// for a contiguous block of centers; the query point stays resident while
/// the center block streams through. Bitwise equal to [`dist_sq`] per
/// center.
#[inline]
pub fn sq_dist_block(x: &[f32], centers: &[f32], d: usize, out: &mut [f64]) {
    debug_assert_eq!(x.len(), d);
    debug_assert_eq!(centers.len(), d * out.len());
    if d == 0 {
        out.fill(0.0);
        return;
    }
    #[cfg(target_arch = "x86_64")]
    {
        if kernel_backend() == KernelBackend::Avx2 {
            // SAFETY: Avx2 is only selected after runtime feature detection.
            return unsafe { avx2::sq_dist_block(x, centers, d, out) };
        }
    }
    scalar::sq_dist_block(x, centers, d, out)
}

/// Cache-blocked rank-B update `A += Σ_r x_r x_rᵀ` over the row-major point
/// block `xs` (each row length `d`, `A` dense `d × d` f64) with the default
/// [`SYRK_BLOCK_ROWS`] blocking. Bitwise equal to the per-point rank-one
/// sequence in row order — see [`syrk_accumulate_blocked`].
#[inline]
pub fn syrk_accumulate(a: &mut [f64], d: usize, xs: &[f32]) {
    syrk_accumulate_blocked(a, d, xs, SYRK_BLOCK_ROWS);
}

/// [`syrk_accumulate`] with an explicit block size (exposed so the unit
/// battery and benches can pin blocked ≡ unblocked).
///
/// Bit-identity for every `block_rows`: element `a[i][j]` receives exactly
/// the additions `(x_r[i] as f64) · (x_r[j] as f64)` in globally ascending
/// row order `r` — the loop nest (block → i → row-in-block → j) never
/// reorders the adds landing on any single accumulator, it only reorders
/// *between* accumulators. Blocking wins because each row of `A` is swept
/// once per block of points instead of once per point.
pub fn syrk_accumulate_blocked(a: &mut [f64], d: usize, xs: &[f32], block_rows: usize) {
    debug_assert_eq!(a.len(), d * d);
    debug_assert!(block_rows > 0);
    if d == 0 || xs.is_empty() {
        return;
    }
    debug_assert_eq!(xs.len() % d, 0);
    for block in xs.chunks(block_rows * d) {
        for i in 0..d {
            let arow = &mut a[i * d..(i + 1) * d];
            for row in block.chunks_exact(d) {
                axpy_f64f32(row[i] as f64, row, arow);
            }
        }
    }
}

/// Lane-structured portable kernels — the bit-exactness specification every
/// other backend must match. The reduction kernels keep N independent
/// accumulator lanes (breaking the serial FP dependency chain so LLVM can
/// autovectorize under strict FP semantics), then combine them with a fixed
/// reduction tree and run the remainder sequentially; the elementwise
/// kernels are chunked the same way so the fallback autovectorizes too.
pub mod scalar {
    /// Eight-lane f32 dot; lanes reduce as `((0+4)+(1+5)) + ((2+6)+(3+7))`.
    #[inline(always)]
    pub fn dot(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let mut acc = [0f32; 8];
        let ca = a.chunks_exact(8);
        let cb = b.chunks_exact(8);
        let (ra, rb) = (ca.remainder(), cb.remainder());
        for (xa, xb) in ca.zip(cb) {
            for l in 0..8 {
                acc[l] += xa[l] * xb[l];
            }
        }
        let mut s = ((acc[0] + acc[4]) + (acc[1] + acc[5]))
            + ((acc[2] + acc[6]) + (acc[3] + acc[7]));
        for (xa, xb) in ra.iter().zip(rb) {
            s += xa * xb;
        }
        s
    }

    /// `y += alpha * x`, eight-wide chunks (elementwise, so bitwise equal
    /// to the naive loop at any chunking).
    #[inline(always)]
    pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
        debug_assert_eq!(x.len(), y.len());
        let mut cy = y.chunks_exact_mut(8);
        let mut cx = x.chunks_exact(8);
        for (ya, xa) in (&mut cy).zip(&mut cx) {
            for l in 0..8 {
                ya[l] += alpha * xa[l];
            }
        }
        for (yv, xv) in cy.into_remainder().iter_mut().zip(cx.remainder()) {
            *yv += alpha * xv;
        }
    }

    /// `y *= alpha`, eight-wide chunks.
    #[inline(always)]
    pub fn scale(alpha: f32, y: &mut [f32]) {
        let mut cy = y.chunks_exact_mut(8);
        for ya in &mut cy {
            for v in ya.iter_mut() {
                *v *= alpha;
            }
        }
        for v in cy.into_remainder() {
            *v *= alpha;
        }
    }

    /// Four-lane f64 squared norm; lanes reduce as `(0+2) + (1+3)`.
    #[inline(always)]
    pub fn norm_sq(a: &[f32]) -> f64 {
        let mut acc = [0f64; 4];
        let ca = a.chunks_exact(4);
        let r = ca.remainder();
        for xa in ca {
            for l in 0..4 {
                let v = xa[l] as f64;
                acc[l] += v * v;
            }
        }
        let mut s = (acc[0] + acc[2]) + (acc[1] + acc[3]);
        for &v in r {
            s += (v as f64) * (v as f64);
        }
        s
    }

    /// Four-lane f64 squared distance: subtract in f32, then widen (the
    /// widening point is part of the bit contract).
    #[inline(always)]
    pub fn dist_sq(a: &[f32], b: &[f32]) -> f64 {
        debug_assert_eq!(a.len(), b.len());
        let mut acc = [0f64; 4];
        let ca = a.chunks_exact(4);
        let cb = b.chunks_exact(4);
        let (ra, rb) = (ca.remainder(), cb.remainder());
        for (xa, xb) in ca.zip(cb) {
            for l in 0..4 {
                let d = (xa[l] - xb[l]) as f64;
                acc[l] += d * d;
            }
        }
        let mut s = (acc[0] + acc[2]) + (acc[1] + acc[3]);
        for (xa, xb) in ra.iter().zip(rb) {
            let d = (xa - xb) as f64;
            s += d * d;
        }
        s
    }

    /// Four-lane mixed-precision dot; lanes reduce as `(0+2) + (1+3)`.
    #[inline(always)]
    pub fn dot_f64f32(w: &[f64], x: &[f32]) -> f64 {
        debug_assert_eq!(w.len(), x.len());
        let mut acc = [0f64; 4];
        let cw = w.chunks_exact(4);
        let cx = x.chunks_exact(4);
        let (rw, rx) = (cw.remainder(), cx.remainder());
        for (wa, xa) in cw.zip(cx) {
            for l in 0..4 {
                acc[l] += wa[l] * (xa[l] as f64);
            }
        }
        let mut s = (acc[0] + acc[2]) + (acc[1] + acc[3]);
        for (wv, &xv) in rw.iter().zip(rx) {
            s += wv * (xv as f64);
        }
        s
    }

    /// Mixed-precision axpy, four-wide chunks (elementwise).
    #[inline(always)]
    pub fn axpy_f64f32(alpha: f64, x: &[f32], y: &mut [f64]) {
        debug_assert_eq!(x.len(), y.len());
        let mut cy = y.chunks_exact_mut(4);
        let mut cx = x.chunks_exact(4);
        for (ya, xa) in (&mut cy).zip(&mut cx) {
            for l in 0..4 {
                ya[l] += alpha * (xa[l] as f64);
            }
        }
        for (yv, &xv) in cy.into_remainder().iter_mut().zip(cx.remainder()) {
            *yv += alpha * (xv as f64);
        }
    }

    /// `y[j] += alpha * (x[j] - y[j])`, eight-wide chunks (elementwise).
    #[inline(always)]
    pub fn avg_update(alpha: f32, x: &[f32], y: &mut [f32]) {
        debug_assert_eq!(x.len(), y.len());
        let mut cy = y.chunks_exact_mut(8);
        let mut cx = x.chunks_exact(8);
        for (ya, xa) in (&mut cy).zip(&mut cx) {
            for l in 0..8 {
                ya[l] += alpha * (xa[l] - ya[l]);
            }
        }
        for (yv, &xv) in cy.into_remainder().iter_mut().zip(cx.remainder()) {
            *yv += alpha * (xv - *yv);
        }
    }

    /// Signed moment accumulation, four-wide chunks (elementwise).
    #[inline(always)]
    pub fn accumulate_stats(sign: f64, x: &[f32], sum: &mut [f64], sumsq: &mut [f64]) {
        debug_assert_eq!(x.len(), sum.len());
        debug_assert_eq!(x.len(), sumsq.len());
        let mut cs = sum.chunks_exact_mut(4);
        let mut cq = sumsq.chunks_exact_mut(4);
        let mut cx = x.chunks_exact(4);
        for ((sa, qa), xa) in (&mut cs).zip(&mut cq).zip(&mut cx) {
            for l in 0..4 {
                let v = xa[l] as f64;
                sa[l] += sign * v;
                qa[l] += sign * (v * v);
            }
        }
        let sr = cs.into_remainder().iter_mut();
        let qr = cq.into_remainder().iter_mut();
        for ((sv, qv), &xv) in sr.zip(qr).zip(cx.remainder()) {
            let v = xv as f64;
            *sv += sign * v;
            *qv += sign * (v * v);
        }
    }

    /// Row-at-a-time block dot (the blocked AVX2 variant must match this
    /// bitwise).
    #[inline(always)]
    pub fn dot_block(w: &[f32], xs: &[f32], d: usize, out: &mut [f32]) {
        for (row, o) in xs.chunks_exact(d).zip(out.iter_mut()) {
            *o = dot(w, row);
        }
    }

    /// Row-at-a-time mixed-precision block dot.
    #[inline(always)]
    pub fn dot_block_f64f32(w: &[f64], xs: &[f32], d: usize, out: &mut [f64]) {
        for (row, o) in xs.chunks_exact(d).zip(out.iter_mut()) {
            *o = dot_f64f32(w, row);
        }
    }

    /// Center-at-a-time block distances.
    #[inline(always)]
    pub fn sq_dist_block(x: &[f32], centers: &[f32], d: usize, out: &mut [f64]) {
        for (c, o) in centers.chunks_exact(d).zip(out.iter_mut()) {
            *o = dist_sq(x, c);
        }
    }
}

/// Explicit AVX2 kernels. Every function here carries
/// `#[target_feature(enable = "avx2")]` and is only reachable through the
/// dispatch wrappers after a runtime feature probe. Bit-identity with
/// [`scalar`] is maintained by construction: separate multiply and add
/// instructions (no FMA contraction), vector lanes mirroring the scalar
/// accumulator arrays, lane spills reduced with the scalar reduction trees,
/// and sequential scalar remainder loops.
#[cfg(target_arch = "x86_64")]
pub mod avx2 {
    use core::arch::x86_64::*;

    /// Spill the eight f32 lanes and apply [`super::scalar::dot`]'s tree.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn reduce8(v: __m256) -> f32 {
        let mut l = [0f32; 8];
        _mm256_storeu_ps(l.as_mut_ptr(), v);
        ((l[0] + l[4]) + (l[1] + l[5])) + ((l[2] + l[6]) + (l[3] + l[7]))
    }

    /// Spill the four f64 lanes and apply the `(0+2) + (1+3)` tree.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn reduce4(v: __m256d) -> f64 {
        let mut l = [0f64; 4];
        _mm256_storeu_pd(l.as_mut_ptr(), v);
        (l[0] + l[2]) + (l[1] + l[3])
    }

    /// Eight-lane dot, bitwise equal to [`super::scalar::dot`].
    ///
    /// # Safety
    /// Requires AVX2 (callers go through the runtime-detected dispatch).
    #[target_feature(enable = "avx2")]
    pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let n8 = n - n % 8;
        let mut acc = _mm256_setzero_ps();
        let mut i = 0;
        while i < n8 {
            let xa = _mm256_loadu_ps(a.as_ptr().add(i));
            let xb = _mm256_loadu_ps(b.as_ptr().add(i));
            acc = _mm256_add_ps(acc, _mm256_mul_ps(xa, xb));
            i += 8;
        }
        let mut s = reduce8(acc);
        while i < n {
            s += a[i] * b[i];
            i += 1;
        }
        s
    }

    /// `y += alpha * x` (elementwise — trivially bitwise equal).
    ///
    /// # Safety
    /// Requires AVX2 (callers go through the runtime-detected dispatch).
    #[target_feature(enable = "avx2")]
    pub unsafe fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
        debug_assert_eq!(x.len(), y.len());
        let n = x.len();
        let n8 = n - n % 8;
        let av = _mm256_set1_ps(alpha);
        let mut i = 0;
        while i < n8 {
            let xv = _mm256_loadu_ps(x.as_ptr().add(i));
            let yv = _mm256_loadu_ps(y.as_ptr().add(i));
            let r = _mm256_add_ps(yv, _mm256_mul_ps(av, xv));
            _mm256_storeu_ps(y.as_mut_ptr().add(i), r);
            i += 8;
        }
        while i < n {
            y[i] += alpha * x[i];
            i += 1;
        }
    }

    /// `y *= alpha` (elementwise).
    ///
    /// # Safety
    /// Requires AVX2 (callers go through the runtime-detected dispatch).
    #[target_feature(enable = "avx2")]
    pub unsafe fn scale(alpha: f32, y: &mut [f32]) {
        let n = y.len();
        let n8 = n - n % 8;
        let av = _mm256_set1_ps(alpha);
        let mut i = 0;
        while i < n8 {
            let yv = _mm256_loadu_ps(y.as_ptr().add(i));
            _mm256_storeu_ps(y.as_mut_ptr().add(i), _mm256_mul_ps(yv, av));
            i += 8;
        }
        while i < n {
            y[i] *= alpha;
            i += 1;
        }
    }

    /// Four-lane f64 squared norm, bitwise equal to
    /// [`super::scalar::norm_sq`].
    ///
    /// # Safety
    /// Requires AVX2 (callers go through the runtime-detected dispatch).
    #[target_feature(enable = "avx2")]
    pub unsafe fn norm_sq(a: &[f32]) -> f64 {
        let n = a.len();
        let n4 = n - n % 4;
        let mut acc = _mm256_setzero_pd();
        let mut i = 0;
        while i < n4 {
            let v = _mm256_cvtps_pd(_mm_loadu_ps(a.as_ptr().add(i)));
            acc = _mm256_add_pd(acc, _mm256_mul_pd(v, v));
            i += 4;
        }
        let mut s = reduce4(acc);
        while i < n {
            let v = a[i] as f64;
            s += v * v;
            i += 1;
        }
        s
    }

    /// Four-lane squared distance: f32 subtract, then widen (exactly the
    /// scalar structure — `_mm_sub_ps` then `_mm256_cvtps_pd`).
    ///
    /// # Safety
    /// Requires AVX2 (callers go through the runtime-detected dispatch).
    #[target_feature(enable = "avx2")]
    pub unsafe fn dist_sq(a: &[f32], b: &[f32]) -> f64 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let n4 = n - n % 4;
        let mut acc = _mm256_setzero_pd();
        let mut i = 0;
        while i < n4 {
            let xa = _mm_loadu_ps(a.as_ptr().add(i));
            let xb = _mm_loadu_ps(b.as_ptr().add(i));
            let d = _mm256_cvtps_pd(_mm_sub_ps(xa, xb));
            acc = _mm256_add_pd(acc, _mm256_mul_pd(d, d));
            i += 4;
        }
        let mut s = reduce4(acc);
        while i < n {
            let d = (a[i] - b[i]) as f64;
            s += d * d;
            i += 1;
        }
        s
    }

    /// Four-lane mixed-precision dot, bitwise equal to
    /// [`super::scalar::dot_f64f32`].
    ///
    /// # Safety
    /// Requires AVX2 (callers go through the runtime-detected dispatch).
    #[target_feature(enable = "avx2")]
    pub unsafe fn dot_f64f32(w: &[f64], x: &[f32]) -> f64 {
        debug_assert_eq!(w.len(), x.len());
        let n = x.len();
        let n4 = n - n % 4;
        let mut acc = _mm256_setzero_pd();
        let mut i = 0;
        while i < n4 {
            let wv = _mm256_loadu_pd(w.as_ptr().add(i));
            let xv = _mm256_cvtps_pd(_mm_loadu_ps(x.as_ptr().add(i)));
            acc = _mm256_add_pd(acc, _mm256_mul_pd(wv, xv));
            i += 4;
        }
        let mut s = reduce4(acc);
        while i < n {
            s += w[i] * (x[i] as f64);
            i += 1;
        }
        s
    }

    /// Mixed-precision axpy (elementwise).
    ///
    /// # Safety
    /// Requires AVX2 (callers go through the runtime-detected dispatch).
    #[target_feature(enable = "avx2")]
    pub unsafe fn axpy_f64f32(alpha: f64, x: &[f32], y: &mut [f64]) {
        debug_assert_eq!(x.len(), y.len());
        let n = x.len();
        let n4 = n - n % 4;
        let av = _mm256_set1_pd(alpha);
        let mut i = 0;
        while i < n4 {
            let xv = _mm256_cvtps_pd(_mm_loadu_ps(x.as_ptr().add(i)));
            let yv = _mm256_loadu_pd(y.as_ptr().add(i));
            let r = _mm256_add_pd(yv, _mm256_mul_pd(av, xv));
            _mm256_storeu_pd(y.as_mut_ptr().add(i), r);
            i += 4;
        }
        while i < n {
            y[i] += alpha * (x[i] as f64);
            i += 1;
        }
    }

    /// `y[j] += alpha * (x[j] - y[j])` (elementwise).
    ///
    /// # Safety
    /// Requires AVX2 (callers go through the runtime-detected dispatch).
    #[target_feature(enable = "avx2")]
    pub unsafe fn avg_update(alpha: f32, x: &[f32], y: &mut [f32]) {
        debug_assert_eq!(x.len(), y.len());
        let n = x.len();
        let n8 = n - n % 8;
        let av = _mm256_set1_ps(alpha);
        let mut i = 0;
        while i < n8 {
            let xv = _mm256_loadu_ps(x.as_ptr().add(i));
            let yv = _mm256_loadu_ps(y.as_ptr().add(i));
            let dv = _mm256_sub_ps(xv, yv);
            let r = _mm256_add_ps(yv, _mm256_mul_ps(av, dv));
            _mm256_storeu_ps(y.as_mut_ptr().add(i), r);
            i += 8;
        }
        while i < n {
            y[i] += alpha * (x[i] - y[i]);
            i += 1;
        }
    }

    /// Signed moment accumulation (elementwise).
    ///
    /// # Safety
    /// Requires AVX2 (callers go through the runtime-detected dispatch).
    #[target_feature(enable = "avx2")]
    pub unsafe fn accumulate_stats(sign: f64, x: &[f32], sum: &mut [f64], sumsq: &mut [f64]) {
        debug_assert_eq!(x.len(), sum.len());
        debug_assert_eq!(x.len(), sumsq.len());
        let n = x.len();
        let n4 = n - n % 4;
        let sv = _mm256_set1_pd(sign);
        let mut i = 0;
        while i < n4 {
            let v = _mm256_cvtps_pd(_mm_loadu_ps(x.as_ptr().add(i)));
            let s0 = _mm256_loadu_pd(sum.as_ptr().add(i));
            let s1 = _mm256_add_pd(s0, _mm256_mul_pd(sv, v));
            _mm256_storeu_pd(sum.as_mut_ptr().add(i), s1);
            let q0 = _mm256_loadu_pd(sumsq.as_ptr().add(i));
            let q1 = _mm256_add_pd(q0, _mm256_mul_pd(sv, _mm256_mul_pd(v, v)));
            _mm256_storeu_pd(sumsq.as_mut_ptr().add(i), q1);
            i += 4;
        }
        while i < n {
            let v = x[i] as f64;
            sum[i] += sign * v;
            sumsq[i] += sign * (v * v);
            i += 1;
        }
    }

    /// Blocked dot: four rows share each loaded `w` chunk (the fused win —
    /// `w` streams from registers instead of being re-read per row). Each
    /// row keeps its own accumulator register with exactly the single-row
    /// lane structure, so every `out[r]` is bitwise equal to
    /// [`dot`]/[`super::scalar::dot`] on that row.
    ///
    /// # Safety
    /// Requires AVX2 (callers go through the runtime-detected dispatch).
    #[target_feature(enable = "avx2")]
    pub unsafe fn dot_block(w: &[f32], xs: &[f32], d: usize, out: &mut [f32]) {
        debug_assert_eq!(w.len(), d);
        debug_assert_eq!(xs.len(), d * out.len());
        let rows = out.len();
        let wp = w.as_ptr();
        let d8 = d - d % 8;
        let mut r = 0;
        while r + 4 <= rows {
            let p0 = xs.as_ptr().add(r * d);
            let p1 = xs.as_ptr().add((r + 1) * d);
            let p2 = xs.as_ptr().add((r + 2) * d);
            let p3 = xs.as_ptr().add((r + 3) * d);
            let mut a0 = _mm256_setzero_ps();
            let mut a1 = _mm256_setzero_ps();
            let mut a2 = _mm256_setzero_ps();
            let mut a3 = _mm256_setzero_ps();
            let mut c = 0;
            while c < d8 {
                let wv = _mm256_loadu_ps(wp.add(c));
                a0 = _mm256_add_ps(a0, _mm256_mul_ps(wv, _mm256_loadu_ps(p0.add(c))));
                a1 = _mm256_add_ps(a1, _mm256_mul_ps(wv, _mm256_loadu_ps(p1.add(c))));
                a2 = _mm256_add_ps(a2, _mm256_mul_ps(wv, _mm256_loadu_ps(p2.add(c))));
                a3 = _mm256_add_ps(a3, _mm256_mul_ps(wv, _mm256_loadu_ps(p3.add(c))));
                c += 8;
            }
            let mut s = [reduce8(a0), reduce8(a1), reduce8(a2), reduce8(a3)];
            for (k, sv) in s.iter_mut().enumerate() {
                let p = xs.as_ptr().add((r + k) * d);
                let mut j = d8;
                while j < d {
                    *sv += *wp.add(j) * *p.add(j);
                    j += 1;
                }
            }
            out[r..r + 4].copy_from_slice(&s);
            r += 4;
        }
        while r < rows {
            out[r] = dot(w, &xs[r * d..(r + 1) * d]);
            r += 1;
        }
    }

    /// Row-at-a-time mixed-precision block dot (the fused win here is the
    /// resident `w`; rows already stream once).
    ///
    /// # Safety
    /// Requires AVX2 (callers go through the runtime-detected dispatch).
    #[target_feature(enable = "avx2")]
    pub unsafe fn dot_block_f64f32(w: &[f64], xs: &[f32], d: usize, out: &mut [f64]) {
        debug_assert_eq!(w.len(), d);
        debug_assert_eq!(xs.len(), d * out.len());
        for (r, o) in out.iter_mut().enumerate() {
            *o = dot_f64f32(w, &xs[r * d..(r + 1) * d]);
        }
    }

    /// Center-at-a-time block distances (query point stays resident).
    ///
    /// # Safety
    /// Requires AVX2 (callers go through the runtime-detected dispatch).
    #[target_feature(enable = "avx2")]
    pub unsafe fn sq_dist_block(x: &[f32], centers: &[f32], d: usize, out: &mut [f64]) {
        debug_assert_eq!(x.len(), d);
        debug_assert_eq!(centers.len(), d * out.len());
        for (c, o) in out.iter_mut().enumerate() {
            *o = dist_sq(x, &centers[c * d..(c + 1) * d]);
        }
    }
}

/// Cholesky factorization of a symmetric positive-definite matrix stored
/// dense row-major (`n × n`). Returns the lower factor `L` (row-major) with
/// `A = L Lᵀ`, or `None` if the matrix is not positive definite.
pub fn cholesky(a: &[f64], n: usize) -> Option<Vec<f64>> {
    debug_assert_eq!(a.len(), n * n);
    let mut l = vec![0f64; n * n];
    for i in 0..n {
        for j in 0..=i {
            let mut s = a[i * n + j];
            for k in 0..j {
                s -= l[i * n + k] * l[j * n + k];
            }
            if i == j {
                if s <= 0.0 {
                    return None;
                }
                l[i * n + i] = s.sqrt();
            } else {
                l[i * n + j] = s / l[j * n + j];
            }
        }
    }
    Some(l)
}

/// Solve `A x = b` given the Cholesky factor `L` of `A` (forward then back
/// substitution).
pub fn cholesky_solve(l: &[f64], n: usize, b: &[f64]) -> Vec<f64> {
    debug_assert_eq!(l.len(), n * n);
    debug_assert_eq!(b.len(), n);
    // L z = b
    let mut z = vec![0f64; n];
    for i in 0..n {
        let mut s = b[i];
        for k in 0..i {
            s -= l[i * n + k] * z[k];
        }
        z[i] = s / l[i * n + i];
    }
    // Lᵀ x = z
    let mut x = vec![0f64; n];
    for i in (0..n).rev() {
        let mut s = z[i];
        for k in i + 1..n {
            s -= l[k * n + i] * x[k];
        }
        x[i] = s / l[i * n + i];
    }
    x
}

/// Invert an SPD matrix via its Cholesky factor (column-by-column solves).
/// Used only by the exact-LOOCV comparator on small `d`.
pub fn cholesky_inverse(l: &[f64], n: usize) -> Vec<f64> {
    let mut inv = vec![0f64; n * n];
    let mut e = vec![0f64; n];
    for j in 0..n {
        e.fill(0.0);
        e[j] = 1.0;
        let col = cholesky_solve(l, n, &e);
        for i in 0..n {
            inv[i * n + j] = col[i];
        }
    }
    inv
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    /// Remainder-lane battery: one below/at/above each lane width plus two
    /// larger sizes (64 = clean multiple, 257 = prime).
    const DIMS: [usize; 6] = [1, 7, 8, 9, 64, 257];

    fn gen(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.next_gaussian()).collect()
    }

    fn gen64(rng: &mut Rng, n: usize) -> Vec<f64> {
        (0..n).map(|_| rng.next_gaussian() as f64).collect()
    }

    fn bits32(x: &[f32]) -> Vec<u32> {
        x.iter().map(|v| v.to_bits()).collect()
    }

    fn bits64(x: &[f64]) -> Vec<u64> {
        x.iter().map(|v| v.to_bits()).collect()
    }

    #[test]
    fn dot_axpy_scale() {
        let a = [1f32, 2., 3.];
        let b = [4f32, 5., 6.];
        assert_eq!(dot(&a, &b), 32.0);
        let mut y = b;
        axpy(2.0, &a, &mut y);
        assert_eq!(y, [6., 9., 12.]);
        scale(0.5, &mut y);
        assert_eq!(y, [3., 4.5, 6.]);
    }

    #[test]
    fn norms() {
        assert!((norm_sq(&[3., 4.]) - 25.0).abs() < 1e-12);
        assert!((dist_sq(&[1., 1.], &[4., 5.]) - 25.0).abs() < 1e-12);
    }

    /// The lane-structured elementwise kernels are bitwise equal to their
    /// naive per-element loops at every remainder-lane dimension.
    #[test]
    fn scalar_elementwise_kernels_match_naive_references() {
        let mut rng = Rng::new(901);
        for &n in &DIMS {
            let x = gen(&mut rng, n);
            let y0 = gen(&mut rng, n);

            let mut y = y0.clone();
            let mut want = y0.clone();
            scalar::axpy(0.37, &x, &mut y);
            for i in 0..n {
                want[i] += 0.37 * x[i];
            }
            assert_eq!(bits32(&y), bits32(&want), "axpy n={n}");

            let mut y = y0.clone();
            let mut want = y0.clone();
            scalar::scale(-1.25, &mut y);
            for v in want.iter_mut() {
                *v *= -1.25;
            }
            assert_eq!(bits32(&y), bits32(&want), "scale n={n}");

            let mut y = y0.clone();
            let mut want = y0.clone();
            scalar::avg_update(0.11, &x, &mut y);
            for i in 0..n {
                want[i] += 0.11 * (x[i] - want[i]);
            }
            assert_eq!(bits32(&y), bits32(&want), "avg_update n={n}");

            let y64 = gen64(&mut rng, n);
            let mut y = y64.clone();
            let mut want = y64.clone();
            scalar::axpy_f64f32(0.61, &x, &mut y);
            for i in 0..n {
                want[i] += 0.61 * (x[i] as f64);
            }
            assert_eq!(bits64(&y), bits64(&want), "axpy_f64f32 n={n}");

            for sign in [1.0f64, -1.0] {
                let s0 = gen64(&mut rng, n);
                let q0 = gen64(&mut rng, n);
                let (mut s, mut q) = (s0.clone(), q0.clone());
                let (mut ws, mut wq) = (s0, q0);
                scalar::accumulate_stats(sign, &x, &mut s, &mut q);
                for i in 0..n {
                    let v = x[i] as f64;
                    ws[i] += sign * v;
                    wq[i] += sign * (v * v);
                }
                assert_eq!(bits64(&s), bits64(&ws), "stats sum n={n}");
                assert_eq!(bits64(&q), bits64(&wq), "stats sumsq n={n}");
            }
        }
    }

    /// Every AVX2 kernel is bitwise equal to its scalar counterpart across
    /// the remainder-lane dimension battery. Skips (trivially passes) off
    /// x86-64 or when the CPU lacks AVX2+FMA — CI's `-C target-cpu=native`
    /// leg exercises the real comparison.
    #[test]
    #[cfg(target_arch = "x86_64")]
    fn avx2_kernels_match_scalar_bitwise() {
        if !avx2_available() {
            return;
        }
        let mut rng = Rng::new(902);
        for &n in &DIMS {
            let a = gen(&mut rng, n);
            let b = gen(&mut rng, n);
            let w64 = gen64(&mut rng, n);

            // SAFETY: guarded by avx2_available() above.
            unsafe {
                assert_eq!(avx2::dot(&a, &b).to_bits(), scalar::dot(&a, &b).to_bits());
                assert_eq!(avx2::norm_sq(&a).to_bits(), scalar::norm_sq(&a).to_bits());
                assert_eq!(
                    avx2::dist_sq(&a, &b).to_bits(),
                    scalar::dist_sq(&a, &b).to_bits()
                );
                assert_eq!(
                    avx2::dot_f64f32(&w64, &a).to_bits(),
                    scalar::dot_f64f32(&w64, &a).to_bits()
                );

                let (mut y1, mut y2) = (b.clone(), b.clone());
                avx2::axpy(0.42, &a, &mut y1);
                scalar::axpy(0.42, &a, &mut y2);
                assert_eq!(bits32(&y1), bits32(&y2), "axpy n={n}");

                let (mut y1, mut y2) = (b.clone(), b.clone());
                avx2::scale(1.73, &mut y1);
                scalar::scale(1.73, &mut y2);
                assert_eq!(bits32(&y1), bits32(&y2), "scale n={n}");

                let (mut y1, mut y2) = (b.clone(), b.clone());
                avx2::avg_update(0.09, &a, &mut y1);
                scalar::avg_update(0.09, &a, &mut y2);
                assert_eq!(bits32(&y1), bits32(&y2), "avg_update n={n}");

                let (mut y1, mut y2) = (w64.clone(), w64.clone());
                avx2::axpy_f64f32(-0.8, &a, &mut y1);
                scalar::axpy_f64f32(-0.8, &a, &mut y2);
                assert_eq!(bits64(&y1), bits64(&y2), "axpy_f64f32 n={n}");

                let q64 = gen64(&mut rng, n);
                let (mut s1, mut q1) = (w64.clone(), q64.clone());
                let (mut s2, mut q2) = (w64.clone(), q64.clone());
                avx2::accumulate_stats(-1.0, &a, &mut s1, &mut q1);
                scalar::accumulate_stats(-1.0, &a, &mut s2, &mut q2);
                assert_eq!(bits64(&s1), bits64(&s2), "stats sum n={n}");
                assert_eq!(bits64(&q1), bits64(&q2), "stats sumsq n={n}");

                // Block kernels: rows 1..=9 cover the 4-row main loop and
                // every remainder-row count.
                for rows in 1..=9usize {
                    let xs = gen(&mut rng, rows * n);
                    let (mut o1, mut o2) = (vec![0f32; rows], vec![0f32; rows]);
                    avx2::dot_block(&a, &xs, n, &mut o1);
                    scalar::dot_block(&a, &xs, n, &mut o2);
                    assert_eq!(bits32(&o1), bits32(&o2), "dot_block n={n} rows={rows}");

                    let (mut o1, mut o2) = (vec![0f64; rows], vec![0f64; rows]);
                    avx2::dot_block_f64f32(&w64, &xs, n, &mut o1);
                    scalar::dot_block_f64f32(&w64, &xs, n, &mut o2);
                    assert_eq!(bits64(&o1), bits64(&o2), "dot_block_f64f32 n={n}");

                    let (mut o1, mut o2) = (vec![0f64; rows], vec![0f64; rows]);
                    avx2::sq_dist_block(&a, &xs, n, &mut o1);
                    scalar::sq_dist_block(&a, &xs, n, &mut o2);
                    assert_eq!(bits64(&o1), bits64(&o2), "sq_dist_block n={n}");
                }
            }
        }
    }

    /// The public block kernels (whatever backend is live) are bitwise
    /// equal to their row-at-a-time counterparts, including d = 0.
    #[test]
    fn block_kernels_match_rowwise() {
        let mut rng = Rng::new(903);
        for &n in &DIMS {
            for rows in [1usize, 2, 3, 4, 5, 9] {
                let w = gen(&mut rng, n);
                let w64 = gen64(&mut rng, n);
                let xs = gen(&mut rng, rows * n);

                let mut out = vec![0f32; rows];
                dot_block(&w, &xs, n, &mut out);
                for r in 0..rows {
                    let want = dot(&w, &xs[r * n..(r + 1) * n]);
                    assert_eq!(out[r].to_bits(), want.to_bits(), "dot n={n} r={r}");
                }

                let mut out = vec![0f64; rows];
                dot_block_f64f32(&w64, &xs, n, &mut out);
                for r in 0..rows {
                    let want = dot_f64f32(&w64, &xs[r * n..(r + 1) * n]);
                    assert_eq!(out[r].to_bits(), want.to_bits(), "dotf64 n={n} r={r}");
                }

                let mut out = vec![0f64; rows];
                sq_dist_block(&w, &xs, n, &mut out);
                for r in 0..rows {
                    let want = dist_sq(&w, &xs[r * n..(r + 1) * n]);
                    assert_eq!(out[r].to_bits(), want.to_bits(), "dist n={n} r={r}");
                }
            }
        }
        // d = 0: defined as all-zeros output, no panic.
        let mut out = vec![1f32; 3];
        dot_block(&[], &[], 0, &mut out);
        assert_eq!(out, [0.0; 3]);
        let mut out = vec![1f64; 3];
        sq_dist_block(&[], &[], 0, &mut out);
        assert_eq!(out, [0.0; 3]);
    }

    /// `syrk_accumulate_blocked` is bitwise equal to the per-point rank-one
    /// sequence for every block size (1, small odd, default, larger than
    /// the point count).
    #[test]
    fn syrk_blocked_matches_rank_one_sequence() {
        let mut rng = Rng::new(904);
        for &d in &[1usize, 3, 7, 9] {
            let points = 37;
            let xs = gen(&mut rng, points * d);
            let a0 = gen64(&mut rng, d * d);

            let mut want = a0.clone();
            for row in xs.chunks_exact(d) {
                for i in 0..d {
                    let xi = row[i] as f64;
                    for j in 0..d {
                        want[i * d + j] += xi * (row[j] as f64);
                    }
                }
            }

            for block_rows in [1usize, 3, SYRK_BLOCK_ROWS, 1000] {
                let mut a = a0.clone();
                syrk_accumulate_blocked(&mut a, d, &xs, block_rows);
                assert_eq!(bits64(&a), bits64(&want), "syrk d={d} B={block_rows}");
            }
            let mut a = a0.clone();
            syrk_accumulate(&mut a, d, &xs);
            assert_eq!(bits64(&a), bits64(&want), "syrk default d={d}");
        }
        // Degenerate shapes are no-ops.
        syrk_accumulate(&mut [], 0, &[]);
        let mut a = [5.0f64];
        syrk_accumulate(&mut a, 1, &[]);
        assert_eq!(a, [5.0]);
    }

    #[test]
    fn backend_override_rules() {
        use KernelBackend::{Avx2, Scalar};
        assert_eq!(backend_from_override(Some("scalar"), true), Scalar);
        assert_eq!(backend_from_override(Some("scalar"), false), Scalar);
        assert_eq!(backend_from_override(None, true), Avx2);
        assert_eq!(backend_from_override(None, false), Scalar);
        assert_eq!(backend_from_override(Some("avx2"), false), Scalar);
        assert_eq!(backend_from_override(Some("anything"), true), Avx2);
        assert_eq!(Scalar.name(), "scalar");
        assert_eq!(Avx2.name(), "avx2");
    }

    /// Forcing the backend through the public dispatch never changes a
    /// result (the property that makes `force_backend` safe mid-run).
    #[test]
    fn forced_backend_dispatch_is_bit_identical() {
        let initial = kernel_backend();
        let mut rng = Rng::new(905);
        let a = gen(&mut rng, 257);
        let b = gen(&mut rng, 257);

        force_backend(KernelBackend::Scalar);
        assert_eq!(kernel_backend(), KernelBackend::Scalar);
        assert_eq!(backend_name(), "scalar");
        let d_scalar = dot(&a, &b);
        let n_scalar = norm_sq(&a);

        let detected = backend_from_override(None, avx2_available());
        force_backend(detected);
        assert_eq!(d_scalar.to_bits(), dot(&a, &b).to_bits());
        assert_eq!(n_scalar.to_bits(), norm_sq(&a).to_bits());

        force_backend(initial);
    }

    #[test]
    fn cholesky_roundtrip() {
        // A = M Mᵀ + I for a random-ish M is SPD.
        let n = 4;
        let m = [
            1.0, 0.5, 0.0, 0.2, //
            0.3, 2.0, 0.1, 0.0, //
            0.0, 0.7, 1.5, 0.4, //
            0.2, 0.0, 0.3, 1.0,
        ];
        let mut a = vec![0f64; n * n];
        for i in 0..n {
            for j in 0..n {
                let mut s = if i == j { 1.0 } else { 0.0 };
                for k in 0..n {
                    s += m[i * n + k] * m[j * n + k];
                }
                a[i * n + j] = s;
            }
        }
        let l = cholesky(&a, n).expect("SPD");
        let b = [1.0, -2.0, 0.5, 3.0];
        let x = cholesky_solve(&l, n, &b);
        // Check A x ≈ b.
        for i in 0..n {
            let mut s = 0f64;
            for j in 0..n {
                s += a[i * n + j] * x[j];
            }
            assert!((s - b[i]).abs() < 1e-9, "row {i}: {s} vs {}", b[i]);
        }
        // Inverse: A * A⁻¹ ≈ I.
        let inv = cholesky_inverse(&l, n);
        for i in 0..n {
            for j in 0..n {
                let mut s = 0f64;
                for k in 0..n {
                    s += a[i * n + k] * inv[k * n + j];
                }
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((s - want).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = [1.0, 2.0, 2.0, 1.0]; // eigenvalues 3, -1
        assert!(cholesky(&a, 2).is_none());
    }
}
