//! Small dense linear-algebra kernels shared by the pure-Rust learners and
//! the exact-LOOCV comparator. These are the L3 hot path for the large-`n`
//! experiments (the XLA artifacts cover the L1/L2 path), so they are kept
//! allocation-free and auto-vectorizable.

/// Dot product `⟨a, b⟩` in f32.
///
/// Eight independent accumulators break the serial FP dependency chain so
/// LLVM can vectorize (strict FP semantics forbid reassociating a single
/// `s += a[i]*b[i]` chain). This is the single hottest operation in the
/// whole system (PEGASOS margin checks + all evaluations) — see
/// EXPERIMENTS.md §Perf for the measured effect.
#[inline(always)]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0f32; 8];
    let ca = a.chunks_exact(8);
    let cb = b.chunks_exact(8);
    let (ra, rb) = (ca.remainder(), cb.remainder());
    for (xa, xb) in ca.zip(cb) {
        // Eight independent lanes → one SIMD FMA per iteration.
        for l in 0..8 {
            acc[l] += xa[l] * xb[l];
        }
    }
    let mut s = ((acc[0] + acc[4]) + (acc[1] + acc[5]))
        + ((acc[2] + acc[6]) + (acc[3] + acc[7]));
    for (xa, xb) in ra.iter().zip(rb) {
        s += xa * xb;
    }
    s
}

/// `y += alpha * x`.
#[inline(always)]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for i in 0..x.len() {
        y[i] += alpha * x[i];
    }
}

/// `y *= alpha`.
#[inline(always)]
pub fn scale(alpha: f32, y: &mut [f32]) {
    for v in y.iter_mut() {
        *v *= alpha;
    }
}

/// Squared l2 norm, f64 accumulator (used for projections and regularizers
/// where drift matters). Four independent lanes break the FP chain (same
/// reasoning as [`dot`]).
#[inline(always)]
pub fn norm_sq(a: &[f32]) -> f64 {
    let mut acc = [0f64; 4];
    let ca = a.chunks_exact(4);
    let r = ca.remainder();
    for xa in ca {
        for l in 0..4 {
            let v = xa[l] as f64;
            acc[l] += v * v;
        }
    }
    let mut s = (acc[0] + acc[2]) + (acc[1] + acc[3]);
    for &v in r {
        s += (v as f64) * (v as f64);
    }
    s
}

/// Squared euclidean distance `||a - b||²` (four-lane, as [`norm_sq`]).
#[inline(always)]
pub fn dist_sq(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0f64; 4];
    let ca = a.chunks_exact(4);
    let cb = b.chunks_exact(4);
    let (ra, rb) = (ca.remainder(), cb.remainder());
    for (xa, xb) in ca.zip(cb) {
        for l in 0..4 {
            let d = (xa[l] - xb[l]) as f64;
            acc[l] += d * d;
        }
    }
    let mut s = (acc[0] + acc[2]) + (acc[1] + acc[3]);
    for (xa, xb) in ra.iter().zip(rb) {
        let d = (xa - xb) as f64;
        s += d * d;
    }
    s
}

/// Cholesky factorization of a symmetric positive-definite matrix stored
/// dense row-major (`n × n`). Returns the lower factor `L` (row-major) with
/// `A = L Lᵀ`, or `None` if the matrix is not positive definite.
pub fn cholesky(a: &[f64], n: usize) -> Option<Vec<f64>> {
    debug_assert_eq!(a.len(), n * n);
    let mut l = vec![0f64; n * n];
    for i in 0..n {
        for j in 0..=i {
            let mut s = a[i * n + j];
            for k in 0..j {
                s -= l[i * n + k] * l[j * n + k];
            }
            if i == j {
                if s <= 0.0 {
                    return None;
                }
                l[i * n + i] = s.sqrt();
            } else {
                l[i * n + j] = s / l[j * n + j];
            }
        }
    }
    Some(l)
}

/// Solve `A x = b` given the Cholesky factor `L` of `A` (forward then back
/// substitution).
pub fn cholesky_solve(l: &[f64], n: usize, b: &[f64]) -> Vec<f64> {
    debug_assert_eq!(l.len(), n * n);
    debug_assert_eq!(b.len(), n);
    // L z = b
    let mut z = vec![0f64; n];
    for i in 0..n {
        let mut s = b[i];
        for k in 0..i {
            s -= l[i * n + k] * z[k];
        }
        z[i] = s / l[i * n + i];
    }
    // Lᵀ x = z
    let mut x = vec![0f64; n];
    for i in (0..n).rev() {
        let mut s = z[i];
        for k in i + 1..n {
            s -= l[k * n + i] * x[k];
        }
        x[i] = s / l[i * n + i];
    }
    x
}

/// Invert an SPD matrix via its Cholesky factor (column-by-column solves).
/// Used only by the exact-LOOCV comparator on small `d`.
pub fn cholesky_inverse(l: &[f64], n: usize) -> Vec<f64> {
    let mut inv = vec![0f64; n * n];
    let mut e = vec![0f64; n];
    for j in 0..n {
        e.fill(0.0);
        e[j] = 1.0;
        let col = cholesky_solve(l, n, &e);
        for i in 0..n {
            inv[i * n + j] = col[i];
        }
    }
    inv
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_axpy_scale() {
        let a = [1f32, 2., 3.];
        let b = [4f32, 5., 6.];
        assert_eq!(dot(&a, &b), 32.0);
        let mut y = b;
        axpy(2.0, &a, &mut y);
        assert_eq!(y, [6., 9., 12.]);
        scale(0.5, &mut y);
        assert_eq!(y, [3., 4.5, 6.]);
    }

    #[test]
    fn norms() {
        assert!((norm_sq(&[3., 4.]) - 25.0).abs() < 1e-12);
        assert!((dist_sq(&[1., 1.], &[4., 5.]) - 25.0).abs() < 1e-12);
    }

    #[test]
    fn cholesky_roundtrip() {
        // A = M Mᵀ + I for a random-ish M is SPD.
        let n = 4;
        let m = [
            1.0, 0.5, 0.0, 0.2, //
            0.3, 2.0, 0.1, 0.0, //
            0.0, 0.7, 1.5, 0.4, //
            0.2, 0.0, 0.3, 1.0,
        ];
        let mut a = vec![0f64; n * n];
        for i in 0..n {
            for j in 0..n {
                let mut s = if i == j { 1.0 } else { 0.0 };
                for k in 0..n {
                    s += m[i * n + k] * m[j * n + k];
                }
                a[i * n + j] = s;
            }
        }
        let l = cholesky(&a, n).expect("SPD");
        let b = [1.0, -2.0, 0.5, 3.0];
        let x = cholesky_solve(&l, n, &b);
        // Check A x ≈ b.
        for i in 0..n {
            let mut s = 0f64;
            for j in 0..n {
                s += a[i * n + j] * x[j];
            }
            assert!((s - b[i]).abs() < 1e-9, "row {i}: {s} vs {}", b[i]);
        }
        // Inverse: A * A⁻¹ ≈ I.
        let inv = cholesky_inverse(&l, n);
        for i in 0..n {
            for j in 0..n {
                let mut s = 0f64;
                for k in 0..n {
                    s += a[i * n + k] * inv[k * n + j];
                }
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((s - want).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = [1.0, 2.0, 2.0, 1.0]; // eigenvalues 3, -1
        assert!(cholesky(&a, 2).is_none());
    }
}
