//! Online perceptron. Not in the paper's experiments, but included because
//! its *mistake-driven* updates give the save/revert strategy (paper §4.1:
//! "when the model undergoes few changes during an update, save/revert
//! might be preferred") a genuinely sparse undo log: only the points that
//! caused a mistake are recorded (4 bytes each), and revert re-subtracts
//! their updates in reverse order. The undo cost is proportional to the
//! number of mistakes, not to `model size × update count`. The `ablations`
//! bench measures Copy vs SaveRevert on exactly this learner.
//!
//! Floating-point note: `fl(fl(w + ηyx) − ηyx)` can differ from `w` by one
//! ulp per component. Revert is therefore exact-in-structure but only
//! ulp-accurate in value; the TreeCV engine's exactness oracles use the
//! integer-state learners ([`super::multiset`], [`super::histdensity`])
//! instead.

use super::{linalg, IncrementalLearner};
use crate::data::Dataset;
use crate::loss;

/// Perceptron trainer.
#[derive(Debug, Clone)]
pub struct Perceptron {
    d: usize,
    /// Learning rate (1.0 is the classic perceptron).
    pub eta: f32,
}

/// Perceptron model.
#[derive(Debug)]
pub struct PerceptronModel {
    pub w: Vec<f32>,
    pub bias: f32,
    /// Total mistakes made (monotone; useful for mistake-bound checks).
    pub mistakes: u64,
}

// Hand-written so `clone_from` reuses the target's heap storage (the
// derive's fallback reallocates; the CV engines recycle snapshot buffers).
impl Clone for PerceptronModel {
    fn clone(&self) -> Self {
        Self { w: self.w.clone(), bias: self.bias, mistakes: self.mistakes }
    }

    fn clone_from(&mut self, src: &Self) {
        self.w.clone_from(&src.w);
        self.bias = src.bias;
        self.mistakes = src.mistakes;
    }
}

/// Sparse undo log: indices whose mistake-updates must be subtracted back,
/// in application order.
#[derive(Debug)]
pub struct PerceptronUndo {
    applied: Vec<u32>,
}

impl PerceptronUndo {
    /// Undo-log footprint in bytes (for the strategy-ablation metrics).
    pub fn bytes(&self) -> usize {
        self.applied.len() * 4
    }
}

impl Perceptron {
    pub fn new(d: usize) -> Self {
        Self { d, eta: 1.0 }
    }

    /// Returns true if the point triggered an update (was misclassified).
    #[inline(always)]
    fn step(&self, m: &mut PerceptronModel, x: &[f32], y: f32) -> bool {
        let score = linalg::dot(&m.w, x) + m.bias;
        if y * score <= 0.0 {
            linalg::axpy(self.eta * y, x, &mut m.w);
            m.bias += self.eta * y;
            m.mistakes += 1;
            true
        } else {
            false
        }
    }
}

impl IncrementalLearner for Perceptron {
    type Model = PerceptronModel;
    type Undo = PerceptronUndo;

    fn name(&self) -> &'static str {
        "perceptron"
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn init(&self) -> PerceptronModel {
        PerceptronModel { w: vec![0.0; self.d], bias: 0.0, mistakes: 0 }
    }

    fn update(&self, m: &mut PerceptronModel, data: &Dataset, idx: &[u32]) {
        for &i in idx {
            self.step(m, data.row(i), data.label(i));
        }
    }

    /// Contiguous fast path: identical mistake-driven `step` sequence
    /// over a row-major slice (folded-layout contract, bit-identical).
    fn update_rows(
        &self,
        m: &mut PerceptronModel,
        x: &[f32],
        y: &[f32],
        _data: &Dataset,
        _ids: &[u32],
    ) {
        debug_assert_eq!(x.len(), y.len() * self.d);
        for (row, &yi) in x.chunks_exact(self.d).zip(y) {
            self.step(m, row, yi);
        }
    }

    fn update_logged(
        &self,
        m: &mut PerceptronModel,
        data: &Dataset,
        idx: &[u32],
    ) -> PerceptronUndo {
        let mut applied = Vec::new();
        for &i in idx {
            if self.step(m, data.row(i), data.label(i)) {
                applied.push(i);
            }
        }
        PerceptronUndo { applied }
    }

    fn revert(&self, m: &mut PerceptronModel, data: &Dataset, undo: PerceptronUndo) {
        for &i in undo.applied.iter().rev() {
            let y = data.label(i);
            linalg::axpy(-self.eta * y, data.row(i), &mut m.w);
            m.bias -= self.eta * y;
            m.mistakes -= 1;
        }
    }

    fn loss(&self, m: &PerceptronModel, data: &Dataset, i: u32) -> f64 {
        loss::misclassification(linalg::dot(&m.w, data.row(i)) + m.bias, data.label(i))
    }

    fn evaluate_rows(
        &self,
        m: &PerceptronModel,
        x: &[f32],
        y: &[f32],
        _data: &Dataset,
        _ids: &[u32],
    ) -> f64 {
        if y.is_empty() {
            return 0.0;
        }
        // Blocked sweep through the kernel layer (dot_block ≡ dot per row,
        // so each score is bitwise equal to the per-row path).
        let mut s = 0f64;
        let mut scores = [0f32; linalg::EVAL_BLOCK_ROWS];
        let xc = x.chunks(self.d * linalg::EVAL_BLOCK_ROWS);
        for (xb, yb) in xc.zip(y.chunks(linalg::EVAL_BLOCK_ROWS)) {
            let out = &mut scores[..yb.len()];
            linalg::dot_block(&m.w, xb, self.d, out);
            for (&sc, &yi) in out.iter().zip(yb) {
                s += loss::misclassification(sc + m.bias, yi);
            }
        }
        s / y.len() as f64
    }

    fn model_bytes(&self, m: &PerceptronModel) -> usize {
        m.w.len() * 4 + 12
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SyntheticCovertype;

    #[test]
    fn learns_separable_data() {
        // Linearly separable toy problem: y = sign(x0).
        let n = 200;
        let mut x = Vec::new();
        let mut y = Vec::new();
        for i in 0..n {
            let v = if i % 2 == 0 { 1.0 + (i as f32) * 0.01 } else { -1.0 - (i as f32) * 0.01 };
            x.extend_from_slice(&[v, 0.5]);
            y.push(v.signum());
        }
        let data = Dataset::new(x, y, 2);
        let l = Perceptron::new(2);
        let mut m = l.init();
        let idx: Vec<u32> = (0..n as u32).collect();
        // A few passes converge on separable data.
        for _ in 0..5 {
            l.update(&mut m, &data, &idx);
        }
        assert_eq!(l.evaluate(&m, &data, &idx), 0.0);
    }

    #[test]
    fn undo_log_is_sparse() {
        let data = SyntheticCovertype::new(2_000, 31).generate();
        let l = Perceptron::new(54);
        let mut m = l.init();
        let idx: Vec<u32> = (0..2_000).collect();
        let undo = l.update_logged(&mut m, &data, &idx);
        assert_eq!(undo.applied.len() as u64, m.mistakes);
        // Mistakes << points once a rough separator is found (noisy data,
        // but still a fraction of all points must be non-mistakes).
        assert!(undo.applied.len() < 2_000);
        assert!(undo.bytes() < 2_000 * 4 + 1);
    }

    #[test]
    fn revert_restores_within_ulp() {
        let data = SyntheticCovertype::new(500, 32).generate();
        let l = Perceptron::new(54);
        let mut m = l.init();
        l.update(&mut m, &data, &(0..250).collect::<Vec<_>>());
        let before = m.clone();
        let undo = l.update_logged(&mut m, &data, &(250..500).collect::<Vec<_>>());
        l.revert(&mut m, &data, undo);
        assert_eq!(m.mistakes, before.mistakes);
        for j in 0..54 {
            assert!(
                (m.w[j] - before.w[j]).abs() <= 1e-4 * (1.0 + before.w[j].abs()),
                "j={j}: {} vs {}",
                m.w[j],
                before.w[j]
            );
        }
    }

    #[test]
    fn contiguous_fast_path_is_bit_identical() {
        let data = SyntheticCovertype::new(300, 33).generate();
        let idx: Vec<u32> = (0..240).collect();
        let block = data.subset(&idx);
        let l = Perceptron::new(54);
        let mut a = l.init();
        l.update(&mut a, &data, &idx);
        let mut b = l.init();
        l.update_rows(&mut b, &block.x, &block.y, &data, &idx);
        assert_eq!(a.w, b.w);
        assert_eq!(a.bias, b.bias);
        assert_eq!(a.mistakes, b.mistakes);
        let held: Vec<u32> = (240..300).collect();
        let hb = data.subset(&held);
        let fast = l.evaluate_rows(&a, &hb.x, &hb.y, &data, &held);
        assert_eq!(l.evaluate(&a, &data, &held).to_bits(), fast.to_bits());
    }

    #[test]
    fn mistake_bound_on_separable_margin() {
        // Perceptron mistake bound: (R/γ)² on margin-γ separable data.
        let n = 1_000;
        let mut x = Vec::new();
        let mut y = Vec::new();
        for i in 0..n {
            let s = if i % 2 == 0 { 1.0f32 } else { -1.0 };
            x.extend_from_slice(&[s * 2.0, 1.0]); // margin ≥ 2/√5, R ≤ √5
            y.push(s);
        }
        let data = Dataset::new(x, y, 2);
        let l = Perceptron::new(2);
        let mut m = l.init();
        let idx: Vec<u32> = (0..n as u32).collect();
        for _ in 0..10 {
            l.update(&mut m, &data, &idx);
        }
        assert!(m.mistakes <= 25, "mistakes {}", m.mistakes);
    }
}
