//! Linear PEGASOS [Shalev-Shwartz et al., 2011]: primal estimated
//! sub-gradient solver for SVM. One of the two learners in the paper's
//! experiments (§5, Table 2 top; λ = 10⁻⁶ on Covertype).
//!
//! Per-point step `t` (1-based): with η_t = 1/(λ t),
//! `w ← (1 − 1/t)·w + η_t · 1{y⟨w,x⟩ < 1} · y·x`.
//! Following the paper (and the original authors' suggestion) the *last*
//! hypothesis is the model. The step counter `t` is part of the model
//! state, so incremental continuation across chunks behaves exactly like
//! one long run — which is what makes PEGASOS incrementally stable
//! (paper §3.1: excess-risk bound O(log n / n) w.r.t. the regularized
//! hinge loss).
//!
//! Implementation note: the scaling `(1 − 1/t)` telescopes —
//! `∏_{τ=2..t} (1 − 1/τ) = 1/t` — so we represent `w = s·v` and rescale
//! lazily. A point update is then O(1) for the shrink plus O(d) only on
//! margin violations, and the hot loop does a single fused dot product.

use super::{linalg, ConvexCorrectable, IncrementalLearner};
use crate::data::Dataset;
use crate::loss;

/// PEGASOS trainer configuration.
#[derive(Debug, Clone)]
pub struct Pegasos {
    d: usize,
    /// Regularization λ (paper experiment: 1e-6).
    pub lambda: f64,
}

/// PEGASOS model: `w = scale · v`, plus the global step counter.
#[derive(Debug)]
pub struct PegasosModel {
    /// Unscaled weights `v`.
    pub v: Vec<f32>,
    /// Scalar so that the true weight vector is `scale * v`.
    pub scale: f64,
    /// Number of points consumed so far.
    pub t: u64,
}

// Hand-written so `clone_from` reuses the target's heap storage — the
// derive's fallback reallocates, which would defeat the CV engines'
// snapshot-buffer recycling.
impl Clone for PegasosModel {
    fn clone(&self) -> Self {
        Self { v: self.v.clone(), scale: self.scale, t: self.t }
    }

    fn clone_from(&mut self, src: &Self) {
        self.v.clone_from(&src.v);
        self.scale = src.scale;
        self.t = src.t;
    }
}

impl PegasosModel {
    /// Materialize the true weight vector `w = scale·v`.
    pub fn weights(&self) -> Vec<f32> {
        self.v.iter().map(|&x| (self.scale * x as f64) as f32).collect()
    }

    /// Decision score `⟨w, x⟩`.
    #[inline(always)]
    pub fn score(&self, x: &[f32]) -> f32 {
        (self.scale * linalg::dot(&self.v, x) as f64) as f32
    }

    /// Fold `scale` back into `v` (keeps `v` well-conditioned; cheap, O(d)).
    fn renormalize(&mut self) {
        if self.scale != 1.0 {
            let s = self.scale as f32;
            linalg::scale(s, &mut self.v);
            self.scale = 1.0;
        }
    }
}

impl Pegasos {
    pub fn new(d: usize, lambda: f64) -> Self {
        assert!(lambda > 0.0, "lambda must be positive");
        Self { d, lambda }
    }

    #[inline(always)]
    fn step(&self, m: &mut PegasosModel, x: &[f32], y: f32) {
        m.t += 1;
        let t = m.t as f64;
        if m.t == 1 {
            // (1 - 1/1) zeroes w; then w = η·1{violation}·y·x with ⟨w,x⟩=0<1.
            m.scale = 1.0;
            let eta = 1.0 / (self.lambda * t);
            m.v.fill(0.0);
            linalg::axpy((eta * y as f64) as f32, x, &mut m.v);
            return;
        }
        let margin = (y as f64) * (m.scale * linalg::dot(&m.v, x) as f64);
        // Shrink: w ← (1 - 1/t) w, folded into the scalar.
        m.scale *= 1.0 - 1.0 / t;
        if margin < 1.0 {
            // w += η y x  ⇔  v += (η y / scale) x.
            let eta = 1.0 / (self.lambda * t);
            linalg::axpy(((eta * y as f64) / m.scale) as f32, x, &mut m.v);
        }
        // Guard against scale underflow on very long runs.
        if m.scale < 1e-30 {
            m.renormalize();
        }
    }
}

impl IncrementalLearner for Pegasos {
    type Model = PegasosModel;
    /// Compact dense model → snapshot undo (paper §4.1: "if the model state
    /// is compact, copying is a useful strategy").
    type Undo = PegasosModel;

    fn name(&self) -> &'static str {
        "pegasos"
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn init(&self) -> PegasosModel {
        PegasosModel { v: vec![0.0; self.d], scale: 1.0, t: 0 }
    }

    fn update(&self, m: &mut PegasosModel, data: &Dataset, idx: &[u32]) {
        debug_assert_eq!(data.d, self.d);
        for &i in idx {
            self.step(m, data.row(i), data.label(i));
        }
    }

    /// Contiguous fast path: the same per-point `step` sequence, swept
    /// over a row-major slice instead of gathered rows — bit-identical,
    /// prefetcher-friendly.
    fn update_rows(
        &self,
        m: &mut PegasosModel,
        x: &[f32],
        y: &[f32],
        _data: &Dataset,
        _ids: &[u32],
    ) {
        debug_assert_eq!(x.len(), y.len() * self.d);
        for (row, &yi) in x.chunks_exact(self.d).zip(y) {
            self.step(m, row, yi);
        }
    }

    fn update_logged(&self, m: &mut PegasosModel, data: &Dataset, idx: &[u32]) -> PegasosModel {
        let snap = m.clone();
        self.update(m, data, idx);
        snap
    }

    fn revert(&self, m: &mut PegasosModel, _data: &Dataset, undo: PegasosModel) {
        *m = undo;
    }

    fn loss(&self, m: &PegasosModel, data: &Dataset, i: u32) -> f64 {
        loss::misclassification(m.score(data.row(i)), data.label(i))
    }

    fn evaluate_rows(
        &self,
        m: &PegasosModel,
        x: &[f32],
        y: &[f32],
        _data: &Dataset,
        _ids: &[u32],
    ) -> f64 {
        if y.is_empty() {
            return 0.0;
        }
        // Blocked sweep through the kernel layer: `v` is loaded once per
        // block of rows instead of once per row. Each blocked score is
        // bitwise equal to `m.score(row)` (dot_block ≡ dot per row).
        let mut s = 0f64;
        let mut scores = [0f32; linalg::EVAL_BLOCK_ROWS];
        let xc = x.chunks(self.d * linalg::EVAL_BLOCK_ROWS);
        for (xb, yb) in xc.zip(y.chunks(linalg::EVAL_BLOCK_ROWS)) {
            let out = &mut scores[..yb.len()];
            linalg::dot_block(&m.v, xb, self.d, out);
            for (&sc, &yi) in out.iter().zip(yb) {
                s += loss::misclassification((m.scale * sc as f64) as f32, yi);
            }
        }
        s / y.len() as f64
    }

    fn model_bytes(&self, m: &PegasosModel) -> usize {
        m.v.len() * 4 + 16
    }

    fn correctable(&self) -> bool {
        true
    }

    fn try_correct_heldout(&self, m: &mut PegasosModel, data: &Dataset, idx: &[u32]) -> bool {
        ConvexCorrectable::correct_heldout(self, m, data, idx);
        true
    }
}

/// One-step subgradient correction. PEGASOS's last hypothesis telescopes
/// to `w_t = (1/(λt)) Σ_{τ active} y_τ x_τ`, so dropping a held-out block
/// of h points gives the first-order estimate
/// `w_{-f} ≈ w_t · t/(t−h) − (1/(λ(t−h))) Σ_{i∈f, margin<1} y_i x_i`,
/// with margin activity judged at the *full-data* model (the one-step
/// approximation — the exact run would judge margins at intermediate
/// hypotheses). Degenerate folds with `t ≤ h` are left uncorrected.
impl ConvexCorrectable for Pegasos {
    fn correct_heldout(&self, m: &mut PegasosModel, data: &Dataset, idx: &[u32]) {
        let held = idx.len() as u64;
        if held == 0 || m.t <= held {
            return;
        }
        let keep = (m.t - held) as f64;
        // Pass 1: subgradient activity at the original model.
        let mut coeff = Vec::with_capacity(idx.len());
        for &i in idx {
            let y = data.label(i);
            let active = (y as f64) * (m.score(data.row(i)) as f64) < 1.0;
            coeff.push(if active { y as f64 } else { 0.0 });
        }
        // Pass 2: rescale, then subtract the held-out active terms.
        m.scale *= m.t as f64 / keep;
        let eta = 1.0 / (self.lambda * keep);
        for (&c, &i) in coeff.iter().zip(idx) {
            if c != 0.0 {
                linalg::axpy(((-c * eta) / m.scale) as f32, data.row(i), &mut m.v);
            }
        }
        m.t -= held;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SyntheticCovertype;

    /// Unoptimized reference PEGASOS (materialized w each step).
    fn reference_run(d: usize, lambda: f64, data: &Dataset, idx: &[u32]) -> Vec<f32> {
        let mut w = vec![0f32; d];
        let mut t = 0u64;
        for &i in idx {
            t += 1;
            let x = data.row(i);
            let y = data.label(i);
            let margin = y * linalg::dot(&w, x);
            let eta = 1.0 / (lambda * t as f64);
            let shrink = (1.0 - 1.0 / t as f64) as f32;
            for v in w.iter_mut() {
                *v *= shrink;
            }
            if margin < 1.0 {
                linalg::axpy((eta * y as f64) as f32, x, &mut w);
            }
        }
        w
    }

    #[test]
    fn scale_trick_matches_reference() {
        let data = SyntheticCovertype::new(300, 11).generate();
        let idx: Vec<u32> = (0..300).collect();
        let l = Pegasos::new(54, 1e-3);
        let mut m = l.init();
        l.update(&mut m, &data, &idx);
        let w = m.weights();
        let wref = reference_run(54, 1e-3, &data, &idx);
        for j in 0..54 {
            assert!(
                (w[j] - wref[j]).abs() <= 1e-3 * (1.0 + wref[j].abs()),
                "j={j}: {} vs {}",
                w[j],
                wref[j]
            );
        }
    }

    #[test]
    fn incremental_equals_single_pass() {
        // Feeding [a, b] in one call must equal feeding a then b — the
        // defining property of an incremental learner (model carries t).
        let data = SyntheticCovertype::new(200, 12).generate();
        let idx: Vec<u32> = (0..200).collect();
        let l = Pegasos::new(54, 1e-4);
        let mut m1 = l.init();
        l.update(&mut m1, &data, &idx);
        let mut m2 = l.init();
        l.update(&mut m2, &data, &idx[..77]);
        l.update(&mut m2, &data, &idx[77..]);
        assert_eq!(m1.t, m2.t);
        let (w1, w2) = (m1.weights(), m2.weights());
        for j in 0..54 {
            assert!((w1[j] - w2[j]).abs() < 1e-5, "j={j}");
        }
    }

    #[test]
    fn learns_better_than_chance() {
        let data = SyntheticCovertype::new(20_000, 13).generate();
        let train: Vec<u32> = (0..15_000).collect();
        let test: Vec<u32> = (15_000..20_000).collect();
        // λ chosen for the test's n (the paper's 1e-6 needs paper-scale n
        // to converge; see DESIGN.md §4 and EXPERIMENTS.md).
        let l = Pegasos::new(54, 1e-3);
        let mut m = l.init();
        l.update(&mut m, &data, &train);
        let err = l.evaluate(&m, &data, &test);
        // Noise floor ≈ 0.19; majority-class baseline ≈ 0.46.
        assert!(err < 0.35, "error {err}");
        assert!(err > 0.10, "suspiciously low error {err}");
    }

    #[test]
    fn update_logged_then_revert_is_identity() {
        let data = SyntheticCovertype::new(100, 14).generate();
        let l = Pegasos::new(54, 1e-3);
        let mut m = l.init();
        l.update(&mut m, &data, &(0..50).collect::<Vec<_>>());
        let before = m.clone();
        let undo = l.update_logged(&mut m, &data, &(50..100).collect::<Vec<_>>());
        assert_ne!(before.t, m.t);
        l.revert(&mut m, &data, undo);
        assert_eq!(before.t, m.t);
        assert_eq!(before.scale, m.scale);
        assert_eq!(before.v, m.v);
    }

    #[test]
    fn contiguous_fast_path_is_bit_identical() {
        // update_rows/evaluate_rows over a materialized row block must
        // reproduce the indexed path exactly (the folded-layout contract).
        let data = SyntheticCovertype::new(120, 17).generate();
        let idx: Vec<u32> = (20..100).collect();
        let block = data.subset(&idx);
        let l = Pegasos::new(54, 1e-3);
        let mut a = l.init();
        l.update(&mut a, &data, &idx);
        let mut b = l.init();
        l.update_rows(&mut b, &block.x, &block.y, &data, &idx);
        assert_eq!(a.v, b.v);
        assert_eq!(a.scale, b.scale);
        assert_eq!(a.t, b.t);
        let held: Vec<u32> = (100..120).collect();
        let hb = data.subset(&held);
        let fast = l.evaluate_rows(&a, &hb.x, &hb.y, &data, &held);
        assert_eq!(l.evaluate(&a, &data, &held).to_bits(), fast.to_bits());
        // Empty block: a no-op, not a panic.
        l.update_rows(&mut b, &[], &[], &data, &[]);
        assert_eq!(a.t, b.t);
        assert_eq!(l.evaluate_rows(&a, &[], &[], &data, &[]), 0.0);
    }

    #[test]
    fn correct_heldout_tracks_retrain_without_block() {
        // First-order correction: not exact, but the corrected model's
        // held-out error must stay within the documented loose bound of
        // the from-scratch model trained without the block.
        let data = SyntheticCovertype::new(400, 18).generate();
        let l = Pegasos::new(54, 1e-3);
        let all: Vec<u32> = (0..400).collect();
        let held: Vec<u32> = (100..140).collect();
        let kept: Vec<u32> = (0..100).chain(140..400).collect();
        let mut full = l.init();
        l.update(&mut full, &data, &all);
        assert!(IncrementalLearner::try_correct_heldout(&l, &mut full, &data, &held));
        assert_eq!(full.t, kept.len() as u64);
        let mut oracle = l.init();
        l.update(&mut oracle, &data, &kept);
        let fast = l.evaluate(&full, &data, &held);
        let slow = l.evaluate(&oracle, &data, &held);
        assert!((fast - slow).abs() <= 0.5 * (1.0 + slow.abs()), "{fast} vs {slow}");
        assert!(full.v.iter().all(|v| v.is_finite()));
        // Degenerate fold (held ≥ t): a no-op, not a panic.
        let mut tiny = l.init();
        l.update(&mut tiny, &data, &all[..10]);
        let snap = tiny.clone();
        assert!(IncrementalLearner::try_correct_heldout(&l, &mut tiny, &data, &all[..10]));
        assert_eq!(snap.t, tiny.t);
    }

    #[test]
    fn empty_update_is_noop() {
        let data = SyntheticCovertype::new(10, 15).generate();
        let l = Pegasos::new(54, 1e-3);
        let mut m = l.init();
        l.update(&mut m, &data, &[]);
        assert_eq!(m.t, 0);
        assert!(m.v.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn long_run_scale_stays_finite() {
        let data = SyntheticCovertype::new(5_000, 16).generate();
        let idx: Vec<u32> = (0..5_000).collect();
        let l = Pegasos::new(54, 1e-6);
        let mut m = l.init();
        for _ in 0..4 {
            // NOTE: multiple passes are not a valid *incremental* usage
            // (paper end of §3.1) but must still be numerically sound.
            l.update(&mut m, &data, &idx);
        }
        assert!(m.scale.is_finite() && m.scale > 0.0);
        assert!(m.weights().iter().all(|v| v.is_finite()));
    }
}
