//! Type-erased, object-safe view of [`IncrementalLearner`] — the layer
//! that lets ONE executor pool schedule runs of *different* learner
//! families (the model-selection workload: rank `{Pegasos(λ), GaussianNb,
//! OnlineRidge(λ), KnnClassifier, …}` on a common dataset).
//!
//! The generic trait is not object-safe: its associated `Model`/`Undo`
//! types monomorphize every engine per learner, so a heterogeneous batch
//! cannot share `TreeCvExecutor::run_many`'s deques. This module erases
//! exactly those associated types and nothing else:
//!
//! * [`DynModel`] — a boxed model with object-safe `clone_box` /
//!   `clone_from_dyn`. The latter is what keeps the engines' pooled-buffer
//!   recycling alive through erasure: [`ErasedModel`]'s `Clone::clone_from`
//!   forwards to the concrete model's storage-reusing `clone_from` when
//!   the buffer holds the same model type, and falls back to a fresh
//!   `clone_box` when a recycled buffer came from a *different* learner
//!   family (possible in heterogeneous batches, where the fork-snapshot
//!   pool is shared across runs).
//! * [`ErasedLearner`] — `update`/`update_logged`/`revert`/`loss`/
//!   `evaluate`/`model_bytes` forwarding over [`ErasedModel`]. `evaluate`
//!   is forwarded explicitly (not reconstructed from `loss`) so learners
//!   with amortized chunk evaluation (ridge's lazy solve, XLA batching)
//!   keep their override — a requirement for bit-identical results.
//! * [`Erased`] — the blanket adapter: `Erased(learner)` implements
//!   [`ErasedLearner`] for every `IncrementalLearner` by downcasting the
//!   erased model/undo back to the concrete types.
//! * [`DynLearner`] — the reverse adapter: gives `&dyn ErasedLearner` the
//!   *generic* interface (`Model = ErasedModel`), so the erased path runs
//!   through the very same engines — `run_subtree`, `TreeCvExecutor`,
//!   `TreeCv`, `StandardCv` — instead of a parallel implementation. Every
//!   arithmetic operation an erased run performs is the concrete
//!   learner's own, in the same order, so per-run results are
//!   **bit-identical** to the generic path (`tests/integration_erased.rs`
//!   pins this for every learner in the crate). That includes the
//!   [`super::linalg`] kernel-layer dispatch: erased forwarding reaches
//!   the very same `update_rows`/`evaluate_rows` bodies, so the selected
//!   SIMD backend is identical (and identically invisible) on both paths.

use super::IncrementalLearner;
use crate::data::Dataset;
use std::any::Any;

/// Object-safe model handle: clonable (into a fresh box, or storage-reusing
/// into an existing same-typed box) and downcastable.
///
/// Implemented blanketly for every `Clone + Send + 'static` type, so
/// concrete learner models need nothing beyond what the generic trait
/// already demands.
pub trait DynModel: Send {
    /// Fresh boxed copy (the erased analogue of `Clone::clone`).
    fn clone_box(&self) -> Box<dyn DynModel>;

    /// Storage-reusing copy from `src` into `self` — the erased analogue
    /// of `Clone::clone_from`. Returns `false` (leaving `self` untouched)
    /// when `src` is a different concrete type, so callers can fall back
    /// to [`Self::clone_box`].
    fn clone_from_dyn(&mut self, src: &dyn DynModel) -> bool;

    fn as_any(&self) -> &dyn Any;
    fn as_any_mut(&mut self) -> &mut dyn Any;
}

impl<M: Clone + Send + 'static> DynModel for M {
    fn clone_box(&self) -> Box<dyn DynModel> {
        Box::new(self.clone())
    }

    fn clone_from_dyn(&mut self, src: &dyn DynModel) -> bool {
        match src.as_any().downcast_ref::<M>() {
            Some(src) => {
                self.clone_from(src);
                true
            }
            None => false,
        }
    }

    fn as_any(&self) -> &dyn Any {
        self
    }

    fn as_any_mut(&mut self) -> &mut dyn Any {
        self
    }
}

/// A type-erased model: what the engines carry when driven through
/// [`DynLearner`]. `Clone::clone_from` preserves the storage-reusing
/// semantics of the concrete model's `clone_from` whenever the target
/// buffer holds the same model type (see module docs).
pub struct ErasedModel(Box<dyn DynModel>);

impl ErasedModel {
    /// Borrow the concrete model, if it is an `M`.
    pub fn downcast_ref<M: 'static>(&self) -> Option<&M> {
        self.0.as_any().downcast_ref()
    }

    /// Mutably borrow the concrete model, if it is an `M`.
    pub fn downcast_mut<M: 'static>(&mut self) -> Option<&mut M> {
        self.0.as_any_mut().downcast_mut()
    }
}

impl Clone for ErasedModel {
    fn clone(&self) -> Self {
        ErasedModel(self.0.clone_box())
    }

    fn clone_from(&mut self, src: &Self) {
        // Same concrete type: reuse this buffer's storage. Different type
        // (a pooled buffer recycled from another learner family's run):
        // replace the box wholesale — correct either way, and the engines'
        // op counters never observe the difference.
        if !self.0.clone_from_dyn(&*src.0) {
            self.0 = src.0.clone_box();
        }
    }
}

/// Object-safe undo token (the erased analogue of the generic trait's
/// associated `Undo`); consumed by [`ErasedLearner::revert`].
pub trait DynUndo: Send {
    /// Unwrap for downcasting back to the concrete undo type.
    fn into_any(self: Box<Self>) -> Box<dyn Any>;
}

impl<U: Send + 'static> DynUndo for U {
    fn into_any(self: Box<Self>) -> Box<dyn Any> {
        self
    }
}

/// Object-safe incremental learner: the paper's `L : (M ∪ {∅}) × Z* → M`
/// with the model type erased, so heterogeneous collections (`Vec<Box<dyn
/// ErasedLearner>>`, registry constructors) and heterogeneous executor
/// batches ([`crate::cv::executor::TreeCvExecutor::run_many_erased`]) are
/// expressible. Obtain one with [`Erased`]; drive engines with
/// [`DynLearner`].
pub trait ErasedLearner: Send + Sync {
    /// Short human-readable name for reports.
    fn name(&self) -> &'static str;

    /// Expected feature dimension.
    fn dim(&self) -> usize;

    /// The empty model `∅`.
    fn init(&self) -> ErasedModel;

    /// Incremental update (ordered index slice, as in the generic trait).
    fn update(&self, model: &mut ErasedModel, data: &Dataset, idx: &[u32]);

    /// Update recording an undo token (save/revert strategy, §4.1).
    fn update_logged(&self, model: &mut ErasedModel, data: &Dataset, idx: &[u32])
        -> Box<dyn DynUndo>;

    /// Restore the model to its state before the matching
    /// [`Self::update_logged`] call.
    fn revert(&self, model: &mut ErasedModel, data: &Dataset, undo: Box<dyn DynUndo>);

    /// Single held-out point loss.
    fn loss(&self, model: &ErasedModel, data: &Dataset, i: u32) -> f64;

    /// Mean loss over a held-out chunk — forwards the concrete learner's
    /// `evaluate` (overrides included) for bit-identical results.
    fn evaluate(&self, model: &ErasedModel, data: &Dataset, idx: &[u32]) -> f64;

    /// Contiguous fast path (same slice contract as the generic
    /// [`IncrementalLearner::update_rows`]): forwards the concrete
    /// learner's override, so the fold-contiguous layout keeps both its
    /// speed and its bit-identity through erasure.
    fn update_rows(
        &self,
        model: &mut ErasedModel,
        x: &[f32],
        y: &[f32],
        data: &Dataset,
        ids: &[u32],
    );

    /// Contiguous chunk evaluation (see
    /// [`IncrementalLearner::evaluate_rows`]); forwards the concrete
    /// override chain.
    fn evaluate_rows(
        &self,
        model: &ErasedModel,
        x: &[f32],
        y: &[f32],
        data: &Dataset,
        ids: &[u32],
    ) -> f64;

    /// Approximate model size in bytes.
    fn model_bytes(&self, model: &ErasedModel) -> usize;

    /// Whether the wrapped learner supports the approximate-CV one-step
    /// correction (see [`IncrementalLearner::correctable`]).
    fn correctable(&self) -> bool;

    /// Probe-and-apply correction forwarding (see
    /// [`IncrementalLearner::try_correct_heldout`]): `false` leaves the
    /// model untouched.
    fn try_correct_heldout(&self, model: &mut ErasedModel, data: &Dataset, idx: &[u32]) -> bool;
}

/// Blanket adapter from the generic trait to the erased one: wrap any
/// learner as `Erased(learner)` and it becomes a `dyn ErasedLearner`.
pub struct Erased<L>(pub L);

impl<L> Erased<L> {
    /// Box the wrapped learner as a trait object (registry constructors).
    pub fn boxed(learner: L) -> Box<dyn ErasedLearner>
    where
        L: IncrementalLearner + Send + Sync + 'static,
        L::Model: 'static,
        L::Undo: 'static,
    {
        Box::new(Erased(learner))
    }
}

/// Downcast an erased model to `L`'s concrete model. A mismatch means the
/// caller fed a model from a different learner into this one — a bug in
/// the engine layer, never recoverable — so it panics with the pairing.
fn concrete<'m, L: IncrementalLearner>(model: &'m mut ErasedModel, name: &str) -> &'m mut L::Model
where
    L::Model: 'static,
{
    model
        .downcast_mut::<L::Model>()
        .unwrap_or_else(|| panic!("erased model fed to wrong learner `{name}`"))
}

impl<L> ErasedLearner for Erased<L>
where
    L: IncrementalLearner + Send + Sync,
    L::Model: 'static,
    L::Undo: 'static,
{
    fn name(&self) -> &'static str {
        self.0.name()
    }

    fn dim(&self) -> usize {
        self.0.dim()
    }

    fn init(&self) -> ErasedModel {
        ErasedModel(Box::new(self.0.init()))
    }

    fn update(&self, model: &mut ErasedModel, data: &Dataset, idx: &[u32]) {
        self.0.update(concrete::<L>(model, self.0.name()), data, idx);
    }

    fn update_logged(
        &self,
        model: &mut ErasedModel,
        data: &Dataset,
        idx: &[u32],
    ) -> Box<dyn DynUndo> {
        Box::new(self.0.update_logged(concrete::<L>(model, self.0.name()), data, idx))
    }

    fn revert(&self, model: &mut ErasedModel, data: &Dataset, undo: Box<dyn DynUndo>) {
        let undo = undo
            .into_any()
            .downcast::<L::Undo>()
            .unwrap_or_else(|_| panic!("erased undo fed to wrong learner `{}`", self.0.name()));
        self.0.revert(concrete::<L>(model, self.0.name()), data, *undo);
    }

    fn loss(&self, model: &ErasedModel, data: &Dataset, i: u32) -> f64 {
        self.0.loss(self.model_ref(model), data, i)
    }

    fn evaluate(&self, model: &ErasedModel, data: &Dataset, idx: &[u32]) -> f64 {
        self.0.evaluate(self.model_ref(model), data, idx)
    }

    fn update_rows(
        &self,
        model: &mut ErasedModel,
        x: &[f32],
        y: &[f32],
        data: &Dataset,
        ids: &[u32],
    ) {
        self.0.update_rows(concrete::<L>(model, self.0.name()), x, y, data, ids);
    }

    fn evaluate_rows(
        &self,
        model: &ErasedModel,
        x: &[f32],
        y: &[f32],
        data: &Dataset,
        ids: &[u32],
    ) -> f64 {
        self.0.evaluate_rows(self.model_ref(model), x, y, data, ids)
    }

    fn model_bytes(&self, model: &ErasedModel) -> usize {
        self.0.model_bytes(self.model_ref(model))
    }

    fn correctable(&self) -> bool {
        self.0.correctable()
    }

    fn try_correct_heldout(&self, model: &mut ErasedModel, data: &Dataset, idx: &[u32]) -> bool {
        self.0.try_correct_heldout(concrete::<L>(model, self.0.name()), data, idx)
    }
}

impl<L> Erased<L>
where
    L: IncrementalLearner,
    L::Model: 'static,
{
    fn model_ref<'m>(&self, model: &'m ErasedModel) -> &'m L::Model {
        model
            .downcast_ref::<L::Model>()
            .unwrap_or_else(|| panic!("erased model fed to wrong learner `{}`", self.0.name()))
    }
}

/// Adapter giving `&dyn ErasedLearner` the *generic* [`IncrementalLearner`]
/// interface (`Model = ErasedModel`), so the erased path drives the exact
/// same engine code — `run_subtree`, the executor, `TreeCv`, `StandardCv`
/// — as the generic path.
#[derive(Clone, Copy)]
pub struct DynLearner<'a>(pub &'a dyn ErasedLearner);

impl IncrementalLearner for DynLearner<'_> {
    type Model = ErasedModel;
    type Undo = Box<dyn DynUndo>;

    fn name(&self) -> &'static str {
        self.0.name()
    }

    fn dim(&self) -> usize {
        self.0.dim()
    }

    fn init(&self) -> ErasedModel {
        self.0.init()
    }

    fn update(&self, model: &mut ErasedModel, data: &Dataset, idx: &[u32]) {
        self.0.update(model, data, idx);
    }

    fn update_logged(
        &self,
        model: &mut ErasedModel,
        data: &Dataset,
        idx: &[u32],
    ) -> Box<dyn DynUndo> {
        self.0.update_logged(model, data, idx)
    }

    fn revert(&self, model: &mut ErasedModel, data: &Dataset, undo: Box<dyn DynUndo>) {
        self.0.revert(model, data, undo);
    }

    fn loss(&self, model: &ErasedModel, data: &Dataset, i: u32) -> f64 {
        self.0.loss(model, data, i)
    }

    fn evaluate(&self, model: &ErasedModel, data: &Dataset, idx: &[u32]) -> f64 {
        // Forward the erased override chain instead of the generic default
        // so learners with amortized chunk evaluation stay bit-identical.
        self.0.evaluate(model, data, idx)
    }

    fn update_rows(
        &self,
        model: &mut ErasedModel,
        x: &[f32],
        y: &[f32],
        data: &Dataset,
        ids: &[u32],
    ) {
        // Forward the erased override chain so the dense learners'
        // contiguous sweeps survive erasure (the generic default would
        // silently fall back to the indexed loop).
        self.0.update_rows(model, x, y, data, ids);
    }

    fn evaluate_rows(
        &self,
        model: &ErasedModel,
        x: &[f32],
        y: &[f32],
        data: &Dataset,
        ids: &[u32],
    ) -> f64 {
        self.0.evaluate_rows(model, x, y, data, ids)
    }

    fn model_bytes(&self, model: &ErasedModel) -> usize {
        self.0.model_bytes(model)
    }

    fn correctable(&self) -> bool {
        self.0.correctable()
    }

    fn try_correct_heldout(&self, model: &mut ErasedModel, data: &Dataset, idx: &[u32]) -> bool {
        self.0.try_correct_heldout(model, data, idx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cv::folds::{Folds, Ordering};
    use crate::cv::treecv::TreeCv;
    use crate::cv::{CvEngine, Strategy};
    use crate::data::synth::{SyntheticCovertype, SyntheticYearMsd};
    use crate::learner::histdensity::HistogramDensity;
    use crate::learner::pegasos::Pegasos;
    use crate::learner::perceptron::Perceptron;
    use crate::learner::ridge::OnlineRidge;

    #[test]
    fn erased_forwards_update_and_loss() {
        let data = SyntheticCovertype::new(200, 61).generate();
        let l = Pegasos::new(54, 1e-3);
        let e: Box<dyn ErasedLearner> = Erased::boxed(l.clone());
        let idx: Vec<u32> = (0..150).collect();
        let mut gm = l.init();
        l.update(&mut gm, &data, &idx);
        let mut em = e.init();
        e.update(&mut em, &data, &idx);
        let held: Vec<u32> = (150..200).collect();
        assert_eq!(l.evaluate(&gm, &data, &held), e.evaluate(&em, &data, &held));
        assert_eq!(l.loss(&gm, &data, 150), e.loss(&em, &data, 150));
        assert_eq!(l.model_bytes(&gm), e.model_bytes(&em));
        assert_eq!(e.name(), "pegasos");
        assert_eq!(e.dim(), 54);
    }

    #[test]
    fn erased_update_logged_revert_roundtrip() {
        // The perceptron has a genuinely sparse undo log; erased revert
        // must restore exactly what the concrete revert restores.
        let data = SyntheticCovertype::new(300, 62).generate();
        let l = Perceptron::new(54);
        let e: Box<dyn ErasedLearner> = Erased::boxed(l.clone());
        let idx: Vec<u32> = (0..200).collect();
        let mut gm = l.init();
        let mut em = e.init();
        l.update(&mut gm, &data, &idx);
        e.update(&mut em, &data, &idx);
        let gu = l.update_logged(&mut gm, &data, &(200..300).collect::<Vec<_>>());
        let eu = e.update_logged(&mut em, &data, &(200..300).collect::<Vec<_>>());
        l.revert(&mut gm, &data, gu);
        e.revert(&mut em, &data, eu);
        let got = em.downcast_ref::<crate::learner::perceptron::PerceptronModel>().unwrap();
        assert_eq!(got.w, gm.w);
        assert_eq!(got.bias, gm.bias);
        assert_eq!(got.mistakes, gm.mistakes);
    }

    #[test]
    fn clone_from_reuses_same_type_and_replaces_mismatch() {
        let l = Erased(HistogramDensity::new(-8.0, 8.0, 32));
        let data = crate::data::synth::SyntheticMixture1d::new(50, 63).generate();
        let mut a = ErasedLearner::init(&l);
        l.update(&mut a, &data, &(0..50).collect::<Vec<_>>());
        // Same-typed buffer: storage-reusing path.
        let mut buf = ErasedLearner::init(&l);
        buf.clone_from(&a);
        assert_eq!(l.evaluate(&buf, &data, &[0, 1]), l.evaluate(&a, &data, &[0, 1]));
        // Mismatched buffer (a pegasos model): wholesale replacement.
        let other = Erased(Pegasos::new(54, 1e-3));
        let mut buf = ErasedLearner::init(&other);
        buf.clone_from(&a);
        assert_eq!(l.evaluate(&buf, &data, &[0, 1]), l.evaluate(&a, &data, &[0, 1]));
    }

    #[test]
    fn dyn_learner_through_treecv_is_bit_identical() {
        // Ridge overrides `evaluate` (lazy solve); the erased path must
        // still match the generic engine bit for bit.
        let data = SyntheticYearMsd::new(240, 64).generate();
        let l = OnlineRidge::new(90, 0.5);
        let folds = Folds::new(240, 8, 65);
        let engine = TreeCv::new(Strategy::Copy, Ordering::Fixed, 3);
        let generic = engine.run(&l, &data, &folds);
        let erased_l = Erased(l);
        let erased = engine.run(&DynLearner(&erased_l), &data, &folds);
        assert_eq!(generic.per_fold, erased.per_fold);
        assert_eq!(generic.estimate.to_bits(), erased.estimate.to_bits());
        assert_eq!(generic.ops.points_updated, erased.ops.points_updated);
        assert_eq!(generic.ops.bytes_copied, erased.ops.bytes_copied);
    }

    #[test]
    fn erased_forwards_contiguous_fast_paths() {
        // The erased layer must forward the dense learners' update_rows /
        // evaluate_rows overrides, bit-identically to the generic calls.
        let data = SyntheticCovertype::new(120, 67).generate();
        let l = Pegasos::new(54, 1e-3);
        let e: Box<dyn ErasedLearner> = Erased::boxed(l.clone());
        let idx: Vec<u32> = (0..90).collect();
        let block = data.subset(&idx);
        let mut gm = l.init();
        l.update_rows(&mut gm, &block.x, &block.y, &data, &idx);
        let mut em = e.init();
        e.update_rows(&mut em, &block.x, &block.y, &data, &idx);
        let held: Vec<u32> = (90..120).collect();
        let hb = data.subset(&held);
        let want = l.evaluate_rows(&gm, &hb.x, &hb.y, &data, &held);
        let got = e.evaluate_rows(&em, &hb.x, &hb.y, &data, &held);
        assert_eq!(want.to_bits(), got.to_bits());
        assert_eq!(want.to_bits(), l.evaluate(&gm, &data, &held).to_bits());
    }

    #[test]
    fn correction_capability_forwards_through_erasure() {
        // Convex learners advertise the capability through every layer of
        // the erasure chain; non-convex ones decline without touching the
        // model.
        let data = SyntheticYearMsd::new(60, 68).generate();
        let ridge = OnlineRidge::new(90, 1.0);
        let e: Box<dyn ErasedLearner> = Erased::boxed(ridge.clone());
        assert!(e.correctable());
        let dynl = DynLearner(&*e);
        assert!(IncrementalLearner::correctable(&dynl));
        let mut gm = ridge.init();
        ridge.update(&mut gm, &data, &(0..60).collect::<Vec<_>>());
        let mut em = e.init();
        e.update(&mut em, &data, &(0..60).collect::<Vec<_>>());
        let held: Vec<u32> = (10..20).collect();
        assert!(IncrementalLearner::try_correct_heldout(&ridge, &mut gm, &data, &held));
        assert!(IncrementalLearner::try_correct_heldout(&dynl, &mut em, &data, &held));
        assert_eq!(
            ridge.evaluate(&gm, &data, &held).to_bits(),
            e.evaluate(&em, &data, &held).to_bits()
        );
        let hist: Box<dyn ErasedLearner> = Erased::boxed(HistogramDensity::new(-8.0, 8.0, 8));
        assert!(!hist.correctable());
        let d1 = crate::data::synth::SyntheticMixture1d::new(20, 69).generate();
        let mut hm = hist.init();
        hist.update(&mut hm, &d1, &(0..20).collect::<Vec<_>>());
        assert!(!hist.try_correct_heldout(&mut hm, &d1, &[0, 1]));
    }

    #[test]
    #[should_panic(expected = "wrong learner")]
    fn model_learner_mismatch_panics() {
        let data = SyntheticCovertype::new(10, 66).generate();
        let pegasos = Erased(Pegasos::new(54, 1e-3));
        let hist = Erased(HistogramDensity::new(-8.0, 8.0, 8));
        let mut m = ErasedLearner::init(&hist);
        pegasos.update(&mut m, &data, &[0]);
    }
}
