//! Least-squares SGD (LSQSGD): the robust stochastic-approximation
//! algorithm of Nemirovski et al. [2009] for the squared loss, with the
//! parameter vector constrained to the unit l2-ball and the *averaged*
//! hypothesis as the model — exactly the second learner in the paper's
//! experiments (§5, Table 2 bottom; step size α = n^{-1/2} on
//! YearPredictionMSD with targets scaled to [0,1]).
//!
//! Per-point step: `g = 2(⟨w,x⟩ − y)·x`; `w ← Π_{‖·‖≤1}(w − α g)`;
//! `w̄ ← w̄ + (w − w̄)/t`. Predictions (and therefore the CV loss) use `w̄`.
//! SGD over a compact set with bounded convex loss has O(1/√n) excess
//! risk, so by the paper's Theorem 2 it is incrementally stable with
//! g(n, b) = O(1/√n).

use super::{linalg, ConvexCorrectable, IncrementalLearner};
use crate::data::Dataset;
use crate::loss;

/// LSQSGD trainer configuration.
#[derive(Debug, Clone)]
pub struct LsqSgd {
    d: usize,
    /// Constant step size (paper: n^{-1/2} for a single pass over n points).
    pub alpha: f64,
}

/// LSQSGD model: current iterate, running average, and step count.
#[derive(Debug)]
pub struct LsqSgdModel {
    /// Current (projected) iterate.
    pub w: Vec<f32>,
    /// Averaged iterate — the hypothesis used for prediction.
    pub wavg: Vec<f32>,
    /// Number of points consumed.
    pub t: u64,
}

// Hand-written so `clone_from` reuses the target's heap storage (the
// derive's fallback reallocates; the CV engines recycle snapshot buffers).
impl Clone for LsqSgdModel {
    fn clone(&self) -> Self {
        Self { w: self.w.clone(), wavg: self.wavg.clone(), t: self.t }
    }

    fn clone_from(&mut self, src: &Self) {
        self.w.clone_from(&src.w);
        self.wavg.clone_from(&src.wavg);
        self.t = src.t;
    }
}

impl LsqSgdModel {
    /// Prediction `⟨w̄, x⟩`.
    #[inline(always)]
    pub fn predict(&self, x: &[f32]) -> f32 {
        linalg::dot(&self.wavg, x)
    }
}

impl LsqSgd {
    pub fn new(d: usize, alpha: f64) -> Self {
        assert!(alpha > 0.0, "step size must be positive");
        Self { d, alpha }
    }

    /// The paper's step-size rule for a dataset of size `n`.
    pub fn with_paper_step(d: usize, n: usize) -> Self {
        Self::new(d, 1.0 / (n as f64).sqrt())
    }

    #[inline(always)]
    fn step(&self, m: &mut LsqSgdModel, x: &[f32], y: f32) {
        m.t += 1;
        // Gradient step: w -= α · 2(⟨w,x⟩ - y) x.
        let resid = linalg::dot(&m.w, x) - y;
        linalg::axpy((-2.0 * self.alpha * resid as f64) as f32, x, &mut m.w);
        // Project onto the unit l2 ball.
        let nsq = linalg::norm_sq(&m.w);
        if nsq > 1.0 {
            linalg::scale((1.0 / nsq.sqrt()) as f32, &mut m.w);
        }
        // Running average: w̄ += (w - w̄)/t, through the kernel layer.
        let inv_t = (1.0 / m.t as f64) as f32;
        linalg::avg_update(inv_t, &m.w, &mut m.wavg);
    }
}

impl IncrementalLearner for LsqSgd {
    type Model = LsqSgdModel;
    /// Dense model touched everywhere per step → snapshot undo.
    type Undo = LsqSgdModel;

    fn name(&self) -> &'static str {
        "lsqsgd"
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn init(&self) -> LsqSgdModel {
        LsqSgdModel { w: vec![0.0; self.d], wavg: vec![0.0; self.d], t: 0 }
    }

    fn update(&self, m: &mut LsqSgdModel, data: &Dataset, idx: &[u32]) {
        debug_assert_eq!(data.d, self.d);
        for &i in idx {
            self.step(m, data.row(i), data.label(i));
        }
    }

    /// Contiguous fast path: identical `step` sequence over a row-major
    /// slice (folded-layout contract — bit-identical to `update`).
    fn update_rows(
        &self,
        m: &mut LsqSgdModel,
        x: &[f32],
        y: &[f32],
        _data: &Dataset,
        _ids: &[u32],
    ) {
        debug_assert_eq!(x.len(), y.len() * self.d);
        for (row, &yi) in x.chunks_exact(self.d).zip(y) {
            self.step(m, row, yi);
        }
    }

    fn update_logged(&self, m: &mut LsqSgdModel, data: &Dataset, idx: &[u32]) -> LsqSgdModel {
        let snap = m.clone();
        self.update(m, data, idx);
        snap
    }

    fn revert(&self, m: &mut LsqSgdModel, _data: &Dataset, undo: LsqSgdModel) {
        *m = undo;
    }

    fn loss(&self, m: &LsqSgdModel, data: &Dataset, i: u32) -> f64 {
        loss::squared_error(m.predict(data.row(i)), data.label(i))
    }

    fn evaluate_rows(
        &self,
        m: &LsqSgdModel,
        x: &[f32],
        y: &[f32],
        _data: &Dataset,
        _ids: &[u32],
    ) -> f64 {
        if y.is_empty() {
            return 0.0;
        }
        // Blocked sweep through the kernel layer (dot_block ≡ dot per row,
        // so each prediction is bitwise equal to `m.predict(row)`).
        let mut s = 0f64;
        let mut preds = [0f32; linalg::EVAL_BLOCK_ROWS];
        let xc = x.chunks(self.d * linalg::EVAL_BLOCK_ROWS);
        for (xb, yb) in xc.zip(y.chunks(linalg::EVAL_BLOCK_ROWS)) {
            let out = &mut preds[..yb.len()];
            linalg::dot_block(&m.wavg, xb, self.d, out);
            for (&p, &yi) in out.iter().zip(yb) {
                s += loss::squared_error(p, yi);
            }
        }
        s / y.len() as f64
    }

    fn model_bytes(&self, m: &LsqSgdModel) -> usize {
        (m.w.len() + m.wavg.len()) * 4 + 8
    }

    fn correctable(&self) -> bool {
        true
    }

    fn try_correct_heldout(&self, m: &mut LsqSgdModel, data: &Dataset, idx: &[u32]) -> bool {
        ConvexCorrectable::correct_heldout(self, m, data, idx);
        true
    }
}

/// One-step gradient correction on the *averaged* hypothesis (the one
/// predictions use): removing a held-out block's influence is one ascent
/// step along its squared-loss gradient at the full-data model,
/// `w̄ ← Π_{‖·‖≤1}(w̄ + α Σ_{i∈f} 2(⟨w̄,x_i⟩ − y_i) x_i)`, followed by the
/// same unit-ball projection the forward pass applies. The current
/// iterate `w` and step count are left untouched — the corrected model is
/// an evaluation-only approximation, which is all the approx engine reads.
impl ConvexCorrectable for LsqSgd {
    fn correct_heldout(&self, m: &mut LsqSgdModel, data: &Dataset, idx: &[u32]) {
        if idx.is_empty() {
            return;
        }
        // Pass 1: residuals at the original averaged hypothesis.
        let mut resid = Vec::with_capacity(idx.len());
        for &i in idx {
            resid.push((m.predict(data.row(i)) - data.label(i)) as f64);
        }
        // Pass 2: one ascent step per held-out point, then re-project.
        for (&r, &i) in resid.iter().zip(idx) {
            linalg::axpy((2.0 * self.alpha * r) as f32, data.row(i), &mut m.wavg);
        }
        let nsq = linalg::norm_sq(&m.wavg);
        if nsq > 1.0 {
            linalg::scale((1.0 / nsq.sqrt()) as f32, &mut m.wavg);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SyntheticYearMsd;

    #[test]
    fn iterate_stays_in_unit_ball() {
        let data = SyntheticYearMsd::new(2_000, 21).generate();
        let l = LsqSgd::new(90, 0.5); // large step to stress the projection
        let mut m = l.init();
        l.update(&mut m, &data, &(0..2_000).collect::<Vec<_>>());
        assert!(linalg::norm_sq(&m.w) <= 1.0 + 1e-5);
    }

    #[test]
    fn average_is_running_mean_of_iterates() {
        let data = SyntheticYearMsd::new(50, 22).generate();
        let l = LsqSgd::new(90, 0.01);
        let mut m = l.init();
        // Manual replication with explicit iterate history.
        let mut iterates: Vec<Vec<f32>> = Vec::new();
        let mut m2 = l.init();
        for i in 0..50u32 {
            l.update(&mut m, &data, &[i]);
            l.update(&mut m2, &data, &[i]);
            iterates.push(m2.w.clone());
        }
        let mut mean = vec![0f64; 90];
        for it in &iterates {
            for j in 0..90 {
                mean[j] += it[j] as f64;
            }
        }
        for j in 0..90 {
            mean[j] /= iterates.len() as f64;
            assert!((m.wavg[j] as f64 - mean[j]).abs() < 1e-4, "j={j}");
        }
    }

    #[test]
    fn reduces_squared_error_vs_zero_predictor() {
        let n = 40_000;
        let data = SyntheticYearMsd::new(n, 23).generate();
        let train: Vec<u32> = (0..30_000).collect();
        let test: Vec<u32> = (30_000..n as u32).collect();
        let l = LsqSgd::with_paper_step(90, train.len());
        let mut m = l.init();
        l.update(&mut m, &data, &train);
        let err = l.evaluate(&m, &data, &test);
        let zero_err: f64 = test
            .iter()
            .map(|&i| (data.label(i) as f64).powi(2))
            .sum::<f64>()
            / test.len() as f64;
        assert!(err < zero_err, "sgd {err} vs zero-predictor {zero_err}");
        assert!(err.is_finite());
    }

    #[test]
    fn incremental_equals_single_pass() {
        let data = SyntheticYearMsd::new(300, 24).generate();
        let idx: Vec<u32> = (0..300).collect();
        let l = LsqSgd::new(90, 0.05);
        let mut m1 = l.init();
        l.update(&mut m1, &data, &idx);
        let mut m2 = l.init();
        l.update(&mut m2, &data, &idx[..123]);
        l.update(&mut m2, &data, &idx[123..]);
        assert_eq!(m1.t, m2.t);
        for j in 0..90 {
            assert!((m1.wavg[j] - m2.wavg[j]).abs() < 1e-6, "j={j}");
        }
    }

    #[test]
    fn contiguous_fast_path_is_bit_identical() {
        let data = SyntheticYearMsd::new(150, 26).generate();
        let idx: Vec<u32> = (10..120).collect();
        let block = data.subset(&idx);
        let l = LsqSgd::new(90, 0.05);
        let mut a = l.init();
        l.update(&mut a, &data, &idx);
        let mut b = l.init();
        l.update_rows(&mut b, &block.x, &block.y, &data, &idx);
        assert_eq!(a.w, b.w);
        assert_eq!(a.wavg, b.wavg);
        assert_eq!(a.t, b.t);
        let held: Vec<u32> = (120..150).collect();
        let hb = data.subset(&held);
        let fast = l.evaluate_rows(&a, &hb.x, &hb.y, &data, &held);
        assert_eq!(l.evaluate(&a, &data, &held).to_bits(), fast.to_bits());
    }

    #[test]
    fn correct_heldout_tracks_retrain_without_block() {
        // First-order correction: the corrected averaged hypothesis must
        // score the held-out block within the documented loose bound of
        // the from-scratch model trained without it.
        let data = SyntheticYearMsd::new(500, 27).generate();
        let l = LsqSgd::with_paper_step(90, 500);
        let all: Vec<u32> = (0..500).collect();
        let held: Vec<u32> = (200..250).collect();
        let kept: Vec<u32> = (0..200).chain(250..500).collect();
        let mut full = l.init();
        l.update(&mut full, &data, &all);
        assert!(IncrementalLearner::try_correct_heldout(&l, &mut full, &data, &held));
        assert!(linalg::norm_sq(&full.wavg) <= 1.0 + 1e-5);
        let mut oracle = l.init();
        l.update(&mut oracle, &data, &kept);
        let fast = l.evaluate(&full, &data, &held);
        let slow = l.evaluate(&oracle, &data, &held);
        assert!((fast - slow).abs() <= 0.5 * (1.0 + slow.abs()), "{fast} vs {slow}");
        assert!(IncrementalLearner::correctable(&l));
    }

    #[test]
    fn update_logged_then_revert_is_identity() {
        let data = SyntheticYearMsd::new(100, 25).generate();
        let l = LsqSgd::new(90, 0.05);
        let mut m = l.init();
        l.update(&mut m, &data, &(0..30).collect::<Vec<_>>());
        let before = m.clone();
        let undo = l.update_logged(&mut m, &data, &(30..100).collect::<Vec<_>>());
        l.revert(&mut m, &data, undo);
        assert_eq!(before.w, m.w);
        assert_eq!(before.wavg, m.wavg);
        assert_eq!(before.t, m.t);
    }
}
