//! Ridge regression over running sufficient statistics `A = XᵀX`,
//! `b = Xᵀy` (f64). Incremental by construction: an update adds rank-one
//! terms; the weight vector is solved lazily at evaluation time via
//! Cholesky.
//!
//! Why it is here: ridge/RLS is the model family the *prior-work* fast-CV
//! methods specialize to (Golub et al. 1979's generalized CV, Pahikkala
//! et al. 2006, Cawley 2006 — paper §1.1). [`crate::cv::exact`] implements
//! the classic closed-form LOOCV (hat-matrix leverage formula) for this
//! learner, giving an *exact* external comparator against which TreeCV's
//! LOOCV is validated end-to-end; this reproduces the paper's claim that
//! for batching-insensitive learners `R̂_{k-CV} = R_{k-CV}` (Theorem 1 with
//! g ≡ 0, modulo f64 rounding).

use super::{linalg, ConvexCorrectable, IncrementalLearner, MergeableLearner};
use crate::data::Dataset;
use crate::loss;

/// Ridge trainer with fixed regularizer λ (added once, not per-point).
#[derive(Debug, Clone)]
pub struct OnlineRidge {
    d: usize,
    pub lambda: f64,
}

/// Sufficient statistics; `a` is the dense d×d Gram matrix (row-major).
#[derive(Debug, PartialEq)]
pub struct RidgeModel {
    pub a: Vec<f64>,
    pub b: Vec<f64>,
    pub n: u64,
}

// Hand-written so `clone_from` reuses the target's heap storage (the
// derive's fallback reallocates; d² Gram matrices are the expensive case
// the CV engines' snapshot-buffer recycling exists for).
impl Clone for RidgeModel {
    fn clone(&self) -> Self {
        Self { a: self.a.clone(), b: self.b.clone(), n: self.n }
    }

    fn clone_from(&mut self, src: &Self) {
        self.a.clone_from(&src.a);
        self.b.clone_from(&src.b);
        self.n = src.n;
    }
}

/// Undo log: indices added (rank-one terms are subtracted back).
pub type RidgeUndo = Vec<u32>;

impl OnlineRidge {
    pub fn new(d: usize, lambda: f64) -> Self {
        assert!(lambda > 0.0);
        Self { d, lambda }
    }

    /// Solve `(A + λI) w = b`. Returns zeros for an empty model.
    pub fn solve(&self, m: &RidgeModel) -> Vec<f64> {
        if m.n == 0 {
            return vec![0.0; self.d];
        }
        let d = self.d;
        let mut reg = m.a.clone();
        for j in 0..d {
            reg[j * d + j] += self.lambda;
        }
        // invariant: A = Σ x xᵀ is PSD, so A + λI is SPD for λ > 0 and
        // the factorization cannot fail.
        let l = linalg::cholesky(&reg, d).expect("A + λI is SPD for λ > 0");
        linalg::cholesky_solve(&l, d, &m.b)
    }

    fn rank_one(&self, m: &mut RidgeModel, x: &[f32], y: f32, sign: f64) {
        // `sign` is ±1, so `sign·y` and `sign·xi` are exact and the
        // historical `sign·xi·y` / `sign·xi·xj` accumulations route
        // through the mixed-precision axpy kernel bitwise unchanged
        // (exact negation + bitwise-commutative multiply).
        let d = self.d;
        linalg::axpy_f64f32(sign * y as f64, x, &mut m.b);
        for i in 0..d {
            linalg::axpy_f64f32(sign * x[i] as f64, x, &mut m.a[i * d..(i + 1) * d]);
        }
    }
}

impl IncrementalLearner for OnlineRidge {
    type Model = RidgeModel;
    type Undo = RidgeUndo;

    fn name(&self) -> &'static str {
        "online-ridge"
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn init(&self) -> RidgeModel {
        RidgeModel { a: vec![0.0; self.d * self.d], b: vec![0.0; self.d], n: 0 }
    }

    fn update(&self, m: &mut RidgeModel, data: &Dataset, idx: &[u32]) {
        for &i in idx {
            self.rank_one(m, data.row(i), data.label(i), 1.0);
            m.n += 1;
        }
    }

    /// Contiguous fast path: `b` in one linear pass, then the d² Gram
    /// update through the cache-blocked rank-B syrk kernel — each row of
    /// `A` is swept once per [`linalg::SYRK_BLOCK_ROWS`] points instead of
    /// once per point. Bitwise equal to the per-point rank-one sequence
    /// (the stats are order-insensitive per accumulator; see
    /// [`linalg::syrk_accumulate_blocked`]).
    fn update_rows(
        &self,
        m: &mut RidgeModel,
        x: &[f32],
        y: &[f32],
        _data: &Dataset,
        _ids: &[u32],
    ) {
        debug_assert_eq!(x.len(), y.len() * self.d);
        for (row, &yi) in x.chunks_exact(self.d).zip(y) {
            linalg::axpy_f64f32(yi as f64, row, &mut m.b);
        }
        linalg::syrk_accumulate(&mut m.a, self.d, x);
        m.n += y.len() as u64;
    }

    fn update_logged(&self, m: &mut RidgeModel, data: &Dataset, idx: &[u32]) -> RidgeUndo {
        self.update(m, data, idx);
        idx.to_vec()
    }

    fn revert(&self, m: &mut RidgeModel, data: &Dataset, undo: RidgeUndo) {
        for &i in undo.iter().rev() {
            self.rank_one(m, data.row(i), data.label(i), -1.0);
            m.n -= 1;
        }
    }

    fn loss(&self, m: &RidgeModel, data: &Dataset, i: u32) -> f64 {
        // Single-point path (solves per call — see `evaluate` for the
        // amortized chunk path the CV engines actually hit).
        let w = self.solve(m);
        let pred = linalg::dot_f64f32(&w, data.row(i));
        loss::squared_error(pred as f32, data.label(i))
    }

    /// Solve once, score the whole chunk through the blocked kernel: rows
    /// are gathered a block at a time and swept with `dot_block_f64f32`
    /// (each blocked prediction is bitwise equal to `dot_f64f32` on that
    /// row, so this is bit-identical to the historical per-row loop).
    fn evaluate(&self, m: &RidgeModel, data: &Dataset, idx: &[u32]) -> f64 {
        if idx.is_empty() {
            return 0.0;
        }
        let d = self.d;
        let w = self.solve(m);
        let mut s = 0f64;
        let mut gathered = vec![0f32; d * linalg::EVAL_BLOCK_ROWS];
        let mut preds = [0f64; linalg::EVAL_BLOCK_ROWS];
        for blk in idx.chunks(linalg::EVAL_BLOCK_ROWS) {
            for (j, &i) in blk.iter().enumerate() {
                gathered[j * d..(j + 1) * d].copy_from_slice(data.row(i));
            }
            let out = &mut preds[..blk.len()];
            linalg::dot_block_f64f32(&w, &gathered[..blk.len() * d], d, out);
            for (&p, &i) in out.iter().zip(blk) {
                s += loss::squared_error(p as f32, data.label(i));
            }
        }
        s / idx.len() as f64
    }

    /// Contiguous chunk evaluation: one solve, then score the row-major
    /// slice — the folded analogue of [`Self::evaluate`], bit-identical.
    fn evaluate_rows(
        &self,
        m: &RidgeModel,
        x: &[f32],
        y: &[f32],
        _data: &Dataset,
        _ids: &[u32],
    ) -> f64 {
        if y.is_empty() {
            return 0.0;
        }
        // One solve, then a blocked mixed-precision sweep (each blocked
        // prediction is bitwise equal to `dot_f64f32` on that row).
        let w = self.solve(m);
        let mut s = 0f64;
        let mut preds = [0f64; linalg::EVAL_BLOCK_ROWS];
        let xc = x.chunks(self.d * linalg::EVAL_BLOCK_ROWS);
        for (xb, yb) in xc.zip(y.chunks(linalg::EVAL_BLOCK_ROWS)) {
            let out = &mut preds[..yb.len()];
            linalg::dot_block_f64f32(&w, xb, self.d, out);
            for (&p, &yi) in out.iter().zip(yb) {
                s += loss::squared_error(p as f32, yi);
            }
        }
        s / y.len() as f64
    }

    fn model_bytes(&self, m: &RidgeModel) -> usize {
        (m.a.len() + m.b.len()) * 8 + 8
    }

    fn correctable(&self) -> bool {
        true
    }

    fn try_correct_heldout(&self, m: &mut RidgeModel, data: &Dataset, idx: &[u32]) -> bool {
        ConvexCorrectable::correct_heldout(self, m, data, idx);
        true
    }
}

/// Ridge's correction is the *exact* Sherman–Morrison/Woodbury block
/// downdate expressed on the sufficient statistics: subtracting the
/// held-out rank-one terms from `A`/`b` gives exactly the statistics of
/// the model trained without the block, so the only approximation left
/// is f64 rounding (the integration battery pins it at 1e-8 against the
/// from-scratch oracle at well-conditioned λ).
impl ConvexCorrectable for OnlineRidge {
    fn correct_heldout(&self, m: &mut RidgeModel, data: &Dataset, idx: &[u32]) {
        for &i in idx {
            self.rank_one(m, data.row(i), data.label(i), -1.0);
        }
        m.n = m.n.saturating_sub(idx.len() as u64);
    }
}

impl MergeableLearner for OnlineRidge {
    fn merge(&self, a: &RidgeModel, b: &RidgeModel) -> RidgeModel {
        RidgeModel {
            a: a.a.iter().zip(&b.a).map(|(x, y)| x + y).collect(),
            b: a.b.iter().zip(&b.b).map(|(x, y)| x + y).collect(),
            n: a.n + b.n,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SyntheticYearMsd;

    #[test]
    fn recovers_exact_linear_relation() {
        // y = 2·x0 − 3·x1, no noise, tiny λ → near-exact recovery.
        let n = 50;
        let mut x = Vec::new();
        let mut y = Vec::new();
        let mut rng = crate::rng::Rng::new(71);
        for _ in 0..n {
            let (a, b) = (rng.next_gaussian(), rng.next_gaussian());
            x.extend_from_slice(&[a, b]);
            y.push(2.0 * a - 3.0 * b);
        }
        let data = Dataset::new(x, y, 2);
        let l = OnlineRidge::new(2, 1e-8);
        let mut m = l.init();
        l.update(&mut m, &data, &(0..n as u32).collect::<Vec<_>>());
        let w = l.solve(&m);
        assert!((w[0] - 2.0).abs() < 1e-4, "w0 {}", w[0]);
        assert!((w[1] + 3.0).abs() < 1e-4, "w1 {}", w[1]);
    }

    #[test]
    fn batching_insensitive() {
        let data = SyntheticYearMsd::new(300, 72).generate();
        let l = OnlineRidge::new(90, 1.0);
        let idx: Vec<u32> = (0..300).collect();
        let mut batch = l.init();
        l.update(&mut batch, &data, &idx);
        let mut inc = l.init();
        for c in idx.chunks(41) {
            l.update(&mut inc, &data, c);
        }
        assert_eq!(batch.n, inc.n);
        for (a, b) in batch.a.iter().zip(&inc.a) {
            assert!((a - b).abs() < 1e-9);
        }
    }

    #[test]
    fn merge_equals_joint() {
        let data = SyntheticYearMsd::new(200, 73).generate();
        let l = OnlineRidge::new(90, 1.0);
        let mut a = l.init();
        let mut b = l.init();
        let mut joint = l.init();
        l.update(&mut a, &data, &(0..100).collect::<Vec<_>>());
        l.update(&mut b, &data, &(100..200).collect::<Vec<_>>());
        l.update(&mut joint, &data, &(0..200).collect::<Vec<_>>());
        let merged = l.merge(&a, &b);
        assert_eq!(merged.n, joint.n);
        for (x, y) in merged.a.iter().zip(&joint.a) {
            assert!((x - y).abs() < 1e-9);
        }
    }

    #[test]
    fn revert_restores_stats() {
        let data = SyntheticYearMsd::new(100, 74).generate();
        let l = OnlineRidge::new(90, 1.0);
        let mut m = l.init();
        l.update(&mut m, &data, &(0..50).collect::<Vec<_>>());
        let before = m.clone();
        let undo = l.update_logged(&mut m, &data, &(50..100).collect::<Vec<_>>());
        l.revert(&mut m, &data, undo);
        assert_eq!(m.n, before.n);
        for (x, y) in m.a.iter().zip(&before.a) {
            assert!((x - y).abs() < 1e-8);
        }
    }

    #[test]
    fn evaluate_matches_per_point_loss() {
        let data = SyntheticYearMsd::new(120, 75).generate();
        let l = OnlineRidge::new(90, 0.5);
        let mut m = l.init();
        l.update(&mut m, &data, &(0..100).collect::<Vec<_>>());
        let idx: Vec<u32> = (100..120).collect();
        let fast = l.evaluate(&m, &data, &idx);
        let slow: f64 = idx.iter().map(|&i| l.loss(&m, &data, i)).sum::<f64>() / idx.len() as f64;
        assert!((fast - slow).abs() < 1e-12);
    }

    #[test]
    fn contiguous_fast_path_is_bit_identical() {
        let data = SyntheticYearMsd::new(120, 77).generate();
        let idx: Vec<u32> = (0..90).collect();
        let block = data.subset(&idx);
        let l = OnlineRidge::new(90, 0.5);
        let mut a = l.init();
        l.update(&mut a, &data, &idx);
        let mut b = l.init();
        l.update_rows(&mut b, &block.x, &block.y, &data, &idx);
        assert_eq!(a.n, b.n);
        assert_eq!(a.a, b.a);
        assert_eq!(a.b, b.b);
        let held: Vec<u32> = (90..120).collect();
        let hb = data.subset(&held);
        let fast = l.evaluate_rows(&a, &hb.x, &hb.y, &data, &held);
        assert_eq!(l.evaluate(&a, &data, &held).to_bits(), fast.to_bits());
    }

    #[test]
    fn correct_heldout_matches_retrain_without_block() {
        // The block downdate is exact on the sufficient statistics: the
        // corrected model must match retraining without the held-out rows
        // to f64 rounding, and the held-out estimates must agree tightly.
        let data = SyntheticYearMsd::new(200, 78).generate();
        let l = OnlineRidge::new(90, 1.0);
        let all: Vec<u32> = (0..200).collect();
        let held: Vec<u32> = (40..80).collect();
        let kept: Vec<u32> = (0..40).chain(80..200).collect();
        let mut full = l.init();
        l.update(&mut full, &data, &all);
        assert!(IncrementalLearner::try_correct_heldout(&l, &mut full, &data, &held));
        let mut oracle = l.init();
        l.update(&mut oracle, &data, &kept);
        assert_eq!(full.n, oracle.n);
        for (a, b) in full.a.iter().zip(&oracle.a) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
        let fast = l.evaluate(&full, &data, &held);
        let slow = l.evaluate(&oracle, &data, &held);
        assert!((fast - slow).abs() < 1e-8, "{fast} vs {slow}");
        assert!(IncrementalLearner::correctable(&l));
    }

    #[test]
    fn empty_model_predicts_zero() {
        let data = SyntheticYearMsd::new(10, 76).generate();
        let l = OnlineRidge::new(90, 1.0);
        let m = l.init();
        let loss0 = l.loss(&m, &data, 0);
        assert!((loss0 - (data.label(0) as f64).powi(2)).abs() < 1e-12);
    }
}
