//! Histogram density estimation — the paper's Table 1 row 4 instantiation
//! (`Y = {NoLabel}`, prediction is a density, loss is `−log f(x)`).
//!
//! The model is a vector of *integer* bin counts over a fixed range, plus
//! an out-of-range mass bucket, with Laplace smoothing at prediction time.
//! Because the sufficient statistics are integers, the learner is *exactly*
//! order- and batching-insensitive: `f^inc == f^batch` bit-for-bit, i.e.
//! g ≡ 0 in the paper's Definition 1. TreeCV must therefore reproduce the
//! standard k-CV estimate exactly (Theorem 1 with g = 0) — this learner is
//! one of the two structural correctness oracles used by the test suite.
//! It is also mergeable (add the counts), driving the Izbicki baseline.

use super::{IncrementalLearner, MergeableLearner};
use crate::data::Dataset;
use crate::loss;

/// Histogram density estimator over feature 0 of the dataset.
#[derive(Debug, Clone)]
pub struct HistogramDensity {
    /// Histogram support `[lo, hi)`.
    pub lo: f32,
    pub hi: f32,
    /// Number of equal-width bins.
    pub bins: usize,
}

/// Integer sufficient statistics.
#[derive(Debug, PartialEq, Eq)]
pub struct HistModel {
    pub counts: Vec<u64>,
    /// Points outside `[lo, hi)`.
    pub outside: u64,
    pub total: u64,
}

// Hand-written so `clone_from` reuses the target's heap storage (the
// derive's fallback reallocates; the CV engines recycle snapshot buffers).
impl Clone for HistModel {
    fn clone(&self) -> Self {
        Self { counts: self.counts.clone(), outside: self.outside, total: self.total }
    }

    fn clone_from(&mut self, src: &Self) {
        self.counts.clone_from(&src.counts);
        self.outside = src.outside;
        self.total = src.total;
    }
}

/// Undo log: the bin each point landed in (`usize::MAX` = outside).
pub type HistUndo = Vec<usize>;

impl HistogramDensity {
    pub fn new(lo: f32, hi: f32, bins: usize) -> Self {
        assert!(hi > lo && bins > 0);
        Self { lo, hi, bins }
    }

    #[inline(always)]
    fn bin(&self, v: f32) -> usize {
        if v < self.lo || v >= self.hi || !v.is_finite() {
            return usize::MAX;
        }
        let w = (self.hi - self.lo) / self.bins as f32;
        (((v - self.lo) / w) as usize).min(self.bins - 1)
    }

    /// Smoothed density at `v` (Laplace add-one over bins + outside bucket).
    pub fn density(&self, m: &HistModel, v: f32) -> f64 {
        let w = ((self.hi - self.lo) / self.bins as f32) as f64;
        let denom = (m.total + self.bins as u64 + 1) as f64;
        match self.bin(v) {
            usize::MAX => 1.0 / denom, // point mass for the outside bucket
            b => (m.counts[b] + 1) as f64 / (denom * w),
        }
    }
}

impl IncrementalLearner for HistogramDensity {
    type Model = HistModel;
    type Undo = HistUndo;

    fn name(&self) -> &'static str {
        "hist-density"
    }

    fn dim(&self) -> usize {
        1
    }

    fn init(&self) -> HistModel {
        HistModel { counts: vec![0; self.bins], outside: 0, total: 0 }
    }

    fn update(&self, m: &mut HistModel, data: &Dataset, idx: &[u32]) {
        for &i in idx {
            match self.bin(data.row(i)[0]) {
                usize::MAX => m.outside += 1,
                b => m.counts[b] += 1,
            }
            m.total += 1;
        }
    }

    fn update_logged(&self, m: &mut HistModel, data: &Dataset, idx: &[u32]) -> HistUndo {
        let mut log = Vec::with_capacity(idx.len());
        for &i in idx {
            let b = self.bin(data.row(i)[0]);
            match b {
                usize::MAX => m.outside += 1,
                b => m.counts[b] += 1,
            }
            m.total += 1;
            log.push(b);
        }
        log
    }

    fn revert(&self, m: &mut HistModel, _data: &Dataset, undo: HistUndo) {
        for b in undo.into_iter().rev() {
            match b {
                usize::MAX => m.outside -= 1,
                b => m.counts[b] -= 1,
            }
            m.total -= 1;
        }
    }

    fn loss(&self, m: &HistModel, data: &Dataset, i: u32) -> f64 {
        loss::negative_log_likelihood(self.density(m, data.row(i)[0]))
    }

    fn model_bytes(&self, m: &HistModel) -> usize {
        m.counts.len() * 8 + 16
    }
}

impl MergeableLearner for HistogramDensity {
    fn merge(&self, a: &HistModel, b: &HistModel) -> HistModel {
        HistModel {
            counts: a.counts.iter().zip(&b.counts).map(|(x, y)| x + y).collect(),
            outside: a.outside + b.outside,
            total: a.total + b.total,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SyntheticMixture1d;

    fn learner() -> HistogramDensity {
        HistogramDensity::new(-8.0, 8.0, 64)
    }

    #[test]
    fn counts_conserve_total() {
        let data = SyntheticMixture1d::new(1_000, 51).generate();
        let l = learner();
        let mut m = l.init();
        l.update(&mut m, &data, &(0..1_000).collect::<Vec<_>>());
        assert_eq!(m.total, 1_000);
        assert_eq!(m.counts.iter().sum::<u64>() + m.outside, 1_000);
    }

    #[test]
    fn batch_equals_incremental_exactly() {
        let data = SyntheticMixture1d::new(500, 52).generate();
        let l = learner();
        let idx: Vec<u32> = (0..500).collect();
        let mut batch = l.init();
        l.update(&mut batch, &data, &idx);
        let mut inc = l.init();
        for c in idx.chunks(37) {
            l.update(&mut inc, &data, c);
        }
        assert_eq!(batch, inc);
    }

    #[test]
    fn order_insensitive_exactly() {
        let data = SyntheticMixture1d::new(500, 53).generate();
        let l = learner();
        let fwd: Vec<u32> = (0..500).collect();
        let rev: Vec<u32> = (0..500).rev().collect();
        let mut a = l.init();
        let mut b = l.init();
        l.update(&mut a, &data, &fwd);
        l.update(&mut b, &data, &rev);
        assert_eq!(a, b);
    }

    #[test]
    fn merge_equals_joint_training() {
        let data = SyntheticMixture1d::new(600, 54).generate();
        let l = learner();
        let mut a = l.init();
        let mut b = l.init();
        let mut joint = l.init();
        l.update(&mut a, &data, &(0..300).collect::<Vec<_>>());
        l.update(&mut b, &data, &(300..600).collect::<Vec<_>>());
        l.update(&mut joint, &data, &(0..600).collect::<Vec<_>>());
        assert_eq!(l.merge(&a, &b), joint);
    }

    #[test]
    fn revert_is_exact() {
        let data = SyntheticMixture1d::new(400, 55).generate();
        let l = learner();
        let mut m = l.init();
        l.update(&mut m, &data, &(0..100).collect::<Vec<_>>());
        let before = m.clone();
        let undo = l.update_logged(&mut m, &data, &(100..400).collect::<Vec<_>>());
        l.revert(&mut m, &data, undo);
        assert_eq!(m, before);
    }

    #[test]
    fn density_integrates_to_about_one() {
        let data = SyntheticMixture1d::new(20_000, 56).generate();
        let l = learner();
        let mut m = l.init();
        l.update(&mut m, &data, &(0..20_000).collect::<Vec<_>>());
        let w = 16.0 / 64.0;
        let mass: f64 =
            (0..64).map(|b| l.density(&m, -8.0 + (b as f32 + 0.5) * w as f32) * w).sum();
        assert!((mass - 1.0).abs() < 0.05, "mass {mass}");
    }

    #[test]
    fn nll_is_lower_for_in_distribution_points() {
        let data = SyntheticMixture1d::new(10_000, 57).generate();
        let l = learner();
        let mut m = l.init();
        l.update(&mut m, &data, &(0..10_000).collect::<Vec<_>>());
        let typical = Dataset::new(vec![-2.0], vec![0.0], 1);
        let atypical = Dataset::new(vec![7.5], vec![0.0], 1);
        assert!(l.loss(&m, &typical, 0) < l.loss(&m, &atypical, 0));
    }
}
