//! Online K-means (sequential / MacQueen K-means), the paper's Table 1
//! row 3 instantiation of the general learning setting: `Y = {NoLabel}`,
//! predictions are cluster centers, and the loss is the quantization error
//! `||x − f(x)||²`.
//!
//! The first `K` points seed the centers; after that each point moves its
//! nearest center by `(x − c)/count`. Updates touch exactly one center, so
//! the save/revert undo log is one `(center id, old center, old count)`
//! record per point — O(d) versus the O(K·d) model copy, another concrete
//! case of the paper's §4.1 trade-off.

use super::{linalg, IncrementalLearner};
use crate::data::Dataset;
use crate::loss;

/// Online K-means trainer.
#[derive(Debug, Clone)]
pub struct OnlineKMeans {
    d: usize,
    /// Number of clusters.
    pub k: usize,
}

/// K-means model: `k × d` centers (row-major) and per-center counts.
/// `seeded` counts how many centers have been initialized.
#[derive(Debug)]
pub struct KMeansModel {
    pub centers: Vec<f32>,
    pub counts: Vec<u64>,
    pub seeded: usize,
}

// Hand-written so `clone_from` reuses the target's heap storage (the
// derive's fallback reallocates; the CV engines recycle snapshot buffers).
impl Clone for KMeansModel {
    fn clone(&self) -> Self {
        Self { centers: self.centers.clone(), counts: self.counts.clone(), seeded: self.seeded }
    }

    fn clone_from(&mut self, src: &Self) {
        self.centers.clone_from(&src.centers);
        self.counts.clone_from(&src.counts);
        self.seeded = src.seeded;
    }
}

impl KMeansModel {
    /// Index of the nearest seeded center, or None if unseeded.
    ///
    /// Blocked assignment through the kernel layer: the query point stays
    /// resident while [`linalg::ASSIGN_BLOCK_CENTERS`]-sized center blocks
    /// stream through. Each distance is bitwise equal to the per-center
    /// [`linalg::dist_sq`] path, and ties keep the lowest index (strict `<`
    /// replacement) — exactly the historical `min_by(total_cmp)`
    /// first-minimum semantics.
    pub fn nearest(&self, d: usize, x: &[f32]) -> Option<usize> {
        if self.seeded == 0 {
            return None;
        }
        if d == 0 {
            return Some(0);
        }
        let mut dists = [0f64; linalg::ASSIGN_BLOCK_CENTERS];
        let (mut best_j, mut best) = (0usize, f64::INFINITY);
        let cb = self.centers[..self.seeded * d].chunks(linalg::ASSIGN_BLOCK_CENTERS * d);
        for (bi, block) in cb.enumerate() {
            let out = &mut dists[..block.len() / d];
            linalg::sq_dist_block(x, block, d, out);
            for (r, &dist) in out.iter().enumerate() {
                if dist.total_cmp(&best).is_lt() {
                    best = dist;
                    best_j = bi * linalg::ASSIGN_BLOCK_CENTERS + r;
                }
            }
        }
        Some(best_j)
    }
}

/// One undo record per training point, in application order.
#[derive(Debug)]
pub enum KMeansUndoOp {
    /// Point seeded center `j`.
    Seeded { j: usize },
    /// Point moved center `j`; stores the pre-update center row.
    Moved { j: usize, old_center: Vec<f32> },
}

impl OnlineKMeans {
    pub fn new(d: usize, k: usize) -> Self {
        assert!(k > 0);
        Self { d, k }
    }

    /// Apply one point; returns the undo record.
    fn step(&self, m: &mut KMeansModel, x: &[f32]) -> KMeansUndoOp {
        let d = self.d;
        if m.seeded < self.k {
            let j = m.seeded;
            m.centers[j * d..(j + 1) * d].copy_from_slice(x);
            m.counts[j] = 1;
            m.seeded += 1;
            return KMeansUndoOp::Seeded { j };
        }
        // invariant: `m.seeded == self.k` here (checked above), so every
        // center is initialized and `nearest` always finds one.
        let j = m.nearest(d, x).expect("seeded model");
        let c = &mut m.centers[j * d..(j + 1) * d];
        let old_center = c.to_vec();
        m.counts[j] += 1;
        let inv = 1.0 / m.counts[j] as f32;
        linalg::avg_update(inv, x, c);
        KMeansUndoOp::Moved { j, old_center }
    }
}

impl IncrementalLearner for OnlineKMeans {
    type Model = KMeansModel;
    type Undo = Vec<KMeansUndoOp>;

    fn name(&self) -> &'static str {
        "online-kmeans"
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn init(&self) -> KMeansModel {
        KMeansModel { centers: vec![0.0; self.k * self.d], counts: vec![0; self.k], seeded: 0 }
    }

    fn update(&self, m: &mut KMeansModel, data: &Dataset, idx: &[u32]) {
        for &i in idx {
            let _ = self.step(m, data.row(i));
        }
    }

    /// Contiguous fast path: identical `step` sequence over a row-major
    /// slice (labels are `NoLabel` here and ignored; bit-identical).
    fn update_rows(
        &self,
        m: &mut KMeansModel,
        x: &[f32],
        y: &[f32],
        _data: &Dataset,
        _ids: &[u32],
    ) {
        debug_assert_eq!(x.len(), y.len() * self.d);
        for row in x.chunks_exact(self.d) {
            let _ = self.step(m, row);
        }
    }

    fn update_logged(&self, m: &mut KMeansModel, data: &Dataset, idx: &[u32]) -> Self::Undo {
        idx.iter().map(|&i| self.step(m, data.row(i))).collect()
    }

    fn revert(&self, m: &mut KMeansModel, _data: &Dataset, undo: Self::Undo) {
        let d = self.d;
        for op in undo.into_iter().rev() {
            match op {
                KMeansUndoOp::Seeded { j } => {
                    m.centers[j * d..(j + 1) * d].fill(0.0);
                    m.counts[j] = 0;
                    m.seeded -= 1;
                }
                KMeansUndoOp::Moved { j, old_center } => {
                    m.centers[j * d..(j + 1) * d].copy_from_slice(&old_center);
                    m.counts[j] -= 1;
                }
            }
        }
    }

    fn loss(&self, m: &KMeansModel, data: &Dataset, i: u32) -> f64 {
        let x = data.row(i);
        match m.nearest(self.d, x) {
            Some(j) => loss::quantization_error(x, &m.centers[j * self.d..(j + 1) * self.d]),
            // Unseeded model: quantize to the origin (the zero center).
            None => linalg::norm_sq(x),
        }
    }

    fn evaluate_rows(
        &self,
        m: &KMeansModel,
        x: &[f32],
        y: &[f32],
        _data: &Dataset,
        _ids: &[u32],
    ) -> f64 {
        if y.is_empty() {
            return 0.0;
        }
        let d = self.d;
        let mut s = 0f64;
        for row in x.chunks_exact(d) {
            s += match m.nearest(d, row) {
                Some(j) => loss::quantization_error(row, &m.centers[j * d..(j + 1) * d]),
                None => linalg::norm_sq(row),
            };
        }
        s / y.len() as f64
    }

    fn model_bytes(&self, m: &KMeansModel) -> usize {
        m.centers.len() * 4 + m.counts.len() * 8 + 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SyntheticBlobs;

    #[test]
    fn seeds_then_assigns() {
        let data = SyntheticBlobs::new(500, 4, 3, 41).generate();
        let l = OnlineKMeans::new(4, 3);
        let mut m = l.init();
        l.update(&mut m, &data, &(0..500).collect::<Vec<_>>());
        assert_eq!(m.seeded, 3);
        assert_eq!(m.counts.iter().sum::<u64>(), 500);
    }

    #[test]
    fn quantization_error_beats_origin() {
        let data = SyntheticBlobs::new(3_000, 4, 3, 42).generate();
        let train: Vec<u32> = (0..2_500).collect();
        let test: Vec<u32> = (2_500..3_000).collect();
        let l = OnlineKMeans::new(4, 3);
        let mut m = l.init();
        l.update(&mut m, &data, &train);
        let q = l.evaluate(&m, &data, &test);
        let origin: f64 =
            test.iter().map(|&i| linalg::norm_sq(data.row(i))).sum::<f64>() / test.len() as f64;
        assert!(q < origin * 0.5, "quantization {q} vs origin {origin}");
    }

    #[test]
    fn center_is_running_mean_of_assigned_points() {
        // Single cluster: center must equal the exact running mean.
        let data = Dataset::new(vec![1., 3., 5., 7.], vec![0.; 4], 1);
        let l = OnlineKMeans::new(1, 1);
        let mut m = l.init();
        l.update(&mut m, &data, &[0, 1, 2, 3]);
        assert!((m.centers[0] - 4.0).abs() < 1e-6);
        assert_eq!(m.counts[0], 4);
    }

    #[test]
    fn contiguous_fast_path_is_bit_identical() {
        let data = SyntheticBlobs::new(300, 4, 3, 45).generate();
        let idx: Vec<u32> = (0..250).collect();
        let block = data.subset(&idx);
        let l = OnlineKMeans::new(4, 3);
        let mut a = l.init();
        l.update(&mut a, &data, &idx);
        let mut b = l.init();
        l.update_rows(&mut b, &block.x, &block.y, &data, &idx);
        assert_eq!(a.centers, b.centers);
        assert_eq!(a.counts, b.counts);
        assert_eq!(a.seeded, b.seeded);
        let held: Vec<u32> = (250..300).collect();
        let hb = data.subset(&held);
        let fast = l.evaluate_rows(&a, &hb.x, &hb.y, &data, &held);
        assert_eq!(l.evaluate(&a, &data, &held).to_bits(), fast.to_bits());
    }

    #[test]
    fn revert_is_exact() {
        // copy_from_slice-based undo restores the model bit-for-bit.
        let data = SyntheticBlobs::new(400, 4, 3, 43).generate();
        let l = OnlineKMeans::new(4, 3);
        let mut m = l.init();
        l.update(&mut m, &data, &(0..100).collect::<Vec<_>>());
        let before = m.clone();
        let undo = l.update_logged(&mut m, &data, &(100..400).collect::<Vec<_>>());
        l.revert(&mut m, &data, undo);
        assert_eq!(m.centers, before.centers);
        assert_eq!(m.counts, before.counts);
        assert_eq!(m.seeded, before.seeded);
    }

    #[test]
    fn revert_across_seeding_boundary() {
        let data = SyntheticBlobs::new(10, 4, 5, 44).generate();
        let l = OnlineKMeans::new(4, 5);
        let mut m = l.init();
        l.update(&mut m, &data, &[0, 1]); // partially seeded
        let before = m.clone();
        let undo = l.update_logged(&mut m, &data, &[2, 3, 4, 5, 6, 7]);
        assert_eq!(m.seeded, 5);
        l.revert(&mut m, &data, undo);
        assert_eq!(m.seeded, 2);
        assert_eq!(m.centers, before.centers);
        assert_eq!(m.counts, before.counts);
    }
}
