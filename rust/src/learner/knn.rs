//! k-nearest-neighbour classification as an incremental learner.
//!
//! Related-work tie-in: Mullin & Sukthankar [2000] (paper §1.1) study fast
//! *complete* CV for nearest-neighbour methods precisely because the k-NN
//! "model" is just the training set — updates are appends, which makes it
//! the ideal real-prediction exactness oracle for TreeCV: the model is
//! exactly order- and batching-insensitive (predictions depend only on the
//! training *set*), so by Theorem 1 (g ≡ 0) TreeCV must reproduce standard
//! k-CV *bit-for-bit* — with a learner that actually classifies, unlike
//! the synthetic multiset oracle.
//!
//! Brute-force neighbour search (O(|train|·d) per query) — fine at the
//! test scales; this learner exists for validation, not throughput.

use super::{linalg, IncrementalLearner, MergeableLearner};
use crate::data::Dataset;
use crate::loss;

/// k-NN trainer for ±1 labels.
#[derive(Debug, Clone)]
pub struct KnnClassifier {
    d: usize,
    /// Number of neighbours (odd avoids vote ties).
    pub k: usize,
}

/// The model is the multiset of training indices (the data itself stays in
/// the shared [`Dataset`]).
#[derive(Debug, Default, PartialEq, Eq)]
pub struct KnnModel {
    pub train: Vec<u32>,
}

// Hand-written so `clone_from` reuses the target's heap storage (the
// derive's fallback reallocates; the model IS the training set, the
// worst case for per-node snapshots).
impl Clone for KnnModel {
    fn clone(&self) -> Self {
        Self { train: self.train.clone() }
    }

    fn clone_from(&mut self, src: &Self) {
        self.train.clone_from(&src.train);
    }
}

impl KnnClassifier {
    pub fn new(d: usize, k: usize) -> Self {
        assert!(k >= 1);
        Self { d, k }
    }

    /// Majority vote over the k nearest training points (ties in distance
    /// broken by the smaller index for determinism; vote ties → +1).
    pub fn predict(&self, m: &KnnModel, data: &Dataset, x: &[f32]) -> f32 {
        // Partial selection of the k smallest distances.
        let mut best: Vec<(f64, u32)> = Vec::with_capacity(self.k + 1);
        for &j in &m.train {
            let dist = linalg::dist_sq(x, data.row(j));
            let pos = best.partition_point(|&(d0, i0)| (d0, i0) < (dist, j));
            if pos < self.k {
                best.insert(pos, (dist, j));
                best.truncate(self.k);
            }
        }
        let vote: f32 = best.iter().map(|&(_, j)| data.label(j)).sum();
        if vote >= 0.0 {
            1.0
        } else {
            -1.0
        }
    }
}

impl IncrementalLearner for KnnClassifier {
    type Model = KnnModel;
    type Undo = usize; // appended count

    fn name(&self) -> &'static str {
        "knn"
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn init(&self) -> KnnModel {
        KnnModel::default()
    }

    fn update(&self, m: &mut KnnModel, _data: &Dataset, idx: &[u32]) {
        m.train.extend_from_slice(idx);
    }

    fn update_logged(&self, m: &mut KnnModel, _data: &Dataset, idx: &[u32]) -> usize {
        m.train.extend_from_slice(idx);
        idx.len()
    }

    fn revert(&self, m: &mut KnnModel, _data: &Dataset, undo: usize) {
        m.train.truncate(m.train.len() - undo);
    }

    fn loss(&self, m: &KnnModel, data: &Dataset, i: u32) -> f64 {
        if m.train.is_empty() {
            return 1.0; // no information: always counted wrong
        }
        let pred = self.predict(m, data, data.row(i));
        loss::misclassification(pred, data.label(i))
    }

    fn model_bytes(&self, m: &KnnModel) -> usize {
        m.train.len() * 4
    }
}

impl MergeableLearner for KnnClassifier {
    /// Appending index sets is an exact merge — k-NN satisfies Izbicki's
    /// assumption *if* model size is ignored (his O(n + k) claim assumes
    /// O(1)-size models; here the merge itself is O(|model|), which is why
    /// the paper calls the assumption restrictive).
    fn merge(&self, a: &KnnModel, b: &KnnModel) -> KnnModel {
        let mut out = a.clone();
        out.train.extend_from_slice(&b.train);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cv::folds::Folds;
    use crate::cv::standard::StandardCv;
    use crate::cv::treecv::TreeCv;
    use crate::cv::CvEngine;
    use crate::data::synth::SyntheticCovertype;

    fn two_blob_data(n: usize) -> Dataset {
        let mut rng = crate::rng::Rng::new(171);
        let mut x = Vec::new();
        let mut y = Vec::new();
        for i in 0..n {
            let s = if i % 2 == 0 { 1.0f32 } else { -1.0 };
            x.push(2.0 * s + 0.5 * rng.next_gaussian());
            x.push(-1.5 * s + 0.5 * rng.next_gaussian());
            y.push(s);
        }
        Dataset::new(x, y, 2)
    }

    #[test]
    fn classifies_separated_blobs() {
        let data = two_blob_data(400);
        let l = KnnClassifier::new(2, 3);
        let mut m = l.init();
        l.update(&mut m, &data, &(0..300).collect::<Vec<_>>());
        let err = l.evaluate(&m, &data, &(300..400).collect::<Vec<_>>());
        assert!(err < 0.05, "error {err}");
    }

    #[test]
    fn prediction_is_order_insensitive() {
        let data = two_blob_data(100);
        let l = KnnClassifier::new(2, 3);
        let fwd: Vec<u32> = (0..80).collect();
        let mut rev = fwd.clone();
        rev.reverse();
        let mut a = l.init();
        let mut b = l.init();
        l.update(&mut a, &data, &fwd);
        l.update(&mut b, &data, &rev);
        for i in 80..100u32 {
            assert_eq!(
                l.predict(&a, &data, data.row(i)),
                l.predict(&b, &data, data.row(i)),
                "i={i}"
            );
        }
    }

    /// The key property: TreeCV == standard CV bit-for-bit with a learner
    /// that makes real predictions (Theorem 1 with g ≡ 0).
    #[test]
    fn treecv_equals_standard_exactly() {
        let data = SyntheticCovertype::new(240, 172).generate();
        let l = KnnClassifier::new(54, 5);
        for k in [2usize, 6, 12, 60] {
            let folds = Folds::new(240, k, 173);
            let tree = TreeCv::default().run(&l, &data, &folds);
            let std_res = StandardCv::default().run(&l, &data, &folds);
            assert_eq!(tree.per_fold, std_res.per_fold, "k={k}");
        }
    }

    #[test]
    fn revert_is_exact() {
        let data = two_blob_data(60);
        let l = KnnClassifier::new(2, 1);
        let mut m = l.init();
        l.update(&mut m, &data, &[0, 1, 2]);
        let before = m.clone();
        let undo = l.update_logged(&mut m, &data, &[3, 4]);
        l.revert(&mut m, &data, undo);
        assert_eq!(m, before);
    }

    #[test]
    fn merge_is_append() {
        let l = KnnClassifier::new(2, 1);
        let a = KnnModel { train: vec![1, 2] };
        let b = KnnModel { train: vec![3] };
        assert_eq!(l.merge(&a, &b).train, vec![1, 2, 3]);
    }

    #[test]
    fn empty_model_counts_as_wrong() {
        let data = two_blob_data(4);
        let l = KnnClassifier::new(2, 3);
        assert_eq!(l.loss(&l.init(), &data, 0), 1.0);
    }

    #[test]
    fn tie_distance_broken_by_index() {
        // Two equidistant points with different labels; k=1 must pick the
        // smaller index deterministically.
        let data = Dataset::new(vec![1.0, 0.0, -1.0, 0.0, 0.0, 0.0], vec![1.0, -1.0, 0.0], 2);
        let l = KnnClassifier::new(2, 1);
        let mut m = l.init();
        l.update(&mut m, &data, &[0, 1]);
        assert_eq!(l.predict(&m, &data, data.row(2)), 1.0);
    }
}
