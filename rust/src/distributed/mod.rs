//! Simulated distributed TreeCV (paper §4.1, last paragraph):
//!
//! > "TREECV is potentially useful in a distributed environment, where each
//! > chunk of the data is stored on a different node in the network.
//! > Updating the model on a given chunk can then be relegated to that
//! > node ... it is only the model (or the updates made to the model), not
//! > the data, that needs to be communicated. Since at every level of the
//! > tree, each chunk is added to exactly one model, the total
//! > communication cost of doing this is O(k log k)."
//!
//! We do not have a cluster, so per the substitution policy we build a
//! discrete simulation: `k` storage nodes each own one chunk; a driver
//! walks the TreeCV recursion, and every time a chunk must be added to a
//! model it *sends the model* to the owning node and receives it back,
//! charging latency + size/bandwidth on a simple network cost model. The
//! naive alternative (shipping data to a compute node) is also modeled, so
//! the `repro dist` experiment can exhibit the paper's claimed asymmetry:
//! model transfers scale O(k log k), data transfers O(n k) for standard CV.

use crate::cv::folds::Folds;
use crate::data::Dataset;
use crate::learner::IncrementalLearner;

/// Simple network cost model.
#[derive(Debug, Clone)]
pub struct NetworkModel {
    /// Per-message latency (seconds).
    pub latency_s: f64,
    /// Bandwidth (bytes / second).
    pub bandwidth_bps: f64,
}

impl Default for NetworkModel {
    fn default() -> Self {
        // 100 µs latency, 10 Gbit/s — a modest datacenter network.
        Self { latency_s: 100e-6, bandwidth_bps: 10e9 / 8.0 }
    }
}

impl NetworkModel {
    /// Simulated time to move `bytes` in one message.
    pub fn transfer_time(&self, bytes: u64) -> f64 {
        self.latency_s + bytes as f64 / self.bandwidth_bps
    }
}

/// Accumulated communication statistics of a simulated run.
#[derive(Debug, Clone, Default)]
pub struct CommStats {
    /// Number of model transfers (send + receive counted as 2 messages).
    pub model_messages: u64,
    /// Total model bytes moved.
    pub model_bytes: u64,
    /// Number of raw-data transfers (naive strategy only).
    pub data_messages: u64,
    /// Total data bytes moved.
    pub data_bytes: u64,
    /// Simulated network time (seconds).
    pub sim_network_time_s: f64,
}

/// Result of a simulated distributed CV run.
#[derive(Debug, Clone)]
pub struct DistributedRunReport {
    pub k: usize,
    pub n: usize,
    pub estimate: f64,
    pub comm: CommStats,
}

/// Simulated cluster: node `i` owns chunk `Z_i`. The model bounces between
/// nodes; the raw chunks never move (TreeCV strategy).
pub struct Cluster<'a> {
    pub data: &'a Dataset,
    pub folds: &'a Folds,
    pub net: NetworkModel,
}

impl<'a> Cluster<'a> {
    pub fn new(data: &'a Dataset, folds: &'a Folds, net: NetworkModel) -> Self {
        Self { data, folds, net }
    }

    /// Distributed TreeCV: walk Algorithm 1; every chunk-update ships the
    /// model to the chunk's node and back.
    pub fn treecv<L: IncrementalLearner>(&self, learner: &L) -> DistributedRunReport {
        let k = self.folds.k();
        let mut comm = CommStats::default();
        let mut per_fold = vec![0.0; k];
        let mut model = learner.init();
        self.recurse(learner, &mut model, 0, k - 1, &mut per_fold, &mut comm);
        let estimate = per_fold.iter().sum::<f64>() / k as f64;
        DistributedRunReport { k, n: self.data.n, estimate, comm }
    }

    fn ship_model<L: IncrementalLearner>(
        &self,
        learner: &L,
        model: &L::Model,
        comm: &mut CommStats,
    ) {
        let bytes = learner.model_bytes(model) as u64;
        comm.model_messages += 2; // to the node and back
        comm.model_bytes += 2 * bytes;
        comm.sim_network_time_s += 2.0 * self.net.transfer_time(bytes);
    }

    /// Update `model` with chunks `lo..=hi`, one node at a time (the paper:
    /// "the model is sent to the processing node, trained and sent back,
    /// i.e., this is not using all the nodes at once").
    fn update_range<L: IncrementalLearner>(
        &self,
        learner: &L,
        model: &mut L::Model,
        lo: usize,
        hi: usize,
        comm: &mut CommStats,
    ) {
        for c in lo..=hi {
            self.ship_model(learner, model, comm);
            learner.update(model, self.data, self.folds.chunk(c));
        }
    }

    fn recurse<L: IncrementalLearner>(
        &self,
        learner: &L,
        model: &mut L::Model,
        s: usize,
        e: usize,
        per_fold: &mut [f64],
        comm: &mut CommStats,
    ) {
        if s == e {
            // Evaluation happens on the node owning the held-out chunk.
            self.ship_model(learner, model, comm);
            per_fold[s] = learner.evaluate(model, self.data, self.folds.chunk(s));
            return;
        }
        let m = (s + e) / 2;
        let saved = model.clone();
        self.update_range(learner, model, m + 1, e, comm);
        self.recurse(learner, model, s, m, per_fold, comm);
        *model = saved;
        self.update_range(learner, model, s, m, comm);
        self.recurse(learner, model, m + 1, e, per_fold, comm);
    }

    /// Naive distributed standard CV: a central compute node pulls every
    /// training chunk over the network for each fold (data moves, models
    /// don't). Communication is Θ(n·k) bytes.
    pub fn standard_naive<L: IncrementalLearner>(&self, learner: &L) -> DistributedRunReport {
        let k = self.folds.k();
        let mut comm = CommStats::default();
        let mut per_fold = vec![0.0; k];
        let row_bytes = (self.data.d * 4 + 4) as u64;
        for i in 0..k {
            let mut model = learner.init();
            for c in 0..k {
                if c == i {
                    continue;
                }
                let chunk = self.folds.chunk(c);
                let bytes = chunk.len() as u64 * row_bytes;
                comm.data_messages += 1;
                comm.data_bytes += bytes;
                comm.sim_network_time_s += self.net.transfer_time(bytes);
                learner.update(&mut model, self.data, chunk);
            }
            per_fold[i] = learner.evaluate(&model, self.data, self.folds.chunk(i));
        }
        let estimate = per_fold.iter().sum::<f64>() / k as f64;
        DistributedRunReport { k, n: self.data.n, estimate, comm }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cv::treecv::TreeCv;
    use crate::cv::CvEngine;
    use crate::data::synth::SyntheticCovertype;
    use crate::learner::pegasos::Pegasos;

    fn setup(n: usize, k: usize) -> (Dataset, Folds) {
        (SyntheticCovertype::new(n, 131).generate(), Folds::new(n, k, 132))
    }

    #[test]
    fn distributed_treecv_matches_local_estimate() {
        let (data, folds) = setup(600, 8);
        let l = Pegasos::new(54, 1e-4);
        let cluster = Cluster::new(&data, &folds, NetworkModel::default());
        let dist = cluster.treecv(&l);
        let local = TreeCv::default().run(&l, &data, &folds);
        assert!((dist.estimate - local.estimate).abs() < 1e-12);
    }

    #[test]
    fn model_messages_scale_k_log_k() {
        let l = Pegasos::new(54, 1e-4);
        for k in [4usize, 16, 64] {
            let (data, folds) = setup(k * 8, k);
            let cluster = Cluster::new(&data, &folds, NetworkModel::default());
            let rep = cluster.treecv(&l);
            // Each level ships each chunk's node one model (2 messages);
            // plus k evaluation round-trips. Bound: 2·k·(log2(2k)+1).
            let bound = 2.0 * (k as f64) * (((2 * k) as f64).log2() + 1.0) + 2.0 * k as f64;
            assert!(
                (rep.comm.model_messages as f64) <= bound,
                "k={k}: {} > {bound}",
                rep.comm.model_messages
            );
            assert_eq!(rep.comm.data_messages, 0);
        }
    }

    /// §4.1's communication asymmetry, pinned for k ∈ {4, 16, 64} at fixed
    /// n: TreeCV's model traffic is Θ(k log k) — messages in
    /// [2k⌊log₂k⌋, 2k(log₂(2k)+1) + 2k], independent of n — while the
    /// naive data-shipping strategy moves exactly (k−1)·n rows, i.e.
    /// Θ(n·k) bytes, growing linearly in k AND in n.
    #[test]
    fn comm_asymmetry_model_klogk_vs_data_nk() {
        let l = Pegasos::new(54, 1e-4);
        let n = 640;
        let row_bytes = (54 * 4 + 4) as u64;
        let mut prev_model_msgs = 0u64;
        let mut prev_data_bytes = 0u64;
        for k in [4usize, 16, 64] {
            let (data, folds) = setup(n, k);
            let cluster = Cluster::new(&data, &folds, NetworkModel::default());
            let tree = cluster.treecv(&l);
            let naive = cluster.standard_naive(&l);

            // Naive data traffic: exactly (k−1)·n rows in k·(k−1) messages.
            assert_eq!(naive.comm.data_bytes, (k as u64 - 1) * n as u64 * row_bytes, "k={k}");
            assert_eq!(naive.comm.data_messages, (k * (k - 1)) as u64, "k={k}");
            assert_eq!(naive.comm.model_messages, 0, "k={k}");

            // TreeCV model traffic: Θ(k log k) messages, no data moved.
            let lo = 2 * (k as u64) * (k as f64).log2().floor() as u64;
            let hi = (2.0 * k as f64 * (((2 * k) as f64).log2() + 1.0) + 2.0 * k as f64) as u64;
            assert!(
                (lo..=hi).contains(&tree.comm.model_messages),
                "k={k}: {} model messages outside [{lo}, {hi}]",
                tree.comm.model_messages
            );
            assert_eq!(tree.comm.data_messages, 0, "k={k}");

            // Both grow with k; the asymmetry in absolute volume holds at
            // every k (models are 4·54+ bytes, chunks are n/k rows).
            assert!(tree.comm.model_messages > prev_model_msgs, "k={k}");
            assert!(naive.comm.data_bytes > prev_data_bytes, "k={k}");
            prev_model_msgs = tree.comm.model_messages;
            prev_data_bytes = naive.comm.data_bytes;
            assert!(tree.comm.model_bytes < naive.comm.data_bytes, "k={k}");
        }

        // Model traffic is independent of n (the whole point of shipping
        // models): doubling n keeps message counts fixed while the naive
        // strategy's bytes double.
        let k = 16;
        let (d1, f1) = setup(n, k);
        let (d2, f2) = setup(2 * n, k);
        let c1 = Cluster::new(&d1, &f1, NetworkModel::default());
        let c2 = Cluster::new(&d2, &f2, NetworkModel::default());
        assert_eq!(c1.treecv(&l).comm.model_messages, c2.treecv(&l).comm.model_messages);
        assert_eq!(
            2 * c1.standard_naive(&l).comm.data_bytes,
            c2.standard_naive(&l).comm.data_bytes
        );
    }

    #[test]
    fn naive_moves_data_quadratically() {
        let l = Pegasos::new(54, 1e-4);
        let (data, folds) = setup(640, 8);
        let cluster = Cluster::new(&data, &folds, NetworkModel::default());
        let naive = cluster.standard_naive(&l);
        let tree = cluster.treecv(&l);
        assert_eq!(naive.comm.model_messages, 0);
        // Standard ships ~ (k-1)·n rows; TreeCV ships models only.
        let row_bytes = (54 * 4 + 4) as u64;
        assert_eq!(naive.comm.data_bytes, 7 * 640 * row_bytes);
        assert!(tree.comm.model_bytes < naive.comm.data_bytes);
    }

    #[test]
    fn network_model_costs() {
        let net = NetworkModel { latency_s: 1e-3, bandwidth_bps: 1e6 };
        let t = net.transfer_time(500_000);
        assert!((t - 0.501).abs() < 1e-9);
    }
}
