//! XLA-backed incremental learners: the same [`IncrementalLearner`]
//! interface as the pure-Rust learners, but the chunk-update and
//! chunk-evaluate steps execute the AOT-compiled JAX/Pallas artifacts
//! (Layer 1/2) through PJRT. This is the three-layer composition: the L3
//! TreeCV engines drive these learners without knowing XLA is underneath.
//!
//! Chunks are processed in fixed-capacity blocks (the artifact's lowered
//! shape `B × d`), padded with zero rows and a 0/1 validity mask so
//! variable-size chunks run on a single compiled executable. Padded rows
//! are masked out of both the SGD step (they do not advance the step
//! counter `t`) and the evaluation sum.
//!
//! Numerics note: the artifacts carry the step counter as an f32 scalar, so
//! the XLA learners are validated for `n < 2²⁴`; the pure-Rust learners are
//! the path used for the huge-`n` Figure-2 sweeps.

use super::{literal_f32, scalar_f32, Executable, Manifest, PjrtRuntime};
use crate::data::Dataset;
use crate::learner::IncrementalLearner;
use crate::loss;
use crate::Result;
use crate::sync::Arc;
use anyhow::anyhow;

/// Gather rows `idx[lo..hi]` into a zero-padded `(block × d)` buffer plus
/// labels and mask.
fn gather_block(
    data: &Dataset,
    idx: &[u32],
    block: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let d = data.d;
    let mut x = vec![0f32; block * d];
    let mut y = vec![0f32; block];
    let mut mask = vec![0f32; block];
    for (r, &i) in idx.iter().enumerate() {
        x[r * d..(r + 1) * d].copy_from_slice(data.row(i));
        y[r] = data.label(i);
        mask[r] = 1.0;
    }
    (x, y, mask)
}

/// PEGASOS whose chunk update/eval run the `pegasos_update` /
/// `pegasos_eval` artifacts.
pub struct XlaPegasos {
    d: usize,
    block: usize,
    pub lambda: f64,
    update_exe: Arc<Executable>,
    eval_exe: Arc<Executable>,
}

/// Host-resident model state (weights round-trip through PJRT per block).
#[derive(Debug)]
pub struct XlaPegasosModel {
    pub w: Vec<f32>,
    pub t: f32,
}

// Hand-written so `clone_from` reuses the target's heap storage (the
// derive's fallback reallocates; the CV engines recycle snapshot buffers).
impl Clone for XlaPegasosModel {
    fn clone(&self) -> Self {
        Self { w: self.w.clone(), t: self.t }
    }

    fn clone_from(&mut self, src: &Self) {
        self.w.clone_from(&src.w);
        self.t = src.t;
    }
}

impl XlaPegasos {
    /// Look up the (block, dim)-matched artifacts in the manifest and
    /// compile them.
    pub fn from_manifest(
        rt: &PjrtRuntime,
        manifest: &Manifest,
        d: usize,
        lambda: f64,
    ) -> Result<Self> {
        let upd = manifest
            .find("pegasos_update", d)
            .ok_or_else(|| anyhow!("no pegasos_update artifact for d={d}"))?;
        let evl = manifest
            .find("pegasos_eval", d)
            .ok_or_else(|| anyhow!("no pegasos_eval artifact for d={d}"))?;
        anyhow::ensure!(upd.block == evl.block, "update/eval artifact block mismatch");
        Ok(Self {
            d,
            block: upd.block,
            lambda,
            update_exe: rt.load(&upd.name)?,
            eval_exe: rt.load(&evl.name)?,
        })
    }

    pub fn block(&self) -> usize {
        self.block
    }

    fn run_update(&self, m: &mut XlaPegasosModel, data: &Dataset, idx: &[u32]) -> Result<()> {
        for blk in idx.chunks(self.block) {
            let (x, y, mask) = gather_block(data, blk, self.block);
            let inputs = [
                literal_f32(&m.w, &[self.d as i64])?,
                scalar_f32(m.t),
                scalar_f32(self.lambda as f32),
                literal_f32(&x, &[self.block as i64, self.d as i64])?,
                literal_f32(&y, &[self.block as i64])?,
                literal_f32(&mask, &[self.block as i64])?,
            ];
            let out = self.update_exe.run(&inputs)?;
            anyhow::ensure!(out.len() == 2, "pegasos_update returned {} outputs", out.len());
            m.w = out[0].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
            m.t = out[1].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?[0];
        }
        Ok(())
    }

    fn run_eval(&self, m: &XlaPegasosModel, data: &Dataset, idx: &[u32]) -> Result<f64> {
        let mut err_sum = 0f64;
        for blk in idx.chunks(self.block) {
            let (x, y, mask) = gather_block(data, blk, self.block);
            let inputs = [
                literal_f32(&m.w, &[self.d as i64])?,
                literal_f32(&x, &[self.block as i64, self.d as i64])?,
                literal_f32(&y, &[self.block as i64])?,
                literal_f32(&mask, &[self.block as i64])?,
            ];
            let out = self.eval_exe.run(&inputs)?;
            err_sum += out[0].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?[0] as f64;
        }
        Ok(err_sum / idx.len().max(1) as f64)
    }
}

impl IncrementalLearner for XlaPegasos {
    type Model = XlaPegasosModel;
    type Undo = XlaPegasosModel;

    fn name(&self) -> &'static str {
        "xla-pegasos"
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn init(&self) -> XlaPegasosModel {
        XlaPegasosModel { w: vec![0.0; self.d], t: 0.0 }
    }

    fn update(&self, m: &mut XlaPegasosModel, data: &Dataset, idx: &[u32]) {
        // invariant: the artifact was validated at construction
        // (`from_manifest` checked shapes and compiled it); a mid-run PJRT
        // failure is unrecoverable and the trait's `update` is infallible.
        self.run_update(m, data, idx).expect("pegasos_update artifact execution failed");
    }

    fn update_logged(&self, m: &mut XlaPegasosModel, data: &Dataset, idx: &[u32]) -> Self::Undo {
        let snap = m.clone();
        self.update(m, data, idx);
        snap
    }

    fn revert(&self, m: &mut XlaPegasosModel, _data: &Dataset, undo: Self::Undo) {
        *m = undo;
    }

    fn loss(&self, m: &XlaPegasosModel, data: &Dataset, i: u32) -> f64 {
        // Host-side single-point path; `evaluate` uses the XLA kernel.
        let x = data.row(i);
        let score: f32 = m.w.iter().zip(x).map(|(a, b)| a * b).sum();
        loss::misclassification(score, data.label(i))
    }

    fn evaluate(&self, m: &XlaPegasosModel, data: &Dataset, idx: &[u32]) -> f64 {
        if idx.is_empty() {
            return 0.0;
        }
        // invariant: same contract as `update` — the artifact compiled at
        // construction; mid-run PJRT failure is unrecoverable.
        self.run_eval(m, data, idx).expect("pegasos_eval artifact execution failed")
    }

    fn model_bytes(&self, m: &XlaPegasosModel) -> usize {
        m.w.len() * 4 + 4
    }
}

/// LSQSGD whose chunk update/eval run the `lsqsgd_update` / `lsqsgd_eval`
/// artifacts.
pub struct XlaLsqSgd {
    d: usize,
    block: usize,
    pub alpha: f64,
    update_exe: Arc<Executable>,
    eval_exe: Arc<Executable>,
}

#[derive(Debug)]
pub struct XlaLsqSgdModel {
    pub w: Vec<f32>,
    pub wavg: Vec<f32>,
    pub t: f32,
}

// Hand-written so `clone_from` reuses the target's heap storage (the
// derive's fallback reallocates; the CV engines recycle snapshot buffers).
impl Clone for XlaLsqSgdModel {
    fn clone(&self) -> Self {
        Self { w: self.w.clone(), wavg: self.wavg.clone(), t: self.t }
    }

    fn clone_from(&mut self, src: &Self) {
        self.w.clone_from(&src.w);
        self.wavg.clone_from(&src.wavg);
        self.t = src.t;
    }
}

impl XlaLsqSgd {
    pub fn from_manifest(
        rt: &PjrtRuntime,
        manifest: &Manifest,
        d: usize,
        alpha: f64,
    ) -> Result<Self> {
        let upd = manifest
            .find("lsqsgd_update", d)
            .ok_or_else(|| anyhow!("no lsqsgd_update artifact for d={d}"))?;
        let evl = manifest
            .find("lsqsgd_eval", d)
            .ok_or_else(|| anyhow!("no lsqsgd_eval artifact for d={d}"))?;
        anyhow::ensure!(upd.block == evl.block, "update/eval artifact block mismatch");
        Ok(Self {
            d,
            block: upd.block,
            alpha,
            update_exe: rt.load(&upd.name)?,
            eval_exe: rt.load(&evl.name)?,
        })
    }

    pub fn block(&self) -> usize {
        self.block
    }

    fn run_update(&self, m: &mut XlaLsqSgdModel, data: &Dataset, idx: &[u32]) -> Result<()> {
        for blk in idx.chunks(self.block) {
            let (x, y, mask) = gather_block(data, blk, self.block);
            let inputs = [
                literal_f32(&m.w, &[self.d as i64])?,
                literal_f32(&m.wavg, &[self.d as i64])?,
                scalar_f32(m.t),
                scalar_f32(self.alpha as f32),
                literal_f32(&x, &[self.block as i64, self.d as i64])?,
                literal_f32(&y, &[self.block as i64])?,
                literal_f32(&mask, &[self.block as i64])?,
            ];
            let out = self.update_exe.run(&inputs)?;
            anyhow::ensure!(out.len() == 3, "lsqsgd_update returned {} outputs", out.len());
            m.w = out[0].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
            m.wavg = out[1].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
            m.t = out[2].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?[0];
        }
        Ok(())
    }
}

impl IncrementalLearner for XlaLsqSgd {
    type Model = XlaLsqSgdModel;
    type Undo = XlaLsqSgdModel;

    fn name(&self) -> &'static str {
        "xla-lsqsgd"
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn init(&self) -> XlaLsqSgdModel {
        XlaLsqSgdModel { w: vec![0.0; self.d], wavg: vec![0.0; self.d], t: 0.0 }
    }

    fn update(&self, m: &mut XlaLsqSgdModel, data: &Dataset, idx: &[u32]) {
        // invariant: the artifact was validated at construction
        // (`from_manifest` checked shapes and compiled it); a mid-run PJRT
        // failure is unrecoverable and the trait's `update` is infallible.
        self.run_update(m, data, idx).expect("lsqsgd_update artifact execution failed");
    }

    fn update_logged(&self, m: &mut XlaLsqSgdModel, data: &Dataset, idx: &[u32]) -> Self::Undo {
        let snap = m.clone();
        self.update(m, data, idx);
        snap
    }

    fn revert(&self, m: &mut XlaLsqSgdModel, _data: &Dataset, undo: Self::Undo) {
        *m = undo;
    }

    fn loss(&self, m: &XlaLsqSgdModel, data: &Dataset, i: u32) -> f64 {
        let x = data.row(i);
        let pred: f32 = m.wavg.iter().zip(x).map(|(a, b)| a * b).sum();
        loss::squared_error(pred, data.label(i))
    }

    fn evaluate(&self, m: &XlaLsqSgdModel, data: &Dataset, idx: &[u32]) -> f64 {
        if idx.is_empty() {
            return 0.0;
        }
        let mut sse = 0f64;
        for blk in idx.chunks(self.block) {
            let (x, y, mask) = gather_block(data, blk, self.block);
            // invariant: buffer sizes match the lowered artifact shape by
            // construction (gather_block pads to `self.block × d`), and a
            // mid-run PJRT failure is unrecoverable — same contract as
            // `update` above.
            let inputs = [
                literal_f32(&m.wavg, &[self.d as i64]).expect("literal"),
                literal_f32(&x, &[self.block as i64, self.d as i64]).expect("literal"),
                literal_f32(&y, &[self.block as i64]).expect("literal"),
                literal_f32(&mask, &[self.block as i64]).expect("literal"),
            ];
            let out = self.eval_exe.run(&inputs).expect("lsqsgd_eval artifact execution failed");
            sse += out[0].to_vec::<f32>().expect("f32 output")[0] as f64;
        }
        sse / idx.len() as f64
    }

    fn model_bytes(&self, m: &XlaLsqSgdModel) -> usize {
        (m.w.len() + m.wavg.len()) * 4 + 4
    }
}
