//! PJRT runtime: loads the AOT-compiled JAX/Pallas artifacts (HLO *text*,
//! see `python/compile/aot.py`) and executes them from the Rust hot path.
//! Python is never invoked at runtime — `make artifacts` runs once at
//! build time.
//!
//! Interchange format is HLO text, not serialized `HloModuleProto`:
//! jax ≥ 0.5 emits protos with 64-bit instruction ids which the pinned
//! xla_extension 0.5.1 rejects; the text parser reassigns ids and
//! round-trips cleanly (see /opt/xla-example/README.md).
//!
//! ## Feature gating
//!
//! The PJRT client needs the external `xla` crate, which is not available
//! in the offline build image — so the real implementation sits behind
//! `cfg(treecv_pjrt)`, which `build.rs` emits only when BOTH the `xla`
//! cargo feature is enabled AND `TREECV_XLA_RUNTIME=1` is set (the
//! environment that adds the `xla` dependency to Cargo.toml sets it).
//! Everywhere else — including a plain `--features xla` build, which CI's
//! feature-matrix job exercises — this module compiles an API-compatible
//! stub: [`PjrtRuntime`] constructors return a clean error, so the CLI
//! `selfcheck`, the `runtime_xla` bench, the `xla_pipeline` example and
//! the runtime integration tests all build, run, and skip/fail gracefully
//! instead of breaking the build. [`Manifest`] parsing and artifact
//! discovery are pure Rust and always available.

pub mod xla_learner;

use crate::sync::Arc;
use crate::Result;
#[cfg(treecv_pjrt)]
use anyhow::anyhow;
use anyhow::Context as _;
#[cfg(treecv_pjrt)]
use crate::sync::Mutex;
#[cfg(treecv_pjrt)]
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Default artifact directory, overridable via `TREECV_ARTIFACTS`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var_os("TREECV_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

/// True if the artifact directory holds the expected compiled programs.
pub fn artifacts_available() -> bool {
    artifacts_dir().join("manifest.txt").exists()
}

// ---------------------------------------------------------------------------
// Real PJRT-backed implementation (requires the `xla` crate).
// ---------------------------------------------------------------------------

/// A compiled, loaded XLA executable plus its artifact identity.
#[cfg(treecv_pjrt)]
pub struct Executable {
    pub name: String,
    exe: xla::PjRtLoadedExecutable,
}

#[cfg(treecv_pjrt)]
impl Executable {
    /// Execute with literal inputs; returns the flattened tuple outputs.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let out = self
            .exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("executing {}: {e:?}", self.name))?;
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result of {}: {e:?}", self.name))?;
        // aot.py lowers with return_tuple=True, so outputs are one tuple.
        lit.to_tuple().map_err(|e| anyhow!("untupling result of {}: {e:?}", self.name))
    }
}

/// PJRT CPU client + compile cache keyed by artifact name.
///
/// Compilation is the expensive step (tens of ms); every CV run reuses the
/// cached executables, so the per-chunk cost is literal marshaling +
/// execution only.
#[cfg(treecv_pjrt)]
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    cache: Mutex<HashMap<String, Arc<Executable>>>,
    dir: PathBuf,
}

#[cfg(treecv_pjrt)]
impl PjrtRuntime {
    /// Create a CPU-backed runtime reading from [`artifacts_dir`].
    pub fn cpu() -> Result<Self> {
        Self::with_dir(artifacts_dir())
    }

    /// Create a runtime reading artifacts from `dir`.
    pub fn with_dir(dir: PathBuf) -> Result<Self> {
        let client =
            xla::PjRtClient::cpu().map_err(|e| anyhow!("creating PJRT CPU client: {e:?}"))?;
        Ok(Self { client, cache: Mutex::new(HashMap::new()), dir })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load (or fetch from cache) the artifact `<name>.hlo.txt`.
    pub fn load(&self, name: &str) -> Result<Arc<Executable>> {
        if let Some(exe) = self.cache.lock().get(name) {
            return Ok(exe.clone());
        }
        let path = self.dir.join(format!("{name}.hlo.txt"));
        let exe = self.compile_file(name, &path)?;
        let exe = Arc::new(exe);
        self.cache.lock().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    fn compile_file(&self, name: &str, path: &Path) -> Result<Executable> {
        if !path.exists() {
            return Err(anyhow!(
                "artifact {} not found — run `make artifacts` first",
                path.display()
            ));
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing HLO text {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e:?}", path.display()))?;
        Ok(Executable { name: name.to_string(), exe })
    }
}

/// Build an `f32` literal of the given shape from a slice.
#[cfg(treecv_pjrt)]
pub fn literal_f32(values: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(values);
    lit.reshape(dims).map_err(|e| anyhow!("reshaping literal to {dims:?}: {e:?}"))
}

/// Build a scalar f32 literal.
#[cfg(treecv_pjrt)]
pub fn scalar_f32(v: f32) -> xla::Literal {
    xla::Literal::from(v)
}

// ---------------------------------------------------------------------------
// Stub implementation (cfg(treecv_pjrt) off — no feature, or feature
// without TREECV_XLA_RUNTIME): same API, constructors error.
// ---------------------------------------------------------------------------

/// Stand-in for `xla::Literal` when PJRT support is compiled out. Values of
/// this type cannot be constructed at runtime (every producer errors
/// first), so its accessors are unreachable.
#[cfg(not(treecv_pjrt))]
#[derive(Debug, Clone)]
pub struct Literal {
    _unconstructible: std::convert::Infallible,
}

#[cfg(not(treecv_pjrt))]
impl Literal {
    /// Mirror of `xla::Literal::to_vec`; never reachable in stub builds.
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unreachable!("stub Literal cannot be constructed")
    }
}

/// Stub [`Executable`]: carries the artifact name only.
#[cfg(not(treecv_pjrt))]
pub struct Executable {
    pub name: String,
    _unconstructible: std::convert::Infallible,
}

#[cfg(not(treecv_pjrt))]
impl Executable {
    /// Mirror of the PJRT execution entry point; never reachable because
    /// no [`Executable`] can be constructed without the `xla` feature.
    pub fn run(&self, _inputs: &[Literal]) -> Result<Vec<Literal>> {
        unreachable!("stub Executable cannot be constructed")
    }
}

/// Stub [`PjrtRuntime`]: constructors return a clean "built without PJRT"
/// error so callers degrade gracefully (skip, or surface the message).
#[cfg(not(treecv_pjrt))]
pub struct PjrtRuntime {
    _unconstructible: std::convert::Infallible,
}

#[cfg(not(treecv_pjrt))]
impl PjrtRuntime {
    fn unavailable<T>() -> Result<T> {
        anyhow::bail!(
            "PJRT runtime unavailable: this binary was built without the `xla` \
             cargo feature (the external `xla` crate is absent in the offline \
             build image). Rebuild with `--features xla` in an environment \
             that provides it."
        )
    }

    /// Always errors in stub builds.
    pub fn cpu() -> Result<Self> {
        Self::unavailable()
    }

    /// Always errors in stub builds.
    pub fn with_dir(_dir: PathBuf) -> Result<Self> {
        Self::unavailable()
    }

    pub fn platform(&self) -> String {
        unreachable!("stub PjrtRuntime cannot be constructed")
    }

    /// Mirror of the artifact loader; unreachable in stub builds.
    pub fn load(&self, _name: &str) -> Result<Arc<Executable>> {
        unreachable!("stub PjrtRuntime cannot be constructed")
    }
}

/// Stub literal builder; errors like the runtime constructors.
#[cfg(not(treecv_pjrt))]
pub fn literal_f32(_values: &[f32], _dims: &[i64]) -> Result<Literal> {
    anyhow::bail!("literal_f32 requires the `xla` cargo feature")
}

/// Stub scalar builder. Unreachable in stub builds: the only callers are
/// the XLA learners, which cannot be constructed without a [`PjrtRuntime`]
/// (whose constructors always error here).
#[cfg(not(treecv_pjrt))]
pub fn scalar_f32(_v: f32) -> Literal {
    unreachable!("scalar_f32 requires the `xla` cargo feature")
}

// ---------------------------------------------------------------------------
// Manifest (always available — pure Rust).
// ---------------------------------------------------------------------------

/// Artifact manifest written by `python/compile/aot.py`: records the
/// (B, d) shapes each program was lowered for, so the Rust side can check
/// compatibility instead of failing inside XLA.
///
/// Line format (whitespace-separated, `#` comments):
/// ```text
/// jax 0.8.2
/// program pegasos_update_b256_d54 256 54
/// ```
#[derive(Debug, Clone)]
pub struct Manifest {
    pub programs: Vec<ManifestEntry>,
    pub jax_version: String,
}

#[derive(Debug, Clone)]
pub struct ManifestEntry {
    pub name: String,
    /// Chunk capacity (rows per execution, padded).
    pub block: usize,
    /// Feature dimension.
    pub dim: usize,
}

impl Manifest {
    pub fn load_default() -> Result<Self> {
        Self::load(&artifacts_dir().join("manifest.txt"))
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading manifest {}", path.display()))?;
        Self::parse(&text)
    }

    /// Parse the line format above.
    pub fn parse(text: &str) -> Result<Self> {
        use anyhow::anyhow;
        let mut programs = Vec::new();
        let mut jax_version = String::from("unknown");
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let mut tok = line.split_ascii_whitespace();
            match tok.next() {
                Some("jax") => {
                    jax_version = tok
                        .next()
                        .ok_or_else(|| anyhow!("line {}: jax version missing", lineno + 1))?
                        .to_string();
                }
                Some("program") => {
                    let name = tok
                        .next()
                        .ok_or_else(|| anyhow!("line {}: program name missing", lineno + 1))?
                        .to_string();
                    let block: usize = tok
                        .next()
                        .ok_or_else(|| anyhow!("line {}: block missing", lineno + 1))?
                        .parse()
                        .map_err(|e| anyhow!("line {}: bad block: {e}", lineno + 1))?;
                    let dim: usize = tok
                        .next()
                        .ok_or_else(|| anyhow!("line {}: dim missing", lineno + 1))?
                        .parse()
                        .map_err(|e| anyhow!("line {}: bad dim: {e}", lineno + 1))?;
                    programs.push(ManifestEntry { name, block, dim });
                }
                Some(other) => anyhow::bail!("line {}: unknown directive `{other}`", lineno + 1),
                None => unreachable!(),
            }
        }
        Ok(Self { programs, jax_version })
    }

    /// Find the program `family` (e.g. "pegasos_update") for dimension `d`,
    /// preferring the largest block.
    pub fn find(&self, family: &str, d: usize) -> Option<&ManifestEntry> {
        self.programs
            .iter()
            .filter(|p| p.dim == d && p.name.starts_with(family))
            .max_by_key(|p| p.block)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifacts_dir_env_override() {
        // Don't mutate the env in parallel tests; just exercise default.
        let d = artifacts_dir();
        assert!(d.ends_with("artifacts") || d.is_absolute());
    }

    #[test]
    fn missing_artifact_is_a_clean_error() {
        let rt = match PjrtRuntime::with_dir(PathBuf::from("/nonexistent-artifacts")) {
            Ok(rt) => rt,
            Err(_) => return, // PJRT unavailable in this environment
        };
        let err = match rt.load("no_such_program") {
            Err(e) => e,
            Ok(_) => panic!("expected a missing-artifact error"),
        };
        assert!(format!("{err}").contains("make artifacts"));
    }

    #[cfg(not(treecv_pjrt))]
    #[test]
    fn stub_runtime_errors_cleanly() {
        let err = PjrtRuntime::cpu().err().expect("stub must error");
        assert!(format!("{err}").contains("xla"), "{err}");
    }

    #[test]
    fn manifest_parses() {
        let m = Manifest::parse(
            "# generated\njax 0.8.2\nprogram pegasos_update_b256_d54 256 54\n\
             program pegasos_update_b64_d54 64 54\n",
        )
        .unwrap();
        assert_eq!(m.jax_version, "0.8.2");
        let e = m.find("pegasos_update", 54).unwrap();
        assert_eq!(e.block, 256);
        assert!(m.find("pegasos_update", 90).is_none());
    }

    #[test]
    fn manifest_rejects_garbage() {
        assert!(Manifest::parse("bogus line\n").is_err());
        assert!(Manifest::parse("program x\n").is_err());
        assert!(Manifest::parse("program x notanum 3\n").is_err());
    }
}
