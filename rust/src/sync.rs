//! The crate's single gateway to concurrency primitives.
//!
//! Every atomic, lock, and thread-parking touchpoint in the library goes
//! through this module instead of `std::sync`/`std::thread` directly —
//! enforced mechanically by the repo lint (`cargo run -p xtask -- lint`,
//! rule `sync-gateway`). Centralizing the primitives buys two things:
//!
//! 1. **Model checking.** Under `cfg(treecv_model_check)` (set via
//!    `RUSTFLAGS="--cfg treecv_model_check"`) the re-exports below swap to
//!    the *instrumented* primitives in [`crate::analysis::shim`], whose
//!    every operation is a scheduling point for the deterministic
//!    interleaving explorer in [`crate::analysis::sched`]. That is what
//!    lets `tests/model_check.rs` drive the real executor through
//!    adversarial thread schedules. Outside a checked run the instrumented
//!    types pass straight through to `std`, so the `treecv_model_check`
//!    build still runs the whole ordinary test suite unchanged.
//! 2. **Poison policy in one place.** [`Mutex::lock`] returns the guard
//!    directly and panics on poisoning with one crate-wide message, so
//!    library code carries no `.lock().unwrap()` noise (and the `no-unwrap`
//!    lint can stay strict). Poisoning still propagates a peer thread's
//!    panic rather than silently continuing on inconsistent state.
//!
//! The default (non-model-check) build compiles to the exact `std` types
//! and operations — the newtypes below are single-field wrappers whose
//! methods forward straight to `std`, so the executor's hot paths are
//! bit-identical in behavior and indistinguishable in cost from the
//! pre-shim code.

/// `Arc` is shared ownership, not inter-thread *synchronization order* —
/// the model checker does not need to interpose on it, so both builds use
/// `std`'s.
pub use std::sync::Arc;

/// Memory-ordering tokens are plain data; both builds use `std`'s enum.
/// (The model checker explores *interleavings* under sequential
/// consistency, shuttle-style; it does not model weak memory.)
pub use std::sync::atomic::Ordering;

#[cfg(not(treecv_model_check))]
mod imp {
    pub use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, AtomicUsize};
    pub use std::sync::Condvar as StdCondvar;
    pub use std::sync::MutexGuard;

    /// `std::sync::Mutex` minus the poison plumbing: [`Mutex::lock`]
    /// yields the guard directly. See the module docs for the policy.
    #[derive(Debug)]
    pub struct Mutex<T>(std::sync::Mutex<T>);

    impl<T: Default> Default for Mutex<T> {
        fn default() -> Self {
            Self::new(T::default())
        }
    }

    impl<T> Mutex<T> {
        pub const fn new(value: T) -> Self {
            Self(std::sync::Mutex::new(value))
        }

        /// Consume the lock and return its data. Panics if a holder
        /// panicked (same policy as [`Self::lock`]).
        pub fn into_inner(self) -> T {
            self.0.into_inner().unwrap_or_else(|_| {
                // invariant: poisoning means a peer thread panicked while
                // holding this lock; that panic is the root failure and
                // must not be absorbed here.
                panic!("treecv::sync::Mutex poisoned: a thread panicked while holding the lock")
            })
        }

        /// Acquire the lock, panicking (not `Err`ing) on poison — a
        /// poisoned lock means a peer thread already panicked, and that
        /// failure must propagate, not be handled.
        pub fn lock(&self) -> MutexGuard<'_, T> {
            self.0.lock().unwrap_or_else(|_| {
                // invariant: see into_inner — the panic that poisoned the
                // lock is the root failure.
                panic!("treecv::sync::Mutex poisoned: a thread panicked while holding the lock")
            })
        }
    }

    /// `std::sync::Condvar` with the same poison policy as [`Mutex`].
    #[derive(Debug, Default)]
    pub struct Condvar(StdCondvar);

    impl Condvar {
        pub const fn new() -> Self {
            Self(StdCondvar::new())
        }

        /// Atomically release `guard` and block until notified; the lock
        /// is re-acquired before returning.
        pub fn wait<'a, T>(&self, guard: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
            self.0.wait(guard).unwrap_or_else(|_| {
                // invariant: same poison policy as Mutex::lock.
                panic!("treecv::sync::Condvar: mutex poisoned while waiting")
            })
        }

        pub fn notify_one(&self) {
            self.0.notify_one();
        }

        pub fn notify_all(&self) {
            self.0.notify_all();
        }
    }

    /// Thread services the library is allowed to touch, re-exported
    /// verbatim from `std`. The model-check build replaces these with
    /// scheduler-aware versions (see [`crate::analysis::shim::thread`]).
    pub mod thread {
        pub use std::thread::{
            available_parallelism, current, panicking, park, scope, Scope, ScopedJoinHandle,
            Thread,
        };
    }
}

#[cfg(treecv_model_check)]
mod imp {
    pub use crate::analysis::shim::thread;
    pub use crate::analysis::shim::{
        AtomicBool, AtomicI64, AtomicU64, AtomicUsize, Condvar, Mutex, MutexGuard,
    };
}

pub use imp::thread;
pub use imp::{AtomicBool, AtomicI64, AtomicU64, AtomicUsize, Condvar, Mutex, MutexGuard};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mutex_roundtrip() {
        let m = Mutex::new(41);
        *m.lock() += 1;
        assert_eq!(*m.lock(), 42);
        assert_eq!(m.into_inner(), 42);
    }

    #[test]
    fn atomics_roundtrip() {
        let b = AtomicBool::new(false);
        b.store(true, Ordering::Release);
        assert!(b.load(Ordering::Acquire));
        let u = AtomicUsize::new(1);
        assert_eq!(u.fetch_add(2, Ordering::AcqRel), 1);
        assert_eq!(u.load(Ordering::Acquire), 3);
        let i = AtomicI64::new(-7);
        i.store(9, Ordering::Relaxed);
        assert_eq!(i.load(Ordering::Relaxed), 9);
        let c = AtomicU64::new(0);
        c.fetch_add(5, Ordering::Relaxed);
        assert_eq!(c.load(Ordering::Relaxed), 5);
    }

    #[test]
    fn park_token_banked_by_early_unpark() {
        // unpark-before-park must bank a token so the park returns
        // immediately — the property the executor's wake_one relies on.
        let t = thread::current();
        t.unpark();
        thread::park(); // would hang forever if the token were lost
    }

    #[test]
    fn condvar_wakes_waiter() {
        let m = Mutex::new(false);
        let cv = Condvar::new();
        thread::scope(|s| {
            s.spawn(|| {
                *m.lock() = true;
                cv.notify_all();
            });
            let mut g = m.lock();
            while !*g {
                g = cv.wait(g);
            }
        });
    }
}
