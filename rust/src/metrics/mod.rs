//! Operation counters and timing used to validate the paper's complexity
//! claims (Theorem 3 / Corollary 4: TreeCV does ≤ (1+c)·n·log₂(2k) update
//! work; §4.1: O(log k) live model copies sequentially, O(k log k)
//! communications distributed).
//!
//! Counters are plain `u64`s carried through the engines (no atomics on the
//! sequential hot path) plus one static string — the selected kernel
//! backend — stamped at construction; the parallel engine keeps per-thread
//! counters and merges them on join.

use std::time::{Duration, Instant};

/// Work counters for one CV computation.
#[derive(Debug, Clone)]
pub struct OpCounts {
    /// Calls into `IncrementalLearner::update` / `update_logged`.
    pub update_calls: u64,
    /// Total points fed through updates (the paper's `n·log₂(2k)` bound
    /// applies to this number for TreeCV, `n·(k-1)/k·k` for standard CV).
    pub points_updated: u64,
    /// Model snapshots taken (Copy strategy / parallel engine).
    pub model_copies: u64,
    /// Bytes of model state snapshotted.
    pub bytes_copied: u64,
    /// Reverts applied (SaveRevert strategy).
    pub model_restores: u64,
    /// Chunk evaluations (one per fold).
    pub evals: u64,
    /// Points scored during evaluation.
    pub points_evaluated: u64,
    /// Points passed through a random permutation (randomized variants).
    pub points_permuted: u64,
    /// Node-stream index vectors materialized via a fresh heap allocation
    /// (`gather_ordered`'s per-node `Vec`, standard CV's training-sequence
    /// buffer, a fold-contiguous run's first scratch buffer). This is the
    /// ONE counter that is *layout-dependent by design*: the indexed path
    /// pays one per training phase, the fold-contiguous layout
    /// ([`crate::data::folded::FoldedDataset`]) pays zero under fixed
    /// ordering and O(1) recycled buffers per worker under randomized
    /// ordering — while every other counter in this struct stays
    /// bit-identical across layouts (`tests/integration_layout.rs`).
    pub stream_allocs: u64,
    /// Wholesale TreeCV subtree re-runs plus touched-leaf re-evaluations
    /// performed by the incremental refresh engine
    /// ([`crate::cv::refresh`]). Bounded by ⌈log₂(2k)⌉ per touched fold
    /// per refresh (the root-to-leaf path of the touched leaf); always 0
    /// for from-scratch runs.
    pub subtrees_recomputed: u64,
    /// One-step held-out corrections applied by the approximate-CV engine
    /// ([`crate::cv::approx`]): exactly k per approx run (one per fold),
    /// always 0 for the exact engines. Together with `points_updated`
    /// (n for approx vs Θ(n log₂(2k)) for TreeCV) this is the counter the
    /// k = n speedup claim is asserted against.
    pub corrections: u64,
    /// Largest per-fold |approx − exact| observed when an exact oracle
    /// was run alongside the approximate engine (`--approx-check`); 0.0
    /// when no check ran. Merged by `max`, not `+`.
    pub exact_gap_max: f64,
    /// Kernel backend the dense learners dispatched to for this run
    /// (`"scalar"` or `"avx2"` — [`crate::learner::linalg::backend_name`]).
    /// Provenance only: backends are bit-identical, so this never affects a
    /// result, and the layout equivalence batteries deliberately exclude it
    /// from their comparisons.
    pub kernel_backend: &'static str,
}

// Hand-written (instead of derived) so the backend is stamped at
// construction; all numeric counters start at zero as before.
impl Default for OpCounts {
    fn default() -> Self {
        Self {
            update_calls: 0,
            points_updated: 0,
            model_copies: 0,
            bytes_copied: 0,
            model_restores: 0,
            evals: 0,
            points_evaluated: 0,
            points_permuted: 0,
            stream_allocs: 0,
            subtrees_recomputed: 0,
            corrections: 0,
            exact_gap_max: 0.0,
            kernel_backend: crate::learner::linalg::backend_name(),
        }
    }
}

impl OpCounts {
    /// Merge counters from another (sub)computation. The backend tag is
    /// process-wide, so `self`'s is kept.
    pub fn merge(&mut self, other: &OpCounts) {
        self.update_calls += other.update_calls;
        self.points_updated += other.points_updated;
        self.model_copies += other.model_copies;
        self.bytes_copied += other.bytes_copied;
        self.model_restores += other.model_restores;
        self.evals += other.evals;
        self.points_evaluated += other.points_evaluated;
        self.points_permuted += other.points_permuted;
        self.stream_allocs += other.stream_allocs;
        self.subtrees_recomputed += other.subtrees_recomputed;
        self.corrections += other.corrections;
        // A gap is a sup-norm over folds, not additive work.
        self.exact_gap_max = self.exact_gap_max.max(other.exact_gap_max);
    }
}

/// Simple scope timer.
#[derive(Debug)]
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }
}

/// Streaming mean/variance accumulator (Welford) for the repetition
/// harness (paper Table 2 reports mean ± std over 100 repetitions).
#[derive(Debug, Default, Clone)]
pub struct RunningStats {
    n: u64,
    mean: f64,
    m2: f64,
}

impl RunningStats {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample standard deviation (n−1 denominator), 0 for n < 2.
    pub fn std(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            (self.m2 / (self.n - 1) as f64).sqrt()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn opcounts_merge_adds() {
        let mut a = OpCounts { update_calls: 1, points_updated: 10, ..Default::default() };
        let b = OpCounts {
            update_calls: 2,
            points_updated: 20,
            evals: 3,
            stream_allocs: 4,
            ..Default::default()
        };
        a.merge(&b);
        assert_eq!(a.update_calls, 3);
        assert_eq!(a.points_updated, 30);
        assert_eq!(a.evals, 3);
        assert_eq!(a.stream_allocs, 4);
    }

    #[test]
    fn opcounts_merge_takes_max_gap_and_adds_corrections() {
        let mut a = OpCounts { corrections: 2, exact_gap_max: 1e-9, ..Default::default() };
        let b = OpCounts { corrections: 5, exact_gap_max: 3e-10, ..Default::default() };
        a.merge(&b);
        assert_eq!(a.corrections, 7);
        assert_eq!(a.exact_gap_max, 1e-9);
        let c = OpCounts { exact_gap_max: 2e-8, ..Default::default() };
        a.merge(&c);
        assert_eq!(a.exact_gap_max, 2e-8);
    }

    #[test]
    fn running_stats_mean_std() {
        let mut s = RunningStats::default();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.push(x);
        }
        assert_eq!(s.count(), 8);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        // Sample std of this classic set is sqrt(32/7).
        assert!((s.std() - (32.0f64 / 7.0).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn running_stats_degenerate() {
        let mut s = RunningStats::default();
        assert_eq!(s.std(), 0.0);
        s.push(3.0);
        assert_eq!(s.mean(), 3.0);
        assert_eq!(s.std(), 0.0);
    }
}
