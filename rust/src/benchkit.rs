//! In-tree micro-benchmark harness (the offline environment vendors no
//! criterion). Benches under `benches/` are `harness = false` binaries that
//! drive [`Bench`]: warmup, repeated timed samples, and a summary with
//! median / mean / std / min, plus CSV emission so EXPERIMENTS.md rows are
//! copy-pasteable — and [`JsonReport`] for machine-readable perf
//! trajectories (`BENCH_<name>.json` files committed at the repo root so
//! later PRs have a baseline to diff against). Deliberately simple — the
//! experiments here measure milliseconds-to-seconds-scale end-to-end CV
//! runs, not nanosecond ops.

use crate::report::Json;
use std::time::{Duration, Instant};

/// One benchmark's samples.
#[derive(Debug, Clone)]
pub struct Samples {
    pub name: String,
    pub secs: Vec<f64>,
}

impl Samples {
    pub fn median(&self) -> f64 {
        let mut s = self.secs.clone();
        s.sort_by(|a, b| a.total_cmp(b));
        let n = s.len();
        if n == 0 {
            return 0.0;
        }
        if n % 2 == 1 {
            s[n / 2]
        } else {
            0.5 * (s[n / 2 - 1] + s[n / 2])
        }
    }

    pub fn mean(&self) -> f64 {
        if self.secs.is_empty() {
            return 0.0;
        }
        self.secs.iter().sum::<f64>() / self.secs.len() as f64
    }

    pub fn std(&self) -> f64 {
        let n = self.secs.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.secs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / (n - 1) as f64).sqrt()
    }

    pub fn min(&self) -> f64 {
        self.secs.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn summary(&self) -> String {
        format!(
            "{:<44} median {:>10.4}s  mean {:>10.4}s ± {:>8.4}  min {:>10.4}s  ({} samples)",
            self.name,
            self.median(),
            self.mean(),
            self.std(),
            self.min(),
            self.secs.len()
        )
    }
}

/// The harness: configure via env (`BENCH_SAMPLES`, `BENCH_WARMUP`) or
/// builder methods.
pub struct Bench {
    samples: usize,
    warmup: usize,
    results: Vec<Samples>,
}

impl Default for Bench {
    fn default() -> Self {
        let samples = std::env::var("BENCH_SAMPLES").ok().and_then(|v| v.parse().ok()).unwrap_or(5);
        let warmup = std::env::var("BENCH_WARMUP").ok().and_then(|v| v.parse().ok()).unwrap_or(1);
        Self { samples, warmup, results: Vec::new() }
    }
}

impl Bench {
    pub fn new(samples: usize, warmup: usize) -> Self {
        Self { samples, warmup, results: Vec::new() }
    }

    /// Time `f` (which must do one full unit of work per call).
    pub fn run<F: FnMut()>(&mut self, name: &str, mut f: F) -> &Samples {
        for _ in 0..self.warmup {
            f();
        }
        let mut secs = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t = Instant::now();
            f();
            secs.push(t.elapsed().as_secs_f64());
        }
        let s = Samples { name: name.to_string(), secs };
        println!("{}", s.summary());
        self.results.push(s);
        // invariant: we pushed one element on the line above.
        self.results.last().expect("results non-empty after push")
    }

    /// Record an externally measured duration series (e.g. from an engine's
    /// own wall-clock) under a name.
    pub fn record(&mut self, name: &str, durations: &[Duration]) -> &Samples {
        let s = Samples {
            name: name.to_string(),
            secs: durations.iter().map(|d| d.as_secs_f64()).collect(),
        };
        println!("{}", s.summary());
        self.results.push(s);
        // invariant: we pushed one element on the line above.
        self.results.last().expect("results non-empty after push")
    }

    /// All results as CSV (name, median, mean, std, min, samples).
    pub fn csv(&self) -> String {
        let mut s = String::from("name,median_s,mean_s,std_s,min_s,samples\n");
        for r in &self.results {
            s.push_str(&format!(
                "{},{:.6},{:.6},{:.6},{:.6},{}\n",
                r.name,
                r.median(),
                r.mean(),
                r.std(),
                r.min(),
                r.secs.len()
            ));
        }
        s
    }

    pub fn results(&self) -> &[Samples] {
        &self.results
    }
}

/// Machine-readable bench report: one object per scenario (sample
/// statistics plus free-form numeric metrics such as op counts or derived
/// speedups), rendered as pretty JSON with a stable schema:
///
/// ```json
/// {
///   "bench": "layout", "schema": 1, "measured": true,
///   "env": { "n": 16384, ... },
///   "scenarios": [
///     { "name": "...", "median_s": 0.01, ..., "stream_allocs": 0 }, ...
///   ]
/// }
/// ```
///
/// `measured: false` marks a committed hand-authored placeholder (same
/// schema, wall-clock fields null, op-count-derived metrics only) —
/// rerunning the bench on a real machine overwrites it with measured
/// numbers and `measured: true`.
///
/// Schema 2 adds provenance: every scenario carries a `kernel_backend`
/// string tag (appended automatically by [`JsonReport::push_samples`]
/// unless the caller supplies its own), and `env` admits string values
/// (e.g. blocking-parameter names) alongside numeric knobs.
pub struct JsonReport {
    bench: String,
    env: Vec<(String, Json)>,
    scenarios: Vec<Json>,
}

impl JsonReport {
    pub fn new(bench: &str) -> Self {
        Self { bench: bench.to_string(), env: Vec::new(), scenarios: Vec::new() }
    }

    /// Record a run-configuration knob (shown once, under `"env"`).
    pub fn env(&mut self, key: &str, value: f64) -> &mut Self {
        self.env.push((key.to_string(), Json::Num(value)));
        self
    }

    /// Record a string-valued configuration knob under `"env"`.
    pub fn env_str(&mut self, key: &str, value: &str) -> &mut Self {
        self.env.push((key.to_string(), Json::str(value)));
        self
    }

    /// Add a scenario from measured [`Samples`] plus extra numeric
    /// metrics (op counts, ratios). The scenario is tagged with the
    /// process-wide kernel backend.
    pub fn push_samples(&mut self, s: &Samples, metrics: &[(&str, f64)]) {
        self.push_samples_tagged(s, metrics, &[]);
    }

    /// Like [`push_samples`](Self::push_samples), with extra string tags
    /// (e.g. a forced-backend label). A `kernel_backend` tag recording the
    /// dispatched SIMD backend is appended automatically unless `tags`
    /// already provides one.
    pub fn push_samples_tagged(
        &mut self,
        s: &Samples,
        metrics: &[(&str, f64)],
        tags: &[(&str, &str)],
    ) {
        let mut pairs = vec![
            ("name", Json::str(s.name.clone())),
            ("median_s", Json::Num(s.median())),
            ("mean_s", Json::Num(s.mean())),
            ("std_s", Json::Num(s.std())),
            ("min_s", Json::Num(s.min())),
            ("samples", Json::num(s.secs.len() as f64)),
        ];
        for &(k, v) in metrics {
            pairs.push((k, Json::Num(v)));
        }
        for &(k, v) in tags {
            pairs.push((k, Json::str(v)));
        }
        if !tags.iter().any(|&(k, _)| k == "kernel_backend") {
            pairs.push(("kernel_backend", Json::str(crate::learner::linalg::backend_name())));
        }
        self.scenarios.push(Json::obj(pairs));
    }

    /// The report as a JSON value. Reports produced here are always
    /// `measured: true`; the `false` variant exists only for committed
    /// placeholders authored without a toolchain.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("bench", Json::str(self.bench.clone())),
            ("schema", Json::num(2.0)),
            ("measured", Json::Bool(true)),
            (
                "env",
                Json::Obj(self.env.iter().map(|(k, v)| (k.clone(), v.clone())).collect()),
            ),
            ("scenarios", Json::Arr(self.scenarios.clone())),
        ])
    }

    /// Write the pretty-rendered report to `path` (trailing newline
    /// included, so committed files are diff-friendly).
    pub fn write(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().render_pretty() + "\n")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn statistics() {
        let s = Samples { name: "x".into(), secs: vec![1.0, 2.0, 3.0, 4.0] };
        assert_eq!(s.median(), 2.5);
        assert_eq!(s.mean(), 2.5);
        assert_eq!(s.min(), 1.0);
        assert!((s.std() - (5.0f64 / 3.0).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn run_collects_samples() {
        let mut b = Bench::new(3, 1);
        let mut calls = 0u32;
        b.run("noop", || calls += 1);
        assert_eq!(calls, 4); // 1 warmup + 3 samples
        assert_eq!(b.results()[0].secs.len(), 3);
        assert!(b.csv().contains("noop"));
    }

    #[test]
    fn median_odd() {
        let s = Samples { name: "x".into(), secs: vec![3.0, 1.0, 2.0] };
        assert_eq!(s.median(), 2.0);
    }

    #[test]
    fn json_report_schema() {
        let mut r = JsonReport::new("layout");
        r.env("n", 16.0);
        r.env_str("block", "syrk=16");
        let s = Samples { name: "a/b".into(), secs: vec![1.0, 3.0] };
        r.push_samples(&s, &[("stream_allocs", 0.0)]);
        let out = r.to_json().render();
        assert!(out.contains("\"bench\":\"layout\""), "{out}");
        assert!(out.contains("\"schema\":2"), "{out}");
        assert!(out.contains("\"measured\":true"), "{out}");
        assert!(out.contains("\"median_s\":2"), "{out}");
        assert!(out.contains("\"stream_allocs\":0"), "{out}");
        assert!(out.contains("\"n\":16"), "{out}");
        assert!(out.contains("\"block\":\"syrk=16\""), "{out}");
        let backend = crate::learner::linalg::backend_name();
        assert!(out.contains(&format!("\"kernel_backend\":\"{backend}\"")), "{out}");
    }

    #[test]
    fn explicit_backend_tag_wins() {
        let mut r = JsonReport::new("kernels");
        let s = Samples { name: "k/forced".into(), secs: vec![1.0] };
        r.push_samples_tagged(&s, &[], &[("kernel_backend", "scalar")]);
        let out = r.to_json().render();
        assert!(out.contains("\"kernel_backend\":\"scalar\""), "{out}");
        assert_eq!(out.matches("kernel_backend").count(), 1, "{out}");
    }
}
