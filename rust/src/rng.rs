//! Deterministic, dependency-free pseudo-random number generation.
//!
//! All randomness in the library flows through [`Rng`] (xoshiro256++),
//! seeded explicitly, so every experiment — fold assignment, data
//! permutations, synthetic datasets, learner initialization — is exactly
//! reproducible from a `(seed, stream)` pair. This matters doubly here:
//! the paper's Table 2 compares estimator *variance* across 100
//! repetitions, which is only meaningful when the repetitions differ in a
//! controlled way.

/// xoshiro256++ PRNG (Blackman & Vigna). Fast, 256-bit state, passes BigCrush.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

#[inline(always)]
fn rotl(x: u64, k: u32) -> u64 {
    x.rotate_left(k)
}

/// SplitMix64, used to expand a single `u64` seed into xoshiro state.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a seed. Two different seeds give
    /// statistically independent streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Self {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Derive a sub-stream: deterministic function of `(self seed, tag)`.
    /// Used to give each repetition / fold / phase its own stream without
    /// correlation.
    pub fn derive(seed: u64, tag: u64) -> Self {
        let mut sm = seed ^ tag.wrapping_mul(0x9E3779B97F4A7C15);
        sm = sm.wrapping_add(0xD1B54A32D192ED03);
        Self::new(splitmix64(&mut sm))
    }

    /// Next raw 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = rotl(self.s[0].wrapping_add(self.s[3]), 23).wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = rotl(self.s[3], 45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, bound) via Lemire's multiply-shift rejection.
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Standard normal via Box–Muller (f32 output; fine for data synthesis).
    #[inline]
    pub fn next_gaussian(&mut self) -> f32 {
        // Avoid log(0) by nudging u1 away from zero.
        let u1 = (self.next_f64()).max(1e-300);
        let u2 = self.next_f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, slice: &mut [T]) {
        let n = slice.len();
        for i in (1..n).rev() {
            let j = self.below(i as u64 + 1) as usize;
            slice.swap(i, j);
        }
    }

    /// A random permutation of `0..n` as `u32` indices.
    pub fn permutation(&mut self, n: usize) -> Vec<u32> {
        let mut p: Vec<u32> = (0..n as u32).collect();
        self.shuffle(&mut p);
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(12345);
        let mut b = Rng::new(12345);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn derive_streams_differ() {
        let mut a = Rng::derive(7, 0);
        let mut b = Rng::derive(7, 1);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(99);
        for _ in 0..10_000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_respects_bound() {
        let mut r = Rng::new(5);
        for bound in [1u64, 2, 3, 7, 10, 1000] {
            for _ in 0..1000 {
                assert!(r.below(bound) < bound);
            }
        }
    }

    #[test]
    fn below_hits_all_values() {
        let mut r = Rng::new(6);
        let mut seen = [false; 8];
        for _ in 0..10_000 {
            seen[r.below(8) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(17);
        let n = 100_000;
        let (mut sum, mut sumsq) = (0f64, 0f64);
        for _ in 0..n {
            let g = r.next_gaussian() as f64;
            sum += g;
            sumsq += g * g;
        }
        let mean = sum / n as f64;
        let var = sumsq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let p = r.permutation(1000);
        let mut seen = vec![false; 1000];
        for &i in &p {
            assert!(!seen[i as usize]);
            seen[i as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_actually_moves() {
        let mut r = Rng::new(4);
        let p = r.permutation(1000);
        let moved = p.iter().enumerate().filter(|(i, &v)| *i as u32 != v).count();
        assert!(moved > 900);
    }
}
