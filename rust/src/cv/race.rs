//! Racing sweeps: statistically eliminate losing configurations
//! mid-flight instead of running every sweep cell to completion.
//!
//! The exhaustive scheduler ([`super::sweep`]) spends `C × S × R` full
//! TreeCV runs on a grid of C configs × S strategies × R repetitions —
//! linear in grid size even though most cells are obvious losers early.
//! Krueger et al. (*Fast Cross-Validation via Sequential Testing*) show a
//! sequential test over partial results can drop most configurations
//! after a fraction of the work. This module implements that discipline
//! on top of the executor's cancellation layer
//! ([`super::executor::RunCtrl`] / `run_many_outcomes`):
//!
//! * **Rounds.** The R repetitions are split into `rounds` round
//!   boundaries `r_j = ⌈R·(j+1)/rounds⌉` (deduplicated; the last is
//!   always R). The whole `C × S × R` batch is dispatched through ONE
//!   executor pool up front — rounds are *decision points*, not barriers:
//!   round j fires the moment every still-alive cell has its first `r_j`
//!   repetitions delivered (the executor's incremental callback), while
//!   later repetitions keep streaming.
//! * **Elimination test.** At each non-final boundary, the *incumbent* is
//!   the alive cell with the lowest mean estimate over the first `r_j`
//!   repetitions (lowest cell index on ties). Every other alive cell is
//!   compared to it by a paired sign test over those repetitions: with
//!   `w` = repetitions where the incumbent's estimate is strictly lower
//!   and `n` = non-tied repetitions, the p-value is the exact binomial
//!   upper tail `P(W ≥ w)` for `W ~ Binomial(n, ½)`. A cell with
//!   `p ≤ alpha` is eliminated: its [`RunCtrl`] token is cancelled, so
//!   its outstanding runs (queued roots and in-flight subtrees) are
//!   dropped and their workers freed; survivors' priorities are raised so
//!   their remaining runs start ahead of anything stale in the injector.
//! * **Determinism.** Decisions depend only on the estimates of the
//!   counted repetition prefix — pure functions of `(learner, data,
//!   folds, seed)` — and round triggers are *set-based* (fire when the
//!   prefix is complete, processed in round order under one lock), never
//!   on arrival order. The [`EliminationTrace`] is therefore identical
//!   for a given seed across worker counts and across re-runs; only
//!   wall-clock and the work-saved counters (how many of a loser's runs
//!   were actually cancelled vs. already finished) vary with scheduling.
//!   With `alpha = 0` the test can never reject (`p > 0` always), so the
//!   race degenerates to the exhaustive sweep and reproduces
//!   [`super::sweep::run_sweep`]'s cells bit for bit —
//!   `tests/integration_race.rs` pins both properties.
//!
//! Aggregation: an eliminated cell reports `mean ± std` over exactly its
//! counted prefix (`reps_used = r_j` at elimination) — never over
//! whichever extra in-flight repetitions happened to finish — and a
//! survivor over all R, exactly as the exhaustive scheduler aggregates.

use super::executor::{ErasedRunSpec, OnResult, RunCtrl, RunOutcome, RunSpec, TreeCvExecutor};
use super::sweep::{build_runs, repetition_folds, validate, SweepSpec};
use super::{CvResult, Strategy};
use crate::data::Dataset;
use crate::learner::erased::ErasedLearner;
use crate::learner::IncrementalLearner;
use crate::metrics::{OpCounts, RunningStats, Timer};
use crate::Result;
use anyhow::bail;
use crate::sync::Mutex;
use std::time::Duration;

/// A racing sweep's axes: the exhaustive sweep's axes plus the racing
/// knobs.
#[derive(Debug, Clone)]
pub struct RaceSpec {
    /// The underlying grid (configs × strategies × repetitions, seeds,
    /// threads) — identical semantics to the exhaustive scheduler.
    pub sweep: SweepSpec,
    /// Number of decision rounds the repetitions are split into
    /// (boundaries at `⌈R·(j+1)/rounds⌉`). `1` means a single final
    /// round, i.e. no elimination opportunities.
    pub rounds: usize,
    /// Significance level of the per-round sign test; a cell is
    /// eliminated when its p-value is `≤ alpha`. `0.0` never eliminates
    /// (the exhaustive sweep, bit for bit).
    pub alpha: f64,
}

/// One row of the [`EliminationTrace`]: cell × round, with the round's
/// statistic and decision. Rows are emitted in (round, cell-index) order
/// and only for cells still alive at that round.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceRow {
    /// Cell index in canonical (config-major, strategy-minor) order.
    pub cell: usize,
    /// Index into the learner axis.
    pub config: usize,
    pub strategy: Strategy,
    /// Decision round (0-based).
    pub round: usize,
    /// Repetitions counted at this round (the boundary `r_j`).
    pub reps_used: usize,
    /// Mean estimate over the counted repetitions.
    pub mean: f64,
    /// Incumbent wins in the paired sign test (0 for the incumbent row).
    pub wins: usize,
    /// Non-tied repetitions in the test (0 for the incumbent row).
    pub n_eff: usize,
    /// Exact binomial upper-tail p-value (1.0 for the incumbent row).
    pub p_value: f64,
    /// Whether this round eliminated the cell.
    pub eliminated: bool,
}

/// The full, deterministic record of a race's decisions: identical for a
/// given seed across worker counts and re-runs.
#[derive(Debug, Clone, PartialEq)]
pub struct EliminationTrace {
    /// Round boundaries `r_j` (ascending; the last equals R).
    pub boundaries: Vec<usize>,
    /// Per-(round, alive cell) decision rows.
    pub rows: Vec<TraceRow>,
}

/// One (config, strategy) cell of a race — the racing analogue of
/// [`super::sweep::SweepCell`], plus its elimination status.
#[derive(Debug, Clone)]
pub struct RaceCell {
    /// Index into the learner axis.
    pub config: usize,
    pub strategy: Strategy,
    /// Mean estimate over the counted repetitions (`runs`).
    pub mean: f64,
    /// Sample std over the counted repetitions.
    pub std: f64,
    /// Counters from the last counted repetition.
    pub ops: OpCounts,
    /// Repetitions this cell's aggregate counts: the elimination
    /// boundary for a loser, R for a survivor.
    pub reps_used: usize,
    /// The round that eliminated this cell, if any.
    pub eliminated_round: Option<usize>,
    /// The counted repetitions' full results, in repetition order; each
    /// is bit-identical to the exhaustive sweep's corresponding run.
    pub runs: Vec<CvResult>,
}

/// Everything a race produced. Cells are in canonical (config-major,
/// strategy-minor) order — ranking is the caller's concern.
#[derive(Debug, Clone)]
pub struct RaceOutcome {
    pub cells: Vec<RaceCell>,
    pub trace: EliminationTrace,
    /// Worker-pool size the batch actually used (knob resolved and
    /// clamped exactly as the exhaustive scheduler reports it).
    pub threads: usize,
    /// Wall-clock of the whole raced batch.
    pub total_wall: Duration,
    /// Executor pools spawned (1 for a multi-worker pool, 0 inline).
    pub pool_spawns: u64,
    /// Work-saved accounting: every run the grid scheduled…
    pub runs_scheduled: usize,
    /// …how many ran to completion (includes a loser's in-flight runs
    /// that finished before its cancellation landed)…
    pub runs_completed: usize,
    /// …and how many were cancelled outright. Scheduling-dependent
    /// (unlike the trace): a fast pool may finish a loser's runs before
    /// the token lands.
    pub runs_cancelled: usize,
    /// Tree tasks dropped by those cancellations (executor accounting).
    pub tasks_cancelled: u64,
}

/// Round boundaries `⌈R·(j+1)/rounds⌉` for `j in 0..rounds`, deduplicated
/// (more rounds than repetitions collapses to one boundary per
/// repetition). The last boundary is always R.
fn round_boundaries(repetitions: usize, rounds: usize) -> Vec<usize> {
    let mut b: Vec<usize> =
        (1..=rounds).map(|j| (repetitions * j + rounds - 1) / rounds).collect();
    b.dedup();
    b
}

/// Exact upper tail `P(W ≥ wins)` for `W ~ Binomial(n_eff, ½)`, computed
/// with the iterative term recurrence `C(n,t+1) = C(n,t)·(n−t)/(t+1)` in
/// f64 — deterministic across platforms (pure IEEE arithmetic, fixed
/// evaluation order). `n_eff = 0` (all ties, or the incumbent row)
/// yields 1.0.
fn sign_test_p(wins: usize, n_eff: usize) -> f64 {
    if n_eff == 0 {
        return 1.0;
    }
    let n = n_eff as f64;
    let mut term = 0.5f64.powi(n_eff as i32); // C(n, 0) / 2^n
    let mut p = 0.0;
    for t in 0..=n_eff {
        if t >= wins {
            p += term;
        }
        term *= (n - t as f64) / (t as f64 + 1.0);
    }
    p
}

/// Mutable race state, guarded by the controller's lock.
struct RaceState {
    /// Delivered estimates, `[cell][repetition]`.
    estimates: Vec<Vec<Option<f64>>>,
    alive: Vec<bool>,
    elim_round: Vec<Option<usize>>,
    /// Next round awaiting its trigger.
    next_round: usize,
    rows: Vec<TraceRow>,
    /// First failure message, if any run failed.
    failed: Option<String>,
}

/// The sequential-elimination controller: receives each run's outcome
/// from the executor's incremental-delivery callback and advances the
/// round cascade under one lock, so decisions are serialized and
/// arrival-order-independent.
struct Controller<'a> {
    state: Mutex<RaceState>,
    /// One shared control block per cell (cloned into its R run specs).
    ctrls: &'a [RunCtrl],
    /// `(config, strategy)` per cell, canonical order.
    meta: &'a [(usize, Strategy)],
    boundaries: &'a [usize],
    repetitions: usize,
    alpha: f64,
}

impl<'a> Controller<'a> {
    fn new(
        ctrls: &'a [RunCtrl],
        meta: &'a [(usize, Strategy)],
        boundaries: &'a [usize],
        repetitions: usize,
        alpha: f64,
    ) -> Self {
        let n_cells = ctrls.len();
        Self {
            state: Mutex::new(RaceState {
                estimates: vec![vec![None; repetitions]; n_cells],
                alive: vec![true; n_cells],
                elim_round: vec![None; n_cells],
                next_round: 0,
                rows: Vec::new(),
                failed: None,
            }),
            ctrls,
            meta,
            boundaries,
            repetitions,
            alpha,
        }
    }

    /// Incremental-delivery entry: record run `run_idx`'s outcome and
    /// fire every round whose trigger it completes.
    fn record(&self, run_idx: usize, out: &RunOutcome) {
        let (cell, rep) = (run_idx / self.repetitions, run_idx % self.repetitions);
        let mut st = self.state.lock();
        match out {
            RunOutcome::Completed(res) => st.estimates[cell][rep] = Some(res.estimate),
            RunOutcome::Failed { error } => {
                // One failed repetition aborts the whole race: cancel
                // every cell so the batch winds down fast; the entry
                // point surfaces the error.
                if st.failed.is_none() {
                    st.failed = Some(error.clone());
                    for ctrl in self.ctrls {
                        ctrl.cancel();
                    }
                }
                return;
            }
            RunOutcome::Cancelled { .. } => return,
        }
        if st.failed.is_some() {
            return;
        }
        self.advance(&mut st);
    }

    /// Fire rounds in order while their triggers hold: round j fires
    /// once every alive cell has estimates for the full counted prefix
    /// `[0, r_j)`. Eliminations shrink the alive set, which may complete
    /// the next round's trigger immediately — hence the cascade loop.
    fn advance(&self, st: &mut RaceState) {
        while st.next_round < self.boundaries.len() {
            let r = self.boundaries[st.next_round];
            let n_cells = self.ctrls.len();
            let ready = (0..n_cells)
                .filter(|&c| st.alive[c])
                .all(|c| st.estimates[c][..r].iter().all(Option::is_some));
            if !ready {
                return;
            }
            let round = st.next_round;
            let is_final = r == self.repetitions;
            let means: Vec<(usize, f64)> = (0..n_cells)
                .filter(|&c| st.alive[c])
                .map(|c| {
                    // invariant: the round fires only once every counted
                    // prefix estimate of every alive cell is recorded.
                    let sum: f64 =
                        st.estimates[c][..r].iter().map(|e| e.expect("trigger held")).sum();
                    (c, sum / r as f64)
                })
                .collect();
            // Incumbent: lowest mean; `min_by` keeps the first (= lowest
            // cell index) among exact ties.
            // invariant: the incumbent is never eliminated, so at least
            // one cell is always alive.
            let &(inc, _) =
                means.iter().min_by(|a, b| a.1.total_cmp(&b.1)).expect("≥ 1 alive cell");
            for &(c, mean) in &means {
                let (wins, n_eff) = if c == inc {
                    (0, 0)
                } else {
                    let mut wins = 0;
                    let mut n_eff = 0;
                    for rep in 0..r {
                        // invariant: same trigger as the means above —
                        // every counted prefix estimate is recorded.
                        let a = st.estimates[inc][rep].expect("trigger held");
                        let b = st.estimates[c][rep].expect("trigger held");
                        if a < b {
                            wins += 1;
                        }
                        if a != b {
                            n_eff += 1;
                        }
                    }
                    (wins, n_eff)
                };
                let p_value = if c == inc { 1.0 } else { sign_test_p(wins, n_eff) };
                let eliminated = !is_final && c != inc && p_value <= self.alpha;
                let (config, strategy) = self.meta[c];
                st.rows.push(TraceRow {
                    cell: c,
                    config,
                    strategy,
                    round,
                    reps_used: r,
                    mean,
                    wins,
                    n_eff,
                    p_value,
                    eliminated,
                });
                if eliminated {
                    st.alive[c] = false;
                    st.elim_round[c] = Some(round);
                    self.ctrls[c].cancel();
                }
            }
            // Survivors outrank anything admitted for an earlier round
            // still sitting in the injector.
            for &(c, _) in &means {
                if st.alive[c] {
                    self.ctrls[c].set_priority((round + 1) as i64);
                }
            }
            st.next_round += 1;
        }
    }

    /// Fold the batch's outcomes and the recorded decisions into the
    /// final report.
    fn finish(
        self,
        outcomes: Vec<RunOutcome>,
        total_wall: Duration,
        threads: usize,
        pool_spawns: u64,
    ) -> Result<RaceOutcome> {
        let st = self.state.into_inner();
        if let Some(error) = st.failed {
            bail!("race aborted: a repetition failed: {error}");
        }
        let runs_scheduled = outcomes.len();
        let runs_completed = outcomes.iter().filter(|o| o.completed().is_some()).count();
        let runs_cancelled = outcomes.iter().filter(|o| o.is_cancelled()).count();
        let tasks_cancelled: u64 = outcomes
            .iter()
            .map(|o| match o {
                RunOutcome::Cancelled { tasks_dropped, .. } => *tasks_dropped as u64,
                _ => 0,
            })
            .sum();
        let mut slots: Vec<Option<RunOutcome>> = outcomes.into_iter().map(Some).collect();
        let cells = (0..self.ctrls.len())
            .map(|c| {
                let reps_used = match st.elim_round[c] {
                    Some(round) => self.boundaries[round],
                    None => self.repetitions,
                };
                let runs: Vec<CvResult> = (0..reps_used)
                    .map(|rep| {
                        let taken = slots[c * self.repetitions + rep].take();
                        match taken {
                            Some(RunOutcome::Completed(res)) => res,
                            _ => panic!(
                                "race invariant violated: counted repetition {rep} of cell {c} \
                                 did not complete"
                            ),
                        }
                    })
                    .collect();
                let mut stats = RunningStats::default();
                for res in &runs {
                    stats.push(res.estimate);
                }
                let (config, strategy) = self.meta[c];
                RaceCell {
                    config,
                    strategy,
                    mean: stats.mean(),
                    std: stats.std(),
                    // invariant: every round boundary is ≥ 1 repetition,
                    // so a cell's counted run list is never empty.
                    ops: runs.last().expect("reps_used >= 1").ops.clone(),
                    reps_used,
                    eliminated_round: st.elim_round[c],
                    runs,
                }
            })
            .collect();
        Ok(RaceOutcome {
            cells,
            trace: EliminationTrace { boundaries: self.boundaries.to_vec(), rows: st.rows },
            threads,
            total_wall,
            pool_spawns,
            runs_scheduled,
            runs_completed,
            runs_cancelled,
            tasks_cancelled,
        })
    }
}

/// Racing-specific validation, on top of the shared sweep validation.
fn validate_race(spec: &RaceSpec) -> Result<()> {
    if spec.rounds == 0 {
        bail!("race needs rounds >= 1");
    }
    if !spec.alpha.is_finite() || !(0.0..=1.0).contains(&spec.alpha) {
        bail!("race alpha = {} must lie in [0, 1]", spec.alpha);
    }
    Ok(())
}

/// `(config, strategy)` per cell in canonical order, plus one fresh
/// control block per cell.
fn cell_axes(n_configs: usize, spec: &SweepSpec) -> (Vec<(usize, Strategy)>, Vec<RunCtrl>) {
    let mut meta = Vec::with_capacity(n_configs * spec.strategies.len());
    for config in 0..n_configs {
        for &strategy in &spec.strategies {
            meta.push((config, strategy));
        }
    }
    let ctrls = meta.iter().map(|_| RunCtrl::default()).collect();
    (meta, ctrls)
}

/// Shared dispatch tail for both race forms.
fn dispatch_race(
    n_runs: usize,
    ctrls: &[RunCtrl],
    meta: &[(usize, Strategy)],
    spec: &RaceSpec,
    run_batch: impl FnOnce(&TreeCvExecutor, &OnResult<'_>) -> Vec<RunOutcome>,
) -> Result<RaceOutcome> {
    let timer = Timer::start();
    let engine = TreeCvExecutor::with_threads_knob(
        spec.sweep.strategies[0],
        spec.sweep.ordering,
        spec.sweep.threads,
    );
    let threads_used = engine.threads.min(n_runs * spec.sweep.k);
    let boundaries = round_boundaries(spec.sweep.repetitions, spec.rounds);
    let controller = Controller::new(ctrls, meta, &boundaries, spec.sweep.repetitions, spec.alpha);
    let record = |i: usize, out: &RunOutcome| controller.record(i, out);
    let outcomes = run_batch(&engine, &record);
    controller.finish(outcomes, timer.elapsed(), threads_used, engine.pool_spawns())
}

/// Race a single-family grid: same batch construction (folds, seeds,
/// canonical run order) as [`super::sweep::run_sweep`], dispatched
/// through the executor's cancellation layer with the sequential
/// elimination test deciding at each round boundary.
pub fn run_race<L>(learners: &[L], data: &Dataset, spec: &RaceSpec) -> Result<RaceOutcome>
where
    L: IncrementalLearner + Sync,
    L::Model: Send,
{
    validate(learners.len(), data, &spec.sweep)?;
    validate_race(spec)?;
    let folds = repetition_folds(data.n, &spec.sweep);
    let (meta, ctrls) = cell_axes(learners.len(), &spec.sweep);
    let reps = spec.sweep.repetitions;
    let mut idx = 0;
    let runs = build_runs(learners.len(), &spec.sweep, &folds, |c, folds, seed, strategy| {
        let ctrl = ctrls[idx / reps].clone();
        idx += 1;
        RunSpec { learner: &learners[c], folds, seed, strategy, folded: None, ctrl }
    });
    dispatch_race(runs.len(), &ctrls, &meta, spec, |engine, record| {
        engine.run_many_outcomes(data, &runs, Some(record))
    })
}

/// Race a **heterogeneous** learner axis (the model-selection workload):
/// the erased counterpart of [`run_race`], batch-constructed exactly as
/// [`super::sweep::run_sweep_erased`].
pub fn run_race_erased(
    learners: &[&dyn ErasedLearner],
    data: &Dataset,
    spec: &RaceSpec,
) -> Result<RaceOutcome> {
    validate(learners.len(), data, &spec.sweep)?;
    validate_race(spec)?;
    let folds = repetition_folds(data.n, &spec.sweep);
    let (meta, ctrls) = cell_axes(learners.len(), &spec.sweep);
    let reps = spec.sweep.repetitions;
    let mut idx = 0;
    let runs = build_runs(learners.len(), &spec.sweep, &folds, |c, folds, seed, strategy| {
        let ctrl = ctrls[idx / reps].clone();
        idx += 1;
        ErasedRunSpec { learner: learners[c], folds, seed, strategy, folded: None, ctrl }
    });
    dispatch_race(runs.len(), &ctrls, &meta, spec, |engine, record| {
        engine.run_many_erased_outcomes(data, &runs, Some(record))
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cv::folds::Ordering;
    use crate::cv::sweep::run_sweep;
    use crate::data::synth::SyntheticMixture1d;
    use crate::learner::histdensity::HistogramDensity;

    fn race_spec(threads: usize, rounds: usize, alpha: f64) -> RaceSpec {
        RaceSpec {
            sweep: SweepSpec {
                ordering: Ordering::Fixed,
                strategies: vec![Strategy::Copy],
                k: 6,
                repetitions: 8,
                seed: 21,
                threads,
            },
            rounds,
            alpha,
        }
    }

    /// A grid with one clearly dominated config: far too few histogram
    /// bins loses on (essentially) every partitioning.
    fn graded_learners() -> Vec<HistogramDensity> {
        vec![
            HistogramDensity::new(-8.0, 8.0, 64),
            HistogramDensity::new(-8.0, 8.0, 48),
            HistogramDensity::new(-8.0, 8.0, 2),
        ]
    }

    #[test]
    fn boundaries_shape() {
        assert_eq!(round_boundaries(8, 4), vec![2, 4, 6, 8]);
        assert_eq!(round_boundaries(8, 1), vec![8]);
        assert_eq!(round_boundaries(3, 4), vec![1, 2, 3]);
        assert_eq!(round_boundaries(20, 3), vec![7, 14, 20]);
        assert_eq!(round_boundaries(1, 5), vec![1]);
    }

    #[test]
    fn sign_test_exact_values() {
        // n = 4: P(W ≥ 4) = 1/16, P(W ≥ 3) = 5/16, P(W ≥ 0) = 1.
        assert_eq!(sign_test_p(4, 4), 1.0 / 16.0);
        assert_eq!(sign_test_p(3, 4), 5.0 / 16.0);
        assert_eq!(sign_test_p(0, 4), 1.0);
        assert_eq!(sign_test_p(0, 0), 1.0);
        // p is always strictly positive, so alpha = 0 never eliminates.
        assert!(sign_test_p(6, 6) > 0.0);
    }

    #[test]
    fn alpha_zero_reproduces_exhaustive_sweep_bitwise() {
        let data = SyntheticMixture1d::new(260, 150).generate();
        let learners = graded_learners();
        let spec = race_spec(3, 4, 0.0);
        let race = run_race(&learners, &data, &spec).unwrap();
        let sweep = run_sweep(&learners, &data, &spec.sweep).unwrap();
        assert_eq!(race.cells.len(), sweep.cells.len());
        assert_eq!(race.runs_cancelled, 0);
        assert_eq!(race.runs_completed, race.runs_scheduled);
        for (rc, sc) in race.cells.iter().zip(&sweep.cells) {
            assert_eq!(rc.eliminated_round, None);
            assert_eq!(rc.reps_used, 8);
            assert_eq!(rc.mean.to_bits(), sc.mean.to_bits());
            assert_eq!(rc.std.to_bits(), sc.std.to_bits());
            for (a, b) in rc.runs.iter().zip(&sc.runs) {
                assert_eq!(a.per_fold, b.per_fold);
                assert_eq!(a.ops.points_updated, b.ops.points_updated);
            }
        }
    }

    #[test]
    fn dominated_config_is_eliminated_and_trace_is_deterministic() {
        let data = SyntheticMixture1d::new(260, 151).generate();
        let learners = graded_learners();
        // alpha = 0.3 > 1/4 = P(W ≥ 2 | n = 2): a clean sweep of the
        // first boundary's 2 repetitions is already significant.
        let spec = race_spec(1, 4, 0.3);
        let a = run_race(&learners, &data, &spec).unwrap();
        assert_eq!(
            a.cells[2].eliminated_round,
            Some(0),
            "dominated config must fall at the first boundary: {:?}",
            a.trace.rows
        );
        assert_eq!(a.cells[2].reps_used, 2);
        assert!(a.cells[0].eliminated_round.is_none() || a.cells[1].eliminated_round.is_none());
        // threads = 1 admits cells in canonical order, so by the time the
        // last cell's prefix triggers round 0 the others already finished
        // all 8 repetitions — the loser's remaining 6 runs are cancelled.
        assert_eq!(a.runs_cancelled, 6);
        assert!(a.tasks_cancelled > 0);
        // Same seed ⇒ identical trace, whatever the worker count.
        for threads in [2usize, 8] {
            let b = run_race(&learners, &data, &race_spec(threads, 4, 0.3)).unwrap();
            assert_eq!(a.trace, b.trace, "threads={threads}");
        }
        let c = run_race(&learners, &data, &spec).unwrap();
        assert_eq!(a.trace, c.trace, "re-run");
        // Eliminated aggregates count exactly the decision prefix.
        for res in &a.cells[2].runs {
            assert!(res.estimate.is_finite());
        }
        assert_eq!(a.cells[2].runs.len(), 2);
    }

    #[test]
    fn single_cell_race_never_eliminates() {
        let data = SyntheticMixture1d::new(120, 152).generate();
        let learners = vec![HistogramDensity::new(-8.0, 8.0, 16)];
        let out = run_race(&learners, &data, &race_spec(2, 3, 0.5)).unwrap();
        assert_eq!(out.cells.len(), 1);
        assert_eq!(out.cells[0].eliminated_round, None);
        assert_eq!(out.runs_cancelled, 0);
        // One trace row per round, all incumbent rows.
        assert!(out.trace.rows.iter().all(|r| r.p_value == 1.0 && !r.eliminated));
        assert_eq!(out.trace.rows.len(), out.trace.boundaries.len());
    }

    #[test]
    fn rejects_bad_racing_knobs() {
        let data = SyntheticMixture1d::new(60, 153).generate();
        let learners = vec![HistogramDensity::new(-8.0, 8.0, 16)];
        let mut spec = race_spec(1, 0, 0.05);
        assert!(run_race(&learners, &data, &spec).is_err());
        spec.rounds = 2;
        spec.alpha = -0.1;
        assert!(run_race(&learners, &data, &spec).is_err());
        spec.alpha = 1.5;
        assert!(run_race(&learners, &data, &spec).is_err());
        spec.alpha = f64::NAN;
        assert!(run_race(&learners, &data, &spec).is_err());
    }
}
