//! Pooled work-stealing TreeCV executor — the engine behind every parallel
//! code path in the crate, now aware of both §4.1 model-preservation
//! strategies.
//!
//! The paper's §4.1 parallelization ("dedicate one thread of computation to
//! each of the data groups") was first implemented by spawning a fresh
//! scoped OS thread at every tree fork (see
//! [`super::parallel::ScopedForkTreeCv`], retained as a baseline). That
//! design churns threads, oversubscribes non-power-of-two machines, and
//! idles once subtrees go unbalanced (which happens whenever `k ∤ n`
//! produces remainder folds). This module replaces it with a persistent
//! executor:
//!
//! * **One worker pool per computation**, sized from
//!   `available_parallelism` (or an explicit `threads` knob) — workers are
//!   spawned once and live for the whole computation, which may be a
//!   single run ([`TreeCvExecutor::run`]) or a whole batch of runs
//!   ([`TreeCvExecutor::run_many`]).
//! * **Tasks are subtrees, not nodes.** Only the nodes above the *snapshot
//!   cutoff* ([`snapshot_cutoff`], ~⌈log₂ workers⌉ + slack levels — the
//!   nodes that actually feed the deques) are forked into independent
//!   tasks; a fork materializes one model snapshot because its two halves
//!   may run concurrently on different workers. Every subtree at or below
//!   the cutoff runs *inline on its worker* through the shared sequential
//!   recursion (`treecv::run_subtree`) with the caller's chosen
//!   [`Strategy`]:
//!   - [`Strategy::SaveRevert`] descends via `update_logged`/`revert` with
//!     **zero** copies below the cutoff, so a run takes `O(workers)` model
//!     snapshots instead of the `k − 1` a Copy run pays — decisive for
//!     LOOCV and for large models (ridge's d² sufficient statistics, KNN's
//!     training-set model), exactly the regime the paper recommends
//!     save/revert for.
//!   - [`Strategy::Copy`] clones at every interior node as before; the
//!     fork/inline split leaves its `k − 1` copy count unchanged.
//! * **Per-worker work-stealing deques.** Owners push/pop LIFO (depth-first
//!   — keeps the live-model count near `O(log k · workers)`); thieves steal
//!   FIFO (breadth-first — steals the largest available subtree, the
//!   classic Blumofe–Leiserson discipline). The cutoff still yields
//!   `~2^slack · workers` independent subtrees, so unbalanced remainders
//!   rebalance instead of leaving a thread idle.
//! * **Model buffer recycling at both granularities.** Fork-node
//!   snapshots draw buffers from a shared pool and `clone_from` into
//!   them; finished subtrees return their (restored) model buffer.
//!   Retention is capped at ~`workers · cutoff` buffers — the fork
//!   levels' steady-state demand, much shallower than the old
//!   `workers · log₂ k` now that deep levels never feed the deques.
//!   Below the cutoff, Copy-strategy snapshots recycle through a
//!   *worker-local* scratch free-list threaded into the shared recursion
//!   (no locking on the hot path), so a Copy run still allocates
//!   O(depth) models per worker, not one per interior node.
//!
//! Because permutation streams are derived per-node from `(seed, node,
//! side)` — never drawn from one sequential stream — the executor produces
//! **bit-identical** estimates to the sequential [`super::treecv::TreeCv`]
//! for the same seed and strategy, under both orderings, for any worker
//! count, whenever the learner's revert is exact (always, under Copy).
//! Learners with approximate revert (the f32 perceptron) are reproduced
//! bit-for-bit at `threads = 1` and to ulp-cascade tolerance above, since
//! forks snapshot where the sequential engine would revert. The tests
//! below and `tests/integration_executor.rs` assert exactly that.
//!
//! **Multi-run batches.** [`TreeCvExecutor::run_many`] feeds the tree
//! tasks of *many* independent runs — every (hyperparameter config ×
//! repetition) of a sweep, each tagged with its `run_id` — through ONE
//! pool: no per-run spawn/teardown, no barrier between runs, and the
//! fork-snapshot buffer pool plus the worker-local scratch free-lists stay
//! warm across runs. Each run keeps its own `(folds, seed, strategy,
//! cutoff)`, so every result is bit-identical to running that
//! configuration alone (`tests/integration_sweep.rs` is the battery). The
//! per-pool [`TreeCvExecutor::pool_spawns`] instrumentation counter lets
//! callers assert the "one pool per batch" claim without serializing
//! against unrelated executors in the process.
//!
//! **Heterogeneous batches.** [`TreeCvExecutor::run_many_erased`] is the
//! same multiplexer over *type-erased* learners
//! ([`crate::learner::erased`]): one batch may mix learner families
//! (Pegasos runs next to GaussianNb next to KnnClassifier), which is what
//! the model-selection harness (`cv::sweep::run_sweep_erased`,
//! `repro select`) schedules. It delegates to [`TreeCvExecutor::run_many`]
//! through [`DynLearner`], so erased runs execute the identical engine
//! code and reproduce their generic counterparts bit for bit
//! (`tests/integration_erased.rs`).
//!
//! **Fold-contiguous layout.** A run whose spec carries a
//! [`FoldedDataset`] ([`RunSpec::folded`]; built once per run from the
//! batch dataset) draws its node streams from contiguous row slices:
//! fixed-order updates and leaf evaluations go through the learners'
//! `update_rows`/`evaluate_rows` fast paths with **zero** per-node
//! index-vector allocations, and randomized updates shuffle ids in
//! recycled worker-local buffers. Results are bit-identical to the
//! indexed path per run (`tests/integration_layout.rs`), and folded and
//! indexed runs mix freely in one batch.
//!
//! **Idle waiting.** A worker whose steal sweep comes up dry parks its
//! thread (`crate::sync::thread::park`) after registering on a sleeper
//! list;
//! task pushes unpark one sleeper and batch completion (or a panic)
//! unparks all. Compared to the earlier yield-then-100µs-sleep backoff,
//! idle workers burn zero CPU during long serial phases (e.g. a root
//! node's O(n) updates) and wake in microseconds when work appears.
//!
//! **Cancellation, priorities and incremental delivery.** Every spec
//! carries a [`RunCtrl`] — a shared [`CancelToken`] plus an integer
//! priority. The cancellation contract:
//!
//! * Workers check the token when they pop one of the run's tasks and
//!   again at fork points (after the two update phases, before the
//!   children are queued). A cancelled task's whole subtree is dropped:
//!   its leaves are accounted as *dropped* (so batch termination still
//!   fires), and its model buffer returns to the shared snapshot pool
//!   under the same retention cap as a completed subtree — the pool stays
//!   warm and bounded, and the executor handle stays reusable for
//!   subsequent batches.
//! * Root tasks start in a shared *injector* rather than the deques;
//!   an idle worker whose sweep (own deque, then steals) comes up dry
//!   pops the injector entry whose run has the highest current priority
//!   ([`RunCtrl::priority`]; FIFO among equals). Priorities order who
//!   *starts* next — they never affect a run's result, which stays a pure
//!   function of `(learner, data, folds, strategy, ordering, seed)`.
//! * [`TreeCvExecutor::run_many_outcomes`] reports each run as a
//!   [`RunOutcome`]: `Completed` carries the usual [`CvResult`],
//!   `Cancelled` is a distinct status with drop accounting (never a bogus
//!   zero-filled result), `Failed` captures a panicking run (the panic is
//!   caught per task; sibling runs keep going unless the caller cancels
//!   them). An optional `on_result` callback delivers each run's outcome
//!   the moment its last leaf lands — racing schedulers
//!   ([`super::race`]) eliminate losers mid-batch from that callback.
//!   [`TreeCvExecutor::run_many`] is the strict facade: it cancels every
//!   sibling on the first failure and panics with the original message,
//!   preserving the historical all-or-nothing contract.

use super::folds::{node_tags, Folds, Ordering};
use super::treecv::{run_subtree, NodeCtx, StreamScratch};
use super::{CvResult, Strategy};
use crate::data::folded::FoldedDataset;
use crate::data::Dataset;
use crate::learner::erased::{DynLearner, ErasedLearner};
use crate::learner::IncrementalLearner;
use crate::metrics::{OpCounts, Timer};
use crate::sync::thread::{self, Thread};
use crate::sync::{
    Arc, AtomicBool, AtomicI64, AtomicU64, AtomicUsize, Mutex, Ordering as MemOrdering,
};
use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::time::Duration;

/// Extra fork levels beyond ⌈log₂ workers⌉: each level doubles the subtree
/// count, so slack 2 yields ~4 independent subtrees per worker — enough
/// over-decomposition for stealing to absorb remainder-fold imbalance,
/// while keeping the per-run snapshot count at `O(workers)`.
const SNAPSHOT_SLACK: usize = 2;

/// First tree depth that is NOT forked into independent tasks: nodes at
/// depth `< snapshot_cutoff(threads)` fork (one model snapshot each, at
/// most `2^cutoff − 1` per run); subtrees rooted at the cutoff run inline
/// on their worker with the engine's strategy. `threads <= 1` forks
/// nothing — the whole tree runs inline, exactly the sequential engine.
pub fn snapshot_cutoff(threads: usize) -> usize {
    if threads <= 1 {
        return 0;
    }
    // ⌈log₂ threads⌉ for threads ≥ 2.
    let ceil_log2 = (usize::BITS - (threads - 1).leading_zeros()) as usize;
    ceil_log2 + SNAPSHOT_SLACK
}

/// Shared cancellation flag for one run (cheaply clonable; all clones
/// observe the same flag). Cancelling is a request, not an interrupt:
/// in-flight node updates finish, but no further task of the run starts
/// and no further child is queued. Cancelling a run whose last leaf
/// already landed is a harmless no-op — the run still reports
/// [`RunOutcome::Completed`].
#[derive(Clone, Debug, Default)]
pub struct CancelToken(Arc<AtomicBool>);

impl CancelToken {
    pub fn new() -> Self {
        Self::default()
    }

    /// Request cancellation (idempotent).
    pub fn cancel(&self) {
        self.0.store(true, MemOrdering::Release);
    }

    pub fn is_cancelled(&self) -> bool {
        self.0.load(MemOrdering::Acquire)
    }
}

/// Per-run scheduling controls: a [`CancelToken`] plus an integer
/// priority. Clones share state with the original, so a caller holding a
/// clone can cancel or re-prioritize the run while the batch executes.
///
/// The priority is read *live* each time an idle worker picks its next
/// root task from the injector (higher starts first; FIFO among equals),
/// so raising a survivor's priority mid-batch moves its queued runs ahead
/// of lower-priority work. Neither knob ever changes a non-cancelled
/// run's result — only when it runs and whether it finishes.
#[derive(Clone, Debug, Default)]
pub struct RunCtrl {
    cancel: CancelToken,
    priority: Arc<AtomicI64>,
}

impl RunCtrl {
    pub fn new() -> Self {
        Self::default()
    }

    /// A control block with an initial priority (default is 0).
    pub fn with_priority(priority: i64) -> Self {
        let ctrl = Self::default();
        ctrl.set_priority(priority);
        ctrl
    }

    /// The shared cancellation token (clone it to hand out).
    pub fn token(&self) -> &CancelToken {
        &self.cancel
    }

    /// Request cancellation of the run (idempotent).
    pub fn cancel(&self) {
        self.cancel.cancel();
    }

    pub fn is_cancelled(&self) -> bool {
        self.cancel.is_cancelled()
    }

    pub fn priority(&self) -> i64 {
        self.priority.load(MemOrdering::Relaxed)
    }

    pub fn set_priority(&self, priority: i64) {
        self.priority.store(priority, MemOrdering::Relaxed);
    }
}

/// Terminal status of one batched run
/// ([`TreeCvExecutor::run_many_outcomes`]).
#[derive(Debug, Clone)]
pub enum RunOutcome {
    /// Every leaf completed; the result is bit-identical to the same spec
    /// in a batch with no cancellations.
    Completed(CvResult),
    /// The run's token was cancelled before its last leaf landed. No
    /// `CvResult` is fabricated from the partial per-fold buffer — a
    /// cancelled run has a *status*, not an estimate.
    Cancelled {
        /// Leaves that completed before the cancellation took effect.
        leaves_done: usize,
        /// Leaves dropped without being evaluated.
        leaves_dropped: usize,
        /// Queued tree tasks dropped (at pop or at a fork point).
        tasks_dropped: usize,
    },
    /// A task of this run panicked; the payload message is captured and
    /// the rest of the run is implicitly cancelled. Sibling runs are NOT
    /// affected unless the caller cancels them (as
    /// [`TreeCvExecutor::run_many`] does).
    Failed { error: String },
}

impl RunOutcome {
    /// The completed result, if any.
    pub fn completed(&self) -> Option<&CvResult> {
        match self {
            RunOutcome::Completed(res) => Some(res),
            _ => None,
        }
    }

    pub fn is_cancelled(&self) -> bool {
        matches!(self, RunOutcome::Cancelled { .. })
    }
}

/// The pooled work-stealing TreeCV engine.
#[derive(Debug, Clone)]
pub struct TreeCvExecutor {
    /// Model-preservation strategy (paper §4.1): applied verbatim inside
    /// every inline subtree; fork nodes above the cutoff always snapshot
    /// (their halves run concurrently), which is the only place a
    /// SaveRevert run still copies.
    pub strategy: Strategy,
    /// Fixed vs randomized feeding order (paper §5).
    pub ordering: Ordering,
    /// Seed for the per-node permutation streams (ignored under Fixed).
    pub seed: u64,
    /// Worker-pool size. `1` runs the whole tree inline on the calling
    /// thread (no spawning, no forking — the sequential engine exactly);
    /// capped at `k` per run.
    pub threads: usize,
    /// Per-pool spawn counter: bumped once per [`Self::run_many`] batch
    /// that actually spawns worker threads (inline single-worker batches
    /// spawn nothing). Shared by clones of this executor — the handle IS
    /// the counter — and read via [`Self::pool_spawns`]. Replaces the old
    /// process-wide counter, so concurrent executors (e.g. parallel unit
    /// tests) no longer perturb each other's accounting.
    spawns: Arc<AtomicU64>,
}

/// One run of a multi-run batch ([`TreeCvExecutor::run_many`]): the full
/// TreeCV computation of `learner` under `folds`, with its own
/// permutation-stream seed and model-preservation strategy. A run's
/// result is a pure function of `(learner, data, folds, strategy,
/// ordering, seed)` — never of pool size or scheduling — so batching runs
/// through a shared pool reproduces each standalone run bit for bit.
pub struct RunSpec<'a, L: IncrementalLearner> {
    pub learner: &'a L,
    pub folds: &'a Folds,
    /// Seed for this run's per-node permutation streams.
    pub seed: u64,
    /// Model-preservation strategy for this run's inline subtrees.
    pub strategy: Strategy,
    /// Fold-contiguous layout of the batch dataset realizing exactly
    /// `folds` (asserted at dispatch). When present, this run's node
    /// streams are contiguous slice feeds / recycled scratch shuffles
    /// instead of per-node gathered index vectors — bit-identical results
    /// either way. `None` keeps the classic indexed path.
    pub folded: Option<&'a FoldedDataset>,
    /// Scheduling controls (cancellation + priority). The default is a
    /// fresh never-cancelled token at priority 0; callers that want to
    /// steer the run keep a clone.
    pub ctrl: RunCtrl,
}

/// [`RunSpec`] over the type-erased learner layer: the element of a
/// *heterogeneous* batch ([`TreeCvExecutor::run_many_erased`]), where each
/// run may belong to a different learner family. Same per-run contract as
/// the generic spec: the result is a pure function of
/// `(learner, data, folds, strategy, ordering, seed)`.
pub struct ErasedRunSpec<'a> {
    pub learner: &'a dyn ErasedLearner,
    pub folds: &'a Folds,
    /// Seed for this run's per-node permutation streams.
    pub seed: u64,
    /// Model-preservation strategy for this run's inline subtrees.
    pub strategy: Strategy,
    /// Fold-contiguous layout (see [`RunSpec::folded`]); forwarded
    /// through the erased adapter unchanged.
    pub folded: Option<&'a FoldedDataset>,
    /// Scheduling controls (see [`RunSpec::ctrl`]); forwarded through the
    /// erased adapter unchanged.
    pub ctrl: RunCtrl,
}

/// One unit of executor work. Under [`RunMode::Tree`]: the TreeCV subtree
/// of run `run` rooted at `(s, e)` plus the model trained on every chunk
/// outside `s..=e`; `depth` decides whether the node forks (above the
/// run's snapshot cutoff) or runs inline. Under [`RunMode::Approx`]: the
/// fold range `s..=e` to correct-and-evaluate, carrying the *full-data*
/// model (`depth` 0 marks the training root, ≥ 1 a fold-range task).
/// Root tasks carry `None` and init their model lazily on the worker
/// that pops them — a batch of R runs would otherwise materialize R full
/// models up front (ruinous for training-set-sized models like k-NN's on
/// a wide sweep).
struct Task<M> {
    run: usize,
    s: usize,
    e: usize,
    depth: usize,
    model: Option<M>,
}

/// Which per-task algorithm a batch's workers execute: the exact TreeCV
/// recursion, or the approximate-CV one-step-correction sweep
/// ([`TreeCvExecutor::run_many_approx`]). Batches are mode-homogeneous —
/// the mode lives on the batch's [`Shared`] state, so [`RunSpec`] is
/// unchanged and exact and approx batches share every other line of the
/// scheduling machinery (deques, injector, cancellation, accounting).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum RunMode {
    Tree,
    Approx,
}

/// Node-stream tag for the approx engine's single full-data training
/// phase. Outside [`super::folds::node_tags`]'s image (tags there encode
/// `(s, e)` in the low 2·33 bits with `s ≤ e < 2³²`, so all-ones is
/// unreachable), keeping approx randomized streams disjoint from any
/// exact tree node's.
const APPROX_FULL_TAG: u64 = u64::MAX;

/// Per-run shared state: the run's inputs plus its output slots.
struct RunShared<'a, L: IncrementalLearner> {
    learner: &'a L,
    folds: &'a Folds,
    folded: Option<&'a FoldedDataset>,
    seed: u64,
    strategy: Strategy,
    /// First non-forking depth for THIS run, computed from the engine's
    /// `threads` knob and the run's own k exactly as a standalone
    /// [`TreeCvExecutor::run`] computes it — that is what keeps every
    /// batched run bit-identical to its standalone counterpart.
    cutoff: usize,
    /// Leaf count (`folds.k()`).
    k: usize,
    /// Per-fold outputs; distinct indices are written exactly once each.
    per_fold: Mutex<Vec<f64>>,
    /// Scheduling controls (shared with the caller's spec clone).
    ctrl: RunCtrl,
    /// Leaves of this run evaluated and recorded so far.
    leaves_done: AtomicUsize,
    /// Leaves dropped by cancellation (or a task panic) — never evaluated.
    leaves_dropped: AtomicUsize,
    /// Queued tree tasks dropped by cancellation.
    tasks_dropped: AtomicUsize,
    /// Completed + dropped leaves: the run finishes — exactly once, on
    /// whichever worker accounts the k-th leaf — when this reaches `k`.
    leaves_acct: AtomicUsize,
    /// First captured panic message of this run's tasks, if any.
    failed: Mutex<Option<String>>,
    /// The run's terminal status, written by the finishing worker.
    outcome: Mutex<Option<RunOutcome>>,
    /// Work counters, merged per task BEFORE the task's leaves are
    /// accounted — so the finishing worker always reads complete totals.
    ops: Mutex<OpCounts>,
    /// Elapsed time from batch start when the run's last leaf landed.
    wall: Mutex<Duration>,
}

/// State shared by the worker pool for one batch of runs.
struct Shared<'a, L: IncrementalLearner> {
    /// One deque per worker. Owner pushes/pops the back; thieves pop the
    /// front. A plain mutexed deque keeps the implementation obviously
    /// correct; contention is negligible at subtree granularity.
    deques: Vec<Mutex<VecDeque<Task<L::Model>>>>,
    /// Root tasks awaiting their first pop, as `(admission seq, task)`.
    /// An idle worker whose deque sweep comes up dry pops the entry whose
    /// run has the highest *current* priority (FIFO among equals) — so
    /// in-flight subtrees drain before new runs start, and priorities
    /// steer who starts next. Filled once before the workers start.
    injector: Mutex<Vec<(u64, Task<L::Model>)>>,
    /// Recycled model buffers (`clone_from` targets for fork-node
    /// snapshots), shared by every run in the batch — later runs start
    /// with a warm pool. Retention is capped at [`Shared::pool_cap`] so
    /// LOOCV-scale batches don't accumulate dead buffers.
    pool: Mutex<Vec<L::Model>>,
    /// Maximum buffers the pool retains (~ workers · max cutoff, the fork
    /// levels' steady-state demand, doubled when several runs are in
    /// flight); excess buffers are dropped instead.
    pool_cap: usize,
    /// Per-task algorithm for this batch (exact tree vs approx
    /// correction); see [`RunMode`].
    mode: RunMode,
    /// The batch's runs, indexed by [`Task::run`].
    runs: Vec<RunShared<'a, L>>,
    /// Total leaf count across all runs.
    leaves_total: usize,
    /// Leaves accounted (completed or dropped) so far across all runs —
    /// the batch terminates when this reaches `leaves_total`.
    leaves_done: AtomicUsize,
    /// Set when all leaves are done (or a worker panicked) so idle workers
    /// exit their steal loop.
    done: AtomicBool,
    /// Idle workers parked waiting for work: `(worker id, thread handle)`.
    /// A worker registers itself here *before* its final verification
    /// sweep and then `park()`s; producers pop-and-unpark one entry per
    /// task push ([`wake_one`]), and batch completion / panic unparks
    /// everyone ([`wake_all`]). Replaces the old 100µs-sleep idle backoff:
    /// parked workers burn zero CPU and wake in ~µs instead of up to a
    /// sleep quantum.
    parked: Mutex<Vec<(usize, Thread)>>,
    /// Batch clock (per-run completion times are read off it).
    timer: Timer,
}

/// Pop one parked worker (if any) and unpark it — called after making new
/// work visible in a deque. Unparking a worker that raced back to running
/// merely sets its park token (its next `park()` returns immediately and
/// re-sweeps), so a stale entry can delay a wakeup but never lose one:
/// tasks are only ever consumed by sweeps, not by notifications.
fn wake_one(parked: &Mutex<Vec<(usize, Thread)>>) {
    let popped = parked.lock().pop();
    if let Some((_, t)) = popped {
        t.unpark();
    }
}

/// Unpark every parked worker (batch done, or a worker panicked).
fn wake_all(parked: &Mutex<Vec<(usize, Thread)>>) {
    let drained: Vec<_> = std::mem::take(&mut *parked.lock());
    for (_, t) in drained {
        t.unpark();
    }
}

/// Remove `wid`'s registration (idempotent — the producer that woke us may
/// already have popped it).
fn unregister(parked: &Mutex<Vec<(usize, Thread)>>, wid: usize) {
    parked.lock().retain(|(w, _)| *w != wid);
}

/// Incremental-delivery callback: called with `(run index, outcome)` on
/// the worker thread that accounts a run's last leaf, before the batch
/// returns. Must not panic.
pub type OnResult<'cb> = dyn Fn(usize, &RunOutcome) + Sync + 'cb;

/// Return a model buffer to the shared snapshot pool (bounded — beyond
/// the cap, just drop it). Cancelled subtrees recycle through here too,
/// so cancellation never grows the pool past its cap.
fn recycle<L: IncrementalLearner>(shared: &Shared<'_, L>, model: L::Model) {
    let mut pool = shared.pool.lock();
    if pool.len() < shared.pool_cap {
        pool.push(model);
    }
}

/// Best-effort extraction of a panic payload's message.
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "task panicked (non-string payload)".to_string()
    }
}

/// Account `leaves` of run `run` as completed or dropped. Whichever
/// worker's call brings the run's accounted total to `k` finishes the
/// run: it stamps the wall clock, builds the terminal [`RunOutcome`],
/// fires the incremental-delivery callback, and publishes the outcome.
/// Then the batch-wide tally is bumped; the call that completes it flips
/// `done` and wakes every parked worker.
fn account<L: IncrementalLearner>(
    shared: &Shared<'_, L>,
    run: usize,
    leaves: usize,
    dropped: bool,
    on_result: Option<&OnResult<'_>>,
) {
    let rs = &shared.runs[run];
    if dropped {
        rs.leaves_dropped.fetch_add(leaves, MemOrdering::AcqRel);
    } else {
        rs.leaves_done.fetch_add(leaves, MemOrdering::AcqRel);
    }
    if rs.leaves_acct.fetch_add(leaves, MemOrdering::AcqRel) + leaves == rs.k {
        let outcome = finish_run(rs, shared.timer.elapsed());
        if let Some(cb) = on_result {
            cb(run, &outcome);
        }
        *rs.outcome.lock() = Some(outcome);
    }
    let done_before = shared.leaves_done.fetch_add(leaves, MemOrdering::AcqRel);
    if done_before + leaves == shared.leaves_total {
        shared.done.store(true, MemOrdering::Release);
        wake_all(&shared.parked);
    }
}

/// Build run `rs`'s terminal status once its last leaf is accounted.
/// Failure wins over cancellation; a run whose every leaf completed
/// before its token landed is `Completed` (cancellation came too late to
/// save any work, and the result is valid).
fn finish_run<L: IncrementalLearner>(rs: &RunShared<'_, L>, wall: Duration) -> RunOutcome {
    *rs.wall.lock() = wall;
    if let Some(error) = rs.failed.lock().take() {
        return RunOutcome::Failed { error };
    }
    let leaves_dropped = rs.leaves_dropped.load(MemOrdering::Acquire);
    if leaves_dropped > 0 {
        return RunOutcome::Cancelled {
            leaves_done: rs.leaves_done.load(MemOrdering::Acquire),
            leaves_dropped,
            tasks_dropped: rs.tasks_dropped.load(MemOrdering::Acquire),
        };
    }
    let per_fold = std::mem::take(&mut *rs.per_fold.lock());
    let ops = std::mem::take(&mut *rs.ops.lock());
    RunOutcome::Completed(CvResult::from_folds(per_fold, ops, wall))
}

/// A task of run `run` panicked: record the message (first wins), cancel
/// the rest of the run's tree, and account the task's whole leaf range as
/// dropped so the batch still terminates.
fn fail_run<L: IncrementalLearner>(
    shared: &Shared<'_, L>,
    run: usize,
    leaves: usize,
    payload: Box<dyn std::any::Any + Send>,
    on_result: Option<&OnResult<'_>>,
) {
    let rs = &shared.runs[run];
    rs.failed.lock().get_or_insert(panic_message(&*payload));
    rs.ctrl.cancel();
    account(shared, run, leaves, true, on_result);
}

/// Sets the shared `done` flag and wakes all parked workers if its thread
/// unwinds, so a panicking worker cannot leave the rest of the pool
/// spinning — or sleeping — forever.
struct PanicSignal<'a> {
    done: &'a AtomicBool,
    parked: &'a Mutex<Vec<(usize, Thread)>>,
}

impl Drop for PanicSignal<'_> {
    fn drop(&mut self) {
        if thread::panicking() {
            self.done.store(true, MemOrdering::Release);
            wake_all(self.parked);
        }
    }
}

impl TreeCvExecutor {
    pub fn new(strategy: Strategy, ordering: Ordering, seed: u64, threads: usize) -> Self {
        Self {
            strategy,
            ordering,
            seed,
            threads: threads.max(1),
            spawns: Arc::new(AtomicU64::new(0)),
        }
    }

    /// Worker pools this executor (and its clones — they share the
    /// counter) has spawned so far: one per multi-worker [`Self::run_many`]
    /// batch, zero for inline (`threads = 1`) batches. A whole sweep of
    /// C configs × r repetitions reads 1 here, where dispatching the runs
    /// one batch at a time reads C·r — the sweep tests assert both.
    pub fn pool_spawns(&self) -> u64 {
        self.spawns.load(MemOrdering::Relaxed)
    }

    /// Pool sized to the machine's available parallelism (no rounding to a
    /// power of two — any worker count schedules fully).
    pub fn with_available_parallelism(strategy: Strategy, ordering: Ordering, seed: u64) -> Self {
        let threads = thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
        Self::new(strategy, ordering, seed, threads)
    }

    /// Resolve a user-facing `threads` knob (`0` = machine parallelism)
    /// into a pool — the single resolution every harness (repetition,
    /// repeated CV, sweep) routes through, so the knob is honored
    /// identically everywhere and never silently ignored. The engine seed
    /// is left at 0: the batching harnesses pass per-run seeds via
    /// [`RunSpec`], which [`Self::run_many`] uses instead.
    pub fn with_threads_knob(strategy: Strategy, ordering: Ordering, threads: usize) -> Self {
        if threads == 0 {
            Self::with_available_parallelism(strategy, ordering, 0)
        } else {
            Self::new(strategy, ordering, 0, threads)
        }
    }

    /// Process one task: fork nodes above the run's cutoff run both update
    /// phases (one snapshot) and enqueue the two child subtrees on this
    /// worker's own deque; everything else — leaves and whole subtrees at
    /// or below the cutoff — runs inline through the shared sequential
    /// recursion with the run's strategy.
    ///
    /// Cancellation is checked twice: at pop (drop the whole subtree) and
    /// at the fork point after the update phases, before the children
    /// become visible (drop both halves). Either way the task's leaf
    /// range is accounted as dropped and its model buffers recycle. A
    /// panic inside the learner work is caught here, recorded on the run,
    /// and converted into an implicit cancellation of the rest of its
    /// tree — sibling runs keep executing.
    fn process<L>(
        &self,
        wid: usize,
        task: Task<L::Model>,
        shared: &Shared<'_, L>,
        data: &Dataset,
        scratch: &mut Vec<L::Model>,
        streams: &mut StreamScratch,
        on_result: Option<&OnResult<'_>>,
    ) where
        L: IncrementalLearner + Sync,
    {
        if shared.mode == RunMode::Approx {
            self.process_approx(wid, task, shared, data, scratch, streams, on_result);
            return;
        }
        let Task { run, s, e, depth, model } = task;
        let rs = &shared.runs[run];
        let leaves = e - s + 1;
        if rs.ctrl.is_cancelled() {
            if let Some(m) = model {
                recycle(shared, m);
            }
            rs.tasks_dropped.fetch_add(1, MemOrdering::AcqRel);
            account(shared, run, leaves, true, on_result);
            return;
        }
        // The run's node-stream context (all borrows) — the same
        // abstraction the sequential engine recurses with, so fork-node
        // updates and inline subtrees draw streams from one source.
        let ctx = NodeCtx {
            learner: rs.learner,
            data,
            folds: rs.folds,
            folded: rs.folded,
            strategy: rs.strategy,
            ordering: self.ordering,
            seed: rs.seed,
        };
        // This task's tallies; merged into the run's shared totals before
        // its leaves (or children) become visible to other workers, so
        // whoever finishes the run reads complete counters.
        let mut ops = OpCounts::default();
        // Root tasks init lazily (pure, so scheduling cannot affect it).
        let mut model = model.unwrap_or_else(|| rs.learner.init());
        if s < e && depth < rs.cutoff {
            let m = (s + e) / 2;
            // Node tags shared with the sequential engine.
            let (tag_right, tag_left) = node_tags(s, e);

            let work = catch_unwind(AssertUnwindSafe(|| {
                // The two halves may run concurrently on different
                // workers, so a fork must snapshot regardless of strategy
                // — this is the only copy a SaveRevert run pays. The
                // snapshot goes into a pooled buffer (clone_from reuses
                // its storage) when one is available.
                let recycled = shared.pool.lock().pop();
                let mut sibling = match recycled {
                    Some(mut buf) => {
                        buf.clone_from(&model);
                        buf
                    }
                    None => model.clone(),
                };
                ops.model_copies += 1;
                ops.bytes_copied += rs.learner.model_bytes(&model) as u64;

                // As in Algorithm 1: the model fed the *second* group
                // serves the left child (s, m); the model fed the *first*
                // group serves the right child (m+1, e).
                ctx.update_phase(&mut model, m + 1, e, tag_right, &mut ops, streams);
                ctx.update_phase(&mut sibling, s, m, tag_left, &mut ops, streams);
                sibling
            }));
            let sibling = match work {
                Ok(sibling) => sibling,
                Err(payload) => {
                    fail_run(shared, run, leaves, payload, on_result);
                    return;
                }
            };
            rs.ops.lock().merge(&ops);

            // Fork-point cancellation check: drop both halves instead of
            // queueing them. (The update work above is wasted, but the
            // whole subtree below — the expensive part — is saved.)
            if rs.ctrl.is_cancelled() {
                recycle(shared, model);
                recycle(shared, sibling);
                rs.tasks_dropped.fetch_add(2, MemOrdering::AcqRel);
                account(shared, run, leaves, true, on_result);
                return;
            }
            {
                let mut dq = shared.deques[wid].lock();
                dq.push_back(Task { run, s, e: m, depth: depth + 1, model: Some(model) });
                dq.push_back(Task { run, s: m + 1, e, depth: depth + 1, model: Some(sibling) });
            }
            // This worker keeps one child; the other is new stealable
            // work — wake one sleeper for it.
            wake_one(&shared.parked);
            return;
        }

        // Inline subtree: the shared sequential recursion, under the run's
        // strategy, into a local buffer (one per-fold lock per subtree
        // instead of one per leaf). Copy-strategy snapshots inside the
        // subtree recycle through this worker's scratch free-list, which
        // lives for the whole batch — tasks of every run share it (as do
        // the randomized-stream id buffers in `streams`).
        let work = catch_unwind(AssertUnwindSafe(|| {
            let mut local = vec![0.0; leaves];
            run_subtree(&ctx, &mut model, s, e, s, &mut local, &mut ops, scratch, streams);
            local
        }));
        let local = match work {
            Ok(local) => local,
            Err(payload) => {
                fail_run(shared, run, leaves, payload, on_result);
                return;
            }
        };
        rs.per_fold.lock()[s..=e].copy_from_slice(&local);
        // Recycle the model storage for future fork-node snapshots.
        recycle(shared, model);
        rs.ops.lock().merge(&ops);
        account(shared, run, leaves, false, on_result);
    }

    /// Process one approximate-CV task. The root (depth 0) trains the
    /// full-data model with ONE update phase over every chunk — the same
    /// node-stream machinery as an exact run, under a reserved tag
    /// ([`APPROX_FULL_TAG`]), so the trained model is a pure function of
    /// `(learner, data, folds, ordering, seed)` and independent of pool
    /// size — then partitions the folds into ~2 contiguous ranges per
    /// worker and queues each range with its own snapshot of the model
    /// (the last range inherits the original, so distribution costs
    /// `ranges − 1` copies). A fold-range task (depth ≥ 1) then, per
    /// fold: copies the full model into a worker-local scratch buffer,
    /// applies the learner's one-step correction
    /// ([`IncrementalLearner::try_correct_heldout`]) for the held-out
    /// chunk, and evaluates that chunk on the corrected model. Total
    /// update work is Θ(n) row updates + k corrections — no tree descent
    /// — and per-fold results are bitwise independent of the range
    /// partition, hence of the worker count.
    ///
    /// A learner without the correction capability panics here (caught
    /// and reported as [`RunOutcome::Failed`]); engines are expected to
    /// capability-check with [`IncrementalLearner::correctable`] first.
    fn process_approx<L>(
        &self,
        wid: usize,
        task: Task<L::Model>,
        shared: &Shared<'_, L>,
        data: &Dataset,
        scratch: &mut Vec<L::Model>,
        streams: &mut StreamScratch,
        on_result: Option<&OnResult<'_>>,
    ) where
        L: IncrementalLearner + Sync,
    {
        let Task { run, s, e, depth, model } = task;
        let rs = &shared.runs[run];
        let leaves = e - s + 1;
        if rs.ctrl.is_cancelled() {
            if let Some(m) = model {
                recycle(shared, m);
            }
            rs.tasks_dropped.fetch_add(1, MemOrdering::AcqRel);
            account(shared, run, leaves, true, on_result);
            return;
        }
        let ctx = NodeCtx {
            learner: rs.learner,
            data,
            folds: rs.folds,
            folded: rs.folded,
            strategy: rs.strategy,
            ordering: self.ordering,
            seed: rs.seed,
        };
        let mut ops = OpCounts::default();
        if depth == 0 {
            // Training root: one update phase over all k chunks.
            let mut model = model.unwrap_or_else(|| rs.learner.init());
            let trained = catch_unwind(AssertUnwindSafe(|| {
                ctx.update_phase(&mut model, 0, rs.k - 1, APPROX_FULL_TAG, &mut ops, streams);
            }));
            if let Err(payload) = trained {
                fail_run(shared, run, leaves, payload, on_result);
                return;
            }
            if rs.ctrl.is_cancelled() {
                recycle(shared, model);
                rs.ops.lock().merge(&ops);
                rs.tasks_dropped.fetch_add(1, MemOrdering::AcqRel);
                account(shared, run, leaves, true, on_result);
                return;
            }
            // Distribute: ~2 contiguous fold ranges per worker (capped at
            // k), each with its own pooled snapshot of the full model.
            let ranges = (shared.deques.len() * 2).min(leaves).max(1);
            let built = catch_unwind(AssertUnwindSafe(|| {
                let mut tasks: Vec<Task<L::Model>> = Vec::with_capacity(ranges);
                for r in 0..ranges - 1 {
                    let lo = s + leaves * r / ranges;
                    let hi = s + leaves * (r + 1) / ranges - 1;
                    let recycled = shared.pool.lock().pop();
                    let buf = match recycled {
                        Some(mut b) => {
                            b.clone_from(&model);
                            b
                        }
                        None => model.clone(),
                    };
                    ops.model_copies += 1;
                    ops.bytes_copied += rs.learner.model_bytes(&model) as u64;
                    tasks.push(Task { run, s: lo, e: hi, depth: 1, model: Some(buf) });
                }
                let lo = s + leaves * (ranges - 1) / ranges;
                tasks.push(Task { run, s: lo, e, depth: 1, model: Some(model) });
                tasks
            }));
            let tasks = match built {
                Ok(tasks) => tasks,
                Err(payload) => {
                    fail_run(shared, run, leaves, payload, on_result);
                    return;
                }
            };
            rs.ops.lock().merge(&ops);
            let stealable = tasks.len() - 1;
            {
                let mut dq = shared.deques[wid].lock();
                for t in tasks {
                    dq.push_back(t);
                }
            }
            // This worker pops one range itself; the rest are stealable.
            for _ in 0..stealable {
                wake_one(&shared.parked);
            }
            return;
        }

        // Fold-range task: per fold, correct a scratch copy of the full
        // model and evaluate the held-out chunk on it.
        // invariant: approx fold-range tasks are always queued with the
        // trained full-data model attached (see the root branch above).
        let full = model.expect("approx fold task carries the full-data model");
        let work = catch_unwind(AssertUnwindSafe(|| {
            let mut local = vec![0.0; leaves];
            let mut buf = scratch.pop().unwrap_or_else(|| rs.learner.init());
            for f in s..=e {
                buf.clone_from(&full);
                ops.model_copies += 1;
                ops.bytes_copied += rs.learner.model_bytes(&full) as u64;
                let corrected = rs.learner.try_correct_heldout(&mut buf, data, rs.folds.chunk(f));
                assert!(
                    corrected,
                    "learner `{}` has no one-step correction (ConvexCorrectable); \
                     the approx engine requires it — use an exact engine instead",
                    rs.learner.name()
                );
                ops.corrections += 1;
                local[f - s] = ctx.eval_leaf(&buf, f, &mut ops);
            }
            scratch.push(buf);
            local
        }));
        let local = match work {
            Ok(local) => local,
            Err(payload) => {
                fail_run(shared, run, leaves, payload, on_result);
                return;
            }
        };
        rs.per_fold.lock()[s..=e].copy_from_slice(&local);
        recycle(shared, full);
        rs.ops.lock().merge(&ops);
        account(shared, run, leaves, false, on_result);
    }

    /// Worker loop: drain own deque LIFO, steal FIFO when empty, admit the
    /// highest-priority root task from the injector when every deque is
    /// dry, park when the full sweep comes up empty, exit once every leaf
    /// of every run is accounted (completed or dropped). Counters are
    /// tallied per task and merged into the run's shared totals inside
    /// [`TreeCvExecutor::process`].
    ///
    /// Parking protocol (lost-wakeup-free): register on `shared.parked`
    /// FIRST, then re-sweep, then `park()`. A producer pushes its task
    /// before calling [`wake_one`], so either the push precedes our
    /// registration (and the verification re-sweep finds it) or the
    /// producer sees a registered sleeper and unparks one. `unpark` on a
    /// running thread banks a token that makes the next `park()` return
    /// immediately, so even a race with a stale registration only costs
    /// one extra sweep, never a hang.
    fn worker<L>(
        &self,
        wid: usize,
        shared: &Shared<'_, L>,
        data: &Dataset,
        on_result: Option<&OnResult<'_>>,
    ) where
        L: IncrementalLearner + Sync,
    {
        let _signal = PanicSignal { done: &shared.done, parked: &shared.parked };
        let n_workers = shared.deques.len();
        // Worker-local free-list for inline-subtree Copy snapshots; lives
        // across tasks — and across runs — so buffers recycle for the
        // whole batch (held count is bounded by the subtree recursion
        // depth, ≤ ⌈log₂ k⌉ of the deepest run).
        let mut scratch: Vec<L::Model> = Vec::new();
        // Worker-local free-list for randomized-stream id buffers (folded
        // layout); same lifetime as `scratch`.
        let mut streams = StreamScratch::new();
        // Injector pop: the pending root task whose run has the highest
        // current priority; FIFO (admission sequence) among equals.
        // Cancelled runs' roots are popped like any other — `process`
        // drops them with full accounting, never silently.
        let pop_injector = || -> Option<Task<L::Model>> {
            let mut inj = shared.injector.lock();
            let best = inj
                .iter()
                .enumerate()
                .max_by_key(|(_, (seq, t))| {
                    (shared.runs[t.run].ctrl.priority(), std::cmp::Reverse(*seq))
                })
                .map(|(idx, _)| idx)?;
            Some(inj.swap_remove(best).1)
        };
        let sweep = || -> Option<Task<L::Model>> {
            let own = shared.deques[wid].lock().pop_back();
            own.or_else(|| {
                (1..n_workers).find_map(|offset| {
                    let victim = (wid + offset) % n_workers;
                    shared.deques[victim].lock().pop_front()
                })
            })
            .or_else(|| pop_injector())
        };
        loop {
            // Sweep; on a dry sweep, run the park protocol, which may
            // still hand back a task (the verification sweep). One
            // `process` call site either way.
            let task = match sweep() {
                Some(t) => Some(t),
                None => {
                    if shared.done.load(MemOrdering::Acquire) {
                        break;
                    }
                    {
                        let mut p = shared.parked.lock();
                        p.retain(|(w, _)| *w != wid);
                        p.push((wid, thread::current()));
                    }
                    // Verification sweep: anything pushed before our
                    // registration became visible is caught here.
                    match sweep() {
                        Some(t) => {
                            unregister(&shared.parked, wid);
                            Some(t)
                        }
                        None => {
                            if shared.done.load(MemOrdering::Acquire) {
                                unregister(&shared.parked, wid);
                                break;
                            }
                            thread::park();
                            unregister(&shared.parked, wid);
                            None
                        }
                    }
                }
            };
            if let Some(t) = task {
                self.process(wid, t, shared, data, &mut scratch, &mut streams, on_result);
            }
        }
    }

    /// Run the executor engine on a single computation. (Not part of the
    /// [`super::CvEngine`] trait because it needs `L: Sync` bounds the
    /// trait doesn't impose.)
    pub fn run<L>(&self, learner: &L, data: &Dataset, folds: &Folds) -> CvResult
    where
        L: IncrementalLearner + Sync,
        L::Model: Send,
    {
        let spec = RunSpec {
            learner,
            folds,
            seed: self.seed,
            strategy: self.strategy,
            folded: None,
            ctrl: RunCtrl::default(),
        };
        // invariant: run_many returns exactly one result per input spec.
        self.run_many(data, std::slice::from_ref(&spec))
            .pop()
            .expect("run_many returns one result per run")
    }

    /// Run a single computation over the fold-contiguous layout (see
    /// [`RunSpec::folded`]): identical scheduling and bit-identical
    /// results to [`Self::run`] on `folded.folds()`, with fixed-order
    /// node streams fed as contiguous slices (zero per-node index-vector
    /// allocations) and randomized streams drawn from recycled
    /// worker-local buffers. `data` must be the dataset `folded` was
    /// built from.
    pub fn run_folded<L>(&self, learner: &L, data: &Dataset, folded: &FoldedDataset) -> CvResult
    where
        L: IncrementalLearner + Sync,
        L::Model: Send,
    {
        let spec = RunSpec {
            learner,
            folds: folded.folds(),
            seed: self.seed,
            strategy: self.strategy,
            folded: Some(folded),
            ctrl: RunCtrl::default(),
        };
        // invariant: run_many returns exactly one result per input spec.
        self.run_many(data, std::slice::from_ref(&spec))
            .pop()
            .expect("run_many returns one result per run")
    }

    /// Run a whole batch of TreeCV computations — e.g. every
    /// (hyperparameter config × repetition) run of a sweep — through ONE
    /// persistent worker pool. Tasks from all runs share the deques, the
    /// fork-snapshot buffer pool and the worker-local scratch free-lists;
    /// there is no barrier between runs and no per-run spawn/teardown.
    ///
    /// Each run keeps its own snapshot cutoff (derived from the engine's
    /// `threads` knob and the run's own k, exactly as a standalone
    /// [`Self::run`] derives it) and its own `(seed, strategy)` from the
    /// spec — the engine's `strategy`/`seed` fields are not consulted —
    /// so result `i` is bit-identical to running `runs[i]` alone at the
    /// same `threads` setting. Results come back in run order; each
    /// `wall` is the elapsed time from batch start to the run's last
    /// leaf.
    ///
    /// This strict form requires every run to complete: the first
    /// [`RunOutcome::Failed`] cancels all sibling runs (fast wind-down)
    /// and re-panics with the original message, and a run cancelled by
    /// the caller's own token panics with a pointer to
    /// [`Self::run_many_outcomes`] — the cancellation-aware form that
    /// reports per-run statuses instead.
    pub fn run_many<L>(&self, data: &Dataset, runs: &[RunSpec<'_, L>]) -> Vec<CvResult>
    where
        L: IncrementalLearner + Sync,
        L::Model: Send,
    {
        self.run_many_mode(data, runs, RunMode::Tree)
    }

    /// Approximate-CV batch (`--engine approx`): every run trains its
    /// full-data model ONCE (Θ(n) row updates) and produces each fold's
    /// held-out estimate by one-step-correcting a copy of that model
    /// ([`crate::learner::ConvexCorrectable`]) instead of descending the
    /// tree — see [`Self::process_approx`]. Fold ranges parallelize
    /// through the same pool, deques, and cancellation machinery as exact
    /// batches, and per-fold estimates are bitwise independent of the
    /// worker count (the full model is trained by one deterministic
    /// update phase; corrections are per-fold independent).
    ///
    /// Specs are ordinary [`RunSpec`]s: `seed`/`folded` behave exactly as
    /// in exact batches; `strategy` is carried but never consulted (the
    /// approx sweep neither forks nor reverts). Every learner in the
    /// batch must advertise [`IncrementalLearner::correctable`] — a
    /// non-correctable learner fails its run (strict form: panics).
    pub fn run_many_approx<L>(&self, data: &Dataset, runs: &[RunSpec<'_, L>]) -> Vec<CvResult>
    where
        L: IncrementalLearner + Sync,
        L::Model: Send,
    {
        self.run_many_mode(data, runs, RunMode::Approx)
    }

    /// Strict facade shared by [`Self::run_many`] (exact) and
    /// [`Self::run_many_approx`]: first failure cancels all siblings and
    /// re-panics; caller-cancelled runs panic with a pointer to the
    /// outcome-reporting form.
    fn run_many_mode<L>(
        &self,
        data: &Dataset,
        runs: &[RunSpec<'_, L>],
        mode: RunMode,
    ) -> Vec<CvResult>
    where
        L: IncrementalLearner + Sync,
        L::Model: Send,
    {
        let abort_siblings = |_idx: usize, out: &RunOutcome| {
            if matches!(out, RunOutcome::Failed { .. }) {
                for r in runs {
                    r.ctrl.cancel();
                }
            }
        };
        let outcomes = self.run_batch_outcomes(data, runs, mode, Some(&abort_siblings));
        for out in &outcomes {
            if let RunOutcome::Failed { error } = out {
                panic!("executor worker panicked: {error}");
            }
        }
        outcomes
            .into_iter()
            .enumerate()
            .map(|(i, out)| match out {
                RunOutcome::Completed(res) => res,
                RunOutcome::Cancelled { .. } => panic!(
                    "run {i} was cancelled mid-batch; run_many returns plain CvResults — \
                     dispatch cancellable batches through run_many_outcomes"
                ),
                RunOutcome::Failed { .. } => unreachable!("failures re-panic above"),
            })
            .collect()
    }

    /// Cancellation-aware batch execution: like [`Self::run_many`] but
    /// each run terminates in a [`RunOutcome`] — `Completed` (bit-identical
    /// to the strict form), `Cancelled` (its [`RunCtrl`] token fired
    /// before the last leaf) or `Failed` (a task panicked; siblings keep
    /// going). `on_result` is invoked on a worker thread the moment each
    /// run's outcome is decided, enabling mid-batch reactions — a racing
    /// scheduler cancels losers and re-prioritizes survivors from here.
    pub fn run_many_outcomes<L>(
        &self,
        data: &Dataset,
        runs: &[RunSpec<'_, L>],
        on_result: Option<&OnResult<'_>>,
    ) -> Vec<RunOutcome>
    where
        L: IncrementalLearner + Sync,
        L::Model: Send,
    {
        self.run_batch_outcomes(data, runs, RunMode::Tree, on_result)
    }

    /// Mode-parameterized batch execution body (see
    /// [`Self::run_many_outcomes`] for the exact-tree contract and
    /// [`Self::run_many_approx`] for the approx one).
    fn run_batch_outcomes<L>(
        &self,
        data: &Dataset,
        runs: &[RunSpec<'_, L>],
        mode: RunMode,
        on_result: Option<&OnResult<'_>>,
    ) -> Vec<RunOutcome>
    where
        L: IncrementalLearner + Sync,
        L::Model: Send,
    {
        if runs.is_empty() {
            return Vec::new();
        }
        for (i, r) in runs.iter().enumerate() {
            if let Some(f) = r.folded {
                assert_eq!(f.n(), data.n, "run {i}: folded layout built for a different dataset");
                assert_eq!(f.d(), data.d, "run {i}: folded layout built for a different dataset");
                assert!(
                    f.matches_folds(r.folds),
                    "run {i}: folded layout does not realize the spec's fold partition"
                );
            }
        }
        let leaves_total: usize = runs.iter().map(|r| r.folds.k()).sum();
        let threads = self.threads.max(1).min(leaves_total);
        let cutoff_of = |k: usize| snapshot_cutoff(self.threads.max(1).min(k));
        let max_cutoff = runs.iter().map(|r| cutoff_of(r.folds.k())).max().unwrap_or(0);
        // Steady-state snapshot demand is one buffer per live fork level
        // per worker; when several runs are in flight, stealing
        // interleaves their fork frontiers, so the retention cap doubles.
        let pool_cap = threads * (max_cutoff + 2) * if runs.len() > 1 { 2 } else { 1 };
        let shared: Shared<'_, L> = Shared {
            deques: (0..threads).map(|_| Mutex::new(VecDeque::new())).collect(),
            // Root tasks all start in the priority injector (admission
            // sequence = run order, so equal priorities run in batch
            // order). Root models are `None` (lazily inited on first pop)
            // so a wide batch doesn't hold every run's full model before
            // work starts.
            injector: Mutex::new(
                runs.iter()
                    .enumerate()
                    .map(|(i, r)| {
                        let task =
                            Task { run: i, s: 0, e: r.folds.k() - 1, depth: 0, model: None };
                        (i as u64, task)
                    })
                    .collect(),
            ),
            pool: Mutex::new(Vec::new()),
            pool_cap,
            mode,
            runs: runs
                .iter()
                .map(|r| RunShared {
                    learner: r.learner,
                    folds: r.folds,
                    folded: r.folded,
                    seed: r.seed,
                    strategy: r.strategy,
                    cutoff: cutoff_of(r.folds.k()),
                    k: r.folds.k(),
                    per_fold: Mutex::new(vec![0.0; r.folds.k()]),
                    ctrl: r.ctrl.clone(),
                    leaves_done: AtomicUsize::new(0),
                    leaves_dropped: AtomicUsize::new(0),
                    tasks_dropped: AtomicUsize::new(0),
                    leaves_acct: AtomicUsize::new(0),
                    failed: Mutex::new(None),
                    outcome: Mutex::new(None),
                    ops: Mutex::new(OpCounts::default()),
                    wall: Mutex::new(Duration::ZERO),
                })
                .collect(),
            leaves_total,
            leaves_done: AtomicUsize::new(0),
            done: AtomicBool::new(false),
            parked: Mutex::new(Vec::new()),
            timer: Timer::start(),
        };

        if threads == 1 {
            // Inline on the calling thread: zero spawn cost, and exactly
            // the sequential engine's work.
            self.worker(0, &shared, data, on_result);
        } else {
            self.spawns.fetch_add(1, MemOrdering::Relaxed);
            let shared_ref = &shared;
            thread::scope(|scope| {
                let handles: Vec<_> = (0..threads)
                    .map(|wid| scope.spawn(move || self.worker(wid, shared_ref, data, on_result)))
                    .collect();
                for handle in handles {
                    // invariant: worker panics that escape the per-task
                    // catch_unwind are unrecoverable harness bugs and are
                    // deliberately re-propagated to the caller.
                    handle.join().expect("executor worker panicked");
                }
            });
        }

        shared
            .runs
            .into_iter()
            .map(|rs| {
                // invariant: the batch only returns once shared.done
                // flipped, which requires every run's leaves accounted and
                // its outcome published.
                rs.outcome
                    .into_inner()
                    .expect("every run accounts all its leaves before the batch returns")
            })
            .collect()
    }

    /// Run a single type-erased computation (see [`Self::run_many_erased`]
    /// for the batch form and the equivalence contract).
    pub fn run_erased(
        &self,
        learner: &dyn ErasedLearner,
        data: &Dataset,
        folds: &Folds,
    ) -> CvResult {
        let spec = ErasedRunSpec {
            learner,
            folds,
            seed: self.seed,
            strategy: self.strategy,
            folded: None,
            ctrl: RunCtrl::default(),
        };
        // invariant: run_many_erased returns one result per input spec.
        self.run_many_erased(data, std::slice::from_ref(&spec))
            .pop()
            .expect("run_many_erased returns one result per run")
    }

    /// Type-erased counterpart of [`Self::run_folded`]: the erased
    /// adapter forwards the contiguous fast paths, so results stay
    /// bit-identical to the generic folded run.
    pub fn run_erased_folded(
        &self,
        learner: &dyn ErasedLearner,
        data: &Dataset,
        folded: &FoldedDataset,
    ) -> CvResult {
        let spec = ErasedRunSpec {
            learner,
            folds: folded.folds(),
            seed: self.seed,
            strategy: self.strategy,
            folded: Some(folded),
            ctrl: RunCtrl::default(),
        };
        // invariant: run_many_erased returns one result per input spec.
        self.run_many_erased(data, std::slice::from_ref(&spec))
            .pop()
            .expect("run_many_erased returns one result per run")
    }

    /// Run a **heterogeneous** batch — runs of *different* learner
    /// families — through ONE persistent worker pool. This is
    /// [`Self::run_many`] over the type-erased learner layer: each spec
    /// wraps its `&dyn ErasedLearner` in a [`DynLearner`] adapter and the
    /// whole batch executes through the identical generic machinery
    /// (deques, fork-snapshot buffer pool, worker-local scratch), so
    /// result `i` is bit-identical to running `runs[i]`'s learner alone
    /// through the generic path at the same `threads` setting —
    /// `tests/integration_erased.rs` pins this per learner. Pooled model
    /// buffers recycle across families via `ErasedModel::clone_from`
    /// (storage-reusing on a type match, wholesale replacement otherwise).
    pub fn run_many_erased(&self, data: &Dataset, runs: &[ErasedRunSpec<'_>]) -> Vec<CvResult> {
        let wrapped: Vec<DynLearner<'_>> = runs.iter().map(|r| DynLearner(r.learner)).collect();
        let specs = Self::erased_specs(&wrapped, runs);
        self.run_many(data, &specs)
    }

    /// Single approximate-CV run (see [`Self::run_many_approx`] for the
    /// batch form and contract).
    pub fn run_approx<L>(&self, learner: &L, data: &Dataset, folds: &Folds) -> CvResult
    where
        L: IncrementalLearner + Sync,
        L::Model: Send,
    {
        let spec = RunSpec {
            learner,
            folds,
            seed: self.seed,
            strategy: self.strategy,
            folded: None,
            ctrl: RunCtrl::default(),
        };
        // invariant: run_many_approx returns one result per input spec.
        self.run_many_approx(data, std::slice::from_ref(&spec))
            .pop()
            .expect("run_many_approx returns one result per run")
    }

    /// Heterogeneous approximate-CV batch: [`Self::run_many_approx`] over
    /// the type-erased learner layer, forwarding the correction capability
    /// through [`DynLearner`]. Every spec's learner must advertise
    /// [`ErasedLearner::correctable`].
    pub fn run_many_approx_erased(
        &self,
        data: &Dataset,
        runs: &[ErasedRunSpec<'_>],
    ) -> Vec<CvResult> {
        let wrapped: Vec<DynLearner<'_>> = runs.iter().map(|r| DynLearner(r.learner)).collect();
        let specs = Self::erased_specs(&wrapped, runs);
        self.run_many_approx(data, &specs)
    }

    /// Cancellation-aware heterogeneous batch: [`Self::run_many_outcomes`]
    /// over the type-erased learner layer. Each spec's [`RunCtrl`] is
    /// shared with the adapter spec, so cancelling/re-prioritizing
    /// through a caller-held clone steers the erased run directly.
    pub fn run_many_erased_outcomes(
        &self,
        data: &Dataset,
        runs: &[ErasedRunSpec<'_>],
        on_result: Option<&OnResult<'_>>,
    ) -> Vec<RunOutcome> {
        let wrapped: Vec<DynLearner<'_>> = runs.iter().map(|r| DynLearner(r.learner)).collect();
        let specs = Self::erased_specs(&wrapped, runs);
        self.run_many_outcomes(data, &specs, on_result)
    }

    /// Adapter specs for an erased batch; each shares its source spec's
    /// control block (same token, same priority cell).
    fn erased_specs<'a>(
        wrapped: &'a [DynLearner<'a>],
        runs: &'a [ErasedRunSpec<'a>],
    ) -> Vec<RunSpec<'a, DynLearner<'a>>> {
        wrapped
            .iter()
            .zip(runs)
            .map(|(learner, r)| RunSpec {
                learner,
                folds: r.folds,
                seed: r.seed,
                strategy: r.strategy,
                folded: r.folded,
                ctrl: r.ctrl.clone(),
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cv::treecv::TreeCv;
    use crate::cv::{CvEngine, Strategy};
    use crate::data::synth::{SyntheticCovertype, SyntheticMixture1d};
    use crate::learner::histdensity::HistogramDensity;
    use crate::learner::pegasos::Pegasos;

    #[test]
    fn matches_sequential_fixed_order() {
        let data = SyntheticCovertype::new(2_000, 91).generate();
        let l = Pegasos::new(54, 1e-4);
        let folds = Folds::new(2_000, 16, 92);
        let seq = TreeCv::new(Strategy::Copy, Ordering::Fixed, 5).run(&l, &data, &folds);
        let exe =
            TreeCvExecutor::new(Strategy::Copy, Ordering::Fixed, 5, 8).run(&l, &data, &folds);
        assert_eq!(seq.per_fold, exe.per_fold);
        assert_eq!(seq.estimate, exe.estimate);
    }

    #[test]
    fn matches_sequential_randomized_order() {
        // Per-node RNG derivation makes randomized ordering identical too.
        let data = SyntheticCovertype::new(1_000, 93).generate();
        let l = Pegasos::new(54, 1e-4);
        let folds = Folds::new(1_000, 8, 94);
        let seq = TreeCv::new(Strategy::Copy, Ordering::Randomized, 7).run(&l, &data, &folds);
        let exe =
            TreeCvExecutor::new(Strategy::Copy, Ordering::Randomized, 7, 4).run(&l, &data, &folds);
        assert_eq!(seq.per_fold, exe.per_fold);
    }

    #[test]
    fn every_worker_count_is_bit_identical() {
        // Including non-power-of-two pools, which the scoped-fork engine
        // could never use, and pools larger than k (internally capped).
        let data = SyntheticCovertype::new(900, 95).generate();
        let l = Pegasos::new(54, 1e-3);
        let folds = Folds::new(900, 13, 96); // remainder folds: k ∤ n
        let seq = TreeCv::new(Strategy::Copy, Ordering::Fixed, 3).run(&l, &data, &folds);
        for threads in [1usize, 2, 3, 5, 6, 7, 12, 16, 64] {
            let exe = TreeCvExecutor::new(Strategy::Copy, Ordering::Fixed, 3, threads)
                .run(&l, &data, &folds);
            assert_eq!(seq.per_fold, exe.per_fold, "threads={threads}");
        }
    }

    #[test]
    fn save_revert_matches_sequential_at_every_worker_count() {
        // Exact-revert learner: the strategy-aware executor must reproduce
        // sequential SaveRevert bit-for-bit at any pool size.
        let data = SyntheticMixture1d::new(700, 89).generate();
        let l = HistogramDensity::new(-8.0, 8.0, 32);
        let folds = Folds::new(700, 11, 88); // remainder folds
        let seq = TreeCv::new(Strategy::SaveRevert, Ordering::Fixed, 4).run(&l, &data, &folds);
        for threads in [1usize, 2, 3, 5, 8, 16] {
            let exe = TreeCvExecutor::new(Strategy::SaveRevert, Ordering::Fixed, 4, threads)
                .run(&l, &data, &folds);
            assert_eq!(seq.per_fold, exe.per_fold, "threads={threads}");
            assert_eq!(seq.ops.points_updated, exe.ops.points_updated, "threads={threads}");
            assert_eq!(seq.ops.evals, exe.ops.evals, "threads={threads}");
        }
    }

    #[test]
    fn save_revert_copies_only_at_forks() {
        // k = 64 LOOCV-ish tree: Copy pays k−1 = 63 snapshots; SaveRevert
        // pays at most 2^cutoff − 1 fork snapshots, restores carry the
        // rest (2 per non-forked interior node).
        let data = SyntheticMixture1d::new(640, 87).generate();
        let l = HistogramDensity::new(-8.0, 8.0, 32);
        let folds = Folds::new(640, 64, 86);
        for threads in [1usize, 3, 6] {
            let exe = TreeCvExecutor::new(Strategy::SaveRevert, Ordering::Fixed, 0, threads)
                .run(&l, &data, &folds);
            let max_forks = (1u64 << snapshot_cutoff(threads)) - 1;
            assert!(
                exe.ops.model_copies <= max_forks,
                "threads={threads}: {} copies > {max_forks} fork nodes",
                exe.ops.model_copies
            );
            assert!(exe.ops.model_copies < 63, "threads={threads}");
            assert_eq!(exe.ops.model_restores, 2 * (63 - exe.ops.model_copies));
        }
    }

    #[test]
    fn single_thread_is_inline_and_identical() {
        let data = SyntheticMixture1d::new(300, 97).generate();
        let l = HistogramDensity::new(-8.0, 8.0, 32);
        let folds = Folds::new(300, 10, 98);
        let exe =
            TreeCvExecutor::new(Strategy::Copy, Ordering::Fixed, 0, 1).run(&l, &data, &folds);
        let seq = TreeCv::default().run(&l, &data, &folds);
        assert_eq!(exe.per_fold, seq.per_fold);
    }

    #[test]
    fn total_work_unchanged_by_pool_size() {
        let data = SyntheticMixture1d::new(512, 99).generate();
        let l = HistogramDensity::new(-8.0, 8.0, 32);
        let folds = Folds::new(512, 32, 100);
        let seq = TreeCv::default().run(&l, &data, &folds);
        let exe =
            TreeCvExecutor::new(Strategy::Copy, Ordering::Fixed, 0, 6).run(&l, &data, &folds);
        assert_eq!(seq.ops.points_updated, exe.ops.points_updated);
        assert_eq!(seq.ops.evals, exe.ops.evals);
        assert_eq!(seq.ops.update_calls, exe.ops.update_calls);
        // One snapshot per interior node, exactly as the Copy strategy:
        // the fork/inline split recycles storage but never changes the
        // Copy-strategy count.
        assert_eq!(exe.ops.model_copies, 31);
    }

    #[test]
    fn loocv_smallest_and_degenerate_k() {
        // k = 1: the root is a leaf; the init model is evaluated directly.
        let data = SyntheticMixture1d::new(40, 101).generate();
        let l = HistogramDensity::new(-8.0, 8.0, 16);
        let folds = Folds::new(40, 1, 102);
        let exe =
            TreeCvExecutor::new(Strategy::Copy, Ordering::Fixed, 0, 4).run(&l, &data, &folds);
        assert_eq!(exe.per_fold.len(), 1);
        assert_eq!(exe.ops.evals, 1);
        // k = n (LOOCV) with a multi-worker pool, both strategies.
        let folds = Folds::loocv(40);
        let seq = TreeCv::default().run(&l, &data, &folds);
        let exe =
            TreeCvExecutor::new(Strategy::Copy, Ordering::Fixed, 0, 4).run(&l, &data, &folds);
        assert_eq!(seq.per_fold, exe.per_fold);
        let seq = TreeCv::new(Strategy::SaveRevert, Ordering::Fixed, 0).run(&l, &data, &folds);
        let exe = TreeCvExecutor::new(Strategy::SaveRevert, Ordering::Fixed, 0, 4)
            .run(&l, &data, &folds);
        assert_eq!(seq.per_fold, exe.per_fold);
    }

    #[test]
    fn run_many_batch_matches_standalone_runs() {
        // Three λ configs × two partitionings through ONE batch: every
        // result must be bit-identical to its standalone run at the same
        // threads setting, counters included.
        let data = SyntheticCovertype::new(800, 103).generate();
        let learners = [Pegasos::new(54, 1e-3), Pegasos::new(54, 1e-4), Pegasos::new(54, 1e-5)];
        let folds = [Folds::new(800, 9, 104), Folds::new(800, 9, 105)];
        let mut specs = Vec::new();
        for learner in &learners {
            for (r, f) in folds.iter().enumerate() {
                let spec = RunSpec {
                    learner,
                    folds: f,
                    seed: 60 + r as u64,
                    strategy: Strategy::Copy,
                    folded: None,
                    ctrl: RunCtrl::default(),
                };
                specs.push(spec);
            }
        }
        let exe = TreeCvExecutor::new(Strategy::Copy, Ordering::Fixed, 0, 4);
        let batch = exe.run_many(&data, &specs);
        assert_eq!(batch.len(), 6);
        for (i, (spec, got)) in specs.iter().zip(&batch).enumerate() {
            let alone = TreeCvExecutor::new(spec.strategy, Ordering::Fixed, spec.seed, 4)
                .run(spec.learner, &data, spec.folds);
            assert_eq!(got.per_fold, alone.per_fold, "run {i}");
            assert_eq!(got.estimate, alone.estimate, "run {i}");
            assert_eq!(got.ops.points_updated, alone.ops.points_updated, "run {i}");
            assert_eq!(got.ops.model_copies, alone.ops.model_copies, "run {i}");
        }
    }

    #[test]
    fn run_many_mixes_strategies_and_fold_counts() {
        // A batch may mix strategies and ks (k = 1 runs are single-leaf);
        // each run must still reproduce the sequential engine under its
        // own (strategy, folds, seed).
        let data = SyntheticMixture1d::new(400, 106).generate();
        let l = HistogramDensity::new(-8.0, 8.0, 32);
        let folds = [Folds::new(400, 7, 107), Folds::new(400, 16, 108), Folds::new(400, 1, 109)];
        let strategies = [Strategy::SaveRevert, Strategy::Copy, Strategy::Copy];
        let specs: Vec<RunSpec<'_, HistogramDensity>> = folds
            .iter()
            .zip(strategies)
            .enumerate()
            .map(|(i, (f, strategy))| RunSpec {
                learner: &l,
                folds: f,
                seed: i as u64,
                strategy,
                folded: None,
                ctrl: RunCtrl::default(),
            })
            .collect();
        let batch =
            TreeCvExecutor::new(Strategy::Copy, Ordering::Randomized, 0, 3).run_many(&data, &specs);
        for (i, (spec, got)) in specs.iter().zip(&batch).enumerate() {
            let seq = TreeCv::new(spec.strategy, Ordering::Randomized, spec.seed)
                .run(&l, &data, spec.folds);
            assert_eq!(got.per_fold, seq.per_fold, "run {i}");
            assert_eq!(got.ops.points_updated, seq.ops.points_updated, "run {i}");
            assert_eq!(got.ops.evals, seq.ops.evals, "run {i}");
        }
    }

    #[test]
    fn run_many_empty_batch_is_empty() {
        let data = SyntheticMixture1d::new(10, 110).generate();
        let exe = TreeCvExecutor::new(Strategy::Copy, Ordering::Fixed, 0, 4);
        let out = exe.run_many::<HistogramDensity>(&data, &[]);
        assert!(out.is_empty());
    }

    #[test]
    fn per_pool_spawn_counter_is_exact_and_local() {
        let data = SyntheticMixture1d::new(200, 111).generate();
        let l = HistogramDensity::new(-8.0, 8.0, 16);
        let folds = Folds::new(200, 8, 112);
        let exe = TreeCvExecutor::new(Strategy::Copy, Ordering::Fixed, 0, 4);
        assert_eq!(exe.pool_spawns(), 0);
        let _ = exe.run(&l, &data, &folds);
        let _ = exe.run(&l, &data, &folds);
        assert_eq!(exe.pool_spawns(), 2, "one spawn per multi-worker batch");
        // Clones share the handle: the counter identifies the pool config,
        // not the clone.
        let clone = exe.clone();
        let _ = clone.run(&l, &data, &folds);
        assert_eq!(exe.pool_spawns(), 3);
        // Inline (threads = 1) batches never spawn.
        let inline = TreeCvExecutor::new(Strategy::Copy, Ordering::Fixed, 0, 1);
        let _ = inline.run(&l, &data, &folds);
        assert_eq!(inline.pool_spawns(), 0);
        // Fresh executors start at zero — the counter is per pool, not
        // process-wide.
        assert_eq!(TreeCvExecutor::new(Strategy::Copy, Ordering::Fixed, 0, 4).pool_spawns(), 0);
    }

    #[test]
    fn erased_heterogeneous_batch_matches_generic_standalone() {
        // Three different learner families through ONE pool; every result
        // must be bit-identical to the generic executor run of that
        // learner alone at the same threads setting, counters included.
        use crate::learner::erased::{Erased, ErasedLearner};
        use crate::learner::knn::KnnClassifier;
        use crate::learner::perceptron::Perceptron;
        let data = SyntheticCovertype::new(400, 113).generate();
        let folds = Folds::new(400, 9, 114);
        let pegasos = Pegasos::new(54, 1e-3);
        let perceptron = Perceptron::new(54);
        let knn = KnnClassifier::new(54, 3);
        let erased: [Box<dyn ErasedLearner>; 3] = [
            Erased::boxed(pegasos.clone()),
            Erased::boxed(perceptron.clone()),
            Erased::boxed(knn.clone()),
        ];
        let specs: Vec<ErasedRunSpec<'_>> = erased
            .iter()
            .enumerate()
            .map(|(i, l)| ErasedRunSpec {
                learner: &**l,
                folds: &folds,
                seed: 70 + i as u64,
                strategy: Strategy::Copy,
                folded: None,
                ctrl: RunCtrl::default(),
            })
            .collect();
        let exe = TreeCvExecutor::new(Strategy::Copy, Ordering::Fixed, 0, 4);
        let batch = exe.run_many_erased(&data, &specs);
        assert_eq!(exe.pool_spawns(), 1, "heterogeneous batch uses one pool");
        let alone =
            |i: usize| TreeCvExecutor::new(Strategy::Copy, Ordering::Fixed, 70 + i as u64, 4);
        let generics = [
            alone(0).run(&pegasos, &data, &folds),
            alone(1).run(&perceptron, &data, &folds),
            alone(2).run(&knn, &data, &folds),
        ];
        for (i, (got, want)) in batch.iter().zip(&generics).enumerate() {
            assert_eq!(got.per_fold, want.per_fold, "run {i}");
            assert_eq!(got.estimate.to_bits(), want.estimate.to_bits(), "run {i}");
            assert_eq!(got.ops.points_updated, want.ops.points_updated, "run {i}");
            assert_eq!(got.ops.model_copies, want.ops.model_copies, "run {i}");
            assert_eq!(got.ops.bytes_copied, want.ops.bytes_copied, "run {i}");
        }
    }

    #[test]
    fn folded_run_matches_indexed_at_every_worker_count() {
        // Same pool, same schedule, two physical layouts: per-fold scores,
        // estimate and every semantic counter must agree bit for bit; the
        // fixed-order folded run additionally allocates zero node streams.
        use crate::data::folded::FoldedDataset;
        let data = SyntheticCovertype::new(900, 115).generate();
        let l = Pegasos::new(54, 1e-3);
        let folds = Folds::new(900, 13, 116); // remainder folds
        let folded = FoldedDataset::build(&data, &folds);
        for ordering in [Ordering::Fixed, Ordering::Randomized] {
            for strategy in [Strategy::Copy, Strategy::SaveRevert] {
                for threads in [1usize, 3, 6, 8] {
                    let exe = TreeCvExecutor::new(strategy, ordering, 3, threads);
                    let a = exe.run(&l, &data, &folds);
                    let b = exe.run_folded(&l, &data, &folded);
                    let ctx = format!("{strategy:?} {ordering:?} threads={threads}");
                    assert_eq!(a.per_fold, b.per_fold, "{ctx}");
                    assert_eq!(a.estimate.to_bits(), b.estimate.to_bits(), "{ctx}");
                    assert_eq!(a.ops.points_updated, b.ops.points_updated, "{ctx}");
                    assert_eq!(a.ops.points_permuted, b.ops.points_permuted, "{ctx}");
                    assert_eq!(a.ops.model_copies, b.ops.model_copies, "{ctx}");
                    assert_eq!(a.ops.update_calls, b.ops.update_calls, "{ctx}");
                    if ordering == Ordering::Fixed {
                        assert_eq!(b.ops.stream_allocs, 0, "{ctx}: folded fixed must not alloc");
                    }
                }
            }
        }
    }

    #[test]
    fn batch_mixes_folded_and_indexed_runs() {
        use crate::data::folded::FoldedDataset;
        let data = SyntheticMixture1d::new(400, 117).generate();
        let l = HistogramDensity::new(-8.0, 8.0, 32);
        let folds_a = Folds::new(400, 7, 118);
        let folds_b = Folds::new(400, 16, 119);
        let folded_a = FoldedDataset::build(&data, &folds_a);
        let specs = [
            RunSpec {
                learner: &l,
                folds: folded_a.folds(),
                seed: 1,
                strategy: Strategy::Copy,
                folded: Some(&folded_a),
                ctrl: RunCtrl::default(),
            },
            RunSpec {
                learner: &l,
                folds: &folds_b,
                seed: 2,
                strategy: Strategy::SaveRevert,
                folded: None,
                ctrl: RunCtrl::default(),
            },
        ];
        let exe = TreeCvExecutor::new(Strategy::Copy, Ordering::Fixed, 0, 3);
        let batch = exe.run_many(&data, &specs);
        let alone_a = TreeCvExecutor::new(Strategy::Copy, Ordering::Fixed, 1, 3)
            .run(&l, &data, &folds_a);
        let alone_b = TreeCvExecutor::new(Strategy::SaveRevert, Ordering::Fixed, 2, 3)
            .run(&l, &data, &folds_b);
        assert_eq!(batch[0].per_fold, alone_a.per_fold);
        assert_eq!(batch[1].per_fold, alone_b.per_fold);
        assert_eq!(batch[0].ops.stream_allocs, 0, "folded run allocates no streams");
    }

    #[test]
    #[should_panic(expected = "does not realize")]
    fn folded_layout_fold_mismatch_panics() {
        use crate::data::folded::FoldedDataset;
        let data = SyntheticMixture1d::new(60, 120).generate();
        let l = HistogramDensity::new(-8.0, 8.0, 8);
        let folds = Folds::new(60, 5, 121);
        let other = Folds::new(60, 5, 122);
        let folded = FoldedDataset::build(&data, &other);
        let spec = RunSpec {
            learner: &l,
            folds: &folds,
            seed: 0,
            strategy: Strategy::Copy,
            folded: Some(&folded),
            ctrl: RunCtrl::default(),
        };
        let _ = TreeCvExecutor::new(Strategy::Copy, Ordering::Fixed, 0, 2)
            .run_many(&data, std::slice::from_ref(&spec));
    }

    #[test]
    fn parked_workers_complete_long_serial_batches() {
        // Regression smoke for the park/unpark idle protocol: a k = 2 tree
        // has ONE fork and long serial phases, so with many workers most
        // of the pool parks and must be woken for the forked child and for
        // batch completion; the erased-heterogeneous path shares the same
        // worker loop. A hang here = lost wakeup.
        let data = SyntheticMixture1d::new(4_000, 123).generate();
        let l = HistogramDensity::new(-8.0, 8.0, 64);
        let folds = Folds::new(4_000, 2, 124);
        let seq = TreeCv::default().run(&l, &data, &folds);
        for _ in 0..20 {
            let exe = TreeCvExecutor::new(Strategy::Copy, Ordering::Fixed, 0, 8)
                .run(&l, &data, &folds);
            assert_eq!(seq.per_fold, exe.per_fold);
        }
    }

    /// Delegates to a histogram learner but (optionally) panics on every
    /// held-out evaluation — drives the Failed-outcome paths.
    struct PanicAtEval {
        inner: HistogramDensity,
        fail: bool,
    }

    impl IncrementalLearner for PanicAtEval {
        type Model = <HistogramDensity as IncrementalLearner>::Model;
        type Undo = <HistogramDensity as IncrementalLearner>::Undo;

        fn name(&self) -> &'static str {
            "panic_at_eval"
        }

        fn dim(&self) -> usize {
            self.inner.dim()
        }

        fn init(&self) -> Self::Model {
            self.inner.init()
        }

        fn update(&self, model: &mut Self::Model, data: &Dataset, idx: &[u32]) {
            self.inner.update(model, data, idx);
        }

        fn update_logged(
            &self,
            model: &mut Self::Model,
            data: &Dataset,
            idx: &[u32],
        ) -> Self::Undo {
            self.inner.update_logged(model, data, idx)
        }

        fn revert(&self, model: &mut Self::Model, data: &Dataset, undo: Self::Undo) {
            self.inner.revert(model, data, undo);
        }

        fn loss(&self, model: &Self::Model, data: &Dataset, i: u32) -> f64 {
            if self.fail {
                panic!("synthetic eval failure");
            }
            self.inner.loss(model, data, i)
        }

        fn model_bytes(&self, model: &Self::Model) -> usize {
            self.inner.model_bytes(model)
        }
    }

    #[test]
    fn pre_cancelled_runs_report_distinct_status() {
        // A token cancelled before dispatch drops the run at its root pop:
        // zero leaves complete, all k drop, one task drops — at EVERY
        // worker count (the check happens before any work starts).
        // Sibling runs stay bit-identical to standalone, and the same
        // executor handle stays reusable afterwards.
        let data = SyntheticMixture1d::new(300, 130).generate();
        let l = HistogramDensity::new(-8.0, 8.0, 32);
        let folds = Folds::new(300, 8, 131);
        let alone =
            TreeCvExecutor::new(Strategy::Copy, Ordering::Fixed, 7, 3).run(&l, &data, &folds);
        for threads in [1usize, 3, 8] {
            let mk = || RunSpec {
                learner: &l,
                folds: &folds,
                seed: 7,
                strategy: Strategy::Copy,
                folded: None,
                ctrl: RunCtrl::default(),
            };
            let specs = [mk(), mk(), mk()];
            specs[1].ctrl.cancel();
            let exe = TreeCvExecutor::new(Strategy::Copy, Ordering::Fixed, 7, threads);
            let out = exe.run_many_outcomes(&data, &specs, None);
            match &out[1] {
                RunOutcome::Cancelled { leaves_done, leaves_dropped, tasks_dropped } => {
                    assert_eq!(*leaves_done, 0, "threads={threads}");
                    assert_eq!(*leaves_dropped, 8, "threads={threads}");
                    assert_eq!(*tasks_dropped, 1, "threads={threads}");
                }
                other => panic!("threads={threads}: expected Cancelled, got {other:?}"),
            }
            for i in [0usize, 2] {
                let res = out[i].completed().unwrap_or_else(|| panic!("run {i} completed"));
                assert_eq!(res.per_fold, alone.per_fold, "threads={threads} run {i}");
                assert_eq!(res.ops.model_copies, alone.ops.model_copies, "threads={threads}");
            }
            // Handle reuse after a cancellation: a fresh strict batch on
            // the SAME executor matches the standalone run bit for bit.
            let again = exe.run(&l, &data, &folds);
            assert_eq!(again.per_fold, alone.per_fold, "threads={threads} reuse");
        }
    }

    #[test]
    fn priorities_order_run_starts_on_one_worker() {
        // threads = 1 makes scheduling deterministic: the lone worker pops
        // the highest-priority injector root, runs that tree to completion
        // (LIFO own deque), then admits the next — so completion order IS
        // priority order, FIFO among equals. Results stay bit-identical
        // regardless (asserted against the equal-priority batch).
        let data = SyntheticMixture1d::new(240, 132).generate();
        let l = HistogramDensity::new(-8.0, 8.0, 32);
        let folds = Folds::new(240, 6, 133);
        let mk = |priority: i64| RunSpec {
            learner: &l,
            folds: &folds,
            seed: 11,
            strategy: Strategy::Copy,
            folded: None,
            ctrl: RunCtrl::with_priority(priority),
        };
        let specs = [mk(1), mk(3), mk(2)];
        let order = Mutex::new(Vec::new());
        let record = |i: usize, _out: &RunOutcome| order.lock().push(i);
        let exe = TreeCvExecutor::new(Strategy::Copy, Ordering::Fixed, 11, 1);
        let out = exe.run_many_outcomes(&data, &specs, Some(&record));
        assert_eq!(*order.lock(), vec![1, 2, 0], "highest priority starts first");
        let flat = [mk(0), mk(0), mk(0)];
        let base = exe.run_many_outcomes(&data, &flat, None);
        for (i, (a, b)) in out.iter().zip(&base).enumerate() {
            let (a, b) = (a.completed().unwrap(), b.completed().unwrap());
            assert_eq!(a.per_fold, b.per_fold, "run {i}: priority must not change results");
        }
    }

    #[test]
    fn callback_can_cancel_siblings_mid_batch() {
        // Incremental delivery reacts mid-batch: when run 0 completes, the
        // callback cancels run 1. At threads = 1 with equal priorities the
        // admission order is run order, so run 1's root has not started —
        // the outcome split is deterministic.
        let data = SyntheticMixture1d::new(200, 134).generate();
        let l = HistogramDensity::new(-8.0, 8.0, 16);
        let folds = Folds::new(200, 5, 135);
        let mk = || RunSpec {
            learner: &l,
            folds: &folds,
            seed: 3,
            strategy: Strategy::Copy,
            folded: None,
            ctrl: RunCtrl::default(),
        };
        let specs = [mk(), mk()];
        let cancel_other = |i: usize, _out: &RunOutcome| {
            if i == 0 {
                specs[1].ctrl.cancel();
            }
        };
        let exe = TreeCvExecutor::new(Strategy::Copy, Ordering::Fixed, 3, 1);
        let out = exe.run_many_outcomes(&data, &specs, Some(&cancel_other));
        assert!(out[0].completed().is_some());
        assert!(out[1].is_cancelled());
    }

    #[test]
    fn failed_run_is_isolated_and_reported() {
        // A panicking task is caught on the worker: the run reports
        // Failed with the payload message, its remaining tree is dropped,
        // and sibling runs complete normally under outcomes dispatch.
        let data = SyntheticMixture1d::new(200, 136).generate();
        let good = PanicAtEval { inner: HistogramDensity::new(-8.0, 8.0, 16), fail: false };
        let bad = PanicAtEval { inner: HistogramDensity::new(-8.0, 8.0, 16), fail: true };
        let folds = Folds::new(200, 6, 137);
        let mk = |learner: &'_ PanicAtEval| RunSpec {
            learner,
            folds: &folds,
            seed: 5,
            strategy: Strategy::Copy,
            folded: None,
            ctrl: RunCtrl::default(),
        };
        let specs = [mk(&good), mk(&bad)];
        let exe = TreeCvExecutor::new(Strategy::Copy, Ordering::Fixed, 5, 2);
        let out = exe.run_many_outcomes(&data, &specs, None);
        let alone =
            TreeCvExecutor::new(Strategy::Copy, Ordering::Fixed, 5, 2).run(&good, &data, &folds);
        assert_eq!(out[0].completed().unwrap().per_fold, alone.per_fold);
        match &out[1] {
            RunOutcome::Failed { error } => {
                assert!(error.contains("synthetic eval failure"), "{error}");
            }
            other => panic!("expected Failed, got {other:?}"),
        }
        assert!(specs[1].ctrl.is_cancelled(), "failure implies cancellation of the run");
    }

    #[test]
    #[should_panic(expected = "synthetic eval failure")]
    fn strict_run_many_repanics_on_failure() {
        let data = SyntheticMixture1d::new(120, 138).generate();
        let bad = PanicAtEval { inner: HistogramDensity::new(-8.0, 8.0, 16), fail: true };
        let folds = Folds::new(120, 4, 139);
        let spec = RunSpec {
            learner: &bad,
            folds: &folds,
            seed: 0,
            strategy: Strategy::Copy,
            folded: None,
            ctrl: RunCtrl::default(),
        };
        let _ = TreeCvExecutor::new(Strategy::Copy, Ordering::Fixed, 0, 2)
            .run_many(&data, std::slice::from_ref(&spec));
    }

    #[test]
    fn approx_per_fold_identical_across_worker_counts() {
        // The full model comes from ONE deterministic update phase and
        // each fold's correction is independent, so per-fold estimates
        // are bitwise invariant under the range partition (worker count).
        use crate::data::synth::SyntheticYearMsd;
        use crate::learner::ridge::OnlineRidge;
        let data = SyntheticYearMsd::new(480, 140).generate();
        let l = OnlineRidge::new(90, 1.0);
        let folds = Folds::new(480, 16, 141);
        let base = TreeCvExecutor::new(Strategy::Copy, Ordering::Fixed, 9, 1)
            .run_approx(&l, &data, &folds);
        assert_eq!(base.ops.update_calls, 1, "one full-data training phase");
        assert_eq!(base.ops.points_updated, 480, "Θ(n) row updates, no tree");
        assert_eq!(base.ops.corrections, 16, "one correction per fold");
        assert_eq!(base.ops.evals, 16);
        for threads in [2usize, 3, 8] {
            let got = TreeCvExecutor::new(Strategy::Copy, Ordering::Fixed, 9, threads)
                .run_approx(&l, &data, &folds);
            for (a, b) in base.per_fold.iter().zip(&got.per_fold) {
                assert_eq!(a.to_bits(), b.to_bits(), "threads={threads}");
            }
            assert_eq!(base.ops.corrections, got.ops.corrections, "threads={threads}");
            assert_eq!(base.ops.points_updated, got.ops.points_updated, "threads={threads}");
        }
    }

    #[test]
    fn approx_ridge_tracks_exact_treecv_tightly() {
        // Ridge's correction is an exact stats downdate, so approx LOOCV
        // per-fold estimates match the exact engine to f64 rounding.
        use crate::data::synth::SyntheticYearMsd;
        use crate::learner::ridge::OnlineRidge;
        let data = SyntheticYearMsd::new(200, 142).generate();
        let l = OnlineRidge::new(90, 1.0);
        let folds = Folds::loocv(200);
        let exact = TreeCv::new(Strategy::Copy, Ordering::Fixed, 0).run(&l, &data, &folds);
        let approx = TreeCvExecutor::new(Strategy::Copy, Ordering::Fixed, 0, 4)
            .run_approx(&l, &data, &folds);
        for (f, (a, b)) in approx.per_fold.iter().zip(&exact.per_fold).enumerate() {
            assert!((a - b).abs() <= 1e-8 * (1.0 + b.abs()), "fold {f}: {a} vs {b}");
        }
        assert!(approx.ops.points_updated < exact.ops.points_updated / 4);
    }

    #[test]
    fn approx_erased_matches_generic_bitwise() {
        use crate::data::synth::SyntheticYearMsd;
        use crate::learner::erased::{Erased, ErasedLearner};
        use crate::learner::ridge::OnlineRidge;
        let data = SyntheticYearMsd::new(240, 143).generate();
        let l = OnlineRidge::new(90, 0.5);
        let folds = Folds::new(240, 12, 144);
        let exe = TreeCvExecutor::new(Strategy::Copy, Ordering::Fixed, 5, 3);
        let generic = exe.run_approx(&l, &data, &folds);
        let erased: Box<dyn ErasedLearner> = Erased::boxed(l);
        let spec = ErasedRunSpec {
            learner: &*erased,
            folds: &folds,
            seed: 5,
            strategy: Strategy::Copy,
            folded: None,
            ctrl: RunCtrl::default(),
        };
        let got = exe
            .run_many_approx_erased(&data, std::slice::from_ref(&spec))
            .pop()
            // invariant: one spec in, one result out.
            .expect("one result per spec");
        assert_eq!(generic.per_fold, got.per_fold);
        assert_eq!(generic.estimate.to_bits(), got.estimate.to_bits());
        assert_eq!(generic.ops.corrections, got.ops.corrections);
    }

    #[test]
    #[should_panic(expected = "one-step correction")]
    fn approx_rejects_non_correctable_learner() {
        let data = SyntheticMixture1d::new(80, 145).generate();
        let l = HistogramDensity::new(-8.0, 8.0, 16);
        let folds = Folds::new(80, 4, 146);
        let _ = TreeCvExecutor::new(Strategy::Copy, Ordering::Fixed, 0, 2)
            .run_approx(&l, &data, &folds);
    }

    #[test]
    fn snapshot_cutoff_shape() {
        assert_eq!(snapshot_cutoff(0), 0);
        assert_eq!(snapshot_cutoff(1), 0);
        assert_eq!(snapshot_cutoff(2), 1 + SNAPSHOT_SLACK);
        assert_eq!(snapshot_cutoff(3), 2 + SNAPSHOT_SLACK);
        assert_eq!(snapshot_cutoff(4), 2 + SNAPSHOT_SLACK);
        assert_eq!(snapshot_cutoff(6), 3 + SNAPSHOT_SLACK);
        assert_eq!(snapshot_cutoff(8), 3 + SNAPSHOT_SLACK);
        assert_eq!(snapshot_cutoff(16), 4 + SNAPSHOT_SLACK);
    }
}
