//! Pooled work-stealing TreeCV executor — the engine behind every parallel
//! code path in the crate, now aware of both §4.1 model-preservation
//! strategies.
//!
//! The paper's §4.1 parallelization ("dedicate one thread of computation to
//! each of the data groups") was first implemented by spawning a fresh
//! scoped OS thread at every tree fork (see
//! [`super::parallel::ScopedForkTreeCv`], retained as a baseline). That
//! design churns threads, oversubscribes non-power-of-two machines, and
//! idles once subtrees go unbalanced (which happens whenever `k ∤ n`
//! produces remainder folds). This module replaces it with a persistent
//! executor:
//!
//! * **One worker pool per computation**, sized from
//!   `available_parallelism` (or an explicit `threads` knob) — workers are
//!   spawned once and live for the whole computation, which may be a
//!   single run ([`TreeCvExecutor::run`]) or a whole batch of runs
//!   ([`TreeCvExecutor::run_many`]).
//! * **Tasks are subtrees, not nodes.** Only the nodes above the *snapshot
//!   cutoff* ([`snapshot_cutoff`], ~⌈log₂ workers⌉ + slack levels — the
//!   nodes that actually feed the deques) are forked into independent
//!   tasks; a fork materializes one model snapshot because its two halves
//!   may run concurrently on different workers. Every subtree at or below
//!   the cutoff runs *inline on its worker* through the shared sequential
//!   recursion (`treecv::run_subtree`) with the caller's chosen
//!   [`Strategy`]:
//!   - [`Strategy::SaveRevert`] descends via `update_logged`/`revert` with
//!     **zero** copies below the cutoff, so a run takes `O(workers)` model
//!     snapshots instead of the `k − 1` a Copy run pays — decisive for
//!     LOOCV and for large models (ridge's d² sufficient statistics, KNN's
//!     training-set model), exactly the regime the paper recommends
//!     save/revert for.
//!   - [`Strategy::Copy`] clones at every interior node as before; the
//!     fork/inline split leaves its `k − 1` copy count unchanged.
//! * **Per-worker work-stealing deques.** Owners push/pop LIFO (depth-first
//!   — keeps the live-model count near `O(log k · workers)`); thieves steal
//!   FIFO (breadth-first — steals the largest available subtree, the
//!   classic Blumofe–Leiserson discipline). The cutoff still yields
//!   `~2^slack · workers` independent subtrees, so unbalanced remainders
//!   rebalance instead of leaving a thread idle.
//! * **Model buffer recycling at both granularities.** Fork-node
//!   snapshots draw buffers from a shared pool and `clone_from` into
//!   them; finished subtrees return their (restored) model buffer.
//!   Retention is capped at ~`workers · cutoff` buffers — the fork
//!   levels' steady-state demand, much shallower than the old
//!   `workers · log₂ k` now that deep levels never feed the deques.
//!   Below the cutoff, Copy-strategy snapshots recycle through a
//!   *worker-local* scratch free-list threaded into the shared recursion
//!   (no locking on the hot path), so a Copy run still allocates
//!   O(depth) models per worker, not one per interior node.
//!
//! Because permutation streams are derived per-node from `(seed, node,
//! side)` — never drawn from one sequential stream — the executor produces
//! **bit-identical** estimates to the sequential [`super::treecv::TreeCv`]
//! for the same seed and strategy, under both orderings, for any worker
//! count, whenever the learner's revert is exact (always, under Copy).
//! Learners with approximate revert (the f32 perceptron) are reproduced
//! bit-for-bit at `threads = 1` and to ulp-cascade tolerance above, since
//! forks snapshot where the sequential engine would revert. The tests
//! below and `tests/integration_executor.rs` assert exactly that.
//!
//! **Multi-run batches.** [`TreeCvExecutor::run_many`] feeds the tree
//! tasks of *many* independent runs — every (hyperparameter config ×
//! repetition) of a sweep, each tagged with its `run_id` — through ONE
//! pool: no per-run spawn/teardown, no barrier between runs, and the
//! fork-snapshot buffer pool plus the worker-local scratch free-lists stay
//! warm across runs. Each run keeps its own `(folds, seed, strategy,
//! cutoff)`, so every result is bit-identical to running that
//! configuration alone (`tests/integration_sweep.rs` is the battery). The
//! per-pool [`TreeCvExecutor::pool_spawns`] instrumentation counter lets
//! callers assert the "one pool per batch" claim without serializing
//! against unrelated executors in the process.
//!
//! **Heterogeneous batches.** [`TreeCvExecutor::run_many_erased`] is the
//! same multiplexer over *type-erased* learners
//! ([`crate::learner::erased`]): one batch may mix learner families
//! (Pegasos runs next to GaussianNb next to KnnClassifier), which is what
//! the model-selection harness (`cv::sweep::run_sweep_erased`,
//! `repro select`) schedules. It delegates to [`TreeCvExecutor::run_many`]
//! through [`DynLearner`], so erased runs execute the identical engine
//! code and reproduce their generic counterparts bit for bit
//! (`tests/integration_erased.rs`).

use super::folds::{gather_ordered, node_tags, Folds, Ordering};
use super::treecv::run_subtree;
use super::{CvResult, Strategy};
use crate::data::Dataset;
use crate::learner::erased::{DynLearner, ErasedLearner};
use crate::learner::IncrementalLearner;
use crate::metrics::{OpCounts, Timer};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering as MemOrdering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Extra fork levels beyond ⌈log₂ workers⌉: each level doubles the subtree
/// count, so slack 2 yields ~4 independent subtrees per worker — enough
/// over-decomposition for stealing to absorb remainder-fold imbalance,
/// while keeping the per-run snapshot count at `O(workers)`.
const SNAPSHOT_SLACK: usize = 2;

/// First tree depth that is NOT forked into independent tasks: nodes at
/// depth `< snapshot_cutoff(threads)` fork (one model snapshot each, at
/// most `2^cutoff − 1` per run); subtrees rooted at the cutoff run inline
/// on their worker with the engine's strategy. `threads <= 1` forks
/// nothing — the whole tree runs inline, exactly the sequential engine.
pub fn snapshot_cutoff(threads: usize) -> usize {
    if threads <= 1 {
        return 0;
    }
    // ⌈log₂ threads⌉ for threads ≥ 2.
    let ceil_log2 = (usize::BITS - (threads - 1).leading_zeros()) as usize;
    ceil_log2 + SNAPSHOT_SLACK
}

/// The pooled work-stealing TreeCV engine.
#[derive(Debug, Clone)]
pub struct TreeCvExecutor {
    /// Model-preservation strategy (paper §4.1): applied verbatim inside
    /// every inline subtree; fork nodes above the cutoff always snapshot
    /// (their halves run concurrently), which is the only place a
    /// SaveRevert run still copies.
    pub strategy: Strategy,
    /// Fixed vs randomized feeding order (paper §5).
    pub ordering: Ordering,
    /// Seed for the per-node permutation streams (ignored under Fixed).
    pub seed: u64,
    /// Worker-pool size. `1` runs the whole tree inline on the calling
    /// thread (no spawning, no forking — the sequential engine exactly);
    /// capped at `k` per run.
    pub threads: usize,
    /// Per-pool spawn counter: bumped once per [`Self::run_many`] batch
    /// that actually spawns worker threads (inline single-worker batches
    /// spawn nothing). Shared by clones of this executor — the handle IS
    /// the counter — and read via [`Self::pool_spawns`]. Replaces the old
    /// process-wide counter, so concurrent executors (e.g. parallel unit
    /// tests) no longer perturb each other's accounting.
    spawns: Arc<AtomicU64>,
}

/// One run of a multi-run batch ([`TreeCvExecutor::run_many`]): the full
/// TreeCV computation of `learner` under `folds`, with its own
/// permutation-stream seed and model-preservation strategy. A run's
/// result is a pure function of `(learner, data, folds, strategy,
/// ordering, seed)` — never of pool size or scheduling — so batching runs
/// through a shared pool reproduces each standalone run bit for bit.
pub struct RunSpec<'a, L: IncrementalLearner> {
    pub learner: &'a L,
    pub folds: &'a Folds,
    /// Seed for this run's per-node permutation streams.
    pub seed: u64,
    /// Model-preservation strategy for this run's inline subtrees.
    pub strategy: Strategy,
}

/// [`RunSpec`] over the type-erased learner layer: the element of a
/// *heterogeneous* batch ([`TreeCvExecutor::run_many_erased`]), where each
/// run may belong to a different learner family. Same per-run contract as
/// the generic spec: the result is a pure function of
/// `(learner, data, folds, strategy, ordering, seed)`.
pub struct ErasedRunSpec<'a> {
    pub learner: &'a dyn ErasedLearner,
    pub folds: &'a Folds,
    /// Seed for this run's per-node permutation streams.
    pub seed: u64,
    /// Model-preservation strategy for this run's inline subtrees.
    pub strategy: Strategy,
}

/// One unit of executor work: the TreeCV subtree of run `run` rooted at
/// `(s, e)` plus the model trained on every chunk outside `s..=e`.
/// `depth` decides whether the node forks (above the run's snapshot
/// cutoff) or runs inline. Root tasks carry `None` and init their model
/// lazily on the worker that pops them — a batch of R runs would
/// otherwise materialize R full models up front (ruinous for
/// training-set-sized models like k-NN's on a wide sweep).
struct Task<M> {
    run: usize,
    s: usize,
    e: usize,
    depth: usize,
    model: Option<M>,
}

/// Per-run shared state: the run's inputs plus its output slots.
struct RunShared<'a, L: IncrementalLearner> {
    learner: &'a L,
    folds: &'a Folds,
    seed: u64,
    strategy: Strategy,
    /// First non-forking depth for THIS run, computed from the engine's
    /// `threads` knob and the run's own k exactly as a standalone
    /// [`TreeCvExecutor::run`] computes it — that is what keeps every
    /// batched run bit-identical to its standalone counterpart.
    cutoff: usize,
    /// Leaf count (`folds.k()`).
    k: usize,
    /// Per-fold outputs; distinct indices are written exactly once each.
    per_fold: Mutex<Vec<f64>>,
    /// Leaves of this run completed so far (done at `k`).
    leaves_done: AtomicUsize,
    /// Work counters, merged from every worker's run-local tallies.
    ops: Mutex<OpCounts>,
    /// Elapsed time from batch start when the run's last leaf landed.
    wall: Mutex<Duration>,
}

/// State shared by the worker pool for one batch of runs.
struct Shared<'a, L: IncrementalLearner> {
    /// One deque per worker. Owner pushes/pops the back; thieves pop the
    /// front. A plain mutexed deque keeps the implementation obviously
    /// correct; contention is negligible at subtree granularity.
    deques: Vec<Mutex<VecDeque<Task<L::Model>>>>,
    /// Recycled model buffers (`clone_from` targets for fork-node
    /// snapshots), shared by every run in the batch — later runs start
    /// with a warm pool. Retention is capped at [`Shared::pool_cap`] so
    /// LOOCV-scale batches don't accumulate dead buffers.
    pool: Mutex<Vec<L::Model>>,
    /// Maximum buffers the pool retains (~ workers · max cutoff, the fork
    /// levels' steady-state demand, doubled when several runs are in
    /// flight); excess buffers are dropped instead.
    pool_cap: usize,
    /// The batch's runs, indexed by [`Task::run`].
    runs: Vec<RunShared<'a, L>>,
    /// Total leaf count across all runs.
    leaves_total: usize,
    /// Leaves completed so far across all runs.
    leaves_done: AtomicUsize,
    /// Set when all leaves are done (or a worker panicked) so idle workers
    /// exit their steal loop.
    done: AtomicBool,
    /// Batch clock (per-run completion times are read off it).
    timer: Timer,
}

/// Sets the shared `done` flag if its thread unwinds, so a panicking
/// worker cannot leave the rest of the pool spinning forever.
struct PanicSignal<'a> {
    done: &'a AtomicBool,
}

impl Drop for PanicSignal<'_> {
    fn drop(&mut self) {
        if std::thread::panicking() {
            self.done.store(true, MemOrdering::Release);
        }
    }
}

impl TreeCvExecutor {
    pub fn new(strategy: Strategy, ordering: Ordering, seed: u64, threads: usize) -> Self {
        Self {
            strategy,
            ordering,
            seed,
            threads: threads.max(1),
            spawns: Arc::new(AtomicU64::new(0)),
        }
    }

    /// Worker pools this executor (and its clones — they share the
    /// counter) has spawned so far: one per multi-worker [`Self::run_many`]
    /// batch, zero for inline (`threads = 1`) batches. A whole sweep of
    /// C configs × r repetitions reads 1 here, where dispatching the runs
    /// one batch at a time reads C·r — the sweep tests assert both.
    pub fn pool_spawns(&self) -> u64 {
        self.spawns.load(MemOrdering::Relaxed)
    }

    /// Pool sized to the machine's available parallelism (no rounding to a
    /// power of two — any worker count schedules fully).
    pub fn with_available_parallelism(strategy: Strategy, ordering: Ordering, seed: u64) -> Self {
        let threads = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
        Self::new(strategy, ordering, seed, threads)
    }

    /// Resolve a user-facing `threads` knob (`0` = machine parallelism)
    /// into a pool — the single resolution every harness (repetition,
    /// repeated CV, sweep) routes through, so the knob is honored
    /// identically everywhere and never silently ignored. The engine seed
    /// is left at 0: the batching harnesses pass per-run seeds via
    /// [`RunSpec`], which [`Self::run_many`] uses instead.
    pub fn with_threads_knob(strategy: Strategy, ordering: Ordering, threads: usize) -> Self {
        if threads == 0 {
            Self::with_available_parallelism(strategy, ordering, 0)
        } else {
            Self::new(strategy, ordering, 0, threads)
        }
    }

    /// Process one task: fork nodes above the run's cutoff run both update
    /// phases (one snapshot) and enqueue the two child subtrees on this
    /// worker's own deque; everything else — leaves and whole subtrees at
    /// or below the cutoff — runs inline through the shared sequential
    /// recursion with the run's strategy.
    fn process<L>(
        &self,
        wid: usize,
        task: Task<L::Model>,
        shared: &Shared<'_, L>,
        data: &Dataset,
        ops_by_run: &mut [OpCounts],
        scratch: &mut Vec<L::Model>,
    ) where
        L: IncrementalLearner + Sync,
    {
        let Task { run, s, e, depth, model } = task;
        let rs = &shared.runs[run];
        let ops = &mut ops_by_run[run];
        // Root tasks init lazily (pure, so scheduling cannot affect it).
        let mut model = model.unwrap_or_else(|| rs.learner.init());
        if s < e && depth < rs.cutoff {
            let m = (s + e) / 2;
            // Node tags shared with the sequential engine.
            let (tag_right, tag_left) = node_tags(s, e);

            let right =
                gather_ordered(rs.folds, m + 1, e, rs.seed, self.ordering, tag_right, ops);
            let left = gather_ordered(rs.folds, s, m, rs.seed, self.ordering, tag_left, ops);
            ops.update_calls += 2;
            ops.points_updated += (right.len() + left.len()) as u64;

            // The two halves may run concurrently on different workers, so
            // a fork must snapshot regardless of strategy — this is the
            // only copy a SaveRevert run pays. The snapshot goes into a
            // pooled buffer (clone_from reuses its storage) when one is
            // available.
            let recycled = shared.pool.lock().unwrap().pop();
            let mut sibling = match recycled {
                Some(mut buf) => {
                    buf.clone_from(&model);
                    buf
                }
                None => model.clone(),
            };
            ops.model_copies += 1;
            ops.bytes_copied += rs.learner.model_bytes(&model) as u64;

            // As in Algorithm 1: the model fed the *second* group serves
            // the left child (s, m); the model fed the *first* group
            // serves the right child (m+1, e).
            rs.learner.update(&mut model, data, &right);
            rs.learner.update(&mut sibling, data, &left);

            let mut dq = shared.deques[wid].lock().unwrap();
            dq.push_back(Task { run, s, e: m, depth: depth + 1, model: Some(model) });
            dq.push_back(Task { run, s: m + 1, e, depth: depth + 1, model: Some(sibling) });
            return;
        }

        // Inline subtree: the shared sequential recursion, under the run's
        // strategy, into a local buffer (one per-fold lock per subtree
        // instead of one per leaf). Copy-strategy snapshots inside the
        // subtree recycle through this worker's scratch free-list, which
        // lives for the whole batch — tasks of every run share it.
        let mut local = vec![0.0; e - s + 1];
        run_subtree(
            rs.learner,
            data,
            rs.folds,
            rs.strategy,
            self.ordering,
            rs.seed,
            &mut model,
            s,
            e,
            s,
            &mut local,
            ops,
            scratch,
        );
        rs.per_fold.lock().unwrap()[s..=e].copy_from_slice(&local);
        // Recycle the model storage for future fork-node snapshots
        // (bounded — beyond the cap, just drop it).
        {
            let mut pool = shared.pool.lock().unwrap();
            if pool.len() < shared.pool_cap {
                pool.push(model);
            }
        }
        let leaves = e - s + 1;
        if rs.leaves_done.fetch_add(leaves, MemOrdering::AcqRel) + leaves == rs.k {
            *rs.wall.lock().unwrap() = shared.timer.elapsed();
        }
        let done_before = shared.leaves_done.fetch_add(leaves, MemOrdering::AcqRel);
        if done_before + leaves == shared.leaves_total {
            shared.done.store(true, MemOrdering::Release);
        }
    }

    /// Worker loop: drain own deque LIFO, steal FIFO when empty, exit once
    /// every leaf of every run is recorded. Counters are tallied per run
    /// locally and merged into the shared per-run totals on exit.
    fn worker<L>(&self, wid: usize, shared: &Shared<'_, L>, data: &Dataset)
    where
        L: IncrementalLearner + Sync,
    {
        let _signal = PanicSignal { done: &shared.done };
        let mut ops_by_run: Vec<OpCounts> = vec![OpCounts::default(); shared.runs.len()];
        let n_workers = shared.deques.len();
        // Worker-local free-list for inline-subtree Copy snapshots; lives
        // across tasks — and across runs — so buffers recycle for the
        // whole batch (held count is bounded by the subtree recursion
        // depth, ≤ ⌈log₂ k⌉ of the deepest run).
        let mut scratch: Vec<L::Model> = Vec::new();
        // Consecutive empty steal sweeps; drives the idle backoff below.
        let mut dry_sweeps = 0u32;
        loop {
            let task = {
                let own = shared.deques[wid].lock().unwrap().pop_back();
                match own {
                    Some(t) => Some(t),
                    None => (1..n_workers).find_map(|offset| {
                        let victim = (wid + offset) % n_workers;
                        shared.deques[victim].lock().unwrap().pop_front()
                    }),
                }
            };
            match task {
                Some(t) => {
                    dry_sweeps = 0;
                    self.process(wid, t, shared, data, &mut ops_by_run, &mut scratch);
                }
                None => {
                    if shared.done.load(MemOrdering::Acquire) {
                        break;
                    }
                    // Tiered backoff: spin-yield briefly (work usually
                    // appears within a node's two updates), then sleep so
                    // idle workers stop hammering the deque mutexes during
                    // long serial phases (e.g. the root node's O(n) updates
                    // while only one task exists).
                    dry_sweeps += 1;
                    if dry_sweeps < 16 {
                        std::thread::yield_now();
                    } else {
                        std::thread::sleep(std::time::Duration::from_micros(100));
                    }
                }
            }
        }
        // Publish this worker's tallies into each run's shared totals.
        for (rs, ops) in shared.runs.iter().zip(&ops_by_run) {
            rs.ops.lock().unwrap().merge(ops);
        }
    }

    /// Run the executor engine on a single computation. (Not part of the
    /// [`super::CvEngine`] trait because it needs `L: Sync` bounds the
    /// trait doesn't impose.)
    pub fn run<L>(&self, learner: &L, data: &Dataset, folds: &Folds) -> CvResult
    where
        L: IncrementalLearner + Sync,
        L::Model: Send,
    {
        let spec = RunSpec { learner, folds, seed: self.seed, strategy: self.strategy };
        self.run_many(data, std::slice::from_ref(&spec))
            .pop()
            .expect("run_many returns one result per run")
    }

    /// Run a whole batch of TreeCV computations — e.g. every
    /// (hyperparameter config × repetition) run of a sweep — through ONE
    /// persistent worker pool. Tasks from all runs share the deques, the
    /// fork-snapshot buffer pool and the worker-local scratch free-lists;
    /// there is no barrier between runs and no per-run spawn/teardown.
    ///
    /// Each run keeps its own snapshot cutoff (derived from the engine's
    /// `threads` knob and the run's own k, exactly as a standalone
    /// [`Self::run`] derives it) and its own `(seed, strategy)` from the
    /// spec — the engine's `strategy`/`seed` fields are not consulted —
    /// so result `i` is bit-identical to running `runs[i]` alone at the
    /// same `threads` setting. Results come back in run order; each
    /// `wall` is the elapsed time from batch start to the run's last
    /// leaf.
    pub fn run_many<L>(&self, data: &Dataset, runs: &[RunSpec<'_, L>]) -> Vec<CvResult>
    where
        L: IncrementalLearner + Sync,
        L::Model: Send,
    {
        if runs.is_empty() {
            return Vec::new();
        }
        let leaves_total: usize = runs.iter().map(|r| r.folds.k()).sum();
        let threads = self.threads.max(1).min(leaves_total);
        let cutoff_of = |k: usize| snapshot_cutoff(self.threads.max(1).min(k));
        let max_cutoff = runs.iter().map(|r| cutoff_of(r.folds.k())).max().unwrap_or(0);
        // Steady-state snapshot demand is one buffer per live fork level
        // per worker; when several runs are in flight, stealing
        // interleaves their fork frontiers, so the retention cap doubles.
        let pool_cap = threads * (max_cutoff + 2) * if runs.len() > 1 { 2 } else { 1 };
        let shared: Shared<'_, L> = Shared {
            deques: (0..threads).map(|_| Mutex::new(VecDeque::new())).collect(),
            pool: Mutex::new(Vec::new()),
            pool_cap,
            runs: runs
                .iter()
                .map(|r| RunShared {
                    learner: r.learner,
                    folds: r.folds,
                    seed: r.seed,
                    strategy: r.strategy,
                    cutoff: cutoff_of(r.folds.k()),
                    k: r.folds.k(),
                    per_fold: Mutex::new(vec![0.0; r.folds.k()]),
                    leaves_done: AtomicUsize::new(0),
                    ops: Mutex::new(OpCounts::default()),
                    wall: Mutex::new(Duration::ZERO),
                })
                .collect(),
            leaves_total,
            leaves_done: AtomicUsize::new(0),
            done: AtomicBool::new(false),
            timer: Timer::start(),
        };
        // Seed the root tasks round-robin so a batch starts spread across
        // the deques. Placement never affects results — only who steals
        // first — and a single run lands on deque 0 as before. Root
        // models are `None` (lazily inited on first pop) so a wide batch
        // doesn't hold every run's full model before work starts.
        for (i, r) in runs.iter().enumerate() {
            shared.deques[i % threads].lock().unwrap().push_back(Task {
                run: i,
                s: 0,
                e: r.folds.k() - 1,
                depth: 0,
                model: None,
            });
        }

        if threads == 1 {
            // Inline on the calling thread: zero spawn cost, and exactly
            // the sequential engine's work.
            self.worker(0, &shared, data);
        } else {
            self.spawns.fetch_add(1, MemOrdering::Relaxed);
            let shared_ref = &shared;
            std::thread::scope(|scope| {
                let handles: Vec<_> = (0..threads)
                    .map(|wid| scope.spawn(move || self.worker(wid, shared_ref, data)))
                    .collect();
                for handle in handles {
                    handle.join().expect("executor worker panicked");
                }
            });
        }

        shared
            .runs
            .into_iter()
            .map(|rs| {
                CvResult::from_folds(
                    rs.per_fold.into_inner().unwrap(),
                    rs.ops.into_inner().unwrap(),
                    rs.wall.into_inner().unwrap(),
                )
            })
            .collect()
    }

    /// Run a single type-erased computation (see [`Self::run_many_erased`]
    /// for the batch form and the equivalence contract).
    pub fn run_erased(
        &self,
        learner: &dyn ErasedLearner,
        data: &Dataset,
        folds: &Folds,
    ) -> CvResult {
        let spec =
            ErasedRunSpec { learner, folds, seed: self.seed, strategy: self.strategy };
        self.run_many_erased(data, std::slice::from_ref(&spec))
            .pop()
            .expect("run_many_erased returns one result per run")
    }

    /// Run a **heterogeneous** batch — runs of *different* learner
    /// families — through ONE persistent worker pool. This is
    /// [`Self::run_many`] over the type-erased learner layer: each spec
    /// wraps its `&dyn ErasedLearner` in a [`DynLearner`] adapter and the
    /// whole batch executes through the identical generic machinery
    /// (deques, fork-snapshot buffer pool, worker-local scratch), so
    /// result `i` is bit-identical to running `runs[i]`'s learner alone
    /// through the generic path at the same `threads` setting —
    /// `tests/integration_erased.rs` pins this per learner. Pooled model
    /// buffers recycle across families via `ErasedModel::clone_from`
    /// (storage-reusing on a type match, wholesale replacement otherwise).
    pub fn run_many_erased(&self, data: &Dataset, runs: &[ErasedRunSpec<'_>]) -> Vec<CvResult> {
        let wrapped: Vec<DynLearner<'_>> = runs.iter().map(|r| DynLearner(r.learner)).collect();
        let specs: Vec<RunSpec<'_, DynLearner<'_>>> = wrapped
            .iter()
            .zip(runs)
            .map(|(learner, r)| RunSpec {
                learner,
                folds: r.folds,
                seed: r.seed,
                strategy: r.strategy,
            })
            .collect();
        self.run_many(data, &specs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cv::treecv::TreeCv;
    use crate::cv::{CvEngine, Strategy};
    use crate::data::synth::{SyntheticCovertype, SyntheticMixture1d};
    use crate::learner::histdensity::HistogramDensity;
    use crate::learner::pegasos::Pegasos;

    #[test]
    fn matches_sequential_fixed_order() {
        let data = SyntheticCovertype::new(2_000, 91).generate();
        let l = Pegasos::new(54, 1e-4);
        let folds = Folds::new(2_000, 16, 92);
        let seq = TreeCv::new(Strategy::Copy, Ordering::Fixed, 5).run(&l, &data, &folds);
        let exe =
            TreeCvExecutor::new(Strategy::Copy, Ordering::Fixed, 5, 8).run(&l, &data, &folds);
        assert_eq!(seq.per_fold, exe.per_fold);
        assert_eq!(seq.estimate, exe.estimate);
    }

    #[test]
    fn matches_sequential_randomized_order() {
        // Per-node RNG derivation makes randomized ordering identical too.
        let data = SyntheticCovertype::new(1_000, 93).generate();
        let l = Pegasos::new(54, 1e-4);
        let folds = Folds::new(1_000, 8, 94);
        let seq = TreeCv::new(Strategy::Copy, Ordering::Randomized, 7).run(&l, &data, &folds);
        let exe =
            TreeCvExecutor::new(Strategy::Copy, Ordering::Randomized, 7, 4).run(&l, &data, &folds);
        assert_eq!(seq.per_fold, exe.per_fold);
    }

    #[test]
    fn every_worker_count_is_bit_identical() {
        // Including non-power-of-two pools, which the scoped-fork engine
        // could never use, and pools larger than k (internally capped).
        let data = SyntheticCovertype::new(900, 95).generate();
        let l = Pegasos::new(54, 1e-3);
        let folds = Folds::new(900, 13, 96); // remainder folds: k ∤ n
        let seq = TreeCv::new(Strategy::Copy, Ordering::Fixed, 3).run(&l, &data, &folds);
        for threads in [1usize, 2, 3, 5, 6, 7, 12, 16, 64] {
            let exe = TreeCvExecutor::new(Strategy::Copy, Ordering::Fixed, 3, threads)
                .run(&l, &data, &folds);
            assert_eq!(seq.per_fold, exe.per_fold, "threads={threads}");
        }
    }

    #[test]
    fn save_revert_matches_sequential_at_every_worker_count() {
        // Exact-revert learner: the strategy-aware executor must reproduce
        // sequential SaveRevert bit-for-bit at any pool size.
        let data = SyntheticMixture1d::new(700, 89).generate();
        let l = HistogramDensity::new(-8.0, 8.0, 32);
        let folds = Folds::new(700, 11, 88); // remainder folds
        let seq = TreeCv::new(Strategy::SaveRevert, Ordering::Fixed, 4).run(&l, &data, &folds);
        for threads in [1usize, 2, 3, 5, 8, 16] {
            let exe = TreeCvExecutor::new(Strategy::SaveRevert, Ordering::Fixed, 4, threads)
                .run(&l, &data, &folds);
            assert_eq!(seq.per_fold, exe.per_fold, "threads={threads}");
            assert_eq!(seq.ops.points_updated, exe.ops.points_updated, "threads={threads}");
            assert_eq!(seq.ops.evals, exe.ops.evals, "threads={threads}");
        }
    }

    #[test]
    fn save_revert_copies_only_at_forks() {
        // k = 64 LOOCV-ish tree: Copy pays k−1 = 63 snapshots; SaveRevert
        // pays at most 2^cutoff − 1 fork snapshots, restores carry the
        // rest (2 per non-forked interior node).
        let data = SyntheticMixture1d::new(640, 87).generate();
        let l = HistogramDensity::new(-8.0, 8.0, 32);
        let folds = Folds::new(640, 64, 86);
        for threads in [1usize, 3, 6] {
            let exe = TreeCvExecutor::new(Strategy::SaveRevert, Ordering::Fixed, 0, threads)
                .run(&l, &data, &folds);
            let max_forks = (1u64 << snapshot_cutoff(threads)) - 1;
            assert!(
                exe.ops.model_copies <= max_forks,
                "threads={threads}: {} copies > {max_forks} fork nodes",
                exe.ops.model_copies
            );
            assert!(exe.ops.model_copies < 63, "threads={threads}");
            assert_eq!(exe.ops.model_restores, 2 * (63 - exe.ops.model_copies));
        }
    }

    #[test]
    fn single_thread_is_inline_and_identical() {
        let data = SyntheticMixture1d::new(300, 97).generate();
        let l = HistogramDensity::new(-8.0, 8.0, 32);
        let folds = Folds::new(300, 10, 98);
        let exe =
            TreeCvExecutor::new(Strategy::Copy, Ordering::Fixed, 0, 1).run(&l, &data, &folds);
        let seq = TreeCv::default().run(&l, &data, &folds);
        assert_eq!(exe.per_fold, seq.per_fold);
    }

    #[test]
    fn total_work_unchanged_by_pool_size() {
        let data = SyntheticMixture1d::new(512, 99).generate();
        let l = HistogramDensity::new(-8.0, 8.0, 32);
        let folds = Folds::new(512, 32, 100);
        let seq = TreeCv::default().run(&l, &data, &folds);
        let exe =
            TreeCvExecutor::new(Strategy::Copy, Ordering::Fixed, 0, 6).run(&l, &data, &folds);
        assert_eq!(seq.ops.points_updated, exe.ops.points_updated);
        assert_eq!(seq.ops.evals, exe.ops.evals);
        assert_eq!(seq.ops.update_calls, exe.ops.update_calls);
        // One snapshot per interior node, exactly as the Copy strategy:
        // the fork/inline split recycles storage but never changes the
        // Copy-strategy count.
        assert_eq!(exe.ops.model_copies, 31);
    }

    #[test]
    fn loocv_smallest_and_degenerate_k() {
        // k = 1: the root is a leaf; the init model is evaluated directly.
        let data = SyntheticMixture1d::new(40, 101).generate();
        let l = HistogramDensity::new(-8.0, 8.0, 16);
        let folds = Folds::new(40, 1, 102);
        let exe =
            TreeCvExecutor::new(Strategy::Copy, Ordering::Fixed, 0, 4).run(&l, &data, &folds);
        assert_eq!(exe.per_fold.len(), 1);
        assert_eq!(exe.ops.evals, 1);
        // k = n (LOOCV) with a multi-worker pool, both strategies.
        let folds = Folds::loocv(40);
        let seq = TreeCv::default().run(&l, &data, &folds);
        let exe =
            TreeCvExecutor::new(Strategy::Copy, Ordering::Fixed, 0, 4).run(&l, &data, &folds);
        assert_eq!(seq.per_fold, exe.per_fold);
        let seq = TreeCv::new(Strategy::SaveRevert, Ordering::Fixed, 0).run(&l, &data, &folds);
        let exe = TreeCvExecutor::new(Strategy::SaveRevert, Ordering::Fixed, 0, 4)
            .run(&l, &data, &folds);
        assert_eq!(seq.per_fold, exe.per_fold);
    }

    #[test]
    fn run_many_batch_matches_standalone_runs() {
        // Three λ configs × two partitionings through ONE batch: every
        // result must be bit-identical to its standalone run at the same
        // threads setting, counters included.
        let data = SyntheticCovertype::new(800, 103).generate();
        let learners = [Pegasos::new(54, 1e-3), Pegasos::new(54, 1e-4), Pegasos::new(54, 1e-5)];
        let folds = [Folds::new(800, 9, 104), Folds::new(800, 9, 105)];
        let mut specs = Vec::new();
        for learner in &learners {
            for (r, f) in folds.iter().enumerate() {
                let spec = RunSpec {
                    learner,
                    folds: f,
                    seed: 60 + r as u64,
                    strategy: Strategy::Copy,
                };
                specs.push(spec);
            }
        }
        let exe = TreeCvExecutor::new(Strategy::Copy, Ordering::Fixed, 0, 4);
        let batch = exe.run_many(&data, &specs);
        assert_eq!(batch.len(), 6);
        for (i, (spec, got)) in specs.iter().zip(&batch).enumerate() {
            let alone = TreeCvExecutor::new(spec.strategy, Ordering::Fixed, spec.seed, 4)
                .run(spec.learner, &data, spec.folds);
            assert_eq!(got.per_fold, alone.per_fold, "run {i}");
            assert_eq!(got.estimate, alone.estimate, "run {i}");
            assert_eq!(got.ops.points_updated, alone.ops.points_updated, "run {i}");
            assert_eq!(got.ops.model_copies, alone.ops.model_copies, "run {i}");
        }
    }

    #[test]
    fn run_many_mixes_strategies_and_fold_counts() {
        // A batch may mix strategies and ks (k = 1 runs are single-leaf);
        // each run must still reproduce the sequential engine under its
        // own (strategy, folds, seed).
        let data = SyntheticMixture1d::new(400, 106).generate();
        let l = HistogramDensity::new(-8.0, 8.0, 32);
        let folds = [Folds::new(400, 7, 107), Folds::new(400, 16, 108), Folds::new(400, 1, 109)];
        let strategies = [Strategy::SaveRevert, Strategy::Copy, Strategy::Copy];
        let specs: Vec<RunSpec<'_, HistogramDensity>> = folds
            .iter()
            .zip(strategies)
            .enumerate()
            .map(|(i, (f, strategy))| RunSpec { learner: &l, folds: f, seed: i as u64, strategy })
            .collect();
        let batch =
            TreeCvExecutor::new(Strategy::Copy, Ordering::Randomized, 0, 3).run_many(&data, &specs);
        for (i, (spec, got)) in specs.iter().zip(&batch).enumerate() {
            let seq = TreeCv::new(spec.strategy, Ordering::Randomized, spec.seed)
                .run(&l, &data, spec.folds);
            assert_eq!(got.per_fold, seq.per_fold, "run {i}");
            assert_eq!(got.ops.points_updated, seq.ops.points_updated, "run {i}");
            assert_eq!(got.ops.evals, seq.ops.evals, "run {i}");
        }
    }

    #[test]
    fn run_many_empty_batch_is_empty() {
        let data = SyntheticMixture1d::new(10, 110).generate();
        let exe = TreeCvExecutor::new(Strategy::Copy, Ordering::Fixed, 0, 4);
        let out = exe.run_many::<HistogramDensity>(&data, &[]);
        assert!(out.is_empty());
    }

    #[test]
    fn per_pool_spawn_counter_is_exact_and_local() {
        let data = SyntheticMixture1d::new(200, 111).generate();
        let l = HistogramDensity::new(-8.0, 8.0, 16);
        let folds = Folds::new(200, 8, 112);
        let exe = TreeCvExecutor::new(Strategy::Copy, Ordering::Fixed, 0, 4);
        assert_eq!(exe.pool_spawns(), 0);
        let _ = exe.run(&l, &data, &folds);
        let _ = exe.run(&l, &data, &folds);
        assert_eq!(exe.pool_spawns(), 2, "one spawn per multi-worker batch");
        // Clones share the handle: the counter identifies the pool config,
        // not the clone.
        let clone = exe.clone();
        let _ = clone.run(&l, &data, &folds);
        assert_eq!(exe.pool_spawns(), 3);
        // Inline (threads = 1) batches never spawn.
        let inline = TreeCvExecutor::new(Strategy::Copy, Ordering::Fixed, 0, 1);
        let _ = inline.run(&l, &data, &folds);
        assert_eq!(inline.pool_spawns(), 0);
        // Fresh executors start at zero — the counter is per pool, not
        // process-wide.
        assert_eq!(TreeCvExecutor::new(Strategy::Copy, Ordering::Fixed, 0, 4).pool_spawns(), 0);
    }

    #[test]
    fn erased_heterogeneous_batch_matches_generic_standalone() {
        // Three different learner families through ONE pool; every result
        // must be bit-identical to the generic executor run of that
        // learner alone at the same threads setting, counters included.
        use crate::learner::erased::{Erased, ErasedLearner};
        use crate::learner::knn::KnnClassifier;
        use crate::learner::perceptron::Perceptron;
        let data = SyntheticCovertype::new(400, 113).generate();
        let folds = Folds::new(400, 9, 114);
        let pegasos = Pegasos::new(54, 1e-3);
        let perceptron = Perceptron::new(54);
        let knn = KnnClassifier::new(54, 3);
        let erased: [Box<dyn ErasedLearner>; 3] = [
            Erased::boxed(pegasos.clone()),
            Erased::boxed(perceptron.clone()),
            Erased::boxed(knn.clone()),
        ];
        let specs: Vec<ErasedRunSpec<'_>> = erased
            .iter()
            .enumerate()
            .map(|(i, l)| ErasedRunSpec {
                learner: &**l,
                folds: &folds,
                seed: 70 + i as u64,
                strategy: Strategy::Copy,
            })
            .collect();
        let exe = TreeCvExecutor::new(Strategy::Copy, Ordering::Fixed, 0, 4);
        let batch = exe.run_many_erased(&data, &specs);
        assert_eq!(exe.pool_spawns(), 1, "heterogeneous batch uses one pool");
        let alone =
            |i: usize| TreeCvExecutor::new(Strategy::Copy, Ordering::Fixed, 70 + i as u64, 4);
        let generics = [
            alone(0).run(&pegasos, &data, &folds),
            alone(1).run(&perceptron, &data, &folds),
            alone(2).run(&knn, &data, &folds),
        ];
        for (i, (got, want)) in batch.iter().zip(&generics).enumerate() {
            assert_eq!(got.per_fold, want.per_fold, "run {i}");
            assert_eq!(got.estimate.to_bits(), want.estimate.to_bits(), "run {i}");
            assert_eq!(got.ops.points_updated, want.ops.points_updated, "run {i}");
            assert_eq!(got.ops.model_copies, want.ops.model_copies, "run {i}");
            assert_eq!(got.ops.bytes_copied, want.ops.bytes_copied, "run {i}");
        }
    }

    #[test]
    fn snapshot_cutoff_shape() {
        assert_eq!(snapshot_cutoff(0), 0);
        assert_eq!(snapshot_cutoff(1), 0);
        assert_eq!(snapshot_cutoff(2), 1 + SNAPSHOT_SLACK);
        assert_eq!(snapshot_cutoff(3), 2 + SNAPSHOT_SLACK);
        assert_eq!(snapshot_cutoff(4), 2 + SNAPSHOT_SLACK);
        assert_eq!(snapshot_cutoff(6), 3 + SNAPSHOT_SLACK);
        assert_eq!(snapshot_cutoff(8), 3 + SNAPSHOT_SLACK);
        assert_eq!(snapshot_cutoff(16), 4 + SNAPSHOT_SLACK);
    }
}
