//! Pooled work-stealing TreeCV executor — the engine behind every parallel
//! code path in the crate.
//!
//! The paper's §4.1 parallelization ("dedicate one thread of computation to
//! each of the data groups") was first implemented by spawning a fresh
//! scoped OS thread at every tree fork (see
//! [`super::parallel::ScopedForkTreeCv`], retained as a baseline). That
//! design churns threads, oversubscribes non-power-of-two machines, and
//! idles once subtrees go unbalanced (which happens whenever `k ∤ n`
//! produces remainder folds). This module replaces it with a persistent
//! executor:
//!
//! * **One worker pool per run**, sized from `available_parallelism` (or an
//!   explicit `threads` knob) — workers are spawned once and live for the
//!   whole computation.
//! * **Tree nodes are tasks.** A task carries `(s, e, model)` where the
//!   model is trained on every chunk outside `s..=e`. Processing an
//!   interior node performs both of the node's update phases and pushes the
//!   two child tasks; a leaf evaluates and records `R̂_s`.
//! * **Per-worker work-stealing deques.** Owners push/pop LIFO (depth-first
//!   — keeps the live-model count near `O(log k · workers)`); thieves steal
//!   FIFO (breadth-first — steals the largest available subtree, the
//!   classic Blumofe–Leiserson discipline). Unbalanced subtrees therefore
//!   rebalance automatically instead of leaving a thread idle.
//! * **A model buffer pool.** The Copy strategy's `k−1` interior-node
//!   snapshots draw buffers from a shared pool and `clone_from` into them,
//!   so model storage is recycled from finished leaves instead of freshly
//!   allocated at every fork. Retention is capped at ~`workers · log₂ k`
//!   buffers, so LOOCV-scale runs never hold O(k) models at once.
//!
//! Because permutation streams are derived per-node from `(seed, node,
//! side)` — never drawn from one sequential stream — the executor produces
//! **bit-identical** estimates to the sequential [`super::treecv::TreeCv`]
//! for the same seed, under both orderings, for any worker count. The tests
//! below and `tests/integration_executor.rs` assert exactly that.

use super::folds::{gather_ordered, node_tags, Folds, Ordering};
use super::CvResult;
use crate::data::Dataset;
use crate::learner::IncrementalLearner;
use crate::metrics::{OpCounts, Timer};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering as MemOrdering};
use std::sync::Mutex;

/// The pooled work-stealing TreeCV engine (Copy strategy at forks).
#[derive(Debug, Clone)]
pub struct TreeCvExecutor {
    /// Fixed vs randomized feeding order (paper §5).
    pub ordering: Ordering,
    /// Seed for the per-node permutation streams (ignored under Fixed).
    pub seed: u64,
    /// Worker-pool size. `1` runs the whole tree inline on the calling
    /// thread (no spawning); capped at `k` per run since at most `k` tasks
    /// are ever live.
    pub threads: usize,
}

/// One unit of executor work: the TreeCV node `(s, e)` plus the model
/// trained on every chunk outside `s..=e`.
struct Task<M> {
    s: usize,
    e: usize,
    model: M,
}

/// State shared by the worker pool for one run.
struct Shared<M> {
    /// One deque per worker. Owner pushes/pops the back; thieves pop the
    /// front. A plain mutexed deque keeps the implementation obviously
    /// correct; contention is negligible at tree-node granularity.
    deques: Vec<Mutex<VecDeque<Task<M>>>>,
    /// Recycled model buffers (`clone_from` targets for interior-node
    /// snapshots). Leaves return their model here when done; retention is
    /// capped at [`Shared::pool_cap`] so LOOCV-scale runs (k = n) don't
    /// accumulate O(k) dead buffers by the end of the computation.
    pool: Mutex<Vec<M>>,
    /// Maximum buffers the pool retains (~ workers · tree depth, the
    /// steady-state demand); excess leaf models are dropped instead.
    pool_cap: usize,
    /// Per-fold outputs; distinct indices are written exactly once each.
    per_fold: Mutex<Vec<f64>>,
    /// Leaves completed so far; the run is done when this reaches `k`.
    leaves_done: AtomicUsize,
    /// Total leaf count.
    k: usize,
    /// Set when all leaves are done (or a worker panicked) so idle workers
    /// exit their steal loop.
    done: AtomicBool,
}

/// Sets the shared `done` flag if its thread unwinds, so a panicking
/// worker cannot leave the rest of the pool spinning forever.
struct PanicSignal<'a> {
    done: &'a AtomicBool,
}

impl Drop for PanicSignal<'_> {
    fn drop(&mut self) {
        if std::thread::panicking() {
            self.done.store(true, MemOrdering::Release);
        }
    }
}

impl TreeCvExecutor {
    pub fn new(ordering: Ordering, seed: u64, threads: usize) -> Self {
        Self { ordering, seed, threads: threads.max(1) }
    }

    /// Pool sized to the machine's available parallelism (no rounding to a
    /// power of two — any worker count schedules fully).
    pub fn with_available_parallelism(ordering: Ordering, seed: u64) -> Self {
        let threads = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
        Self::new(ordering, seed, threads)
    }

    /// Gather the points of chunks `lo..=hi` in the engine's feeding order.
    /// The permutation stream is a pure function of `(seed, node, side)`,
    /// which is what makes any execution order reproduce the sequential
    /// engine bit-for-bit.
    fn gather(
        &self,
        folds: &Folds,
        lo: usize,
        hi: usize,
        tag: u64,
        ops: &mut OpCounts,
    ) -> Vec<u32> {
        gather_ordered(folds, lo, hi, self.seed, self.ordering, tag, ops)
    }

    /// Process one tree node: evaluate at a leaf, otherwise run both update
    /// phases and enqueue the two children on this worker's own deque.
    #[allow(clippy::too_many_arguments)]
    fn process<L>(
        &self,
        wid: usize,
        task: Task<L::Model>,
        shared: &Shared<L::Model>,
        learner: &L,
        data: &Dataset,
        folds: &Folds,
        ops: &mut OpCounts,
    ) where
        L: IncrementalLearner + Sync,
    {
        let Task { s, e, mut model } = task;
        if s == e {
            let chunk = folds.chunk(s);
            let score = learner.evaluate(&model, data, chunk);
            ops.evals += 1;
            ops.points_evaluated += chunk.len() as u64;
            shared.per_fold.lock().unwrap()[s] = score;
            // Recycle the model storage for future interior-node
            // snapshots (bounded — beyond the cap, just drop it).
            {
                let mut pool = shared.pool.lock().unwrap();
                if pool.len() < shared.pool_cap {
                    pool.push(model);
                }
            }
            if shared.leaves_done.fetch_add(1, MemOrdering::AcqRel) + 1 == shared.k {
                shared.done.store(true, MemOrdering::Release);
            }
            return;
        }

        let m = (s + e) / 2;
        // Node tags shared with the sequential engine (`folds::node_tags`).
        let (tag_right, tag_left) = node_tags(s, e);

        let right = self.gather(folds, m + 1, e, tag_right, ops);
        let left = self.gather(folds, s, m, tag_left, ops);
        ops.update_calls += 2;
        ops.points_updated += (right.len() + left.len()) as u64;

        // Snapshot into a pooled buffer (clone_from reuses its storage)
        // instead of allocating a fresh model at every interior node.
        let recycled = shared.pool.lock().unwrap().pop();
        let mut sibling = match recycled {
            Some(mut buf) => {
                buf.clone_from(&model);
                buf
            }
            None => model.clone(),
        };
        ops.model_copies += 1;
        ops.bytes_copied += learner.model_bytes(&model) as u64;

        // As in Algorithm 1: the model fed the *second* group serves the
        // left child (s, m); the model fed the *first* group serves the
        // right child (m+1, e).
        learner.update(&mut model, data, &right);
        learner.update(&mut sibling, data, &left);

        let mut dq = shared.deques[wid].lock().unwrap();
        dq.push_back(Task { s, e: m, model });
        dq.push_back(Task { s: m + 1, e, model: sibling });
    }

    /// Worker loop: drain own deque LIFO, steal FIFO when empty, exit once
    /// every leaf is recorded. Returns this worker's operation counters.
    fn worker<L>(
        &self,
        wid: usize,
        shared: &Shared<L::Model>,
        learner: &L,
        data: &Dataset,
        folds: &Folds,
    ) -> OpCounts
    where
        L: IncrementalLearner + Sync,
    {
        let _signal = PanicSignal { done: &shared.done };
        let mut ops = OpCounts::default();
        let n_workers = shared.deques.len();
        // Consecutive empty steal sweeps; drives the idle backoff below.
        let mut dry_sweeps = 0u32;
        loop {
            let task = {
                let own = shared.deques[wid].lock().unwrap().pop_back();
                match own {
                    Some(t) => Some(t),
                    None => (1..n_workers).find_map(|offset| {
                        let victim = (wid + offset) % n_workers;
                        shared.deques[victim].lock().unwrap().pop_front()
                    }),
                }
            };
            match task {
                Some(t) => {
                    dry_sweeps = 0;
                    self.process(wid, t, shared, learner, data, folds, &mut ops);
                }
                None => {
                    if shared.done.load(MemOrdering::Acquire) {
                        break;
                    }
                    // Tiered backoff: spin-yield briefly (work usually
                    // appears within a node's two updates), then sleep so
                    // idle workers stop hammering the deque mutexes during
                    // long serial phases (e.g. the root node's O(n) updates
                    // while only one task exists).
                    dry_sweeps += 1;
                    if dry_sweeps < 16 {
                        std::thread::yield_now();
                    } else {
                        std::thread::sleep(std::time::Duration::from_micros(100));
                    }
                }
            }
        }
        ops
    }

    /// Run the executor engine. (Not part of the [`super::CvEngine`] trait
    /// because it needs `L: Sync` bounds the trait doesn't impose.)
    pub fn run<L>(&self, learner: &L, data: &Dataset, folds: &Folds) -> CvResult
    where
        L: IncrementalLearner + Sync,
        L::Model: Send,
    {
        let timer = Timer::start();
        let k = folds.k();
        let threads = self.threads.max(1).min(k);
        // Steady-state snapshot demand is one buffer per live tree path
        // per worker: ~threads · ⌈log₂ k⌉ (+ slack). Capping retention
        // here keeps LOOCV (k = n) from holding O(k) buffers at once.
        let pool_cap = threads * (k.max(2).ilog2() as usize + 2);
        let shared: Shared<L::Model> = Shared {
            deques: (0..threads).map(|_| Mutex::new(VecDeque::new())).collect(),
            pool: Mutex::new(Vec::new()),
            pool_cap,
            per_fold: Mutex::new(vec![0.0; k]),
            leaves_done: AtomicUsize::new(0),
            k,
            done: AtomicBool::new(false),
        };
        shared.deques[0]
            .lock()
            .unwrap()
            .push_back(Task { s: 0, e: k - 1, model: learner.init() });

        let mut ops = OpCounts::default();
        if threads == 1 {
            // Inline on the calling thread: zero spawn cost, and exactly
            // the sequential engine's work.
            ops = self.worker(0, &shared, learner, data, folds);
        } else {
            let shared_ref = &shared;
            std::thread::scope(|scope| {
                let handles: Vec<_> = (0..threads)
                    .map(|wid| {
                        scope.spawn(move || {
                            self.worker(wid, shared_ref, learner, data, folds)
                        })
                    })
                    .collect();
                for handle in handles {
                    ops.merge(&handle.join().expect("executor worker panicked"));
                }
            });
        }

        let per_fold = shared.per_fold.into_inner().unwrap();
        CvResult::from_folds(per_fold, ops, timer.elapsed())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cv::treecv::TreeCv;
    use crate::cv::{CvEngine, Strategy};
    use crate::data::synth::{SyntheticCovertype, SyntheticMixture1d};
    use crate::learner::histdensity::HistogramDensity;
    use crate::learner::pegasos::Pegasos;

    #[test]
    fn matches_sequential_fixed_order() {
        let data = SyntheticCovertype::new(2_000, 91).generate();
        let l = Pegasos::new(54, 1e-4);
        let folds = Folds::new(2_000, 16, 92);
        let seq = TreeCv::new(Strategy::Copy, Ordering::Fixed, 5).run(&l, &data, &folds);
        let exe = TreeCvExecutor::new(Ordering::Fixed, 5, 8).run(&l, &data, &folds);
        assert_eq!(seq.per_fold, exe.per_fold);
        assert_eq!(seq.estimate, exe.estimate);
    }

    #[test]
    fn matches_sequential_randomized_order() {
        // Per-node RNG derivation makes randomized ordering identical too.
        let data = SyntheticCovertype::new(1_000, 93).generate();
        let l = Pegasos::new(54, 1e-4);
        let folds = Folds::new(1_000, 8, 94);
        let seq = TreeCv::new(Strategy::Copy, Ordering::Randomized, 7).run(&l, &data, &folds);
        let exe = TreeCvExecutor::new(Ordering::Randomized, 7, 4).run(&l, &data, &folds);
        assert_eq!(seq.per_fold, exe.per_fold);
    }

    #[test]
    fn every_worker_count_is_bit_identical() {
        // Including non-power-of-two pools, which the scoped-fork engine
        // could never use, and pools larger than k (internally capped).
        let data = SyntheticCovertype::new(900, 95).generate();
        let l = Pegasos::new(54, 1e-3);
        let folds = Folds::new(900, 13, 96); // remainder folds: k ∤ n
        let seq = TreeCv::new(Strategy::Copy, Ordering::Fixed, 3).run(&l, &data, &folds);
        for threads in [1usize, 2, 3, 5, 6, 7, 12, 16, 64] {
            let exe = TreeCvExecutor::new(Ordering::Fixed, 3, threads).run(&l, &data, &folds);
            assert_eq!(seq.per_fold, exe.per_fold, "threads={threads}");
        }
    }

    #[test]
    fn single_thread_is_inline_and_identical() {
        let data = SyntheticMixture1d::new(300, 97).generate();
        let l = HistogramDensity::new(-8.0, 8.0, 32);
        let folds = Folds::new(300, 10, 98);
        let exe = TreeCvExecutor::new(Ordering::Fixed, 0, 1).run(&l, &data, &folds);
        let seq = TreeCv::default().run(&l, &data, &folds);
        assert_eq!(exe.per_fold, seq.per_fold);
    }

    #[test]
    fn total_work_unchanged_by_pool_size() {
        let data = SyntheticMixture1d::new(512, 99).generate();
        let l = HistogramDensity::new(-8.0, 8.0, 32);
        let folds = Folds::new(512, 32, 100);
        let seq = TreeCv::default().run(&l, &data, &folds);
        let exe = TreeCvExecutor::new(Ordering::Fixed, 0, 6).run(&l, &data, &folds);
        assert_eq!(seq.ops.points_updated, exe.ops.points_updated);
        assert_eq!(seq.ops.evals, exe.ops.evals);
        assert_eq!(seq.ops.update_calls, exe.ops.update_calls);
        // One snapshot per interior node, exactly as the Copy strategy:
        // the pool recycles storage but never changes the copy count.
        assert_eq!(exe.ops.model_copies, 31);
    }

    #[test]
    fn loocv_smallest_and_degenerate_k() {
        // k = 1: the root is a leaf; the init model is evaluated directly.
        let data = SyntheticMixture1d::new(40, 101).generate();
        let l = HistogramDensity::new(-8.0, 8.0, 16);
        let folds = Folds::new(40, 1, 102);
        let exe = TreeCvExecutor::new(Ordering::Fixed, 0, 4).run(&l, &data, &folds);
        assert_eq!(exe.per_fold.len(), 1);
        assert_eq!(exe.ops.evals, 1);
        // k = n (LOOCV) with a multi-worker pool.
        let folds = Folds::loocv(40);
        let seq = TreeCv::default().run(&l, &data, &folds);
        let exe = TreeCvExecutor::new(Ordering::Fixed, 0, 4).run(&l, &data, &folds);
        assert_eq!(seq.per_fold, exe.per_fold);
    }
}
