//! The standard ("naive", k-repetition) CV computation the paper measures
//! against: train k models independently from scratch, each on all chunks
//! except one, and evaluate on the held-out chunk. Work is
//! `k · (n − n/k) = Θ(n·k)` update points versus TreeCV's `O(n log k)`.

use super::folds::{Folds, Ordering};
use super::CvResult;
use crate::data::folded::FoldedDataset;
use crate::data::Dataset;
use crate::learner::IncrementalLearner;
use crate::metrics::{OpCounts, Timer};
use crate::rng::Rng;

/// The k-repetition baseline engine.
#[derive(Debug, Clone)]
pub struct StandardCv {
    pub ordering: Ordering,
    pub seed: u64,
}

impl Default for StandardCv {
    fn default() -> Self {
        Self { ordering: Ordering::Fixed, seed: 0 }
    }
}

impl StandardCv {
    pub fn new(ordering: Ordering, seed: u64) -> Self {
        Self { ordering, seed }
    }

    /// Run the baseline over the fold-contiguous layout. "All chunks but
    /// fold `i`" is exactly two contiguous row blocks there, so
    /// fixed-order training feeds the learner's `update_rows` fast path
    /// with **no index vector at all** (the indexed engine pays one reused
    /// `≈(k−1)·n/k` gather buffer per run); randomized training shuffles
    /// one recycled id buffer. Results — estimate, per-fold scores in
    /// original fold numbering, all semantic counters — are bit-identical
    /// to [`super::CvEngine::run`]. `data` must be the dataset `folded`
    /// was built from.
    pub fn run_folded<L: IncrementalLearner>(
        &self,
        learner: &L,
        data: &Dataset,
        folded: &FoldedDataset,
    ) -> CvResult {
        assert_eq!(folded.n(), data.n, "folded layout built for a different dataset (n)");
        assert_eq!(folded.d(), data.d, "folded layout built for a different dataset (d)");
        let timer = Timer::start();
        let folds = folded.folds();
        let k = folds.k();
        let mut ops = OpCounts::default();
        let mut per_fold = vec![0.0; k];
        // One recycled id buffer for every randomized training sequence.
        let mut scratch: Vec<u32> = Vec::new();
        if self.ordering == Ordering::Randomized {
            ops.stream_allocs += 1;
        }
        for i in 0..k {
            let mut model = learner.init();
            ops.update_calls += 1;
            ops.points_updated += (folds.n() - folds.chunk(i).len()) as u64;
            match self.ordering {
                Ordering::Fixed => {
                    // Two contiguous blocks in gather_except's order; the
                    // split into two feeds is invisible to a per-point
                    // incremental update.
                    let (x, y, ids) = folded.rows_before(i);
                    learner.update_rows(&mut model, x, y, data, ids);
                    let (x, y, ids) = folded.rows_after(i);
                    learner.update_rows(&mut model, x, y, data, ids);
                }
                Ordering::Randomized => {
                    scratch.clear();
                    scratch.extend_from_slice(folded.ids_before(i));
                    scratch.extend_from_slice(folded.ids_after(i));
                    let mut rng = Rng::derive(self.seed, i as u64);
                    self.ordering.apply(&mut scratch, &mut rng, &mut ops);
                    learner.update(&mut model, data, &scratch);
                }
            }
            let (x, y, ids) = folded.rows(i, i);
            per_fold[i] = learner.evaluate_rows(&model, x, y, data, ids);
            ops.evals += 1;
            ops.points_evaluated += ids.len() as u64;
        }
        CvResult::from_folds(per_fold, ops, timer.elapsed())
    }
}

impl super::CvEngine for StandardCv {
    fn engine_name(&self) -> &'static str {
        "standard"
    }

    fn run<L: IncrementalLearner>(&self, learner: &L, data: &Dataset, folds: &Folds) -> CvResult {
        let timer = Timer::start();
        let k = folds.k();
        let mut ops = OpCounts::default();
        let mut per_fold = vec![0.0; k];
        // One training-sequence buffer reused across all k folds (the old
        // per-fold `gather_except` allocated k fresh ≈(k−1)·n/k vectors).
        let mut idx: Vec<u32> = Vec::new();
        ops.stream_allocs += 1;
        for i in 0..k {
            folds.gather_except_into(i, &mut idx);
            let mut rng = Rng::derive(self.seed, i as u64);
            self.ordering.apply(&mut idx, &mut rng, &mut ops);
            let mut model = learner.init();
            learner.update(&mut model, data, &idx);
            ops.update_calls += 1;
            ops.points_updated += idx.len() as u64;
            let chunk = folds.chunk(i);
            per_fold[i] = learner.evaluate(&model, data, chunk);
            ops.evals += 1;
            ops.points_evaluated += chunk.len() as u64;
        }
        CvResult::from_folds(per_fold, ops, timer.elapsed())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cv::treecv::TreeCv;
    use crate::cv::CvEngine;
    use crate::learner::histdensity::HistogramDensity;
    use crate::learner::multiset::MultisetLearner;
    use crate::learner::ridge::OnlineRidge;
    use crate::data::synth::{SyntheticMixture1d, SyntheticYearMsd};

    fn dummy(n: usize) -> Dataset {
        Dataset::new(vec![0.0; n], vec![0.0; n], 1)
    }

    /// Theorem 1 with g ≡ 0: for an exactly order/batching-insensitive
    /// learner, TreeCV reproduces the standard estimate *exactly*.
    #[test]
    fn treecv_equals_standard_for_multiset_oracle() {
        for (n, k) in [(24usize, 4usize), (30, 5), (12, 12), (50, 7)] {
            let data = dummy(n);
            let folds = Folds::new(n, k, 81);
            let l = MultisetLearner::new(1);
            let std_res = StandardCv::default().run(&l, &data, &folds);
            let tree_res = TreeCv::default().run(&l, &data, &folds);
            assert_eq!(std_res.per_fold, tree_res.per_fold, "n={n} k={k}");
            assert_eq!(std_res.estimate, tree_res.estimate);
        }
    }

    /// Same, with a real (histogram-density) learner: bit-for-bit equality.
    #[test]
    fn treecv_equals_standard_for_histogram_density() {
        let data = SyntheticMixture1d::new(400, 82).generate();
        let l = HistogramDensity::new(-8.0, 8.0, 32);
        for k in [2, 5, 10, 100, 400] {
            let folds = Folds::new(400, k, 83);
            let a = StandardCv::default().run(&l, &data, &folds);
            let b = TreeCv::default().run(&l, &data, &folds);
            assert_eq!(a.per_fold, b.per_fold, "k={k}");
        }
    }

    /// Ridge is batching-insensitive up to f64 rounding: the two engines
    /// agree to tight tolerance.
    #[test]
    fn treecv_matches_standard_for_ridge() {
        let data = SyntheticYearMsd::new(150, 84).generate();
        let l = OnlineRidge::new(90, 1.0);
        let folds = Folds::new(150, 10, 85);
        let a = StandardCv::default().run(&l, &data, &folds);
        let b = TreeCv::default().run(&l, &data, &folds);
        for (x, y) in a.per_fold.iter().zip(&b.per_fold) {
            assert!((x - y).abs() < 1e-9, "{x} vs {y}");
        }
    }

    /// Standard CV's work is k·(n − b) update points.
    #[test]
    fn work_is_linear_in_k() {
        let n = 60;
        let data = dummy(n);
        let l = MultisetLearner::new(1);
        for k in [2usize, 5, 10, 30] {
            let folds = Folds::new(n, k, 86);
            let res = StandardCv::default().run(&l, &data, &folds);
            let expected: u64 =
                (0..k).map(|i| (n - folds.chunk(i).len()) as u64).sum();
            assert_eq!(res.ops.points_updated, expected, "k={k}");
            assert_eq!(res.ops.model_copies, 0);
        }
    }

    /// Folded standard CV must be bit-identical to the indexed engine —
    /// pinned here with the index-sensitive multiset oracle and a real
    /// learner, under both orderings, including a remainder shape.
    #[test]
    fn folded_matches_indexed_bitwise() {
        use crate::data::folded::FoldedDataset;
        let data = SyntheticMixture1d::new(103, 89).generate();
        let hist = HistogramDensity::new(-8.0, 8.0, 32);
        let oracle = MultisetLearner::new(1);
        let folds = Folds::new(103, 10, 90);
        let folded = FoldedDataset::build(&data, &folds);
        for ordering in [Ordering::Fixed, Ordering::Randomized] {
            let engine = StandardCv::new(ordering, 4);
            let a = engine.run(&hist, &data, &folds);
            let b = engine.run_folded(&hist, &data, &folded);
            assert_eq!(a.per_fold, b.per_fold, "{ordering:?}");
            assert_eq!(a.ops.update_calls, b.ops.update_calls);
            assert_eq!(a.ops.points_updated, b.ops.points_updated);
            assert_eq!(a.ops.points_permuted, b.ops.points_permuted);
            let oa = engine.run(&oracle, &data, &folds);
            let ob = engine.run_folded(&oracle, &data, &folded);
            assert_eq!(oa.per_fold, ob.per_fold, "{ordering:?} oracle");
            if ordering == Ordering::Fixed {
                assert_eq!(b.ops.stream_allocs, 0, "fixed folded runs allocate no streams");
            }
        }
        // The indexed engine now pays ONE reused buffer per run, not k.
        let res = StandardCv::default().run(&hist, &data, &folds);
        assert_eq!(res.ops.stream_allocs, 1);
    }

    /// Randomized ordering changes the per-fold sequence but not the
    /// multiset; for an order-insensitive learner the estimate is unchanged.
    #[test]
    fn randomized_invariant_for_order_insensitive_learner() {
        let data = SyntheticMixture1d::new(200, 87).generate();
        let l = HistogramDensity::new(-8.0, 8.0, 32);
        let folds = Folds::new(200, 8, 88);
        let fixed = StandardCv::new(Ordering::Fixed, 1).run(&l, &data, &folds);
        let rand = StandardCv::new(Ordering::Randomized, 2).run(&l, &data, &folds);
        assert_eq!(fixed.per_fold, rand.per_fold);
        assert!(rand.ops.points_permuted > 0);
        assert_eq!(fixed.ops.points_permuted, 0);
    }
}
