//! Approximate cross-validation via one-step corrections (the k = n
//! engine).
//!
//! Exact TreeCV spends Θ(n log₂(2k)) row updates per run; at the LOOCV
//! extreme (k = n) that log factor is ~21 at n = 10⁶. This engine trains
//! **once** on the full dataset (n row updates) and then derives each
//! fold's held-out estimate from the full-data model by a *one-step
//! correction* — a closed-form or first-order approximation of "the model
//! trained without this fold":
//!
//! * ridge — exact Sherman–Morrison block *downdate* of the sufficient
//!   statistics (only f64 rounding separates it from a re-train);
//! * pegasos / lsqsgd — a single re-weighted gradient step removing the
//!   held-out block's contribution (first-order accurate).
//!
//! The capability is opt-in per learner via
//! [`crate::learner::ConvexCorrectable`] and probed at runtime through
//! [`crate::learner::IncrementalLearner::correctable`]; non-convex
//! learners (knn, histdensity, kmeans, ...) have no meaningful one-step
//! correction and are rejected with a hard error.
//!
//! Cost model: n row updates + k corrections + k evaluations, and the
//! corrections sum to Θ(n) row-sized operations across all folds. Work is
//! counted in [`OpCounts::corrections`]; `--approx-check` additionally
//! runs the exact engine and records the largest per-fold deviation in
//! [`OpCounts::exact_gap_max`].
//!
//! Determinism: the full-data training pass is a single sequential stream
//! (tagged [`APPROX_FULL_TAG`]-style inside the executor), and each
//! fold's correction starts from an identical clone of that model — so
//! per-fold results are **bitwise independent of the worker count**. The
//! parallel dispatch lives in [`super::executor`]
//! ([`TreeCvExecutor::run_many_approx`]); this module is the
//! single-threaded facade plus the gap helper shared by the repetition
//! harness and the test batteries.
//!
//! [`OpCounts::corrections`]: crate::metrics::OpCounts::corrections
//! [`OpCounts::exact_gap_max`]: crate::metrics::OpCounts::exact_gap_max
//! [`APPROX_FULL_TAG`]: super::executor

use super::executor::TreeCvExecutor;
use super::folds::{Folds, Ordering};
use super::{CvResult, Strategy};
use crate::data::Dataset;
use crate::learner::IncrementalLearner;

/// Single-threaded approximate-CV engine: train once, correct per fold.
///
/// Strategy-free: the approx sweep neither forks interior nodes nor
/// reverts updates, so there is no Copy-vs-SaveRevert axis. `ordering`
/// and `seed` control the full-data training stream exactly as they do
/// for the exact engines (Fixed feeds rows in index order; Randomized
/// shuffles the gathered sequence with the run's derived RNG stream).
#[derive(Debug, Clone, Copy)]
pub struct ApproxCv {
    pub ordering: Ordering,
    pub seed: u64,
}

impl ApproxCv {
    pub fn new(ordering: Ordering, seed: u64) -> Self {
        Self { ordering, seed }
    }

    /// Engine name for reports (mirrors [`super::CvEngine::engine_name`]).
    pub fn engine_name(&self) -> &'static str {
        "approx"
    }

    /// Compute the approximate k-CV estimate of `learner` on `data`.
    ///
    /// Not part of the [`super::CvEngine`] trait because the executor
    /// path needs `L: Sync` / `L::Model: Send` bounds the trait doesn't
    /// impose (same precedent as `TreeCvExecutor::run`). Panics if the
    /// learner does not advertise a one-step correction
    /// ([`crate::learner::IncrementalLearner::correctable`]).
    pub fn run<L>(&self, learner: &L, data: &Dataset, folds: &Folds) -> CvResult
    where
        L: IncrementalLearner + Sync,
        L::Model: Send,
    {
        // Strategy::Copy is carried but never consulted on the approx
        // path (see run_many_approx docs).
        TreeCvExecutor::new(Strategy::Copy, self.ordering, self.seed, 1)
            .run_approx(learner, data, folds)
    }
}

/// Largest per-fold absolute deviation between two CV results — the
/// quantity recorded in `OpCounts::exact_gap_max` under `--approx-check`
/// and pinned by the bounded-error batteries.
///
/// Panics if the fold counts differ: comparing results from different
/// fold assignments is a caller bug, not a gap of ∞.
pub fn max_fold_gap(a: &CvResult, b: &CvResult) -> f64 {
    assert_eq!(
        a.per_fold.len(),
        b.per_fold.len(),
        "max_fold_gap: fold-count mismatch — results come from different assignments"
    );
    a.per_fold
        .iter()
        .zip(&b.per_fold)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0_f64, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cv::treecv::TreeCv;
    use crate::cv::CvEngine;
    use crate::data::synth::SyntheticYearMsd;
    use crate::learner::ridge::OnlineRidge;

    #[test]
    fn facade_matches_executor_and_counts_work() {
        let data = SyntheticYearMsd::new(240, 11).generate();
        let learner = OnlineRidge::new(SyntheticYearMsd::D, 1.0);
        let folds = Folds::new(240, 12, 7);
        let engine = ApproxCv::new(Ordering::Fixed, 5);
        let r = engine.run(&learner, &data, &folds);
        assert_eq!(engine.engine_name(), "approx");
        assert_eq!(r.ops.update_calls, 1);
        assert_eq!(r.ops.points_updated, 240);
        assert_eq!(r.ops.corrections, 12);
        assert_eq!(r.ops.evals, 12);
        // Same knobs through the executor directly: bitwise identical.
        let ex = TreeCvExecutor::new(Strategy::Copy, Ordering::Fixed, 5, 1)
            .run_approx(&learner, &data, &folds);
        assert_eq!(r.estimate.to_bits(), ex.estimate.to_bits());
        for (a, b) in r.per_fold.iter().zip(&ex.per_fold) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn ridge_downdate_tracks_exact_treecv() {
        let data = SyntheticYearMsd::new(160, 3).generate();
        let learner = OnlineRidge::new(SyntheticYearMsd::D, 1.0);
        let folds = Folds::loocv(160);
        let approx = ApproxCv::new(Ordering::Fixed, 9).run(&learner, &data, &folds);
        let exact = TreeCv::new(Strategy::Copy, Ordering::Fixed, 9).run(&learner, &data, &folds);
        let gap = max_fold_gap(&approx, &exact);
        assert!(gap <= 1e-8, "ridge downdate drifted from exact: gap {gap:e}");
        // LOOCV work: n updates + n corrections vs Θ(n log 2n) updates.
        assert!(approx.ops.points_updated < exact.ops.points_updated / 4);
    }

    #[test]
    fn max_fold_gap_is_the_sup_norm() {
        let ops = crate::metrics::OpCounts::default;
        let wall = std::time::Duration::ZERO;
        let a = CvResult::from_folds(vec![1.0, 2.0, 3.0], ops(), wall);
        let b = CvResult::from_folds(vec![1.5, 2.0, 2.0], ops(), wall);
        assert_eq!(max_fold_gap(&a, &b), 1.0);
        assert_eq!(max_fold_gap(&a, &a), 0.0);
    }

    #[test]
    #[should_panic(expected = "fold-count mismatch")]
    fn max_fold_gap_rejects_mismatched_assignments() {
        let ops = crate::metrics::OpCounts::default;
        let wall = std::time::Duration::ZERO;
        let a = CvResult::from_folds(vec![1.0], ops(), wall);
        let b = CvResult::from_folds(vec![1.0, 2.0], ops(), wall);
        let _ = max_fold_gap(&a, &b);
    }
}
