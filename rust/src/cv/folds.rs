//! Fold assignment ("chunks" in the paper) and the data-ordering policies
//! of §5.
//!
//! The paper fixes a partitioning of `{z_1..z_n}` into k chunks, and then
//! distinguishes two ways of ordering the points fed to an online learner:
//!
//! * **fixed** — "a fixed ordering of the chunks and of the samples within
//!   each chunk"; training on chunks `Z_{i1}..Z_{ij}` concatenates them in
//!   this hierarchical order.
//! * **randomized** — "the samples used in a training phase are provided
//!   in a random order": each training call shuffles the union of the
//!   chunks it is about to feed.

use crate::metrics::OpCounts;
use crate::rng::Rng;

/// A partition of `0..n` into `k` chunks of (near-)equal size.
#[derive(Debug, Clone)]
pub struct Folds {
    chunks: Vec<Vec<u32>>,
    n: usize,
}

impl Folds {
    /// Random equal-size partition: shuffle `0..n`, then deal round-robin
    /// free slices. Sizes differ by at most 1 (the paper's analysis assumes
    /// `n = k·b`; we support remainders for real data).
    pub fn new(n: usize, k: usize, seed: u64) -> Self {
        assert!(k >= 1 && k <= n, "need 1 <= k ({k}) <= n ({n})");
        let mut rng = Rng::derive(seed, 0xF01D5);
        let perm = rng.permutation(n);
        Self::from_permutation(&perm, k)
    }

    /// Contiguous partition of the *unshuffled* indices — useful when the
    /// dataset was already shuffled once up front (paper's fixed layout).
    pub fn contiguous(n: usize, k: usize) -> Self {
        assert!(k >= 1 && k <= n);
        let perm: Vec<u32> = (0..n as u32).collect();
        Self::from_permutation(&perm, k)
    }

    /// Like [`Folds::new`] (random assignment of points to chunks), but
    /// with each chunk's indices sorted ascending. The fold *sets* are
    /// identical in distribution; only the fixed within-chunk order
    /// changes, which is a valid "fixed ordering" in the paper's sense
    /// and makes training passes walk the dataset near-sequentially —
    /// a pure memory-locality optimization (EXPERIMENTS.md §Perf).
    pub fn new_sorted(n: usize, k: usize, seed: u64) -> Self {
        let mut f = Self::new(n, k, seed);
        for c in f.chunks.iter_mut() {
            c.sort_unstable();
        }
        f
    }

    /// Leave-one-out folds.
    pub fn loocv(n: usize) -> Self {
        Self::contiguous(n, n)
    }

    fn from_permutation(perm: &[u32], k: usize) -> Self {
        let n = perm.len();
        let base = n / k;
        let extra = n % k;
        let mut chunks = Vec::with_capacity(k);
        let mut off = 0;
        for i in 0..k {
            let len = base + usize::from(i < extra);
            chunks.push(perm[off..off + len].to_vec());
            off += len;
        }
        debug_assert_eq!(off, n);
        Self { chunks, n }
    }

    pub fn k(&self) -> usize {
        self.chunks.len()
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// The held-out chunk `Z_i`.
    pub fn chunk(&self, i: usize) -> &[u32] {
        &self.chunks[i]
    }

    /// Concatenate chunks `lo..=hi` in hierarchical (fixed) order.
    pub fn gather_range(&self, lo: usize, hi: usize) -> Vec<u32> {
        let cap: usize = (lo..=hi).map(|c| self.chunks[c].len()).sum();
        let mut out = Vec::with_capacity(cap);
        for c in lo..=hi {
            out.extend_from_slice(&self.chunks[c]);
        }
        out
    }

    /// Concatenate every chunk except `i` (standard CV's training set),
    /// fixed order.
    pub fn gather_except(&self, i: usize) -> Vec<u32> {
        let mut out = Vec::with_capacity(self.n - self.chunks[i].len());
        self.gather_except_into(i, &mut out);
        out
    }

    /// Like [`Self::gather_except`], but into a caller-owned buffer so the
    /// k training sequences of one standard-CV run reuse ONE allocation
    /// instead of materializing k fresh `≈(k−1)·n/k` vectors
    /// ([`super::standard::StandardCv`] is the caller).
    pub fn gather_except_into(&self, i: usize, out: &mut Vec<u32>) {
        out.clear();
        out.reserve(self.n - self.chunks[i].len());
        for (c, chunk) in self.chunks.iter().enumerate() {
            if c != i {
                out.extend_from_slice(chunk);
            }
        }
    }

    /// Append original index `id` to chunk `c`'s tail (streaming arrivals
    /// extend a chunk without disturbing the fixed within-chunk order of
    /// the points already there). Ids must stay dense — the next appended
    /// id is always the current `n` — so the partition keeps covering
    /// `0..n` exactly once.
    pub fn append_to_chunk(&mut self, c: usize, id: u32) {
        assert_eq!(
            id as usize, self.n,
            "appended ids must be dense: expected {}, got {id}",
            self.n
        );
        self.chunks[c].push(id);
        self.n += 1;
    }

    /// The chunk a streaming append should land in: the smallest one
    /// (lowest index on ties). Routing every append through this keeps
    /// chunk sizes within 1 of each other under any arrival pattern, the
    /// same near-equal-size invariant [`Folds::new`] establishes.
    pub fn smallest_chunk(&self) -> usize {
        let mut best = 0;
        for (c, chunk) in self.chunks.iter().enumerate().skip(1) {
            if chunk.len() < self.chunks[best].len() {
                best = c;
            }
        }
        best
    }

    /// Whether [`Self::retire_below`]`(cutoff)` would leave every chunk
    /// non-empty (a CV partition needs k non-empty folds). Lets a
    /// long-running caller validate a sliding-window retirement instead of
    /// panicking mid-service.
    pub fn can_retire_below(&self, cutoff: u32) -> bool {
        (cutoff as usize) < self.n
            && self.chunks.iter().all(|c| c.iter().any(|&id| id >= cutoff))
    }

    /// Sliding-window retirement: drop every original index below
    /// `cutoff` and renumber the survivors down by `cutoff`, so the
    /// partition covers the shifted window `0..n-cutoff` exactly once.
    /// Panics if any chunk would end up empty (check
    /// [`Self::can_retire_below`] first in long-running callers).
    pub fn retire_below(&mut self, cutoff: u32) {
        assert!(
            (cutoff as usize) < self.n,
            "retire_below({cutoff}) must leave at least one row (n = {})",
            self.n
        );
        let mut removed = 0usize;
        for chunk in self.chunks.iter_mut() {
            let before = chunk.len();
            chunk.retain(|&id| id >= cutoff);
            removed += before - chunk.len();
            assert!(!chunk.is_empty(), "retire_below({cutoff}) would empty a fold chunk");
            for id in chunk.iter_mut() {
                *id -= cutoff;
            }
        }
        // Ids are dense 0..n, so exactly `cutoff` of them sat below it.
        debug_assert_eq!(removed, cutoff as usize);
        self.n -= removed;
    }
}

/// The `(right, left)` stream tags for TreeCV node `(s, e)` — one per
/// update phase, unique across the tree for u32-sized ranges.
///
/// Every engine (sequential, scoped-fork, pooled executor) derives its
/// per-node permutation streams from these tags via [`gather_ordered`],
/// so their cross-engine bit-identity is structural rather than three
/// hand-synchronized copies of the same bit-packing.
pub fn node_tags(s: usize, e: usize) -> (u64, u64) {
    let right = ((s as u64) << 33) | ((e as u64) << 1);
    (right, right | 1)
}

/// Gather the points of chunks `lo..=hi` under `ordering`, permuting (if
/// randomized) with the stream derived from `(seed, tag)`. The stream is
/// a pure function of its arguments — never drawn from a shared
/// sequential source — which is what lets any execution order reproduce
/// the sequential engine exactly.
///
/// This is the *indexed* node-stream path: it materializes (and counts,
/// via `OpCounts::stream_allocs`) one fresh index vector per call. The
/// fold-contiguous layout ([`crate::data::folded::FoldedDataset`]) feeds
/// the same point sequence from contiguous slices instead.
pub fn gather_ordered(
    folds: &Folds,
    lo: usize,
    hi: usize,
    seed: u64,
    ordering: Ordering,
    tag: u64,
    ops: &mut OpCounts,
) -> Vec<u32> {
    let mut idx = folds.gather_range(lo, hi);
    ops.stream_allocs += 1;
    let mut rng = Rng::derive(seed, tag);
    ordering.apply(&mut idx, &mut rng, ops);
    idx
}

/// Fixed vs randomized feeding order (paper §5).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Ordering {
    Fixed,
    Randomized,
}

impl Ordering {
    /// Apply the policy to a gathered training sequence. `rng` is a
    /// per-call derived stream so sequential and parallel engines agree.
    pub fn apply(self, idx: &mut [u32], rng: &mut Rng, ops: &mut OpCounts) {
        if self == Ordering::Randomized {
            rng.shuffle(idx);
            ops.points_permuted += idx.len() as u64;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partition_covers_exactly_once() {
        let f = Folds::new(103, 10, 1);
        assert_eq!(f.k(), 10);
        let mut seen = vec![false; 103];
        for i in 0..10 {
            for &p in f.chunk(i) {
                assert!(!seen[p as usize], "duplicate {p}");
                seen[p as usize] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    /// Remainder-fold coverage property: for every constructor and any
    /// shape — especially `n % k != 0`, plus the k = 1 and k = n (LOOCV)
    /// edges — every index lands in EXACTLY one fold, the fold count is
    /// k, and sizes split as `n % k` chunks of `⌈n/k⌉` followed by
    /// `k − n % k` chunks of `⌊n/k⌋`.
    #[test]
    fn prop_every_index_in_exactly_one_fold() {
        let mut rng = crate::rng::Rng::new(0xF01D5EED);
        let mut shapes: Vec<(usize, usize)> = vec![
            (1, 1),
            (2, 1),
            (7, 7),     // LOOCV
            (103, 10),  // remainder
            (101, 100), // k = n - 1, all-but-one singleton
            (64, 64),
        ];
        for _ in 0..40 {
            let n = 2 + rng.below(300) as usize;
            let k = 1 + rng.below(n as u64) as usize;
            shapes.push((n, k));
        }
        for &(n, k) in &shapes {
            let seed = (n * 31 + k) as u64;
            for (which, f) in [
                ("new", Folds::new(n, k, seed)),
                ("contiguous", Folds::contiguous(n, k)),
                ("new_sorted", Folds::new_sorted(n, k, seed)),
            ] {
                assert_eq!(f.k(), k, "{which} n={n} k={k}");
                assert_eq!(f.n(), n, "{which} n={n} k={k}");
                let mut count = vec![0u32; n];
                for i in 0..k {
                    for &p in f.chunk(i) {
                        count[p as usize] += 1;
                    }
                }
                assert!(
                    count.iter().all(|&c| c == 1),
                    "{which} n={n} k={k}: some index not covered exactly once"
                );
                let (base, extra) = (n / k, n % k);
                for i in 0..k {
                    let want = base + usize::from(i < extra);
                    assert_eq!(f.chunk(i).len(), want, "{which} n={n} k={k} chunk {i}");
                }
                // gather_range over the whole tree root must be a
                // permutation of 0..n (what every engine consumes).
                let mut all = f.gather_range(0, k - 1);
                all.sort_unstable();
                assert!(all.iter().enumerate().all(|(i, &p)| p as usize == i), "{which}");
            }
        }
    }

    #[test]
    #[should_panic(expected = "1 <= k")]
    fn k_above_n_panics() {
        let _ = Folds::new(5, 6, 0);
    }

    #[test]
    #[should_panic(expected = "1 <= k")]
    fn k_zero_panics() {
        let _ = Folds::new(5, 0, 0);
    }

    #[test]
    fn sizes_near_equal() {
        let f = Folds::new(103, 10, 2);
        let sizes: Vec<usize> = (0..10).map(|i| f.chunk(i).len()).collect();
        assert_eq!(sizes.iter().sum::<usize>(), 103);
        assert!(sizes.iter().all(|&s| s == 10 || s == 11));
    }

    #[test]
    fn deterministic_in_seed() {
        let a = Folds::new(50, 5, 9);
        let b = Folds::new(50, 5, 9);
        let c = Folds::new(50, 5, 10);
        for i in 0..5 {
            assert_eq!(a.chunk(i), b.chunk(i));
        }
        assert!((0..5).any(|i| a.chunk(i) != c.chunk(i)));
    }

    #[test]
    fn loocv_is_singletons() {
        let f = Folds::loocv(7);
        assert_eq!(f.k(), 7);
        for i in 0..7 {
            assert_eq!(f.chunk(i), &[i as u32]);
        }
    }

    #[test]
    fn gather_range_hierarchical_order() {
        let f = Folds::contiguous(9, 3);
        assert_eq!(f.gather_range(1, 2), vec![3, 4, 5, 6, 7, 8]);
        assert_eq!(f.gather_range(0, 0), vec![0, 1, 2]);
    }

    #[test]
    fn gather_except_skips_fold() {
        let f = Folds::contiguous(6, 3);
        assert_eq!(f.gather_except(1), vec![0, 1, 4, 5]);
    }

    #[test]
    fn node_tags_unique_per_phase() {
        // Distinct (s, e, side) triples must never collide for u32 ranges.
        let mut seen = std::collections::HashSet::new();
        for (s, e) in [(0usize, 0usize), (0, 1), (0, 7), (1, 7), (4, 7), (0, 1000)] {
            let (r, l) = node_tags(s, e);
            assert_ne!(r, l);
            assert!(seen.insert(r), "({s},{e}) right collides");
            assert!(seen.insert(l), "({s},{e}) left collides");
        }
    }

    #[test]
    fn gather_ordered_fixed_matches_gather_range() {
        let f = Folds::contiguous(9, 3);
        let mut ops = OpCounts::default();
        let idx = gather_ordered(&f, 0, 1, 7, Ordering::Fixed, 42, &mut ops);
        assert_eq!(idx, f.gather_range(0, 1));
        assert_eq!(ops.points_permuted, 0);
        assert_eq!(ops.stream_allocs, 1);
    }

    #[test]
    fn gather_except_into_reuses_buffer() {
        let f = Folds::contiguous(9, 3);
        let mut buf = Vec::new();
        for i in 0..3 {
            f.gather_except_into(i, &mut buf);
            assert_eq!(buf, f.gather_except(i), "fold {i}");
        }
        let cap = buf.capacity();
        f.gather_except_into(0, &mut buf);
        assert_eq!(buf.capacity(), cap, "refill must not reallocate");
    }

    #[test]
    fn append_routes_to_smallest_and_stays_balanced() {
        let mut f = Folds::new(103, 10, 3); // 3 chunks of 11, 7 of 10
        for _ in 0..37 {
            let c = f.smallest_chunk();
            let id = f.n() as u32;
            f.append_to_chunk(c, id);
        }
        assert_eq!(f.n(), 140);
        let sizes: Vec<usize> = (0..10).map(|i| f.chunk(i).len()).collect();
        assert_eq!(sizes.iter().sum::<usize>(), 140);
        let (lo, hi) = (sizes.iter().min().copied(), sizes.iter().max().copied());
        assert!(hi.zip(lo).is_some_and(|(h, l)| h - l <= 1), "{sizes:?}");
        // Still a partition of 0..n.
        let mut all = f.gather_range(0, 9);
        all.sort_unstable();
        assert!(all.iter().enumerate().all(|(i, &p)| p as usize == i));
    }

    #[test]
    #[should_panic(expected = "dense")]
    fn append_rejects_non_dense_id() {
        let mut f = Folds::new(10, 2, 0);
        f.append_to_chunk(0, 11);
    }

    #[test]
    fn retire_below_renumbers_and_preserves_partition() {
        let mut f = Folds::contiguous(12, 3); // chunks [0..4),[4..8),[8..12)
        assert!(f.can_retire_below(3));
        f.retire_below(3);
        assert_eq!(f.n(), 9);
        assert_eq!(f.chunk(0), &[0]); // was [3], shifted down
        assert_eq!(f.chunk(1), &[1, 2, 3, 4]);
        assert_eq!(f.chunk(2), &[5, 6, 7, 8]);
        let mut all = f.gather_range(0, 2);
        all.sort_unstable();
        assert!(all.iter().enumerate().all(|(i, &p)| p as usize == i));
    }

    #[test]
    fn can_retire_below_detects_emptied_chunk() {
        let f = Folds::contiguous(12, 3);
        assert!(f.can_retire_below(3));
        assert!(!f.can_retire_below(4), "cutoff 4 empties chunk 0");
        assert!(!f.can_retire_below(12), "must leave at least one row");
    }

    #[test]
    #[should_panic(expected = "would empty a fold chunk")]
    fn retire_below_rejects_emptied_chunk() {
        let mut f = Folds::contiguous(12, 3);
        f.retire_below(4);
    }

    #[test]
    fn ordering_fixed_is_noop() {
        let mut idx = vec![1u32, 2, 3];
        let mut rng = Rng::new(1);
        let mut ops = OpCounts::default();
        Ordering::Fixed.apply(&mut idx, &mut rng, &mut ops);
        assert_eq!(idx, vec![1, 2, 3]);
        assert_eq!(ops.points_permuted, 0);
    }

    #[test]
    fn ordering_randomized_permutes_and_counts() {
        let mut idx: Vec<u32> = (0..100).collect();
        let orig = idx.clone();
        let mut rng = Rng::new(1);
        let mut ops = OpCounts::default();
        Ordering::Randomized.apply(&mut idx, &mut rng, &mut ops);
        assert_ne!(idx, orig);
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, orig);
        assert_eq!(ops.points_permuted, 100);
    }
}
