//! Repeated (multi-partitioning) cross-validation — the related-work
//! setting of An et al. [2007] (paper §1.1): "To reduce the variance due
//! to different partitionings, the k-CV score can be averaged over
//! multiple random partitionings."
//!
//! [`RepeatedCv`] runs any engine over `l` independent fold assignments
//! and averages; with TreeCV underneath, each partitioning costs
//! O(n log k), so repeated CV costs O(l · n log k) versus the
//! O(l · n k) of An et al.'s specialized LSSVM method generalized
//! naively. The struct also reports the across-partitioning spread, which
//! is exactly the ± column of the paper's Table 2.

use super::executor::{RunCtrl, RunSpec, TreeCvExecutor};
use super::folds::{Folds, Ordering};
use super::standard::StandardCv;
use super::stats::repetition_fold_seed;
use super::treecv::TreeCv;
use super::{CvEngine, CvResult, Strategy};
use crate::data::Dataset;
use crate::learner::IncrementalLearner;
use crate::metrics::{OpCounts, RunningStats, Timer};

/// Which underlying engine the repetitions use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Inner {
    TreeCv(Strategy),
    Standard,
    /// Every partitioning through ONE pooled executor batch
    /// ([`TreeCvExecutor::run_many`]) — per-partitioning results are
    /// bit-identical to `Inner::TreeCv` for exact-revert learners (always
    /// under Copy), without the `L − 1` extra pool spawns.
    PooledTreeCv(Strategy),
}

/// Repeated-partitioning CV.
#[derive(Debug, Clone)]
pub struct RepeatedCv {
    pub inner: Inner,
    pub ordering: Ordering,
    /// Number of independent partitionings (An et al.'s `L`).
    pub partitionings: usize,
    pub seed: u64,
    /// Worker-pool size for [`Inner::PooledTreeCv`] (`0` = machine
    /// parallelism); ignored by the sequential inners.
    pub threads: usize,
}

/// Aggregate over partitionings.
#[derive(Debug, Clone)]
pub struct RepeatedCvResult {
    /// Mean of the per-partitioning k-CV estimates (the repeated-CV score).
    pub estimate: f64,
    /// Sample std across partitionings (the Table-2 ±).
    pub spread: f64,
    /// Every individual k-CV result, in partitioning order.
    pub runs: Vec<CvResult>,
    /// Total work across all partitionings.
    pub ops: OpCounts,
    pub wall: std::time::Duration,
}

impl RepeatedCv {
    pub fn new(inner: Inner, ordering: Ordering, partitionings: usize, seed: u64) -> Self {
        assert!(partitionings >= 1);
        Self { inner, ordering, partitionings, seed, threads: 0 }
    }

    /// Run k-CV under `partitionings` independent fold assignments.
    pub fn run<L>(&self, learner: &L, data: &Dataset, k: usize) -> RepeatedCvResult
    where
        L: IncrementalLearner + Sync,
        L::Model: Send,
    {
        let timer = Timer::start();
        // Fold-assignment seeds share the harness-wide derivation
        // (`cv::stats::repetition_fold_seed`); only the engine-seed xor
        // (0x5EED) is RepeatedCv's own.
        let rep_seed = |r: usize| repetition_fold_seed(self.seed, r);
        let runs: Vec<CvResult> = match self.inner {
            Inner::PooledTreeCv(strategy) => {
                let folds: Vec<Folds> = (0..self.partitionings)
                    .map(|r| Folds::new(data.n, k, rep_seed(r)))
                    .collect();
                // One shared control block: a partitioning that fails
                // mid-batch cancels its siblings' outstanding tree tasks
                // instead of letting the batch run to completion first.
                let batch_ctrl = RunCtrl::new();
                let specs: Vec<RunSpec<'_, L>> = folds
                    .iter()
                    .enumerate()
                    .map(|(r, f)| RunSpec {
                        learner,
                        folds: f,
                        seed: rep_seed(r) ^ 0x5EED,
                        strategy,
                        folded: None,
                        ctrl: batch_ctrl.clone(),
                    })
                    .collect();
                TreeCvExecutor::with_threads_knob(strategy, self.ordering, self.threads)
                    .run_many(data, &specs)
            }
            Inner::TreeCv(_) | Inner::Standard => (0..self.partitionings)
                .map(|r| {
                    let folds = Folds::new(data.n, k, rep_seed(r));
                    match self.inner {
                        Inner::TreeCv(strategy) => {
                            TreeCv::new(strategy, self.ordering, rep_seed(r) ^ 0x5EED)
                                .run(learner, data, &folds)
                        }
                        Inner::Standard => StandardCv::new(self.ordering, rep_seed(r) ^ 0x5EED)
                            .run(learner, data, &folds),
                        Inner::PooledTreeCv(_) => unreachable!("batched above"),
                    }
                })
                .collect(),
        };
        let mut stats = RunningStats::default();
        let mut ops = OpCounts::default();
        for res in &runs {
            stats.push(res.estimate);
            ops.merge(&res.ops);
        }
        RepeatedCvResult {
            estimate: stats.mean(),
            spread: stats.std(),
            runs,
            ops,
            wall: timer.elapsed(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{SyntheticCovertype, SyntheticMixture1d};
    use crate::learner::histdensity::HistogramDensity;
    use crate::learner::pegasos::Pegasos;

    #[test]
    fn averages_over_partitionings() {
        let data = SyntheticMixture1d::new(300, 181).generate();
        let l = HistogramDensity::new(-8.0, 8.0, 32);
        let rep = RepeatedCv::new(Inner::TreeCv(Strategy::Copy), Ordering::Fixed, 8, 3)
            .run(&l, &data, 10);
        assert_eq!(rep.runs.len(), 8);
        let manual: f64 = rep.runs.iter().map(|r| r.estimate).sum::<f64>() / 8.0;
        assert!((rep.estimate - manual).abs() < 1e-12);
        assert!(rep.spread > 0.0);
    }

    /// The variance-reduction claim: averaging over L partitionings gives
    /// an estimator whose deviation from the grand mean shrinks vs a
    /// single partitioning.
    #[test]
    fn repeated_cv_reduces_partitioning_variance() {
        let data = SyntheticCovertype::new(600, 182).generate();
        let l = Pegasos::new(54, 1e-3);
        // Spread of single-partitioning estimates:
        let single = RepeatedCv::new(Inner::TreeCv(Strategy::Copy), Ordering::Fixed, 12, 5)
            .run(&l, &data, 5);
        // Spread of 4-partitioning averages (12 of them):
        let mut avg_stats = crate::metrics::RunningStats::default();
        for g in 0..12u64 {
            let rep = RepeatedCv::new(Inner::TreeCv(Strategy::Copy), Ordering::Fixed, 4, 100 + g)
                .run(&l, &data, 5);
            avg_stats.push(rep.estimate);
        }
        assert!(
            avg_stats.std() < single.spread,
            "repeated {} !< single {}",
            avg_stats.std(),
            single.spread
        );
    }

    #[test]
    fn work_scales_linearly_in_partitionings() {
        let data = SyntheticMixture1d::new(200, 183).generate();
        let l = HistogramDensity::new(-8.0, 8.0, 16);
        let r1 = RepeatedCv::new(Inner::TreeCv(Strategy::Copy), Ordering::Fixed, 1, 9)
            .run(&l, &data, 8);
        let r4 = RepeatedCv::new(Inner::TreeCv(Strategy::Copy), Ordering::Fixed, 4, 9)
            .run(&l, &data, 8);
        assert_eq!(r4.ops.points_updated, 4 * r1.ops.points_updated);
    }

    #[test]
    fn pooled_inner_bit_identical_to_treecv_inner() {
        // One executor batch for all partitionings must reproduce the
        // per-partitioning sequential engine exactly — per_fold vectors,
        // estimate and spread — for an exact-revert learner under both
        // strategies, and for an order-sensitive learner under Copy.
        let data = SyntheticMixture1d::new(320, 185).generate();
        let l = HistogramDensity::new(-8.0, 8.0, 32);
        for strategy in [Strategy::Copy, Strategy::SaveRevert] {
            let a = RepeatedCv::new(Inner::TreeCv(strategy), Ordering::Fixed, 6, 17)
                .run(&l, &data, 9);
            let b = RepeatedCv::new(Inner::PooledTreeCv(strategy), Ordering::Fixed, 6, 17)
                .run(&l, &data, 9);
            assert_eq!(a.estimate.to_bits(), b.estimate.to_bits(), "{strategy:?}");
            assert_eq!(a.spread.to_bits(), b.spread.to_bits(), "{strategy:?}");
            for (x, y) in a.runs.iter().zip(&b.runs) {
                assert_eq!(x.per_fold, y.per_fold, "{strategy:?}");
            }
        }
        let cover = SyntheticCovertype::new(500, 186).generate();
        let p = Pegasos::new(54, 1e-3);
        let a = RepeatedCv::new(Inner::TreeCv(Strategy::Copy), Ordering::Randomized, 5, 19)
            .run(&p, &cover, 7);
        let b = RepeatedCv::new(Inner::PooledTreeCv(Strategy::Copy), Ordering::Randomized, 5, 19)
            .run(&p, &cover, 7);
        assert_eq!(a.estimate.to_bits(), b.estimate.to_bits());
        assert_eq!(a.spread.to_bits(), b.spread.to_bits());
    }

    #[test]
    fn tree_and_standard_agree_for_insensitive_learner() {
        let data = SyntheticMixture1d::new(240, 184).generate();
        let l = HistogramDensity::new(-8.0, 8.0, 32);
        let a = RepeatedCv::new(Inner::TreeCv(Strategy::Copy), Ordering::Fixed, 5, 11)
            .run(&l, &data, 6);
        let b = RepeatedCv::new(Inner::Standard, Ordering::Fixed, 5, 11).run(&l, &data, 6);
        assert_eq!(a.estimate, b.estimate);
        assert_eq!(a.spread, b.spread);
    }
}
