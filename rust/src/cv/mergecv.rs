//! The Izbicki [2013] fold-merging baseline ("algebraic classifiers"),
//! implemented for learners satisfying its restrictive assumption: models
//! trained on two datasets can be *merged* in O(model) time into the model
//! trained on the union ([`MergeableLearner`]).
//!
//! Train one model per chunk — O(n) total update work — then build prefix
//! and suffix merges so each fold's leave-chunk-out model is a single merge
//! `prefix[i] ⊕ suffix[i+1]`: O(k) merges total, giving the O(n + k)
//! complexity the paper's related-work section quotes. The paper's point is
//! that this only works for "simple methods, such as Bayesian
//! classification" — our [`crate::learner::naive_bayes::GaussianNb`] and
//! [`crate::learner::histdensity::HistogramDensity`] qualify; PEGASOS and
//! LSQSGD do not, which is exactly why TreeCV is needed.

use super::folds::Folds;
use super::CvResult;
use crate::data::Dataset;
use crate::learner::MergeableLearner;
use crate::metrics::{OpCounts, Timer};

/// The fold-merging CV engine.
#[derive(Debug, Clone, Default)]
pub struct MergeCv;

impl MergeCv {
    /// Compute k-CV via per-chunk models and prefix/suffix merging.
    pub fn run<L: MergeableLearner>(&self, learner: &L, data: &Dataset, folds: &Folds) -> CvResult {
        let timer = Timer::start();
        let k = folds.k();
        let mut ops = OpCounts::default();

        // One model per chunk: total O(n) update points.
        let chunk_models: Vec<L::Model> = (0..k)
            .map(|i| {
                let mut m = learner.init();
                let idx = folds.chunk(i);
                learner.update(&mut m, data, idx);
                ops.update_calls += 1;
                ops.points_updated += idx.len() as u64;
                m
            })
            .collect();

        // prefix[i] = merge of chunks [0, i); suffix[i] = merge of [i, k).
        // prefix[0] and suffix[k] are the empty model.
        let mut prefix: Vec<L::Model> = Vec::with_capacity(k + 1);
        prefix.push(learner.init());
        for i in 0..k {
            let next = learner.merge(&prefix[i], &chunk_models[i]);
            ops.model_copies += 1; // a merge materializes a model
            ops.bytes_copied += learner.model_bytes(&next) as u64;
            prefix.push(next);
        }
        let mut suffix: Vec<L::Model> = vec![learner.init(); k + 1];
        for i in (0..k).rev() {
            suffix[i] = learner.merge(&chunk_models[i], &suffix[i + 1]);
            ops.model_copies += 1;
            ops.bytes_copied += learner.model_bytes(&suffix[i]) as u64;
        }

        let mut per_fold = vec![0.0; k];
        for i in 0..k {
            let model = learner.merge(&prefix[i], &suffix[i + 1]);
            ops.model_copies += 1;
            let chunk = folds.chunk(i);
            per_fold[i] = learner.evaluate(&model, data, chunk);
            ops.evals += 1;
            ops.points_evaluated += chunk.len() as u64;
        }
        CvResult::from_folds(per_fold, ops, timer.elapsed())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cv::standard::StandardCv;
    use crate::cv::treecv::TreeCv;
    use crate::cv::CvEngine;
    use crate::data::synth::{SyntheticCovertype, SyntheticMixture1d};
    use crate::learner::histdensity::HistogramDensity;
    use crate::learner::naive_bayes::GaussianNb;

    /// For an exactly-mergeable learner all three engines agree.
    #[test]
    fn merge_equals_standard_and_treecv_for_histogram() {
        let data = SyntheticMixture1d::new(300, 101).generate();
        let l = HistogramDensity::new(-8.0, 8.0, 32);
        for k in [2, 4, 10, 30] {
            let folds = Folds::new(300, k, 102);
            let merge = MergeCv.run(&l, &data, &folds);
            let std_res = StandardCv::default().run(&l, &data, &folds);
            let tree = TreeCv::default().run(&l, &data, &folds);
            assert_eq!(merge.per_fold, std_res.per_fold, "k={k}");
            assert_eq!(merge.per_fold, tree.per_fold, "k={k}");
        }
    }

    #[test]
    fn merge_matches_standard_for_naive_bayes() {
        let data = SyntheticCovertype::new(400, 103).generate();
        let l = GaussianNb::new(54);
        let folds = Folds::new(400, 8, 104);
        let merge = MergeCv.run(&l, &data, &folds);
        let std_res = StandardCv::default().run(&l, &data, &folds);
        for (a, b) in merge.per_fold.iter().zip(&std_res.per_fold) {
            assert!((a - b).abs() < 1e-12, "{a} vs {b}");
        }
    }

    /// Work accounting: update points are exactly n (each point trained
    /// once), versus standard CV's k·(n−b).
    #[test]
    fn update_work_is_linear_in_n_only() {
        let data = SyntheticMixture1d::new(200, 105).generate();
        let l = HistogramDensity::new(-8.0, 8.0, 16);
        for k in [2usize, 10, 50] {
            let folds = Folds::new(200, k, 106);
            let res = MergeCv.run(&l, &data, &folds);
            assert_eq!(res.ops.points_updated, 200, "k={k}");
            // 2k prefix/suffix merges + k final merges.
            assert_eq!(res.ops.model_copies, 3 * k as u64);
        }
    }
}
