//! Incremental re-estimation of the TreeCV estimate after the dataset
//! grows: the streaming half of ROADMAP's "heavy traffic" scenario.
//!
//! [`FoldedDataset::append_rows`] lands a batch in fold-balanced tail
//! chunks and reports which folds it touched ([`AppendDelta`]). Appending
//! to fold `t` changes fold `t`'s *evaluation* chunk and every **other**
//! fold's *training* complement, so all `k` per-fold scores legitimately
//! move — a refresh must rewrite the whole `per_fold` vector. What it does
//! NOT have to do is re-run the whole tree node by node: along the
//! root-to-leaf path of a touched fold, the sibling subtree at each level
//! is clean *inside* but its incoming model absorbed the new rows, so it
//! is re-run wholesale through the shared recursion
//! ([`run_subtree`]) — O(log k) such subtree re-runs per touched fold —
//! while the dirty child's incoming model is either rebuilt by one update
//! phase or restored from the [`RefreshSession`] cache of interior
//! snapshots. The new [`OpCounts::subtrees_recomputed`] counter pins that
//! bound: ≤ ⌈log₂(2k)⌉ per touched fold (the ⌈log₂ k⌉ sibling re-runs
//! plus the touched leaf's own re-evaluation).
//!
//! **Cache contract.** An entry keyed `(a, b)` holds the *incoming* model
//! of node `(a, b)`: trained on every chunk outside `a..=b` as of
//! insertion time. It stays valid exactly while every subsequent append
//! touches only folds inside `[a, b]`; [`TreeCvExecutor::refresh`]
//! enforces this by purging, at entry, every key that does not contain
//! the current touched range (inductively sufficient: an earlier refresh
//! touching outside `[a, b]` purged the entry then). Surviving keys form
//! a nested chain around the touched folds, so the cache holds O(log k)
//! models.
//!
//! **Bit-identity.** A refresh replays, stream for stream, the exact
//! update phases (`(seed, node-tag)`-derived, order included) that a
//! from-scratch [`super::treecv::TreeCv::run_folded`] on the extended
//! layout would run. Under [`Strategy::Copy`] the refreshed estimate and
//! per-fold scores are therefore bit-identical for every learner; under
//! [`Strategy::SaveRevert`] they are bit-identical whenever the learner's
//! revert is exact (the from-scratch run reaches interior models through
//! revert cascades, the refresh through clones), and agree to
//! accumulated-rounding tolerance for the f32-inexact learners —
//! `tests/integration_serve.rs` asserts both tiers.

use std::collections::HashMap;

use super::executor::TreeCvExecutor;
use super::folds::node_tags;
use super::treecv::{run_subtree, NodeCtx, StreamScratch};
use super::{CvResult, Strategy};
use crate::data::folded::{AppendDelta, FoldedDataset};
use crate::data::Dataset;
use crate::learner::IncrementalLearner;
use crate::metrics::{OpCounts, Timer};

/// Interior-model snapshots carried between refreshes of one logical
/// stream. Create with [`TreeCvExecutor::prime`] (or `default()`), feed
/// every subsequent [`TreeCvExecutor::refresh`] of the same stream, and
/// [`RefreshSession::invalidate`] after any mutation other than
/// `append_rows` (e.g. [`FoldedDataset::retire_oldest`], which renumbers
/// rows under every cached model).
pub struct RefreshSession<L: IncrementalLearner> {
    /// Cached *incoming* models keyed by node range `(a, b)` — see the
    /// module docs for the validity contract.
    cache: HashMap<(usize, usize), L::Model>,
}

impl<L: IncrementalLearner> RefreshSession<L> {
    pub fn new() -> Self {
        Self { cache: HashMap::new() }
    }

    /// Drop every cached snapshot. Required after any dataset mutation
    /// that is not an `append_rows` the next `refresh` will be told about.
    pub fn invalidate(&mut self) {
        self.cache.clear();
    }

    /// Number of interior models currently cached (O(log k) by the purge
    /// rule; exposed for tests and staleness diagnostics).
    pub fn cached_nodes(&self) -> usize {
        self.cache.len()
    }
}

impl<L: IncrementalLearner> Default for RefreshSession<L> {
    fn default() -> Self {
        Self::new()
    }
}

/// One level of the dirty path: the incoming `model` of node `(s, e)` is
/// fed the dirty half (`dirty_lo..=dirty_hi` under `dirty_tag`) and the
/// clean sibling subtree `(sib_lo, sib_hi)` is re-run wholesale —
/// writing its per-fold scores — after which `model` is restored to the
/// node's incoming state (snapshot/restore under Copy, log/revert under
/// SaveRevert, mirroring [`run_subtree`]'s own arms).
#[allow(clippy::too_many_arguments)]
fn rerun_sibling<L: IncrementalLearner>(
    ctx: &NodeCtx<'_, L>,
    model: &mut L::Model,
    dirty_lo: usize,
    dirty_hi: usize,
    dirty_tag: u64,
    sib_lo: usize,
    sib_hi: usize,
    per_fold: &mut [f64],
    ops: &mut OpCounts,
    scratch: &mut Vec<L::Model>,
    streams: &mut StreamScratch,
) {
    match ctx.strategy {
        Strategy::Copy => {
            let saved = match scratch.pop() {
                Some(mut buf) => {
                    buf.clone_from(model);
                    buf
                }
                None => model.clone(),
            };
            ops.model_copies += 1;
            ops.bytes_copied += ctx.learner.model_bytes(&saved) as u64;
            ctx.update_phase(model, dirty_lo, dirty_hi, dirty_tag, ops, streams);
            run_subtree(ctx, model, sib_lo, sib_hi, 0, per_fold, ops, scratch, streams);
            let spent = std::mem::replace(model, saved);
            scratch.push(spent);
        }
        Strategy::SaveRevert => {
            let undo = ctx.update_phase_logged(model, dirty_lo, dirty_hi, dirty_tag, ops, streams);
            run_subtree(ctx, model, sib_lo, sib_hi, 0, per_fold, ops, scratch, streams);
            ctx.learner.revert(model, ctx.data, undo);
            ops.model_restores += 1;
        }
    }
    ops.subtrees_recomputed += 1;
}

/// Advance `model` from node `(s, e)`'s incoming state to the dirty
/// child's incoming state via the *clean* half's update phase — or skip
/// the feed entirely when the child's incoming model is cached. On a
/// cache miss the freshly built model is snapshotted under `key` (the
/// dirty child's range) so the next refresh down the same path starts
/// here.
#[allow(clippy::too_many_arguments)]
fn chain_feed<L: IncrementalLearner>(
    ctx: &NodeCtx<'_, L>,
    model: &mut L::Model,
    clean_lo: usize,
    clean_hi: usize,
    clean_tag: u64,
    key: (usize, usize),
    ops: &mut OpCounts,
    streams: &mut StreamScratch,
    cache: &mut HashMap<(usize, usize), L::Model>,
) {
    if let Some(cached) = cache.get(&key) {
        model.clone_from(cached);
        ops.model_copies += 1;
        ops.bytes_copied += ctx.learner.model_bytes(model) as u64;
        return;
    }
    ctx.update_phase(model, clean_lo, clean_hi, clean_tag, ops, streams);
    let snap = model.clone();
    ops.model_copies += 1;
    ops.bytes_copied += ctx.learner.model_bytes(&snap) as u64;
    cache.insert(key, snap);
}

/// The refresh recursion: `model` is node `(s, e)`'s incoming model on
/// the **extended** dataset, `touched` the (sorted, non-empty) touched
/// folds inside `s..=e`. Writes every per-fold score in `s..=e` exactly
/// once: clean sibling subtrees wholesale via [`rerun_sibling`], touched
/// leaves by direct re-evaluation, straddled nodes by descending both
/// halves from a snapshot pair (no wholesale re-run, no counter bump —
/// both children are on dirty paths).
#[allow(clippy::too_many_arguments)]
fn refresh_node<L: IncrementalLearner>(
    ctx: &NodeCtx<'_, L>,
    model: &mut L::Model,
    s: usize,
    e: usize,
    touched: &[usize],
    per_fold: &mut [f64],
    ops: &mut OpCounts,
    scratch: &mut Vec<L::Model>,
    streams: &mut StreamScratch,
    cache: &mut HashMap<(usize, usize), L::Model>,
) {
    if s == e {
        debug_assert_eq!(touched, [s]);
        per_fold[s] = ctx.eval_leaf(model, s, ops);
        ops.subtrees_recomputed += 1;
        return;
    }
    let m = (s + e) / 2;
    let (tag_right, tag_left) = node_tags(s, e);
    let split = touched.partition_point(|&f| f <= m);
    let (tl, tr) = touched.split_at(split);
    if tr.is_empty() {
        // Dirty left half: right sibling re-runs wholesale, then descend
        // left from the (cacheable) left-child incoming model.
        rerun_sibling(ctx, model, s, m, tag_left, m + 1, e, per_fold, ops, scratch, streams);
        chain_feed(ctx, model, m + 1, e, tag_right, (s, m), ops, streams, cache);
        refresh_node(ctx, model, s, m, tl, per_fold, ops, scratch, streams, cache);
    } else if tl.is_empty() {
        // Dirty right half: mirror image.
        rerun_sibling(ctx, model, m + 1, e, tag_right, s, m, per_fold, ops, scratch, streams);
        chain_feed(ctx, model, s, m, tag_left, (m + 1, e), ops, streams, cache);
        refresh_node(ctx, model, m + 1, e, tr, per_fold, ops, scratch, streams, cache);
    } else {
        // Straddle: both halves dirty. Build both children's incoming
        // models from one snapshot and descend each; neither half is
        // clean, so nothing re-runs wholesale and nothing is cached.
        let mut sib = match scratch.pop() {
            Some(mut buf) => {
                buf.clone_from(model);
                buf
            }
            None => model.clone(),
        };
        ops.model_copies += 1;
        ops.bytes_copied += ctx.learner.model_bytes(&sib) as u64;
        ctx.update_phase(&mut sib, s, m, tag_left, ops, streams);
        ctx.update_phase(model, m + 1, e, tag_right, ops, streams);
        refresh_node(ctx, model, s, m, tl, per_fold, ops, scratch, streams, cache);
        refresh_node(ctx, &mut sib, m + 1, e, tr, per_fold, ops, scratch, streams, cache);
        scratch.push(sib);
    }
}

impl TreeCvExecutor {
    /// Establish the baseline estimate for a stream: one ordinary pooled
    /// from-scratch folded run plus a fresh (empty) [`RefreshSession`]
    /// for the appends that follow.
    pub fn prime<L>(
        &self,
        learner: &L,
        data: &Dataset,
        folded: &FoldedDataset,
    ) -> (RefreshSession<L>, CvResult)
    where
        L: IncrementalLearner + Sync,
        L::Model: Send,
    {
        (RefreshSession::new(), self.run_folded(learner, data, folded))
    }

    /// Re-estimate after [`FoldedDataset::append_rows`] extended the
    /// stream's dataset: recompute only the O(log k) subtrees per touched
    /// fold that the appended rows dirtied (see the module docs), under
    /// this executor's `strategy`/`ordering`/`seed`. `data` and `folded`
    /// must already include the appended rows and `delta` must be the
    /// value `append_rows` returned. Runs sequentially on the calling
    /// thread — the whole point is that the work is tiny compared to a
    /// pooled from-scratch run.
    pub fn refresh<L: IncrementalLearner>(
        &self,
        session: &mut RefreshSession<L>,
        learner: &L,
        data: &Dataset,
        folded: &FoldedDataset,
        delta: &AppendDelta,
    ) -> CvResult {
        assert_eq!(folded.n(), data.n, "folded layout built for a different dataset (n)");
        assert_eq!(folded.d(), data.d, "folded layout built for a different dataset (d)");
        let k = folded.folds().k();
        assert!(!delta.touched.is_empty(), "refresh needs a non-empty touched-fold set");
        assert!(
            delta.touched.windows(2).all(|w| w[0] < w[1]),
            "AppendDelta::touched must be sorted ascending and deduplicated"
        );
        let fmin = delta.touched[0];
        let fmax = delta.touched[delta.touched.len() - 1];
        assert!(fmax < k, "touched fold {fmax} out of range for k = {k}");
        // Purge every cached node whose range does not contain the whole
        // touched set: its complement (= its training data) just grew.
        session.cache.retain(|&(a, b), _| a <= fmin && fmax <= b);

        let timer = Timer::start();
        let ctx = NodeCtx {
            learner,
            data,
            folds: folded.folds(),
            folded: Some(folded),
            strategy: self.strategy,
            ordering: self.ordering,
            seed: self.seed,
        };
        let mut ops = OpCounts::default();
        let mut per_fold = vec![0.0; k];
        let mut model = learner.init();
        let mut scratch = Vec::new();
        let mut streams = StreamScratch::new();
        refresh_node(
            &ctx,
            &mut model,
            0,
            k - 1,
            &delta.touched,
            &mut per_fold,
            &mut ops,
            &mut scratch,
            &mut streams,
            &mut session.cache,
        );
        CvResult::from_folds(per_fold, ops, timer.elapsed())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cv::folds::{Folds, Ordering};
    use crate::cv::treecv::TreeCv;
    use crate::learner::multiset::MultisetLearner;

    fn dummy(n: usize) -> Dataset {
        Dataset::new(vec![0.0; n], vec![0.0; n], 1)
    }

    fn ceil_log2(k: usize) -> u64 {
        (usize::BITS - (k - 1).leading_zeros()) as u64
    }

    /// Refresh after each appended batch must reproduce a from-scratch
    /// folded run on the extended layout bitwise, while staying under the
    /// ⌈log₂(2k)⌉-per-touched-fold subtree budget.
    #[test]
    fn refresh_matches_scratch_and_respects_budget() {
        for (n, k, batches) in [(40usize, 8usize, 4usize), (43, 8, 3), (30, 5, 5), (12, 12, 3)] {
            for strategy in [Strategy::Copy, Strategy::SaveRevert] {
                for ordering in [Ordering::Fixed, Ordering::Randomized] {
                    let mut data = dummy(n);
                    let folds = Folds::new(n, k, 21);
                    let mut folded = FoldedDataset::build(&data, &folds);
                    let l = MultisetLearner::new(1);
                    let exe = TreeCvExecutor::new(strategy, ordering, 7, 1);
                    let (mut session, _) = exe.prime(&l, &data, &folded);
                    for b in 0..batches {
                        let rows = b + 1; // growing batch sizes
                        let x = vec![0.0f32; rows];
                        data.push_rows(&x, &x);
                        let delta = folded.append_rows(&x, &x);
                        let got = exe.refresh(&mut session, &l, &data, &folded, &delta);
                        let want =
                            TreeCv::new(strategy, ordering, 7).run_folded(&l, &data, &folded);
                        assert_eq!(
                            got.per_fold, want.per_fold,
                            "n={n} k={k} batch={b} {strategy:?} {ordering:?}"
                        );
                        assert_eq!(got.estimate, want.estimate);
                        let budget = delta.touched.len() as u64 * (ceil_log2(k) + 1);
                        assert!(
                            got.ops.subtrees_recomputed <= budget,
                            "n={n} k={k} batch={b}: {} > {budget}",
                            got.ops.subtrees_recomputed
                        );
                        assert_eq!(want.ops.subtrees_recomputed, 0, "scratch runs never refresh");
                    }
                }
            }
        }
    }

    /// A refresh down an already-cached path must reuse the interior
    /// snapshots: re-running the same delta on the warm session skips
    /// every chain feed (strictly fewer points updated), reproduces the
    /// cold result bitwise, and keeps the cache at O(log k) entries.
    #[test]
    fn repeated_refresh_reuses_cached_chain() {
        let n = 64;
        let k = 8;
        let mut data = dummy(n);
        let folds = Folds::new(n, k, 5);
        let mut folded = FoldedDataset::build(&data, &folds);
        let l = MultisetLearner::new(1);
        let exe = TreeCvExecutor::new(Strategy::Copy, Ordering::Fixed, 0, 1);
        let (mut session, _) = exe.prime(&l, &data, &folded);

        let x = vec![0.0f32; 1];
        data.push_rows(&x, &x);
        let delta = folded.append_rows(&x, &x);
        let cold = exe.refresh(&mut session, &l, &data, &folded, &delta);
        let cached = session.cached_nodes();
        assert!(cached >= 1, "first refresh must populate the chain");
        assert!(cached as u64 <= ceil_log2(k) + 1, "cache stays O(log k)");

        let warm = exe.refresh(&mut session, &l, &data, &folded, &delta);
        assert_eq!(warm.per_fold, cold.per_fold, "cache path must be bit-identical");
        assert!(
            warm.ops.points_updated < cold.ops.points_updated,
            "cached chain must save update work: {} !< {}",
            warm.ops.points_updated,
            cold.ops.points_updated
        );
        assert_eq!(session.cached_nodes(), cached, "re-refresh adds no new entries");
    }

    /// `invalidate` empties the cache and the next refresh still agrees
    /// with a from-scratch run (it just rebuilds the chain).
    #[test]
    fn invalidate_then_refresh_still_correct() {
        let n = 30;
        let k = 6;
        let mut data = dummy(n);
        let folds = Folds::new(n, k, 11);
        let mut folded = FoldedDataset::build(&data, &folds);
        let l = MultisetLearner::new(1);
        let exe = TreeCvExecutor::new(Strategy::Copy, Ordering::Fixed, 0, 1);
        let (mut session, _) = exe.prime(&l, &data, &folded);
        let x = vec![0.0f32; 3];
        data.push_rows(&x, &x);
        let delta = folded.append_rows(&x, &x);
        let _ = exe.refresh(&mut session, &l, &data, &folded, &delta);
        session.invalidate();
        assert_eq!(session.cached_nodes(), 0);
        let x = vec![0.0f32; 2];
        data.push_rows(&x, &x);
        let delta = folded.append_rows(&x, &x);
        let got = exe.refresh(&mut session, &l, &data, &folded, &delta);
        let want = TreeCv::default().run_folded(&l, &data, &folded);
        assert_eq!(got.per_fold, want.per_fold);
    }

    #[test]
    #[should_panic(expected = "non-empty touched-fold set")]
    fn refresh_rejects_empty_delta() {
        let data = dummy(20);
        let folds = Folds::new(20, 4, 1);
        let folded = FoldedDataset::build(&data, &folds);
        let l = MultisetLearner::new(1);
        let exe = TreeCvExecutor::new(Strategy::Copy, Ordering::Fixed, 0, 1);
        let (mut session, _) = exe.prime(&l, &data, &folded);
        let delta = AppendDelta { appended: vec![], fold_of: vec![], touched: vec![] };
        let _ = exe.refresh(&mut session, &l, &data, &folded, &delta);
    }
}
