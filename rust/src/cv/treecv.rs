//! TREECV (paper Algorithm 1): tree-structured computation of the k-CV
//! estimate for incremental learners.
//!
//! `TREECV(s, e, f̂_{s..e})` receives a model trained on every chunk
//! *except* `Z_s..Z_e`. If `s == e` it evaluates the model on the held-out
//! chunk `Z_s` (computing `R̂_s`). Otherwise it splits at the midpoint
//! `m = ⌊(s+e)/2⌋`, updates the model with the *second* group
//! `Z_{m+1}..Z_e` and recurses on `(s, m)`, then — starting again from the
//! model it received — updates with the *first* group `Z_s..Z_m` and
//! recurses on `(m+1, e)`. `TREECV(1, k, ∅)` yields `R̂_{k-CV}`.
//!
//! Each chunk is added to exactly one model per tree level and the tree has
//! `⌈log₂ k⌉` levels, so total update work is `O(n log k)` (Theorem 3) and
//! at most one saved model per level is live at a time, i.e. `O(log k)`
//! extra storage (§4.1).
//!
//! "Starting again from the model it received" is the engine's policy
//! choice (paper §4.1): [`Strategy::Copy`] snapshots the incoming model;
//! [`Strategy::SaveRevert`] logs the changes each update makes and reverts
//! them. With SaveRevert this implementation also reverts the *second*
//! update before returning, so every call leaves the model exactly as it
//! found it — that invariant is what makes the recursion compose.

use super::folds::{gather_ordered, node_tags, Folds, Ordering};
use super::{CvResult, Strategy};
use crate::data::folded::FoldedDataset;
use crate::data::Dataset;
use crate::learner::IncrementalLearner;
use crate::metrics::{OpCounts, Timer};
use crate::rng::Rng;

/// Free-list of recycled `Vec<u32>` node-stream buffers: randomized
/// orderings on the fold-contiguous layout permute into one of these
/// (copy + in-place shuffle) instead of allocating per node. Popping an
/// empty list allocates a fresh buffer and counts it in
/// `OpCounts::stream_allocs`; every buffer is returned after its update,
/// so a sequential run holds exactly one and an executor worker holds one
/// per pool lifetime.
pub(crate) struct StreamScratch(Vec<Vec<u32>>);

impl StreamScratch {
    pub(crate) fn new() -> Self {
        Self(Vec::new())
    }

    fn acquire(&mut self, ops: &mut OpCounts) -> Vec<u32> {
        self.0.pop().unwrap_or_else(|| {
            ops.stream_allocs += 1;
            Vec::new()
        })
    }

    fn release(&mut self, buf: Vec<u32>) {
        self.0.push(buf);
    }
}

/// The per-run inputs every TreeCV node shares: learner, data access
/// (indexed, plus the optional fold-contiguous layout), strategy,
/// ordering, and the permutation-stream seed. One `NodeCtx` describes one
/// run; the engines build it once (or once per task, it is all borrows)
/// and thread it through [`run_subtree`].
///
/// When `folded` is `Some`, its layout MUST realize exactly `folds`
/// (callers assert [`FoldedDataset::matches_folds`]) and have been built
/// from `data`. Node streams then come from the layout: fixed-order
/// updates and all leaf evaluations feed contiguous row slices through
/// the learner's `update_rows`/`evaluate_rows` fast paths with no index
/// vector at all; randomized updates shuffle a recycled id buffer.
/// Indexed calls (randomized streams, `update_logged`, the fast-path
/// *defaults*) always receive **original** indices against the original
/// `data`, which is why every engine × strategy × ordering combination is
/// bit-identical across layouts — including index-dependent learners.
pub(crate) struct NodeCtx<'a, L: IncrementalLearner> {
    pub learner: &'a L,
    pub data: &'a Dataset,
    pub folds: &'a Folds,
    pub folded: Option<&'a FoldedDataset>,
    pub strategy: Strategy,
    pub ordering: Ordering,
    pub seed: u64,
}

impl<L: IncrementalLearner> NodeCtx<'_, L> {
    /// Shared tail of both update phases for every case that reaches the
    /// learner through an *indexed* call: materialize the phase's id
    /// stream — a recycled, shuffled copy of the folded layout's
    /// contiguous id slice, or the classic per-node `gather_ordered` —
    /// and hand it to `feed`. One copy of the stream derivation, so the
    /// plain and logged phases cannot drift. (Fixed ordering on a folded
    /// layout never comes here: it feeds contiguous slices directly.)
    fn with_id_stream<R>(
        &self,
        lo: usize,
        hi: usize,
        tag: u64,
        ops: &mut OpCounts,
        streams: &mut StreamScratch,
        feed: impl FnOnce(&Dataset, &[u32]) -> R,
    ) -> R {
        match self.folded {
            Some(f) => {
                let ids = f.ids(lo, hi);
                ops.points_updated += ids.len() as u64;
                let mut buf = streams.acquire(ops);
                buf.clear();
                buf.extend_from_slice(ids);
                let mut rng = Rng::derive(self.seed, tag);
                self.ordering.apply(&mut buf, &mut rng, ops);
                let out = feed(self.data, &buf);
                streams.release(buf);
                out
            }
            None => {
                let idx = gather_ordered(self.folds, lo, hi, self.seed, self.ordering, tag, ops);
                ops.points_updated += idx.len() as u64;
                feed(self.data, &idx)
            }
        }
    }

    /// One update phase: feed chunks `lo..=hi` (under the run's ordering,
    /// with the node-phase `tag`'s derived stream) into `model` via
    /// `update`. Counter contract: one `update_calls` bump and the phase's
    /// point count, identical across layouts.
    pub(crate) fn update_phase(
        &self,
        model: &mut L::Model,
        lo: usize,
        hi: usize,
        tag: u64,
        ops: &mut OpCounts,
        streams: &mut StreamScratch,
    ) {
        ops.update_calls += 1;
        if let (Some(f), Ordering::Fixed) = (self.folded, self.ordering) {
            let (x, y, ids) = f.rows(lo, hi);
            ops.points_updated += ids.len() as u64;
            self.learner.update_rows(model, x, y, self.data, ids);
            return;
        }
        self.with_id_stream(lo, hi, tag, ops, streams, |data, ids| {
            self.learner.update(model, data, ids);
        });
    }

    /// [`Self::update_phase`] via `update_logged` (save/revert strategy).
    /// The logged path stays indexed — undo logs speak in original
    /// indices — but on the folded layout the fixed-order id slice is a
    /// borrow, so it is still free of per-node index-vector allocations.
    pub(crate) fn update_phase_logged(
        &self,
        model: &mut L::Model,
        lo: usize,
        hi: usize,
        tag: u64,
        ops: &mut OpCounts,
        streams: &mut StreamScratch,
    ) -> L::Undo {
        ops.update_calls += 1;
        if let (Some(f), Ordering::Fixed) = (self.folded, self.ordering) {
            let ids = f.ids(lo, hi);
            ops.points_updated += ids.len() as u64;
            return self.learner.update_logged(model, self.data, ids);
        }
        self.with_id_stream(lo, hi, tag, ops, streams, |data, ids| {
            self.learner.update_logged(model, data, ids)
        })
    }

    /// Leaf evaluation of fold `s` (held-out chunk, in chunk order under
    /// both orderings — the paper randomizes training streams only).
    pub(crate) fn eval_leaf(&self, model: &L::Model, s: usize, ops: &mut OpCounts) -> f64 {
        ops.evals += 1;
        match self.folded {
            Some(f) => {
                let (x, y, ids) = f.rows(s, s);
                ops.points_evaluated += ids.len() as u64;
                self.learner.evaluate_rows(model, x, y, self.data, ids)
            }
            None => {
                let chunk = self.folds.chunk(s);
                ops.points_evaluated += chunk.len() as u64;
                self.learner.evaluate(model, self.data, chunk)
            }
        }
    }
}

/// Run the TreeCV recursion (Algorithm 1) over the subtree rooted at
/// `(s, e)`, sequentially, with the context's model-preservation strategy.
///
/// This is *the* sequential recursion: [`TreeCv`] runs it over the whole
/// tree, the pooled executor ([`super::executor::TreeCvExecutor`]) runs it
/// inline on a worker for every subtree below its snapshot cutoff, and
/// [`super::parallel::ScopedForkTreeCv`] runs it as its sequential tail —
/// one implementation instead of three hand-synchronized copies. Node
/// streams come from [`NodeCtx`], so the fold-contiguous layout and the
/// indexed path share every line of scheduling logic.
///
/// `model` must be trained on every chunk outside `s..=e`; fold `i`'s score
/// is written to `per_fold[i - base]` (callers hand a slice covering
/// exactly their subtree by passing `base = s`, or the whole run with
/// `base = 0`). Under [`Strategy::SaveRevert`] the recursion also reverts
/// the *second* update before returning, so every call leaves `model`
/// exactly as it found it — that invariant is what makes the recursion
/// compose, and what lets the executor recycle the buffer afterwards.
///
/// `scratch` is a free-list of model buffers for Copy-strategy snapshots:
/// each interior node pops a buffer (`clone_from` reuses its storage) and
/// pushes the spent one back at its restore, so steady-state allocation is
/// the recursion depth, not one fresh model per node. `streams` plays the
/// same role for randomized-ordering id buffers on the folded layout.
/// Callers pass empty containers (or longer-lived ones to recycle across
/// calls, as the executor's workers do).
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_subtree<L: IncrementalLearner>(
    ctx: &NodeCtx<'_, L>,
    model: &mut L::Model,
    s: usize,
    e: usize,
    base: usize,
    per_fold: &mut [f64],
    ops: &mut OpCounts,
    scratch: &mut Vec<L::Model>,
    streams: &mut StreamScratch,
) {
    if s == e {
        per_fold[s - base] = ctx.eval_leaf(model, s, ops);
        return;
    }
    let m = (s + e) / 2;
    // Unique tags for this node's two update phases (u32 ranges), shared
    // with the parallel engines via `folds::node_tags`.
    let (tag_right, tag_left) = node_tags(s, e);

    match ctx.strategy {
        Strategy::Copy => {
            let saved = match scratch.pop() {
                Some(mut buf) => {
                    buf.clone_from(model);
                    buf
                }
                None => model.clone(),
            };
            ops.model_copies += 1;
            ops.bytes_copied += ctx.learner.model_bytes(&saved) as u64;

            ctx.update_phase(model, m + 1, e, tag_right, ops, streams);
            run_subtree(ctx, model, s, m, base, per_fold, ops, scratch, streams);

            // Restore the snapshot and recycle the spent buffer for a
            // descendant's next snapshot.
            let spent = std::mem::replace(model, saved);
            scratch.push(spent);
            ctx.update_phase(model, s, m, tag_left, ops, streams);
            run_subtree(ctx, model, m + 1, e, base, per_fold, ops, scratch, streams);
        }
        Strategy::SaveRevert => {
            let undo = ctx.update_phase_logged(model, m + 1, e, tag_right, ops, streams);
            run_subtree(ctx, model, s, m, base, per_fold, ops, scratch, streams);
            ctx.learner.revert(model, ctx.data, undo);
            ops.model_restores += 1;

            let undo = ctx.update_phase_logged(model, s, m, tag_left, ops, streams);
            run_subtree(ctx, model, m + 1, e, base, per_fold, ops, scratch, streams);
            ctx.learner.revert(model, ctx.data, undo);
            ops.model_restores += 1;
        }
    }
}

/// The TreeCV engine.
#[derive(Debug, Clone)]
pub struct TreeCv {
    /// Model-preservation strategy at interior nodes.
    pub strategy: Strategy,
    /// Fixed vs randomized feeding order (paper §5).
    pub ordering: Ordering,
    /// Seed for the randomized ordering streams (ignored under Fixed).
    pub seed: u64,
}

impl Default for TreeCv {
    fn default() -> Self {
        Self { strategy: Strategy::Copy, ordering: Ordering::Fixed, seed: 0 }
    }
}

impl TreeCv {
    pub fn new(strategy: Strategy, ordering: Ordering, seed: u64) -> Self {
        Self { strategy, ordering, seed }
    }

    fn run_ctx<L: IncrementalLearner>(&self, ctx: &NodeCtx<'_, L>) -> CvResult {
        let timer = Timer::start();
        let k = ctx.folds.k();
        let mut ops = OpCounts::default();
        let mut per_fold = vec![0.0; k];
        let mut model = ctx.learner.init();
        let mut scratch = Vec::new();
        let mut streams = StreamScratch::new();
        run_subtree(
            ctx,
            &mut model,
            0,
            k - 1,
            0,
            &mut per_fold,
            &mut ops,
            &mut scratch,
            &mut streams,
        );
        CvResult::from_folds(per_fold, ops, timer.elapsed())
    }

    /// Run the engine over the fold-contiguous layout: identical
    /// scheduling, identical results (estimate, per-fold scores in
    /// original fold numbering, all semantic counters) — but fixed-order
    /// node streams are contiguous slice feeds with zero index-vector
    /// allocations, and randomized streams recycle one scratch buffer.
    /// `data` must be the dataset `folded` was built from.
    pub fn run_folded<L: IncrementalLearner>(
        &self,
        learner: &L,
        data: &Dataset,
        folded: &FoldedDataset,
    ) -> CvResult {
        assert_eq!(folded.n(), data.n, "folded layout built for a different dataset (n)");
        assert_eq!(folded.d(), data.d, "folded layout built for a different dataset (d)");
        self.run_ctx(&NodeCtx {
            learner,
            data,
            folds: folded.folds(),
            folded: Some(folded),
            strategy: self.strategy,
            ordering: self.ordering,
            seed: self.seed,
        })
    }
}

impl super::CvEngine for TreeCv {
    fn engine_name(&self) -> &'static str {
        "treecv"
    }

    fn run<L: IncrementalLearner>(&self, learner: &L, data: &Dataset, folds: &Folds) -> CvResult {
        self.run_ctx(&NodeCtx {
            learner,
            data,
            folds,
            folded: None,
            strategy: self.strategy,
            ordering: self.ordering,
            seed: self.seed,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cv::CvEngine;
    use crate::learner::multiset::{MultisetLearner, MultisetModel};

    fn dummy(n: usize) -> Dataset {
        Dataset::new(vec![0.0; n], vec![0.0; n], 1)
    }

    /// Resolve a recorded leaf marker (first held-out point) to its fold id.
    fn fold_of_marker(folds: &Folds, marker: usize) -> usize {
        (0..folds.k())
            .find(|&i| folds.chunk(i)[0] as usize == marker)
            .expect("marker is the first element of some chunk")
    }

    /// A learner that records, at each leaf, the multiset of points its
    /// model was trained on — used to assert the defining invariant of
    /// Algorithm 1: leaf `i` sees exactly `Z \ Z_i`.
    #[test]
    fn leaf_models_trained_on_exactly_complement() {
        for (n, k) in [(16usize, 4usize), (17, 5), (20, 20), (9, 2), (7, 7), (24, 3)] {
            let data = dummy(n);
            let folds = Folds::new(n, k, 33);
            let learner = RecordingLearner::default();
            let engine = TreeCv::default();
            engine.run(&learner, &data, &folds);
            let leaves = learner.leaves.take();
            assert_eq!(leaves.len(), k, "n={n} k={k}");
            for (marker, seen) in leaves {
                let i = fold_of_marker(&folds, marker);
                let mut want = folds.gather_except(i);
                want.sort_unstable();
                assert_eq!(seen, want, "n={n} k={k} fold {i}");
            }
        }
    }

    /// Same invariant under SaveRevert.
    #[test]
    fn leaf_models_correct_under_save_revert() {
        let n = 19;
        let k = 6;
        let data = dummy(n);
        let folds = Folds::new(n, k, 34);
        let learner = RecordingLearner::default();
        let engine = TreeCv::new(Strategy::SaveRevert, Ordering::Fixed, 0);
        engine.run(&learner, &data, &folds);
        let leaves = learner.leaves.take();
        for (marker, seen) in leaves {
            let i = fold_of_marker(&folds, marker);
            let mut want = folds.gather_except(i);
            want.sort_unstable();
            assert_eq!(seen, want, "fold {i}");
        }
    }

    /// Randomized ordering must feed the same multiset (just reordered).
    #[test]
    fn randomized_ordering_preserves_multisets() {
        let n = 22;
        let k = 5;
        let data = dummy(n);
        let folds = Folds::new(n, k, 35);
        let learner = RecordingLearner::default();
        let engine = TreeCv::new(Strategy::Copy, Ordering::Randomized, 99);
        engine.run(&learner, &data, &folds);
        for (marker, seen) in learner.leaves.take() {
            let i = fold_of_marker(&folds, marker);
            let mut want = folds.gather_except(i);
            want.sort_unstable();
            assert_eq!(seen, want, "fold {i}");
        }
    }

    /// Copy and SaveRevert must produce identical estimates for a learner
    /// with exact revert.
    #[test]
    fn strategies_agree() {
        let n = 40;
        let data = dummy(n);
        let folds = Folds::new(n, 8, 36);
        let l = MultisetLearner::new(1);
        let a = TreeCv::new(Strategy::Copy, Ordering::Fixed, 0).run(&l, &data, &folds);
        let b = TreeCv::new(Strategy::SaveRevert, Ordering::Fixed, 0).run(&l, &data, &folds);
        assert_eq!(a.per_fold, b.per_fold);
    }

    /// Theorem 3 workload bound: points_updated ≤ n·log₂(2k) and each level
    /// of the tree feeds each chunk exactly once.
    #[test]
    fn update_work_is_n_log_k() {
        for k in [2usize, 3, 5, 8, 16, 33, 100] {
            let n = k * 7;
            let data = dummy(n);
            let folds = Folds::new(n, k, 37);
            let l = MultisetLearner::new(1);
            let res = TreeCv::default().run(&l, &data, &folds);
            let bound = (n as f64) * ((2 * k) as f64).log2();
            assert!(
                (res.ops.points_updated as f64) <= bound + 1e-9,
                "k={k}: {} > {bound}",
                res.ops.points_updated
            );
            // And it must do at least the single-training work (n-b points
            // reach every leaf's model).
            assert!(res.ops.points_updated as usize >= n - n / k);
        }
    }

    /// §4.1: sequential TreeCV stores O(log k) models — with Copy, the
    /// number of *live* snapshots equals the recursion depth; we check the
    /// total copies is k-1 (one per interior node), matching the 2k-1-node
    /// tree, and restores are 0; vice versa under SaveRevert.
    #[test]
    fn copy_and_restore_counts_match_tree_shape() {
        let n = 64;
        let k = 16;
        let data = dummy(n);
        let folds = Folds::new(n, k, 38);
        let l = MultisetLearner::new(1);
        let res = TreeCv::new(Strategy::Copy, Ordering::Fixed, 0).run(&l, &data, &folds);
        assert_eq!(res.ops.model_copies, (k - 1) as u64); // interior nodes
        assert_eq!(res.ops.model_restores, 0);
        assert_eq!(res.ops.evals, k as u64);

        let res = TreeCv::new(Strategy::SaveRevert, Ordering::Fixed, 0).run(&l, &data, &folds);
        assert_eq!(res.ops.model_copies, 0);
        assert_eq!(res.ops.model_restores, 2 * (k - 1) as u64); // 2 per interior node
    }

    /// The folded layout must reproduce the indexed path bit-for-bit even
    /// for an index-*sensitive* learner (the multiset oracle's loss hashes
    /// the training indices), because fallback calls keep feeding original
    /// indices — and fixed-order folded runs allocate zero index vectors.
    #[test]
    fn folded_run_matches_indexed_bitwise() {
        use crate::data::folded::FoldedDataset;
        let n = 43; // remainder folds
        let data = dummy(n);
        let folds = Folds::new(n, 8, 77);
        let folded = FoldedDataset::build(&data, &folds);
        let l = MultisetLearner::new(1);
        for strategy in [Strategy::Copy, Strategy::SaveRevert] {
            for ordering in [Ordering::Fixed, Ordering::Randomized] {
                let engine = TreeCv::new(strategy, ordering, 3);
                let a = engine.run(&l, &data, &folds);
                let b = engine.run_folded(&l, &data, &folded);
                assert_eq!(a.per_fold, b.per_fold, "{strategy:?} {ordering:?}");
                assert_eq!(a.ops.points_updated, b.ops.points_updated);
                assert_eq!(a.ops.points_permuted, b.ops.points_permuted);
                assert_eq!(a.ops.model_copies, b.ops.model_copies);
                match ordering {
                    Ordering::Fixed => assert_eq!(b.ops.stream_allocs, 0, "{strategy:?}"),
                    Ordering::Randomized => assert_eq!(b.ops.stream_allocs, 1, "one recycled buf"),
                }
            }
        }
    }

    #[test]
    fn loocv_runs() {
        let n = 33;
        let data = dummy(n);
        let folds = Folds::loocv(n);
        let l = MultisetLearner::new(1);
        let res = TreeCv::default().run(&l, &data, &folds);
        assert_eq!(res.per_fold.len(), n);
        assert!((res.estimate - res.per_fold.iter().sum::<f64>() / n as f64).abs() < 1e-15);
    }

    /// Learner whose update records indices and whose evaluate snapshots
    /// the training multiset per leaf.
    #[derive(Default)]
    struct RecordingLearner {
        leaves: std::cell::Cell<Vec<(usize, Vec<u32>)>>,
    }

    impl RecordingLearner {
        fn push_leaf(&self, fold: usize, seen: Vec<u32>) {
            let mut v = self.leaves.take();
            v.push((fold, seen));
            self.leaves.set(v);
        }
    }

    impl IncrementalLearner for RecordingLearner {
        type Model = MultisetModel;
        type Undo = usize;

        fn name(&self) -> &'static str {
            "recording"
        }
        fn dim(&self) -> usize {
            1
        }
        fn init(&self) -> MultisetModel {
            MultisetModel::default()
        }
        fn update(&self, m: &mut MultisetModel, _d: &Dataset, idx: &[u32]) {
            m.seen.extend_from_slice(idx);
        }
        fn update_logged(&self, m: &mut MultisetModel, _d: &Dataset, idx: &[u32]) -> usize {
            m.seen.extend_from_slice(idx);
            idx.len()
        }
        fn revert(&self, m: &mut MultisetModel, _d: &Dataset, undo: usize) {
            m.seen.truncate(m.seen.len() - undo);
        }
        fn loss(&self, _m: &MultisetModel, _d: &Dataset, _i: u32) -> f64 {
            0.0
        }
        fn evaluate(&self, m: &MultisetModel, _d: &Dataset, idx: &[u32]) -> f64 {
            // Record (marker, training multiset); the marker is the first
            // held-out point, which the test maps back to its fold id.
            self.push_leaf(idx[0] as usize, m.sorted());
            0.0
        }
        fn model_bytes(&self, m: &MultisetModel) -> usize {
            m.seen.len() * 4
        }
    }
}
