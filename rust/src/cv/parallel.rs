//! Parallel TreeCV (paper §4.1): "TREECV can be easily parallelized by
//! dedicating one thread of computation to each of the data groups used in
//! updating f̂_{s..e} in one call. In this case one typically needs to copy
//! the model since the two threads need to run independently; thus the
//! total number of models TreeCV needs to store is O(k)."
//!
//! [`ParallelTreeCv`] is the public parallel engine. It used to spawn a
//! fresh scoped OS thread at every tree fork down to `fork_depth`; it now
//! delegates to the pooled work-stealing executor
//! ([`super::executor::TreeCvExecutor`]) with a pool of `2^fork_depth`
//! workers, which schedules the same tree without thread churn,
//! oversubscription, or idle tails on unbalanced subtrees — and honors the
//! caller's model-preservation [`Strategy`] (SaveRevert runs copy only at
//! the executor's fork frontier, O(workers) snapshots per run). Because the
//! randomized-ordering streams are derived per-node (not drawn from one
//! sequential stream), the parallel engine produces *identical* estimates
//! to the sequential [`super::treecv::TreeCv`] for the same seed and
//! strategy (exactly-reverting learners; bit-identical always under Copy)
//! — tested below.
//!
//! [`ScopedForkTreeCv`] preserves the original recursive `thread::scope`
//! implementation as a measurement baseline so `benches/scaling_k.rs` can
//! quantify the executor's win; it is not wired into any dispatch path.
//! Its sequential tail shares `treecv::run_subtree` with the other
//! engines, so it too honors both strategies (forks above the tail must
//! snapshot regardless, exactly like the executor's fork frontier).

use super::executor::TreeCvExecutor;
use super::folds::{gather_ordered, node_tags, Folds, Ordering};
use super::treecv::{run_subtree, NodeCtx, StreamScratch};
use super::{CvResult, Strategy};
use crate::data::Dataset;
use crate::learner::IncrementalLearner;
use crate::metrics::{OpCounts, Timer};
use crate::sync::thread;

/// Largest fork depth whose subtree count does not oversubscribe
/// `threads`: the greatest `d` with `2^d <= threads` (0 for `threads <= 1`).
///
/// The previous implementation rounded *up* via `next_power_of_two`, so a
/// 6-core machine got depth 3 — eight concurrent subtrees on six cores.
pub fn fork_depth_for_threads(threads: usize) -> usize {
    if threads <= 1 {
        0
    } else {
        (usize::BITS - 1 - threads.leading_zeros()) as usize
    }
}

/// Threaded TreeCV engine facade. Runs on the pooled work-stealing
/// executor with `2^fork_depth` workers (or an exact `threads` override —
/// the executor schedules any count), under the caller's strategy.
#[derive(Debug, Clone)]
pub struct ParallelTreeCv {
    /// Model-preservation strategy, forwarded to the executor.
    pub strategy: Strategy,
    pub ordering: Ordering,
    pub seed: u64,
    /// Fork depth: up to `2^fork_depth` concurrent subtrees.
    pub fork_depth: usize,
    /// Exact worker-pool size, overriding the `2^fork_depth` derivation.
    /// Set by [`Self::with_available_parallelism`] so non-power-of-two
    /// machines use every core instead of rounding down.
    pub threads: Option<usize>,
}

impl ParallelTreeCv {
    pub fn new(strategy: Strategy, ordering: Ordering, seed: u64, fork_depth: usize) -> Self {
        Self { strategy, ordering, seed, fork_depth, threads: None }
    }

    /// Pool sized to the machine's full parallelism. `fork_depth` is set
    /// to the largest depth with `2^depth <= threads` (the historical
    /// clamp), but the run uses the exact thread count — a 6-core machine
    /// gets 6 workers, not 4.
    pub fn with_available_parallelism(strategy: Strategy, ordering: Ordering, seed: u64) -> Self {
        let threads = thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
        Self {
            strategy,
            ordering,
            seed,
            fork_depth: fork_depth_for_threads(threads),
            threads: Some(threads),
        }
    }

    /// Run the parallel engine. (Not part of the [`super::CvEngine`] trait
    /// because it needs `L: Sync` bounds the trait doesn't impose.)
    pub fn run<L>(&self, learner: &L, data: &Dataset, folds: &Folds) -> CvResult
    where
        L: IncrementalLearner + Sync,
        L::Model: Send,
    {
        // Exact override, else 2^fork_depth workers; a single worker runs
        // inline on the calling thread.
        let threads = self
            .threads
            .unwrap_or_else(|| 1usize << self.fork_depth.min(usize::BITS as usize - 1));
        TreeCvExecutor::new(self.strategy, self.ordering, self.seed, threads)
            .run(learner, data, folds)
    }
}

/// The original §4.1 implementation: recursively fork a scoped OS thread at
/// every tree node down to `fork_depth` — cloning the model at each fork,
/// which concurrency requires regardless of strategy — with a sequential
/// tail below that depth that runs the shared recursion under the engine's
/// [`Strategy`].
///
/// Retained **only** as the baseline for executor benchmarks and the
/// equivalence tests; production dispatch goes through [`ParallelTreeCv`]
/// (i.e. the executor).
#[derive(Debug, Clone)]
pub struct ScopedForkTreeCv {
    /// Model-preservation strategy for the sequential tails.
    pub strategy: Strategy,
    pub ordering: Ordering,
    pub seed: u64,
    /// Fork depth: up to `2^fork_depth` concurrent subtrees.
    pub fork_depth: usize,
}

impl ScopedForkTreeCv {
    pub fn new(strategy: Strategy, ordering: Ordering, seed: u64, fork_depth: usize) -> Self {
        Self { strategy, ordering, seed, fork_depth }
    }

    /// Depth fitting the machine's parallelism (same clamp as
    /// [`ParallelTreeCv::with_available_parallelism`]).
    pub fn with_available_parallelism(strategy: Strategy, ordering: Ordering, seed: u64) -> Self {
        let threads = thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
        Self::new(strategy, ordering, seed, fork_depth_for_threads(threads))
    }

    fn gather(
        &self,
        folds: &Folds,
        lo: usize,
        hi: usize,
        tag: u64,
        ops: &mut OpCounts,
    ) -> Vec<u32> {
        gather_ordered(folds, lo, hi, self.seed, self.ordering, tag, ops)
    }

    #[allow(clippy::too_many_arguments)]
    fn recurse<L>(
        &self,
        learner: &L,
        data: &Dataset,
        folds: &Folds,
        mut model: L::Model,
        s: usize,
        e: usize,
        depth: usize,
        per_fold: &mut [f64],
    ) -> OpCounts
    where
        L: IncrementalLearner + Sync,
        L::Model: Send,
    {
        let mut ops = OpCounts::default();
        if s == e || depth >= self.fork_depth {
            // Sequential tail (also handles leaves): the shared recursion
            // under the engine's strategy, writing `per_fold[i - s]`.
            let mut scratch = Vec::new();
            let mut streams = StreamScratch::new();
            let ctx = NodeCtx {
                learner,
                data,
                folds,
                folded: None,
                strategy: self.strategy,
                ordering: self.ordering,
                seed: self.seed,
            };
            run_subtree(&ctx, &mut model, s, e, s, per_fold, &mut ops, &mut scratch, &mut streams);
            return ops;
        }
        let m = (s + e) / 2;
        let (tag_right, tag_left) = node_tags(s, e);

        let right = self.gather(folds, m + 1, e, tag_right, &mut ops);
        let left = self.gather(folds, s, m, tag_left, &mut ops);
        ops.update_calls += 2;
        ops.points_updated += (right.len() + left.len()) as u64;

        // Split the per-fold output at the midpoint so the halves can be
        // written concurrently without locks.
        let (pf_left, pf_right) = per_fold.split_at_mut(m - s + 1);

        let mut model_right = model.clone();
        ops.model_copies += 1;
        ops.bytes_copied += learner.model_bytes(&model) as u64;
        let (ops_a, ops_b) = thread::scope(|scope| {
            let handle = scope.spawn(move || {
                // Right side of the split: model updated with the LEFT
                // chunk group, recursing on (m+1, e).
                learner.update(&mut model_right, data, &left);
                self.recurse(learner, data, folds, model_right, m + 1, e, depth + 1, pf_right)
            });
            learner.update(&mut model, data, &right);
            let ops_a = self.recurse(learner, data, folds, model, s, m, depth + 1, pf_left);
            // invariant: the worker closure contains no panicking
            // operations of its own; a panic here is a learner bug and
            // must propagate.
            (ops_a, handle.join().expect("treecv worker panicked"))
        });
        ops.merge(&ops_a);
        ops.merge(&ops_b);
        ops
    }

    /// Run the scoped-fork baseline.
    pub fn run<L>(&self, learner: &L, data: &Dataset, folds: &Folds) -> CvResult
    where
        L: IncrementalLearner + Sync,
        L::Model: Send,
    {
        let timer = Timer::start();
        let k = folds.k();
        let mut per_fold = vec![0.0; k];
        let model = learner.init();
        let ops = self.recurse(learner, data, folds, model, 0, k - 1, 0, &mut per_fold);
        CvResult::from_folds(per_fold, ops, timer.elapsed())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cv::treecv::TreeCv;
    use crate::cv::{CvEngine, Strategy};
    use crate::data::synth::{SyntheticCovertype, SyntheticMixture1d};
    use crate::learner::histdensity::HistogramDensity;
    use crate::learner::pegasos::Pegasos;

    #[test]
    fn matches_sequential_fixed_order() {
        let data = SyntheticCovertype::new(2_000, 91).generate();
        let l = Pegasos::new(54, 1e-4);
        let folds = Folds::new(2_000, 16, 92);
        let seq = TreeCv::new(Strategy::Copy, Ordering::Fixed, 5).run(&l, &data, &folds);
        let par =
            ParallelTreeCv::new(Strategy::Copy, Ordering::Fixed, 5, 3).run(&l, &data, &folds);
        assert_eq!(seq.per_fold, par.per_fold);
    }

    #[test]
    fn matches_sequential_randomized_order() {
        // Per-node RNG derivation makes randomized ordering identical too.
        let data = SyntheticCovertype::new(1_000, 93).generate();
        let l = Pegasos::new(54, 1e-4);
        let folds = Folds::new(1_000, 8, 94);
        let seq = TreeCv::new(Strategy::Copy, Ordering::Randomized, 7).run(&l, &data, &folds);
        let par =
            ParallelTreeCv::new(Strategy::Copy, Ordering::Randomized, 7, 2).run(&l, &data, &folds);
        assert_eq!(seq.per_fold, par.per_fold);
    }

    #[test]
    fn fork_depth_zero_is_sequential() {
        let data = SyntheticMixture1d::new(300, 95).generate();
        let l = HistogramDensity::new(-8.0, 8.0, 32);
        let folds = Folds::new(300, 10, 96);
        let par =
            ParallelTreeCv::new(Strategy::Copy, Ordering::Fixed, 0, 0).run(&l, &data, &folds);
        let seq = TreeCv::default().run(&l, &data, &folds);
        assert_eq!(par.per_fold, seq.per_fold);
    }

    #[test]
    fn save_revert_honored_by_facade_and_baseline() {
        // Exact-revert learner: both parallel engines must reproduce the
        // sequential SaveRevert engine bit-for-bit — and actually run
        // save/revert (restores > 0, copies strictly below k − 1).
        let data = SyntheticMixture1d::new(520, 85).generate();
        let l = HistogramDensity::new(-8.0, 8.0, 32);
        let folds = Folds::new(520, 20, 84);
        let seq = TreeCv::new(Strategy::SaveRevert, Ordering::Fixed, 2).run(&l, &data, &folds);
        let par = ParallelTreeCv::new(Strategy::SaveRevert, Ordering::Fixed, 2, 2)
            .run(&l, &data, &folds);
        let sco = ScopedForkTreeCv::new(Strategy::SaveRevert, Ordering::Fixed, 2, 2)
            .run(&l, &data, &folds);
        assert_eq!(seq.per_fold, par.per_fold);
        assert_eq!(seq.per_fold, sco.per_fold);
        for res in [&par, &sco] {
            assert!(res.ops.model_restores > 0);
            assert!(res.ops.model_copies < 19, "copies {}", res.ops.model_copies);
        }
        // The scoped baseline forks 2^2 − 1 = 3 interior nodes (one copy
        // each); the remaining 16 interior nodes save/revert (2 each).
        assert_eq!(sco.ops.model_copies, 3);
        assert_eq!(sco.ops.model_restores, 2 * 16);
    }

    #[test]
    fn total_work_unchanged_by_parallelism() {
        let data = SyntheticMixture1d::new(512, 97).generate();
        let l = HistogramDensity::new(-8.0, 8.0, 32);
        let folds = Folds::new(512, 32, 98);
        let seq = TreeCv::default().run(&l, &data, &folds);
        let par =
            ParallelTreeCv::new(Strategy::Copy, Ordering::Fixed, 0, 4).run(&l, &data, &folds);
        assert_eq!(seq.ops.points_updated, par.ops.points_updated);
        assert_eq!(seq.ops.evals, par.ops.evals);
        // Copies: the paper notes parallel CV stores O(k) models; every
        // interior node still copies exactly once under Copy.
        assert_eq!(par.ops.model_copies, 31);
    }

    #[test]
    fn scoped_fork_baseline_matches_executor_dispatch() {
        let data = SyntheticCovertype::new(1_100, 89).generate();
        let l = Pegasos::new(54, 1e-3);
        let folds = Folds::new(1_100, 11, 90);
        let scoped =
            ScopedForkTreeCv::new(Strategy::Copy, Ordering::Fixed, 4, 2).run(&l, &data, &folds);
        let pooled =
            ParallelTreeCv::new(Strategy::Copy, Ordering::Fixed, 4, 2).run(&l, &data, &folds);
        assert_eq!(scoped.per_fold, pooled.per_fold);
        assert_eq!(scoped.ops.points_updated, pooled.ops.points_updated);
        assert_eq!(scoped.ops.evals, pooled.ops.evals);
    }

    #[test]
    fn fork_depth_never_oversubscribes() {
        // Regression test for the next_power_of_two rounding bug: on a
        // 6-thread machine the old code picked depth 3 (8 subtrees).
        for threads in 1usize..=16 {
            let depth = fork_depth_for_threads(threads);
            assert!(
                (1usize << depth) <= threads.max(1),
                "threads={threads}: 2^{depth} subtrees oversubscribe"
            );
            assert!(
                (1usize << (depth + 1)) > threads,
                "threads={threads}: depth {depth} is not the largest fit"
            );
        }
        assert_eq!(fork_depth_for_threads(0), 0);
        assert_eq!(fork_depth_for_threads(1), 0);
        assert_eq!(fork_depth_for_threads(6), 2);
        assert_eq!(fork_depth_for_threads(8), 3);
        assert_eq!(fork_depth_for_threads(9), 3);
    }
}
