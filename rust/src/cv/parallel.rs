//! Parallel TreeCV (paper §4.1): "TREECV can be easily parallelized by
//! dedicating one thread of computation to each of the data groups used in
//! updating f̂_{s..e} in one call. In this case one typically needs to copy
//! the model since the two threads need to run independently; thus the
//! total number of models TreeCV needs to store is O(k)."
//!
//! This engine forks at tree nodes down to a configurable depth (2^depth
//! concurrent subtrees), cloning the model at each fork, and falls back to
//! the sequential Copy-strategy recursion below that depth. Because the
//! randomized-ordering streams are derived per-node (not drawn from one
//! sequential stream), the parallel engine produces *identical* estimates
//! to the sequential [`super::treecv::TreeCv`] for the same seed — tested
//! below.

use super::folds::{Folds, Ordering};
use super::CvResult;
use crate::data::Dataset;
use crate::learner::IncrementalLearner;
use crate::metrics::{OpCounts, Timer};
use crate::rng::Rng;

/// Threaded TreeCV engine (always uses the Copy strategy at forks).
#[derive(Debug, Clone)]
pub struct ParallelTreeCv {
    pub ordering: Ordering,
    pub seed: u64,
    /// Fork depth: up to `2^fork_depth` concurrent subtrees.
    pub fork_depth: usize,
}

impl ParallelTreeCv {
    pub fn new(ordering: Ordering, seed: u64, fork_depth: usize) -> Self {
        Self { ordering, seed, fork_depth }
    }

    /// Default fork depth covering the machine's parallelism.
    pub fn with_available_parallelism(ordering: Ordering, seed: u64) -> Self {
        let threads = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
        // Smallest depth with 2^depth >= threads.
        let depth = (usize::BITS - threads.next_power_of_two().leading_zeros() - 1) as usize;
        Self::new(ordering, seed, depth)
    }

    fn gather(&self, folds: &Folds, lo: usize, hi: usize, tag: u64, ops: &mut OpCounts) -> Vec<u32> {
        let mut idx = folds.gather_range(lo, hi);
        let mut rng = Rng::derive(self.seed, tag);
        self.ordering.apply(&mut idx, &mut rng, ops);
        idx
    }

    #[allow(clippy::too_many_arguments)]
    fn recurse<L>(
        &self,
        learner: &L,
        data: &Dataset,
        folds: &Folds,
        mut model: L::Model,
        s: usize,
        e: usize,
        depth: usize,
        per_fold: &mut [f64],
    ) -> OpCounts
    where
        L: IncrementalLearner + Sync,
        L::Model: Send,
    {
        let mut ops = OpCounts::default();
        if s == e {
            let chunk = folds.chunk(s);
            per_fold[0] = learner.evaluate(&model, data, chunk);
            ops.evals += 1;
            ops.points_evaluated += chunk.len() as u64;
            return ops;
        }
        let m = (s + e) / 2;
        let tag_right = ((s as u64) << 33) | ((e as u64) << 1);
        let tag_left = tag_right | 1;

        let right = self.gather(folds, m + 1, e, tag_right, &mut ops);
        let left = self.gather(folds, s, m, tag_left, &mut ops);
        ops.update_calls += 2;
        ops.points_updated += (right.len() + left.len()) as u64;

        // Split the per-fold output at the midpoint so the halves can be
        // written concurrently without locks.
        let (pf_left, pf_right) = per_fold.split_at_mut(m - s + 1);

        if depth < self.fork_depth {
            let mut model_right = model.clone();
            ops.model_copies += 1;
            ops.bytes_copied += learner.model_bytes(&model) as u64;
            let (ops_a, ops_b) = std::thread::scope(|scope| {
                let handle = scope.spawn(move || {
                    // Right side of the split: model updated with the LEFT
                    // chunk group, recursing on (m+1, e).
                    learner.update(&mut model_right, data, &left);
                    self.recurse(learner, data, folds, model_right, m + 1, e, depth + 1, pf_right)
                });
                learner.update(&mut model, data, &right);
                let ops_a =
                    self.recurse(learner, data, folds, model, s, m, depth + 1, pf_left);
                (ops_a, handle.join().expect("treecv worker panicked"))
            });
            ops.merge(&ops_a);
            ops.merge(&ops_b);
        } else {
            // Sequential tail: same order as the sequential engine.
            let saved = model.clone();
            ops.model_copies += 1;
            ops.bytes_copied += learner.model_bytes(&saved) as u64;
            learner.update(&mut model, data, &right);
            let ops_a = self.recurse(learner, data, folds, model, s, m, depth + 1, pf_left);
            let mut model = saved;
            learner.update(&mut model, data, &left);
            let ops_b = self.recurse(learner, data, folds, model, m + 1, e, depth + 1, pf_right);
            ops.merge(&ops_a);
            ops.merge(&ops_b);
        }
        ops
    }
}

impl ParallelTreeCv {
    /// Run the parallel engine. (Not part of the [`super::CvEngine`] trait
    /// because it needs `L: Sync` bounds the trait doesn't impose.)
    pub fn run<L>(&self, learner: &L, data: &Dataset, folds: &Folds) -> CvResult
    where
        L: IncrementalLearner + Sync,
        L::Model: Send,
    {
        let timer = Timer::start();
        let k = folds.k();
        let mut per_fold = vec![0.0; k];
        let model = learner.init();
        let ops = self.recurse(learner, data, folds, model, 0, k - 1, 0, &mut per_fold);
        CvResult::from_folds(per_fold, ops, timer.elapsed())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cv::treecv::TreeCv;
    use crate::cv::{CvEngine, Strategy};
    use crate::data::synth::{SyntheticCovertype, SyntheticMixture1d};
    use crate::learner::histdensity::HistogramDensity;
    use crate::learner::pegasos::Pegasos;

    #[test]
    fn matches_sequential_fixed_order() {
        let data = SyntheticCovertype::new(2_000, 91).generate();
        let l = Pegasos::new(54, 1e-4);
        let folds = Folds::new(2_000, 16, 92);
        let seq = TreeCv::new(Strategy::Copy, Ordering::Fixed, 5).run(&l, &data, &folds);
        let par = ParallelTreeCv::new(Ordering::Fixed, 5, 3).run(&l, &data, &folds);
        assert_eq!(seq.per_fold, par.per_fold);
    }

    #[test]
    fn matches_sequential_randomized_order() {
        // Per-node RNG derivation makes randomized ordering identical too.
        let data = SyntheticCovertype::new(1_000, 93).generate();
        let l = Pegasos::new(54, 1e-4);
        let folds = Folds::new(1_000, 8, 94);
        let seq = TreeCv::new(Strategy::Copy, Ordering::Randomized, 7).run(&l, &data, &folds);
        let par = ParallelTreeCv::new(Ordering::Randomized, 7, 2).run(&l, &data, &folds);
        assert_eq!(seq.per_fold, par.per_fold);
    }

    #[test]
    fn fork_depth_zero_is_sequential() {
        let data = SyntheticMixture1d::new(300, 95).generate();
        let l = HistogramDensity::new(-8.0, 8.0, 32);
        let folds = Folds::new(300, 10, 96);
        let par = ParallelTreeCv::new(Ordering::Fixed, 0, 0).run(&l, &data, &folds);
        let seq = TreeCv::default().run(&l, &data, &folds);
        assert_eq!(par.per_fold, seq.per_fold);
    }

    #[test]
    fn total_work_unchanged_by_parallelism() {
        let data = SyntheticMixture1d::new(512, 97).generate();
        let l = HistogramDensity::new(-8.0, 8.0, 32);
        let folds = Folds::new(512, 32, 98);
        let seq = TreeCv::default().run(&l, &data, &folds);
        let par = ParallelTreeCv::new(Ordering::Fixed, 0, 4).run(&l, &data, &folds);
        assert_eq!(seq.ops.points_updated, par.ops.points_updated);
        assert_eq!(seq.ops.evals, par.ops.evals);
        // Copies: the paper notes parallel CV stores O(k) models; every
        // interior node still copies exactly once here.
        assert_eq!(par.ops.model_copies, 31);
    }
}
