//! Cross-validation engines.
//!
//! * [`approx`] — approximate CV for the k = n regime: train once on the
//!   full dataset, then derive each fold's held-out estimate by a
//!   one-step correction ([`crate::learner::ConvexCorrectable`] — exact
//!   Sherman–Morrison downdates for ridge, a single re-weighted gradient
//!   step for pegasos/lsqsgd). n row updates + k corrections instead of
//!   TreeCV's Θ(n log₂(2k)); per-fold results bitwise independent of the
//!   worker count. Opt-in per learner; non-convex tasks are a hard error.
//! * [`treecv`] — the paper's contribution (Algorithm 1): recursive
//!   tree-structured CV in `O(log k)`-times single-training time. Its
//!   recursion (`run_subtree`) is *the* sequential implementation, shared
//!   with both parallel engines for their inline subtrees/tails.
//! * [`standard`] — the naive k-repetition baseline the paper compares
//!   against (train k models from scratch).
//! * [`executor`] — the pooled work-stealing executor that forks TreeCV
//!   subtrees above a snapshot cutoff (~⌈log₂ workers⌉ levels) and runs
//!   everything below inline under the caller's [`Strategy`] — SaveRevert
//!   therefore pays O(workers) model copies per run instead of k − 1.
//!   Every parallel dispatch path routes through it; its `run_many`
//!   schedules whole batches of runs (each task tagged with its run id)
//!   through one pool, and `run_many_erased` extends that to
//!   **heterogeneous** batches over the type-erased learner layer
//!   ([`crate::learner::erased`]) — runs of different learner families in
//!   one pool, bit-identical to their generic counterparts. Pool-spawn
//!   accounting is per executor (`TreeCvExecutor::pool_spawns`), not
//!   process-wide.
//! * [`sweep`] — the tuning workload: every (learner config × strategy ×
//!   repetition) TreeCV run of a grid sweep as ONE executor batch — no
//!   per-run pool spawn, shared snapshot-buffer pools, fold assignments
//!   common across configs so the config is the only difference between
//!   rows. `run_sweep` takes one learner family's grid (`repro sweep
//!   --sweep lambda=0.1,0.01`); `run_sweep_erased` takes a heterogeneous
//!   learner axis — the model-selection workload behind `repro select`.
//! * [`race`] — racing sweeps (`repro sweep --race`): the same batch as
//!   [`sweep`] dispatched through the executor's cancellation layer, with
//!   a Krueger-style sequential sign test eliminating losing configs at
//!   round boundaries and cancelling their outstanding runs mid-flight.
//!   Deterministic given the seed; `alpha = 0` reproduces the exhaustive
//!   sweep bit for bit.
//! * [`refresh`] — incremental re-estimation for streams: after
//!   [`crate::data::folded::FoldedDataset::append_rows`] lands a batch,
//!   `TreeCvExecutor::refresh` recomputes only the O(log k) subtrees per
//!   touched fold that the new rows dirtied, reusing cached interior
//!   models ([`refresh::RefreshSession`]) — bit-identical to a
//!   from-scratch folded run, pinned by `OpCounts::subtrees_recomputed`.
//!   The engine behind `repro serve`.
//! * [`parallel`] — the §4.1 parallel engine facade (delegates to
//!   [`executor`]) plus the original scoped-thread forking retained as a
//!   bench baseline; both are strategy-aware.
//! * [`mergecv`] — the Izbicki [2013] O(n + k) baseline for *mergeable*
//!   learners (related-work comparator).
//! * [`exact`] — closed-form ridge LOOCV (hat-matrix), the external
//!   correctness comparator from the classical fast-CV literature.
//! * [`folds`] — fold assignment and the fixed/randomized data-ordering
//!   policies of the paper's §5. The *physical* counterpart is the
//!   fold-contiguous layout ([`crate::data::folded::FoldedDataset`]):
//!   every engine accepts one via its `run_folded` entry (or
//!   [`executor::RunSpec::folded`]) and then feeds node streams as
//!   contiguous row slices through the learners' `update_rows` /
//!   `evaluate_rows` fast paths — bit-identical results, zero per-node
//!   index-vector allocations under fixed ordering.
//! * [`stats`] — the repetition harness producing Table-2-style
//!   `mean ± std` rows.

pub mod approx;
pub mod exact;
pub mod executor;
pub mod folds;
pub mod mergecv;
pub mod parallel;
pub mod race;
pub mod refresh;
pub mod repeated;
pub mod standard;
pub mod stats;
pub mod sweep;
pub mod treecv;

use crate::data::Dataset;
use crate::learner::IncrementalLearner;
use crate::metrics::OpCounts;
use folds::Folds;
use std::time::Duration;

/// Result of one CV computation.
#[derive(Debug, Clone)]
pub struct CvResult {
    /// The k-CV estimate `R_{k-CV} = (1/k) Σ R_i`.
    pub estimate: f64,
    /// Per-fold scores `R_i`.
    pub per_fold: Vec<f64>,
    /// Work counters (for the Theorem-3 complexity validation).
    pub ops: OpCounts,
    /// Wall-clock time of the computation.
    pub wall: Duration,
}

impl CvResult {
    /// Build a result from per-fold scores.
    ///
    /// Panics on an empty fold vector: a CV computation that evaluated
    /// zero folds is a caller bug (k ≥ 1 is enforced by
    /// [`folds::Folds::new`]), and returning `estimate = 0.0` would
    /// silently masquerade as a perfect score.
    pub(crate) fn from_folds(per_fold: Vec<f64>, ops: OpCounts, wall: Duration) -> Self {
        assert!(
            !per_fold.is_empty(),
            "CvResult::from_folds: empty per-fold vector — no folds were \
             evaluated; every engine requires k >= 1"
        );
        let estimate = per_fold.iter().sum::<f64>() / per_fold.len() as f64;
        Self { estimate, per_fold, ops, wall }
    }
}

/// Common interface over the CV engines, so benches/examples can swap them.
pub trait CvEngine {
    /// Engine name for reports.
    fn engine_name(&self) -> &'static str;

    /// Compute the k-CV estimate of `learner` on `data` under `folds`.
    fn run<L: IncrementalLearner>(&self, learner: &L, data: &Dataset, folds: &Folds) -> CvResult;
}

/// How interior TreeCV nodes preserve the incoming model while updating it
/// twice (paper §4.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Strategy {
    /// Clone the model before the first child's update ("if the model state
    /// is compact, copying is a useful strategy").
    Copy,
    /// Record the changes made by each update and revert them ("when the
    /// model undergoes few changes during an update, save/revert might be
    /// preferred").
    SaveRevert,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    #[should_panic(expected = "empty per-fold vector")]
    fn from_folds_rejects_empty() {
        let _ = CvResult::from_folds(Vec::new(), OpCounts::default(), Duration::ZERO);
    }

    #[test]
    fn from_folds_estimate_is_mean() {
        let r = CvResult::from_folds(vec![1.0, 3.0], OpCounts::default(), Duration::ZERO);
        assert_eq!(r.estimate, 2.0);
        assert_eq!(r.per_fold, vec![1.0, 3.0]);
    }
}
