//! Closed-form ridge LOOCV — the external correctness comparator from the
//! classical fast-CV literature the paper reviews (§1.1: Golub, Heath &
//! Wahba 1979; Pahikkala et al. 2006; Cawley 2006).
//!
//! For ridge regression `w = (XᵀX + λI)⁻¹ Xᵀ y` fitted on the full dataset,
//! the leave-one-out residual has the classic closed form
//! `y_i − x_iᵀ w_{−i} = e_i / (1 − h_ii)` with leverage
//! `h_ii = x_iᵀ (XᵀX + λI)⁻¹ x_i` and full-data residual `e_i = y_i − x_iᵀw`.
//! So `LOOCV = (1/n) Σ (e_i / (1 − h_ii))²` in O(n·d² + d³) — no n-fold
//! retraining.
//!
//! Because [`crate::learner::ridge::OnlineRidge`] is batching-insensitive,
//! TreeCV's LOOCV with that learner must equal this closed form (paper
//! Theorem 1 with g ≡ 0, modulo f64 rounding) — an end-to-end validation
//! of the whole TreeCV pipeline against independent mathematics.

use crate::data::Dataset;
use crate::learner::linalg;

/// Result of the closed-form computation.
#[derive(Debug, Clone)]
pub struct ExactLoocv {
    /// The LOOCV mean squared error.
    pub estimate: f64,
    /// Per-point leave-one-out squared residuals.
    pub per_point: Vec<f64>,
    /// Leverages `h_ii` (diagnostics; all in (0, 1) for λ > 0).
    pub leverage: Vec<f64>,
}

/// Compute exact ridge LOOCV on the full dataset.
pub fn ridge_loocv(data: &Dataset, lambda: f64) -> ExactLoocv {
    let (n, d) = (data.n, data.d);
    assert!(n > 0 && lambda > 0.0);

    // A = XᵀX + λI, b = Xᵀy in f64.
    let mut a = vec![0f64; d * d];
    let mut b = vec![0f64; d];
    for i in 0..n {
        let x = data.row(i as u32);
        let y = data.label(i as u32) as f64;
        for p in 0..d {
            let xp = x[p] as f64;
            b[p] += xp * y;
            for q in 0..d {
                a[p * d + q] += xp * (x[q] as f64);
            }
        }
    }
    for j in 0..d {
        a[j * d + j] += lambda;
    }

    // invariant: XᵀX is PSD, so XᵀX + λI is SPD for the asserted λ > 0.
    let l = linalg::cholesky(&a, d).expect("XᵀX + λI is SPD");
    let w = linalg::cholesky_solve(&l, d, &b);
    let a_inv = linalg::cholesky_inverse(&l, d);

    let mut per_point = Vec::with_capacity(n);
    let mut leverage = Vec::with_capacity(n);
    for i in 0..n {
        let x = data.row(i as u32);
        let y = data.label(i as u32) as f64;
        // h_ii = xᵀ A⁻¹ x.
        let mut h = 0f64;
        for p in 0..d {
            let mut s = 0f64;
            for q in 0..d {
                s += a_inv[p * d + q] * (x[q] as f64);
            }
            h += (x[p] as f64) * s;
        }
        let pred: f64 = (0..d).map(|j| w[j] * x[j] as f64).sum();
        let e = y - pred;
        let loo = e / (1.0 - h);
        per_point.push(loo * loo);
        leverage.push(h);
    }
    let estimate = per_point.iter().sum::<f64>() / n as f64;
    ExactLoocv { estimate, per_point, leverage }
}

/// Generalized cross-validation (Golub, Heath & Wahba 1979; paper §1.1):
/// the rotation-invariant LOOCV approximation
/// `V(λ) = n·‖(I − A(λ))y‖² / tr(I − A(λ))²`
/// with influence matrix `A(λ) = X(XᵀX + λI)⁻¹Xᵀ`. GCV replaces each
/// leverage `h_ii` by the average `tr(A)/n` — so it equals exact LOOCV
/// when leverages are homogeneous and deviates otherwise. Provided as a
/// second classical comparator (and a λ-selection criterion).
pub fn ridge_gcv(data: &Dataset, lambda: f64) -> f64 {
    let (n, d) = (data.n, data.d);
    assert!(n > 0 && lambda > 0.0);
    let mut a = vec![0f64; d * d];
    let mut b = vec![0f64; d];
    for i in 0..n {
        let x = data.row(i as u32);
        let y = data.label(i as u32) as f64;
        for p in 0..d {
            let xp = x[p] as f64;
            b[p] += xp * y;
            for q in 0..d {
                a[p * d + q] += xp * (x[q] as f64);
            }
        }
    }
    let gram = a.clone(); // XᵀX before regularization (for the trace)
    for j in 0..d {
        a[j * d + j] += lambda;
    }
    // invariant: XᵀX is PSD, so XᵀX + λI is SPD for the asserted λ > 0.
    let l = linalg::cholesky(&a, d).expect("SPD");
    let w = linalg::cholesky_solve(&l, d, &b);
    let a_inv = linalg::cholesky_inverse(&l, d);
    // tr(A(λ)) = tr((XᵀX + λI)⁻¹ XᵀX).
    let mut trace = 0f64;
    for p in 0..d {
        for q in 0..d {
            trace += a_inv[p * d + q] * gram[q * d + p];
        }
    }
    let mut rss = 0f64;
    for i in 0..n {
        let x = data.row(i as u32);
        let pred: f64 = (0..d).map(|j| w[j] * x[j] as f64).sum();
        let e = data.label(i as u32) as f64 - pred;
        rss += e * e;
    }
    let denom = (1.0 - trace / n as f64).powi(2);
    rss / (n as f64 * denom)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cv::folds::Folds;
    use crate::cv::standard::StandardCv;
    use crate::cv::treecv::TreeCv;
    use crate::cv::CvEngine;
    use crate::data::synth::SyntheticYearMsd;
    use crate::learner::ridge::OnlineRidge;
    use crate::learner::IncrementalLearner;

    fn small_data(n: usize, seed: u64) -> Dataset {
        // Small d keeps the O(n·d²) brute-force comparison cheap.
        let full = SyntheticYearMsd::new(n, seed).generate();
        let d = 8;
        let mut x = Vec::with_capacity(n * d);
        for i in 0..n {
            x.extend_from_slice(&full.row(i as u32)[..d]);
        }
        Dataset::new(x, full.y.clone(), d)
    }

    /// Closed form vs brute force: retrain without point i, per i.
    #[test]
    fn closed_form_matches_brute_force() {
        let data = small_data(60, 111);
        let lambda = 0.5;
        let exact = ridge_loocv(&data, lambda);
        let l = OnlineRidge::new(8, lambda);
        for i in 0..data.n {
            let idx: Vec<u32> = (0..data.n as u32).filter(|&j| j != i as u32).collect();
            let mut m = l.init();
            l.update(&mut m, &data, &idx);
            let loss = l.loss(&m, &data, i as u32);
            assert!(
                (loss - exact.per_point[i]).abs() < 1e-6 * (1.0 + loss),
                "i={i}: brute {loss} vs closed {}",
                exact.per_point[i]
            );
        }
    }

    /// Leverages lie in (0, 1) and sum to the effective dof ≤ d.
    #[test]
    fn leverages_are_sane() {
        let data = small_data(100, 112);
        let exact = ridge_loocv(&data, 1.0);
        let trace: f64 = exact.leverage.iter().sum();
        assert!(exact.leverage.iter().all(|&h| h > 0.0 && h < 1.0));
        assert!(trace <= 8.0 + 1e-9, "trace {trace}");
    }

    /// GCV approximates exact LOOCV (equality requires homogeneous
    /// leverages; on i.i.d. Gaussian features they are near-homogeneous).
    #[test]
    fn gcv_close_to_exact_loocv() {
        let data = small_data(200, 114);
        for lambda in [0.1, 1.0, 10.0] {
            let exact = ridge_loocv(&data, lambda).estimate;
            let gcv = ridge_gcv(&data, lambda);
            assert!(
                (gcv - exact).abs() < 0.05 * (1.0 + exact),
                "λ={lambda}: gcv {gcv} vs exact {exact}"
            );
        }
    }

    /// GCV is a valid λ-selection criterion: it prefers moderate λ over a
    /// degenerate one on noisy data.
    #[test]
    fn gcv_penalizes_undersmoothing() {
        let data = small_data(120, 115);
        let tiny = ridge_gcv(&data, 1e-9);
        let moderate = ridge_gcv(&data, 1.0);
        assert!(moderate <= tiny * 1.05, "moderate {moderate} vs tiny-λ {tiny}");
    }

    /// The headline validation: TreeCV LOOCV with the incremental ridge
    /// learner reproduces the closed form (Theorem 1 with g ≡ 0).
    #[test]
    fn treecv_loocv_equals_closed_form() {
        let data = small_data(80, 113);
        let lambda = 0.7;
        let exact = ridge_loocv(&data, lambda);
        let l = OnlineRidge::new(8, lambda);
        let folds = Folds::loocv(data.n);
        let tree = TreeCv::default().run(&l, &data, &folds);
        assert!(
            (tree.estimate - exact.estimate).abs() < 1e-7 * (1.0 + exact.estimate),
            "treecv {} vs exact {}",
            tree.estimate,
            exact.estimate
        );
        // Standard CV agrees too (and with TreeCV, not just in aggregate).
        let std_res = StandardCv::default().run(&l, &data, &folds);
        assert!((std_res.estimate - exact.estimate).abs() < 1e-7 * (1.0 + exact.estimate));
    }
}
