//! Sweep scheduler: every (learner config × strategy × repetition)
//! TreeCV run of a tuning workload through ONE pooled executor.
//!
//! The paper positions fast CV as the tool for "performance estimation
//! and parameter tuning"; related work (Krueger et al., *Fast
//! Cross-Validation via Sequential Testing*; Mohr & van Rijn, *Learning
//! Curve Cross-Validation*) shows most CV compute is spent exactly in
//! this multi-run regime. [`run_sweep`] therefore does not dispatch the
//! `C × S × r` runs one executor invocation at a time: it builds one
//! [`RunSpec`] per run and hands the whole batch to
//! [`TreeCvExecutor::run_many`], which schedules every tree node of every
//! run — tagged `(run_id, s, e)` — from one persistent work-stealing
//! pool. No per-run worker spin-up/teardown, no barrier between runs, no
//! model-pool cold starts; [`SweepOutcome::pool_spawns`] (read off the
//! executor's per-pool counter) records that the whole sweep cost one
//! pool (zero for `threads = 1`, which runs inline).
//!
//! **The learner axis.** [`run_sweep`] is the generic single-family form
//! (`&[L]` — e.g. one λ grid of PEGASOS configs). [`run_sweep_erased`]
//! generalizes the axis to `&[&dyn ErasedLearner]`: the configs may be
//! *different learner families* (Pegasos next to GaussianNb next to
//! KnnClassifier), which turns the grid tuner into the model-selection
//! scheduler behind `repro select`. Both forms share the same seed/fold
//! derivation and batch through one pool; the erased form delegates to
//! [`TreeCvExecutor::run_many_erased`], whose runs are bit-identical to
//! their generic counterparts.
//!
//! Determinism contract: repetition `r` derives its fold assignment and
//! engine seed exactly as [`super::stats::run_repetitions`] does, and the
//! folds are shared by every config and strategy — common partitionings
//! isolate the learner config as the only difference between sweep rows
//! (the multi-run analogue of the paper comparing Table-2 columns on
//! common partitionings). Each run's result is bit-identical to running
//! that configuration alone through the executor (or the
//! [`super::parallel::ParallelTreeCv`] facade) at the same `threads`
//! setting — `tests/integration_sweep.rs` is the battery.
//!
//! **Exhaustive vs racing.** This module always runs every cell to
//! completion (the `--no-race` behavior). [`super::race`] layers a
//! sequential-elimination scheduler on the same batch construction —
//! shared `validate`/`repetition_folds`/`build_runs` helpers, identical
//! canonical run order — so a race with `alpha = 0` (never eliminate)
//! reproduces this module's cells bit for bit.

use super::executor::{ErasedRunSpec, RunCtrl, RunSpec, TreeCvExecutor};
use super::folds::{Folds, Ordering};
use super::stats::{repetition_engine_seed, repetition_fold_seed};
use super::{CvResult, Strategy};
use crate::data::Dataset;
use crate::learner::erased::ErasedLearner;
use crate::learner::IncrementalLearner;
use crate::metrics::{OpCounts, RunningStats, Timer};
use crate::Result;
use anyhow::bail;
use std::time::Duration;

/// The sweep's shared axes: every learner config passed to [`run_sweep`]
/// (or [`run_sweep_erased`]) is run under every strategy in `strategies`
/// for `repetitions` independent partitionings of k folds.
#[derive(Debug, Clone)]
pub struct SweepSpec {
    /// Feeding order (paper §5), shared by every run.
    pub ordering: Ordering,
    /// Model-preservation strategies to sweep (usually one).
    pub strategies: Vec<Strategy>,
    /// Fold count, shared by every run.
    pub k: usize,
    /// Independent partitionings per (config, strategy) cell.
    pub repetitions: usize,
    /// Master seed; repetition seeds derive from it as in
    /// [`super::stats::run_repetitions`].
    pub seed: u64,
    /// Worker-pool size for the whole sweep; `0` = machine parallelism.
    pub threads: usize,
}

/// One (config, strategy) cell of a sweep: the repetition-aggregated
/// estimate plus every underlying run.
#[derive(Debug, Clone)]
pub struct SweepCell {
    /// Index into the learner slice given to the sweep entry point.
    pub config: usize,
    pub strategy: Strategy,
    /// Mean of the per-repetition CV estimates.
    pub mean: f64,
    /// Sample std of the estimates (the Table-2 ±).
    pub std: f64,
    /// Counters from the last repetition (work is identical across
    /// repetitions, mirroring [`super::stats::RepetitionResult`]).
    pub ops: OpCounts,
    /// Every repetition's full result, in repetition order. Caveat: each
    /// run's `wall` measures elapsed time from *batch* start to that
    /// run's last leaf (runs share the pool and overlap), so it is NOT a
    /// per-run cost — compare configs on `ops`, or on
    /// [`SweepOutcome::total_wall`] across whole sweeps.
    pub runs: Vec<CvResult>,
}

/// Everything a sweep produced. Cells are in (config-major,
/// strategy-minor) order — ranking is the caller's concern
/// (`coordinator::run_sweep`/`run_select` sort by mean loss).
#[derive(Debug, Clone)]
pub struct SweepOutcome {
    pub cells: Vec<SweepCell>,
    /// Worker-pool size the batch actually used: the `threads` knob
    /// resolved (0 → machine parallelism) and clamped to the batch's
    /// total leaf count, exactly as the executor sizes its pool.
    pub threads: usize,
    /// Wall-clock of the whole pooled batch.
    pub total_wall: Duration,
    /// Executor pools spawned by this sweep, read directly off the
    /// executor's per-pool counter ([`TreeCvExecutor::pool_spawns`]):
    /// 1 for a multi-worker pool, 0 for a single-worker batch (runs
    /// inline) — never one per run.
    pub pool_spawns: u64,
}

/// Shared validation for both sweep forms (and the racing scheduler,
/// [`super::race`], which layers its own knobs on top).
pub(crate) fn validate(n_configs: usize, data: &Dataset, spec: &SweepSpec) -> Result<()> {
    if n_configs == 0 {
        bail!("sweep needs at least one learner config");
    }
    if spec.strategies.is_empty() {
        bail!("sweep needs at least one strategy");
    }
    if spec.repetitions == 0 {
        bail!("sweep needs repetitions >= 1");
    }
    if spec.k < 1 || spec.k > data.n {
        bail!("sweep k = {} out of range 1..={}", spec.k, data.n);
    }
    Ok(())
}

/// One fold assignment per repetition, shared by every config and
/// strategy, derived exactly as the repetition harness derives it.
pub(crate) fn repetition_folds(n: usize, spec: &SweepSpec) -> Vec<Folds> {
    (0..spec.repetitions)
        .map(|r| Folds::new(n, spec.k, repetition_fold_seed(spec.seed, r)))
        .collect()
}

/// Fold the flat (config-major, strategy, repetition) result stream back
/// into aggregated cells.
fn collect_cells(results: Vec<CvResult>, n_configs: usize, spec: &SweepSpec) -> Vec<SweepCell> {
    let mut cells = Vec::with_capacity(n_configs * spec.strategies.len());
    let mut results = results.into_iter();
    for config in 0..n_configs {
        for &strategy in &spec.strategies {
            let cell_runs: Vec<CvResult> = results.by_ref().take(spec.repetitions).collect();
            let mut stats = RunningStats::default();
            for res in &cell_runs {
                stats.push(res.estimate);
            }
            // invariant: `validate` rejects specs with 0 repetitions, so
            // every cell drains at least one run from the stream.
            let ops = cell_runs.last().expect("repetitions >= 1").ops.clone();
            cells.push(SweepCell {
                config,
                strategy,
                mean: stats.mean(),
                std: stats.std(),
                ops,
                runs: cell_runs,
            });
        }
    }
    cells
}

/// Build the batch's run list in THE canonical (config-major, strategy,
/// repetition) order both [`collect_cells`] and the equivalence tests
/// assume; `make` constructs one run from its `(config, folds, seed,
/// strategy)` cell. One implementation for both spec types so the
/// generic and erased entry points cannot drift.
pub(crate) fn build_runs<'a, T>(
    n_configs: usize,
    spec: &SweepSpec,
    folds: &'a [Folds],
    mut make: impl FnMut(usize, &'a Folds, u64, Strategy) -> T,
) -> Vec<T> {
    let mut runs = Vec::with_capacity(n_configs * spec.strategies.len() * spec.repetitions);
    for config in 0..n_configs {
        for &strategy in &spec.strategies {
            for (r, f) in folds.iter().enumerate() {
                runs.push(make(config, f, repetition_engine_seed(spec.seed, r), strategy));
            }
        }
    }
    runs
}

/// Shared dispatch tail: size one executor from the spec's knobs, run
/// the whole batch through it, and fold the flat results into cells plus
/// the pool accounting. `n_runs` is the batch's run count (for the
/// threads clamp, mirroring the executor's own `leaves_total` clamp).
fn dispatch_batch(
    n_configs: usize,
    n_runs: usize,
    spec: &SweepSpec,
    run_batch: impl FnOnce(&TreeCvExecutor) -> Vec<CvResult>,
) -> SweepOutcome {
    let timer = Timer::start();
    let engine = TreeCvExecutor::with_threads_knob(spec.strategies[0], spec.ordering, spec.threads);
    let threads_used = engine.threads.min(n_runs * spec.k);
    let results = run_batch(&engine);
    SweepOutcome {
        cells: collect_cells(results, n_configs, spec),
        threads: threads_used,
        total_wall: timer.elapsed(),
        pool_spawns: engine.pool_spawns(),
    }
}

/// Run the full single-family sweep: `learners.len() ×
/// spec.strategies.len() × spec.repetitions` TreeCV runs through one
/// pooled executor.
pub fn run_sweep<L>(learners: &[L], data: &Dataset, spec: &SweepSpec) -> Result<SweepOutcome>
where
    L: IncrementalLearner + Sync,
    L::Model: Send,
{
    validate(learners.len(), data, spec)?;
    let folds = repetition_folds(data.n, spec);
    let runs = build_runs(learners.len(), spec, &folds, |c, folds, seed, strategy| RunSpec {
        learner: &learners[c],
        folds,
        seed,
        strategy,
        folded: None,
        ctrl: RunCtrl::default(),
    });
    Ok(dispatch_batch(learners.len(), runs.len(), spec, |engine| {
        engine.run_many(data, &runs)
    }))
}

/// Run the **heterogeneous** sweep: the learner axis holds type-erased
/// configs that may belong to different families — the model-selection
/// workload. Same seed/fold derivation, same one-pool batching, same
/// (config-major, strategy-minor) cell layout as [`run_sweep`]; each
/// run's result is bit-identical to its generic standalone counterpart.
pub fn run_sweep_erased(
    learners: &[&dyn ErasedLearner],
    data: &Dataset,
    spec: &SweepSpec,
) -> Result<SweepOutcome> {
    validate(learners.len(), data, spec)?;
    let folds = repetition_folds(data.n, spec);
    let runs =
        build_runs(learners.len(), spec, &folds, |c, folds, seed, strategy| ErasedRunSpec {
            learner: learners[c],
            folds,
            seed,
            strategy,
            folded: None,
            ctrl: RunCtrl::default(),
        });
    Ok(dispatch_batch(learners.len(), runs.len(), spec, |engine| {
        engine.run_many_erased(data, &runs)
    }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SyntheticMixture1d;
    use crate::learner::erased::Erased;
    use crate::learner::histdensity::HistogramDensity;

    fn spec(threads: usize) -> SweepSpec {
        SweepSpec {
            ordering: Ordering::Fixed,
            strategies: vec![Strategy::Copy],
            k: 8,
            repetitions: 3,
            seed: 11,
            threads,
        }
    }

    #[test]
    fn cell_layout_and_aggregates() {
        let data = SyntheticMixture1d::new(300, 141).generate();
        let learners =
            vec![HistogramDensity::new(-8.0, 8.0, 16), HistogramDensity::new(-8.0, 8.0, 64)];
        let mut s = spec(2);
        s.strategies = vec![Strategy::Copy, Strategy::SaveRevert];
        let out = run_sweep(&learners, &data, &s).unwrap();
        assert_eq!(out.cells.len(), 4); // 2 configs × 2 strategies
        for (i, cell) in out.cells.iter().enumerate() {
            assert_eq!(cell.config, i / 2);
            assert_eq!(cell.runs.len(), 3);
            let manual: f64 = cell.runs.iter().map(|r| r.estimate).sum::<f64>() / 3.0;
            assert!((cell.mean - manual).abs() < 1e-12, "cell {i}");
            assert!(cell.mean.is_finite());
        }
        // Histogram density has exact revert: each config's Copy and
        // SaveRevert cells must agree bit for bit, run by run.
        for c in 0..2 {
            let (a, b) = (&out.cells[2 * c], &out.cells[2 * c + 1]);
            for (x, y) in a.runs.iter().zip(&b.runs) {
                assert_eq!(x.per_fold, y.per_fold, "config {c}");
            }
        }
    }

    #[test]
    fn erased_sweep_matches_generic_sweep_bitwise() {
        // Same configs through run_sweep (generic) and run_sweep_erased:
        // the erased learner axis must reproduce the generic cells bit
        // for bit — means, stds, per-fold vectors and counters.
        let data = SyntheticMixture1d::new(260, 143).generate();
        let generic =
            vec![HistogramDensity::new(-8.0, 8.0, 16), HistogramDensity::new(-8.0, 8.0, 48)];
        let erased: Vec<Erased<HistogramDensity>> =
            generic.iter().map(|l| Erased(l.clone())).collect();
        let refs: Vec<&dyn crate::learner::erased::ErasedLearner> =
            erased.iter().map(|l| l as &dyn crate::learner::erased::ErasedLearner).collect();
        let mut s = spec(3);
        s.strategies = vec![Strategy::Copy, Strategy::SaveRevert];
        let a = run_sweep(&generic, &data, &s).unwrap();
        let b = run_sweep_erased(&refs, &data, &s).unwrap();
        assert_eq!(a.cells.len(), b.cells.len());
        assert_eq!(a.pool_spawns, 1);
        assert_eq!(b.pool_spawns, 1);
        for (x, y) in a.cells.iter().zip(&b.cells) {
            assert_eq!(x.mean.to_bits(), y.mean.to_bits());
            assert_eq!(x.std.to_bits(), y.std.to_bits());
            for (ra, rb) in x.runs.iter().zip(&y.runs) {
                assert_eq!(ra.per_fold, rb.per_fold);
                assert_eq!(ra.ops.points_updated, rb.ops.points_updated);
                assert_eq!(ra.ops.model_copies, rb.ops.model_copies);
                assert_eq!(ra.ops.bytes_copied, rb.ops.bytes_copied);
            }
        }
    }

    #[test]
    fn rejects_degenerate_specs() {
        let data = SyntheticMixture1d::new(50, 142).generate();
        let l = vec![HistogramDensity::new(-8.0, 8.0, 16)];
        let empty: Vec<HistogramDensity> = Vec::new();
        assert!(run_sweep(&empty, &data, &spec(1)).is_err());
        assert!(run_sweep_erased(&[], &data, &spec(1)).is_err());
        let mut s = spec(1);
        s.repetitions = 0;
        assert!(run_sweep(&l, &data, &s).is_err());
        let mut s = spec(1);
        s.k = 51;
        assert!(run_sweep(&l, &data, &s).is_err());
        let mut s = spec(1);
        s.strategies.clear();
        assert!(run_sweep(&l, &data, &s).is_err());
    }
}
