//! Sweep scheduler: every (hyperparameter config × strategy × repetition)
//! TreeCV run of a tuning workload through ONE pooled executor.
//!
//! The paper positions fast CV as the tool for "performance estimation
//! and parameter tuning"; related work (Krueger et al., *Fast
//! Cross-Validation via Sequential Testing*; Mohr & van Rijn, *Learning
//! Curve Cross-Validation*) shows most CV compute is spent exactly in
//! this multi-run regime. [`run_sweep`] therefore does not dispatch the
//! `C × S × r` runs one executor invocation at a time: it builds one
//! [`RunSpec`] per run and hands the whole batch to
//! [`TreeCvExecutor::run_many`], which schedules every tree node of every
//! run — tagged `(run_id, s, e)` — from one persistent work-stealing
//! pool. No per-run worker spin-up/teardown, no barrier between runs, no
//! model-pool cold starts; [`SweepOutcome::pool_spawns`] records that the
//! whole sweep cost one pool (zero for `threads = 1`, which runs inline).
//!
//! Determinism contract: repetition `r` derives its fold assignment and
//! engine seed exactly as [`super::stats::run_repetitions`] does, and the
//! folds are shared by every config and strategy — common partitionings
//! isolate the hyperparameter as the only difference between sweep rows
//! (the multi-run analogue of the paper comparing Table-2 columns on
//! common partitionings). Each run's result is bit-identical to running
//! that configuration alone through the executor (or the
//! [`super::parallel::ParallelTreeCv`] facade) at the same `threads`
//! setting — `tests/integration_sweep.rs` is the battery.

use super::executor::{RunSpec, TreeCvExecutor};
use super::folds::{Folds, Ordering};
use super::stats::{repetition_engine_seed, repetition_fold_seed};
use super::{CvResult, Strategy};
use crate::data::Dataset;
use crate::learner::IncrementalLearner;
use crate::metrics::{OpCounts, RunningStats, Timer};
use crate::Result;
use anyhow::bail;
use std::time::Duration;

/// The sweep's shared axes: every learner config passed to [`run_sweep`]
/// is run under every strategy in `strategies` for `repetitions`
/// independent partitionings of k folds.
#[derive(Debug, Clone)]
pub struct SweepSpec {
    /// Feeding order (paper §5), shared by every run.
    pub ordering: Ordering,
    /// Model-preservation strategies to sweep (usually one).
    pub strategies: Vec<Strategy>,
    /// Fold count, shared by every run.
    pub k: usize,
    /// Independent partitionings per (config, strategy) cell.
    pub repetitions: usize,
    /// Master seed; repetition seeds derive from it as in
    /// [`super::stats::run_repetitions`].
    pub seed: u64,
    /// Worker-pool size for the whole sweep; `0` = machine parallelism.
    pub threads: usize,
}

/// One (config, strategy) cell of a sweep: the repetition-aggregated
/// estimate plus every underlying run.
#[derive(Debug, Clone)]
pub struct SweepCell {
    /// Index into the `learners` slice given to [`run_sweep`].
    pub config: usize,
    pub strategy: Strategy,
    /// Mean of the per-repetition CV estimates.
    pub mean: f64,
    /// Sample std of the estimates (the Table-2 ±).
    pub std: f64,
    /// Counters from the last repetition (work is identical across
    /// repetitions, mirroring [`super::stats::RepetitionResult`]).
    pub ops: OpCounts,
    /// Every repetition's full result, in repetition order. Caveat: each
    /// run's `wall` measures elapsed time from *batch* start to that
    /// run's last leaf (runs share the pool and overlap), so it is NOT a
    /// per-run cost — compare configs on `ops`, or on
    /// [`SweepOutcome::total_wall`] across whole sweeps.
    pub runs: Vec<CvResult>,
}

/// Everything [`run_sweep`] produced. Cells are in (config-major,
/// strategy-minor) order — ranking is the caller's concern
/// (`coordinator::run_sweep` sorts by mean loss).
#[derive(Debug, Clone)]
pub struct SweepOutcome {
    pub cells: Vec<SweepCell>,
    /// Worker-pool size the batch actually used: the `threads` knob
    /// resolved (0 → machine parallelism) and clamped to the batch's
    /// total leaf count, exactly as the executor sizes its pool.
    pub threads: usize,
    /// Wall-clock of the whole pooled batch.
    pub total_wall: Duration,
    /// Executor pools spawned by this sweep: 1 for a multi-worker pool,
    /// 0 for a single-worker batch (runs inline) — never one per run.
    /// Known locally (the sweep makes exactly one `run_many` call, which
    /// spawns iff the pool has more than one worker), so the count is
    /// exact even when other executors run concurrently in the process;
    /// the global [`super::executor::pool_spawn_count`] counter
    /// corroborates it in `tests/integration_sweep.rs`.
    pub pool_spawns: u64,
}

/// Run the full sweep: `learners.len() × spec.strategies.len() ×
/// spec.repetitions` TreeCV runs through one pooled executor.
pub fn run_sweep<L>(learners: &[L], data: &Dataset, spec: &SweepSpec) -> Result<SweepOutcome>
where
    L: IncrementalLearner + Sync,
    L::Model: Send,
{
    if learners.is_empty() {
        bail!("sweep needs at least one hyperparameter config");
    }
    if spec.strategies.is_empty() {
        bail!("sweep needs at least one strategy");
    }
    if spec.repetitions == 0 {
        bail!("sweep needs repetitions >= 1");
    }
    if spec.k < 1 || spec.k > data.n {
        bail!("sweep k = {} out of range 1..={}", spec.k, data.n);
    }

    // One fold assignment per repetition, shared by every config and
    // strategy, derived exactly as the repetition harness derives it.
    let folds: Vec<Folds> = (0..spec.repetitions)
        .map(|r| Folds::new(data.n, spec.k, repetition_fold_seed(spec.seed, r)))
        .collect();

    let mut runs = Vec::with_capacity(learners.len() * spec.strategies.len() * spec.repetitions);
    for learner in learners {
        for &strategy in &spec.strategies {
            for (r, f) in folds.iter().enumerate() {
                let seed = repetition_engine_seed(spec.seed, r);
                runs.push(RunSpec { learner, folds: f, seed, strategy });
            }
        }
    }

    let timer = Timer::start();
    let engine = TreeCvExecutor::with_threads_knob(spec.strategies[0], spec.ordering, spec.threads);
    // The pool size the executor will actually use (its own clamp,
    // mirrored on the batch's total leaf count) — and, from it, the exact
    // spawn count: one run_many call spawns iff the pool is multi-worker.
    let threads_used = engine.threads.min(runs.len() * spec.k);
    let results = engine.run_many(data, &runs);
    let total_wall = timer.elapsed();
    let pool_spawns = u64::from(threads_used > 1);

    let mut cells = Vec::with_capacity(learners.len() * spec.strategies.len());
    let mut results = results.into_iter();
    for config in 0..learners.len() {
        for &strategy in &spec.strategies {
            let cell_runs: Vec<CvResult> = results.by_ref().take(spec.repetitions).collect();
            let mut stats = RunningStats::default();
            for res in &cell_runs {
                stats.push(res.estimate);
            }
            let ops = cell_runs.last().expect("repetitions >= 1").ops.clone();
            cells.push(SweepCell {
                config,
                strategy,
                mean: stats.mean(),
                std: stats.std(),
                ops,
                runs: cell_runs,
            });
        }
    }
    Ok(SweepOutcome { cells, threads: threads_used, total_wall, pool_spawns })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SyntheticMixture1d;
    use crate::learner::histdensity::HistogramDensity;

    fn spec(threads: usize) -> SweepSpec {
        SweepSpec {
            ordering: Ordering::Fixed,
            strategies: vec![Strategy::Copy],
            k: 8,
            repetitions: 3,
            seed: 11,
            threads,
        }
    }

    #[test]
    fn cell_layout_and_aggregates() {
        let data = SyntheticMixture1d::new(300, 141).generate();
        let learners =
            vec![HistogramDensity::new(-8.0, 8.0, 16), HistogramDensity::new(-8.0, 8.0, 64)];
        let mut s = spec(2);
        s.strategies = vec![Strategy::Copy, Strategy::SaveRevert];
        let out = run_sweep(&learners, &data, &s).unwrap();
        assert_eq!(out.cells.len(), 4); // 2 configs × 2 strategies
        for (i, cell) in out.cells.iter().enumerate() {
            assert_eq!(cell.config, i / 2);
            assert_eq!(cell.runs.len(), 3);
            let manual: f64 = cell.runs.iter().map(|r| r.estimate).sum::<f64>() / 3.0;
            assert!((cell.mean - manual).abs() < 1e-12, "cell {i}");
            assert!(cell.mean.is_finite());
        }
        // Histogram density has exact revert: each config's Copy and
        // SaveRevert cells must agree bit for bit, run by run.
        for c in 0..2 {
            let (a, b) = (&out.cells[2 * c], &out.cells[2 * c + 1]);
            for (x, y) in a.runs.iter().zip(&b.runs) {
                assert_eq!(x.per_fold, y.per_fold, "config {c}");
            }
        }
    }

    #[test]
    fn rejects_degenerate_specs() {
        let data = SyntheticMixture1d::new(50, 142).generate();
        let l = vec![HistogramDensity::new(-8.0, 8.0, 16)];
        let empty: Vec<HistogramDensity> = Vec::new();
        assert!(run_sweep(&empty, &data, &spec(1)).is_err());
        let mut s = spec(1);
        s.repetitions = 0;
        assert!(run_sweep(&l, &data, &s).is_err());
        let mut s = spec(1);
        s.k = 51;
        assert!(run_sweep(&l, &data, &s).is_err());
        let mut s = spec(1);
        s.strategies.clear();
        assert!(run_sweep(&l, &data, &s).is_err());
    }
}
