//! Repetition harness: the paper's Table 2 reports k-CV estimates
//! "averaged over 100 repetitions (and their standard deviations), with
//! and without data re-permutation". Each repetition draws a fresh random
//! fold assignment (and, in the randomized variants, fresh feeding-order
//! permutations), runs an engine, and the harness accumulates mean ± std
//! of the resulting estimates plus aggregate work counters.

use super::approx::max_fold_gap;
use super::executor::{RunCtrl, RunSpec, TreeCvExecutor};
use super::folds::{Folds, Ordering};
use super::standard::StandardCv;
use super::treecv::TreeCv;
use super::{CvEngine, CvResult, Strategy};
use crate::data::Dataset;
use crate::learner::IncrementalLearner;
use crate::metrics::{OpCounts, RunningStats, Timer};
use crate::Result;
use anyhow::bail;
use std::time::Duration;

/// Mix constant of the repetition-seed derivation.
const REP_SEED_MIX: u64 = 0x9E3779B97F4A7C15;

/// Repetition `r`'s fold-assignment seed for master seed `seed` — THE
/// derivation every multi-partitioning harness shares (this module,
/// [`super::repeated`], [`super::sweep`]), so all of them see the same
/// fold assignments for the same master seed.
pub fn repetition_fold_seed(seed: u64, r: usize) -> u64 {
    seed.wrapping_add(r as u64).wrapping_mul(REP_SEED_MIX)
}

/// Repetition `r`'s engine (permutation-stream) seed for master `seed`.
pub fn repetition_engine_seed(seed: u64, r: usize) -> u64 {
    repetition_fold_seed(seed, r) ^ 0xA5A5
}

/// Which engine a repetition run uses. `ParallelTreeCv` executes on the
/// pooled work-stealing executor ([`TreeCvExecutor`]) sized to the
/// machine's available parallelism; `Approx` runs the one-step-correction
/// engine ([`super::approx`]) on the same pool.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKind {
    TreeCv,
    Standard,
    ParallelTreeCv,
    Approx,
}

/// Configuration of one Table-2-style cell.
#[derive(Debug, Clone)]
pub struct RepetitionSpec {
    pub engine: EngineKind,
    pub ordering: Ordering,
    pub strategy: Strategy,
    pub k: usize,
    pub repetitions: usize,
    pub seed: u64,
    /// Worker-pool size for `EngineKind::ParallelTreeCv` and
    /// `EngineKind::Approx` (`0` = machine parallelism); ignored by the
    /// sequential engines.
    pub threads: usize,
    /// For `EngineKind::Approx`: also run the exact TreeCV engine on each
    /// repetition's partitioning and record the largest per-fold
    /// |approx − exact| in `OpCounts::exact_gap_max`. Ignored by the
    /// exact engines.
    pub approx_check: bool,
}

/// Aggregated outcome of the repetitions.
#[derive(Debug, Clone)]
pub struct RepetitionResult {
    pub spec: RepetitionSpec,
    /// Mean of the per-repetition CV estimates.
    pub mean: f64,
    /// Sample standard deviation of the estimates (the paper's ±).
    pub std: f64,
    /// Total wall-clock across repetitions.
    pub total_wall: Duration,
    /// Mean wall-clock per repetition (seconds).
    pub mean_wall_secs: f64,
    /// Counters from the last repetition (work is identical across reps).
    pub ops: OpCounts,
}

/// Run `spec.repetitions` independent CV computations.
///
/// Repetition `r` derives its fold assignment from `(seed, r)` and its
/// permutation streams from `(seed, r, node)` — so TreeCV and StandardCv
/// called with the same spec see the *same* fold assignments, isolating
/// the engine as the only difference (this mirrors the paper comparing
/// columns of Table 2 on common partitionings).
///
/// `spec.strategy` is honored by every TreeCV-family engine — including
/// `EngineKind::ParallelTreeCv`, which forwards it to the pooled executor.
/// An engine that cannot honor a requested strategy is a hard error, never
/// a silent downgrade: `EngineKind::Standard` trains each fold's model
/// from scratch and has no update to rewind, so it rejects SaveRevert.
///
/// `EngineKind::ParallelTreeCv` repetitions are batched through ONE
/// executor pool ([`TreeCvExecutor::run_many`]) instead of one pool per
/// repetition; seeds and folds derive identically either way, so the
/// estimates are bit-identical to per-repetition dispatch — only the
/// `repetitions − 1` pool spawns and cold starts disappear.
///
/// `EngineKind::Approx` batches the same way through
/// [`TreeCvExecutor::run_many_approx`]. It requires a learner advertising
/// a one-step correction ([`IncrementalLearner::correctable`]) and has no
/// Copy-vs-SaveRevert axis (it neither forks interior nodes nor rewinds
/// updates), so SaveRevert is rejected like `standard` rejects it. With
/// `spec.approx_check` each repetition also runs the exact sequential
/// TreeCV on the same partitioning and records the largest per-fold
/// deviation in `OpCounts::exact_gap_max` (the reported ops carry the
/// sup over repetitions).
pub fn run_repetitions<L>(
    learner: &L,
    data: &Dataset,
    spec: &RepetitionSpec,
) -> Result<RepetitionResult>
where
    L: IncrementalLearner + Sync,
    L::Model: Send,
{
    if spec.engine == EngineKind::Standard && spec.strategy == Strategy::SaveRevert {
        bail!(
            "engine `standard` cannot honor the save/revert strategy (it retrains every fold \
             from scratch and never rewinds an update); refusing to silently run Copy instead — \
             use --engine treecv or parallel_treecv"
        );
    }
    if spec.engine == EngineKind::Approx {
        if spec.strategy == Strategy::SaveRevert {
            bail!(
                "engine `approx` cannot honor the save/revert strategy (it trains once and \
                 corrects per fold — it neither forks interior nodes nor rewinds an update); \
                 use --strategy copy or an exact engine"
            );
        }
        if !learner.correctable() {
            bail!(
                "engine `approx` requires a learner with a one-step held-out correction \
                 (ConvexCorrectable), which `{}` does not provide — use a convex task \
                 (pegasos, lsqsgd, ridge) or an exact engine (treecv, parallel_treecv, \
                 standard)",
                learner.name()
            );
        }
    }
    let timer = Timer::start();
    let results: Vec<CvResult> = match spec.engine {
        EngineKind::ParallelTreeCv => {
            let folds: Vec<Folds> = (0..spec.repetitions)
                .map(|r| Folds::new(data.n, spec.k, repetition_fold_seed(spec.seed, r)))
                .collect();
            // All repetitions share ONE control block: a repetition that
            // fails mid-batch cancels its siblings' outstanding tree
            // tasks (fast wind-down) instead of running the batch to
            // completion before the failure surfaces. `run_many`
            // re-panics with the original failure either way.
            let batch_ctrl = RunCtrl::new();
            let runs: Vec<RunSpec<'_, L>> = folds
                .iter()
                .enumerate()
                .map(|(r, f)| RunSpec {
                    learner,
                    folds: f,
                    seed: repetition_engine_seed(spec.seed, r),
                    strategy: spec.strategy,
                    folded: None,
                    ctrl: batch_ctrl.clone(),
                })
                .collect();
            TreeCvExecutor::with_threads_knob(spec.strategy, spec.ordering, spec.threads)
                .run_many(data, &runs)
        }
        EngineKind::Approx => {
            let folds: Vec<Folds> = (0..spec.repetitions)
                .map(|r| Folds::new(data.n, spec.k, repetition_fold_seed(spec.seed, r)))
                .collect();
            let batch_ctrl = RunCtrl::new();
            let runs: Vec<RunSpec<'_, L>> = folds
                .iter()
                .enumerate()
                .map(|(r, f)| RunSpec {
                    learner,
                    folds: f,
                    seed: repetition_engine_seed(spec.seed, r),
                    strategy: spec.strategy,
                    folded: None,
                    ctrl: batch_ctrl.clone(),
                })
                .collect();
            let mut results =
                TreeCvExecutor::with_threads_knob(spec.strategy, spec.ordering, spec.threads)
                    .run_many_approx(data, &runs);
            if spec.approx_check {
                // Exact oracle on the SAME partitioning and permutation
                // seed, so the correction error is the only difference.
                for (r, f) in folds.iter().enumerate() {
                    let seed = repetition_engine_seed(spec.seed, r);
                    let exact =
                        TreeCv::new(Strategy::Copy, spec.ordering, seed).run(learner, data, f);
                    results[r].ops.exact_gap_max = max_fold_gap(&results[r], &exact);
                }
            }
            results
        }
        EngineKind::TreeCv | EngineKind::Standard => (0..spec.repetitions)
            .map(|r| {
                let folds = Folds::new(data.n, spec.k, repetition_fold_seed(spec.seed, r));
                let seed = repetition_engine_seed(spec.seed, r);
                match spec.engine {
                    EngineKind::TreeCv => {
                        TreeCv::new(spec.strategy, spec.ordering, seed).run(learner, data, &folds)
                    }
                    EngineKind::Standard => {
                        StandardCv::new(spec.ordering, seed).run(learner, data, &folds)
                    }
                    EngineKind::ParallelTreeCv | EngineKind::Approx => {
                        unreachable!("batched above")
                    }
                }
            })
            .collect(),
    };
    let mut stats = RunningStats::default();
    for res in &results {
        stats.push(res.estimate);
    }
    // Pooled repetitions overlap in time, so "total" is the harness
    // elapsed; for the sequential engines the two notions agree up to
    // loop overhead.
    let total_wall = timer.elapsed();
    let mut last_ops = results.last().map(|r| r.ops.clone()).unwrap_or_default();
    // Work counters are identical across repetitions, but an approx-check
    // gap varies with the partitioning — report the sup over the batch.
    for res in &results {
        last_ops.exact_gap_max = last_ops.exact_gap_max.max(res.ops.exact_gap_max);
    }
    Ok(RepetitionResult {
        spec: spec.clone(),
        mean: stats.mean(),
        std: stats.std(),
        total_wall,
        mean_wall_secs: total_wall.as_secs_f64() / spec.repetitions.max(1) as f64,
        ops: last_ops,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SyntheticMixture1d;
    use crate::learner::histdensity::HistogramDensity;

    fn spec(engine: EngineKind, k: usize, reps: usize) -> RepetitionSpec {
        RepetitionSpec {
            engine,
            ordering: Ordering::Fixed,
            strategy: Strategy::Copy,
            k,
            repetitions: reps,
            seed: 7,
            threads: 0,
            approx_check: false,
        }
    }

    fn spec_with_strategy(engine: EngineKind, strategy: Strategy, k: usize) -> RepetitionSpec {
        RepetitionSpec { strategy, ..spec(engine, k, 5) }
    }

    #[test]
    fn tree_and_standard_agree_exactly_per_partitioning() {
        // Same seeds → same fold assignments → identical estimates for an
        // order-insensitive learner, hence identical means AND stds.
        let data = SyntheticMixture1d::new(300, 121).generate();
        let l = HistogramDensity::new(-8.0, 8.0, 32);
        let a = run_repetitions(&l, &data, &spec(EngineKind::TreeCv, 10, 20)).unwrap();
        let b = run_repetitions(&l, &data, &spec(EngineKind::Standard, 10, 20)).unwrap();
        assert_eq!(a.mean, b.mean);
        assert_eq!(a.std, b.std);
    }

    #[test]
    fn variance_decreases_with_k() {
        // More folds → more averaging inside each estimate → lower
        // across-partitioning variance (the Table 2 trend for TreeCV).
        let data = SyntheticMixture1d::new(400, 122).generate();
        let l = HistogramDensity::new(-8.0, 8.0, 32);
        let lo = run_repetitions(&l, &data, &spec(EngineKind::TreeCv, 2, 40)).unwrap();
        let hi = run_repetitions(&l, &data, &spec(EngineKind::TreeCv, 40, 40)).unwrap();
        assert!(
            hi.std < lo.std,
            "std(k=40) {} !< std(k=2) {}",
            hi.std,
            lo.std
        );
    }

    #[test]
    fn parallel_engine_kind_is_bit_identical_to_treecv() {
        // The executor derives permutation streams per node, so routing
        // EngineKind::ParallelTreeCv through it must reproduce the
        // sequential engine exactly — identical means AND stds, even for
        // an order-sensitive learner.
        let data = crate::data::synth::SyntheticCovertype::new(600, 124).generate();
        let l = crate::learner::pegasos::Pegasos::new(54, 1e-3);
        let a = run_repetitions(&l, &data, &spec(EngineKind::TreeCv, 8, 5)).unwrap();
        let b = run_repetitions(&l, &data, &spec(EngineKind::ParallelTreeCv, 8, 5)).unwrap();
        assert_eq!(a.mean, b.mean);
        assert_eq!(a.std, b.std);
        assert_eq!(a.ops.points_updated, b.ops.points_updated);
    }

    #[test]
    fn parallel_engine_kind_honors_save_revert() {
        // SaveRevert through EngineKind::ParallelTreeCv must match the
        // sequential SaveRevert engine (exact-revert learner) and keep the
        // §4.1 interior-node accounting: every interior node is either one
        // fork snapshot or two restores, never both.
        let data = SyntheticMixture1d::new(320, 125).generate();
        let l = HistogramDensity::new(-8.0, 8.0, 32);
        let k = 32usize;
        let a = run_repetitions(
            &l,
            &data,
            &spec_with_strategy(EngineKind::TreeCv, Strategy::SaveRevert, k),
        )
        .unwrap();
        let b = run_repetitions(
            &l,
            &data,
            &spec_with_strategy(EngineKind::ParallelTreeCv, Strategy::SaveRevert, k),
        )
        .unwrap();
        assert_eq!(a.mean, b.mean);
        assert_eq!(a.std, b.std);
        assert_eq!(
            2 * b.ops.model_copies + b.ops.model_restores,
            2 * (k as u64 - 1),
            "copies {} / restores {}",
            b.ops.model_copies,
            b.ops.model_restores
        );
    }

    #[test]
    fn standard_with_save_revert_is_a_hard_error() {
        let data = SyntheticMixture1d::new(100, 126).generate();
        let l = HistogramDensity::new(-8.0, 8.0, 32);
        let err = run_repetitions(
            &l,
            &data,
            &spec_with_strategy(EngineKind::Standard, Strategy::SaveRevert, 5),
        )
        .unwrap_err();
        assert!(format!("{err}").contains("save/revert"), "{err}");
    }

    #[test]
    fn pooled_repetitions_bit_identical_to_per_rep_dispatch() {
        // EngineKind::ParallelTreeCv now batches every repetition through
        // one executor pool; the estimates must match dispatching each
        // repetition through its own pool (the old behavior) bit for bit.
        let data = SyntheticMixture1d::new(300, 127).generate();
        let l = HistogramDensity::new(-8.0, 8.0, 32);
        let s = spec(EngineKind::ParallelTreeCv, 9, 6);
        let pooled = run_repetitions(&l, &data, &s).unwrap();
        let mut manual = crate::metrics::RunningStats::default();
        for r in 0..s.repetitions {
            let folds = Folds::new(data.n, s.k, repetition_fold_seed(s.seed, r));
            let res = TreeCvExecutor::with_available_parallelism(
                s.strategy,
                s.ordering,
                repetition_engine_seed(s.seed, r),
            )
            .run(&l, &data, &folds);
            manual.push(res.estimate);
        }
        assert_eq!(pooled.mean.to_bits(), manual.mean().to_bits());
        assert_eq!(pooled.std.to_bits(), manual.std().to_bits());

        // The threads knob is honored, not silently ignored: an explicit
        // single-worker spec runs inline and still matches bit for bit.
        let inline = run_repetitions(&l, &data, &RepetitionSpec { threads: 1, ..s }).unwrap();
        assert_eq!(inline.mean.to_bits(), pooled.mean.to_bits());
        assert_eq!(inline.std.to_bits(), pooled.std.to_bits());
    }

    #[test]
    fn repetition_seed_derivation_pinned() {
        // Pinned by value: cv::sweep and cv::repeated derive their fold
        // assignments through these helpers, so a drive-by change here
        // would silently re-partition every harness.
        assert_eq!(repetition_fold_seed(7, 0), 7u64.wrapping_mul(0x9E3779B97F4A7C15));
        assert_eq!(repetition_engine_seed(7, 2), repetition_fold_seed(7, 2) ^ 0xA5A5);
    }

    #[test]
    fn approx_repetitions_record_corrections_and_checked_gap() {
        let data = crate::data::synth::SyntheticYearMsd::new(240, 129).generate();
        let l = crate::learner::ridge::OnlineRidge::new(90, 1.0);
        let k = 12usize;
        let s = RepetitionSpec { approx_check: true, ..spec(EngineKind::Approx, k, 4) };
        let res = run_repetitions(&l, &data, &s).unwrap();
        assert!(res.mean.is_finite());
        assert_eq!(res.ops.corrections, k as u64);
        assert_eq!(res.ops.update_calls, 1);
        // Ridge's downdate is exact up to rounding; the checked gap must
        // be tiny but (having run) is recorded, not left at the default.
        assert!(res.ops.exact_gap_max <= 1e-8, "gap {:e}", res.ops.exact_gap_max);
        // Without the check the gap field stays at its 0.0 default.
        let unchecked = run_repetitions(&l, &data, &spec(EngineKind::Approx, k, 4)).unwrap();
        assert_eq!(unchecked.ops.exact_gap_max, 0.0);
        assert_eq!(unchecked.mean.to_bits(), res.mean.to_bits());
    }

    #[test]
    fn approx_rejects_non_correctable_learner_and_save_revert() {
        let data = SyntheticMixture1d::new(120, 130).generate();
        let l = HistogramDensity::new(-8.0, 8.0, 32);
        let err = run_repetitions(&l, &data, &spec(EngineKind::Approx, 5, 2)).unwrap_err();
        assert!(format!("{err}").contains("one-step held-out correction"), "{err}");
        let err = run_repetitions(
            &l,
            &data,
            &spec_with_strategy(EngineKind::Approx, Strategy::SaveRevert, 5),
        )
        .unwrap_err();
        assert!(format!("{err}").contains("save/revert"), "{err}");
    }

    #[test]
    fn repetitions_vary_partitionings() {
        let data = SyntheticMixture1d::new(200, 123).generate();
        let l = HistogramDensity::new(-8.0, 8.0, 32);
        let res = run_repetitions(&l, &data, &spec(EngineKind::TreeCv, 5, 10)).unwrap();
        // With varying partitions the estimator std must be nonzero.
        assert!(res.std > 0.0);
        assert!(res.mean.is_finite());
    }
}
