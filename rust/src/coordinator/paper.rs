//! Paper-shaped outputs: every table and figure in the paper's evaluation
//! section (§5) has a generator here that prints the same rows/series the
//! paper reports. The CLI subcommands and the criterion benches both call
//! these, so EXPERIMENTS.md numbers are regenerable from one place.

use super::{run_experiment, CellReport};
use crate::config::{Engine, ExperimentConfig, OrderingCfg, Task};
use crate::cv::exact;
use crate::cv::folds::Folds;
use crate::data::synth::SyntheticCovertype;
use crate::distributed::{Cluster, NetworkModel};
use crate::learner::pegasos::Pegasos;
use crate::learner::IncrementalLearner;
use crate::Result;
use anyhow::bail;

fn base_cfg(task: Task, n: usize, seed: u64) -> ExperimentConfig {
    ExperimentConfig { task, n, seed, ..ExperimentConfig::default() }
}

// ---------------------------------------------------------------------------
// Table 2
// ---------------------------------------------------------------------------

/// One Table-2 cell: engine × ordering × k.
#[derive(Debug, Clone)]
pub struct Table2Cell {
    pub k: usize,
    pub is_loocv: bool,
    pub engine: Engine,
    pub ordering: OrderingCfg,
    pub mean: f64,
    pub std: f64,
    pub mean_wall_secs: f64,
}

/// Full Table-2 reproduction for one task.
#[derive(Debug, Clone)]
pub struct Table2Output {
    pub task: Task,
    pub n: usize,
    pub repetitions: usize,
    pub cells: Vec<Table2Cell>,
}

/// Reproduce Table 2: for each k, the four columns
/// (TreeCV × {fixed, randomized}, Standard × {fixed, randomized});
/// for k = n (LOOCV) the standard columns are N/A, as in the paper.
pub fn table2(task: Task, n: usize, ks: &[usize], reps: usize, seed: u64) -> Result<Table2Output> {
    let mut cells = Vec::new();
    for &k_raw in ks {
        let is_loocv = k_raw == 0 || k_raw == n;
        for engine in [Engine::Treecv, Engine::Standard] {
            if is_loocv && engine == Engine::Standard {
                continue; // paper: "N/A" — infeasible by construction
            }
            for ordering in [OrderingCfg::Fixed, OrderingCfg::Randomized] {
                let cfg = ExperimentConfig {
                    engine,
                    ordering,
                    ks: vec![k_raw],
                    repetitions: reps,
                    ..base_cfg(task, n, seed)
                };
                let rep: CellReport = run_experiment(&cfg)?.remove(0);
                cells.push(Table2Cell {
                    k: rep.k,
                    is_loocv,
                    engine,
                    ordering,
                    mean: rep.mean,
                    std: rep.std,
                    mean_wall_secs: rep.mean_wall_secs,
                });
            }
        }
    }
    Ok(Table2Output { task, n, repetitions: reps, cells })
}

impl crate::report::ToJson for Table2Output {
    fn to_json(&self) -> crate::report::Json {
        use crate::report::Json;
        Json::obj(vec![
            ("task", Json::str(self.task.name())),
            ("n", Json::num(self.n as f64)),
            ("repetitions", Json::num(self.repetitions as f64)),
            (
                "cells",
                Json::Arr(
                    self.cells
                        .iter()
                        .map(|c| {
                            Json::obj(vec![
                                ("k", Json::num(c.k as f64)),
                                ("is_loocv", Json::Bool(c.is_loocv)),
                                ("engine", Json::str(c.engine.name())),
                                ("ordering", Json::str(c.ordering.name())),
                                ("mean", Json::Num(c.mean)),
                                ("std", Json::Num(c.std)),
                                ("mean_wall_secs", Json::Num(c.mean_wall_secs)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

impl Table2Output {
    /// Render in the paper's layout (values ×100, like Table 2).
    pub fn render(&self) -> String {
        let mut s = format!(
            "Table 2 — CV estimates for {:?} (loss ×100), n = {}, {} repetitions\n\
             {:>8} | {:>22} | {:>22} | {:>22} | {:>22}\n",
            self.task, self.n, self.repetitions,
            "k", "TreeCV fixed", "TreeCV randomized", "Standard fixed", "Standard randomized",
        );
        let mut ks: Vec<usize> = self.cells.iter().map(|c| c.k).collect();
        ks.dedup();
        for k in ks {
            let cell = |engine: Engine, ordering: OrderingCfg| -> String {
                self.cells
                    .iter()
                    .find(|c| c.k == k && c.engine == engine && c.ordering == ordering)
                    .map(|c| format!("{:>10.3} ± {:<8.4}", c.mean * 100.0, c.std * 100.0))
                    .unwrap_or_else(|| format!("{:>22}", "N/A"))
            };
            let k_label = if self.cells.iter().any(|c| c.k == k && c.is_loocv) {
                format!("n={k}")
            } else {
                format!("{k}")
            };
            s.push_str(&format!(
                "{:>8} | {} | {} | {} | {}\n",
                k_label,
                cell(Engine::Treecv, OrderingCfg::Fixed),
                cell(Engine::Treecv, OrderingCfg::Randomized),
                cell(Engine::Standard, OrderingCfg::Fixed),
                cell(Engine::Standard, OrderingCfg::Randomized),
            ));
        }
        s
    }
}

// ---------------------------------------------------------------------------
// Figure 2
// ---------------------------------------------------------------------------

/// Which column of Figure 2.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Panel {
    /// Left column: k ∈ {5,10,100}, fixed order.
    Fixed,
    /// Middle column: k ∈ {5,10,100}, randomized order.
    Randomized,
    /// Right column: LOOCV (k = n), both orderings, standard only at small n.
    Loocv,
}

impl Panel {
    pub fn parse(s: &str) -> Result<Panel> {
        Ok(match s {
            "fixed" => Panel::Fixed,
            "randomized" => Panel::Randomized,
            "loocv" => Panel::Loocv,
            other => bail!("unknown panel `{other}` (fixed|randomized|loocv)"),
        })
    }
}

/// One measured point of a Figure-2 series.
#[derive(Debug, Clone)]
pub struct Figure2Row {
    pub series: String,
    pub n: usize,
    pub k: usize,
    pub mean_wall_secs: f64,
    pub points_updated: u64,
}

#[derive(Debug, Clone)]
pub struct Figure2Output {
    pub task: Task,
    pub panel: Panel,
    pub rows: Vec<Figure2Row>,
}

/// Default n-sweep for a maximum size (rough geometric spacing, as in the
/// paper's n axis).
pub fn default_ns(max_n: usize) -> Vec<usize> {
    let mut ns = Vec::new();
    let mut n = 1_000usize;
    while n < max_n {
        ns.push(n);
        ns.push((n * 2).min(max_n));
        ns.push((n * 5).min(max_n));
        n *= 10;
    }
    ns.push(max_n);
    ns.sort_unstable();
    ns.dedup();
    ns.retain(|&v| v >= 100);
    ns
}

/// Reproduce one Figure-2 panel: runtime vs n for TreeCV and the standard
/// method. `reps` repetitions are averaged per point (the paper used 100).
pub fn figure2(
    task: Task,
    panel: Panel,
    ns: &[usize],
    reps: usize,
    seed: u64,
) -> Result<Figure2Output> {
    let mut rows = Vec::new();
    let ordering = match panel {
        Panel::Randomized => OrderingCfg::Randomized,
        _ => OrderingCfg::Fixed,
    };
    match panel {
        Panel::Fixed | Panel::Randomized => {
            for &k in &[5usize, 10, 100] {
                for engine in [Engine::Treecv, Engine::Standard] {
                    for &n in ns {
                        if k > n {
                            continue;
                        }
                        let cfg = ExperimentConfig {
                            engine,
                            ordering,
                            ks: vec![k],
                            repetitions: reps,
                            ..base_cfg(task, n, seed)
                        };
                        let rep = run_experiment(&cfg)?.remove(0);
                        rows.push(Figure2Row {
                            series: format!("{engine:?}-k{k}").to_lowercase(),
                            n,
                            k,
                            mean_wall_secs: rep.mean_wall_secs,
                            points_updated: rep.ops.points_updated,
                        });
                    }
                }
            }
        }
        Panel::Loocv => {
            for &n in ns {
                for ordering in [OrderingCfg::Fixed, OrderingCfg::Randomized] {
                    let cfg = ExperimentConfig {
                        engine: Engine::Treecv,
                        ordering,
                        ks: vec![0],
                        repetitions: reps,
                        ..base_cfg(task, n, seed)
                    };
                    let rep = run_experiment(&cfg)?.remove(0);
                    rows.push(Figure2Row {
                        series: format!("treecv-loocv-{ordering:?}").to_lowercase(),
                        n,
                        k: n,
                        mean_wall_secs: rep.mean_wall_secs,
                        points_updated: rep.ops.points_updated,
                    });
                }
                // Standard LOOCV is Θ(n²): only run where the paper could
                // (n ≤ 10,000), so the panel shows the same cut-off.
                if n <= 10_000 {
                    for ordering in [OrderingCfg::Fixed, OrderingCfg::Randomized] {
                        let cfg = ExperimentConfig {
                            engine: Engine::Standard,
                            ordering,
                            ks: vec![0],
                            repetitions: reps.min(3),
                            ..base_cfg(task, n, seed)
                        };
                        let rep = run_experiment(&cfg)?.remove(0);
                        rows.push(Figure2Row {
                            series: format!("standard-loocv-{ordering:?}").to_lowercase(),
                            n,
                            k: n,
                            mean_wall_secs: rep.mean_wall_secs,
                            points_updated: rep.ops.points_updated,
                        });
                    }
                }
            }
        }
    }
    Ok(Figure2Output { task, panel, rows })
}

impl Figure2Output {
    pub fn render_csv(&self) -> String {
        let mut s = String::from("series,n,k,mean_wall_secs,points_updated\n");
        for r in &self.rows {
            s.push_str(&format!(
                "{},{},{},{:.6},{}\n",
                r.series, r.n, r.k, r.mean_wall_secs, r.points_updated
            ));
        }
        s
    }
}

// ---------------------------------------------------------------------------
// LOOCV headline, distributed report, grid search, selfcheck
// ---------------------------------------------------------------------------

/// The paper's headline comparison: TreeCV LOOCV at large n versus the
/// standard method at a small n (the paper: TreeCV at n = 581,012 took a
/// fraction of the standard method's time at n = 10,000).
pub fn loocv_headline(task: Task, n: usize, standard_max_n: usize, seed: u64) -> Result<String> {
    let tree_cfg = ExperimentConfig {
        engine: Engine::Treecv,
        ks: vec![0],
        repetitions: 1,
        ..base_cfg(task, n, seed)
    };
    let tree = run_experiment(&tree_cfg)?.remove(0);
    let std_cfg = ExperimentConfig {
        engine: Engine::Standard,
        ks: vec![0],
        repetitions: 1,
        n: standard_max_n,
        ..base_cfg(task, standard_max_n, seed)
    };
    let std_rep = run_experiment(&std_cfg)?.remove(0);
    let mut s = String::new();
    s.push_str(&format!("LOOCV headline ({task:?}):\n"));
    s.push_str(&format!(
        "  TreeCV   LOOCV @ n={:>8}: {:>10.3}s  estimate={:.6}  ({} update-points)\n",
        n, tree.mean_wall_secs, tree.mean, tree.ops.points_updated
    ));
    s.push_str(&format!(
        "  Standard LOOCV @ n={:>8}: {:>10.3}s  estimate={:.6}  ({} update-points)\n",
        standard_max_n, std_rep.mean_wall_secs, std_rep.mean, std_rep.ops.points_updated
    ));
    s.push_str(&format!(
        "  TreeCV at {}x the data runs {:.1}x {} than standard at n={}\n",
        n / standard_max_n.max(1),
        if tree.mean_wall_secs > 0.0 {
            (std_rep.mean_wall_secs / tree.mean_wall_secs).max(
                tree.mean_wall_secs / std_rep.mean_wall_secs,
            )
        } else {
            f64::INFINITY
        },
        if tree.mean_wall_secs <= std_rep.mean_wall_secs { "FASTER" } else { "slower" },
        standard_max_n
    ));
    Ok(s)
}

/// §4.1 distributed simulation: model-message counts vs the O(k log k)
/// bound, against the naive data-shipping standard CV.
pub fn distributed_report(n: usize, ks: &[usize], seed: u64) -> Result<String> {
    let data = SyntheticCovertype::new(n, seed).generate();
    let learner = Pegasos::new(data.d, 1e-6);
    let mut s = String::from(
        "Distributed TreeCV simulation (model moves, data stays)\n\
         k, model_msgs, bound_2k_log2k, model_MB, naive_data_MB, sim_net_time_s, \
         naive_net_time_s\n",
    );
    for &k in ks {
        let folds = Folds::new(n, k, seed ^ 0xD157);
        let cluster = Cluster::new(&data, &folds, NetworkModel::default());
        let tree = cluster.treecv(&learner);
        let naive = cluster.standard_naive(&learner);
        let bound = 2.0 * k as f64 * (((2 * k) as f64).log2() + 1.0) + 2.0 * k as f64;
        s.push_str(&format!(
            "{k}, {}, {:.0}, {:.3}, {:.3}, {:.4}, {:.4}\n",
            tree.comm.model_messages,
            bound,
            tree.comm.model_bytes as f64 / 1e6,
            naive.comm.data_bytes as f64 / 1e6,
            tree.comm.sim_network_time_s,
            naive.comm.sim_network_time_s,
        ));
    }
    Ok(s)
}

/// The intro's motivating workload: tune PEGASOS's λ by k-CV over a grid.
/// With TreeCV each grid point costs O(n log k) instead of O(nk).
pub fn grid_search(n: usize, k: usize, log_lambdas: &[f64], seed: u64) -> Result<String> {
    use crate::cv::treecv::TreeCv;
    use crate::cv::standard::StandardCv;
    use crate::cv::CvEngine;
    let data = SyntheticCovertype::new(n, seed).generate();
    let folds = Folds::new(n, k, seed ^ 0x617D);
    let mut s = format!(
        "Grid search over λ (PEGASOS, n={n}, k={k})\n\
         log10(lambda), treecv_estimate, treecv_secs, standard_estimate, standard_secs\n"
    );
    let mut best = (f64::INFINITY, 0.0f64);
    let mut tree_total = 0.0;
    let mut std_total = 0.0;
    for &ll in log_lambdas {
        let lambda = 10f64.powf(ll);
        let learner = Pegasos::new(data.d, lambda);
        let tree = TreeCv::default().run(&learner, &data, &folds);
        let std_res = StandardCv::default().run(&learner, &data, &folds);
        tree_total += tree.wall.as_secs_f64();
        std_total += std_res.wall.as_secs_f64();
        if tree.estimate < best.0 {
            best = (tree.estimate, ll);
        }
        s.push_str(&format!(
            "{ll}, {:.6}, {:.4}, {:.6}, {:.4}\n",
            tree.estimate,
            tree.wall.as_secs_f64(),
            std_res.estimate,
            std_res.wall.as_secs_f64()
        ));
    }
    s.push_str(&format!(
        "best: log10(lambda)={} (estimate {:.6}); grid total: treecv {:.3}s vs standard \
         {:.3}s ({:.2}x)\n",
        best.1,
        best.0,
        tree_total,
        std_total,
        std_total / tree_total.max(1e-12)
    ));
    Ok(s)
}

/// Smoke-test the PJRT runtime and every artifact in the manifest, and
/// cross-check the XLA PEGASOS learner against the pure-Rust one.
pub fn selfcheck() -> Result<()> {
    use crate::runtime::{xla_learner::XlaPegasos, Manifest, PjrtRuntime};
    let rt = PjrtRuntime::cpu()?;
    println!("PJRT platform: {}", rt.platform());
    let manifest = Manifest::load_default()?;
    println!("manifest: {} programs (jax {})", manifest.programs.len(), manifest.jax_version);
    for p in &manifest.programs {
        rt.load(&p.name)?;
        println!("  compiled {} (B={}, d={})", p.name, p.block, p.dim);
    }
    // Cross-check XLA vs Rust PEGASOS on a small run.
    let d = 54;
    let data = SyntheticCovertype::new(512, 7).generate();
    let idx: Vec<u32> = (0..512).collect();
    let xla_l = XlaPegasos::from_manifest(&rt, &manifest, d, 1e-3)?;
    let mut xm = xla_l.init();
    xla_l.update(&mut xm, &data, &idx);
    let rust_l = Pegasos::new(d, 1e-3);
    let mut rm = rust_l.init();
    rust_l.update(&mut rm, &data, &idx);
    let xla_err = xla_l.evaluate(&xm, &data, &idx);
    let rust_err = rust_l.evaluate(&rm, &data, &idx);
    println!("xla pegasos err={xla_err:.6}  rust pegasos err={rust_err:.6}");
    anyhow::ensure!(
        (xla_err - rust_err).abs() < 0.02,
        "XLA and Rust PEGASOS disagree: {xla_err} vs {rust_err}"
    );
    println!("selfcheck OK");
    Ok(())
}

/// Validate the TreeCV LOOCV against the closed-form ridge LOOCV (§1.1
/// comparator); returns (treecv, exact) estimates.
pub fn ridge_exact_comparison(n: usize, d: usize, lambda: f64, seed: u64) -> Result<(f64, f64)> {
    use crate::cv::treecv::TreeCv;
    use crate::cv::CvEngine;
    use crate::learner::ridge::OnlineRidge;
    let full = crate::data::synth::SyntheticYearMsd::new(n, seed).generate();
    let mut x = Vec::with_capacity(n * d);
    for i in 0..n {
        x.extend_from_slice(&full.row(i as u32)[..d]);
    }
    let data = crate::data::Dataset::new(x, full.y.clone(), d);
    let ex = exact::ridge_loocv(&data, lambda);
    let learner = OnlineRidge::new(d, lambda);
    let folds = Folds::loocv(n);
    let tree = TreeCv::default().run(&learner, &data, &folds);
    Ok((tree.estimate, ex.estimate))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_ns_monotone() {
        let ns = default_ns(50_000);
        assert!(ns.windows(2).all(|w| w[0] < w[1]));
        assert_eq!(*ns.last().unwrap(), 50_000);
        assert!(ns.contains(&1_000));
    }

    #[test]
    fn panel_parse() {
        assert_eq!(Panel::parse("fixed").unwrap(), Panel::Fixed);
        assert_eq!(Panel::parse("loocv").unwrap(), Panel::Loocv);
        assert!(Panel::parse("bogus").is_err());
    }

    #[test]
    fn table2_small_smoke() {
        let out = table2(Task::Density, 120, &[4, 0], 2, 3).unwrap();
        // k=4: 4 cells; k=n (LOOCV): TreeCV only → 2 cells.
        assert_eq!(out.cells.len(), 6);
        let render = out.render();
        assert!(render.contains("n=120"));
        assert!(render.contains("N/A"));
    }

    #[test]
    fn figure2_loocv_small_smoke() {
        let out = figure2(Task::Density, Panel::Loocv, &[100, 200], 1, 3).unwrap();
        // 2 ns × (2 treecv + 2 standard) rows.
        assert_eq!(out.rows.len(), 8);
        let csv = out.render_csv();
        assert!(csv.starts_with("series,"));
    }

    #[test]
    fn ridge_exact_comparison_agrees() {
        let (tree, exact) = ridge_exact_comparison(60, 6, 0.5, 9).unwrap();
        assert!((tree - exact).abs() < 1e-6 * (1.0 + exact), "{tree} vs {exact}");
    }

    #[test]
    fn grid_search_smoke() {
        let s = grid_search(300, 5, &[-4.0, -3.0], 11).unwrap();
        assert!(s.contains("best:"));
    }

    #[test]
    fn distributed_report_smoke() {
        let s = distributed_report(256, &[4, 8], 12).unwrap();
        assert!(s.lines().count() >= 4);
    }
}
