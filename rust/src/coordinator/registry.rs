//! The learner registry: ONE table mapping every [`Task`] to its dataset
//! family, its constructor-from-config closure (returning a type-erased
//! learner), its merge-engine support and its sweepable hyperparameter.
//!
//! Before this table existed, reaching a learner from the CLI meant
//! adding copy-pasted `match cfg.task` arms to `run_experiment`,
//! `build_dataset` and `run_sweep` — which is why only a minority of the
//! crate's learners were ever CLI-reachable. Now `repro cv --task <name>`
//! works for every entry here (including the structural oracles and the
//! XLA-backed learners, whose constructors error cleanly when the PJRT
//! runtime or its artifacts are absent), `repro sweep` consults
//! [`LearnerEntry::sweep_param`], and `repro select` builds heterogeneous
//! learner sets from these constructors to rank model families against
//! each other through one executor pool. The constructors feed both
//! sweep schedulers identically — the exhaustive scheduler and the
//! racing one (`repro sweep --race`) build the same learner-per-grid-
//! value set here; only the dispatch downstream differs.
//!
//! A registry test pins the Task ↔ entry bijection, so adding a `Task`
//! variant without a registry row (or vice versa) fails fast.

use super::CellReport;
use crate::config::{ExperimentConfig, Task};
use crate::data::synth::{
    SyntheticBlobs, SyntheticCovertype, SyntheticMixture1d, SyntheticYearMsd,
};
use crate::data::{libsvm, Dataset};
use crate::learner::erased::{Erased, ErasedLearner};
use crate::learner::histdensity::HistogramDensity;
use crate::learner::kmeans::OnlineKMeans;
use crate::learner::knn::KnnClassifier;
use crate::learner::lsqsgd::LsqSgd;
use crate::learner::multiset::MultisetLearner;
use crate::learner::naive_bayes::GaussianNb;
use crate::learner::pegasos::Pegasos;
use crate::learner::perceptron::Perceptron;
use crate::learner::ridge::OnlineRidge;
#[cfg(not(treecv_pjrt))]
use crate::runtime::xla_learner::{XlaLsqSgd, XlaPegasos};
#[cfg(not(treecv_pjrt))]
use crate::runtime::{Manifest, PjrtRuntime};
use crate::Result;
use anyhow::bail;

/// Default neighbour count of the CLI-built k-NN classifier (odd avoids
/// vote ties).
pub const KNN_NEIGHBOURS: usize = 5;

/// Default PEGASOS regularizer when the config carries no `--lambda`
/// (the paper-scale value the CLI has always defaulted to).
pub const PEGASOS_LAMBDA_DEFAULT: f64 = 1e-6;

/// Default ridge regularizer when the config carries no `--lambda` —
/// the value the pre-registry coordinator hardcoded, and the one the
/// exact-LOOCV comparator oracles pin.
pub const RIDGE_LAMBDA_DEFAULT: f64 = 1.0;

/// Default number of clusters for the CLI-built online K-means (matches
/// the synthetic blobs generator's center count).
pub const KMEANS_CENTERS: usize = 5;

/// Which synthetic dataset family a task runs on by default, and how a
/// LIBSVM file given via `--data` is preprocessed for it. Model-selection
/// runs (`repro select`) require all chosen learners to share one kind,
/// so their CV losses are computed on a common dataset.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DatasetKind {
    /// Covertype-like binary classification (binarized labels, unit
    /// feature variance), d = 54.
    Covertype,
    /// YearPredictionMSD-like regression (targets scaled to [0, 1]),
    /// d = 90.
    YearMsd,
    /// Gaussian blobs for clustering, d = 8.
    Blobs,
    /// 1-D Gaussian mixture for density estimation.
    Mixture1d,
}

impl DatasetKind {
    /// Build the dataset: the LIBSVM file from `cfg.data_path` (with this
    /// kind's preprocessing) when given, the synthetic stand-in otherwise.
    pub fn build(&self, cfg: &ExperimentConfig) -> Result<Dataset> {
        if let Some(path) = &cfg.data_path {
            let binarize = matches!(self, DatasetKind::Covertype).then_some(1.0);
            let mut data = libsvm::load(std::path::Path::new(path), None, binarize)?;
            match self {
                DatasetKind::Covertype => {
                    data.scale_to_unit_variance();
                }
                DatasetKind::YearMsd => {
                    data.scale_targets_to_unit_interval();
                }
                DatasetKind::Blobs | DatasetKind::Mixture1d => {}
            }
            let n = cfg.n.min(data.n);
            return Ok(data.take(n));
        }
        Ok(match self {
            DatasetKind::Covertype => SyntheticCovertype::new(cfg.n, cfg.seed).generate(),
            DatasetKind::YearMsd => SyntheticYearMsd::new(cfg.n, cfg.seed).generate(),
            DatasetKind::Blobs => {
                SyntheticBlobs::new(cfg.n, 8, KMEANS_CENTERS, cfg.seed).generate()
            }
            DatasetKind::Mixture1d => SyntheticMixture1d::new(cfg.n, cfg.seed).generate(),
        })
    }
}

/// Constructor-from-config closure: builds the task's learner for the
/// already-built dataset (dimension and size come from `data`).
pub type BuildFn = fn(&ExperimentConfig, &Dataset) -> Result<Box<dyn ErasedLearner>>;

/// Merge-engine dispatcher for learners satisfying Izbicki's mergeability
/// assumption (kept generic — fold merging needs the concrete
/// `MergeableLearner`, which erasure intentionally does not expose).
pub type MergeFn = fn(&ExperimentConfig, &Dataset) -> Result<Vec<CellReport>>;

/// One registry row. See the module docs for what each hook powers.
pub struct LearnerEntry {
    pub task: Task,
    /// Dataset family + preprocessing the task defaults to.
    pub dataset: DatasetKind,
    /// Erased-learner constructor (`repro cv` / `sweep` / `select`).
    pub build: BuildFn,
    /// Hyperparameter the task's sweep may vary, if any.
    pub sweep_param: Option<&'static str>,
    /// Izbicki fold-merging dispatcher, for mergeable learners only.
    pub merge: Option<MergeFn>,
    /// True when `build` needs the PJRT runtime + AOT artifacts; such
    /// entries stay CLI-reachable but error cleanly in stub builds.
    pub requires_runtime: bool,
    /// False for structural test oracles whose "loss" is a correctness
    /// fingerprint, not a statistical metric — they run fine under
    /// `repro cv` but are rejected from `repro select` rankings.
    pub comparable_loss: bool,
}

fn build_pegasos(cfg: &ExperimentConfig, data: &Dataset) -> Result<Box<dyn ErasedLearner>> {
    Ok(Erased::boxed(Pegasos::new(data.d, cfg.lambda.unwrap_or(PEGASOS_LAMBDA_DEFAULT))))
}

fn build_lsqsgd(cfg: &ExperimentConfig, data: &Dataset) -> Result<Box<dyn ErasedLearner>> {
    // The paper sets α from the full-data n; so do we.
    Ok(Erased::boxed(LsqSgd::new(data.d, cfg.effective_alpha(data.n))))
}

fn build_kmeans(_cfg: &ExperimentConfig, data: &Dataset) -> Result<Box<dyn ErasedLearner>> {
    Ok(Erased::boxed(OnlineKMeans::new(data.d, KMEANS_CENTERS)))
}

fn build_density(_cfg: &ExperimentConfig, _data: &Dataset) -> Result<Box<dyn ErasedLearner>> {
    Ok(Erased::boxed(HistogramDensity::new(-8.0, 8.0, 64)))
}

fn build_naive_bayes(_cfg: &ExperimentConfig, data: &Dataset) -> Result<Box<dyn ErasedLearner>> {
    Ok(Erased::boxed(GaussianNb::new(data.d)))
}

fn build_ridge(cfg: &ExperimentConfig, data: &Dataset) -> Result<Box<dyn ErasedLearner>> {
    Ok(Erased::boxed(OnlineRidge::new(data.d, cfg.lambda.unwrap_or(RIDGE_LAMBDA_DEFAULT))))
}

fn build_knn(_cfg: &ExperimentConfig, data: &Dataset) -> Result<Box<dyn ErasedLearner>> {
    Ok(Erased::boxed(KnnClassifier::new(data.d, KNN_NEIGHBOURS)))
}

fn build_perceptron(_cfg: &ExperimentConfig, data: &Dataset) -> Result<Box<dyn ErasedLearner>> {
    Ok(Erased::boxed(Perceptron::new(data.d)))
}

fn build_multiset(_cfg: &ExperimentConfig, data: &Dataset) -> Result<Box<dyn ErasedLearner>> {
    Ok(Erased::boxed(MultisetLearner::new(data.d)))
}

// The XLA builders exist in two flavors. In stub builds (everything CI
// compiles, including plain `--features xla`), the stub runtime types are
// trivially Send + Sync, so `Erased::boxed` compiles and the constructor
// errors cleanly at runtime ("PJRT runtime unavailable"). In REAL
// `cfg(treecv_pjrt)` builds the `xla` crate's executable handles have not
// been vetted Send + Sync (the erased layer's bound, required by the
// pooled engines) — so rather than risk an un-compilable configuration or
// sharing one PJRT executable across worker threads untested, the
// registry path declines with a pointer at the sequential XLA surfaces.
#[cfg(not(treecv_pjrt))]
fn build_xla_pegasos(cfg: &ExperimentConfig, data: &Dataset) -> Result<Box<dyn ErasedLearner>> {
    let rt = PjrtRuntime::cpu()?;
    let manifest = Manifest::load_default()?;
    let lambda = cfg.lambda.unwrap_or(PEGASOS_LAMBDA_DEFAULT);
    Ok(Erased::boxed(XlaPegasos::from_manifest(&rt, &manifest, data.d, lambda)?))
}

#[cfg(not(treecv_pjrt))]
fn build_xla_lsqsgd(cfg: &ExperimentConfig, data: &Dataset) -> Result<Box<dyn ErasedLearner>> {
    let rt = PjrtRuntime::cpu()?;
    let manifest = Manifest::load_default()?;
    let alpha = cfg.effective_alpha(data.n);
    Ok(Erased::boxed(XlaLsqSgd::from_manifest(&rt, &manifest, data.d, alpha)?))
}

#[cfg(treecv_pjrt)]
fn build_xla_pegasos(_cfg: &ExperimentConfig, _data: &Dataset) -> Result<Box<dyn ErasedLearner>> {
    bail!(
        "xla_pegasos is not reachable through the CV registry in real-PJRT builds yet: the \
         PJRT executable types are not vetted Send + Sync for the pooled engines — drive the \
         XLA learners via `repro selfcheck`, the runtime_xla bench, or the sequential runtime \
         integration tests"
    )
}

#[cfg(treecv_pjrt)]
fn build_xla_lsqsgd(_cfg: &ExperimentConfig, _data: &Dataset) -> Result<Box<dyn ErasedLearner>> {
    bail!(
        "xla_lsqsgd is not reachable through the CV registry in real-PJRT builds yet: the \
         PJRT executable types are not vetted Send + Sync for the pooled engines — drive the \
         XLA learners via `repro selfcheck`, the runtime_xla bench, or the sequential runtime \
         integration tests"
    )
}

fn merge_naive_bayes(cfg: &ExperimentConfig, data: &Dataset) -> Result<Vec<CellReport>> {
    super::run_merge_cells(&GaussianNb::new(data.d), data, cfg)
}

fn merge_density(cfg: &ExperimentConfig, data: &Dataset) -> Result<Vec<CellReport>> {
    super::run_merge_cells(&HistogramDensity::new(-8.0, 8.0, 64), data, cfg)
}

fn merge_ridge(cfg: &ExperimentConfig, data: &Dataset) -> Result<Vec<CellReport>> {
    let lambda = cfg.lambda.unwrap_or(RIDGE_LAMBDA_DEFAULT);
    super::run_merge_cells(&OnlineRidge::new(data.d, lambda), data, cfg)
}

fn merge_knn(cfg: &ExperimentConfig, data: &Dataset) -> Result<Vec<CellReport>> {
    super::run_merge_cells(&KnnClassifier::new(data.d, KNN_NEIGHBOURS), data, cfg)
}

/// The registry itself: exactly one row per [`Task`] variant.
pub static REGISTRY: &[LearnerEntry] = &[
    LearnerEntry {
        task: Task::Pegasos,
        dataset: DatasetKind::Covertype,
        build: build_pegasos,
        sweep_param: Some("lambda"),
        merge: None,
        requires_runtime: false,
        comparable_loss: true,
    },
    LearnerEntry {
        task: Task::Lsqsgd,
        dataset: DatasetKind::YearMsd,
        build: build_lsqsgd,
        sweep_param: Some("alpha"),
        merge: None,
        requires_runtime: false,
        comparable_loss: true,
    },
    LearnerEntry {
        task: Task::Kmeans,
        dataset: DatasetKind::Blobs,
        build: build_kmeans,
        sweep_param: None,
        merge: None,
        requires_runtime: false,
        comparable_loss: true,
    },
    LearnerEntry {
        task: Task::Density,
        dataset: DatasetKind::Mixture1d,
        build: build_density,
        sweep_param: None,
        merge: Some(merge_density),
        requires_runtime: false,
        comparable_loss: true,
    },
    LearnerEntry {
        task: Task::NaiveBayes,
        dataset: DatasetKind::Covertype,
        build: build_naive_bayes,
        sweep_param: None,
        merge: Some(merge_naive_bayes),
        requires_runtime: false,
        comparable_loss: true,
    },
    LearnerEntry {
        task: Task::Ridge,
        dataset: DatasetKind::YearMsd,
        build: build_ridge,
        sweep_param: Some("lambda"),
        merge: Some(merge_ridge),
        requires_runtime: false,
        comparable_loss: true,
    },
    LearnerEntry {
        task: Task::Knn,
        dataset: DatasetKind::Covertype,
        build: build_knn,
        sweep_param: None,
        merge: Some(merge_knn),
        requires_runtime: false,
        comparable_loss: true,
    },
    LearnerEntry {
        task: Task::Perceptron,
        dataset: DatasetKind::Covertype,
        build: build_perceptron,
        sweep_param: None,
        merge: None,
        requires_runtime: false,
        comparable_loss: true,
    },
    LearnerEntry {
        task: Task::Multiset,
        dataset: DatasetKind::Mixture1d,
        build: build_multiset,
        sweep_param: None,
        merge: None,
        requires_runtime: false,
        // The "loss" is a hash fingerprint of the training multiset — a
        // correctness probe, never a rankable metric.
        comparable_loss: false,
    },
    LearnerEntry {
        task: Task::XlaPegasos,
        dataset: DatasetKind::Covertype,
        build: build_xla_pegasos,
        sweep_param: Some("lambda"),
        merge: None,
        requires_runtime: true,
        comparable_loss: true,
    },
    LearnerEntry {
        task: Task::XlaLsqSgd,
        dataset: DatasetKind::YearMsd,
        build: build_xla_lsqsgd,
        sweep_param: Some("alpha"),
        merge: None,
        requires_runtime: true,
        comparable_loss: true,
    },
];

/// Look up a task's registry row. Total over [`Task`] — the bijection is
/// pinned by a test, so a missing row is a programmer error.
pub fn entry(task: Task) -> &'static LearnerEntry {
    REGISTRY
        .iter()
        .find(|e| e.task == task)
        .unwrap_or_else(|| panic!("no registry entry for task {task:?}"))
}

/// Apply a named hyperparameter value to a config. Valid names come from
/// [`LearnerEntry::sweep_param`]; callers go through
/// [`checked_apply_param`], which validates name and domain first.
fn apply_param(cfg: &mut ExperimentConfig, param: &str, value: f64) -> Result<()> {
    match param {
        "lambda" => cfg.lambda = Some(value),
        "alpha" => cfg.alpha = value,
        other => bail!("unknown hyperparameter `{other}` (expected lambda or alpha)"),
    }
    Ok(())
}

/// THE per-task hyperparameter-override validation, shared by the sweep
/// grid (`coordinator::run_sweep`, one call per grid value) and the
/// select list (`coordinator::run_select`, one call per `task:param=v`
/// entry), so the two CLIs cannot drift in which overrides they accept:
/// the task must declare the parameter ([`LearnerEntry::sweep_param`])
/// and the value must be positive (learner constructors assert
/// positivity — reject here with a clean error instead of panicking
/// inside a builder).
pub fn checked_apply_param(
    cfg: &mut ExperimentConfig,
    task: Task,
    param: &str,
    value: f64,
) -> Result<()> {
    match entry(task).sweep_param {
        None => {
            // Derive the hint from the registry so it can never trail it.
            let tunable: Vec<String> = REGISTRY
                .iter()
                .filter_map(|e| e.sweep_param.map(|p| format!("{} tunes {p}", e.task.name())))
                .collect();
            bail!(
                "task {} has no tunable hyperparameter (got `{param}`; {})",
                task.name(),
                tunable.join(", ")
            );
        }
        Some(want) if want != param => bail!(
            "task {} tunes `{want}`, not `{param}`",
            task.name()
        ),
        Some(_) if value <= 0.0 => bail!(
            "task {}: {param} must be > 0, got {value}",
            task.name()
        ),
        Some(_) => apply_param(cfg, param, value),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_is_a_bijection_over_tasks() {
        assert_eq!(REGISTRY.len(), Task::all().len());
        for &task in Task::all() {
            let e = entry(task);
            assert_eq!(e.task, task);
        }
        // No duplicate rows.
        for (i, a) in REGISTRY.iter().enumerate() {
            for b in &REGISTRY[i + 1..] {
                assert_ne!(a.task, b.task);
            }
        }
    }

    #[test]
    fn builders_construct_for_every_runtime_free_task() {
        let cfg = ExperimentConfig { n: 60, ..ExperimentConfig::default() };
        for e in REGISTRY.iter().filter(|e| !e.requires_runtime) {
            let data = e.dataset.build(&cfg).unwrap();
            let learner = (e.build)(&cfg, &data).unwrap();
            assert!(!learner.name().is_empty(), "{:?}", e.task);
            assert_eq!(learner.dim(), data.d, "{:?}", e.task);
            // The built learner runs: init + one update + one evaluate.
            let mut m = learner.init();
            learner.update(&mut m, &data, &(0..50).collect::<Vec<_>>());
            assert!(learner.evaluate(&m, &data, &[50, 51]).is_finite(), "{:?}", e.task);
        }
    }

    #[test]
    fn runtime_tasks_error_cleanly_without_pjrt() {
        let cfg = ExperimentConfig { n: 40, ..ExperimentConfig::default() };
        for e in REGISTRY.iter().filter(|e| e.requires_runtime) {
            let data = e.dataset.build(&cfg).unwrap();
            match (e.build)(&cfg, &data) {
                // Real runtime present (artifact-equipped environment).
                Ok(_) => {}
                Err(err) => {
                    let msg = format!("{err}");
                    assert!(
                        msg.contains("xla") || msg.contains("artifact") || msg.contains("manifest"),
                        "{:?}: unexpected error `{msg}`",
                        e.task
                    );
                }
            }
        }
    }

    #[test]
    fn apply_param_sets_known_names_only() {
        let mut cfg = ExperimentConfig::default();
        apply_param(&mut cfg, "lambda", 0.25).unwrap();
        assert_eq!(cfg.lambda, Some(0.25));
        apply_param(&mut cfg, "alpha", 0.5).unwrap();
        assert_eq!(cfg.alpha, 0.5);
        assert!(apply_param(&mut cfg, "gamma", 1.0).is_err());
    }

    #[test]
    fn checked_apply_param_enforces_name_and_domain() {
        let mut cfg = ExperimentConfig::default();
        checked_apply_param(&mut cfg, Task::Ridge, "lambda", 0.5).unwrap();
        assert_eq!(cfg.lambda, Some(0.5));
        // Task without a tunable parameter.
        assert!(checked_apply_param(&mut cfg, Task::Knn, "lambda", 0.5).is_err());
        // Wrong parameter name for the task.
        assert!(checked_apply_param(&mut cfg, Task::Lsqsgd, "lambda", 0.5).is_err());
        // Non-positive values are a clean error, never a constructor panic.
        let err = checked_apply_param(&mut cfg, Task::Pegasos, "lambda", 0.0).unwrap_err();
        assert!(format!("{err}").contains("must be > 0"), "{err}");
        assert!(checked_apply_param(&mut cfg, Task::Lsqsgd, "alpha", -0.1).is_err());
    }
}
