//! Experiment orchestration: turns an [`ExperimentConfig`] into datasets,
//! learners and engine runs, and collects Table-2-style cell reports.
//! This is the layer the CLI (`rust/src/main.rs`), the examples and the
//! benches all drive, so every experiment in EXPERIMENTS.md is a function
//! call away. All parallel engine selections dispatch through the pooled
//! work-stealing executor ([`crate::cv::executor::TreeCvExecutor`]) via
//! the repetition harness.

pub mod paper;

use crate::config::{Engine, ExperimentConfig, Task};
use crate::cv::folds::{Folds, Ordering};
use crate::cv::mergecv::MergeCv;
use crate::cv::stats::{run_repetitions, EngineKind, RepetitionResult, RepetitionSpec};
use crate::cv::Strategy;
use crate::data::synth::{
    SyntheticBlobs, SyntheticCovertype, SyntheticMixture1d, SyntheticYearMsd,
};
use crate::data::{libsvm, Dataset};
use crate::learner::histdensity::HistogramDensity;
use crate::learner::kmeans::OnlineKMeans;
use crate::learner::lsqsgd::LsqSgd;
use crate::learner::naive_bayes::GaussianNb;
use crate::learner::pegasos::Pegasos;
use crate::learner::ridge::OnlineRidge;
use crate::learner::{IncrementalLearner, MergeableLearner};
use crate::metrics::OpCounts;
use crate::Result;
use anyhow::bail;

/// One (task, engine, k) cell of results.
#[derive(Debug, Clone)]
pub struct CellReport {
    pub task: Task,
    pub engine: Engine,
    /// Effective fold count (LOOCV is reported as n).
    pub k: usize,
    pub n: usize,
    pub repetitions: usize,
    pub mean: f64,
    pub std: f64,
    pub mean_wall_secs: f64,
    pub ops: OpCounts,
}

impl CellReport {
    fn from_rep(task: Task, engine: Engine, n: usize, rep: &RepetitionResult) -> Self {
        Self {
            task,
            engine,
            k: rep.spec.k,
            n,
            repetitions: rep.spec.repetitions,
            mean: rep.mean,
            std: rep.std,
            mean_wall_secs: rep.mean_wall_secs,
            ops: rep.ops.clone(),
        }
    }
}

/// Build the dataset for a task (synthetic unless `data_path` is given).
pub fn build_dataset(cfg: &ExperimentConfig) -> Result<Dataset> {
    if let Some(path) = &cfg.data_path {
        let binarize = matches!(cfg.task, Task::Pegasos | Task::NaiveBayes).then_some(1.0);
        let mut data = libsvm::load(std::path::Path::new(path), None, binarize)?;
        match cfg.task {
            Task::Pegasos | Task::NaiveBayes => {
                data.scale_to_unit_variance();
            }
            Task::Lsqsgd | Task::Ridge => {
                data.scale_targets_to_unit_interval();
            }
            _ => {}
        }
        let n = cfg.n.min(data.n);
        return Ok(data.take(n));
    }
    Ok(match cfg.task {
        Task::Pegasos | Task::NaiveBayes => SyntheticCovertype::new(cfg.n, cfg.seed).generate(),
        Task::Lsqsgd | Task::Ridge => SyntheticYearMsd::new(cfg.n, cfg.seed).generate(),
        Task::Kmeans => SyntheticBlobs::new(cfg.n, 8, 5, cfg.seed).generate(),
        Task::Density => SyntheticMixture1d::new(cfg.n, cfg.seed).generate(),
    })
}

fn engine_kind(engine: Engine) -> Result<EngineKind> {
    Ok(match engine {
        Engine::Treecv => EngineKind::TreeCv,
        Engine::Standard => EngineKind::Standard,
        Engine::ParallelTreecv => EngineKind::ParallelTreeCv,
        Engine::Merge => bail!("merge engine is dispatched separately"),
    })
}

fn run_cells<L>(learner: &L, data: &Dataset, cfg: &ExperimentConfig) -> Result<Vec<CellReport>>
where
    L: IncrementalLearner + Sync,
    L::Model: Send,
{
    let mut out = Vec::new();
    for &k_raw in &cfg.ks {
        let k = if k_raw == 0 { data.n } else { k_raw };
        if k > data.n {
            bail!("k = {k} exceeds n = {}", data.n);
        }
        let spec = RepetitionSpec {
            engine: engine_kind(cfg.engine)?,
            ordering: Ordering::from(cfg.ordering),
            strategy: Strategy::from(cfg.strategy),
            k,
            repetitions: cfg.repetitions,
            seed: cfg.seed,
        };
        let rep = run_repetitions(learner, data, &spec)?;
        out.push(CellReport::from_rep(cfg.task, cfg.engine, data.n, &rep));
    }
    Ok(out)
}

fn run_merge_cells<L: MergeableLearner>(
    learner: &L,
    data: &Dataset,
    cfg: &ExperimentConfig,
) -> Result<Vec<CellReport>> {
    if cfg.strategy == crate::config::StrategyCfg::SaveRevert {
        bail!(
            "engine `merge` cannot honor the save/revert strategy (Izbicki-style fold merging \
             never updates a model in place); refusing to silently run Copy instead"
        );
    }
    let mut out = Vec::new();
    for &k_raw in &cfg.ks {
        let k = if k_raw == 0 { data.n } else { k_raw };
        if k > data.n {
            bail!("k = {k} exceeds n = {}", data.n);
        }
        let mut stats = crate::metrics::RunningStats::default();
        let mut wall = std::time::Duration::ZERO;
        let mut ops = OpCounts::default();
        for r in 0..cfg.repetitions {
            let rep_seed = cfg.seed.wrapping_add(r as u64).wrapping_mul(0x9E3779B97F4A7C15);
            let folds = Folds::new(data.n, k, rep_seed);
            let res = MergeCv.run(learner, data, &folds);
            stats.push(res.estimate);
            wall += res.wall;
            ops = res.ops;
        }
        out.push(CellReport {
            task: cfg.task,
            engine: Engine::Merge,
            k,
            n: data.n,
            repetitions: cfg.repetitions,
            mean: stats.mean(),
            std: stats.std(),
            mean_wall_secs: wall.as_secs_f64() / cfg.repetitions.max(1) as f64,
            ops,
        });
    }
    Ok(out)
}

/// Run the experiment described by `cfg` and return one report per k.
pub fn run_experiment(cfg: &ExperimentConfig) -> Result<Vec<CellReport>> {
    let data = build_dataset(cfg)?;
    let d = data.d;
    // The paper sets α from the full-data n; we do the same.
    let alpha = cfg.effective_alpha(data.n);

    if cfg.engine == Engine::Merge {
        return match cfg.task {
            Task::NaiveBayes => run_merge_cells(&GaussianNb::new(d), &data, cfg),
            Task::Density => run_merge_cells(&HistogramDensity::new(-8.0, 8.0, 64), &data, cfg),
            Task::Ridge => run_merge_cells(&OnlineRidge::new(d, 1.0), &data, cfg),
            t => bail!("task {t:?} is not mergeable (Izbicki's assumption does not hold)"),
        };
    }

    match cfg.task {
        Task::Pegasos => run_cells(&Pegasos::new(d, cfg.lambda), &data, cfg),
        Task::Lsqsgd => run_cells(&LsqSgd::new(d, alpha), &data, cfg),
        Task::Kmeans => run_cells(&OnlineKMeans::new(d, 5), &data, cfg),
        Task::Density => run_cells(&HistogramDensity::new(-8.0, 8.0, 64), &data, cfg),
        Task::NaiveBayes => run_cells(&GaussianNb::new(d), &data, cfg),
        Task::Ridge => run_cells(&OnlineRidge::new(d, 1.0), &data, cfg),
    }
}

/// Pretty-print reports as an aligned text table (the CLI's default output).
pub fn format_table(reports: &[CellReport]) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "{:<12} {:<16} {:>8} {:>9} {:>5} {:>12} {:>12} {:>12} {:>14}\n",
        "task", "engine", "k", "n", "reps", "mean", "std", "wall(s)", "pts_updated"
    ));
    for r in reports {
        s.push_str(&format!(
            "{:<12} {:<16} {:>8} {:>9} {:>5} {:>12.6} {:>12.6} {:>12.4} {:>14}\n",
            r.task.name(),
            r.engine.name(),
            r.k,
            r.n,
            r.repetitions,
            r.mean,
            r.std,
            r.mean_wall_secs,
            r.ops.points_updated,
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{OrderingCfg, StrategyCfg};

    fn tiny_cfg(task: Task, engine: Engine) -> ExperimentConfig {
        ExperimentConfig {
            task,
            engine,
            ordering: OrderingCfg::Fixed,
            strategy: StrategyCfg::Copy,
            n: 200,
            ks: vec![5],
            repetitions: 3,
            seed: 1,
            lambda: 1e-4,
            alpha: 0.0,
            data_path: None,
            out: None,
        }
    }

    #[test]
    fn runs_every_task_with_treecv() {
        for &task in Task::all() {
            let cfg = tiny_cfg(task, Engine::Treecv);
            let reports = run_experiment(&cfg).unwrap();
            assert_eq!(reports.len(), 1, "{task:?}");
            assert!(reports[0].mean.is_finite(), "{task:?}");
        }
    }

    #[test]
    fn loocv_k_zero_expands_to_n() {
        let mut cfg = tiny_cfg(Task::Density, Engine::Treecv);
        cfg.ks = vec![0];
        cfg.repetitions = 1;
        let reports = run_experiment(&cfg).unwrap();
        assert_eq!(reports[0].k, 200);
    }

    #[test]
    fn merge_engine_rejects_nonmergeable() {
        let cfg = tiny_cfg(Task::Pegasos, Engine::Merge);
        assert!(run_experiment(&cfg).is_err());
    }

    #[test]
    fn save_revert_honored_by_treecv_and_parallel_engines() {
        for engine in [Engine::Treecv, Engine::ParallelTreecv] {
            let mut cfg = tiny_cfg(Task::Density, engine);
            cfg.strategy = StrategyCfg::SaveRevert;
            let reports = run_experiment(&cfg).unwrap();
            assert!(reports[0].mean.is_finite(), "{engine:?}");
            // The strategy actually ran: interior nodes account as one
            // fork snapshot OR two restores each (k − 1 = 4 interior).
            let ops = &reports[0].ops;
            assert_eq!(2 * ops.model_copies + ops.model_restores, 8, "{engine:?}");
        }
    }

    #[test]
    fn save_revert_on_standard_or_merge_is_a_hard_error() {
        let mut cfg = tiny_cfg(Task::Density, Engine::Standard);
        cfg.strategy = StrategyCfg::SaveRevert;
        let err = run_experiment(&cfg).unwrap_err();
        assert!(format!("{err}").contains("save/revert"), "{err}");

        let mut cfg = tiny_cfg(Task::Density, Engine::Merge);
        cfg.strategy = StrategyCfg::SaveRevert;
        let err = run_experiment(&cfg).unwrap_err();
        assert!(format!("{err}").contains("save/revert"), "{err}");
    }

    #[test]
    fn merge_engine_works_for_naive_bayes() {
        let cfg = tiny_cfg(Task::NaiveBayes, Engine::Merge);
        let reports = run_experiment(&cfg).unwrap();
        assert!(reports[0].mean.is_finite());
        assert_eq!(reports[0].ops.points_updated, 200);
    }

    #[test]
    fn oversized_k_is_an_error() {
        let mut cfg = tiny_cfg(Task::Pegasos, Engine::Treecv);
        cfg.ks = vec![9999];
        assert!(run_experiment(&cfg).is_err());
    }

    #[test]
    fn table_formatting_contains_rows() {
        let cfg = tiny_cfg(Task::Pegasos, Engine::Treecv);
        let reports = run_experiment(&cfg).unwrap();
        let table = format_table(&reports);
        assert!(table.contains("pegasos"));
        assert!(table.contains("treecv"));
    }
}
