//! Experiment orchestration: turns an [`ExperimentConfig`] into datasets,
//! learners and engine runs, and collects Table-2-style cell reports.
//! This is the layer the CLI (`rust/src/main.rs`), the examples and the
//! benches all drive, so every experiment in EXPERIMENTS.md is a function
//! call away. All parallel engine selections dispatch through the pooled
//! work-stealing executor ([`crate::cv::executor::TreeCvExecutor`]) via
//! the repetition harness.

pub mod paper;

use crate::config::{Engine, ExperimentConfig, StrategyCfg, Task};
use crate::cv::folds::{Folds, Ordering};
use crate::cv::mergecv::MergeCv;
use crate::cv::stats::{run_repetitions, EngineKind, RepetitionResult, RepetitionSpec};
use crate::cv::sweep::{self, SweepOutcome, SweepSpec};
use crate::cv::Strategy;
use crate::data::synth::{
    SyntheticBlobs, SyntheticCovertype, SyntheticMixture1d, SyntheticYearMsd,
};
use crate::data::{libsvm, Dataset};
use crate::learner::histdensity::HistogramDensity;
use crate::learner::kmeans::OnlineKMeans;
use crate::learner::lsqsgd::LsqSgd;
use crate::learner::naive_bayes::GaussianNb;
use crate::learner::pegasos::Pegasos;
use crate::learner::ridge::OnlineRidge;
use crate::learner::{IncrementalLearner, MergeableLearner};
use crate::metrics::OpCounts;
use crate::Result;
use anyhow::bail;

/// One (task, engine, k) cell of results.
#[derive(Debug, Clone)]
pub struct CellReport {
    pub task: Task,
    pub engine: Engine,
    /// Effective fold count (LOOCV is reported as n).
    pub k: usize,
    pub n: usize,
    pub repetitions: usize,
    pub mean: f64,
    pub std: f64,
    pub mean_wall_secs: f64,
    pub ops: OpCounts,
}

impl CellReport {
    fn from_rep(task: Task, engine: Engine, n: usize, rep: &RepetitionResult) -> Self {
        Self {
            task,
            engine,
            k: rep.spec.k,
            n,
            repetitions: rep.spec.repetitions,
            mean: rep.mean,
            std: rep.std,
            mean_wall_secs: rep.mean_wall_secs,
            ops: rep.ops.clone(),
        }
    }
}

/// Build the dataset for a task (synthetic unless `data_path` is given).
pub fn build_dataset(cfg: &ExperimentConfig) -> Result<Dataset> {
    if let Some(path) = &cfg.data_path {
        let binarize = matches!(cfg.task, Task::Pegasos | Task::NaiveBayes).then_some(1.0);
        let mut data = libsvm::load(std::path::Path::new(path), None, binarize)?;
        match cfg.task {
            Task::Pegasos | Task::NaiveBayes => {
                data.scale_to_unit_variance();
            }
            Task::Lsqsgd | Task::Ridge => {
                data.scale_targets_to_unit_interval();
            }
            _ => {}
        }
        let n = cfg.n.min(data.n);
        return Ok(data.take(n));
    }
    Ok(match cfg.task {
        Task::Pegasos | Task::NaiveBayes => SyntheticCovertype::new(cfg.n, cfg.seed).generate(),
        Task::Lsqsgd | Task::Ridge => SyntheticYearMsd::new(cfg.n, cfg.seed).generate(),
        Task::Kmeans => SyntheticBlobs::new(cfg.n, 8, 5, cfg.seed).generate(),
        Task::Density => SyntheticMixture1d::new(cfg.n, cfg.seed).generate(),
    })
}

fn engine_kind(engine: Engine) -> Result<EngineKind> {
    Ok(match engine {
        Engine::Treecv => EngineKind::TreeCv,
        Engine::Standard => EngineKind::Standard,
        Engine::ParallelTreecv => EngineKind::ParallelTreeCv,
        Engine::Merge => bail!("merge engine is dispatched separately"),
    })
}

fn run_cells<L>(learner: &L, data: &Dataset, cfg: &ExperimentConfig) -> Result<Vec<CellReport>>
where
    L: IncrementalLearner + Sync,
    L::Model: Send,
{
    let mut out = Vec::new();
    for &k_raw in &cfg.ks {
        let k = if k_raw == 0 { data.n } else { k_raw };
        if k > data.n {
            bail!("k = {k} exceeds n = {}", data.n);
        }
        let spec = RepetitionSpec {
            engine: engine_kind(cfg.engine)?,
            ordering: Ordering::from(cfg.ordering),
            strategy: Strategy::from(cfg.strategy),
            k,
            repetitions: cfg.repetitions,
            seed: cfg.seed,
            threads: cfg.threads,
        };
        let rep = run_repetitions(learner, data, &spec)?;
        out.push(CellReport::from_rep(cfg.task, cfg.engine, data.n, &rep));
    }
    Ok(out)
}

fn run_merge_cells<L: MergeableLearner>(
    learner: &L,
    data: &Dataset,
    cfg: &ExperimentConfig,
) -> Result<Vec<CellReport>> {
    if cfg.strategy == crate::config::StrategyCfg::SaveRevert {
        bail!(
            "engine `merge` cannot honor the save/revert strategy (Izbicki-style fold merging \
             never updates a model in place); refusing to silently run Copy instead"
        );
    }
    let mut out = Vec::new();
    for &k_raw in &cfg.ks {
        let k = if k_raw == 0 { data.n } else { k_raw };
        if k > data.n {
            bail!("k = {k} exceeds n = {}", data.n);
        }
        let mut stats = crate::metrics::RunningStats::default();
        let mut wall = std::time::Duration::ZERO;
        let mut ops = OpCounts::default();
        for r in 0..cfg.repetitions {
            let rep_seed = cfg.seed.wrapping_add(r as u64).wrapping_mul(0x9E3779B97F4A7C15);
            let folds = Folds::new(data.n, k, rep_seed);
            let res = MergeCv.run(learner, data, &folds);
            stats.push(res.estimate);
            wall += res.wall;
            ops = res.ops;
        }
        out.push(CellReport {
            task: cfg.task,
            engine: Engine::Merge,
            k,
            n: data.n,
            repetitions: cfg.repetitions,
            mean: stats.mean(),
            std: stats.std(),
            mean_wall_secs: wall.as_secs_f64() / cfg.repetitions.max(1) as f64,
            ops,
        });
    }
    Ok(out)
}

/// Run the experiment described by `cfg` and return one report per k.
pub fn run_experiment(cfg: &ExperimentConfig) -> Result<Vec<CellReport>> {
    let data = build_dataset(cfg)?;
    let d = data.d;
    // The paper sets α from the full-data n; we do the same.
    let alpha = cfg.effective_alpha(data.n);

    if cfg.engine == Engine::Merge {
        return match cfg.task {
            Task::NaiveBayes => run_merge_cells(&GaussianNb::new(d), &data, cfg),
            Task::Density => run_merge_cells(&HistogramDensity::new(-8.0, 8.0, 64), &data, cfg),
            Task::Ridge => run_merge_cells(&OnlineRidge::new(d, 1.0), &data, cfg),
            t => bail!("task {t:?} is not mergeable (Izbicki's assumption does not hold)"),
        };
    }

    match cfg.task {
        Task::Pegasos => run_cells(&Pegasos::new(d, cfg.lambda), &data, cfg),
        Task::Lsqsgd => run_cells(&LsqSgd::new(d, alpha), &data, cfg),
        Task::Kmeans => run_cells(&OnlineKMeans::new(d, 5), &data, cfg),
        Task::Density => run_cells(&HistogramDensity::new(-8.0, 8.0, 64), &data, cfg),
        Task::NaiveBayes => run_cells(&GaussianNb::new(d), &data, cfg),
        Task::Ridge => run_cells(&OnlineRidge::new(d, 1.0), &data, cfg),
    }
}

/// One ranked row of a sweep: a (hyperparameter value, strategy) cell.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    /// Swept parameter name (`lambda` / `alpha`).
    pub param: String,
    pub value: f64,
    pub strategy: StrategyCfg,
    /// Mean CV estimate over the repetitions (the ranking key).
    pub mean: f64,
    /// Sample std over the repetitions.
    pub std: f64,
    /// Counters from the cell's last repetition.
    pub ops: OpCounts,
}

/// Result of `repro sweep`: one row per grid point, ranked by mean loss
/// (best first), plus the pooled-execution accounting.
#[derive(Debug, Clone)]
pub struct SweepReport {
    pub task: Task,
    pub n: usize,
    pub k: usize,
    pub repetitions: usize,
    /// Worker-pool size the sweep actually used.
    pub threads: usize,
    /// Executor pools spawned by the whole sweep — 1 for a multi-worker
    /// pool, 0 for `--threads 1` (inline), never one per run.
    pub pool_spawns: u64,
    /// Wall-clock of the whole pooled batch (runs overlap, so there is no
    /// meaningful per-row wall).
    pub total_wall_secs: f64,
    /// Rows ranked by mean loss ascending.
    pub points: Vec<SweepPoint>,
}

/// The hyperparameter a task's sweep may vary, or None if the task has no
/// sweepable knob.
fn sweepable_param(task: Task) -> Option<&'static str> {
    match task {
        Task::Pegasos | Task::Ridge => Some("lambda"),
        Task::Lsqsgd => Some("alpha"),
        Task::Kmeans | Task::Density | Task::NaiveBayes => None,
    }
}

/// Run the tuning workload described by `cfg`: every (grid value ×
/// repetition) TreeCV run through ONE pooled executor
/// ([`crate::cv::sweep::run_sweep`]), returning rows ranked by mean loss.
/// Fold assignments are shared across grid values, so the hyperparameter
/// is the only difference between rows.
pub fn run_sweep(cfg: &ExperimentConfig) -> Result<SweepReport> {
    let Some(grid) = &cfg.sweep else {
        bail!("sweep needs a grid — pass --sweep name=v1,v2,... (e.g. lambda=0.1,0.01,0.001)");
    };
    if cfg.ks.len() != 1 {
        bail!("sweep uses a single fold count; got ks = {:?}", cfg.ks);
    }
    match sweepable_param(cfg.task) {
        None => bail!(
            "task {} has no sweepable hyperparameter (pegasos/ridge sweep lambda=..., \
             lsqsgd sweeps alpha=...)",
            cfg.task.name()
        ),
        Some(want) if want != grid.param => bail!(
            "task {} sweeps `{want}`, not `{}`",
            cfg.task.name(),
            grid.param
        ),
        Some(_) => {}
    }
    if let Some(v) = grid.values.iter().find(|&&v| v <= 0.0) {
        bail!("sweep {}: values must be > 0, got {v}", grid.param);
    }

    let data = build_dataset(cfg)?;
    let k = if cfg.ks[0] == 0 { data.n } else { cfg.ks[0] };
    if k > data.n {
        bail!("k = {k} exceeds n = {}", data.n);
    }
    let d = data.d;
    let spec = SweepSpec {
        ordering: Ordering::from(cfg.ordering),
        strategies: vec![Strategy::from(cfg.strategy)],
        k,
        repetitions: cfg.repetitions,
        seed: cfg.seed,
        threads: cfg.threads,
    };
    let outcome: SweepOutcome = match cfg.task {
        Task::Pegasos => {
            let learners: Vec<Pegasos> = grid.values.iter().map(|&v| Pegasos::new(d, v)).collect();
            sweep::run_sweep(&learners, &data, &spec)?
        }
        Task::Ridge => {
            let learners: Vec<OnlineRidge> =
                grid.values.iter().map(|&v| OnlineRidge::new(d, v)).collect();
            sweep::run_sweep(&learners, &data, &spec)?
        }
        Task::Lsqsgd => {
            let learners: Vec<LsqSgd> = grid.values.iter().map(|&v| LsqSgd::new(d, v)).collect();
            sweep::run_sweep(&learners, &data, &spec)?
        }
        _ => unreachable!("rejected by sweepable_param above"),
    };

    let mut points: Vec<SweepPoint> = outcome
        .cells
        .iter()
        .map(|c| SweepPoint {
            param: grid.param.clone(),
            value: grid.values[c.config],
            strategy: StrategyCfg::from(c.strategy),
            mean: c.mean,
            std: c.std,
            ops: c.ops.clone(),
        })
        .collect();
    points.sort_by(|a, b| a.mean.total_cmp(&b.mean).then(a.value.total_cmp(&b.value)));
    Ok(SweepReport {
        task: cfg.task,
        n: data.n,
        k,
        repetitions: cfg.repetitions,
        threads: outcome.threads,
        pool_spawns: outcome.pool_spawns,
        total_wall_secs: outcome.total_wall.as_secs_f64(),
        points,
    })
}

/// Pretty-print a sweep as its ranked table (the `sweep` CLI's default
/// output; the schema is documented in EXPERIMENTS.md).
pub fn format_sweep_table(report: &SweepReport) -> String {
    let mut s = format!(
        "sweep task={} n={} k={} reps={} threads={} pool_spawns={} total_wall={:.4}s\n",
        report.task.name(),
        report.n,
        report.k,
        report.repetitions,
        report.threads,
        report.pool_spawns,
        report.total_wall_secs,
    );
    s.push_str(&format!(
        "{:>4} {:>10} {:>14} {:>12} {:>12} {:>12} {:>14}\n",
        "rank", "param", "value", "strategy", "mean", "std", "pts_updated"
    ));
    for (i, p) in report.points.iter().enumerate() {
        s.push_str(&format!(
            "{:>4} {:>10} {:>14e} {:>12} {:>12.6} {:>12.6} {:>14}\n",
            i + 1,
            p.param,
            p.value,
            p.strategy.name(),
            p.mean,
            p.std,
            p.ops.points_updated,
        ));
    }
    s
}

/// Pretty-print reports as an aligned text table (the CLI's default output).
pub fn format_table(reports: &[CellReport]) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "{:<12} {:<16} {:>8} {:>9} {:>5} {:>12} {:>12} {:>12} {:>14}\n",
        "task", "engine", "k", "n", "reps", "mean", "std", "wall(s)", "pts_updated"
    ));
    for r in reports {
        s.push_str(&format!(
            "{:<12} {:<16} {:>8} {:>9} {:>5} {:>12.6} {:>12.6} {:>12.4} {:>14}\n",
            r.task.name(),
            r.engine.name(),
            r.k,
            r.n,
            r.repetitions,
            r.mean,
            r.std,
            r.mean_wall_secs,
            r.ops.points_updated,
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{OrderingCfg, StrategyCfg};

    fn tiny_cfg(task: Task, engine: Engine) -> ExperimentConfig {
        ExperimentConfig {
            task,
            engine,
            ordering: OrderingCfg::Fixed,
            strategy: StrategyCfg::Copy,
            n: 200,
            ks: vec![5],
            repetitions: 3,
            seed: 1,
            lambda: 1e-4,
            alpha: 0.0,
            data_path: None,
            out: None,
            sweep: None,
            threads: 0,
        }
    }

    #[test]
    fn runs_every_task_with_treecv() {
        for &task in Task::all() {
            let cfg = tiny_cfg(task, Engine::Treecv);
            let reports = run_experiment(&cfg).unwrap();
            assert_eq!(reports.len(), 1, "{task:?}");
            assert!(reports[0].mean.is_finite(), "{task:?}");
        }
    }

    #[test]
    fn loocv_k_zero_expands_to_n() {
        let mut cfg = tiny_cfg(Task::Density, Engine::Treecv);
        cfg.ks = vec![0];
        cfg.repetitions = 1;
        let reports = run_experiment(&cfg).unwrap();
        assert_eq!(reports[0].k, 200);
    }

    #[test]
    fn merge_engine_rejects_nonmergeable() {
        let cfg = tiny_cfg(Task::Pegasos, Engine::Merge);
        assert!(run_experiment(&cfg).is_err());
    }

    #[test]
    fn save_revert_honored_by_treecv_and_parallel_engines() {
        for engine in [Engine::Treecv, Engine::ParallelTreecv] {
            let mut cfg = tiny_cfg(Task::Density, engine);
            cfg.strategy = StrategyCfg::SaveRevert;
            let reports = run_experiment(&cfg).unwrap();
            assert!(reports[0].mean.is_finite(), "{engine:?}");
            // The strategy actually ran: interior nodes account as one
            // fork snapshot OR two restores each (k − 1 = 4 interior).
            let ops = &reports[0].ops;
            assert_eq!(2 * ops.model_copies + ops.model_restores, 8, "{engine:?}");
        }
    }

    #[test]
    fn save_revert_on_standard_or_merge_is_a_hard_error() {
        let mut cfg = tiny_cfg(Task::Density, Engine::Standard);
        cfg.strategy = StrategyCfg::SaveRevert;
        let err = run_experiment(&cfg).unwrap_err();
        assert!(format!("{err}").contains("save/revert"), "{err}");

        let mut cfg = tiny_cfg(Task::Density, Engine::Merge);
        cfg.strategy = StrategyCfg::SaveRevert;
        let err = run_experiment(&cfg).unwrap_err();
        assert!(format!("{err}").contains("save/revert"), "{err}");
    }

    #[test]
    fn merge_engine_works_for_naive_bayes() {
        let cfg = tiny_cfg(Task::NaiveBayes, Engine::Merge);
        let reports = run_experiment(&cfg).unwrap();
        assert!(reports[0].mean.is_finite());
        assert_eq!(reports[0].ops.points_updated, 200);
    }

    #[test]
    fn oversized_k_is_an_error() {
        let mut cfg = tiny_cfg(Task::Pegasos, Engine::Treecv);
        cfg.ks = vec![9999];
        assert!(run_experiment(&cfg).is_err());
    }

    fn sweep_cfg(task: Task, grid: &str) -> ExperimentConfig {
        ExperimentConfig {
            ks: vec![4],
            repetitions: 2,
            threads: 2,
            sweep: Some(crate::config::SweepGrid::parse(grid).unwrap()),
            ..tiny_cfg(task, Engine::ParallelTreecv)
        }
    }

    #[test]
    fn sweep_ranks_by_mean_loss() {
        let report = run_sweep(&sweep_cfg(Task::Pegasos, "lambda=1e-3,1e-4,1e-5")).unwrap();
        assert_eq!(report.points.len(), 3);
        assert!(report.points.windows(2).all(|w| w[0].mean <= w[1].mean));
        assert!(report.points.iter().all(|p| p.mean.is_finite() && p.param == "lambda"));
        // Exactly one multi-worker pool for the whole sweep (counted
        // locally, so exact even with concurrent unit tests; the global
        // counter corroborates it in tests/integration_sweep.rs).
        assert_eq!(report.pool_spawns, 1);
        assert_eq!(report.threads, 2);
        let table = format_sweep_table(&report);
        assert!(table.contains("rank"));
        assert!(table.contains("pool_spawns="));
        assert_eq!(table.lines().count(), 2 + 3);
    }

    #[test]
    fn sweep_rejects_bad_grids() {
        // No grid at all.
        let mut cfg = sweep_cfg(Task::Pegasos, "lambda=1e-4");
        cfg.sweep = None;
        assert!(run_sweep(&cfg).is_err());
        // Unsupported task.
        assert!(run_sweep(&sweep_cfg(Task::Density, "lambda=1e-4")).is_err());
        // Wrong parameter for the task.
        assert!(run_sweep(&sweep_cfg(Task::Pegasos, "alpha=0.1")).is_err());
        // Non-positive values.
        assert!(run_sweep(&sweep_cfg(Task::Pegasos, "lambda=0")).is_err());
        // Multiple ks.
        let mut cfg = sweep_cfg(Task::Pegasos, "lambda=1e-4");
        cfg.ks = vec![4, 8];
        assert!(run_sweep(&cfg).is_err());
    }

    #[test]
    fn sweep_runs_every_sweepable_task() {
        for (task, grid) in [
            (Task::Pegasos, "lambda=1e-4,1e-5"),
            (Task::Ridge, "lambda=0.5,1.0"),
            (Task::Lsqsgd, "alpha=0.05,0.1"),
        ] {
            let report = run_sweep(&sweep_cfg(task, grid)).unwrap();
            assert_eq!(report.points.len(), 2, "{task:?}");
            assert!(report.points[0].mean.is_finite(), "{task:?}");
        }
    }

    #[test]
    fn table_formatting_contains_rows() {
        let cfg = tiny_cfg(Task::Pegasos, Engine::Treecv);
        let reports = run_experiment(&cfg).unwrap();
        let table = format_table(&reports);
        assert!(table.contains("pegasos"));
        assert!(table.contains("treecv"));
    }
}
